//! Forest specialization (λ = 1): Corollaries 27 / 29 / 31 live.
//!
//!     cargo run --release --example forest_matching [-- --n 100000]
//!
//! Demonstrates that maximum matchings give *optimal* correlation
//! clusterings on forests (verified against the exact solver on small
//! subsamples), and compares the maximal (2-approx) and (1+ε) matching
//! pipelines, including Remark 30's P4 tightness instance.

use arbocc::algorithms::forest::{clustering_from_matching, matching_clustering_cost};
use arbocc::algorithms::matching::{
    approx_matching, maximal_matching, maximum_matching_forest,
};
use arbocc::cluster::cost::cost;
use arbocc::cluster::exact::exact_cost;
use arbocc::graph::generators::{path, random_forest};
use arbocc::mpc::memory::Words;
use arbocc::mpc::{MpcConfig, MpcSimulator};
use arbocc::util::cli::Args;
use arbocc::util::rng::Rng;
use arbocc::util::table::{fnum, Table};

fn main() -> arbocc::util::error::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 100_000)?;
    let seed = args.get_u64("seed", 3)?;
    let mut rng = Rng::new(seed);

    // --- Corollary 27 on exactly-solvable instances -------------------
    println!("Corollary 27 check (maximum matching = OPT) on 20 random 12-vertex forests:");
    let mut ok = 0;
    for _ in 0..20 {
        let g = random_forest(12, 0.8, &mut rng);
        let m = maximum_matching_forest(&g);
        let c = clustering_from_matching(g.n(), &m);
        if cost(&g, &c).total() == exact_cost(&g) {
            ok += 1;
        }
    }
    println!("  {ok}/20 matched the exact optimum\n");
    assert_eq!(ok, 20);

    // --- The big forest ------------------------------------------------
    let g = random_forest(n, 0.9, &mut rng);
    println!("random forest: n={} m={}", g.n(), g.m());
    let sim = || MpcSimulator::new(MpcConfig::model1(g.n(), (g.n() + 2 * g.m()) as Words, 0.5));

    let mut table = Table::new(
        "forest correlation clustering via matchings",
        &["algorithm", "|M|", "cost", "vs opt", "MPC rounds"],
    );

    let m_star = maximum_matching_forest(&g);
    let opt_cost = matching_clustering_cost(g.m(), m_star.len());
    table.row(&[
        "maximum matching (OPT, Cor. 27)".into(),
        m_star.len().to_string(),
        opt_cost.to_string(),
        "1.000".into(),
        "-".into(),
    ]);

    let mut s1 = sim();
    let maximal = maximal_matching(&g, &mut rng, &mut s1, 64);
    let maximal_cost = matching_clustering_cost(g.m(), maximal.matching.len());
    table.row(&[
        "maximal matching (2-approx)".into(),
        maximal.matching.len().to_string(),
        maximal_cost.to_string(),
        fnum(maximal_cost as f64 / opt_cost.max(1) as f64),
        s1.n_rounds().to_string(),
    ]);

    for eps in [1.0, 0.5, 0.25] {
        let mut s = sim();
        let run = approx_matching(&g, maximal.matching.clone(), eps, &mut s);
        let c = matching_clustering_cost(g.m(), run.matching.len());
        table.row(&[
            format!("(1+{eps})-approx matching"),
            run.matching.len().to_string(),
            c.to_string(),
            fnum(c as f64 / opt_cost.max(1) as f64),
            s.n_rounds().to_string(),
        ]);
        // Lemma 29's guarantee, checked.
        assert!(
            (1.0 + eps) * run.matching.len() as f64 + 1e-9 >= m_star.len() as f64,
            "(1+ε)|M| ≥ |M*| violated"
        );
    }
    table.print();

    // --- Remark 30 tightness -------------------------------------------
    println!("\nRemark 30 (P4 tightness): maximal matching can be 2× off:");
    let p4 = path(4);
    let worst_maximal = vec![(1u32, 2u32)]; // the middle edge is maximal
    let best = maximum_matching_forest(&p4);
    println!(
        "  P4: worst maximal cost = {}, optimum cost = {} (ratio {})",
        matching_clustering_cost(p4.m(), worst_maximal.len()),
        matching_clustering_cost(p4.m(), best.len()),
        fnum(
            matching_clustering_cost(p4.m(), worst_maximal.len()) as f64
                / matching_clustering_cost(p4.m(), best.len()) as f64
        )
    );
    println!("forest_matching OK");
    Ok(())
}
