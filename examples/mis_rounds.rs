//! Greedy-MIS round complexity study (Theorem 24 / Theorem 6).
//!
//!     cargo run --release --example mis_rounds [-- --sizes 1024,4096,16384]
//!
//! For each workload size, runs the three pipelines on the *same*
//! permutation — direct Fischer–Noever simulation (O(log n) rounds),
//! Algorithm 1 + Algorithm 2 (Model 1) and Algorithm 1 + Algorithm 3
//! (Model 2) — verifies they compute the *identical* MIS, and reports
//! simulated round counts.

use arbocc::algorithms::greedy_mis::greedy_mis;
use arbocc::algorithms::mpc_mis::{
    alg1_greedy_mis, direct_simulation_mis, Alg1Params, Alg2Params, Alg3Params, Subroutine,
};
use arbocc::graph::generators::Family;
use arbocc::mpc::memory::Words;
use arbocc::mpc::{MpcConfig, MpcSimulator};
use arbocc::util::cli::Args;
use arbocc::util::rng::Rng;
use arbocc::util::table::Table;

fn main() -> arbocc::util::error::Result<()> {
    let args = Args::from_env();
    let sizes = args.get_list("sizes", &[1024usize, 4096, 16384])?;
    let lambda = args.get_usize("lambda", 3)?;
    let seed = args.get_u64("seed", 11)?;

    let mut table = Table::new(
        &format!("greedy MIS rounds on arboric-{lambda} graphs (same π per row)"),
        &["n", "Δ", "direct (M1)", "Alg1+Alg2 (M1)", "Alg1+Alg3 (M2)", "identical MIS"],
    );

    for &n in &sizes {
        let mut rng = Rng::new(seed ^ n as u64);
        let g = Family::LambdaArboric(lambda).generate(n, &mut rng);
        let perm = rng.permutation(g.n());
        let words = (g.n() + 2 * g.m()) as Words;
        let reference = greedy_mis(&g, &perm);

        let mut s_direct = MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));
        let direct = direct_simulation_mis(&g, &perm, &mut s_direct);

        let mut s2 = MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));
        let run2 = alg1_greedy_mis(
            &g,
            &perm,
            &Alg1Params { c_prefix: 1.0, subroutine: Subroutine::Alg2(Alg2Params::default()) },
            &mut s2,
        );

        let mut s3 = MpcSimulator::new(MpcConfig::model2(g.n(), words, 0.5));
        let run3 = alg1_greedy_mis(
            &g,
            &perm,
            &Alg1Params { c_prefix: 1.0, subroutine: Subroutine::Alg3(Alg3Params::default()) },
            &mut s3,
        );

        let identical = direct == reference && run2.in_mis == reference && run3.in_mis == reference;
        assert!(identical, "MPC simulations must reproduce sequential greedy MIS exactly");
        table.row(&[
            n.to_string(),
            g.max_degree().to_string(),
            s_direct.n_rounds().to_string(),
            s2.n_rounds().to_string(),
            s3.n_rounds().to_string(),
            "yes".into(),
        ]);
    }
    table.print();
    println!("\ndirect grows with log n; Alg3's count reflects gather (loglog n) + logΔ sweeps.");
    Ok(())
}
