//! Scale-free network clustering — the paper's motivating workload (§1:
//! "real life networks, such as those modelled by scale-free network
//! models (such as Barabási-Albert), admit structures with a few high
//! degree nodes and a small average degree").
//!
//!     cargo run --release --example scale_free_clustering [-- --n 50000]
//!
//! Head-to-head on Barabási–Albert graphs: sequential PIVOT, Algorithm 4
//! + PIVOT, the full MPC pipeline, the O(λ²) simple algorithm, and the
//! §1.4 baselines — cost ratios against the bad-triangle lower bound and
//! simulated MPC rounds.

use arbocc::algorithms::alg4::alg4;
use arbocc::algorithms::baselines::{c4, clusterwild, parallel_pivot};
use arbocc::algorithms::mpc_mis::{mpc_pivot, Alg1Params, Alg2Params, Subroutine};
use arbocc::algorithms::pivot::{pivot_random, pivot};
use arbocc::algorithms::simple::simple_clustering;
use arbocc::cluster::cost::cost;
use arbocc::cluster::triangles::packing_lower_bound;
use arbocc::graph::arboricity::estimate_arboricity;
use arbocc::graph::generators::barabasi_albert;
use arbocc::mpc::memory::Words;
use arbocc::mpc::{MpcConfig, MpcSimulator};
use arbocc::util::cli::Args;
use arbocc::util::rng::Rng;
use arbocc::util::table::{fnum, Table};

fn main() -> arbocc::util::error::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 50_000)?;
    let m_attach = args.get_usize("attach", 3)?;
    let seed = args.get_u64("seed", 7)?;

    let mut rng = Rng::new(seed);
    let g = barabasi_albert(n, m_attach, &mut rng);
    let est = estimate_arboricity(&g);
    let lambda = est.degeneracy.max(1);
    let lb = packing_lower_bound(&g);
    println!(
        "Barabási–Albert n={} m={} Δ={} λ∈[{},{}] triangle-LB={}",
        g.n(),
        g.m(),
        g.max_degree(),
        est.density_lower_bound,
        est.degeneracy,
        lb
    );
    println!("note: Δ/λ = {:.1} — exactly the regime where Theorem 12 pays off\n", g.max_degree() as f64 / lambda as f64);

    let sim = |g: &arbocc::graph::Graph| {
        MpcSimulator::new(MpcConfig::model1(g.n(), (g.n() + 2 * g.m()) as Words, 0.5))
    };

    let mut table = Table::new(
        "scale-free clustering head-to-head",
        &["algorithm", "cost", "ratio≤", "clusters", "MPC rounds"],
    );
    let mut add = |name: &str, total: u64, clusters: usize, rounds: Option<usize>| {
        table.row(&[
            name.to_string(),
            total.to_string(),
            fnum(total as f64 / lb.max(1) as f64),
            clusters.to_string(),
            rounds.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
        ]);
    };

    // Sequential PIVOT.
    let c = pivot_random(&g, &mut rng);
    add("PIVOT (sequential)", cost(&g, &c).total(), c.n_clusters(), None);

    // Algorithm 4 + PIVOT (the paper's Corollary 28 shape, ε = 2).
    let c = alg4(&g, lambda, 2.0, |sub| pivot_random(sub, &mut rng));
    add("Alg4 + PIVOT (ε=2)", cost(&g, &c).total(), c.n_clusters(), None);

    // Full MPC pipeline (Model 1, Algorithm 1 + Algorithm 2).
    let perm = rng.permutation(g.n());
    let mut s = sim(&g);
    let run = mpc_pivot(
        &g,
        &perm,
        &Alg1Params { c_prefix: 1.0, subroutine: Subroutine::Alg2(Alg2Params::default()) },
        &mut s,
    );
    add("MPC PIVOT (Alg1+Alg2, M1)", cost(&g, &run.clustering).total(), run.clustering.n_clusters(), Some(s.n_rounds()));
    // Exactness of the simulation (the paper's key property).
    assert_eq!(run.clustering.normalize(), pivot(&g, &perm).normalize());

    // O(λ²) simple algorithm (Corollary 32).
    let mut s = sim(&g);
    let simple = simple_clustering(&g, lambda, &mut s);
    add("Simple (Cor. 32)", cost(&g, &simple.clustering).total(), simple.clustering.n_clusters(), Some(simple.rounds));

    // Baselines (§1.4).
    let mut s = sim(&g);
    let r = c4::c4(&g, &perm, 0.9, &mut s);
    add("C4 (PPORRJ)", cost(&g, &r.clustering).total(), r.clustering.n_clusters(), Some(r.rounds));

    let mut s = sim(&g);
    let r = clusterwild::clusterwild(&g, &perm, 0.9, &mut s);
    add("ClusterWild! (PPORRJ)", cost(&g, &r.clustering).total(), r.clustering.n_clusters(), Some(r.rounds));

    let mut s = sim(&g);
    let r = parallel_pivot::parallel_pivot(&g, &perm, 0.5, &mut rng, &mut s);
    add("ParallelPivot (CDK)", cost(&g, &r.clustering).total(), r.clustering.n_clusters(), Some(r.rounds));

    table.print();
    println!("\n'ratio≤' is cost / bad-triangle-packing LB — an upper bound on the true ratio.");
    Ok(())
}
