//! Quickstart: the 60-second tour of the arbocc public API.
//!
//!     cargo run --release --example quickstart
//!
//! Generates a bounded-arboricity graph, estimates λ, runs the paper's
//! Algorithm 4 with PIVOT inside, scores the result against the
//! bad-triangle lower bound, and applies the Lemma 25 structural
//! transform.

use arbocc::algorithms::alg4::{alg4, degree_threshold};
use arbocc::algorithms::pivot::pivot_random;
use arbocc::cluster::cost::cost;
use arbocc::cluster::structural::bound_cluster_sizes;
use arbocc::cluster::triangles::packing_lower_bound;
use arbocc::graph::arboricity::estimate_arboricity;
use arbocc::graph::generators::lambda_arboric;
use arbocc::util::rng::Rng;

fn main() {
    // 1. A graph whose positive edges are 3-arboric (union of 3 random
    //    spanning trees), 50k vertices.
    let mut rng = Rng::new(2021);
    let g = lambda_arboric(50_000, 3, &mut rng);
    println!("graph: n={} m={} Δ={}", g.n(), g.m(), g.max_degree());

    // 2. Estimate arboricity: λ is sandwiched by a Nash-Williams density
    //    witness and the degeneracy.
    let est = estimate_arboricity(&g);
    let (lo, hi) = est.bounds();
    println!("arboricity: λ ∈ [{lo}, {hi}] (degeneracy {})", est.degeneracy);
    let lambda = hi;

    // 3. Algorithm 4 (Theorem 26): singleton out vertices with degree
    //    above 8(1+ε)λ/ε, run PIVOT on the bounded-degree rest.
    let eps = 2.0;
    println!(
        "Algorithm 4: ε={eps}, threshold d(v) > {:.0}",
        degree_threshold(lambda, eps)
    );
    let clustering = alg4(&g, lambda, eps, |sub| pivot_random(sub, &mut rng));

    // 4. Score it. Bad-triangle packings lower-bound every clustering,
    //    so cost/LB upper-bounds the true approximation ratio.
    let c = cost(&g, &clustering);
    let lb = packing_lower_bound(&g);
    println!(
        "cost = {} ({} positive + {} negative disagreements), {} clusters",
        c.total(),
        c.positive,
        c.negative,
        clustering.n_clusters()
    );
    println!(
        "lower bound = {lb} ⇒ measured ratio ≤ {:.3} (paper: 3 in expectation)",
        c.total() as f64 / lb as f64
    );

    // 5. Lemma 25 in action: the structural transform never increases
    //    cost and caps cluster sizes at 4λ−2.
    let res = bound_cluster_sizes(&g, &clustering, lambda);
    let c2 = cost(&g, &res.clustering);
    println!(
        "structural transform: {} moves, max cluster {} ≤ {}, cost {} (≤ {})",
        res.moves,
        res.max_cluster_size,
        4 * lambda - 2,
        c2.total(),
        c.total()
    );
    assert!(c2.total() <= c.total());
    assert!(res.max_cluster_size <= 4 * lambda - 2);
    println!("quickstart OK");
}
