//! Community detection on planted partitions — the use-case the paper's
//! introduction motivates ("community detection and link prediction").
//!
//!     cargo run --release --example community_detection
//!
//! A planted-partition graph has k ground-truth communities; positive
//! edges appear with probability p_in inside and p_out across.  We run
//! the paper's pipeline (Algorithm 4 + PIVOT, best-of-K) plus the
//! local-search extension and report both the correlation-clustering
//! objective and *recovery* metrics (adjusted Rand index, pairwise F1)
//! against the planted truth, across a noise sweep.

use std::sync::Arc;

use arbocc::algorithms::local_search::local_search;
use arbocc::cluster::metrics::{adjusted_rand_index, pairwise_f1};
use arbocc::cluster::cost::cost;
use arbocc::cluster::Clustering;
use arbocc::coordinator::{best_of_k, TrialSpec};
use arbocc::graph::arboricity::estimate_arboricity;
use arbocc::graph::generators::planted_partition;
use arbocc::runtime::CostEngine;
use arbocc::util::cli::Args;
use arbocc::util::rng::Rng;
use arbocc::util::table::{fnum, Table};

fn main() -> arbocc::util::error::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 4_000)?;
    let k = args.get_usize("k", 400)?; // communities of size 10
    let seed = args.get_u64("seed", 17)?;
    let engine = CostEngine::native();

    let mut table = Table::new(
        &format!("community detection, planted partition n={n}, k={k} (|C|=10)"),
        &["p_in", "p_out", "λ̂", "truth cost", "ours cost", "+local search", "ARI", "F1"],
    );

    for &(p_in, p_out) in &[(0.95, 0.0002), (0.85, 0.001), (0.7, 0.002), (0.55, 0.004)] {
        let mut rng = Rng::new(seed);
        let (g, truth_labels) = planted_partition(n, k, p_in, p_out, &mut rng);
        let truth = Clustering::from_labels(truth_labels);
        let est = estimate_arboricity(&g);
        let lambda = est.degeneracy.max(1);

        let garc = Arc::new(g);
        let bok = best_of_k(
            &garc,
            &TrialSpec::Alg4Pivot { lambda, eps: 2.0 },
            8,
            4,
            seed ^ 0xBEEF,
            &engine,
        )?;
        let refined = local_search(&garc, &bok.best, 10);
        let ari = adjusted_rand_index(&refined.clustering, &truth);
        let (_, _, f1) = pairwise_f1(&refined.clustering, &truth);
        table.row(&[
            p_in.to_string(),
            p_out.to_string(),
            lambda.to_string(),
            cost(&garc, &truth).total().to_string(),
            bok.best_cost.total().to_string(),
            refined.final_cost.to_string(),
            fnum(ari),
            fnum(f1),
        ]);
        // Low noise ⇒ near-perfect recovery.
        if p_in >= 0.9 {
            assert!(ari > 0.9, "low-noise recovery should be near-perfect (ARI {ari})");
        }
        assert!(refined.final_cost <= bok.best_cost.total());
    }
    table.print();
    println!("\nARI/F1 measure recovery of the planted communities; 'truth cost' is the");
    println!("objective value of the planted clustering itself (not necessarily optimal).");
    println!("community_detection OK");
    Ok(())
}
