//! End-to-end driver: the full three-layer system on a realistic
//! workload.  **This is the repo's headline validation run** (recorded in
//! EXPERIMENTS.md).
//!
//!     make artifacts && cargo run --release --example end_to_end
//!
//! Pipeline (all layers composing):
//!   1. workload: a Barabási–Albert scale-free graph (the paper's §1
//!      motivation) with ~2^16 vertices;
//!   2. substrate: arboricity estimation ⇒ λ;
//!   3. L3 algorithms on the MPC simulator: Algorithm 4 high-degree
//!      filtering + Algorithm 1/2 greedy-MIS + PIVOT join, with measured
//!      rounds checked against the O(log λ · polyloglog n) budget;
//!   4. coordinator: Remark 14 best-of-K across worker threads;
//!   5. L1/L2 via PJRT: every candidate clustering scored through the
//!      AOT-compiled JAX/Pallas cost kernels (exact dense-block protocol),
//!      cross-checked against the native twin;
//!   6. report: cost, certified ratio (vs bad-triangle packing LB),
//!      rounds, and scoring throughput.

use std::sync::Arc;

use arbocc::algorithms::mpc_mis::{mpc_pivot, Alg1Params, Alg2Params, Subroutine};
use arbocc::cluster::cost::cost;
use arbocc::cluster::triangles::packing_lower_bound;
use arbocc::coordinator::{best_of_k, TrialSpec};
use arbocc::graph::arboricity::estimate_arboricity;
use arbocc::graph::generators::barabasi_albert;
use arbocc::mpc::memory::Words;
use arbocc::mpc::{MpcConfig, MpcSimulator};
use arbocc::runtime::{BackendKind, CostEngine};
use arbocc::util::cli::Args;
use arbocc::util::json::{write_report, Json};
use arbocc::util::rng::Rng;
use arbocc::util::timer::Timer;

fn main() -> arbocc::util::error::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 1 << 16)?;
    let k = args.get_usize("k", 8)?;
    let workers = args.get_usize("workers", 4)?;
    let seed = args.get_u64("seed", 2021)?;

    println!("=== arbocc end-to-end driver ===\n");

    // -- 1/2: workload + arboricity --------------------------------------
    let mut rng = Rng::new(seed);
    let t_gen = Timer::start();
    let g = barabasi_albert(n, 3, &mut rng);
    let est = estimate_arboricity(&g);
    let lambda = est.degeneracy.max(1);
    println!(
        "[1] workload: BA(n={}, m=3): m={} Δ={}  ({:.2}s)",
        g.n(),
        g.m(),
        g.max_degree(),
        t_gen.elapsed_s()
    );
    println!(
        "[2] arboricity: λ ∈ [{}, {}] — Δ/λ = {:.0}× (Theorem 12 regime)",
        est.density_lower_bound,
        est.degeneracy,
        g.max_degree() as f64 / lambda as f64
    );

    // -- 3: MPC pipeline with round accounting ---------------------------
    let words = (g.n() + 2 * g.m()) as Words;
    let mut sim = MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));
    let perm = rng.permutation(g.n());
    let t_mpc = Timer::start();
    let run = mpc_pivot(
        &g,
        &perm,
        &Alg1Params { c_prefix: 1.0, subroutine: Subroutine::Alg2(Alg2Params::default()) },
        &mut sim,
    );
    let mpc_cost = cost(&g, &run.clustering);
    let loglog = (g.n() as f64).log2().log2();
    let budget = ((lambda.max(2) as f64).log2() + 1.0) * loglog.powi(3) * 8.0;
    println!(
        "[3] MPC PIVOT (M1, Alg1+Alg2): cost={} rounds={} (≤ budget 8·logλ·(loglog n)³ = {:.0}: {})  ({:.2}s)",
        mpc_cost.total(),
        sim.n_rounds(),
        budget,
        if (sim.n_rounds() as f64) <= budget { "PASS" } else { "over — see EXPERIMENTS.md" },
        t_mpc.elapsed_s()
    );
    println!(
        "    phases: {} | peak machine words {} / S={} | total comm {}",
        run.mis_run.phases.len(),
        sim.peak_machine_words(),
        sim.config.s_words,
        sim.total_communication()
    );

    // -- 4/5: coordinator + PJRT scoring ----------------------------------
    let engine = CostEngine::auto_default();
    println!("[4] coordinator: best-of-{k} over {workers} workers; backend {:?}", engine.kind());
    if engine.kind() == BackendKind::Native {
        println!("    (run `make artifacts` to exercise the PJRT path)");
    }
    let g = Arc::new(g);
    let t_bok = Timer::start();
    let bok = best_of_k(&g, &TrialSpec::Alg4Pivot { lambda, eps: 2.0 }, k, workers, seed, &engine)?;
    let bok_s = t_bok.elapsed_s();
    let worst = *bok.costs.iter().max().unwrap();
    println!(
        "[5] scored {k} candidates in {:.2}s ({:.1}/s): best={} worst={} (spread {:.1}%)",
        bok_s,
        k as f64 / bok_s,
        bok.best_cost.total(),
        worst,
        100.0 * (worst - bok.best_cost.total()) as f64 / worst.max(1) as f64
    );
    // Cross-check engine vs sparse formula on the winner.
    let sparse = cost(&g, &bok.best);
    assert_eq!(sparse.total(), bok.best_cost.total(), "engine and sparse cost must agree");

    // -- 6: certified ratio ----------------------------------------------
    let t_lb = Timer::start();
    let lb = packing_lower_bound(&g);
    let ratio = bok.best_cost.total() as f64 / lb.max(1) as f64;
    println!(
        "[6] bad-triangle packing LB={} ({:.2}s) ⇒ certified ratio ≤ {:.3} (paper: 3 in expectation)",
        lb,
        t_lb.elapsed_s(),
        ratio
    );

    // Report for EXPERIMENTS.md.
    let mut report = Json::obj();
    report
        .set("n", Json::num(g.n() as f64))
        .set("m", Json::num(g.m() as f64))
        .set("max_degree", Json::num(g.max_degree() as f64))
        .set("lambda_lo", Json::num(est.density_lower_bound as f64))
        .set("lambda_hi", Json::num(est.degeneracy as f64))
        .set("mpc_rounds", Json::num(sim.n_rounds() as f64))
        .set("mpc_cost", Json::num(mpc_cost.total() as f64))
        .set("best_of_k", Json::num(bok.best_cost.total() as f64))
        .set("lower_bound", Json::num(lb as f64))
        .set("certified_ratio", Json::num(ratio))
        .set("backend", Json::str(format!("{:?}", engine.kind())));
    let path = write_report("end_to_end", &report)?;
    println!("\nreport written to {}", path.display());
    assert!(ratio <= 3.0, "certified ratio should be well under the 3x bound on BA graphs");
    println!("end_to_end OK");
    Ok(())
}
