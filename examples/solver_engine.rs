//! The unified solver engine in one sitting:
//!
//!     cargo run --release --example solver_engine
//!
//! Builds a deliberately mixed workload — a forest, a grid, a scale-free
//! graph and a handful of cliques, all disjoint — then lets the engine
//! decompose it into components, route every component through the
//! planner's Theorem 26 / Corollary 27–32 decision tree, solve the
//! components concurrently on the shard pool, and stitch one clustering
//! back together. The same request then runs the Remark 14 best-of-K
//! driver over any registered solver.

use std::sync::Arc;

use arbocc::cluster::cost::cost;
use arbocc::coordinator::best_of_k_solver;
use arbocc::graph::generators::{
    barabasi_albert, clique, disjoint_union, grid, random_forest,
};
use arbocc::runtime::CostEngine;
use arbocc::solve::{solve_decomposed, DriverConfig, SolveRequest, SolverRegistry};
use arbocc::util::rng::Rng;

fn main() {
    // 1. A mixed workload: four families, one graph, no cross edges.
    let mut rng = Rng::new(2021);
    let g = disjoint_union(&[
        random_forest(5_000, 0.95, &mut rng),
        grid(60, 60),
        barabasi_albert(8_000, 3, &mut rng),
        clique(6),
        clique(5),
    ]);
    println!("workload: n={} m={} Δ={}", g.n(), g.m(), g.max_degree());

    // 2. One request, planner-routed per component, solved on all
    //    hardware threads. The plan trace shows every routing decision.
    let registry = SolverRegistry::standard();
    let req = SolveRequest { seed: 7, ..SolveRequest::new(Arc::new(g)) };
    let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let report = solve_decomposed(&req, &DriverConfig::auto(shards), &registry)
        .expect("auto driver cannot fail");
    println!("plan:");
    for line in report.plan.iter().take(12) {
        println!("  {line}");
    }
    println!(
        "solver={} cost={} clusters={} wall={:.3}s",
        report.solver,
        report.cost.total(),
        report.clustering.n_clusters(),
        report.wall_s
    );
    assert_eq!(report.cost, cost(&req.graph, &report.clustering));

    // 3. Determinism: the stitched clustering is bit-identical at every
    //    shard count.
    let serial = solve_decomposed(&req, &DriverConfig::auto(1), &registry).unwrap();
    assert_eq!(serial.clustering.labels(), report.clustering.labels());
    println!("determinism OK: 1-shard and {shards}-shard runs are bit-identical");

    // 4. Remark 14 through the same API: 8 trials of any registered
    //    solver, scored on the cost engine, best kept.
    let mut best_req = req.clone();
    best_req.trials = 8;
    let solver = registry.get("alg4-pivot").expect("registered");
    let run = best_of_k_solver(&best_req, solver, shards, &CostEngine::native())
        .expect("best-of-k");
    println!(
        "best-of-8 (alg4-pivot): best={} worst={}",
        run.best_cost.total(),
        run.costs.iter().max().unwrap()
    );
    println!("solver_engine OK");
}
