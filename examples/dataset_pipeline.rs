//! The dataset subsystem in one sitting:
//!
//!     cargo run --release --example dataset_pipeline
//!
//! Addresses a workload by corpus spec, snapshots it to the
//! `arbocc-csr/v1` binary format, re-encodes it as a text edge list,
//! reloads both with auto-detection, and feeds the snapshot to the
//! unified solver engine — the same pipeline as
//!
//!     arbocc gen planted:n=2000,k=8,seed=7 -o g.csr
//!     arbocc convert g.csr g.edges
//!     arbocc solve --input g.csr --algo auto

use std::sync::Arc;

use arbocc::cluster::cost::cost;
use arbocc::cluster::triangles::packing_lower_bound;
use arbocc::data::corpus::{describe_families, WorkloadSpec};
use arbocc::data::{load_graph, save_graph};
use arbocc::solve::{solve_decomposed, DriverConfig, SolveRequest, SolverRegistry};

fn main() {
    // 1. The corpus: every workload family is addressable by string.
    println!("generator corpus ({} families):", describe_families().len());
    for line in describe_families().iter().take(5) {
        println!("  {line}");
    }
    println!("  …");

    // 2. Address one instance by spec and generate it.
    let spec = WorkloadSpec::parse("planted:n=2000,k=8,seed=7").expect("spec parses");
    let g = spec.generate().expect("spec generates");
    println!("\nworkload {}: n={} m={} Δ={}", spec.canonical(), g.n(), g.m(), g.max_degree());

    // 3. Snapshot + edge-list round trips through real files.
    let dir = std::env::temp_dir().join(format!("arbocc_pipeline_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let csr = dir.join("g.csr");
    let edges = dir.join("g.edges");
    let fmt = save_graph(&g, &csr).expect("write snapshot");
    println!("wrote {} ({fmt})", csr.display());
    let (from_csr, stats) = load_graph(&csr).expect("load snapshot");
    println!("reloaded: {}", stats.describe());
    assert_eq!(from_csr, g, "snapshot round-trip must be lossless");
    let fmt = save_graph(&from_csr, &edges).expect("write edge list");
    println!("converted to {} ({fmt})", edges.display());
    let (from_edges, stats) = load_graph(&edges).expect("load edge list");
    println!("reloaded: {}", stats.describe());
    assert_eq!(from_edges, g, "edge-list round-trip must be lossless");

    // 4. Feed the snapshot to the solver engine, exactly as
    //    `arbocc solve --input g.csr --algo auto` does.
    let registry = SolverRegistry::standard();
    let req = SolveRequest { seed: 7, ..SolveRequest::new(Arc::new(from_csr)) };
    let report = solve_decomposed(&req, &DriverConfig::auto(2), &registry)
        .expect("auto driver cannot fail");
    assert_eq!(report.cost, cost(&req.graph, &report.clustering));
    let lb = packing_lower_bound(&req.graph);
    println!(
        "\nsolver={} cost={} clusters={} (LB {lb} ⇒ ratio ≤ {:.3})",
        report.solver,
        report.cost.total(),
        report.clustering.n_clusters(),
        report.cost.total() as f64 / lb.max(1) as f64
    );

    let _ = std::fs::remove_dir_all(&dir);
    println!("dataset_pipeline OK");
}
