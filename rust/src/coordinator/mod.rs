//! Leader/worker coordinator.
//!
//! The deployment shape of `arbocc` on one host: worker threads run the
//! combinatorial algorithms (PIVOT trials, Algorithm 4 pipelines) in
//! parallel, while the **leader thread owns the PJRT engine** (the xla
//! crate's client is `Rc`-based and must not cross threads) and scores
//! candidate clusterings through the AOT executables.
//!
//! Substitution note (DESIGN.md §2): tokio is unavailable in the offline
//! registry; `std::thread` + `std::sync::mpsc` provide the same
//! leader/worker semantics for a single-host deployment.

pub mod best_of_k;

pub use best_of_k::{best_of_k, BestOfK, TrialSpec};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use crate::cluster::Clustering;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// A unit of worker output: trial id plus the produced clustering labels.
#[derive(Debug)]
pub struct TrialResult {
    pub trial: usize,
    pub clustering: Clustering,
}

/// Run `trials` independent clustering trials across `workers` threads.
///
/// `make` is the per-trial algorithm: it receives a trial-specific RNG
/// (forked deterministically from `base_seed`) and the shared graph.
/// Results arrive on the returned receiver in completion order; the
/// leader (caller) consumes them while workers keep producing —
/// backpressure is the channel itself.
pub fn run_trials<F>(
    g: Arc<Graph>,
    trials: usize,
    workers: usize,
    base_seed: u64,
    make: F,
) -> mpsc::Receiver<TrialResult>
where
    F: Fn(&Graph, &mut Rng) -> Clustering + Send + Sync + 'static,
{
    let (tx, rx) = mpsc::channel();
    let next = Arc::new(AtomicUsize::new(0));
    let make = Arc::new(make);
    for w in 0..workers.max(1) {
        let tx = tx.clone();
        let g = Arc::clone(&g);
        let next = Arc::clone(&next);
        let make = Arc::clone(&make);
        std::thread::Builder::new()
            .name(format!("arbocc-worker-{w}"))
            .spawn(move || loop {
                let trial = next.fetch_add(1, Ordering::Relaxed);
                if trial >= trials {
                    break;
                }
                // Deterministic per-trial stream regardless of which
                // worker picks the trial up.
                let mut rng = Rng::new(base_seed ^ (trial as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let clustering = make(&g, &mut rng);
                if tx.send(TrialResult { trial, clustering }).is_err() {
                    break; // leader hung up
                }
            })
            .expect("spawning worker thread");
    }
    rx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pivot::pivot_random;
    use crate::graph::generators::lambda_arboric;

    #[test]
    fn trials_are_deterministic_per_seed() {
        let mut rng = Rng::new(240);
        let g = Arc::new(lambda_arboric(120, 2, &mut rng));
        let collect = |workers: usize| -> Vec<Vec<u32>> {
            let rx = run_trials(Arc::clone(&g), 8, workers, 42, |g, rng| pivot_random(g, rng));
            let mut out: Vec<_> = rx.into_iter().collect();
            out.sort_by_key(|r| r.trial);
            out.into_iter().map(|r| r.clustering.normalize().labels().to_vec()).collect()
        };
        // Same trial results regardless of worker count / scheduling.
        assert_eq!(collect(1), collect(4));
    }

    #[test]
    fn all_trials_delivered() {
        let mut rng = Rng::new(241);
        let g = Arc::new(lambda_arboric(60, 1, &mut rng));
        let rx = run_trials(g, 20, 3, 7, |g, rng| pivot_random(g, rng));
        let got: Vec<_> = rx.into_iter().map(|r| r.trial).collect();
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }
}
