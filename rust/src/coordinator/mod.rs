//! Leader/worker coordinator.
//!
//! The deployment shape of `arbocc` on one host: the Remark 14 trials run
//! sharded across the same scoped-thread [`ShardPool`] that powers the
//! MPC executor, while the **leader thread owns the PJRT engine** (the
//! xla crate's client is `Rc`-based and must not cross threads) and
//! scores candidate clusterings through the AOT executables.
//!
//! Substitution note (DESIGN.md §2): tokio is unavailable in the offline
//! registry; `mpc::pool::ShardPool` (std scoped threads) provides the
//! worker fan-out for a single-host deployment.
//!
//! [`ShardPool`]: crate::mpc::pool::ShardPool

pub mod best_of_k;

pub use best_of_k::{best_of_k, best_of_k_solver, BestOfK, TrialSpec};

use crate::util::rng::Rng;

/// Deterministic per-trial seed: a function of `(base_seed, trial)`
/// only, never of which worker thread runs the trial — the single
/// source of the stream derivation, so trial results are identical at
/// every worker count. Solver-based trials feed this seed straight into
/// `SolveRequest::seed`; RNG-based trials wrap it via [`trial_rng`].
pub fn trial_seed(base_seed: u64, trial: usize) -> u64 {
    base_seed ^ (trial as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Deterministic per-trial RNG over [`trial_seed`].
pub fn trial_rng(base_seed: u64, trial: usize) -> Rng {
    Rng::new(trial_seed(base_seed, trial))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trial_streams_depend_on_trial_id_only() {
        // Re-deriving a trial's stream yields the identical sequence…
        let mut a = trial_rng(42, 3);
        let mut b = trial_rng(42, 3);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // …and distinct trials get decorrelated streams.
        let mut r0 = trial_rng(42, 0);
        let mut r1 = trial_rng(42, 1);
        let same = (0..64).filter(|_| r0.next_u64() == r1.next_u64()).count();
        assert!(same < 4);
    }
}
