//! The Remark 14 driver: run K independent PIVOT copies, keep the best.
//!
//! PIVOT's 3-approximation holds *in expectation*; running O(log n)
//! parallel copies and keeping the cheapest converts it to a
//! with-high-probability guarantee at a log-factor memory cost.  This is
//! the system's end-to-end hot path: workers produce K clusterings, the
//! leader scores them through the PJRT engine (batched when the graph
//! fits one dense block) and streams the running best.

use std::sync::Arc;

use anyhow::Result;

use crate::cluster::cost::Cost;
use crate::cluster::Clustering;
use crate::coordinator::run_trials;
use crate::graph::Graph;
use crate::runtime::blocks::BLOCK_N;
use crate::runtime::CostEngine;

/// What each trial runs.
#[derive(Debug, Clone)]
pub enum TrialSpec {
    /// Plain PIVOT with a fresh permutation.
    Pivot,
    /// Algorithm 4 with PIVOT inside (ε, λ).
    Alg4Pivot { lambda: usize, eps: f64 },
}

/// Outcome of a best-of-K run.
#[derive(Debug)]
pub struct BestOfK {
    pub best: Clustering,
    pub best_cost: Cost,
    /// Cost of every trial, indexed by trial id.
    pub costs: Vec<u64>,
}

/// Run K trials over `workers` threads and score on `engine`.
pub fn best_of_k(
    g: &Arc<Graph>,
    spec: &TrialSpec,
    k: usize,
    workers: usize,
    base_seed: u64,
    engine: &CostEngine,
) -> Result<BestOfK> {
    assert!(k >= 1);
    let spec2 = spec.clone();
    let rx = run_trials(Arc::clone(g), k, workers, base_seed, move |g, rng| match spec2 {
        TrialSpec::Pivot => crate::algorithms::pivot::pivot_random(g, rng),
        TrialSpec::Alg4Pivot { lambda, eps } => {
            crate::algorithms::alg4::alg4(g, lambda, eps, |sub| {
                crate::algorithms::pivot::pivot_random(sub, rng)
            })
        }
    });

    let single_block = g.n() <= BLOCK_N;
    let mut costs = vec![u64::MAX; k];
    let mut best: Option<(Clustering, Cost)> = None;

    if single_block {
        // Batch-friendly: buffer trials and score in kernel batches.
        let mut pending: Vec<(usize, Clustering)> = Vec::new();
        let flush = |pending: &mut Vec<(usize, Clustering)>,
                     costs: &mut Vec<u64>,
                     best: &mut Option<(Clustering, Cost)>|
         -> Result<()> {
            if pending.is_empty() {
                return Ok(());
            }
            let cs: Vec<Clustering> = pending.iter().map(|(_, c)| c.clone()).collect();
            let scored = engine.cost_batch_single_block(g, &cs)?;
            for ((trial, c), cost) in pending.drain(..).zip(scored) {
                costs[trial] = cost.total();
                if best.as_ref().map(|(_, b)| cost.total() < b.total()).unwrap_or(true) {
                    *best = Some((c, cost));
                }
            }
            Ok(())
        };
        for result in rx {
            pending.push((result.trial, result.clustering));
            if pending.len() >= crate::runtime::blocks::BLOCK_BATCH {
                flush(&mut pending, &mut costs, &mut best)?;
            }
        }
        flush(&mut pending, &mut costs, &mut best)?;
    } else {
        for result in rx {
            let cost = engine.cost(g, &result.clustering)?;
            costs[result.trial] = cost.total();
            if best.as_ref().map(|(_, b)| cost.total() < b.total()).unwrap_or(true) {
                best = Some((result.clustering, cost));
            }
        }
    }

    let (best, best_cost) = best.expect("k >= 1 produces at least one trial");
    Ok(BestOfK { best, best_cost, costs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::cost;
    use crate::graph::generators::lambda_arboric;
    use crate::util::rng::Rng;

    #[test]
    fn best_is_min_of_costs() {
        let mut rng = Rng::new(250);
        let g = Arc::new(lambda_arboric(150, 2, &mut rng));
        let engine = CostEngine::native();
        let run = best_of_k(&g, &TrialSpec::Pivot, 12, 3, 99, &engine).unwrap();
        assert_eq!(run.costs.len(), 12);
        assert!(run.costs.iter().all(|&c| c != u64::MAX));
        assert_eq!(run.best_cost.total(), *run.costs.iter().min().unwrap());
        // The returned clustering really has that cost.
        assert_eq!(cost(&g, &run.best).total(), run.best_cost.total());
    }

    #[test]
    fn more_trials_never_worse() {
        let mut rng = Rng::new(251);
        let g = Arc::new(lambda_arboric(300, 3, &mut rng));
        let engine = CostEngine::native();
        let small = best_of_k(&g, &TrialSpec::Pivot, 2, 2, 5, &engine).unwrap();
        let large = best_of_k(&g, &TrialSpec::Pivot, 16, 4, 5, &engine).unwrap();
        // Trials 0..2 are shared (deterministic per-trial seeds), so the
        // best over 16 ≤ best over 2.
        assert!(large.best_cost.total() <= small.best_cost.total());
    }

    #[test]
    fn alg4_trials_work() {
        let mut rng = Rng::new(252);
        let g = Arc::new(lambda_arboric(400, 3, &mut rng));
        let engine = CostEngine::native();
        let run =
            best_of_k(&g, &TrialSpec::Alg4Pivot { lambda: 3, eps: 2.0 }, 6, 2, 11, &engine)
                .unwrap();
        assert_eq!(run.best.n(), 400);
    }
}
