//! The Remark 14 driver: run K independent PIVOT copies, keep the best.
//!
//! PIVOT's 3-approximation holds *in expectation*; running O(log n)
//! parallel copies and keeping the cheapest converts it to a
//! with-high-probability guarantee at a log-factor memory cost.  This is
//! the system's end-to-end hot path: the K trials are sharded across the
//! same scoped-thread [`ShardPool`] that powers the MPC executor — each
//! trial's RNG stream is a function of the trial id alone, so results are
//! identical at every worker count — and the leader scores the candidates
//! through the PJRT engine (batched when the graph fits one dense block).

use std::sync::Arc;

use crate::cluster::cost::Cost;
use crate::cluster::Clustering;
use crate::coordinator::{trial_rng, trial_seed};
use crate::graph::Graph;
use crate::mpc::pool::ShardPool;
use crate::runtime::blocks::{BLOCK_BATCH, BLOCK_N};
use crate::runtime::CostEngine;
use crate::solve::{SolveCtx, SolveRequest, Solver};
use crate::util::error::Result;
use crate::util::rng::Rng;

/// What each trial runs.
#[derive(Debug, Clone)]
pub enum TrialSpec {
    /// Plain PIVOT with a fresh permutation.
    Pivot,
    /// Algorithm 4 with PIVOT inside (ε, λ).
    Alg4Pivot { lambda: usize, eps: f64 },
}

/// Outcome of a best-of-K run.
#[derive(Debug)]
pub struct BestOfK {
    pub best: Clustering,
    pub best_cost: Cost,
    /// Cost of every trial, indexed by trial id.
    pub costs: Vec<u64>,
}

fn run_trial(g: &Graph, spec: &TrialSpec, rng: &mut Rng) -> Clustering {
    match *spec {
        TrialSpec::Pivot => crate::algorithms::pivot::pivot_random(g, rng),
        TrialSpec::Alg4Pivot { lambda, eps } => {
            crate::algorithms::alg4::alg4(g, lambda, eps, |sub| {
                crate::algorithms::pivot::pivot_random(sub, rng)
            })
        }
    }
}

/// Run K trials over a `workers`-shard pool and score on `engine`.
///
/// Trials run in *waves* of a few batches each: a wave is produced in
/// parallel on the pool, scored by the leader, and dropped before the
/// next wave starts — so resident memory is bounded by the wave size,
/// not K, while per-trial seeds keep results identical at every worker
/// count and wave boundary. (Deliberate tradeoff: the wave barrier gives
/// up overlap between production and scoring in exchange for bounded
/// memory, a single fan-out mechanism, and a leader-affine engine — the
/// PJRT client must not cross threads.)
pub fn best_of_k(
    g: &Arc<Graph>,
    spec: &TrialSpec,
    k: usize,
    workers: usize,
    base_seed: u64,
    engine: &CostEngine,
) -> Result<BestOfK> {
    let graph: &Graph = g;
    best_of_k_with(g, k, workers, engine, |trial| {
        let mut rng = trial_rng(base_seed, trial);
        run_trial(graph, spec, &mut rng)
    })
}

/// The solver-engine generalization of [`best_of_k`]: run `req.trials`
/// independent copies of **any** registered [`Solver`], keep the best.
///
/// Each trial's request carries `trial_seed(req.seed, trial)` — the
/// same per-trial derivation the closure path uses — so results are
/// identical at every worker count, and a solver run through the
/// coordinator at trial seed `s` reproduces a standalone
/// `solver.solve` at seed `s`.
pub fn best_of_k_solver(
    req: &SolveRequest,
    solver: &dyn Solver,
    workers: usize,
    engine: &CostEngine,
) -> Result<BestOfK> {
    let k = req.trials.max(1);
    // Resolve the λ estimate once per run, not once per trial — the
    // degeneracy peel is O(n + m) and the graph is the same every time.
    let mut base = req.clone();
    if base.lambda.is_none() {
        base.lambda = Some(base.lambda_or_estimate());
    }
    best_of_k_with(&req.graph, k, workers, engine, |trial| {
        let trial_req =
            SolveRequest { seed: trial_seed(req.seed, trial), ..base.clone() };
        solver.solve(&trial_req, &mut SolveCtx::serial()).clustering
    })
}

/// Shared wave engine behind both entry points: `run(trial)` produces
/// candidate `trial`'s clustering (it must be a function of the trial
/// id only — never of scheduling).
fn best_of_k_with<F>(
    g: &Arc<Graph>,
    k: usize,
    workers: usize,
    engine: &CostEngine,
    run: F,
) -> Result<BestOfK>
where
    F: Fn(usize) -> Clustering + Sync,
{
    assert!(k >= 1);
    let pool = ShardPool::new(workers);
    let single_block = g.n() <= BLOCK_N;
    let wave_size = workers.max(1) * BLOCK_BATCH;

    let mut costs = vec![u64::MAX; k];
    let mut best: Option<(Clustering, Cost)> = None;
    let mut start = 0usize;
    while start < k {
        let end = (start + wave_size).min(k);
        // Produce this wave's candidates, sharded across the pool and
        // collected in trial order.
        let mut wave: Vec<Clustering> = pool
            .run(end - start, |_, range| {
                range.map(|i| run(start + i)).collect::<Vec<Clustering>>()
            })
            .into_iter()
            .flatten()
            .collect();

        // Leader half: score the wave.
        let scored: Vec<Cost> = if single_block {
            engine.cost_batch_single_block(g, &wave)?
        } else {
            let mut out = Vec::with_capacity(wave.len());
            for c in &wave {
                out.push(engine.cost(g, c)?);
            }
            out
        };
        // Record costs and fold the wave's first minimum into the running
        // best; ties break toward the lowest trial id, deterministic
        // regardless of worker count.
        let mut wave_best: Option<usize> = None;
        for (i, cost) in scored.iter().enumerate() {
            costs[start + i] = cost.total();
            if wave_best.map(|j| cost.total() < scored[j].total()).unwrap_or(true) {
                wave_best = Some(i);
            }
        }
        let i = wave_best.expect("non-empty wave");
        if best.as_ref().map(|(_, b)| scored[i].total() < b.total()).unwrap_or(true) {
            best = Some((wave.swap_remove(i), scored[i]));
        }
        start = end;
    }

    let (best, best_cost) = best.expect("k >= 1 produces at least one trial");
    Ok(BestOfK { best, best_cost, costs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::cost;
    use crate::graph::generators::lambda_arboric;
    use crate::util::rng::Rng;

    #[test]
    fn best_is_min_of_costs() {
        let mut rng = Rng::new(250);
        let g = Arc::new(lambda_arboric(150, 2, &mut rng));
        let engine = CostEngine::native();
        let run = best_of_k(&g, &TrialSpec::Pivot, 12, 3, 99, &engine).unwrap();
        assert_eq!(run.costs.len(), 12);
        assert!(run.costs.iter().all(|&c| c != u64::MAX));
        assert_eq!(run.best_cost.total(), *run.costs.iter().min().unwrap());
        // The returned clustering really has that cost.
        assert_eq!(cost(&g, &run.best).total(), run.best_cost.total());
    }

    #[test]
    fn more_trials_never_worse() {
        let mut rng = Rng::new(251);
        let g = Arc::new(lambda_arboric(300, 3, &mut rng));
        let engine = CostEngine::native();
        let small = best_of_k(&g, &TrialSpec::Pivot, 2, 2, 5, &engine).unwrap();
        let large = best_of_k(&g, &TrialSpec::Pivot, 16, 4, 5, &engine).unwrap();
        // Trials 0..2 are shared (deterministic per-trial seeds), so the
        // best over 16 ≤ best over 2.
        assert!(large.best_cost.total() <= small.best_cost.total());
    }

    #[test]
    fn alg4_trials_work() {
        let mut rng = Rng::new(252);
        let g = Arc::new(lambda_arboric(400, 3, &mut rng));
        let engine = CostEngine::native();
        let run =
            best_of_k(&g, &TrialSpec::Alg4Pivot { lambda: 3, eps: 2.0 }, 6, 2, 11, &engine)
                .unwrap();
        assert_eq!(run.best.n(), 400);
    }

    #[test]
    fn solver_path_matches_closure_path() {
        // TrialSpec::Pivot and the registered "pivot" solver share the
        // per-trial seed derivation, so the generalized path reproduces
        // the legacy closure path cost for cost.
        let mut rng = Rng::new(254);
        let g = Arc::new(lambda_arboric(180, 2, &mut rng));
        let engine = CostEngine::native();
        let via_spec = best_of_k(&g, &TrialSpec::Pivot, 6, 3, 17, &engine).unwrap();
        let req = SolveRequest { seed: 17, trials: 6, ..SolveRequest::new(g.clone()) };
        let solver = crate::solve::solvers::dispatch("pivot").unwrap();
        let via_solver = best_of_k_solver(&req, solver.as_ref(), 3, &engine).unwrap();
        assert_eq!(via_solver.costs, via_spec.costs);
        assert_eq!(via_solver.best_cost, via_spec.best_cost);
        assert_eq!(
            via_solver.best.normalize().labels(),
            via_spec.best.normalize().labels()
        );
    }

    #[test]
    fn solver_path_worker_count_invariant() {
        let mut rng = Rng::new(255);
        let g = Arc::new(lambda_arboric(150, 3, &mut rng));
        let engine = CostEngine::native();
        let req = SolveRequest { seed: 5, trials: 9, ..SolveRequest::new(g) };
        let solver = crate::solve::solvers::dispatch("alg4-pivot").unwrap();
        let one = best_of_k_solver(&req, solver.as_ref(), 1, &engine).unwrap();
        for workers in [2usize, 8] {
            let many = best_of_k_solver(&req, solver.as_ref(), workers, &engine).unwrap();
            assert_eq!(many.costs, one.costs, "{workers} workers");
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let mut rng = Rng::new(253);
        let g = Arc::new(lambda_arboric(200, 2, &mut rng));
        let engine = CostEngine::native();
        let one = best_of_k(&g, &TrialSpec::Pivot, 9, 1, 41, &engine).unwrap();
        for workers in [2usize, 4, 8] {
            let many = best_of_k(&g, &TrialSpec::Pivot, 9, workers, 41, &engine).unwrap();
            assert_eq!(many.costs, one.costs, "{workers} workers");
            assert_eq!(many.best_cost, one.best_cost, "{workers} workers");
            assert_eq!(
                many.best.normalize().labels(),
                one.best.normalize().labels(),
                "{workers} workers"
            );
        }
    }
}
