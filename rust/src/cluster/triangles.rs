//! Bad triangles: counting and greedy edge-disjoint packing.
//!
//! A bad triangle {u, v, w} has uv, vw ∈ E+ and uw ∉ E+ (§1: the negative
//! edge is implicit).  Every clustering pays ≥ 1 disagreement per bad
//! triangle, and *edge-disjoint* bad triangles charge disjoint
//! disagreements, so a packing certifies `OPT ≥ packing size` — the
//! cost-charging currency behind PIVOT's 3-approximation.  We provide
//!
//! * [`count_bad_triangles`] — exact count in O(Σ_v deg(v)²), the sparse
//!   twin of the L1 `triangles` kernel;
//! * [`greedy_packing`] — maximal edge-disjoint packing, our LP-free lower
//!   bound for approximation-ratio experiments.

use crate::graph::Graph;

/// Exact bad-triangle count.  Enumerates 2-paths u–v–w (u < w) and checks
/// that the closing pair is non-adjacent.
pub fn count_bad_triangles(g: &Graph) -> u64 {
    let mut count = 0u64;
    for v in 0..g.n() as u32 {
        let nb = g.neighbors(v);
        for (i, &u) in nb.iter().enumerate() {
            for &w in &nb[i + 1..] {
                if !g.has_edge(u, w) {
                    count += 1;
                }
            }
        }
    }
    count
}

/// A packed bad triangle: (u, v, w) with positive edges uv, vw and
/// implicit negative uw.
pub type BadTriangle = (u32, u32, u32);

/// Greedy maximal edge-disjoint bad-triangle packing.
///
/// Disjointness covers *all* edges of the complete signed graph: the two
/// positive edges and the implicit negative pair may each be used by only
/// one packed triangle.  Any maximal packing is a valid lower bound on
/// OPT; greedy over a deterministic sweep keeps experiments reproducible.
pub fn greedy_packing(g: &Graph) -> Vec<BadTriangle> {
    // Ordered sets: the sweep itself is deterministic, and keeping hash
    // containers out of the lower-bound certifier makes that auditable.
    let mut used_pos: std::collections::BTreeSet<(u32, u32)> = std::collections::BTreeSet::new();
    let mut used_neg: std::collections::BTreeSet<(u32, u32)> = std::collections::BTreeSet::new();
    let key = |a: u32, b: u32| if a < b { (a, b) } else { (b, a) };
    let mut packing = Vec::new();
    for v in 0..g.n() as u32 {
        let nb = g.neighbors(v);
        for (i, &u) in nb.iter().enumerate() {
            if used_pos.contains(&key(u, v)) {
                continue;
            }
            for &w in &nb[i + 1..] {
                if used_pos.contains(&key(v, w)) || g.has_edge(u, w) {
                    continue;
                }
                if used_neg.contains(&key(u, w)) {
                    continue;
                }
                used_pos.insert(key(u, v));
                used_pos.insert(key(v, w));
                used_neg.insert(key(u, w));
                packing.push((u, v, w));
                break; // positive edge (u,v) is now consumed
            }
        }
    }
    packing
}

/// Lower bound on OPT from the greedy packing. Returns `max(packing, 1)`
/// when the graph has at least one bad triangle, else the packing size
/// (possibly 0 — e.g. unions of cliques have OPT candidates at cost 0).
pub fn packing_lower_bound(g: &Graph) -> u64 {
    greedy_packing(g).len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{barbell, clique, lambda_arboric, path, star};
    use crate::util::rng::Rng;

    #[test]
    fn path3_is_one_bad_triangle() {
        let g = path(3);
        assert_eq!(count_bad_triangles(&g), 1);
        assert_eq!(greedy_packing(&g).len(), 1);
    }

    #[test]
    fn clique_has_none() {
        let g = clique(8);
        assert_eq!(count_bad_triangles(&g), 0);
        assert!(greedy_packing(&g).is_empty());
    }

    #[test]
    fn star_counts_choose_two() {
        // Star K_{1,k}: every pair of leaves forms a bad triangle.
        let g = star(6);
        assert_eq!(count_bad_triangles(&g), 15);
        // Packing is limited by positive-edge disjointness: each leaf edge
        // used once => floor(6/2) = 3 triangles.
        assert_eq!(greedy_packing(&g).len(), 3);
    }

    #[test]
    fn packing_is_edge_disjoint() {
        let mut rng = Rng::new(20);
        let g = lambda_arboric(200, 3, &mut rng);
        let packing = greedy_packing(&g);
        let mut pos = std::collections::HashSet::new();
        let mut neg = std::collections::HashSet::new();
        let key = |a: u32, b: u32| if a < b { (a, b) } else { (b, a) };
        for &(u, v, w) in &packing {
            assert!(g.has_edge(u, v) && g.has_edge(v, w) && !g.has_edge(u, w));
            assert!(pos.insert(key(u, v)), "positive edge reused");
            assert!(pos.insert(key(v, w)), "positive edge reused");
            assert!(neg.insert(key(u, w)), "negative pair reused");
        }
    }

    #[test]
    fn packing_at_most_count() {
        let mut rng = Rng::new(21);
        for lambda in [1usize, 2, 4] {
            let g = lambda_arboric(100, lambda, &mut rng);
            assert!(packing_lower_bound(&g) <= count_bad_triangles(&g));
        }
    }

    #[test]
    fn barbell_has_bad_triangles_only_at_bridge() {
        let g = barbell(4);
        // Bridge edge (0, 4): bad triangles are {x,0,4} for x clique
        // neighbor of 0, and {0,4,y} for y clique neighbor of 4: 3 + 3.
        assert_eq!(count_bad_triangles(&g), 6);
    }
}
