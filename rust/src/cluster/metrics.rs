//! Clustering-quality metrics beyond disagreement cost.
//!
//! Used by the community-detection (planted partition) experiment: when a
//! ground-truth clustering exists, we can measure how well correlation
//! clustering *recovers* it — the use-case the paper's introduction
//! motivates (community detection, link prediction).

use crate::cluster::Clustering;

/// Pair-counting confusion between a predicted and a reference clustering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PairConfusion {
    /// Pairs together in both.
    pub tt: u64,
    /// Together in prediction, apart in reference.
    pub tf: u64,
    /// Apart in prediction, together in reference.
    pub ft: u64,
    /// Apart in both.
    pub ff: u64,
}

/// Compute the pair confusion in O(n + Σ cluster-intersections) using
/// the contingency table (not the naive O(n²) loop).
pub fn pair_confusion(pred: &Clustering, truth: &Clustering) -> PairConfusion {
    assert_eq!(pred.n(), truth.n());
    let n = pred.n() as u64;
    let p = pred.normalize();
    let t = truth.normalize();
    // Contingency counts. BTreeMaps, not hash maps: the sums below are
    // order-independent, but keeping ordered containers here means the
    // whole module is trivially deterministic (and audit-clean).
    let mut cont: std::collections::BTreeMap<(u32, u32), u64> = std::collections::BTreeMap::new();
    for v in 0..pred.n() as u32 {
        *cont.entry((p.label(v), t.label(v))).or_insert(0) += 1;
    }
    let mut p_sizes: std::collections::BTreeMap<u32, u64> = std::collections::BTreeMap::new();
    let mut t_sizes: std::collections::BTreeMap<u32, u64> = std::collections::BTreeMap::new();
    for v in 0..pred.n() as u32 {
        *p_sizes.entry(p.label(v)).or_insert(0) += 1;
        *t_sizes.entry(t.label(v)).or_insert(0) += 1;
    }
    let choose2 = |x: u64| x * x.saturating_sub(1) / 2;
    let sum_cont: u64 = cont.values().map(|&c| choose2(c)).sum();
    let sum_p: u64 = p_sizes.values().map(|&c| choose2(c)).sum();
    let sum_t: u64 = t_sizes.values().map(|&c| choose2(c)).sum();
    let total = choose2(n);
    let tt = sum_cont;
    let tf = sum_p - sum_cont;
    let ft = sum_t - sum_cont;
    let ff = total - tt - tf - ft;
    PairConfusion { tt, tf, ft, ff }
}

/// Rand index: fraction of vertex pairs on which the two clusterings
/// agree (together-together or apart-apart). 1.0 = identical partitions.
pub fn rand_index(pred: &Clustering, truth: &Clustering) -> f64 {
    let c = pair_confusion(pred, truth);
    let total = c.tt + c.tf + c.ft + c.ff;
    if total == 0 {
        return 1.0;
    }
    (c.tt + c.ff) as f64 / total as f64
}

/// Adjusted Rand index (Hubert–Arabie): Rand corrected for chance;
/// 1.0 = identical, ~0 = random relabeling.
pub fn adjusted_rand_index(pred: &Clustering, truth: &Clustering) -> f64 {
    let c = pair_confusion(pred, truth);
    let (tt, tf, ft, ff) = (c.tt as f64, c.tf as f64, c.ft as f64, c.ff as f64);
    let total = tt + tf + ft + ff;
    if total == 0.0 {
        return 1.0;
    }
    let sum_p = tt + tf;
    let sum_t = tt + ft;
    let expected = sum_p * sum_t / total;
    let max_index = (sum_p + sum_t) / 2.0;
    if (max_index - expected).abs() < 1e-12 {
        return 1.0;
    }
    (tt - expected) / (max_index - expected)
}

/// Pairwise precision/recall/F1 of the "same cluster" relation.
pub fn pairwise_f1(pred: &Clustering, truth: &Clustering) -> (f64, f64, f64) {
    let c = pair_confusion(pred, truth);
    let precision = if c.tt + c.tf == 0 { 1.0 } else { c.tt as f64 / (c.tt + c.tf) as f64 };
    let recall = if c.tt + c.ft == 0 { 1.0 } else { c.tt as f64 / (c.tt + c.ft) as f64 };
    let f1 = if precision + recall == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    };
    (precision, recall, f1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_partitions_score_one() {
        let a = Clustering::from_labels(vec![0, 0, 1, 1, 2]);
        let b = Clustering::from_labels(vec![7, 7, 3, 3, 9]); // same partition
        assert_eq!(rand_index(&a, &b), 1.0);
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
        let (p, r, f1) = pairwise_f1(&a, &b);
        assert_eq!((p, r, f1), (1.0, 1.0, 1.0));
    }

    #[test]
    fn disjoint_views_score_low() {
        // Prediction: all singletons; truth: one big cluster.
        let pred = Clustering::singletons(6);
        let truth = Clustering::single_cluster(6);
        let c = pair_confusion(&pred, &truth);
        assert_eq!(c.tt, 0);
        assert_eq!(c.ft, 15);
        assert_eq!(rand_index(&pred, &truth), 0.0);
        let (p, r, _) = pairwise_f1(&pred, &truth);
        assert_eq!(p, 1.0); // vacuous precision
        assert_eq!(r, 0.0);
    }

    #[test]
    fn confusion_matches_brute_force() {
        let pred = Clustering::from_labels(vec![0, 0, 1, 1, 1, 2, 2]);
        let truth = Clustering::from_labels(vec![0, 1, 1, 1, 2, 2, 2]);
        let c = pair_confusion(&pred, &truth);
        // Brute force.
        let (mut tt, mut tf, mut ft, mut ff) = (0u64, 0u64, 0u64, 0u64);
        for u in 0..7u32 {
            for v in (u + 1)..7 {
                match (pred.same_cluster(u, v), truth.same_cluster(u, v)) {
                    (true, true) => tt += 1,
                    (true, false) => tf += 1,
                    (false, true) => ft += 1,
                    (false, false) => ff += 1,
                }
            }
        }
        assert_eq!(c, PairConfusion { tt, tf, ft, ff });
    }

    #[test]
    fn ari_near_zero_for_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        let n = 500;
        let truth = Clustering::from_labels((0..n).map(|v| (v % 10) as u32).collect());
        let pred = Clustering::from_labels((0..n).map(|_| rng.index(10) as u32).collect());
        let ari = adjusted_rand_index(&pred, &truth);
        assert!(ari.abs() < 0.05, "random ARI should be ~0, got {ari}");
    }
}
