//! Clustering representation: a partition of `V` as a label vector.
//!
//! The paper's clustering `C = {C_1, ..., C_r}` is stored as
//! `label[v] = cluster id of v`.  Any `u32` ids are accepted;
//! [`Clustering::normalize`] canonicalizes to `[0, r)` ordered by first
//! appearance, which makes clusterings comparable across algorithms.

/// A partition of the vertex set, by labels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Clustering {
    labels: Vec<u32>,
}

impl Clustering {
    pub fn from_labels(labels: Vec<u32>) -> Clustering {
        Clustering { labels }
    }

    /// All-singletons clustering.
    pub fn singletons(n: usize) -> Clustering {
        Clustering { labels: (0..n as u32).collect() }
    }

    /// Everything in one cluster.
    pub fn single_cluster(n: usize) -> Clustering {
        Clustering { labels: vec![0; n] }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.labels.len()
    }

    #[inline]
    pub fn label(&self, v: u32) -> u32 {
        self.labels[v as usize]
    }

    #[inline]
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    #[inline]
    pub fn same_cluster(&self, u: u32, v: u32) -> bool {
        self.labels[u as usize] == self.labels[v as usize]
    }

    pub fn set_label(&mut self, v: u32, c: u32) {
        self.labels[v as usize] = c;
    }

    /// Relabel clusters to `[0, r)` by order of first appearance.
    ///
    /// Perf note (§Perf L3-1): label ids produced by the algorithms are
    /// vertex ids (< n), so the dense `Vec` remap fast path applies on
    /// every hot call; the `BTreeMap` path only serves adversarial label
    /// spaces.
    pub fn normalize(&self) -> Clustering {
        let n = self.labels.len();
        let max = self.labels.iter().copied().max().unwrap_or(0) as usize;
        if max <= 4 * n + 4 {
            let mut map = vec![u32::MAX; max + 1];
            let mut next = 0u32;
            let labels = self
                .labels
                .iter()
                .map(|&l| {
                    let slot = &mut map[l as usize];
                    if *slot == u32::MAX {
                        *slot = next;
                        next += 1;
                    }
                    *slot
                })
                .collect();
            Clustering { labels }
        } else {
            // Ordered map on the cold path: first-appearance order comes
            // from the label scan, not map iteration, so a BTreeMap is
            // behaviour-identical — and keeps the type deterministic.
            let mut map = std::collections::BTreeMap::new();
            let mut next = 0u32;
            let labels = self
                .labels
                .iter()
                .map(|&l| {
                    *map.entry(l).or_insert_with(|| {
                        let id = next;
                        next += 1;
                        id
                    })
                })
                .collect();
            Clustering { labels }
        }
    }

    /// Number of distinct clusters.
    pub fn n_clusters(&self) -> usize {
        let mut labels = self.labels.clone();
        labels.sort_unstable();
        labels.dedup();
        labels.len()
    }

    /// Sizes keyed by normalized cluster id.
    pub fn sizes(&self) -> Vec<usize> {
        let norm = self.normalize();
        let k = norm.n_clusters();
        let mut sizes = vec![0usize; k];
        for &l in &norm.labels {
            sizes[l as usize] += 1;
        }
        sizes
    }

    pub fn max_cluster_size(&self) -> usize {
        self.sizes().into_iter().max().unwrap_or(0)
    }

    /// Members of each cluster (normalized ids).
    pub fn members(&self) -> Vec<Vec<u32>> {
        let norm = self.normalize();
        let mut out = vec![Vec::new(); norm.n_clusters()];
        for (v, &l) in norm.labels.iter().enumerate() {
            out[l as usize].push(v as u32);
        }
        out
    }

    /// Merge another clustering defined on a vertex subset into this one.
    ///
    /// `sub_old_ids[i]` is the original id of sub-vertex `i`; labels from
    /// `sub` are offset to avoid collisions.  This is the Algorithm 4 /
    /// Theorem 26 union step: `{{v} : v ∈ H} ∪ A(G')`.
    pub fn merge_subclustering(&mut self, sub: &Clustering, sub_old_ids: &[u32]) {
        let offset = self.labels.iter().copied().max().map(|x| x + 1).unwrap_or(0);
        self.merge_subclustering_with_offset(sub, sub_old_ids, offset);
    }

    /// [`Self::merge_subclustering`] with the collision-avoiding offset
    /// threaded explicitly: labels from `sub` land at `offset + label`,
    /// and the first offset free *after* this merge is returned.
    ///
    /// This is the per-component stitch of the solve driver: merging k
    /// component clusterings costs O(Σ|Cᵢ|) total instead of the O(k·n)
    /// a max-scan per merge would pay, while the caller-supplied offsets
    /// keep the result deterministic at every shard count.
    pub fn merge_subclustering_with_offset(
        &mut self,
        sub: &Clustering,
        sub_old_ids: &[u32],
        offset: u32,
    ) -> u32 {
        assert_eq!(sub.n(), sub_old_ids.len());
        let mut max_label = 0u32;
        for (i, &old) in sub_old_ids.iter().enumerate() {
            let l = sub.label(i as u32);
            max_label = max_label.max(l);
            self.labels[old as usize] = offset + l;
        }
        if sub_old_ids.is_empty() {
            offset
        } else {
            offset + max_label + 1
        }
    }

    /// Histogram of cluster sizes (index = size, value = #clusters).
    pub fn size_histogram(&self) -> Vec<usize> {
        let sizes = self.sizes();
        let max = sizes.iter().copied().max().unwrap_or(0);
        let mut h = vec![0usize; max + 1];
        for s in sizes {
            h[s] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_orders_by_first_appearance() {
        let c = Clustering::from_labels(vec![7, 7, 2, 9, 2]);
        let n = c.normalize();
        assert_eq!(n.labels(), &[0, 0, 1, 2, 1]);
        assert_eq!(c.n_clusters(), 3);
    }

    #[test]
    fn sizes_and_histogram() {
        let c = Clustering::from_labels(vec![0, 0, 1, 2, 2, 2]);
        assert_eq!(c.sizes(), vec![2, 1, 3]);
        assert_eq!(c.max_cluster_size(), 3);
        let h = c.size_histogram();
        assert_eq!(h[1], 1);
        assert_eq!(h[2], 1);
        assert_eq!(h[3], 1);
    }

    #[test]
    fn singletons_and_single() {
        assert_eq!(Clustering::singletons(4).n_clusters(), 4);
        assert_eq!(Clustering::single_cluster(4).n_clusters(), 1);
    }

    #[test]
    fn members_partition_vertices() {
        let c = Clustering::from_labels(vec![5, 5, 3, 3, 8]);
        let mem = c.members();
        let total: usize = mem.iter().map(|m| m.len()).sum();
        assert_eq!(total, 5);
        assert_eq!(mem[0], vec![0, 1]);
    }

    #[test]
    fn merge_subclustering_unions() {
        // 5 vertices; vertices 1 and 3 were "low degree" and clustered
        // together by the inner algorithm; others are singletons.
        let mut c = Clustering::singletons(5);
        let sub = Clustering::from_labels(vec![0, 0]);
        c.merge_subclustering(&sub, &[1, 3]);
        assert!(c.same_cluster(1, 3));
        assert!(!c.same_cluster(0, 1));
        assert_eq!(c.n_clusters(), 4);
    }

    #[test]
    fn merge_with_offset_threads_disjoint_ranges() {
        // Two disjoint sub-clusterings stitched with threaded offsets:
        // labels never collide and the running offset advances by the
        // sub label-space width each time.
        let mut c = Clustering::singletons(6);
        let a = Clustering::from_labels(vec![0, 0]);
        let b = Clustering::from_labels(vec![1, 0, 1]);
        let next = c.merge_subclustering_with_offset(&a, &[0, 1], 6);
        assert_eq!(next, 7);
        let next = c.merge_subclustering_with_offset(&b, &[2, 3, 4], next);
        assert_eq!(next, 9);
        assert!(c.same_cluster(0, 1));
        assert!(c.same_cluster(2, 4));
        assert!(!c.same_cluster(2, 3));
        assert!(!c.same_cluster(1, 2));
        assert_eq!(c.n_clusters(), 4); // {0,1}, {2,4}, {3}, {5}
        // Empty merge is a no-op on the offset.
        let empty = Clustering::from_labels(vec![]);
        assert_eq!(c.merge_subclustering_with_offset(&empty, &[], 42), 42);
    }

    #[test]
    fn same_cluster_reflexive() {
        let c = Clustering::from_labels(vec![1, 2, 1]);
        assert!(c.same_cluster(0, 2));
        assert!(!c.same_cluster(0, 1));
    }
}
