//! Lemma 25's structural transform, made executable.
//!
//! The paper proves: any clustering with a cluster of size ≥ 4λ−1 contains
//! a vertex v* with internal positive degree ≤ 2λ−1, and moving v* to a
//! singleton does not increase the cost.  Repeating yields an optimum
//! clustering with all clusters ≤ 4λ−2.
//!
//! [`bound_cluster_sizes`] runs exactly that local-update procedure on an
//! *arbitrary* input clustering.  It is both a component of experiments
//! (E1 validates the lemma by transforming exact optima) and a usable
//! post-processing pass (cost never increases, sizes become ≤ 4λ−2).

use crate::cluster::clustering::Clustering;
use crate::graph::Graph;

/// Outcome of the transform.
#[derive(Debug, Clone)]
pub struct StructuralResult {
    pub clustering: Clustering,
    /// Number of vertices split off into singletons.
    pub moves: usize,
    /// Largest cluster size after the transform.
    pub max_cluster_size: usize,
}

/// Apply Lemma 25's local updates until every cluster has size ≤ 4λ−2.
///
/// Each step picks, from any oversized cluster, a vertex of minimum
/// internal positive degree.  The lemma guarantees that degree is
/// ≤ 2λ−1 ≤ (|C|−1)/2, so the move cannot increase the cost; we assert
/// the guarantee instead of trusting it.
pub fn bound_cluster_sizes(g: &Graph, input: &Clustering, lambda: usize) -> StructuralResult {
    assert!(lambda >= 1, "λ must be ≥ 1");
    let limit = 4 * lambda - 2;
    let norm = input.normalize();
    let _n = g.n();
    let mut labels: Vec<u32> = norm.labels().to_vec();
    let mut next_label = labels.iter().copied().max().map(|x| x + 1).unwrap_or(0);

    // members[c] = vertices currently in cluster c (tombstone-free vecs,
    // rebuilt lazily when dirty).
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); next_label as usize];
    for (v, &c) in labels.iter().enumerate() {
        members[c as usize].push(v as u32);
    }

    let mut moves = 0usize;
    // Vertex-indexed membership marker, reused across steps (set and
    // reset over the current cluster only) — no hash sets on this path.
    let mut in_cluster = vec![false; g.n()];
    let mut queue: std::collections::VecDeque<u32> =
        (0..members.len() as u32).filter(|&c| members[c as usize].len() > limit).collect();

    while let Some(c) = queue.pop_front() {
        loop {
            let cluster = &members[c as usize];
            if cluster.len() <= limit {
                break;
            }
            // Find v* minimizing internal positive degree.
            for &v in cluster {
                in_cluster[v as usize] = true;
            }
            let (v_star, d_int) = cluster
                .iter()
                .map(|&v| {
                    let d = g.neighbors(v).iter().filter(|&&u| in_cluster[u as usize]).count();
                    (v, d)
                })
                .min_by_key(|&(_, d)| d)
                .expect("oversized cluster is nonempty");
            for &v in cluster {
                in_cluster[v as usize] = false;
            }
            // Lemma 25's existence guarantee (contradiction argument via
            // arboricity): the min internal degree is ≤ 2λ−1. Moving v*
            // out removes (|C|−1−d_int) negative disagreements and adds
            // d_int positive ones; non-increase needs d_int ≤ (|C|−1)/2.
            assert!(
                d_int <= 2 * lambda - 1,
                "Lemma 25 violated: |C|={} min internal degree {} > 2λ-1={} — \
                 is λ={lambda} really an upper bound on the arboricity?",
                cluster.len(),
                d_int,
                2 * lambda - 1
            );
            debug_assert!(2 * d_int <= cluster.len() - 1);
            // Execute the move.
            let pos = members[c as usize].iter().position(|&x| x == v_star).unwrap();
            members[c as usize].swap_remove(pos);
            labels[v_star as usize] = next_label;
            members.push(vec![v_star]);
            next_label += 1;
            moves += 1;
        }
    }

    let clustering = Clustering::from_labels(labels);
    let max_cluster_size = clustering.max_cluster_size();
    StructuralResult { clustering, moves, max_cluster_size }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::cost;
    use crate::cluster::exact::solve_exact;
    use crate::graph::arboricity::estimate_arboricity;
    use crate::graph::generators::{clique, lambda_arboric, random_tree};
    use crate::util::rng::Rng;

    #[test]
    fn transform_never_increases_cost_and_caps_sizes() {
        let mut rng = Rng::new(40);
        for lambda in [1usize, 2, 3] {
            for trial in 0..10 {
                let g = lambda_arboric(60, lambda, &mut rng);
                // Adversarial start: everything in one cluster.
                let start = Clustering::single_cluster(60);
                let before = cost(&g, &start).total();
                let res = bound_cluster_sizes(&g, &start, lambda);
                let after = cost(&g, &res.clustering).total();
                assert!(after <= before, "λ={lambda} trial={trial}: {after} > {before}");
                assert!(
                    res.max_cluster_size <= 4 * lambda - 2,
                    "λ={lambda}: size {} > {}",
                    res.max_cluster_size,
                    4 * lambda - 2
                );
            }
        }
    }

    #[test]
    fn exact_optimum_stays_optimal_after_transform() {
        // Lemma 25's statement: there EXISTS an optimum with bounded
        // clusters; transforming an optimum must keep the cost equal.
        let mut rng = Rng::new(41);
        for trial in 0..10 {
            let g = lambda_arboric(10, 1 + trial % 2, &mut rng);
            let lambda = 1 + trial % 2;
            let (opt, opt_cost) = solve_exact(&g);
            let res = bound_cluster_sizes(&g, &opt, lambda);
            assert_eq!(
                cost(&g, &res.clustering).total(),
                opt_cost.total(),
                "transforming an optimum must preserve optimality"
            );
        }
    }

    #[test]
    fn forest_clusters_capped_at_two() {
        // λ=1 ⇒ limit = 2: the transform reduces any clustering of a
        // forest to clusters of size ≤ 2 (matching Corollary 27's view).
        let mut rng = Rng::new(42);
        let g = random_tree(40, &mut rng);
        let start = Clustering::single_cluster(40);
        let res = bound_cluster_sizes(&g, &start, 1);
        assert!(res.max_cluster_size <= 2);
    }

    #[test]
    fn clique_within_limit_untouched() {
        // K_6 is 3-arboric; limit 4·3−2 = 10 ≥ 6: nothing to do.
        let g = clique(6);
        let est = estimate_arboricity(&g);
        let lambda = est.degeneracy.div_ceil(2).max(1) + 1; // ≥ true λ
        let start = Clustering::single_cluster(6);
        let res = bound_cluster_sizes(&g, &start, lambda);
        assert_eq!(res.moves, 0);
        assert_eq!(cost(&g, &res.clustering).total(), 0);
    }

    #[test]
    fn already_small_clusters_noop() {
        let mut rng = Rng::new(43);
        let g = lambda_arboric(30, 2, &mut rng);
        let start = Clustering::singletons(30);
        let res = bound_cluster_sizes(&g, &start, 2);
        assert_eq!(res.moves, 0);
    }
}
