//! Correlation clustering core: partitions, disagreement costs, bad
//! triangles, exact small-instance optima, and the Lemma 25 structural
//! transform.

pub mod clustering;
pub mod cost;
pub mod exact;
pub mod metrics;
pub mod structural;
pub mod triangles;

pub use clustering::Clustering;
pub use cost::{cost, Cost};
