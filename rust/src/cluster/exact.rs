//! Exact optimum correlation clustering by subset DP — ratio ground truth
//! for small instances (n ≤ 14).
//!
//! Decomposition: writing `w(C) = pairs(C) − 2·posEdges(C)` for a cluster
//! C, the total disagreement cost of a partition P is
//!
//! ```text
//! cost(P) = m + Σ_{C ∈ P} w(C)
//! ```
//!
//! (each intra-cluster positive edge cancels one positive disagreement and
//! one negative-pair unit).  Minimizing Σ w(C) over partitions is the
//! classic subset-DP: `best[S] = min over T ⊆ S, lowbit(S) ∈ T` of
//! `w(T) + best[S \ T]`, O(3^n) time, O(2^n) space.

use crate::cluster::clustering::Clustering;
use crate::cluster::cost::{cost, Cost};
use crate::graph::Graph;

/// Hard cap: 3^14 ≈ 4.8M subset-pair steps, comfortably fast.
pub const MAX_EXACT_N: usize = 14;

/// Exact optimum clustering and its cost.
pub fn solve_exact(g: &Graph) -> (Clustering, Cost) {
    let n = g.n();
    assert!(n <= MAX_EXACT_N, "exact solver capped at n={MAX_EXACT_N}, got {n}");
    if n == 0 {
        return (Clustering::from_labels(vec![]), Cost { positive: 0, negative: 0 });
    }

    // Adjacency bitmasks.
    let adj: Vec<u32> = (0..n as u32)
        .map(|v| g.neighbors(v).iter().fold(0u32, |acc, &u| acc | (1 << u)))
        .collect();

    let full = (1u32 << n) - 1;
    // posEdges[s] = positive edges inside subset s, built incrementally:
    // pos(s) = pos(s without lowbit) + |adj(lowbit) ∩ (s without lowbit)|.
    let mut pos = vec![0i32; (full + 1) as usize];
    for s in 1..=full {
        let low = s.trailing_zeros() as usize;
        let rest = s & (s - 1);
        pos[s as usize] = pos[rest as usize] + (adj[low] & rest).count_ones() as i32;
    }

    // w(s) = pairs(s) - 2 pos(s).
    let w = |s: u32| -> i32 {
        let k = s.count_ones() as i32;
        k * (k - 1) / 2 - 2 * pos[s as usize]
    };

    let mut best = vec![i32::MAX; (full + 1) as usize];
    let mut choice = vec![0u32; (full + 1) as usize];
    best[0] = 0;
    for s in 1..=full {
        let low = 1u32 << s.trailing_zeros();
        // Enumerate submasks T of s that contain `low`.
        let rest = s & !low;
        let mut sub = rest;
        loop {
            let t = sub | low;
            let cand = w(t).saturating_add(best[(s & !t) as usize]);
            if cand < best[s as usize] {
                best[s as usize] = cand;
                choice[s as usize] = t;
            }
            if sub == 0 {
                break;
            }
            sub = (sub - 1) & rest;
        }
    }

    // Reconstruct.
    let mut labels = vec![0u32; n];
    let mut s = full;
    let mut cid = 0u32;
    while s != 0 {
        let t = choice[s as usize];
        let mut bits = t;
        while bits != 0 {
            let v = bits.trailing_zeros();
            labels[v as usize] = cid;
            bits &= bits - 1;
        }
        cid += 1;
        s &= !t;
    }
    let clustering = Clustering::from_labels(labels);
    let c = cost(g, &clustering);
    debug_assert_eq!(
        c.total() as i64,
        g.m() as i64 + best[full as usize] as i64,
        "DP objective and direct cost disagree"
    );
    (clustering, c)
}

/// Exact optimum cost only.
pub fn exact_cost(g: &Graph) -> u64 {
    solve_exact(g).1.total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::cost_brute;
    use crate::graph::generators::{barbell, clique, disjoint_cliques, path, star};
    use crate::graph::Graph;
    use crate::util::rng::Rng;

    #[test]
    fn clique_opt_is_zero() {
        let g = clique(7);
        let (c, k) = solve_exact(&g);
        assert_eq!(k.total(), 0);
        assert_eq!(c.n_clusters(), 1);
    }

    #[test]
    fn disjoint_cliques_opt_is_zero() {
        let g = disjoint_cliques(3, 4);
        let (c, k) = solve_exact(&g);
        assert_eq!(k.total(), 0);
        assert_eq!(c.n_clusters(), 3);
    }

    #[test]
    fn p3_opt_is_one() {
        let (_, k) = solve_exact(&path(3));
        assert_eq!(k.total(), 1);
    }

    #[test]
    fn p4_opt_is_one() {
        // Corollary 27: opt = (n-1) - maxmatching = 3 - 2 = 1.
        let (c, k) = solve_exact(&path(4));
        assert_eq!(k.total(), 1);
        assert!(c.max_cluster_size() <= 2, "λ=1 ⇒ clusters ≤ 2 (Lemma 25)");
    }

    #[test]
    fn star_opt_matches_matching_formula() {
        // Star K_{1,k}: max matching = 1 ⇒ OPT = k - 1.
        for k in 2..6 {
            let g = star(k);
            assert_eq!(exact_cost(&g), (k - 1) as u64, "star k={k}");
        }
    }

    #[test]
    fn barbell_opt_is_one() {
        // Remark 33: cluster each K_λ, pay the bridge.
        let g = barbell(5);
        assert_eq!(exact_cost(&g), 1);
    }

    #[test]
    fn exact_beats_every_random_clustering() {
        let mut rng = Rng::new(30);
        for trial in 0..10 {
            let n = 8;
            let edges: Vec<(u32, u32)> = (0..n as u32)
                .flat_map(|u| ((u + 1)..n as u32).map(move |v| (u, v)))
                .filter(|_| rng.bernoulli(0.4))
                .collect();
            let g = Graph::from_edges(n, &edges);
            let (opt_c, opt_k) = solve_exact(&g);
            assert_eq!(cost_brute(&g, &opt_c), opt_k, "trial {trial}");
            for _ in 0..50 {
                let labels: Vec<u32> = (0..n).map(|_| rng.index(n) as u32).collect();
                let c = Clustering::from_labels(labels);
                assert!(cost_brute(&g, &c).total() >= opt_k.total(), "trial {trial}");
            }
        }
    }

    #[test]
    fn empty_graph_zero() {
        assert_eq!(exact_cost(&Graph::empty(0)), 0);
        assert_eq!(exact_cost(&Graph::empty(5)), 0);
    }

    #[test]
    #[should_panic(expected = "capped")]
    fn oversize_panics() {
        let _ = solve_exact(&Graph::empty(15));
    }
}
