//! Disagreement cost of a clustering on a complete signed graph, O(n + m).
//!
//! For positive-edge graph `G = (V, E+)` (negatives implicit) and
//! clustering `C`:
//!
//! * positive disagreements = #{ {u,v} ∈ E+ : C(u) != C(v) }
//! * negative disagreements = Σ_C (|C| choose 2) − #intra-cluster
//!   positive edges
//!
//! This sparse formula is the pure-Rust twin of the L1 dense kernel
//! (`python/compile/kernels/disagreement.py`); the integration tests and
//! the runtime's self-check assert they agree exactly.

use crate::cluster::clustering::Clustering;
use crate::graph::Graph;

/// Disagreement breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cost {
    pub positive: u64,
    pub negative: u64,
}

impl Cost {
    pub fn total(&self) -> u64 {
        self.positive + self.negative
    }
}

impl std::fmt::Display for Cost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} (+{} / -{})", self.total(), self.positive, self.negative)
    }
}

/// Compute the disagreement cost in O(n + m).
///
/// Perf note (§Perf L3-4): iterates the *directed* adjacency flat and
/// halves the same-cluster count, instead of filtering `u < v` per entry
/// — the branch-free scan is ~40% faster on scale-free CSR layouts.
pub fn cost(g: &Graph, clustering: &Clustering) -> Cost {
    assert_eq!(g.n(), clustering.n(), "clustering size mismatch");
    let norm = clustering.normalize();
    let labels = norm.labels();
    let k = norm.n_clusters();
    let mut sizes = vec![0u64; k];
    for &l in labels {
        sizes[l as usize] += 1;
    }
    // Each undirected edge appears twice in the directed scan.
    let mut intra2 = 0u64;
    for v in 0..g.n() as u32 {
        let lv = labels[v as usize];
        for &u in g.neighbors(v) {
            intra2 += (labels[u as usize] == lv) as u64;
        }
    }
    let intra = intra2 / 2;
    let cut = g.m() as u64 - intra;
    let pairs: u64 = sizes.iter().map(|&s| s * (s - 1) / 2).sum();
    Cost { positive: cut, negative: pairs - intra }
}

/// O(n^2) textbook reference used by tests and the exact solver.
pub fn cost_brute(g: &Graph, clustering: &Clustering) -> Cost {
    let n = g.n() as u32;
    let mut positive = 0u64;
    let mut negative = 0u64;
    for u in 0..n {
        for v in (u + 1)..n {
            let same = clustering.same_cluster(u, v);
            let edge = g.has_edge(u, v);
            if edge && !same {
                positive += 1;
            }
            if !edge && same {
                negative += 1;
            }
        }
    }
    Cost { positive, negative }
}

/// Agreements (the maximization objective): total pairs minus cost.
pub fn agreements(g: &Graph, clustering: &Clustering) -> u64 {
    let n = g.n() as u64;
    let total_pairs = n * (n - 1) / 2;
    total_pairs - cost(g, clustering).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{clique, lambda_arboric, path};
    use crate::util::rng::Rng;

    #[test]
    fn singletons_cost_equals_m() {
        let g = clique(6);
        let c = Clustering::singletons(6);
        let k = cost(&g, &c);
        assert_eq!(k.positive, 15);
        assert_eq!(k.negative, 0);
    }

    #[test]
    fn single_cluster_costs_missing_pairs() {
        let g = path(4); // 3 edges, 6 pairs
        let c = Clustering::single_cluster(4);
        let k = cost(&g, &c);
        assert_eq!(k.positive, 0);
        assert_eq!(k.negative, 3);
    }

    #[test]
    fn clique_single_cluster_is_free() {
        let g = clique(7);
        let c = Clustering::single_cluster(7);
        assert_eq!(cost(&g, &c).total(), 0);
    }

    #[test]
    fn p4_optimal_cost_is_one() {
        // Path a-b-c-d: cluster {a,b},{c,d} ⇒ only edge b-c disagrees.
        let g = path(4);
        let c = Clustering::from_labels(vec![0, 0, 1, 1]);
        assert_eq!(cost(&g, &c).total(), 1);
    }

    #[test]
    fn sparse_matches_brute_force() {
        let mut rng = Rng::new(10);
        for trial in 0..20 {
            let g = lambda_arboric(30, 1 + trial % 3, &mut rng);
            let labels: Vec<u32> = (0..30).map(|_| rng.index(8) as u32).collect();
            let c = Clustering::from_labels(labels);
            assert_eq!(cost(&g, &c), cost_brute(&g, &c), "trial {trial}");
        }
    }

    #[test]
    fn agreements_complement() {
        let g = path(5);
        let c = Clustering::from_labels(vec![0, 0, 1, 1, 2]);
        let k = cost(&g, &c);
        assert_eq!(agreements(&g, &c), 10 - k.total());
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn size_mismatch_panics() {
        let g = path(4);
        cost(&g, &Clustering::singletons(3));
    }
}
