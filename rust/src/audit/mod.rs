//! `arbocc audit` — the determinism & MPC-invariant static analysis
//! pass (DESIGN.md §8).
//!
//! The repo's load-bearing guarantee — bit-identical clusterings and
//! O(S) ledger traces at every shard count — is a *global* property no
//! unit test can pin down locally, and both historical determinism bugs
//! (the PR 4 `barabasi_albert` seed leak, the alg1/alg2 HashMap-tally
//! hazards hand-audited in PR 5) were unordered-iteration defects. This
//! module mechanizes that audit:
//!
//! * [`scan`] — a light line scanner: comments dropped, literals
//!   blanked, `#[cfg(test)]` items skipped, `audit:allow` markers
//!   parsed;
//! * [`manifest`] — the checked-in `audit.toml` classifying modules
//!   into `deterministic` / `wire` / `overflow` / `cli` classes;
//! * [`rules`] — the eight class-scoped token rules;
//! * this file — the walking engine, suppression accounting, and the
//!   human (`file:line`) / JSON (`arbocc-audit/v1`) reports.
//!
//! Suppressions must carry a justification (`// audit:allow(rule):
//! why`); a bare, stale, or unknown-rule marker is itself a finding, so
//! the allow-list can only shrink under review, never rot silently.

pub mod manifest;
pub mod rules;
pub mod scan;

pub use manifest::Manifest;

use std::path::{Path, PathBuf};

use crate::util::error::{Result, ResultExt};
use crate::util::json::Json;

/// One rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    pub line: usize,
    pub class: String,
    pub message: String,
    pub snippet: String,
}

/// One justified `audit:allow` that absorbed a violation.
#[derive(Debug, Clone)]
pub struct Suppression {
    pub rule: String,
    pub file: String,
    pub line: usize,
    pub justification: String,
}

/// The full audit result.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppression>,
    pub files_scanned: usize,
}

impl AuditReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// `file:line: [rule] message` lines plus a one-line tally.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
            if !f.snippet.is_empty() {
                out.push_str(&format!("    {}\n", f.snippet));
            }
        }
        out.push_str(&format!(
            "audit: {} finding(s), {} suppression(s), {} file(s) scanned\n",
            self.findings.len(),
            self.suppressed.len(),
            self.files_scanned
        ));
        out
    }

    /// The `arbocc-audit/v1` report.
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("schema", Json::str("arbocc-audit/v1"))
            .set("files_scanned", Json::num(self.files_scanned as f64))
            .set("clean", Json::Bool(self.is_clean()));
        let mut findings = Json::Arr(Vec::new());
        for f in &self.findings {
            let mut o = Json::obj();
            o.set("rule", Json::str(f.rule.clone()))
                .set("file", Json::str(f.file.clone()))
                .set("line", Json::num(f.line as f64))
                .set("class", Json::str(f.class.clone()))
                .set("message", Json::str(f.message.clone()))
                .set("snippet", Json::str(f.snippet.clone()));
            findings.push(o);
        }
        root.set("findings", findings);
        let mut suppressed = Json::Arr(Vec::new());
        for s in &self.suppressed {
            let mut o = Json::obj();
            o.set("rule", Json::str(s.rule.clone()))
                .set("file", Json::str(s.file.clone()))
                .set("line", Json::num(s.line as f64))
                .set("justification", Json::str(s.justification.clone()));
            suppressed.push(o);
        }
        root.set("suppressed", suppressed);
        let mut counts = std::collections::BTreeMap::new();
        for f in &self.findings {
            *counts.entry(f.rule.clone()).or_insert(0usize) += 1;
        }
        let mut counts_json = Json::obj();
        for (rule, n) in counts {
            counts_json.set(&rule, Json::num(n as f64));
        }
        root.set("counts", counts_json);
        root
    }
}

/// Audit one file's source under its manifest classification. `rel` is
/// the manifest-relative path (e.g. `src/mpc/wire.rs`) — it decides
/// which classes, and therefore which rules, apply.
pub fn audit_source(rel: &str, source: &str, m: &Manifest) -> AuditReport {
    let scanned = scan::scan(source);
    let classes = m.classes_of(rel);
    let mut report = AuditReport { files_scanned: 1, ..AuditReport::default() };
    let mut consumed = vec![false; scanned.allows.len()];

    for line in &scanned.lines {
        if line.in_test {
            continue;
        }
        for rule in rules::RULES {
            if !classes.contains(&rule.class) || m.is_exempt(rule.id, rel) {
                continue;
            }
            let Some(message) = rules::check(rule.id, &line.code, m) else {
                continue;
            };
            let allow = scanned.allows.iter().position(|a| {
                a.rule == rule.id
                    && (a.line == line.number || (a.own_line && a.line + 1 == line.number))
            });
            match allow {
                Some(idx) if !scanned.allows[idx].justification.is_empty() => {
                    consumed[idx] = true;
                    report.suppressed.push(Suppression {
                        rule: rule.id.to_string(),
                        file: rel.to_string(),
                        line: line.number,
                        justification: scanned.allows[idx].justification.clone(),
                    });
                }
                Some(idx) => {
                    // A bare allow never suppresses: the justification is
                    // the reviewable artifact the mechanism exists for.
                    consumed[idx] = true;
                    report.findings.push(Finding {
                        rule: rule.id.to_string(),
                        file: rel.to_string(),
                        line: line.number,
                        class: rule.class.to_string(),
                        message: format!(
                            "{message} — audit:allow({}) found but it needs a \
                             `: <justification>` tail to suppress",
                            rule.id
                        ),
                        snippet: line.raw.trim().to_string(),
                    });
                }
                None => report.findings.push(Finding {
                    rule: rule.id.to_string(),
                    file: rel.to_string(),
                    line: line.number,
                    class: rule.class.to_string(),
                    message,
                    snippet: line.raw.trim().to_string(),
                }),
            }
        }
    }

    // The suppression channel polices itself: unknown rule names and
    // markers that matched nothing are findings too.
    for (idx, allow) in scanned.allows.iter().enumerate() {
        if allow.in_test {
            continue;
        }
        if !rules::known(&allow.rule) {
            report.findings.push(Finding {
                rule: rules::META_RULE.to_string(),
                file: rel.to_string(),
                line: allow.line,
                class: "meta".to_string(),
                message: format!(
                    "audit:allow names unknown rule '{}' (known: {})",
                    allow.rule,
                    rules::rule_ids().join("|")
                ),
                snippet: String::new(),
            });
        } else if !consumed[idx] {
            report.findings.push(Finding {
                rule: rules::META_RULE.to_string(),
                file: rel.to_string(),
                line: allow.line,
                class: "meta".to_string(),
                message: format!(
                    "stale audit:allow({}): no finding here for it to suppress — \
                     remove it so the allow-list only shrinks",
                    allow.rule
                ),
                snippet: String::new(),
            });
        }
    }
    report
}

/// Walk `dir/<manifest.root>` and audit every `.rs` file, in sorted
/// path order (the report itself must be deterministic).
pub fn audit_tree(dir: &Path, m: &Manifest) -> Result<AuditReport> {
    let root = dir.join(&m.root);
    crate::ensure!(root.is_dir(), "audit root {} is not a directory", root.display());
    let mut files = Vec::new();
    collect_rs_files(&root, &mut files)
        .with_context(|| format!("walking {}", root.display()))?;
    files.sort();
    let mut report = AuditReport::default();
    for path in &files {
        let sub = path
            .strip_prefix(&root)
            .map_err(|e| crate::util::error::Error::new(e.to_string()))?;
        let rel_tail: Vec<String> =
            sub.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
        let rel = format!("{}/{}", m.root, rel_tail.join("/"));
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let file_report = audit_source(&rel, &text, m);
        report.findings.extend(file_report.findings);
        report.suppressed.extend(file_report.suppressed);
        report.files_scanned += 1;
    }
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(path.clone());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"
[classes]
deterministic = ["src/algorithms/"]
wire = ["src/wire.rs"]
overflow = ["src/gen.rs"]
cli = ["src/main.rs"]
[idents]
edge_count = ["n", "m"]
"#,
        )
        .unwrap()
    }

    #[test]
    fn findings_only_in_matching_classes() {
        let m = manifest();
        let src = "let s: std::collections::HashSet<u32> = x;\n";
        assert_eq!(audit_source("src/algorithms/a.rs", src, &m).findings.len(), 1);
        assert!(audit_source("src/util/a.rs", src, &m).is_clean());
    }

    #[test]
    fn justified_allow_suppresses_bare_allow_does_not() {
        let m = manifest();
        let ok = "let s = HashSet::new(); // audit:allow(hash-iter): probe-only, never iterated\n";
        let rep = audit_source("src/algorithms/a.rs", ok, &m);
        assert!(rep.is_clean(), "{:?}", rep.findings);
        assert_eq!(rep.suppressed.len(), 1);

        let bare = "let s = HashSet::new(); // audit:allow(hash-iter)\n";
        let rep = audit_source("src/algorithms/a.rs", bare, &m);
        assert_eq!(rep.findings.len(), 1);
        assert!(rep.findings[0].message.contains("justification"));
    }

    #[test]
    fn own_line_allow_covers_next_line() {
        let m = manifest();
        let src = "// audit:allow(hash-iter): membership probe, output re-sorted\nlet s = HashSet::new();\n";
        let rep = audit_source("src/algorithms/a.rs", src, &m);
        assert!(rep.is_clean(), "{:?}", rep.findings);
    }

    #[test]
    fn stale_and_unknown_allows_are_findings() {
        let m = manifest();
        let src = "let v = 1; // audit:allow(hash-iter): nothing here\nlet w = 2; // audit:allow(bogus-rule): hm\n";
        let rep = audit_source("src/algorithms/a.rs", src, &m);
        assert_eq!(rep.findings.len(), 2);
        assert!(rep.findings.iter().all(|f| f.rule == rules::META_RULE));
    }

    #[test]
    fn test_code_is_skipped() {
        let m = manifest();
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\n";
        assert!(audit_source("src/algorithms/a.rs", src, &m).is_clean());
    }

    #[test]
    fn json_report_shape() {
        let m = manifest();
        let rep = audit_source("src/algorithms/a.rs", "let s = HashSet::new();\n", &m);
        let j = rep.to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("arbocc-audit/v1"));
        assert_eq!(j.get("clean"), Some(&Json::Bool(false)));
        let findings = j.get("findings").and_then(Json::as_arr).unwrap();
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].get("rule").and_then(Json::as_str), Some("hash-iter"));
        assert!(findings[0].get("line").and_then(Json::as_f64).is_some());
    }
}
