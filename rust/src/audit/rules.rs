//! The eight audit rules (DESIGN.md §8).
//!
//! Each rule is a token-level check over comment-stripped code, scoped
//! to one module class from the manifest. The checks deliberately
//! over-approximate — a membership-only `HashSet` probe is flagged the
//! same as an order-leaking iteration — because the suppression channel
//! (`// audit:allow(<rule>): <justification>`) is where a human records
//! *why* a site is safe, turning every exception into a reviewed,
//! greppable artifact instead of tribal knowledge.

use crate::audit::manifest::Manifest;

/// One rule: id, the module class it applies to, and a summary line.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    pub id: &'static str,
    pub class: &'static str,
    pub summary: &'static str,
}

/// The rule registry. Classes refer to `[classes]` entries in
/// `audit.toml`; a manifest missing one of them fails to parse.
pub const RULES: &[Rule] = &[
    Rule {
        id: "hash-iter",
        class: "deterministic",
        summary: "HashMap/HashSet in a deterministic-path module (iteration order leaks \
                  into output — the PR 4 barabasi_albert bug class)",
    },
    Rule {
        id: "wall-clock",
        class: "deterministic",
        summary: "Instant/SystemTime/thread::current in algorithm code (results must be \
                  a function of inputs and seeds only)",
    },
    Rule {
        id: "raw-payload",
        class: "deterministic",
        summary: "raw payload[..] indexing that bypasses the wire.rs codec layer",
    },
    Rule {
        id: "unchecked-arith",
        class: "overflow",
        summary: "unchecked + or * on an edge-count expression (data/corpus.rs mandates \
                  checked_*/saturating_*)",
    },
    Rule {
        id: "cast-truncate",
        class: "wire",
        summary: "truncating `as` cast in a wire/codec/snapshot path (use u32::try_from \
                  or justify the guard)",
    },
    Rule {
        id: "panic-path",
        class: "cli",
        summary: "unwrap/expect/panic! on a CLI-reachable path (return a bail!-style \
                  error; PR 3 convention)",
    },
    Rule {
        id: "sort-ambiguous",
        class: "deterministic",
        summary: "comparator sort whose ties make output order ambiguous (use \
                  sort_unstable_by_key with a total key, as alg3 does)",
    },
    Rule {
        id: "rng-stream",
        class: "deterministic",
        summary: "RNG constructed outside the sanctioned seed-stream homes \
                  (pool::machine_rng, coordinator::trial_seed derivations)",
    },
];

/// Rule id for engine-synthesized findings about the suppression
/// mechanism itself (bare/stale/unknown `audit:allow` markers).
pub const META_RULE: &str = "audit-allow";

pub fn known(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

pub fn rule_ids() -> Vec<&'static str> {
    RULES.iter().map(|r| r.id).collect()
}

/// Run one rule over a comment-stripped code line. Returns the finding
/// message when the rule fires.
pub fn check(rule: &str, code: &str, manifest: &Manifest) -> Option<String> {
    match rule {
        "hash-iter" => first_token(code, &["HashMap", "HashSet"]).map(|t| {
            format!(
                "`{t}` in a deterministic-path module: iteration order leaks into \
                 output; use a vertex-indexed Vec or BTreeMap, or justify a \
                 probe-only use with audit:allow"
            )
        }),
        "wall-clock" => {
            first_token(code, &["Instant", "SystemTime", "thread::current"]).map(|t| {
                format!("`{t}` in algorithm code: wall-clock and thread identity must never influence results")
            })
        }
        "raw-payload" => code.contains("payload[").then(|| {
            "raw `payload[..]` indexing bypasses the wire.rs codec layer; use the typed \
             Encode/Decode frames"
                .to_string()
        }),
        "unchecked-arith" => unchecked_arith(code, &manifest.edge_count_idents),
        "cast-truncate" => first_token(code, &[" as u8", " as u16", " as u32"]).map(|t| {
            format!(
                "truncating cast `{}` in a wire/codec path: use u32::try_from, or \
                 document the range guard with audit:allow",
                t.trim()
            )
        }),
        "panic-path" => first_token(
            code,
            &[".unwrap()", ".expect(", "panic!", "unreachable!", "todo!", "unimplemented!"],
        )
        .map(|t| {
            format!(
                "`{t}` on a CLI-reachable path: return a bail!-style error so dispatch \
                 prints one line, never a backtrace"
            )
        }),
        "sort-ambiguous" => first_token(code, &[".sort_by(", ".sort_unstable_by("]).map(|t| {
            format!(
                "`{t}…)` comparator can hide a partial key: use sort_unstable_by_key \
                 with a total key so ties cannot reorder output"
            )
        }),
        "rng-stream" => code.contains("Rng::new(").then(|| {
            "`Rng::new` outside the sanctioned stream homes: derive streams via \
             pool::machine_rng / coordinator::trial_seed instead of constructing \
             ad-hoc generators"
                .to_string()
        }),
        _ => None,
    }
}

fn first_token<'a>(code: &str, tokens: &[&'a str]) -> Option<&'a str> {
    tokens.iter().copied().find(|t| code.contains(t))
}

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// The `unchecked-arith` core: flag a bare binary `*` with an edge-count
/// operand on either side, or a bare binary `+` with edge-count operands
/// on *both* sides (`n + 1` loop arithmetic stays quiet). Lines that
/// already use `checked_*`/`saturating_*`/`wrapping_*` or float math are
/// exempt.
fn unchecked_arith(code: &str, idents: &[String]) -> Option<String> {
    if ["checked_", "saturating_", "wrapping_", "f64", "f32"].iter().any(|t| code.contains(t)) {
        return None;
    }
    let b = code.as_bytes();
    for i in 0..b.len() {
        let op = b[i];
        if op != b'*' && op != b'+' {
            continue;
        }
        // `*=`, `+=`, `+ =`? no — just the compound-assign forms.
        if b.get(i + 1) == Some(&b'=') {
            continue;
        }
        let Some(left) = left_operand(b, i) else { continue };
        let right = right_operand(b, i);
        let lhit = left.as_deref().map(|t| idents.iter().any(|x| x == t)).unwrap_or(false);
        let rhit = right.as_deref().map(|t| idents.iter().any(|x| x == t)).unwrap_or(false);
        let fires = if op == b'*' { lhit || rhit } else { lhit && rhit };
        if fires {
            let tok = if lhit { left.unwrap_or_default() } else { right.unwrap_or_default() };
            return Some(format!(
                "unchecked `{}` on edge-count operand `{tok}`: use checked_*/saturating_* \
                 (the data/corpus.rs mandate)",
                op as char
            ));
        }
    }
    None
}

/// Identifier ending at the operator's left (through a closing bracket:
/// `g.m() * 2` resolves to `m`). `None` when the operator is unary or
/// the operand is a numeric literal.
fn left_operand(b: &[u8], op: usize) -> Option<Option<String>> {
    let mut i = op;
    loop {
        if i == 0 {
            return None; // line starts with the operator: unary / continuation
        }
        i -= 1;
        if b[i] != b' ' {
            break;
        }
    }
    if b[i] == b')' || b[i] == b']' {
        let close = b[i];
        let open = if close == b')' { b'(' } else { b'[' };
        let mut bal = 1i64;
        while i > 0 && bal > 0 {
            i -= 1;
            if b[i] == close {
                bal += 1;
            } else if b[i] == open {
                bal -= 1;
            }
        }
        if bal != 0 || i == 0 {
            return Some(None);
        }
        i -= 1; // char before the opening bracket
        if !is_ident_byte(b[i]) {
            return Some(None); // `(a + b) * n`: binary, opaque left operand
        }
        return Some(token_ending_at(b, i));
    }
    if !is_ident_byte(b[i]) {
        return None; // `(x * y`, `= *ptr`, `, *v` … unary or opaque
    }
    Some(token_ending_at(b, i))
}

/// Identifier starting at the operator's right, skipping unary `&`/`*`
/// and opening parens (`n * (m - 1)` resolves to `m`).
fn right_operand(b: &[u8], op: usize) -> Option<String> {
    let mut i = op + 1;
    while i < b.len() && matches!(b[i], b' ' | b'(' | b'&') {
        i += 1;
    }
    if i >= b.len() || !is_ident_byte(b[i]) {
        return None;
    }
    let start = i;
    while i < b.len() && is_ident_byte(b[i]) {
        i += 1;
    }
    non_numeric_token(&b[start..i])
}

fn token_ending_at(b: &[u8], last: usize) -> Option<String> {
    let end = last + 1;
    let mut start = last;
    while start > 0 && is_ident_byte(b[start - 1]) {
        start -= 1;
    }
    non_numeric_token(&b[start..end])
}

fn non_numeric_token(bytes: &[u8]) -> Option<String> {
    let tok = std::str::from_utf8(bytes).ok()?;
    if tok.is_empty() || tok.bytes().next().is_some_and(|c| c.is_ascii_digit()) {
        return None; // numeric literal (incl. typed forms like 100usize)
    }
    Some(tok.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"
[classes]
deterministic = ["src/"]
wire = ["src/"]
overflow = ["src/"]
cli = ["src/"]
[idents]
edge_count = ["n", "m", "k", "w"]
"#,
        )
        .unwrap()
    }

    #[test]
    fn registry_ids_are_unique_and_known() {
        let ids = rule_ids();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len());
        assert!(known("hash-iter"));
        assert!(!known(META_RULE), "the meta rule is synthesized, not registered");
    }

    #[test]
    fn arith_rule_distinguishes_ops() {
        let m = manifest();
        // `*` fires on one edge-count side; `+` needs both.
        assert!(check("unchecked-arith", "let t = 100 * m_total;", &m).is_none());
        assert!(check("unchecked-arith", "let t = 100 * m;", &m).is_some());
        assert!(check("unchecked-arith", "let t = n * (m - 1) / 2;", &m).is_some());
        assert!(check("unchecked-arith", "let t = g.m() * 2;", &m).is_some());
        assert!(check("unchecked-arith", "let next = i + 1;", &m).is_none());
        assert!(check("unchecked-arith", "let t = n + m;", &m).is_some());
        assert!(check("unchecked-arith", "let t = n.checked_mul(m);", &m).is_none());
        assert!(check("unchecked-arith", "let avg = 2.0 * g.m() as f64;", &m).is_none());
        assert!(check("unchecked-arith", "let p = *ptr;", &m).is_none());
    }

    #[test]
    fn token_rules_fire_on_their_tokens() {
        let m = manifest();
        assert!(check("hash-iter", "let s: HashSet<u32> = x;", &m).is_some());
        assert!(check("hash-iter", "let v: Vec<u32> = x;", &m).is_none());
        assert!(check("wall-clock", "let t = Instant::now();", &m).is_some());
        assert!(check("raw-payload", "let x = payload[0];", &m).is_some());
        assert!(check("cast-truncate", "let x = len as u32;", &m).is_some());
        assert!(check("cast-truncate", "let x = len as u64;", &m).is_none());
        assert!(check("panic-path", "let x = v.last().unwrap();", &m).is_some());
        assert!(check("panic-path", "let x = v.last().unwrap_or(&0);", &m).is_none());
        assert!(check("sort-ambiguous", "v.sort_by(|a, b| a.cmp(b));", &m).is_some());
        assert!(check("sort-ambiguous", "v.sort_unstable_by_key(|x| x.0);", &m).is_none());
        assert!(check("rng-stream", "let mut rng = Rng::new(7);", &m).is_some());
    }
}
