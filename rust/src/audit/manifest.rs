//! The checked-in module-classification manifest (`audit.toml`).
//!
//! A tiny TOML-subset parser (the offline registry has no `toml` crate;
//! see DESIGN.md §2): sections, `key = "string"` and
//! `key = ["a", "b", …]` entries (arrays may span lines), `#` comments.
//! The manifest declares which path prefixes belong to which *module
//! class* — `deterministic`, `wire`, `overflow`, `cli` — plus per-rule
//! path exemptions (the sanctioned homes of an otherwise-banned
//! construct) and the edge-count identifier set the `unchecked-arith`
//! rule watches. Strict by construction: unknown sections, unknown keys
//! and unknown rule ids are parse errors, so a typo can never silently
//! disable a rule.

use std::collections::BTreeMap;
use std::path::Path;

use crate::audit::rules;
use crate::util::error::{Error, Result, ResultExt};

/// Parsed `audit.toml`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Scan root, relative to the manifest's directory (usually `src`).
    pub root: String,
    /// Module class -> path prefixes (dirs end in `/`, files are exact).
    pub classes: BTreeMap<String, Vec<String>>,
    /// Rule id -> path prefixes where the rule does not apply.
    pub exempt: BTreeMap<String, Vec<String>>,
    /// Identifiers the `unchecked-arith` rule treats as edge counts.
    pub edge_count_idents: Vec<String>,
}

impl Manifest {
    /// Read and parse a manifest file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse manifest text (strict: unknown names are errors).
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest { root: "src".to_string(), ..Manifest::default() };
        let mut section = String::new();
        // A `key = [` entry accumulating lines until brackets balance.
        let mut pending: Option<(usize, String, String)> = None;

        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim().to_string();
            if let Some((start, key, mut value)) = pending.take() {
                value.push(' ');
                value.push_str(&line);
                if brackets_balance(&value) {
                    m.entry(&section, &key, &value, start)?;
                } else {
                    pending = Some((start, key, value));
                }
                continue;
            }
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                crate::ensure!(
                    matches!(section.as_str(), "audit" | "classes" | "exempt" | "idents"),
                    "line {lineno}: unknown section [{section}] (audit|classes|exempt|idents)"
                );
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                crate::bail!("line {lineno}: expected `key = value`, got '{line}'");
            };
            let (key, value) = (key.trim().to_string(), value.trim().to_string());
            if brackets_balance(&value) {
                m.entry(&section, &key, &value, lineno)?;
            } else {
                pending = Some((lineno, key, value));
            }
        }
        if let Some((lineno, key, _)) = pending {
            crate::bail!("line {lineno}: unclosed array for key '{key}'");
        }
        for rule in rules::RULES {
            crate::ensure!(
                m.classes.contains_key(rule.class),
                "rule '{}' needs a [classes] entry for '{}' — without it the rule \
                 would silently never run",
                rule.id,
                rule.class
            );
        }
        Ok(m)
    }

    fn entry(&mut self, section: &str, key: &str, value: &str, lineno: usize) -> Result<()> {
        match section {
            "audit" => {
                crate::ensure!(key == "root", "line {lineno}: unknown [audit] key '{key}'");
                self.root = parse_string(value)
                    .ok_or_else(|| Error::new(format!("line {lineno}: root must be a string")))?;
            }
            "classes" => {
                self.classes.insert(key.to_string(), parse_string_array(value, lineno)?);
            }
            "exempt" => {
                crate::ensure!(
                    rules::known(key),
                    "line {lineno}: [exempt] names unknown rule '{key}' (known: {})",
                    rules::rule_ids().join("|")
                );
                self.exempt.insert(key.to_string(), parse_string_array(value, lineno)?);
            }
            "idents" => {
                crate::ensure!(
                    key == "edge_count",
                    "line {lineno}: unknown [idents] key '{key}'"
                );
                self.edge_count_idents = parse_string_array(value, lineno)?;
            }
            _ => crate::bail!("line {lineno}: entry '{key}' outside any section"),
        }
        Ok(())
    }

    /// All classes whose prefix list matches this path (deterministic
    /// order — `classes` is a BTreeMap).
    pub fn classes_of(&self, rel: &str) -> Vec<&str> {
        self.classes
            .iter()
            .filter(|(_, prefixes)| prefixes.iter().any(|p| rel.starts_with(p.as_str())))
            .map(|(name, _)| name.as_str())
            .collect()
    }

    /// Whether `rule` is manifest-exempted for this path.
    pub fn is_exempt(&self, rule: &str, rel: &str) -> bool {
        self.exempt
            .get(rule)
            .map(|prefixes| prefixes.iter().any(|p| rel.starts_with(p.as_str())))
            .unwrap_or(false)
    }
}

/// Cut the line at the first `#` outside quotes.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// `[` / `]` balance outside quotes (array values may span lines).
fn brackets_balance(value: &str) -> bool {
    let mut in_str = false;
    let mut depth = 0i64;
    for c in value.chars() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            _ => {}
        }
    }
    depth == 0
}

fn parse_string(value: &str) -> Option<String> {
    value.strip_prefix('"')?.strip_suffix('"').map(|s| s.to_string())
}

fn parse_string_array(value: &str, lineno: usize) -> Result<Vec<String>> {
    let inner = value
        .trim()
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| Error::new(format!("line {lineno}: expected a [\"…\"] array")))?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let s = parse_string(part).ok_or_else(|| {
            Error::new(format!("line {lineno}: array item '{part}' is not a quoted string"))
        })?;
        out.push(s);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# comment
[audit]
root = "src"

[classes]
deterministic = [
    "src/algorithms/",  # trailing comment
    "src/mpc/",
]
wire = ["src/mpc/wire.rs"]
overflow = ["src/data/"]
cli = ["src/main.rs"]

[exempt]
rng-stream = ["src/mpc/pool.rs"]

[idents]
edge_count = ["n", "m"]
"#;

    #[test]
    fn parses_sections_and_multiline_arrays() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.root, "src");
        assert_eq!(
            m.classes["deterministic"],
            vec!["src/algorithms/".to_string(), "src/mpc/".to_string()]
        );
        assert_eq!(m.edge_count_idents, vec!["n".to_string(), "m".to_string()]);
    }

    #[test]
    fn classifies_paths_by_prefix() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.classes_of("src/mpc/wire.rs"), vec!["deterministic", "wire"]);
        assert_eq!(m.classes_of("src/util/rng.rs"), Vec::<&str>::new());
        assert!(m.is_exempt("rng-stream", "src/mpc/pool.rs"));
        assert!(!m.is_exempt("rng-stream", "src/mpc/wire.rs"));
    }

    #[test]
    fn unknown_names_are_errors() {
        assert!(Manifest::parse("[nope]\n").is_err());
        let bad_rule = SAMPLE.replace("rng-stream", "rngg");
        assert!(Manifest::parse(&bad_rule).unwrap_err().to_string().contains("unknown rule"));
        // A rule class with no [classes] entry would silently disable the
        // rule — that's a parse error.
        let no_cli = SAMPLE.replace("cli = [\"src/main.rs\"]", "");
        assert!(Manifest::parse(&no_cli).unwrap_err().to_string().contains("needs a [classes]"));
    }
}
