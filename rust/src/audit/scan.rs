//! The light line/token scanner under the audit engine.
//!
//! Rules match on *code text only*: this pass blanks string/char literal
//! contents and drops comments (line, block, nested block), so a rule
//! token inside a message string or a doc comment never fires. It also
//! tracks `#[cfg(test)]` items by brace depth — test code is allowed to
//! `unwrap` and build `HashSet`s freely, the production invariants live
//! outside it — and parses `// audit:allow(<rule>): <justification>`
//! suppression markers from the comments it strips.

/// One scanned source line.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// The raw source line (what findings quote back).
    pub raw: String,
    /// Code text with comments removed and literal contents blanked.
    pub code: String,
    /// Trailing `//` comment text (without the slashes), if any.
    pub comment: String,
    /// Inside a `#[cfg(test)]` item (attribute line through closing brace).
    pub in_test: bool,
}

/// One `audit:allow(<rule>)` suppression marker.
#[derive(Debug, Clone)]
pub struct Allow {
    /// Line the marker sits on.
    pub line: usize,
    /// Rule id named inside the parentheses.
    pub rule: String,
    /// Text after the closing `):` — empty means no justification given.
    pub justification: String,
    /// The line carries no code, so the marker covers the *next* line.
    pub own_line: bool,
    /// Marker lives inside test code (never stale, never consumed).
    pub in_test: bool,
}

/// A fully scanned file.
#[derive(Debug, Default)]
pub struct Scan {
    pub lines: Vec<Line>,
    pub allows: Vec<Allow>,
}

/// Scanner mode carried across lines (strings and block comments span
/// line boundaries; everything else resolves within one line).
#[derive(Debug, Clone, Copy, PartialEq)]
enum Mode {
    Code,
    /// Nested block comment, with depth.
    Block(u32),
    /// Inside a `"…"` string literal.
    Str,
    /// Inside a raw string; the payload is the `#` count of the opener.
    RawStr(u32),
}

fn is_ident(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

/// Scan one file into comment-stripped lines plus suppression markers.
pub fn scan(source: &str) -> Scan {
    let mut out = Scan::default();
    let mut mode = Mode::Code;
    let mut depth: i64 = 0;
    // `#[cfg(test)]` seen; waiting for the item's opening brace.
    let mut pending_test = false;
    // Brace depth at which the active test item opened.
    let mut test_depth: Option<i64> = None;

    for (idx, raw) in source.lines().enumerate() {
        let number = idx + 1;
        let mut code = String::new();
        let mut comment = String::new();
        let chars: Vec<char> = raw.chars().collect();
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            match mode {
                Mode::Block(d) => {
                    if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(d + 1);
                        i += 2;
                    } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                        mode = if d == 1 { Mode::Code } else { Mode::Block(d - 1) };
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                Mode::Str => {
                    if c == '\\' {
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        mode = Mode::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    if c == '"' && closes_raw(&chars, i, hashes) {
                        code.push('"');
                        mode = Mode::Code;
                        i += 1 + hashes as usize;
                    } else {
                        i += 1;
                    }
                }
                Mode::Code => {
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        comment = chars[i + 2..].iter().collect();
                        break;
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(1);
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        mode = Mode::Str;
                        i += 1;
                    } else if c == 'r' && !prev_is_ident(&chars, i) {
                        match raw_string_hashes(&chars, i) {
                            Some(h) => {
                                code.push('"');
                                mode = Mode::RawStr(h);
                                // skip `r`, the hashes and the quote
                                i += 2 + h as usize;
                            }
                            None => {
                                code.push(c);
                                i += 1;
                            }
                        }
                    } else if c == '\'' {
                        // Char literal vs lifetime: a literal closes with
                        // a quote within a couple of chars.
                        if let Some(adv) = char_literal_len(&chars, i) {
                            code.push('\'');
                            code.push('\'');
                            i += adv;
                        } else {
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }

        // Test-block tracking over the stripped code text.
        let mut in_test = test_depth.is_some() || pending_test;
        for c in code.chars() {
            match c {
                '{' => {
                    if pending_test && test_depth.is_none() {
                        test_depth = Some(depth);
                        pending_test = false;
                        in_test = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if test_depth == Some(depth) {
                        test_depth = None;
                        in_test = true; // the closing brace line is still test code
                    }
                }
                _ => {}
            }
        }
        if code.contains("#[cfg(test)]") {
            pending_test = true;
            in_test = true;
        }

        if let Some((rule, justification)) = parse_allow(&comment) {
            out.allows.push(Allow {
                line: number,
                rule,
                justification,
                own_line: code.trim().is_empty(),
                in_test,
            });
        }
        out.lines.push(Line { number, raw: raw.to_string(), code, comment, in_test });
    }
    out
}

/// `r`, `r#`, `r##`… opener check at position `i` (pointing at the `r`).
/// Returns the hash count when this really starts a raw string.
fn raw_string_hashes(chars: &[char], i: usize) -> Option<u32> {
    let mut j = i + 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(hashes)
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && is_ident(chars[i - 1])
}

/// `"` at `i` closes a raw string only when followed by its hash count.
fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Length of a char literal starting at the `'` at `i`, or `None` for a
/// lifetime (`'a`, `'static`) which has no closing quote nearby.
fn char_literal_len(chars: &[char], i: usize) -> Option<usize> {
    match chars.get(i + 1) {
        Some('\\') => {
            // Escaped char: scan to the closing quote (bounded — `\u{…}`
            // is the longest form).
            let mut j = i + 2;
            while j < chars.len() && j < i + 12 {
                if chars[j] == '\'' {
                    return Some(j - i + 1);
                }
                j += 1;
            }
            None
        }
        Some(_) if chars.get(i + 2) == Some(&'\'') => Some(3),
        _ => None,
    }
}

/// Parse `audit:allow(<rule>)` plus the optional `: justification` tail
/// out of a comment. The marker must BE the comment (first thing after
/// the slashes) — prose that merely mentions the syntax, like this doc
/// comment or the module headers, is not a marker. Doc comments can
/// never be markers either: their text starts with the extra `/` or `!`.
fn parse_allow(comment: &str) -> Option<(String, String)> {
    let rest = comment.trim_start().strip_prefix("audit:allow(")?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    if rule.is_empty() || !rule.chars().all(|c| c == '-' || c.is_ascii_alphanumeric()) {
        return None; // `audit:allow(<rule>)` in prose is not a marker
    }
    let after = &rest[close + 1..];
    let justification =
        after.strip_prefix(':').map(|s| s.trim().to_string()).unwrap_or_default();
    Some((rule, justification))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        scan(src).lines.into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_and_block_comments() {
        let c = code_of("let x = 1; // HashMap here\nlet y = 2; /* HashSet */ let z;\n");
        assert_eq!(c[0], "let x = 1; ");
        assert_eq!(c[1], "let y = 2;  let z;");
    }

    #[test]
    fn blanks_string_and_char_literals() {
        let c = code_of("let s = \"HashMap::new()\"; let c = 'x'; let l: &'static str;");
        assert!(!c[0].contains("HashMap"));
        assert!(!c[0].contains('x'));
        assert!(c[0].contains("&'static str"));
    }

    #[test]
    fn multiline_strings_and_nested_blocks_carry_over() {
        let c = code_of("let s = \"one \\\n  HashMap two\";\n/* outer /* HashSet */ still */ done");
        assert!(!c.concat().contains("HashMap"));
        assert!(!c.concat().contains("HashSet"));
        assert!(c[2].contains("done"));
    }

    #[test]
    fn raw_strings_blank() {
        let c = code_of("let s = r#\"HashMap \"quoted\" inside\"#; tail()");
        assert!(!c[0].contains("HashMap"));
        assert!(c[0].contains("tail()"));
    }

    #[test]
    fn cfg_test_blocks_are_marked() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x(); }\n}\nfn after() {}\n";
        let s = scan(src);
        let flags: Vec<bool> = s.lines.iter().map(|l| l.in_test).collect();
        assert_eq!(flags, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn parses_allow_markers() {
        let src = "x(); // audit:allow(hash-iter): probe-only set\n// audit:allow(cast-truncate)\ny();\n";
        let s = scan(src);
        assert_eq!(s.allows.len(), 2);
        assert_eq!(s.allows[0].rule, "hash-iter");
        assert_eq!(s.allows[0].justification, "probe-only set");
        assert!(!s.allows[0].own_line);
        assert_eq!(s.allows[1].rule, "cast-truncate");
        assert_eq!(s.allows[1].justification, "");
        assert!(s.allows[1].own_line);
    }

    #[test]
    fn prose_mentions_are_not_markers() {
        // Mid-comment mentions, doc-comment syntax examples and
        // placeholder rule names must not register as suppressions —
        // the audit module's own docs would otherwise flag themselves.
        let src = "\
// see the audit:allow(hash-iter) marker above\n\
/// write `// audit:allow(<rule>): <justification>` on the line\n\
//! docs show audit:allow(rule): why\n\
// audit:allow(<rule>): placeholder name\n\
x();\n";
        assert!(scan(src).allows.is_empty());
    }
}
