//! # arbocc
//!
//! A production-grade reproduction of **"Massively Parallel Correlation
//! Clustering in Bounded Arboricity Graphs"** (Cambus, Choo, Miikonen,
//! Uitto — DISC 2021) as a three-layer Rust + JAX + Pallas system.
//!
//! * [`graph`] — CSR graphs, workload generators, arboricity estimation.
//! * [`mpc`] — the MPC model simulator: machines, rounds, memory budgets,
//!   broadcast trees, graph exponentiation.
//! * [`cluster`] — correlation-clustering core: costs, bad triangles,
//!   exact small-instance optima, the Lemma 25 structural transform.
//! * [`algorithms`] — the paper's algorithms (PIVOT, randomized greedy
//!   MIS, Algorithms 1–4, matching-based forest algorithms, the O(λ²)
//!   simple algorithm) and its baselines (ParallelPivot, C4,
//!   ClusterWild!).
//! * [`data`] — the dataset subsystem: edge-list / `arbocc-csr/v1`
//!   snapshot IO and the string-addressable generator corpus
//!   (`planted:n=50000,k=40,p=0.05,seed=7`) feeding the CLI, the solver
//!   engine and the perf lab.
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`), with a bit-identical pure-Rust
//!   fallback.
//! * [`coordinator`] — leader/worker orchestration and the best-of-K
//!   scoring driver (Remark 14).
//! * [`solve`] — the unified solver engine: one `Solver` trait over the
//!   whole algorithm family, a structure-aware planner (Theorem 26 /
//!   Corollary 27–32 decision tree), and the per-component sharded
//!   decomposition driver.
//! * [`bench`] — micro-benchmark harness and experiment workloads.
//! * [`audit`] — the determinism & MPC-invariant static analysis pass
//!   (`arbocc audit`): class-scoped token rules over `rust/src`,
//!   driven by the checked-in `audit.toml` manifest.
//! * [`util`] — PRNG, statistics, JSON reports, property testing, CLI.
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for
//! measured results.

pub mod algorithms;
pub mod audit;
pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod graph;
pub mod mpc;
pub mod runtime;
pub mod solve;
pub mod util;
