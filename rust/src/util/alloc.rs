//! Allocation counting for the perf lab: a [`System`]-backed
//! [`GlobalAlloc`] wrapper that counts heap allocations (alloc,
//! realloc and alloc_zeroed; frees are not counted), installed as the
//! `#[global_allocator]` by the binaries that report allocation-count
//! metrics — the `arbocc` CLI and `benches/message_plane.rs`.
//!
//! The library itself never installs it. Scenario code probes
//! [`installed`] at run time and skips allocation metrics when the
//! host binary runs on the plain system allocator (e.g. the unit-test
//! harness), so the same scenario source works in every binary.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Counting wrapper over the system allocator. Zero-sized; the count
/// lives in a process-global atomic so [`allocations`] works without a
/// handle to the installed instance.
pub struct CountingAlloc;

// SAFETY: defers every operation to `System`, which upholds the
// `GlobalAlloc` contract; the counter increment has no effect on the
// returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Heap allocations observed since process start. Stays 0 forever when
/// the host binary did not install [`CountingAlloc`].
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Whether the host binary installed [`CountingAlloc`] as its global
/// allocator: performs one heap allocation through an opaque call and
/// checks that the counter moved.
pub fn installed() -> bool {
    let before = allocations();
    std::hint::black_box(Box::new(before));
    allocations() > before
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test (not several) so the shared counter is not raced by
    // parallel test threads bumping it through the manual calls below.
    #[test]
    fn manual_calls_count_but_probe_reports_uninstalled() {
        // The unit-test harness runs on the system allocator.
        assert!(!installed());
        assert_eq!(allocations(), 0);

        let a = CountingAlloc;
        let layout = Layout::from_size_align(64, 8).unwrap();
        unsafe {
            let p = a.alloc(layout);
            assert!(!p.is_null());
            let p = a.realloc(p, layout, 128);
            assert!(!p.is_null());
            a.dealloc(p, Layout::from_size_align(128, 8).unwrap());
            let z = a.alloc_zeroed(layout);
            assert!(!z.is_null());
            assert_eq!(*z, 0);
            a.dealloc(z, layout);
        }
        assert_eq!(allocations(), 3);

        // Still uninstalled: ordinary allocations bypass the counter.
        assert!(!installed());
    }
}
