//! Minimal JSON writer for experiment reports (serde is unavailable in the
//! offline registry; this covers the small value tree the benches emit).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Num` stores f64; integers round-trip exactly below 2^53.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    // BTreeMap => deterministic key order => diffable reports.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, value: Json) -> &mut Self {
        match self {
            Json::Arr(v) => v.push(value),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 9.0e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    // JSON has no Inf/NaN; stringify for report robustness.
                    let _ = write!(out, "\"{x}\"");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    out.push_str(&pad_in);
                    item.write(out, indent + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    out.push_str(&pad_in);
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < map.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Write a report under `reports/<name>.json`, creating the directory.
pub fn write_report(name: &str, value: &Json) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("reports");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.pretty())?;
    Ok(path)
}

// ---------------------------------------------------------------------
// Parser — used to read `artifacts/manifest.json` (the AOT contract) and
// to round-trip experiment reports. Full JSON minus exotic escapes.
// ---------------------------------------------------------------------

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, message: &str) -> ParseError {
        ParseError { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\n') | Some(b'\t') | Some(b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

impl Json {
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let mut o = Json::obj();
        o.set("n", Json::num(1024))
            .set("ratio", Json::num(2.5))
            .set("name", Json::str("pivot"))
            .set("ok", Json::Bool(true))
            .set("xs", Json::arr([Json::num(1), Json::num(2)]));
        let s = o.pretty();
        assert!(s.contains("\"n\": 1024"));
        assert!(s.contains("\"ratio\": 2.5"));
        assert!(s.contains("\"name\": \"pivot\""));
        assert!(s.contains('['));
    }

    #[test]
    fn escapes_strings() {
        let s = Json::str("a\"b\\c\nd").pretty();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn integers_have_no_fraction() {
        assert_eq!(Json::num(3.0).pretty(), "3");
        assert_eq!(Json::num(3.25).pretty(), "3.25");
    }

    #[test]
    fn deterministic_key_order() {
        let mut o = Json::obj();
        o.set("zebra", Json::num(1)).set("alpha", Json::num(2));
        let s = o.pretty();
        assert!(s.find("alpha").unwrap() < s.find("zebra").unwrap());
    }

    #[test]
    fn parse_roundtrip() {
        let mut o = Json::obj();
        o.set("n", Json::num(1024))
            .set("ratio", Json::num(2.5))
            .set("name", Json::str("piv\"ot"))
            .set("ok", Json::Bool(true))
            .set("none", Json::Null)
            .set("xs", Json::arr([Json::num(1), Json::num(-2.5), Json::str("a\nb")]));
        let text = o.pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn parse_plain_documents() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("[1, 2]").unwrap(), Json::arr([Json::num(1), Json::num(2)]));
        assert_eq!(parse("  {}  ").unwrap(), Json::obj());
        assert_eq!(parse("\"hi\\u0041\"").unwrap(), Json::str("hiA"));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
    }

    #[test]
    fn parse_errors_have_offsets() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        let err = parse("{\"a\" 1}").unwrap_err();
        assert!(err.offset > 0);
    }

    #[test]
    fn accessors() {
        let v = parse("{\"a\": 1, \"b\": \"x\", \"c\": [2]}").unwrap();
        assert_eq!(v.get("a").and_then(Json::as_f64), Some(1.0));
        assert_eq!(v.get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("c").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        assert!(v.get("missing").is_none());
    }
}
