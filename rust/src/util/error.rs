//! Minimal string-carrying error type — the `anyhow` replacement
//! (unavailable in the offline registry; see DESIGN.md §2).
//!
//! Fallible system paths (runtime loading, report IO, the coordinator)
//! return [`Result`]. Errors carry a human-readable message plus optional
//! context frames added with [`Error::context`] / [`ResultExt::context`],
//! mirroring the `anyhow::Context` idiom:
//!
//! ```ignore
//! let proto = parse(&text).context("parsing cost_eval.hlo.txt")?;
//! arbocc::ensure!(a == b, "cost mismatch: {a:?} vs {b:?}");
//! ```

/// Crate-wide error: a message with optional context frames.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into(), context: Vec::new() }
    }

    /// Attach a context frame (outermost printed first, like anyhow).
    pub fn context(mut self, frame: impl Into<String>) -> Error {
        self.context.push(frame.into());
        self
    }

    pub fn message(&self) -> &str {
        &self.msg
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for frame in self.context.iter().rev() {
            write!(f, "{frame}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error::new(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::new(msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::new(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::new(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style helper for adding frames to any fallible value.
pub trait ResultExt<T> {
    fn context(self, frame: impl Into<String>) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, frame: F) -> Result<T>;
}

impl<T, E: std::fmt::Display> ResultExt<T> for std::result::Result<T, E> {
    fn context(self, frame: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::new(e.to_string()).context(frame))
    }

    fn with_context<F: FnOnce() -> String>(self, frame: F) -> Result<T> {
        self.map_err(|e| Error::new(e.to_string()).context(frame()))
    }
}

/// `anyhow::ensure!` twin: early-return an [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::util::error::Error::new(format!($($arg)+)).into());
        }
    };
}

/// `anyhow::bail!` twin: early-return an [`Error`] unconditionally.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::util::error::Error::new(format!($($arg)+)).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context_outermost_first() {
        let e = Error::new("file missing").context("loading artifact").context("engine init");
        assert_eq!(e.to_string(), "engine init: loading artifact: file missing");
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn result_ext_adds_frames() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn ensure_macro_returns_error() {
        fn check(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(5).unwrap(), 5);
        assert_eq!(check(50).unwrap_err().to_string(), "x too big: 50");
    }
}
