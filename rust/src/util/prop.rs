//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! Provides the discipline that matters for this codebase: run an invariant
//! against many seeded random inputs, and on failure report the seed and a
//! size-minimized counterexample.  Generators are plain closures over
//! [`crate::util::rng::Rng`]; shrinking halves the "size" knob until the
//! failure disappears, then reports the smallest failing size/seed pair.
//!
//! Usage:
//! ```ignore
//! forall("clustering is a partition", 200, |rng, size| {
//!     let g = random_graph(rng, size);
//!     let c = pivot(&g, rng);
//!     check!(c.is_partition(g.n()));
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Result of a single property case: `Err(msg)` is a counterexample.
pub type CaseResult = Result<(), String>;

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropFailure {
    pub property: String,
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

impl std::fmt::Display for PropFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property '{}' failed at seed={} size={}: {}",
            self.property, self.seed, self.size, self.message
        )
    }
}

/// Run `cases` random cases of the property, with sizes ramping from
/// `min_size` to `max_size`.  On failure, shrink the size by halving while
/// the property still fails with the same seed, and panic with the minimal
/// counterexample (standard test-failure signaling).
pub fn forall_sized<F>(
    property: &str,
    cases: usize,
    min_size: usize,
    max_size: usize,
    mut f: F,
) where
    F: FnMut(&mut Rng, usize) -> CaseResult,
{
    // Base seed is fixed: test runs are reproducible by construction, and
    // per-case streams are forked from it.
    let base_seed = 0xA5B0_CC00_0000_0000u64 ^ (hash_str(property));
    let mut driver = Rng::new(base_seed);
    for case in 0..cases {
        let case_seed = driver.next_u64();
        let size = if cases <= 1 {
            max_size
        } else {
            min_size + (max_size - min_size) * case / (cases - 1)
        };
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = f(&mut rng, size) {
            // Shrink: halve size while it still fails.
            let (min_fail_size, min_msg) = shrink(case_seed, size, min_size, &mut f, msg);
            let failure = PropFailure {
                property: property.to_string(),
                seed: case_seed,
                size: min_fail_size,
                message: min_msg,
            };
            panic!("{failure}");
        }
    }
}

/// Convenience wrapper with a default size ramp of 2..=64.
pub fn forall<F>(property: &str, cases: usize, f: F)
where
    F: FnMut(&mut Rng, usize) -> CaseResult,
{
    forall_sized(property, cases, 2, 64, f)
}

fn shrink<F>(
    seed: u64,
    mut size: usize,
    min_size: usize,
    f: &mut F,
    mut last_msg: String,
) -> (usize, String)
where
    F: FnMut(&mut Rng, usize) -> CaseResult,
{
    let mut best = size;
    while size > min_size {
        let candidate = min_size.max(size / 2);
        if candidate == size {
            break;
        }
        let mut rng = Rng::new(seed);
        match f(&mut rng, candidate) {
            Err(msg) => {
                best = candidate;
                last_msg = msg;
                size = candidate;
            }
            Ok(()) => break,
        }
    }
    (best, last_msg)
}

fn hash_str(s: &str) -> u64 {
    crate::util::fnv1a(s.as_bytes())
}

/// Assert-like macro producing a `CaseResult` error with context.
#[macro_export]
macro_rules! prop_check {
    ($cond:expr) => {
        if !$cond {
            return Err(format!("check failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!("check failed: {}: {}", stringify!($cond), format!($($arg)+)));
        }
    };
}

/// Equality check with value printing.
#[macro_export]
macro_rules! prop_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum of two indices below 2n", 50, |rng, size| {
            let a = rng.index(size.max(1));
            let b = rng.index(size.max(1));
            prop_check!(a + b < 2 * size.max(1));
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_context() {
        forall("always-failing", 10, |_rng, _size| Err("always fails".into()));
    }

    #[test]
    fn shrinking_reports_smaller_size() {
        let result = std::panic::catch_unwind(|| {
            forall_sized("fails above 10", 50, 2, 64, |_rng, size| {
                if size > 10 {
                    Err(format!("size {size} too big"))
                } else {
                    Ok(())
                }
            });
        });
        let err = result.expect_err("property should fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        // The shrinker should get at or below 2x the threshold.
        assert!(msg.contains("size="), "got: {msg}");
    }

    #[test]
    fn prop_eq_formats_values() {
        fn inner() -> CaseResult {
            prop_eq!(1 + 1, 2);
            Ok(())
        }
        assert!(inner().is_ok());
    }
}
