//! Deterministic PRNG stack: SplitMix64 seeding + Xoshiro256++ streams.
//!
//! The uniform-at-random vertex permutation `π` is *load-bearing* in this
//! system — greedy MIS, PIVOT and every MPC schedule are defined relative
//! to it, and the paper's guarantees are statements about its distribution.
//! Owning the generator (instead of depending on `rand`, unavailable in the
//! offline registry) makes every experiment bit-reproducible from a `u64`
//! seed recorded in the report.
//!
//! Xoshiro256++ is the reference algorithm of Blackman & Vigna (2019);
//! SplitMix64 is the recommended seeder that avoids correlated low-entropy
//! states.

/// SplitMix64: stateless-style stream used to expand seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++: the main generator. Period 2^256 - 1.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Build a generator from a user-facing seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // An all-zero state is the one forbidden state; SplitMix64 cannot
        // produce four consecutive zeros from any seed, but keep the guard
        // for clarity.
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x1;
        }
        Self { s }
    }

    /// Derive an independent child stream (for per-worker / per-trial RNGs).
    ///
    /// Implemented as the Xoshiro `jump`-free alternative: reseed through
    /// SplitMix64 from a draw plus a stream tag, which is sufficient
    /// decorrelation for simulation workloads.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(mixed)
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniform-at-random permutation π : position -> vertex, as the paper
    /// uses it (π(1), ..., π(n) is the processing order).
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the published SplitMix64.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn deterministic_per_seed() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(2);
        let same = (0..64).filter(|_| r1.next_u64() == r2.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn next_below_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let x = r.next_below(10);
            assert!(x < 10);
            counts[x as usize] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(99);
        let p = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &v in &p {
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_distinct(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&x| x < 100));
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut base = Rng::new(11);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
