//! Wall-clock timing helpers for the bench harness and examples.

use std::time::{Duration, Instant};

/// Scoped timer: `let t = Timer::start(); ...; t.elapsed_ms()`.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

/// Human-readable duration for logs.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.0}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else {
        format!("{:.1}min", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_result() {
        let (x, secs) = timed(|| 41 + 1);
        assert_eq!(x, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-5).ends_with("µs"));
        assert!(fmt_duration(5e-2).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with('s'));
        assert!(fmt_duration(500.0).ends_with("min"));
    }
}
