//! Shared infrastructure: PRNG, statistics, JSON reports, property testing,
//! CLI parsing, error handling, tables and timers.
//!
//! These replace `rand`, `proptest`, `serde`, `clap`, `anyhow` and
//! `criterion`, none of which are available in the offline crate registry
//! (see DESIGN.md §2).

pub mod alloc;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

/// FNV-1a over a byte slice — the one non-cryptographic hash the crate
/// uses (property-test seed derivation, the `arbocc-csr/v1` snapshot
/// checksum).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(super::fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
