//! Shared infrastructure: PRNG, statistics, JSON reports, property testing,
//! CLI parsing, error handling, tables and timers.
//!
//! These replace `rand`, `proptest`, `serde`, `clap`, `anyhow` and
//! `criterion`, none of which are available in the offline crate registry
//! (see DESIGN.md §2).

pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;
