//! Aligned ASCII table printer — every bench prints its paper-shaped table
//! through this so the output format is uniform and diffable.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            // Default: first column left (labels), rest right (numbers).
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: format heterogeneous cells via `ToString`.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                match aligns[i] {
                    Align::Left => line.push_str(&format!("{:<width$}", cell, width = widths[i])),
                    Align::Right => line.push_str(&format!("{:>width$}", cell, width = widths[i])),
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths, &self.aligns));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len().saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

/// Format a float with sensible precision for tables.
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    if x == x.trunc() && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else if x.abs() >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "n", "ratio"]);
        t.row(&["pivot".into(), "1000".into(), "2.51".into()]);
        t.row(&["c4".into(), "10".into(), "3".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // All data lines equal width.
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(3.0), "3");
        assert_eq!(fnum(3.14159), "3.142");
        assert_eq!(fnum(12345.6789), "12345.7");
    }
}
