//! Tiny CLI flag parser used by `main.rs`, the examples and bench bins
//! (clap is unavailable in the offline registry).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, short
//! `-o value` flags (single dash + alphabetic name; `-3` stays
//! positional so negative numbers pass through), and free positional
//! arguments.  Typed getters parse on access and return precise
//! [`Error`]s — a bad `--n abc` must exit with a one-line message
//! through `main`'s dispatch, never a panic backtrace (the PR 3
//! convention, enforced by the `panic-path` audit rule).

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

/// `-o` style short flag: single dash followed by an alphabetic name
/// (`--long` is handled first; `-`, `-3` stay positional).
fn short_flag(item: &str) -> Option<&str> {
    let raw = item.strip_prefix('-')?;
    if raw.starts_with('-') || !raw.chars().next().is_some_and(|c| c.is_ascii_alphabetic()) {
        return None;
    }
    Some(raw)
}

#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, Vec<String>>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (prod).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(item) = it.next() {
            if let Some(raw) = item.strip_prefix("--") {
                args.push_flag(raw, &mut it);
            } else if let Some(raw) = short_flag(&item) {
                args.push_flag(raw, &mut it);
            } else {
                args.positional.push(item);
            }
        }
        args
    }

    /// One value-consumption rule for long and short flags alike:
    /// `name=value` splits inline, otherwise a following non-flag token
    /// is the value, otherwise the flag is boolean `true`.
    fn push_flag<I: Iterator<Item = String>>(
        &mut self,
        raw: &str,
        it: &mut std::iter::Peekable<I>,
    ) {
        if let Some((k, v)) = raw.split_once('=') {
            self.flags.entry(k.to_string()).or_default().push(v.to_string());
        } else if let Some(v) =
            it.next_if(|next| !next.starts_with("--") && short_flag(next).is_none())
        {
            self.flags.entry(raw.to_string()).or_default().push(v);
        } else {
            self.flags.entry(raw.to_string()).or_default().push("true".to_string());
        }
    }

    /// Parse the process arguments, skipping argv[0].
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values provided for a repeatable flag.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_bool(&self, key: &str) -> bool {
        match self.get(key) {
            None => false,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => true,
        }
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| Error::new(format!("invalid value for --{key}: '{s}' ({e})"))),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        self.get_parsed(key, default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        self.get_parsed(key, default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        self.get_parsed(key, default)
    }

    /// Comma-separated list flag: `--ns 100,1000,10000`.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .filter(|part| !part.is_empty())
                .map(|part| {
                    part.trim().parse().map_err(|e| {
                        Error::new(format!("invalid item in --{key}: '{part}' ({e})"))
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_value_styles() {
        // NOTE: bare boolean flags are greedy — `--verbose run` would read
        // `run` as the flag value. Convention: positionals (subcommands)
        // come first, or use `--flag=true`.
        let a = parse("run --n 100 --eps=0.5 --verbose");
        assert_eq!(a.get_usize("n", 0).unwrap(), 100);
        assert_eq!(a.get_f64("eps", 0.0).unwrap(), 0.5);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn short_flags_parse_and_negatives_stay_positional() {
        let a = parse("gen spec -o /tmp/g.csr -v");
        assert_eq!(a.get("o"), Some("/tmp/g.csr"));
        assert!(a.get_bool("v"));
        assert_eq!(a.positional(), &["gen".to_string(), "spec".to_string()]);
        let b = parse("run -3 -o=x.csr -");
        assert_eq!(b.get("o"), Some("x.csr"));
        assert_eq!(b.positional(), &["run".to_string(), "-3".to_string(), "-".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("n", 42).unwrap(), 42);
        assert!(!a.get_bool("verbose"));
        assert_eq!(a.get_str("mode", "m1"), "m1");
    }

    #[test]
    fn list_flag() {
        let a = parse("--ns 1,2,3");
        assert_eq!(a.get_list("ns", &[9usize]).unwrap(), vec![1, 2, 3]);
        let b = parse("");
        assert_eq!(b.get_list("ns", &[9usize]).unwrap(), vec![9]);
        let c = parse("--ns 1,x,3");
        let err = c.get_list("ns", &[9usize]).unwrap_err();
        assert!(err.to_string().contains("invalid item in --ns"), "{err}");
    }

    #[test]
    fn repeated_flags_collect() {
        let a = parse("--algo pivot --algo c4");
        assert_eq!(a.get_all("algo"), vec!["pivot", "c4"]);
        assert_eq!(a.get("algo"), Some("c4")); // last wins for scalar get
    }

    #[test]
    fn bad_parse_is_a_one_line_error() {
        let a = parse("--n abc");
        let err = a.get_usize("n", 0).unwrap_err();
        assert!(err.to_string().contains("invalid value for --n"), "{err}");
    }
}
