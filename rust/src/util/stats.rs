//! Summary statistics and regression helpers for the experiment harness.
//!
//! Everything the benches report — medians, MADs, percentiles, scaling
//! exponents fitted in log–log space — lives here so that every bench
//! prints numbers computed the same way.

/// Streaming mean/variance via Welford's algorithm.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than two samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Mean of a slice; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Interpolated percentile (p in [0, 100]) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = rank - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation — the bench harness's robust spread measure.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Ordinary least squares fit `y = a + b x`; returns (a, b, r^2).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len(), "linear_fit length mismatch");
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (f64::NAN, f64::NAN, f64::NAN);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 {
        return (my, 0.0, if syy == 0.0 { 1.0 } else { 0.0 });
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    let _ = n;
    (a, b, r2)
}

/// Fit a power law `y ~ c * x^e` in log-log space; returns (e, r^2).
///
/// Used to report measured scaling exponents against the paper's bounds
/// (e.g. rounds vs log λ should fit exponent ~1 in log λ).
pub fn power_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let (_, b, r2) = linear_fit(&lx, &ly);
    (b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let batch_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - batch_var).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [3.0, 1.0, 2.0, 4.0, 5.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 2.0, 3.0, 4.0, 1000.0];
        assert_eq!(mad(&xs), 1.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn power_fit_recovers_exponent() {
        let xs: Vec<f64> = (1..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x.powf(1.5)).collect();
        let (e, r2) = power_fit(&xs, &ys);
        assert!((e - 1.5).abs() < 1e-9);
        assert!(r2 > 0.999);
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert_eq!(mean(&[]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
        assert!(mad(&[]).is_nan());
    }
}
