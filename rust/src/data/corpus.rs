//! The generator corpus: every workload family behind one string key,
//! so datasets are *addressable* — `planted:n=50000,k=40,p=0.05,seed=7`
//! names the same graph everywhere (CLI `gen`, `--workload`, bench
//! scenarios, the golden-ratio lab).
//!
//! A [`WorkloadSpec`] is `family[:k=v,...]`.  Every family declares its
//! parameters with defaults, so specs stay terse and typos are strict
//! errors (unknown family, unknown/duplicate key, bad value) instead of
//! silently-default behavior.
//!
//! **Determinism contract:** `generate` is a pure function of the spec —
//! same string, same [`crate::graph::Graph`], on any platform and from
//! any thread (see `graph::generators`' module doc; pinned at 1/2/8
//! shards by `tests/data_io.rs`).

use crate::graph::generators::{
    barabasi_albert, barbell, caterpillar, disjoint_cliques, disjoint_union, erdos_renyi,
    grid, ladder, lambda_arboric, path, planted_partition, random_forest, random_tree,
    star, with_flip_noise,
};
use crate::graph::Graph;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// One declared parameter of a family.
#[derive(Debug, Clone, Copy)]
pub struct ParamSpec {
    pub key: &'static str,
    pub default: &'static str,
    pub about: &'static str,
}

/// A registered generator family.
pub struct FamilySpec {
    pub name: &'static str,
    pub about: &'static str,
    pub params: &'static [ParamSpec],
    gen: fn(&Params) -> Result<Graph>,
}

/// Typed accessors over the resolved (given ∪ default) parameters.
pub struct Params<'a> {
    family: &'static str,
    specs: &'static [ParamSpec],
    given: &'a [(String, String)],
}

impl Params<'_> {
    fn raw(&self, key: &str) -> &str {
        if let Some((_, v)) = self.given.iter().find(|(k, _)| k == key) {
            return v.as_str();
        }
        self.specs
            .iter()
            .find(|p| p.key == key)
            .unwrap_or_else(|| panic!("family '{}' never declared parameter '{key}'", self.family))
            .default
    }

    fn parse<T: std::str::FromStr>(&self, key: &str) -> Result<T> {
        let raw = self.raw(key);
        raw.parse().map_err(|_| {
            Error::new(format!(
                "family '{}': parameter {key}='{raw}' is not a valid {}",
                self.family,
                std::any::type_name::<T>()
            ))
        })
    }

    pub fn usize(&self, key: &str) -> Result<usize> {
        self.parse(key)
    }

    pub fn u64(&self, key: &str) -> Result<u64> {
        self.parse(key)
    }

    /// An f64 parameter constrained to a probability.
    pub fn prob(&self, key: &str) -> Result<f64> {
        let v: f64 = self.parse(key)?;
        crate::ensure!(
            (0.0..=1.0).contains(&v),
            "family '{}': parameter {key}={v} outside [0,1]",
            self.family
        );
        Ok(v)
    }

    pub fn f64(&self, key: &str) -> Result<f64> {
        self.parse(key)
    }
}

const fn prm(
    key: &'static str,
    default: &'static str,
    about: &'static str,
) -> ParamSpec {
    ParamSpec { key, default, about }
}

fn gen_forest(p: &Params) -> Result<Graph> {
    let (n, keep, flip, seed) =
        (p.usize("n")?, p.prob("keep")?, p.prob("flip")?, p.u64("seed")?);
    let mut rng = Rng::new(seed);
    let g = random_forest(n, keep, &mut rng);
    Ok(with_flip_noise(&g, flip, &mut rng))
}

fn gen_tree(p: &Params) -> Result<Graph> {
    Ok(random_tree(p.usize("n")?, &mut Rng::new(p.u64("seed")?)))
}

fn gen_arboric(p: &Params) -> Result<Graph> {
    let (n, lambda, seed) = (p.usize("n")?, p.usize("lambda")?, p.u64("seed")?);
    crate::ensure!(lambda >= 1, "family 'arboric': lambda must be >= 1");
    Ok(lambda_arboric(n, lambda, &mut Rng::new(seed)))
}

fn gen_powerlaw(p: &Params) -> Result<Graph> {
    let (n, attach, seed) = (p.usize("n")?, p.usize("attach")?, p.u64("seed")?);
    crate::ensure!(attach >= 1, "family 'powerlaw': attach must be >= 1");
    // Same clamp as `generators::Family::BarabasiAlbert` so the two
    // addressing schemes generate identical graphs.
    Ok(barabasi_albert(n.max(attach + 2), attach, &mut Rng::new(seed)))
}

fn gen_planted(p: &Params) -> Result<Graph> {
    let (n, k) = (p.usize("n")?, p.usize("k")?);
    let (pin, pout, seed) = (p.prob("pin")?, p.prob("p")?, p.u64("seed")?);
    crate::ensure!(
        k >= 1 && k <= n.max(1),
        "family 'planted': k={k} outside 1..=n (n={n})"
    );
    Ok(planted_partition(n, k, pin, pout, &mut Rng::new(seed)).0)
}

fn gen_ladder(p: &Params) -> Result<Graph> {
    let (n, flip, seed) = (p.usize("n")?, p.prob("flip")?, p.u64("seed")?);
    crate::ensure!(n % 2 == 0, "family 'ladder': n={n} must be even (two rails)");
    let g = ladder(n / 2);
    Ok(with_flip_noise(&g, flip, &mut Rng::new(seed)))
}

fn gen_caterpillar(p: &Params) -> Result<Graph> {
    Ok(caterpillar(p.usize("spine")?, p.usize("legs")?))
}

fn gen_star(p: &Params) -> Result<Graph> {
    Ok(star(p.usize("k")?))
}

fn gen_path(p: &Params) -> Result<Graph> {
    Ok(path(p.usize("n")?))
}

fn gen_grid(p: &Params) -> Result<Graph> {
    Ok(grid(p.usize("w")?, p.usize("h")?))
}

fn gen_barbell(p: &Params) -> Result<Graph> {
    let lambda = p.usize("lambda")?;
    crate::ensure!(lambda >= 1, "family 'barbell': lambda must be >= 1");
    Ok(barbell(lambda))
}

fn gen_cliques(p: &Params) -> Result<Graph> {
    let (count, k) = (p.usize("count")?, p.usize("k")?);
    crate::ensure!(count >= 1 && k >= 1, "family 'cliques': count and k must be >= 1");
    Ok(disjoint_cliques(count, k))
}

fn gen_er(p: &Params) -> Result<Graph> {
    let (n, prob, seed) = (p.usize("n")?, p.prob("p")?, p.u64("seed")?);
    Ok(erdos_renyi(n, prob, &mut Rng::new(seed)))
}

/// The post-delta endpoint of a drift sequence: generate the base spec
/// (nested commas `;`-encoded), drift it through `batches` seeded
/// `with_flip_noise` steps, and return the final graph. The same
/// machinery `arbocc delta gen` records batch-by-batch
/// (`data::delta::drift_delta`), so `drift:...` names the graph the
/// incremental driver must land on.
fn gen_drift(p: &Params) -> Result<Graph> {
    use crate::data::delta::{apply_batch, decode_base_spec, drift_batches};
    let base_spec = WorkloadSpec::parse(&decode_base_spec(p.raw("base")))?;
    crate::ensure!(
        base_spec.family() != "drift",
        "family 'drift': base must be a concrete family, not another drift spec"
    );
    let (batches, flip, seed) = (p.usize("batches")?, p.prob("flip")?, p.u64("seed")?);
    let base = base_spec.generate()?;
    let mut cur = base.clone();
    for batch in &drift_batches(&base, batches, flip, seed)? {
        cur = apply_batch(&cur, batch)?;
    }
    Ok(cur)
}

fn gen_mixed(p: &Params) -> Result<Graph> {
    let (n, seed) = (p.usize("n")?, p.u64("seed")?);
    crate::ensure!(n >= 32, "family 'mixed': n={n} too small (needs four parts of >= 8)");
    let q = n / 4;
    let mut rng = Rng::new(seed);
    let forest = random_forest(q, 0.9, &mut rng);
    let rails = ladder(q / 2);
    let hubs = barabasi_albert(q, 2, &mut rng);
    let cliques = disjoint_cliques((q / 6).max(1), 6);
    Ok(disjoint_union(&[forest, rails, hubs, cliques]))
}

/// Every registered family, in listing order.
pub const FAMILIES: &[FamilySpec] = &[
    FamilySpec {
        name: "forest",
        about: "random forest (λ=1), optional edge-flip noise",
        params: &[
            prm("n", "1000", "vertices"),
            prm("keep", "0.9", "per-edge keep probability of the spanning tree"),
            prm("flip", "0", "edge flip-noise probability"),
            prm("seed", "1", "generator seed"),
        ],
        gen: gen_forest,
    },
    FamilySpec {
        name: "tree",
        about: "uniform random labelled tree (Prüfer)",
        params: &[prm("n", "1000", "vertices"), prm("seed", "1", "generator seed")],
        gen: gen_tree,
    },
    FamilySpec {
        name: "arboric",
        about: "union of λ random spanning trees (arboricity ≤ λ)",
        params: &[
            prm("n", "1000", "vertices"),
            prm("lambda", "3", "number of spanning trees"),
            prm("seed", "1", "generator seed"),
        ],
        gen: gen_arboric,
    },
    FamilySpec {
        name: "powerlaw",
        about: "Barabási–Albert preferential attachment (scale-free)",
        params: &[
            prm("n", "1000", "vertices"),
            prm("attach", "3", "edges per new vertex"),
            prm("seed", "1", "generator seed"),
        ],
        gen: gen_powerlaw,
    },
    FamilySpec {
        name: "planted",
        about: "planted communities with sign noise (recovery workload)",
        params: &[
            prm("n", "1000", "vertices"),
            prm("k", "10", "ground-truth communities"),
            prm("pin", "0.9", "intra-community positive-edge probability"),
            prm("p", "0.01", "inter-community sign-noise probability"),
            prm("seed", "1", "generator seed"),
        ],
        gen: gen_planted,
    },
    FamilySpec {
        name: "ladder",
        about: "2×(n/2) ladder (arboricity ≤ 2), optional flip noise",
        params: &[
            prm("n", "1000", "vertices (must be even)"),
            prm("flip", "0", "edge flip-noise probability"),
            prm("seed", "1", "generator seed"),
        ],
        gen: gen_ladder,
    },
    FamilySpec {
        name: "caterpillar",
        about: "path spine with pendant legs (adversarial forest)",
        params: &[prm("spine", "16", "spine vertices"), prm("legs", "4", "legs per spine vertex")],
        gen: gen_caterpillar,
    },
    FamilySpec {
        name: "star",
        about: "K_{1,k}: minimal unbounded-degree forest",
        params: &[prm("k", "16", "leaves")],
        gen: gen_star,
    },
    FamilySpec {
        name: "path",
        about: "path P_n (Remark 30 tightness at n=4)",
        params: &[prm("n", "64", "vertices")],
        gen: gen_path,
    },
    FamilySpec {
        name: "grid",
        about: "w×h grid (planar, arboricity ≤ 2)",
        params: &[prm("w", "16", "width"), prm("h", "16", "height")],
        gen: gen_grid,
    },
    FamilySpec {
        name: "barbell",
        about: "two K_λ joined by one edge (Remark 33 tightness)",
        params: &[prm("lambda", "8", "clique size")],
        gen: gen_barbell,
    },
    FamilySpec {
        name: "cliques",
        about: "disjoint K_k components (OPT = 0)",
        params: &[prm("count", "8", "cliques"), prm("k", "8", "clique size")],
        gen: gen_cliques,
    },
    FamilySpec {
        name: "er",
        about: "Erdős–Rényi G(n,p) — unbounded-arboricity contrast",
        params: &[
            prm("n", "1000", "vertices"),
            prm("p", "0.01", "edge probability"),
            prm("seed", "1", "generator seed"),
        ],
        gen: gen_er,
    },
    FamilySpec {
        name: "mixed",
        about: "disjoint union: forest + ladder + powerlaw + cliques",
        params: &[prm("n", "2000", "total vertices"), prm("seed", "1", "generator seed")],
        gen: gen_mixed,
    },
    FamilySpec {
        name: "drift",
        about: "post-delta endpoint of a drift sequence over a base spec",
        params: &[
            prm("base", "planted:n=2000;k=8;seed=7", "base spec, inner commas as ';'"),
            prm("batches", "4", "drift batches"),
            prm("flip", "0.01", "per-batch edge flip-noise probability"),
            prm("seed", "1", "drift stream seed"),
        ],
        gen: gen_drift,
    },
];

/// A parsed `family[:k=v,...]` workload address.
#[derive(Clone)]
pub struct WorkloadSpec {
    family: &'static FamilySpec,
    /// Caller-provided parameters, canonicalized into declared order.
    given: Vec<(String, String)>,
}

impl std::fmt::Debug for WorkloadSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WorkloadSpec({})", self.canonical())
    }
}

impl WorkloadSpec {
    pub fn parse(s: &str) -> Result<WorkloadSpec> {
        let s = s.trim();
        let (fam_s, rest) = match s.split_once(':') {
            Some((f, r)) => (f.trim(), Some(r)),
            None => (s, None),
        };
        let Some(family) = FAMILIES.iter().find(|f| f.name == fam_s) else {
            crate::bail!(
                "unknown workload family '{fam_s}' (registered: {})",
                FAMILIES.iter().map(|f| f.name).collect::<Vec<_>>().join("|")
            );
        };
        let mut given: Vec<(String, String)> = Vec::new();
        if let Some(rest) = rest {
            for part in rest.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                let Some((k, v)) = part.split_once('=') else {
                    crate::bail!("family '{}': parameter '{part}' is not key=value", family.name);
                };
                let (k, v) = (k.trim(), v.trim());
                crate::ensure!(
                    family.params.iter().any(|p| p.key == k),
                    "family '{}': unknown parameter '{k}' (expected {})",
                    family.name,
                    family.params.iter().map(|p| p.key).collect::<Vec<_>>().join(", ")
                );
                crate::ensure!(
                    !given.iter().any(|(gk, _)| gk == k),
                    "family '{}': duplicate parameter '{k}'",
                    family.name
                );
                crate::ensure!(!v.is_empty(), "family '{}': empty value for '{k}'", family.name);
                given.push((k.to_string(), v.to_string()));
            }
        }
        given.sort_by_key(|(k, _)| {
            family.params.iter().position(|p| p.key == k.as_str()).unwrap_or(usize::MAX)
        });
        Ok(WorkloadSpec { family, given })
    }

    /// Family key (`planted`, `powerlaw`, …).
    pub fn family(&self) -> &'static str {
        self.family.name
    }

    /// Resolved (given ∪ default) value of one declared parameter —
    /// the out-of-band accessor `arbocc delta gen` uses to read a
    /// `drift:` spec's base/batches/flip/seed without generating it.
    pub fn param(&self, key: &str) -> Result<String> {
        if let Some((_, v)) = self.given.iter().find(|(k, _)| k == key) {
            return Ok(v.clone());
        }
        match self.family.params.iter().find(|p| p.key == key) {
            Some(p) => Ok(p.default.to_string()),
            None => crate::bail!("family '{}' has no parameter '{key}'", self.family.name),
        }
    }

    /// The normalized spec string: given parameters in declared order.
    pub fn canonical(&self) -> String {
        if self.given.is_empty() {
            self.family.name.to_string()
        } else {
            let params: Vec<String> =
                self.given.iter().map(|(k, v)| format!("{k}={v}")).collect();
            format!("{}:{}", self.family.name, params.join(","))
        }
    }

    /// Generate the graph — a pure function of the spec.
    pub fn generate(&self) -> Result<Graph> {
        let p = Params {
            family: self.family.name,
            specs: self.family.params,
            given: &self.given,
        };
        (self.family.gen)(&p).map_err(|e| e.context(format!("generating '{}'", self.canonical())))
    }
}

/// `name  signature  about` lines for `arbocc gen --list`.
pub fn describe_families() -> Vec<String> {
    FAMILIES
        .iter()
        .map(|f| {
            let sig: Vec<String> =
                f.params.iter().map(|p| format!("{}={}", p.key, p.default)).collect();
            let addr = if sig.is_empty() {
                f.name.to_string()
            } else {
                format!("{}:{}", f.name, sig.join(","))
            };
            format!("{:<12} {:<52} {}", f.name, addr, f.about)
        })
        .collect()
}

/// Exact-checkable corpus slice: every instance has n ≤
/// [`crate::cluster::exact::MAX_EXACT_N`], so the golden-ratio lab can
/// pin solver costs against true optima.
pub fn tiny_corpus() -> Vec<&'static str> {
    vec![
        "path:n=8",
        "path:n=12",
        "star:k=9",
        "barbell:lambda=5",
        "cliques:count=3,k=4",
        "forest:n=13,keep=0.85,seed=3",
        "planted:n=12,k=3,pin=0.9,p=0.1,seed=5",
        "ladder:n=12,flip=0.15,seed=2",
        "caterpillar:spine=4,legs=2",
    ]
}

/// The standard corpus sweep behind `solve/corpus_sweep` and the dataset
/// example: one spec per structural axis the paper reasons about, sized
/// by the caller.
pub fn sweep_corpus(n: usize, seed: u64) -> Vec<String> {
    // Inter-community noise scales as ~40/n so the planted instance
    // keeps Θ(n) noise edges at every sweep size (p is a probability
    // over all Θ(n²) pairs). Display (shortest round-trip, never
    // scientific) keeps the spec parseable and exact at any n.
    let pout = (40.0 / n.max(1) as f64).min(0.02).to_string();
    vec![
        format!("planted:n={n},k={},p={pout},seed={seed}", (n / 50).max(2)),
        format!("powerlaw:n={n},attach=3,seed={seed}"),
        format!("ladder:n={},flip=0.05,seed={seed}", n / 2 * 2),
        format!("forest:n={n},keep=0.9,flip=0.02,seed={seed}"),
        format!("mixed:n={n},seed={seed}"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_canonicalize() {
        let spec = WorkloadSpec::parse("planted: seed=7, n=100 ,k=4").unwrap();
        assert_eq!(spec.family(), "planted");
        // Canonical order follows the declaration, not the input.
        assert_eq!(spec.canonical(), "planted:n=100,k=4,seed=7");
        let again = WorkloadSpec::parse(&spec.canonical()).unwrap();
        assert_eq!(again.canonical(), spec.canonical());
        assert_eq!(WorkloadSpec::parse("grid").unwrap().canonical(), "grid");
    }

    #[test]
    fn defaults_apply_and_generate() {
        let g = WorkloadSpec::parse("planted:n=120,k=4,seed=9").unwrap().generate().unwrap();
        assert_eq!(g.n(), 120);
        assert!(g.m() > 0);
        let g = WorkloadSpec::parse("grid:w=5,h=4").unwrap().generate().unwrap();
        assert_eq!(g.n(), 20);
    }

    #[test]
    fn strict_parse_errors() {
        for (spec, frag) in [
            ("warp:n=3", "unknown workload family"),
            ("planted:zz=3", "unknown parameter"),
            ("planted:n", "not key=value"),
            ("planted:n=2,n=3", "duplicate parameter"),
            ("planted:n=", "empty value"),
        ] {
            let err = WorkloadSpec::parse(spec).unwrap_err().to_string();
            assert!(err.contains(frag), "{spec}: {err}");
        }
    }

    #[test]
    fn strict_generate_errors() {
        for (spec, frag) in [
            ("forest:n=10,keep=1.5", "outside [0,1]"),
            ("ladder:n=7", "must be even"),
            ("planted:n=4,k=9", "outside 1..=n"),
            ("forest:n=x", "not a valid usize"),
            ("mixed:n=8", "too small"),
        ] {
            let err = WorkloadSpec::parse(spec).unwrap().generate().unwrap_err().to_string();
            assert!(err.contains(frag), "{spec}: {err}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        for spec_s in tiny_corpus() {
            let spec = WorkloadSpec::parse(spec_s).unwrap();
            assert_eq!(
                spec.generate().unwrap(),
                spec.generate().unwrap(),
                "{spec_s}: same spec must regenerate the identical graph"
            );
        }
    }

    #[test]
    fn tiny_corpus_is_exact_checkable() {
        for spec_s in tiny_corpus() {
            let g = WorkloadSpec::parse(spec_s).unwrap().generate().unwrap();
            assert!(
                g.n() <= crate::cluster::exact::MAX_EXACT_N,
                "{spec_s}: n={} exceeds the exact solver cap",
                g.n()
            );
        }
    }

    #[test]
    fn sweep_corpus_parses_whole() {
        for s in sweep_corpus(400, 9) {
            let spec = WorkloadSpec::parse(&s).unwrap();
            let g = spec.generate().unwrap();
            assert!(g.n() > 0, "{s}");
        }
    }

    #[test]
    fn drift_family_generates_and_is_deterministic() {
        let spec_s = "drift:base=cliques:count=4;k=5,batches=3,flip=0.05,seed=6";
        let spec = WorkloadSpec::parse(spec_s).unwrap();
        assert_eq!(spec.family(), "drift");
        assert_eq!(spec.param("base").unwrap(), "cliques:count=4;k=5");
        assert_eq!(spec.param("batches").unwrap(), "3");
        let g = spec.generate().unwrap();
        assert_eq!(g.n(), 20);
        assert_eq!(g, spec.generate().unwrap(), "drift must regenerate identically");
        // flip=0 drifts nowhere: the endpoint is the base itself.
        let frozen = WorkloadSpec::parse("drift:base=cliques:count=4;k=5,flip=0")
            .unwrap()
            .generate()
            .unwrap();
        assert_eq!(frozen, WorkloadSpec::parse("cliques:count=4,k=5").unwrap().generate().unwrap());
        // A recursive base is refused.
        let err = WorkloadSpec::parse("drift:base=drift:flip=0")
            .unwrap()
            .generate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("concrete family"), "{err}");
        // param() rejects undeclared keys.
        assert!(spec.param("warp").is_err());
    }

    #[test]
    fn describe_lists_every_family() {
        let lines = describe_families();
        assert_eq!(lines.len(), FAMILIES.len());
        assert!(lines.iter().any(|l| l.contains("planted:")));
    }
}
