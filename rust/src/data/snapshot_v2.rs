//! `arbocc-csr/v2` — columnar compressed CSR snapshots with
//! block-parallel load.
//!
//! v1 ([`super::snapshot`]) stores raw offsets and neighbor ids; on the
//! low-arboricity graphs this repo targets that is ~4 B per directed
//! edge of mostly-zero high bytes. v2 stores the same graph as three
//! integer columns, each cut into fixed-size blocks that are
//! delta-friendly and independently decodable:
//!
//! * **degree column** — `n` values, vertex `v`'s adjacency length;
//! * **head column** — `n` values, `zigzag(first_neighbor − v)` for
//!   nonempty vertices and the canonical `0` for empty ones;
//! * **gap column** — `gap_count = m_dir − #nonempty` values, the
//!   strictly-sorted neighbor deltas `u_j − u_{j−1} − 1`.
//!
//! Each block of [`DEFAULT_BLOCK_LEN`] values is encoded **twice** —
//! LEB128 varint and fixed-width bit-packing (one width byte, LSB-first)
//! — and the smaller payload wins, tagged per block in a directory of
//! `(offset u64, len u32, tag u8, checksum u64)` entries. The layout
//! (all integers little-endian):
//!
//! ```text
//! magic      8 B   b"ARBOCSR2"
//! version    u32   2
//! block_len  u32   values per block (1 ..= MAX_BLOCK_LEN)
//! n          u64   vertex count
//! m_dir      u64   directed adjacency length (= 2·|E+|)
//! gap_count  u64   gap-column length (= m_dir − #nonempty vertices)
//! header_ck  u64   FNV-1a over the 40 header bytes above
//! directory  nblocks × 21 B  (off u64 | len u32 | tag u8 | ck u64)
//! dir_ck     u64   FNV-1a over the directory bytes
//! payloads   contiguous block payloads, in directory order, to EOF
//! ```
//!
//! where `nblocks = 2·⌈n/L⌉ + ⌈gap_count/L⌉` (degree and head blocks
//! first, then gap blocks). Every byte of the file is covered by exactly
//! one checksum — header, directory, or block — so any single-byte
//! corruption or truncation is a one-line `Err`, never a wrong graph.
//!
//! **Lazy-validation contract:** [`read_snapshot_v2_bytes`] validates
//! the header, the directory checksum, tags, and payload contiguity
//! *eagerly* (cheap, O(nblocks), before any proportional allocation);
//! per-block checksums and decoding are deferred to a fan-out over the
//! [`ShardPool`], one contiguous block range per shard. Partials are
//! merged in shard order, so both the decoded graph and the first error
//! reported are bit-identical at any shard count. Reconstruction
//! (prefix-summing degrees, re-adding gaps) and the structural
//! validation v1 also performs (range, loop-freedom, symmetry) are
//! likewise sharded by vertex.

use std::io::{Read, Write};

use crate::graph::Graph;
use crate::mpc::pool::ShardPool;
use crate::util::error::{Error, Result};
use crate::util::fnv1a;

use super::snapshot;

/// Leading magic of every `arbocc-csr/v2` snapshot.
pub const MAGIC: &[u8; 8] = b"ARBOCSR2";
/// Format version written and accepted.
pub const VERSION: u32 = 2;
/// Values per block written by [`snapshot_v2_bytes`]. Swept offline on
/// planted workloads: 256 beats 512/1024 because one noisy gap only
/// forces varint (or a wide bit width) on 256 neighbors, while the
/// 21 B directory entry amortizes to < 0.1 B per value.
pub const DEFAULT_BLOCK_LEN: u32 = 256;
/// Upper bound on the declared block length accepted by the reader —
/// a forged header cannot demand absurd per-block scratch.
pub const MAX_BLOCK_LEN: u32 = 1 << 20;

/// Header size in bytes (magic + version + block_len + n + m_dir +
/// gap_count + header checksum).
const HEADER_LEN: usize = 8 + 4 + 4 + 8 + 8 + 8 + 8;
/// Directory entry size in bytes (off u64 | len u32 | tag u8 | ck u64).
const DIR_ENTRY_LEN: usize = 21;

const TAG_VARINT: u8 = 0;
const TAG_BITPACK: u8 = 1;

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Map a signed delta onto the unsigned gap domain (small magnitudes →
/// small codes, both signs).
fn zigzag(d: i64) -> u64 {
    (d.wrapping_shl(1) ^ (d >> 63)) as u64
}

fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// Append `v` as an LEB128 varint (7 data bits per byte, MSB continues).
fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        // audit:allow(cast-truncate): masked to the low 7 bits
        let low = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(low);
            return;
        }
        out.push(low | 0x80);
    }
}

/// Fixed-width bit-packed payload: one width byte (0..=64), then the
/// values LSB-first at that width, zero-padded to a byte boundary.
fn bitpack_payload(vals: &[u64]) -> Vec<u8> {
    let width = vals.iter().map(|&x| 64 - x.leading_zeros()).max().unwrap_or(0);
    let bits = vals.len().saturating_mul(width as usize);
    let mut out = Vec::with_capacity(1 + bits.div_ceil(8));
    // audit:allow(cast-truncate): width ≤ 64 fits one byte
    out.push(width as u8);
    let mut acc: u128 = 0;
    let mut filled: u32 = 0;
    for &x in vals {
        acc |= u128::from(x) << filled;
        filled += width;
        while filled >= 8 {
            // audit:allow(cast-truncate): masked to the low byte
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            filled -= 8;
        }
    }
    if filled > 0 {
        // audit:allow(cast-truncate): masked to the low byte
        out.push((acc & 0xFF) as u8);
    }
    out
}

/// Encode one block both ways and keep the smaller payload (ties favor
/// bit-packing: fixed-width decode is branch-free).
fn encode_block(vals: &[u64]) -> (u8, Vec<u8>) {
    let mut var = Vec::new();
    for &x in vals {
        push_varint(&mut var, x);
    }
    let packed = bitpack_payload(vals);
    if packed.len() <= var.len() {
        (TAG_BITPACK, packed)
    } else {
        (TAG_VARINT, var)
    }
}

/// Decode a varint block that must hold exactly `cnt` values and consume
/// every payload byte.
fn decode_varint_block(pl: &[u8], cnt: usize) -> Result<Vec<u64>> {
    let mut out = Vec::with_capacity(cnt);
    let mut pos = 0usize;
    for i in 0..cnt {
        let mut val: u64 = 0;
        let mut shift: u32 = 0;
        loop {
            crate::ensure!(pos < pl.len(), "varint block truncated at value {i}");
            let byte = pl[pos];
            pos += 1;
            crate::ensure!(shift < 64, "varint at value {i} exceeds 64 bits");
            let low = u64::from(byte & 0x7F);
            crate::ensure!(
                shift < 63 || low <= 1,
                "varint at value {i} overflows u64"
            );
            val |= low << shift;
            if byte & 0x80 == 0 {
                break;
            }
            shift += 7;
        }
        out.push(val);
    }
    crate::ensure!(
        pos == pl.len(),
        "varint block has {} trailing byte(s)",
        pl.len() - pos
    );
    Ok(out)
}

/// Decode a bit-packed block of exactly `cnt` values; the payload length
/// must match the width byte exactly and padding bits must be zero.
fn decode_bitpack_block(pl: &[u8], cnt: usize) -> Result<Vec<u64>> {
    crate::ensure!(!pl.is_empty(), "bitpack block is empty (missing width byte)");
    let width = u32::from(pl[0]);
    crate::ensure!(width <= 64, "bitpack width {width} exceeds 64 bits");
    let bits = (cnt as u64)
        .checked_mul(u64::from(width))
        .ok_or_else(|| Error::new("bitpack bit count overflows"))?;
    let need = 1 + bits.div_ceil(8);
    crate::ensure!(
        pl.len() as u64 == need,
        "bitpack block is {} byte(s), {cnt} value(s) × {width} bit(s) needs {need}",
        pl.len()
    );
    let mask: u128 = if width == 64 { u128::from(u64::MAX) } else { (1u128 << width) - 1 };
    let mut out = Vec::with_capacity(cnt);
    let mut acc: u128 = 0;
    let mut filled: u32 = 0;
    let mut pos = 1usize;
    for _ in 0..cnt {
        while filled < width {
            acc |= u128::from(pl[pos]) << filled;
            pos += 1;
            filled += 8;
        }
        out.push((acc & mask) as u64);
        acc >>= width;
        filled -= width;
    }
    crate::ensure!(acc == 0, "bitpack block has nonzero padding bits");
    Ok(out)
}

/// Serialize with [`DEFAULT_BLOCK_LEN`].
pub fn snapshot_v2_bytes(g: &Graph) -> Result<Vec<u8>> {
    snapshot_v2_bytes_with(g, DEFAULT_BLOCK_LEN)
}

/// Serialize with an explicit block length (the block-boundary tests
/// force tiny blocks; the bench lab sweeps sizes).
pub fn snapshot_v2_bytes_with(g: &Graph, block_len: u32) -> Result<Vec<u8>> {
    crate::ensure!(
        (1..=MAX_BLOCK_LEN).contains(&block_len),
        "block length {block_len} outside 1..={MAX_BLOCK_LEN}"
    );
    let nv = snapshot::vertex_count_u32(g)?;
    let nvu = nv as usize;
    let mdir: usize = (0..nv).map(|v| g.degree(v)).sum();
    let mut degs: Vec<u64> = Vec::with_capacity(nvu);
    let mut heads: Vec<u64> = Vec::with_capacity(nvu);
    let mut gaps: Vec<u64> = Vec::with_capacity(mdir);
    for v in 0..nv {
        let list = g.neighbors(v);
        degs.push(list.len() as u64);
        match list.split_first() {
            Some((&first, rest)) => {
                heads.push(zigzag(i64::from(first) - i64::from(v)));
                let mut prev = first;
                for &u in rest {
                    crate::ensure!(
                        u > prev,
                        "vertex {v}: adjacency not sorted-unique (CSR invariant broken)"
                    );
                    gaps.push(u64::from(u) - u64::from(prev) - 1);
                    prev = u;
                }
            }
            None => heads.push(0),
        }
    }
    let blk = block_len as usize;
    let mut payloads: Vec<(u8, Vec<u8>)> = Vec::new();
    for col in [&degs, &heads, &gaps] {
        for chunk in col.chunks(blk) {
            payloads.push(encode_block(chunk));
        }
    }
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    push_u32(&mut buf, VERSION);
    push_u32(&mut buf, block_len);
    push_u64(&mut buf, nvu as u64);
    push_u64(&mut buf, mdir as u64);
    push_u64(&mut buf, gaps.len() as u64);
    let header_ck = fnv1a(&buf);
    push_u64(&mut buf, header_ck);
    let dir_bytes = payloads.len().saturating_mul(DIR_ENTRY_LEN).saturating_add(8);
    let mut off = HEADER_LEN.saturating_add(dir_bytes);
    let dir_start = buf.len();
    for (tag, pl) in &payloads {
        push_u64(&mut buf, off as u64);
        let len32 = u32::try_from(pl.len()).map_err(|_| {
            Error::new(format!("block payload of {} bytes exceeds u32", pl.len()))
        })?;
        push_u32(&mut buf, len32);
        buf.push(*tag);
        push_u64(&mut buf, fnv1a(pl));
        off = off.saturating_add(pl.len());
    }
    let dir_ck = fnv1a(&buf[dir_start..]);
    push_u64(&mut buf, dir_ck);
    for (_, pl) in &payloads {
        buf.extend_from_slice(pl);
    }
    Ok(buf)
}

/// One parsed directory entry (offsets already bounds-checked).
struct DirEntry {
    off: usize,
    len: usize,
    tag: u8,
    ck: u64,
}

/// Values in chunk `idx` of a column of `total` values at block length
/// `bl` (the final chunk is short).
fn chunk_len(total: u64, bl: u64, idx: u64) -> u64 {
    (total - idx.saturating_mul(bl)).min(bl)
}

/// Parse and validate an `arbocc-csr/v2` snapshot, fanning block
/// checksum+decode and graph reconstruction across `pool`. The result —
/// including which error is reported for a corrupt file — is identical
/// at any shard count.
pub fn read_snapshot_v2_bytes(bytes: &[u8], pool: &ShardPool) -> Result<Graph> {
    let mut pos = 0usize;
    let magic = snapshot::take(bytes, &mut pos, 8)?;
    crate::ensure!(
        magic == MAGIC.as_slice(),
        "bad magic {magic:?}: not an arbocc-csr/v2 snapshot (expected {MAGIC:?})"
    );
    let version = snapshot::take_u32(bytes, &mut pos)?;
    crate::ensure!(
        version == VERSION,
        "unsupported snapshot version {version} (reader speaks {VERSION})"
    );
    let block_len = snapshot::take_u32(bytes, &mut pos)?;
    crate::ensure!(
        (1..=MAX_BLOCK_LEN).contains(&block_len),
        "bad block length {block_len} (expected 1..={MAX_BLOCK_LEN})"
    );
    let n64 = snapshot::take_u64(bytes, &mut pos)?;
    let mdir64 = snapshot::take_u64(bytes, &mut pos)?;
    let gap64 = snapshot::take_u64(bytes, &mut pos)?;
    let stored_hck = snapshot::take_u64(bytes, &mut pos)?;
    let actual_hck = fnv1a(&bytes[..HEADER_LEN - 8]);
    crate::ensure!(
        stored_hck == actual_hck,
        "header checksum mismatch: stored {stored_hck:#018x}, computed {actual_hck:#018x}"
    );
    crate::ensure!(n64 <= u32::MAX as u64, "n={n64} exceeds the u32 vertex-id space");
    crate::ensure!(gap64 <= mdir64, "gap count {gap64} exceeds m_dir={mdir64}");
    crate::ensure!(
        u128::from(mdir64) <= u128::from(n64).saturating_mul(u128::from(n64)),
        "m_dir={mdir64} impossible for n={n64}"
    );
    let bl64 = u64::from(block_len);
    let vblocks64 = n64.div_ceil(bl64);
    let gblocks64 = gap64.div_ceil(bl64);
    let nblocks64 = vblocks64
        .checked_mul(2)
        .and_then(|x| x.checked_add(gblocks64))
        .ok_or_else(|| Error::new("block count overflows u64"))?;
    // Eager phase: the whole directory must fit *before* any allocation
    // proportional to the declared sizes.
    let need = nblocks64
        .checked_mul(DIR_ENTRY_LEN as u64)
        .and_then(|x| x.checked_add((HEADER_LEN + 8) as u64))
        .ok_or_else(|| Error::new("directory size overflows u64"))?;
    crate::ensure!(
        need <= bytes.len() as u64,
        "truncated snapshot: {nblocks64} block(s) need {need} header+directory bytes, \
         the file has {}",
        bytes.len()
    );
    let nblocks = nblocks64 as usize;
    let vb = vblocks64 as usize;
    let dir_start = pos;
    let mut entries: Vec<DirEntry> = Vec::with_capacity(nblocks);
    for b in 0..nblocks {
        let off = snapshot::take_u64(bytes, &mut pos)?;
        let len = snapshot::take_u32(bytes, &mut pos)?;
        let tag = snapshot::take(bytes, &mut pos, 1)?[0];
        let ck = snapshot::take_u64(bytes, &mut pos)?;
        crate::ensure!(
            tag == TAG_VARINT || tag == TAG_BITPACK,
            "block {b}: bad encoding tag {tag} (expected {TAG_VARINT} varint / {TAG_BITPACK} bitpack)"
        );
        crate::ensure!(
            off <= bytes.len() as u64,
            "block {b}: payload offset {off} past end of file ({} bytes)",
            bytes.len()
        );
        entries.push(DirEntry { off: off as usize, len: len as usize, tag, ck });
    }
    let dir_end = pos;
    let stored_dck = snapshot::take_u64(bytes, &mut pos)?;
    let actual_dck = fnv1a(&bytes[dir_start..dir_end]);
    crate::ensure!(
        stored_dck == actual_dck,
        "directory checksum mismatch: stored {stored_dck:#018x}, computed {actual_dck:#018x}"
    );
    // Payloads must tile [end-of-directory, EOF) exactly, in order.
    let mut expect = pos;
    for (b, e) in entries.iter().enumerate() {
        crate::ensure!(
            e.off == expect,
            "block {b}: payload offset {} breaks contiguity (expected {expect})",
            e.off
        );
        let end = e
            .off
            .checked_add(e.len)
            .ok_or_else(|| Error::new(format!("block {b}: payload end overflows")))?;
        crate::ensure!(
            end <= bytes.len(),
            "block {b}: payload [{}, {end}) past end of file ({} bytes)",
            e.off,
            bytes.len()
        );
        expect = end;
    }
    crate::ensure!(
        expect == bytes.len(),
        "snapshot length mismatch: payloads end at {expect} but the file has {} bytes",
        bytes.len()
    );
    // Lazy phase: per-block checksum + decode, sharded over the block
    // index space. Partials merge in shard order, so errors and values
    // are deterministic at any shard count.
    let entries_ref = &entries;
    let partials: Vec<Result<Vec<Vec<u64>>>> = pool.run(nblocks, |_, range| -> Result<Vec<Vec<u64>>> {
        let mut decoded = Vec::with_capacity(range.len());
        for b in range {
            let e = &entries_ref[b];
            let pl = &bytes[e.off..e.off + e.len];
            let actual = fnv1a(pl);
            crate::ensure!(
                actual == e.ck,
                "block {b}: checksum mismatch (stored {:#018x}, computed {actual:#018x})",
                e.ck
            );
            let cnt64 = if b < vb {
                chunk_len(n64, bl64, b as u64)
            } else if b < 2 * vb {
                chunk_len(n64, bl64, (b - vb) as u64)
            } else {
                chunk_len(gap64, bl64, (b - 2 * vb) as u64)
            };
            let cnt = cnt64 as usize;
            let decoded_block = if e.tag == TAG_BITPACK {
                decode_bitpack_block(pl, cnt)
            } else {
                decode_varint_block(pl, cnt)
            };
            let vals =
                decoded_block.map_err(|err| err.context(format!("decoding block {b}")))?;
            decoded.push(vals);
        }
        Ok(decoded)
    });
    let mut blocks: Vec<Vec<u64>> = Vec::with_capacity(nblocks);
    for p in partials {
        blocks.extend(p?);
    }
    let nvu = n64 as usize;
    let mut degs: Vec<u64> = Vec::with_capacity(nvu);
    for vals in &blocks[..vb] {
        degs.extend_from_slice(vals);
    }
    let mut heads: Vec<u64> = Vec::with_capacity(nvu);
    for vals in &blocks[vb..2 * vb] {
        heads.extend_from_slice(vals);
    }
    let gap_total = gap64 as usize;
    let mut gaps: Vec<u64> = Vec::with_capacity(gap_total);
    for vals in &blocks[2 * vb..] {
        gaps.extend_from_slice(vals);
    }
    drop(blocks);
    // Serial prefix sums: CSR offsets and each vertex's slice of the gap
    // column (what makes per-vertex reconstruction embarrassingly
    // parallel below).
    let mut offsets: Vec<usize> = Vec::with_capacity(nvu + 1);
    offsets.push(0);
    let mut gap_start: Vec<usize> = Vec::with_capacity(nvu + 1);
    gap_start.push(0);
    let mut acc: u64 = 0;
    let mut nonempty: u64 = 0;
    let mut gacc: u64 = 0;
    for (v, &d) in degs.iter().enumerate() {
        acc = acc
            .checked_add(d)
            .ok_or_else(|| Error::new(format!("vertex {v}: degree prefix sum overflows")))?;
        crate::ensure!(
            acc <= mdir64,
            "vertex {v}: degree prefix sum {acc} exceeds m_dir={mdir64}"
        );
        if d > 0 {
            nonempty += 1;
            gacc += d - 1;
        }
        offsets.push(acc as usize);
        gap_start.push(gacc as usize);
    }
    crate::ensure!(
        acc == mdir64,
        "degree column sums to {acc}, header declares m_dir={mdir64}"
    );
    crate::ensure!(
        gacc == gap64,
        "degree column implies {gacc} gap(s), header declares {gap64}"
    );
    let mdir = mdir64 as usize;
    // Parallel reconstruction: vertex v's list is head + running gaps,
    // strictly increasing by construction; range and loop-freedom are
    // checked per value.
    let degs_ref = &degs;
    let heads_ref = &heads;
    let gaps_ref = &gaps;
    let offsets_ref = &offsets;
    let gap_start_ref = &gap_start;
    let partials: Vec<Result<Vec<u32>>> = pool.run(nvu, |_, range| -> Result<Vec<u32>> {
        let take_len = offsets_ref[range.end] - offsets_ref[range.start];
        let mut out: Vec<u32> = Vec::with_capacity(take_len);
        let mut gi = gap_start_ref[range.start];
        for v in range {
            let d = degs_ref[v];
            if d == 0 {
                crate::ensure!(
                    heads_ref[v] == 0,
                    "vertex {v}: nonzero head {} for empty adjacency (noncanonical)",
                    heads_ref[v]
                );
                continue;
            }
            let delta = unzigzag(heads_ref[v]);
            let first = (v as i64).checked_add(delta).ok_or_else(|| {
                Error::new(format!("vertex {v}: head delta {delta} overflows"))
            })?;
            crate::ensure!(
                first >= 0 && (first as u64) < n64,
                "vertex {v}: first neighbor {first} out of range n={n64}"
            );
            let mut u = first as u64;
            crate::ensure!(u != v as u64, "vertex {v}: self-loop in adjacency");
            // audit:allow(cast-truncate): u < n ≤ u32::MAX, ensured above
            out.push(u as u32);
            for _ in 1..d {
                let gap = gaps_ref[gi];
                gi += 1;
                u = u
                    .checked_add(1)
                    .and_then(|x| x.checked_add(gap))
                    .ok_or_else(|| {
                        Error::new(format!("vertex {v}: neighbor gap {gap} overflows"))
                    })?;
                crate::ensure!(u < n64, "vertex {v}: neighbor {u} out of range n={n64}");
                crate::ensure!(u != v as u64, "vertex {v}: self-loop in adjacency");
                // audit:allow(cast-truncate): u < n ≤ u32::MAX, ensured above
                out.push(u as u32);
            }
        }
        Ok(out)
    });
    let mut neighbors: Vec<u32> = Vec::with_capacity(mdir);
    for p in partials {
        neighbors.extend(p?);
    }
    // Symmetry validation (the graph is undirected by contract), sharded
    // by vertex like v1's serial loop.
    let neighbors_ref = &neighbors;
    let checks: Vec<Result<()>> = pool.run(nvu, |_, range| -> Result<()> {
        for v in range {
            // audit:allow(cast-truncate): v < n ≤ u32::MAX
            let v32 = v as u32;
            for &u in &neighbors_ref[offsets_ref[v]..offsets_ref[v + 1]] {
                let lo = offsets_ref[u as usize];
                let hi = offsets_ref[u as usize + 1];
                crate::ensure!(
                    neighbors_ref[lo..hi].binary_search(&v32).is_ok(),
                    "asymmetric edge: {v}→{u} present but {u}→{v} missing"
                );
            }
        }
        Ok(())
    });
    for c in checks {
        c?;
    }
    Ok(Graph::from_csr(offsets, neighbors))
}

/// Write a v2 snapshot.
pub fn write_snapshot_v2<W: Write>(g: &Graph, mut w: W) -> Result<()> {
    w.write_all(&snapshot_v2_bytes(g)?)?;
    Ok(())
}

pub fn write_snapshot_v2_file(g: &Graph, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, snapshot_v2_bytes(g)?)?;
    Ok(())
}

/// Read a v2 snapshot from any reader (buffers fully, then validates).
pub fn read_snapshot_v2<R: Read>(mut r: R, pool: &ShardPool) -> Result<Graph> {
    let mut bytes = Vec::new();
    r.read_to_end(&mut bytes)?;
    read_snapshot_v2_bytes(&bytes, pool)
}

pub fn read_snapshot_v2_file(path: &std::path::Path, pool: &ShardPool) -> Result<Graph> {
    read_snapshot_v2_bytes(&std::fs::read(path)?, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{barbell, lambda_arboric, planted_partition};
    use crate::util::rng::Rng;

    fn families() -> Vec<Graph> {
        let mut rng = Rng::new(77);
        vec![
            Graph::empty(0),
            Graph::empty(9),
            barbell(6),
            lambda_arboric(300, 3, &mut rng),
            planted_partition(400, 8, 0.8, 0.02, &mut Rng::new(5)).0,
        ]
    }

    #[test]
    fn roundtrip_all_families_default_blocks() {
        let pool = ShardPool::new(2);
        for g in families() {
            let bytes = snapshot_v2_bytes(&g).unwrap();
            let back = read_snapshot_v2_bytes(&bytes, &pool).unwrap();
            assert_eq!(back, g);
            assert_eq!(
                snapshot_v2_bytes(&back).unwrap(),
                bytes,
                "write-read-write is byte-stable"
            );
        }
    }

    #[test]
    fn roundtrip_at_awkward_block_lengths() {
        let pool = ShardPool::serial();
        let g = lambda_arboric(200, 2, &mut Rng::new(3));
        for bl in [1u32, 2, 3, 7, 64, 255, 257] {
            let bytes = snapshot_v2_bytes_with(&g, bl).unwrap();
            assert_eq!(read_snapshot_v2_bytes(&bytes, &pool).unwrap(), g, "block_len={bl}");
        }
    }

    #[test]
    fn shard_count_does_not_change_the_graph() {
        let (g, _) = planted_partition(600, 12, 0.9, 0.01, &mut Rng::new(11));
        let bytes = snapshot_v2_bytes(&g).unwrap();
        let serial = read_snapshot_v2_bytes(&bytes, &ShardPool::serial()).unwrap();
        for shards in [2usize, 3, 8] {
            let pool = ShardPool::new(shards);
            assert_eq!(read_snapshot_v2_bytes(&bytes, &pool).unwrap(), serial, "{shards} shards");
        }
    }

    #[test]
    fn v2_matches_v1_content() {
        let pool = ShardPool::new(4);
        for g in families() {
            let v1 = snapshot::snapshot_bytes(&g).unwrap();
            let via_v1 = snapshot::read_snapshot_bytes(&v1).unwrap();
            let v2 = snapshot_v2_bytes(&g).unwrap();
            let via_v2 = read_snapshot_v2_bytes(&v2, &pool).unwrap();
            assert_eq!(via_v1, via_v2);
        }
    }

    #[test]
    fn v2_is_smaller_on_clustered_graphs() {
        let (g, _) = planted_partition(2000, 20, 0.9, 0.001, &mut Rng::new(9));
        let v1 = snapshot::snapshot_bytes(&g).unwrap();
        let v2 = snapshot_v2_bytes(&g).unwrap();
        assert!(
            v2.len() * 2 < v1.len(),
            "v2 ({}) should be well under half of v1 ({})",
            v2.len(),
            v1.len()
        );
    }

    #[test]
    fn header_corruption_is_rejected_with_context() {
        let g = barbell(5);
        let bytes = snapshot_v2_bytes(&g).unwrap();
        let pool = ShardPool::serial();
        let mut bad = bytes.clone();
        bad[0] ^= 1;
        let msg = read_snapshot_v2_bytes(&bad, &pool).unwrap_err().to_string();
        assert!(msg.contains("magic"), "{msg}");
        let mut bad = bytes.clone();
        bad[8] = 9; // version field
        let msg = read_snapshot_v2_bytes(&bad, &pool).unwrap_err().to_string();
        assert!(msg.contains("version"), "{msg}");
        let mut bad = bytes.clone();
        bad[16] ^= 0xFF; // n field — caught by the header checksum
        let msg = read_snapshot_v2_bytes(&bad, &pool).unwrap_err().to_string();
        assert!(msg.contains("header checksum"), "{msg}");
    }

    #[test]
    fn block_corruption_error_is_shard_invariant() {
        let g = lambda_arboric(500, 3, &mut Rng::new(21));
        let bytes = snapshot_v2_bytes(&g).unwrap();
        let mut bad = bytes.clone();
        let last = bad.len() - 1; // inside the final payload block
        bad[last] ^= 0xFF;
        let serial_msg =
            read_snapshot_v2_bytes(&bad, &ShardPool::serial()).unwrap_err().to_string();
        assert!(serial_msg.contains("checksum") || serial_msg.contains("block"), "{serial_msg}");
        for shards in [2usize, 8] {
            let msg = read_snapshot_v2_bytes(&bad, &ShardPool::new(shards))
                .unwrap_err()
                .to_string();
            assert_eq!(msg, serial_msg, "error must not depend on shard count");
        }
    }

    #[test]
    fn varint_and_bitpack_blocks_roundtrip() {
        for vals in [
            vec![0u64; 300],
            (0..300u64).collect::<Vec<_>>(),
            vec![u64::MAX, 0, 1, u64::MAX - 1],
            vec![7u64],
            (0..100u64).map(|i| if i % 9 == 0 { 1 << 40 } else { i % 3 }).collect(),
        ] {
            let (tag, pl) = encode_block(&vals);
            let back = if tag == TAG_BITPACK {
                decode_bitpack_block(&pl, vals.len()).unwrap()
            } else {
                decode_varint_block(&pl, vals.len()).unwrap()
            };
            assert_eq!(back, vals);
        }
    }

    #[test]
    fn zigzag_roundtrips() {
        for d in [0i64, 1, -1, 5, -5, i64::MAX, i64::MIN, 1 << 40, -(1 << 40)] {
            assert_eq!(unzigzag(zigzag(d)), d, "{d}");
        }
    }
}
