//! The dataset subsystem: graph IO + the generator corpus.
//!
//! Everything above this layer — the solver engine, the perf lab, the
//! CLI — addresses inputs through two currencies:
//!
//! * **files** — [`edge_list`] (whitespace/CSV signed edge lists with
//!   strict line-numbered errors and sort/dedup/self-loop normalization),
//!   [`snapshot`] (the `arbocc-csr/v1` versioned binary CSR format), and
//!   [`snapshot_v2`] (the columnar compressed `arbocc-csr/v2` format,
//!   block-checksummed and decoded in parallel on the `ShardPool`), and
//!   [`delta`] (the `arbocc-delta/v1` edge-delta batches the incremental
//!   solver replays against a fingerprint-checked base);
//!   [`load_graph`] auto-detects which one a path holds by its magic.
//! * **specs** — [`corpus`]'s `family:k=v,...` strings naming seeded
//!   generator instances (`planted:n=50000,k=40,p=0.05,seed=7`), so any
//!   workload in a bench table, test, or shell command is reproducible
//!   from its name alone.
//!
//! `arbocc gen <spec> -o g.csr && arbocc solve --input g.csr` is the
//! whole pipeline; see DESIGN.md §7.

pub mod corpus;
pub mod delta;
pub mod edge_list;
pub mod snapshot;
pub mod snapshot_v2;

use std::path::Path;

use crate::graph::Graph;
use crate::util::error::{Error, Result};

/// What [`load_graph`] found at the path.
#[derive(Debug, Clone)]
pub enum LoadStats {
    Snapshot { bytes: usize },
    SnapshotV2 { bytes: usize, shards: usize },
    EdgeList(edge_list::IngestStats),
}

impl LoadStats {
    pub fn describe(&self) -> String {
        match self {
            LoadStats::Snapshot { bytes } => {
                format!("arbocc-csr/v1 snapshot ({bytes} bytes)")
            }
            LoadStats::SnapshotV2 { bytes, shards } => {
                format!("arbocc-csr/v2 snapshot ({bytes} bytes, decoded on {shards} shard(s))")
            }
            LoadStats::EdgeList(stats) => format!("edge list: {}", stats.describe()),
        }
    }
}

/// Load a graph from disk, auto-detecting the format: `arbocc-csr/v1`
/// or `arbocc-csr/v2` by magic (v2 block decode fans out across an
/// auto-sized [`crate::mpc::pool::ShardPool`]), anything else as a text
/// edge list.
pub fn load_graph(path: &Path) -> Result<(Graph, LoadStats)> {
    let bytes = std::fs::read(path)
        .map_err(|e| Error::new(format!("{}: {e}", path.display())))?;
    if bytes.starts_with(snapshot::MAGIC) {
        let g = snapshot::read_snapshot_bytes(&bytes)
            .map_err(|e| e.context(format!("reading snapshot {}", path.display())))?;
        return Ok((g, LoadStats::Snapshot { bytes: bytes.len() }));
    }
    if bytes.starts_with(snapshot_v2::MAGIC) {
        let pool = crate::mpc::pool::ShardPool::auto();
        let g = snapshot_v2::read_snapshot_v2_bytes(&bytes, &pool)
            .map_err(|e| e.context(format!("reading v2 snapshot {}", path.display())))?;
        return Ok((
            g,
            LoadStats::SnapshotV2 { bytes: bytes.len(), shards: pool.shards() },
        ));
    }
    let text = std::str::from_utf8(&bytes).map_err(|_| {
        Error::new(format!(
            "{}: neither an arbocc-csr snapshot nor UTF-8 edge-list text",
            path.display()
        ))
    })?;
    let (g, stats) = edge_list::read_edges(text)
        .map_err(|e| e.context(format!("parsing {}", path.display())))?;
    Ok((g, LoadStats::EdgeList(stats)))
}

/// Save a graph, choosing the format from the extension: `.csr` /
/// `.snapshot` / `.bin` write the v1 binary snapshot, `.csr2` / `.csrz`
/// the columnar compressed v2 snapshot, `.csv` a CSV edge list, anything
/// else a whitespace edge list.  Returns the format label for CLI
/// reporting.
pub fn save_graph(g: &Graph, path: &Path) -> Result<&'static str> {
    let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
    let label = match ext {
        "csr" | "snapshot" | "bin" => {
            snapshot::write_snapshot_file(g, path)?;
            "arbocc-csr/v1 snapshot"
        }
        "csr2" | "csrz" => {
            snapshot_v2::write_snapshot_v2_file(g, path)?;
            "arbocc-csr/v2 snapshot"
        }
        "csv" => {
            edge_list::write_edges_file(g, path, edge_list::EdgeListFormat::Csv)?;
            "csv edge list"
        }
        _ => {
            edge_list::write_edges_file(g, path, edge_list::EdgeListFormat::Whitespace)?;
            "whitespace edge list"
        }
    };
    Ok(label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::lambda_arboric;
    use crate::util::rng::Rng;

    fn temp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("arbocc_data_mod_{}_{tag}", std::process::id()))
    }

    #[test]
    fn save_and_load_every_format() {
        let g = lambda_arboric(80, 2, &mut Rng::new(55));
        for (tag, expect) in [
            ("a.csr", "v1 snapshot"),
            ("d.csr2", "v2 snapshot"),
            ("b.csv", "csv"),
            ("c.edges", "whitespace"),
        ] {
            let path = temp(tag);
            let label = save_graph(&g, &path).unwrap();
            assert!(label.contains(expect), "{tag}: {label}");
            let (back, stats) = load_graph(&path).unwrap();
            assert_eq!(back, g, "{tag}");
            assert!(!stats.describe().is_empty());
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn load_missing_file_is_an_error() {
        let err = load_graph(Path::new("/definitely/not/here.csr")).unwrap_err();
        assert!(err.to_string().contains("not/here.csr"));
    }
}
