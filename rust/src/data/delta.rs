//! `arbocc-delta/v1` — checksummed edge-delta batches against a base
//! snapshot, plus the drift generator behind the `drift:` corpus family.
//!
//! A delta names a base graph (by corpus spec *and* fingerprint, so a
//! mismatched base is a one-line error, never a silently wrong solve)
//! and carries an ordered sequence of batches; each batch is a set of
//! edge inserts/deletes that transforms graph *i* into graph *i+1*.
//! The layout (all integers little-endian):
//!
//! ```text
//! magic     8 B   b"ARBODLT1"
//! version   u32   1
//! n         u64   vertex count of the base (and every successor)
//! base_fp   u64   `graph_fingerprint` of the base graph
//! spec_len  u32   byte length of the base corpus spec (may be 0)
//! spec      spec_len × u8   UTF-8 corpus spec of the base
//! batches   u32   batch count
//! per batch:
//!   n_ops   u32   op count
//!   per op: kind u8 (0 insert | 1 delete), u u32, v u32 (u < v)
//! checksum  u64   FNV-1a over every preceding byte
//! ```
//!
//! Reads validate everything — magic, version, checksum (verified over
//! the whole body *before* structural parsing), exact length, op kind,
//! endpoint range and orientation — so every single-byte flip and
//! truncation is an `Err` with context, never a panic (pinned by
//! `tests/incremental.rs`, the same battery shape as the snapshot
//! codecs).
//!
//! **Determinism contract:** [`drift_delta`] is a pure function of its
//! `drift:` spec — the batches are diffs between successive
//! `with_flip_noise` applications under one seeded stream, so the same
//! spec names the same delta everywhere (CLI `delta gen`, `--delta`,
//! bench scenarios, tests).

use crate::data::corpus::WorkloadSpec;
use crate::graph::generators::with_flip_noise;
use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::fnv1a;
use crate::util::rng::Rng;

/// Leading magic of every `arbocc-delta/v1` file.
pub const MAGIC: &[u8; 8] = b"ARBODLT1";
/// Format version written and accepted.
pub const VERSION: u32 = 1;

/// One edge mutation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeOp {
    Insert,
    Delete,
}

impl EdgeOp {
    fn tag(self) -> u8 {
        match self {
            EdgeOp::Insert => 0,
            EdgeOp::Delete => 1,
        }
    }

    fn from_tag(tag: u8) -> Option<EdgeOp> {
        match tag {
            0 => Some(EdgeOp::Insert),
            1 => Some(EdgeOp::Delete),
            _ => None,
        }
    }
}

/// One batch of edge mutations, applied atomically.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DeltaBatch {
    /// `(op, u, v)` with `u < v`; each pair appears at most once.
    pub ops: Vec<(EdgeOp, u32, u32)>,
}

impl DeltaBatch {
    /// `(inserts, deletes)` split into endpoint lists.
    pub fn split_ops(&self) -> (Vec<(u32, u32)>, Vec<(u32, u32)>) {
        let mut inserts = Vec::new();
        let mut deletes = Vec::new();
        for &(op, u, v) in &self.ops {
            match op {
                EdgeOp::Insert => inserts.push((u, v)),
                EdgeOp::Delete => deletes.push((u, v)),
            }
        }
        (inserts, deletes)
    }
}

/// A parsed `arbocc-delta/v1` value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Delta {
    /// Vertex count of the base and every successor graph.
    pub n: usize,
    /// [`graph_fingerprint`] of the base graph.
    pub base_fingerprint: u64,
    /// Corpus spec of the base (`planted:n=2000,k=8,seed=7`; may be
    /// empty when the base came from a file).
    pub base_spec: String,
    pub batches: Vec<DeltaBatch>,
}

impl Delta {
    /// Total op count across all batches.
    pub fn total_ops(&self) -> usize {
        self.batches.iter().map(|b| b.ops.len()).sum()
    }
}

/// Order-sensitive structural fingerprint of a graph: FNV-1a over the
/// vertex count, the degree sequence and the concatenated adjacency —
/// the exact information content of the CSR arrays. Two graphs
/// fingerprint equal iff their CSR representations are identical; this
/// is the cache key of the incremental driver and the base check of
/// every delta apply.
pub fn graph_fingerprint(g: &Graph) -> u64 {
    // Incremental FNV-1a over the stream `n · (degree · adjacency)*`
    // (little-endian u64/u32) without materializing it.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    mix(&(g.n() as u64).to_le_bytes());
    for v in 0..g.n() {
        // audit:allow(cast-truncate): v < n and Graph vertex ids are u32 by contract
        let vid = v as u32;
        mix(&(g.degree(vid) as u64).to_le_bytes());
        for &u in g.neighbors(vid) {
            mix(&u.to_le_bytes());
        }
    }
    h
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn ensure_op(n: usize, op: EdgeOp, u: u32, v: u32) -> Result<()> {
    crate::ensure!(u < v, "delta op {op:?} ({u},{v}): endpoints must satisfy u < v");
    crate::ensure!(
        (v as usize) < n,
        "delta op {op:?} ({u},{v}): endpoint {v} out of range n={n}"
    );
    Ok(())
}

/// Serialize a delta (validates op orientation/range and count widths).
pub fn delta_bytes(delta: &Delta) -> Result<Vec<u8>> {
    crate::ensure!(
        delta.n <= u32::MAX as usize,
        "delta n={} exceeds the u32 vertex-id space",
        delta.n
    );
    let n_batches = u32::try_from(delta.batches.len())
        .map_err(|_| crate::util::error::Error::new("delta has more than u32::MAX batches"))?;
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    push_u32(&mut buf, VERSION);
    push_u64(&mut buf, delta.n as u64);
    push_u64(&mut buf, delta.base_fingerprint);
    let spec = delta.base_spec.as_bytes();
    let spec_len = u32::try_from(spec.len())
        .map_err(|_| crate::util::error::Error::new("delta base spec exceeds u32::MAX bytes"))?;
    push_u32(&mut buf, spec_len);
    buf.extend_from_slice(spec);
    push_u32(&mut buf, n_batches);
    for batch in &delta.batches {
        let n_ops = u32::try_from(batch.ops.len()).map_err(|_| {
            crate::util::error::Error::new("delta batch has more than u32::MAX ops")
        })?;
        push_u32(&mut buf, n_ops);
        for &(op, u, v) in &batch.ops {
            ensure_op(delta.n, op, u, v)?;
            buf.push(op.tag());
            push_u32(&mut buf, u);
            push_u32(&mut buf, v);
        }
    }
    let ck = fnv1a(&buf);
    push_u64(&mut buf, ck);
    Ok(buf)
}

/// Parse and fully validate an `arbocc-delta/v1` file.
pub fn read_delta_bytes(bytes: &[u8]) -> Result<Delta> {
    use crate::data::snapshot::{take, take_u32, take_u64};
    let mut pos = 0usize;
    let magic = take(bytes, &mut pos, 8)?;
    crate::ensure!(
        magic == MAGIC.as_slice(),
        "bad magic {magic:?}: not an arbocc-delta file (expected {MAGIC:?})"
    );
    let version = take_u32(bytes, &mut pos)?;
    crate::ensure!(
        version == VERSION,
        "unsupported delta version {version} (reader speaks {VERSION})"
    );
    // Whole-body checksum before any structural parsing: a flipped
    // count field must never steer allocation or op decoding.
    crate::ensure!(
        bytes.len() >= pos.saturating_add(8),
        "truncated delta: no room for the trailing checksum"
    );
    let body = &bytes[..bytes.len() - 8];
    let mut tail = bytes.len() - 8;
    let stored = take_u64(bytes, &mut tail)?;
    let actual = fnv1a(body);
    crate::ensure!(
        stored == actual,
        "delta checksum mismatch: stored {stored:#018x}, computed {actual:#018x}"
    );
    let n64 = take_u64(body, &mut pos)?;
    crate::ensure!(n64 <= u32::MAX as u64, "delta n={n64} exceeds the u32 vertex-id space");
    let n = n64 as usize;
    let base_fingerprint = take_u64(body, &mut pos)?;
    let spec_len = take_u32(body, &mut pos)? as usize;
    let spec_bytes = take(body, &mut pos, spec_len)?;
    let base_spec = std::str::from_utf8(spec_bytes)
        .map_err(|_| crate::util::error::Error::new("delta base spec is not UTF-8"))?
        .to_string();
    let n_batches = take_u32(body, &mut pos)? as usize;
    let mut batches = Vec::new();
    for bi in 0..n_batches {
        let n_ops = take_u32(body, &mut pos)? as usize;
        // Length check before allocation: 9 bytes per op must fit in
        // what remains of the body.
        crate::ensure!(
            n_ops.saturating_mul(9) <= body.len().saturating_sub(pos),
            "delta batch {bi} declares {n_ops} ops but only {} byte(s) remain",
            body.len().saturating_sub(pos)
        );
        let mut ops = Vec::with_capacity(n_ops);
        for _ in 0..n_ops {
            let tag = take(body, &mut pos, 1)?[0];
            let Some(op) = EdgeOp::from_tag(tag) else {
                crate::bail!("delta batch {bi}: bad op kind {tag} (expected 0|1)");
            };
            let u = take_u32(body, &mut pos)?;
            let v = take_u32(body, &mut pos)?;
            ensure_op(n, op, u, v)?;
            ops.push((op, u, v));
        }
        batches.push(DeltaBatch { ops });
    }
    crate::ensure!(
        pos == body.len(),
        "delta has {} trailing byte(s) after the last batch",
        body.len() - pos
    );
    Ok(Delta { n, base_fingerprint, base_spec, batches })
}

pub fn write_delta_file(delta: &Delta, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, delta_bytes(delta)?)?;
    Ok(())
}

pub fn read_delta_file(path: &std::path::Path) -> Result<Delta> {
    read_delta_bytes(&std::fs::read(path)?)
}

/// Apply one batch to a graph, strictly: every delete must name a
/// present edge, every insert an absent one, and no pair may appear
/// twice in the batch — a drifted-out-of-sync delta is an error with
/// context, never a silently divergent graph.
pub fn apply_batch(g: &Graph, batch: &DeltaBatch) -> Result<Graph> {
    let n = g.n();
    let mut seen: std::collections::BTreeSet<(u32, u32)> = std::collections::BTreeSet::new();
    let mut edges: std::collections::BTreeSet<(u32, u32)> = g.edges().collect();
    for &(op, u, v) in &batch.ops {
        ensure_op(n, op, u, v)?;
        crate::ensure!(
            seen.insert((u, v)),
            "delta batch touches edge ({u},{v}) twice"
        );
        match op {
            EdgeOp::Insert => crate::ensure!(
                edges.insert((u, v)),
                "delta insert ({u},{v}): edge already present in the base"
            ),
            EdgeOp::Delete => crate::ensure!(
                edges.remove(&(u, v)),
                "delta delete ({u},{v}): edge not present in the base"
            ),
        }
    }
    let edge_list: Vec<(u32, u32)> = edges.into_iter().collect();
    Ok(Graph::from_edges(n, &edge_list))
}

/// Apply every batch in order against a fingerprint-checked base;
/// returns the post-batch graph sequence (one entry per batch).
pub fn apply_batches(base: &Graph, delta: &Delta) -> Result<Vec<Graph>> {
    crate::ensure!(
        base.n() == delta.n,
        "delta base mismatch: delta says n={}, base graph has n={}",
        delta.n,
        base.n()
    );
    let fp = graph_fingerprint(base);
    crate::ensure!(
        fp == delta.base_fingerprint,
        "delta base mismatch: delta was generated against fingerprint \
         {:#018x}, this base fingerprints {fp:#018x}",
        delta.base_fingerprint
    );
    let mut out = Vec::with_capacity(delta.batches.len());
    let mut cur = base.clone();
    for (i, batch) in delta.batches.iter().enumerate() {
        cur = apply_batch(&cur, batch)
            .map_err(|e| e.context(format!("applying delta batch {i}")))?;
        out.push(cur.clone());
    }
    Ok(out)
}

/// The exact edge diff `old → new`: deletes (in `old`, not `new`) then
/// inserts (in `new`, not `old`), each ascending — so
/// `apply_batch(old, &diff_graphs(old, new)?) == new`.
pub fn diff_graphs(old: &Graph, new: &Graph) -> Result<DeltaBatch> {
    crate::ensure!(
        old.n() == new.n(),
        "diff requires equal vertex counts (old n={}, new n={})",
        old.n(),
        new.n()
    );
    let mut ops = Vec::new();
    for (u, v) in old.edges() {
        if !new.has_edge(u, v) {
            ops.push((EdgeOp::Delete, u, v));
        }
    }
    for (u, v) in new.edges() {
        if !old.has_edge(u, v) {
            ops.push((EdgeOp::Insert, u, v));
        }
    }
    Ok(DeltaBatch { ops })
}

/// Deterministic drift: `batches` successive [`with_flip_noise`]
/// perturbations under one seeded stream, recorded as diffs. A pure
/// function of `(base, batches, flip, seed)`.
pub fn drift_batches(base: &Graph, batches: usize, flip: f64, seed: u64) -> Result<Vec<DeltaBatch>> {
    crate::ensure!(
        (0.0..=1.0).contains(&flip),
        "drift flip probability {flip} outside [0,1]"
    );
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(batches);
    let mut cur = base.clone();
    for _ in 0..batches {
        let next = with_flip_noise(&cur, flip, &mut rng);
        out.push(diff_graphs(&cur, &next)?);
        cur = next;
    }
    Ok(out)
}

/// Decode the `;`-encoded base spec of a `drift:` address (the corpus
/// grammar splits on `,`, so the nested spec swaps its commas for `;`:
/// `drift:base=planted:n=2000;k=8;seed=7,batches=4`).
pub fn decode_base_spec(raw: &str) -> String {
    raw.replace(';', ",")
}

/// Build the full [`Delta`] a `drift:` spec names: parse + generate the
/// base, drift it, record the (comma-form) base spec and fingerprint.
pub fn drift_delta(spec: &WorkloadSpec) -> Result<Delta> {
    crate::ensure!(
        spec.family() == "drift",
        "delta generation needs a drift: spec, got family '{}'",
        spec.family()
    );
    let base_raw = spec.param("base")?;
    let base_spec = WorkloadSpec::parse(&decode_base_spec(&base_raw))?;
    crate::ensure!(
        base_spec.family() != "drift",
        "drift base must be a concrete family, not another drift spec"
    );
    let batches: usize = spec
        .param("batches")?
        .parse()
        .map_err(|_| crate::util::error::Error::new("drift: batches is not a valid usize"))?;
    let flip: f64 = spec
        .param("flip")?
        .parse()
        .map_err(|_| crate::util::error::Error::new("drift: flip is not a valid f64"))?;
    let seed: u64 = spec
        .param("seed")?
        .parse()
        .map_err(|_| crate::util::error::Error::new("drift: seed is not a valid u64"))?;
    let base = base_spec.generate()?;
    let batch_list = drift_batches(&base, batches, flip, seed)?;
    Ok(Delta {
        n: base.n(),
        base_fingerprint: graph_fingerprint(&base),
        base_spec: base_spec.canonical(),
        batches: batch_list,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{clique, disjoint_cliques, lambda_arboric};

    fn sample_delta() -> (Graph, Delta) {
        let base = lambda_arboric(60, 2, &mut Rng::new(11));
        let batches = drift_batches(&base, 3, 0.05, 9).unwrap();
        let delta = Delta {
            n: base.n(),
            base_fingerprint: graph_fingerprint(&base),
            base_spec: "arboric:n=60,lambda=2,seed=11".to_string(),
            batches,
        };
        (base, delta)
    }

    #[test]
    fn roundtrip_is_byte_stable() {
        let (_, delta) = sample_delta();
        assert!(delta.total_ops() > 0, "drift at flip=0.05 should move edges");
        let bytes = delta_bytes(&delta).unwrap();
        let back = read_delta_bytes(&bytes).unwrap();
        assert_eq!(back, delta);
        assert_eq!(delta_bytes(&back).unwrap(), bytes);
    }

    #[test]
    fn diff_then_apply_is_identity() {
        let old = lambda_arboric(50, 2, &mut Rng::new(21));
        let new = with_flip_noise(&old, 0.2, &mut Rng::new(22));
        let batch = diff_graphs(&old, &new).unwrap();
        assert_eq!(apply_batch(&old, &batch).unwrap(), new);
        // Empty diff round-trips too.
        let none = diff_graphs(&old, &old).unwrap();
        assert!(none.ops.is_empty());
        assert_eq!(apply_batch(&old, &none).unwrap(), old);
    }

    #[test]
    fn apply_batches_checks_fingerprint() {
        let (base, delta) = sample_delta();
        let graphs = apply_batches(&base, &delta).unwrap();
        assert_eq!(graphs.len(), delta.batches.len());
        let wrong = clique(base.n());
        let err = apply_batches(&wrong, &delta).unwrap_err().to_string();
        assert!(err.contains("fingerprint"), "{err}");
        let short = Graph::empty(3);
        let err = apply_batches(&short, &delta).unwrap_err().to_string();
        assert!(err.contains("n="), "{err}");
    }

    #[test]
    fn strict_apply_errors() {
        let g = disjoint_cliques(2, 3); // edges within {0,1,2} and {3,4,5}
        for (ops, frag) in [
            (vec![(EdgeOp::Insert, 0u32, 1u32)], "already present"),
            (vec![(EdgeOp::Delete, 0, 3)], "not present"),
            (vec![(EdgeOp::Insert, 2, 2)], "u < v"),
            (vec![(EdgeOp::Insert, 1, 0)], "u < v"),
            (vec![(EdgeOp::Insert, 0, 9)], "out of range"),
            (
                vec![(EdgeOp::Delete, 0, 1), (EdgeOp::Insert, 0, 1)],
                "twice",
            ),
        ] {
            let err = apply_batch(&g, &DeltaBatch { ops }).unwrap_err().to_string();
            assert!(err.contains(frag), "{err}");
        }
    }

    #[test]
    fn fingerprint_separates_structure() {
        let a = clique(5);
        let b = disjoint_cliques(1, 5);
        assert_eq!(graph_fingerprint(&a), graph_fingerprint(&b));
        let c = lambda_arboric(5, 1, &mut Rng::new(3));
        assert_ne!(graph_fingerprint(&a), graph_fingerprint(&c));
        assert_ne!(
            graph_fingerprint(&Graph::empty(4)),
            graph_fingerprint(&Graph::empty(5)),
            "fingerprint must see the vertex count"
        );
    }

    #[test]
    fn drift_is_deterministic() {
        let base = lambda_arboric(40, 2, &mut Rng::new(31));
        let a = drift_batches(&base, 4, 0.1, 7).unwrap();
        let b = drift_batches(&base, 4, 0.1, 7).unwrap();
        assert_eq!(a, b);
        let c = drift_batches(&base, 4, 0.1, 8).unwrap();
        assert_ne!(a, c, "different seeds should drift differently");
    }

    #[test]
    fn drift_delta_from_spec() {
        let spec =
            WorkloadSpec::parse("drift:base=arboric:n=50;lambda=2;seed=4,batches=2,flip=0.1,seed=6")
                .unwrap();
        let delta = drift_delta(&spec).unwrap();
        assert_eq!(delta.n, 50);
        assert_eq!(delta.batches.len(), 2);
        assert_eq!(delta.base_spec, "arboric:n=50,lambda=2,seed=4");
        let base = WorkloadSpec::parse(&delta.base_spec).unwrap().generate().unwrap();
        assert_eq!(graph_fingerprint(&base), delta.base_fingerprint);
    }

    #[test]
    fn corruption_is_rejected_with_context() {
        let (_, delta) = sample_delta();
        let bytes = delta_bytes(&delta).unwrap();
        let mut bad = bytes.clone();
        bad[0] ^= 1;
        assert!(read_delta_bytes(&bad).unwrap_err().to_string().contains("magic"));
        let mut bad = bytes.clone();
        bad[8] = 9; // version field
        assert!(read_delta_bytes(&bad).unwrap_err().to_string().contains("version"));
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xFF;
        assert!(read_delta_bytes(&bad).unwrap_err().to_string().contains("checksum"));
        let msg = read_delta_bytes(&bytes[..bytes.len() - 3]).unwrap_err().to_string();
        assert!(!msg.is_empty());
    }
}
