//! `arbocc-csr/v1` — the versioned binary CSR snapshot format.
//!
//! A snapshot is the wire twin of [`crate::graph::Graph`]: the exact CSR
//! arrays, so loading is a validate-and-adopt instead of a re-sort.  The
//! layout (all integers little-endian):
//!
//! ```text
//! magic     8 B   b"ARBOCSR1"
//! version   u32   1
//! width     u32   4 | 8 — bytes per offset entry (u32 / u64 tagged)
//! n         u64   vertex count
//! m_dir     u64   directed adjacency length (= 2·|E+|)
//! offsets   (n+1) × width
//! neighbors m_dir × 4 (vertex ids are always u32)
//! checksum  u64   FNV-1a over every preceding byte
//! ```
//!
//! The offset width is chosen automatically (u32 while `m_dir` fits, u64
//! beyond) and tagged in the header, so the same reader handles both;
//! [`snapshot_bytes_width`] forces a width for cross-width tests.  Reads
//! validate everything — magic, version, width, exact length, checksum,
//! offset monotonicity, sorted-unique loop-free adjacency, and edge
//! symmetry — so a corrupted file is a line of context, never a panic
//! deep inside an algorithm.

use std::io::{Read, Write};

use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::fnv1a;

/// Leading magic of every `arbocc-csr/v1` snapshot.
pub const MAGIC: &[u8; 8] = b"ARBOCSR1";
/// Format version written and accepted.
pub const VERSION: u32 = 1;

/// Header size in bytes (magic + version + width + n + m_dir).
const HEADER_LEN: usize = 8 + 4 + 4 + 8 + 8;

/// Bytes per offset entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffsetWidth {
    U32,
    U64,
}

impl OffsetWidth {
    pub fn bytes(self) -> usize {
        match self {
            OffsetWidth::U32 => 4,
            OffsetWidth::U64 => 8,
        }
    }

    fn from_tag(tag: u32) -> Option<OffsetWidth> {
        match tag {
            4 => Some(OffsetWidth::U32),
            8 => Some(OffsetWidth::U64),
            _ => None,
        }
    }
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// The snapshot formats address vertices as u32; a graph past that is a
/// hard error with context, never an abort (`arbocc convert` on an
/// oversized input must print one line, not a panic backtrace).
pub(crate) fn ensure_vertex_count(n: usize) -> Result<u32> {
    u32::try_from(n).map_err(|_| {
        crate::util::error::Error::new(format!(
            "graph has {n} vertices but arbocc-csr vertex ids are u32 (max {})",
            u32::MAX
        ))
    })
}

/// [`ensure_vertex_count`] for a graph value (shared with the v2 codec).
pub(crate) fn vertex_count_u32(g: &Graph) -> Result<u32> {
    ensure_vertex_count(g.n())
}

/// Serialize with the automatic offset width (u32 while the directed
/// adjacency length fits, u64 beyond).
pub fn snapshot_bytes(g: &Graph) -> Result<Vec<u8>> {
    let n32 = vertex_count_u32(g)?;
    let m_dir: usize = (0..n32).map(|v| g.degree(v)).sum();
    let width =
        if m_dir <= u32::MAX as usize { OffsetWidth::U32 } else { OffsetWidth::U64 };
    snapshot_bytes_width(g, width)
}

/// Serialize with a forced offset width (the cross-width round-trip
/// tests read a u64-offset snapshot of a small graph).
pub fn snapshot_bytes_width(g: &Graph, width: OffsetWidth) -> Result<Vec<u8>> {
    let n = g.n();
    let n32 = vertex_count_u32(g)?;
    let m_dir: usize = (0..n32).map(|v| g.degree(v)).sum();
    crate::ensure!(
        width == OffsetWidth::U64 || m_dir <= u32::MAX as usize,
        "u32 offsets cannot index {m_dir} directed edges"
    );
    let payload = HEADER_LEN
        .saturating_add((n + 1).saturating_mul(width.bytes()))
        .saturating_add(m_dir.saturating_mul(4))
        .saturating_add(8);
    let mut buf = Vec::with_capacity(payload);
    buf.extend_from_slice(MAGIC);
    push_u32(&mut buf, VERSION);
    // audit:allow(cast-truncate): width.bytes() is the constant 4 or 8
    push_u32(&mut buf, width.bytes() as u32);
    push_u64(&mut buf, n as u64);
    push_u64(&mut buf, m_dir as u64);
    let mut off = 0usize;
    match width {
        OffsetWidth::U32 => push_u32(&mut buf, 0),
        OffsetWidth::U64 => push_u64(&mut buf, 0),
    }
    for v in 0..n32 {
        off += g.degree(v);
        match width {
            // audit:allow(cast-truncate): off ≤ m_dir ≤ u32::MAX on this arm (ensured at entry)
            OffsetWidth::U32 => push_u32(&mut buf, off as u32),
            OffsetWidth::U64 => push_u64(&mut buf, off as u64),
        }
    }
    for v in 0..n32 {
        for &u in g.neighbors(v) {
            push_u32(&mut buf, u);
        }
    }
    let ck = fnv1a(&buf);
    push_u64(&mut buf, ck);
    Ok(buf)
}

/// Write a snapshot (automatic width).
pub fn write_snapshot<W: Write>(g: &Graph, mut w: W) -> Result<()> {
    w.write_all(&snapshot_bytes(g)?)?;
    Ok(())
}

pub fn write_snapshot_file(g: &Graph, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, snapshot_bytes(g)?)?;
    Ok(())
}

pub(crate) fn take<'a>(bytes: &'a [u8], pos: &mut usize, k: usize) -> Result<&'a [u8]> {
    crate::ensure!(
        pos.saturating_add(k) <= bytes.len(),
        "truncated snapshot: need {k} byte(s) at offset {pos}, file has {}",
        bytes.len()
    );
    let out = &bytes[*pos..*pos + k];
    *pos += k;
    Ok(out)
}

pub(crate) fn take_u32(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    Ok(u32::from_le_bytes(take(bytes, pos, 4)?.try_into().expect("4 bytes")))
}

pub(crate) fn take_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    Ok(u64::from_le_bytes(take(bytes, pos, 8)?.try_into().expect("8 bytes")))
}

/// Parse and fully validate a snapshot.
pub fn read_snapshot_bytes(bytes: &[u8]) -> Result<Graph> {
    let mut pos = 0usize;
    let magic = take(bytes, &mut pos, 8)?;
    crate::ensure!(
        magic == MAGIC.as_slice(),
        "bad magic {magic:?}: not an arbocc-csr snapshot (expected {MAGIC:?})"
    );
    let version = take_u32(bytes, &mut pos)?;
    crate::ensure!(
        version == VERSION,
        "unsupported snapshot version {version} (reader speaks {VERSION})"
    );
    let width_tag = take_u32(bytes, &mut pos)?;
    let Some(width) = OffsetWidth::from_tag(width_tag) else {
        crate::bail!("bad offset width tag {width_tag} (expected 4 or 8)");
    };
    let n64 = take_u64(bytes, &mut pos)?;
    let m64 = take_u64(bytes, &mut pos)?;
    crate::ensure!(n64 <= u32::MAX as u64, "n={n64} exceeds the u32 vertex-id space");
    crate::ensure!(
        width == OffsetWidth::U64 || m64 <= u32::MAX as u64,
        "u32 offsets cannot index m_dir={m64}"
    );
    let expected = HEADER_LEN as u128
        + (n64 as u128 + 1) * width.bytes() as u128
        + m64 as u128 * 4
        + 8;
    crate::ensure!(
        bytes.len() as u128 == expected,
        "snapshot length mismatch: header declares n={n64} m_dir={m64} \
         ({expected} bytes) but the file has {}",
        bytes.len()
    );
    let body = &bytes[..bytes.len() - 8];
    let mut tail = bytes.len() - 8;
    let stored = take_u64(bytes, &mut tail)?;
    let actual = fnv1a(body);
    crate::ensure!(
        stored == actual,
        "snapshot checksum mismatch: stored {stored:#018x}, computed {actual:#018x}"
    );
    let (n, m_dir) = (n64 as usize, m64 as usize);
    let n32 = u32::try_from(n64).expect("ensured n64 <= u32::MAX above");
    let mut offsets = Vec::with_capacity(n + 1);
    for i in 0..=n {
        let off = match width {
            OffsetWidth::U32 => take_u32(bytes, &mut pos)? as u64,
            OffsetWidth::U64 => take_u64(bytes, &mut pos)?,
        };
        crate::ensure!(off <= m64, "offset[{i}]={off} exceeds m_dir={m64}");
        if let Some(&prev) = offsets.last() {
            crate::ensure!(
                off >= prev as u64,
                "offsets not monotone at vertex {i}: {off} < {prev}"
            );
        } else {
            crate::ensure!(off == 0, "offset[0] must be 0, got {off}");
        }
        offsets.push(off as usize);
    }
    crate::ensure!(
        offsets[n] == m_dir,
        "final offset {} != m_dir {m_dir}",
        offsets[n]
    );
    let mut neighbors = Vec::with_capacity(m_dir);
    for _ in 0..m_dir {
        neighbors.push(take_u32(bytes, &mut pos)?);
    }
    // Structural validation: sorted strictly-increasing loop-free
    // adjacency (has_edge's binary search depends on it) and symmetry
    // (the graph is undirected by contract).
    for v in 0..n32 {
        let list = &neighbors[offsets[v as usize]..offsets[v as usize + 1]];
        for (i, &u) in list.iter().enumerate() {
            crate::ensure!((u as usize) < n, "vertex {v}: neighbor {u} out of range n={n}");
            crate::ensure!(u != v, "vertex {v}: self-loop in adjacency");
            if i > 0 {
                crate::ensure!(
                    list[i - 1] < u,
                    "vertex {v}: adjacency not sorted-unique at position {i}"
                );
            }
        }
    }
    for v in 0..n32 {
        for &u in &neighbors[offsets[v as usize]..offsets[v as usize + 1]] {
            let peer = &neighbors[offsets[u as usize]..offsets[u as usize + 1]];
            crate::ensure!(
                peer.binary_search(&v).is_ok(),
                "asymmetric edge: {v}→{u} present but {u}→{v} missing"
            );
        }
    }
    Ok(Graph::from_csr(offsets, neighbors))
}

/// Read a snapshot from any reader (buffers fully, then validates).
pub fn read_snapshot<R: Read>(mut r: R) -> Result<Graph> {
    let mut bytes = Vec::new();
    r.read_to_end(&mut bytes)?;
    read_snapshot_bytes(&bytes)
}

pub fn read_snapshot_file(path: &std::path::Path) -> Result<Graph> {
    read_snapshot_bytes(&std::fs::read(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{barbell, lambda_arboric};
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_small() {
        let mut rng = Rng::new(77);
        let g = lambda_arboric(300, 3, &mut rng);
        let bytes = snapshot_bytes(&g).unwrap();
        let back = read_snapshot_bytes(&bytes).unwrap();
        assert_eq!(back, g);
        assert_eq!(snapshot_bytes(&back).unwrap(), bytes, "write-read-write is byte-stable");
    }

    #[test]
    fn forced_u64_width_reads_back() {
        let g = barbell(6);
        let wide = snapshot_bytes_width(&g, OffsetWidth::U64).unwrap();
        let auto = snapshot_bytes(&g).unwrap();
        assert!(wide.len() > auto.len());
        assert_eq!(read_snapshot_bytes(&wide).unwrap(), g);
        assert_eq!(read_snapshot_bytes(&auto).unwrap(), g);
    }

    #[test]
    fn empty_and_isolated_graphs() {
        for g in [Graph::empty(0), Graph::empty(9)] {
            let bytes = snapshot_bytes(&g).unwrap();
            assert_eq!(read_snapshot_bytes(&bytes).unwrap(), g);
        }
    }

    #[test]
    fn oversized_vertex_count_is_an_error_not_a_panic() {
        // A graph past u32::MAX vertices cannot be built in a test (its
        // offsets alone are ~34 GB), so the extracted check is exercised
        // directly — the same path snapshot_bytes{,_width} now take.
        let over = u32::MAX as usize + 1;
        let msg = ensure_vertex_count(over).unwrap_err().to_string();
        assert!(msg.contains("4294967296 vertices"), "{msg}");
        assert!(msg.contains("u32"), "{msg}");
        assert_eq!(ensure_vertex_count(u32::MAX as usize).unwrap(), u32::MAX);
        assert_eq!(ensure_vertex_count(0).unwrap(), 0);
    }

    #[test]
    fn corruption_is_rejected_with_context() {
        let g = barbell(5);
        let bytes = snapshot_bytes(&g).unwrap();
        let mut bad = bytes.clone();
        bad[0] ^= 1;
        assert!(read_snapshot_bytes(&bad).unwrap_err().to_string().contains("magic"));
        let mut bad = bytes.clone();
        bad[8] = 9; // version field
        assert!(read_snapshot_bytes(&bad).unwrap_err().to_string().contains("version"));
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xFF;
        let msg = read_snapshot_bytes(&bad).unwrap_err().to_string();
        assert!(msg.contains("checksum") || msg.contains("offset"), "{msg}");
        let msg = read_snapshot_bytes(&bytes[..bytes.len() - 3]).unwrap_err().to_string();
        assert!(msg.contains("length mismatch") || msg.contains("truncated"), "{msg}");
    }
}
