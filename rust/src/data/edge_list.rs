//! Signed edge-list text IO: whitespace *and* CSV rows, strict
//! line-numbered parse errors, and full normalization on ingest.
//!
//! Accepted rows (comments start with `#` or `%`):
//!
//! ```text
//! # arbocc-edges/v1 n=6 m=3     <- optional directive: id space + edge count
//! 0 1                           <- whitespace pair
//! 2,3                           <- CSV pair
//! 4,5,+                         <- optional sign column: + +1 1 - -1
//! ```
//!
//! Negative rows are counted and dropped — in the paper's complete signed
//! graph every non-adjacent pair *is* a negative edge, so only `E+` is
//! materialized.  Self-loops and duplicates (in either orientation) are
//! normalized away and counted in [`IngestStats`].
//!
//! Vertex ids are arbitrary `u64`s.  When the `arbocc-edges/v1` directive
//! declares `n=`, ids are taken verbatim (must be `< n`; isolated
//! vertices survive a round-trip).  Without it, ids are compacted by
//! **numeric rank**, not first appearance — so permuting or duplicating
//! input lines cannot change the parsed graph (pinned by
//! `tests/data_io.rs`).

use std::io::Write;

use crate::graph::Graph;
use crate::util::error::{Error, Result};

/// Output flavor of [`write_edges`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeListFormat {
    Whitespace,
    Csv,
}

/// What ingest normalized away, for CLI reporting and tests.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IngestStats {
    /// Well-formed data rows seen (including dropped ones).
    pub rows: usize,
    /// Vertex count of the parsed graph.
    pub n: usize,
    /// Undirected positive edges kept (= `g.m()`).
    pub edges: usize,
    /// Duplicate rows dropped (either orientation).
    pub duplicates: usize,
    /// Self-loop rows dropped.
    pub self_loops: usize,
    /// Explicitly negative rows dropped (negatives are implicit).
    pub negatives: usize,
    /// `n=` from an `arbocc-edges/v1` directive, when present.
    pub header_n: Option<usize>,
}

impl IngestStats {
    pub fn describe(&self) -> String {
        format!(
            "{} vertices, {} positive edge(s) from {} row(s) \
             ({} duplicate(s), {} self-loop(s), {} negative(s) dropped)",
            self.n, self.edges, self.rows, self.duplicates, self.self_loops, self.negatives
        )
    }
}

/// Parse an edge list with strict, line-numbered errors.
pub fn read_edges(text: &str) -> Result<(Graph, IngestStats)> {
    let mut raw: Vec<(u64, u64)> = Vec::new();
    let mut stats = IngestStats::default();
    let mut header_n: Option<usize> = None;
    let mut header_m: Option<usize> = None;
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        if t.starts_with('#') || t.starts_with('%') {
            if t.contains("arbocc-edges/") {
                crate::ensure!(
                    t.contains("arbocc-edges/v1"),
                    "line {lineno}: unsupported edge-list directive (reader speaks \
                     arbocc-edges/v1): '{t}'"
                );
                for tok in t.split_whitespace() {
                    if let Some(v) = tok.strip_prefix("n=") {
                        let n = v.parse().map_err(|_| {
                            Error::new(format!("line {lineno}: bad directive token 'n={v}'"))
                        })?;
                        if header_n.is_none() {
                            header_n = Some(n);
                        }
                    }
                    if let Some(v) = tok.strip_prefix("m=") {
                        let m = v.parse().map_err(|_| {
                            Error::new(format!("line {lineno}: bad directive token 'm={v}'"))
                        })?;
                        if header_m.is_none() {
                            header_m = Some(m);
                        }
                    }
                }
            }
            continue;
        }
        let fields: Vec<&str> = if t.contains(',') {
            t.split(',').map(str::trim).collect()
        } else {
            t.split_whitespace().collect()
        };
        crate::ensure!(
            fields.len() == 2 || fields.len() == 3,
            "line {lineno}: expected 'u v' or 'u,v[,sign]', got {} field(s)",
            fields.len()
        );
        let parse_id = |tok: &str| -> Result<u64> {
            tok.parse().map_err(|_| {
                Error::new(format!("line {lineno}: invalid vertex id '{tok}'"))
            })
        };
        let u = parse_id(fields[0])?;
        let v = parse_id(fields[1])?;
        stats.rows += 1;
        // Range-check before the drop rules: a dropped (negative or
        // self-loop) row with an out-of-space id is still a malformed
        // file under the declared-n contract.
        if let Some(n) = header_n {
            crate::ensure!(
                (u as u128) < n as u128 && (v as u128) < n as u128,
                "line {lineno}: vertex id out of range for declared n={n}"
            );
        }
        if fields.len() == 3 {
            match fields[2] {
                "+" | "+1" | "1" => {}
                "-" | "-1" => {
                    stats.negatives += 1;
                    continue;
                }
                s => crate::bail!(
                    "line {lineno}: invalid sign '{s}' (expected +, +1, 1, - or -1)"
                ),
            }
        }
        if u == v {
            stats.self_loops += 1;
            continue;
        }
        raw.push((u, v));
    }
    let (n, edges): (usize, Vec<(u32, u32)>) = match header_n {
        Some(n) => {
            crate::ensure!(
                n <= u32::MAX as usize,
                "declared n={n} exceeds the u32 vertex-id space"
            );
            // Re-validate: rows parsed before a late directive line
            // skipped the inline range check.
            for &(u, v) in &raw {
                crate::ensure!(
                    u < n as u64 && v < n as u64,
                    "vertex id {} out of range for declared n={n}",
                    u.max(v)
                );
            }
            // audit:allow(cast-truncate): u,v < n ≤ u32::MAX, re-validated just above
            (n, raw.iter().map(|&(u, v)| (u as u32, v as u32)).collect())
        }
        None => {
            // Rank compaction: id order, not appearance order, so the
            // parse is invariant under line permutation.
            let mut ids: Vec<u64> = raw.iter().flat_map(|&(u, v)| [u, v]).collect();
            ids.sort_unstable();
            ids.dedup();
            crate::ensure!(
                ids.len() <= u32::MAX as usize,
                "{} distinct vertex ids exceed the u32 id space",
                ids.len()
            );
            // audit:allow(cast-truncate): rank < ids.len() ≤ u32::MAX, ensured just above
            let rank = |x: u64| ids.binary_search(&x).expect("id interned") as u32;
            (ids.len(), raw.iter().map(|&(u, v)| (rank(u), rank(v))).collect())
        }
    };
    let g = Graph::from_edges(n, &edges);
    if let Some(m) = header_m {
        // The v1 writer records the normalized positive-edge count, so a
        // truncated or concatenated file fails loudly (the text format
        // has no checksum to catch it otherwise).
        crate::ensure!(
            g.m() == m,
            "directive declares m={m} positive edge(s) but the file normalizes to {}",
            g.m()
        );
    }
    stats.duplicates = edges.len() - g.m();
    stats.n = n;
    stats.edges = g.m();
    stats.header_n = header_n;
    Ok((g, stats))
}

pub fn read_edges_file(path: &std::path::Path) -> Result<(Graph, IngestStats)> {
    let bytes = std::fs::read(path)?;
    let text = std::str::from_utf8(&bytes)
        .map_err(|_| Error::new(format!("{}: not valid UTF-8 text", path.display())))?;
    read_edges(text).map_err(|e| e.context(format!("parsing {}", path.display())))
}

/// Write a graph with the `arbocc-edges/v1` directive (so a round-trip
/// preserves isolated vertices).
pub fn write_edges<W: Write>(g: &Graph, mut w: W, format: EdgeListFormat) -> Result<()> {
    writeln!(w, "# arbocc-edges/v1 n={} m={}", g.n(), g.m())?;
    for (u, v) in g.edges() {
        match format {
            EdgeListFormat::Whitespace => writeln!(w, "{u} {v}")?,
            EdgeListFormat::Csv => writeln!(w, "{u},{v}")?,
        }
    }
    Ok(())
}

pub fn write_edges_file(g: &Graph, path: &std::path::Path, format: EdgeListFormat) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(file);
    write_edges(g, &mut w, format)?;
    // BufWriter's Drop swallows I/O errors — surface a failed flush
    // (full disk, quota) instead of reporting a truncated file as Ok.
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whitespace_and_csv_rows_mix() {
        let text = "# comment\n0 1\n1,2\n2 , 3\n";
        let (g, stats) = read_edges(text).unwrap();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 3);
        assert_eq!(stats.rows, 3);
    }

    #[test]
    fn sign_column_drops_negatives() {
        let text = "0,1,+\n1,2,-\n2,3,1\n3,0,-1\n0 2 +1\n";
        let (g, stats) = read_edges(text).unwrap();
        assert_eq!(g.m(), 3);
        assert_eq!(stats.negatives, 2);
    }

    #[test]
    fn directive_preserves_isolated_vertices() {
        let g = Graph::from_edges(5, &[(0, 1), (3, 4)]); // vertex 2 isolated
        for format in [EdgeListFormat::Whitespace, EdgeListFormat::Csv] {
            let mut buf = Vec::new();
            write_edges(&g, &mut buf, format).unwrap();
            let (back, stats) = read_edges(std::str::from_utf8(&buf).unwrap()).unwrap();
            assert_eq!(back, g);
            assert_eq!(stats.header_n, Some(5));
        }
    }

    #[test]
    fn rank_compaction_is_order_invariant() {
        let a = read_edges("10 20\n20 30\n").unwrap().0;
        let b = read_edges("20 30\n20 10\n10 20\n").unwrap().0;
        assert_eq!(a, b);
    }

    #[test]
    fn errors_carry_line_numbers() {
        for (text, frag) in [
            ("0 1\n1 2\nx 3\n", "line 3"),
            ("0,1\n1,2,maybe\n", "line 2"),
            ("0 1 2 3\n", "line 1"),
            ("# arbocc-edges/v1 n=abc\n0 1\n", "line 1"),
            ("3\n", "line 1"),
            ("# arbocc-edges/v1 n=2\n0 1\n0 5\n", "line 3"),
        ] {
            let err = read_edges(text).unwrap_err().to_string();
            assert!(err.contains(frag), "{text:?}: {err}");
        }
    }

    #[test]
    fn directive_m_and_version_are_validated() {
        // Truncation: declared m disagrees with the parsed edge count.
        let err = read_edges("# arbocc-edges/v1 n=4 m=3\n0 1\n").unwrap_err().to_string();
        assert!(err.contains("m=3") && err.contains("normalizes to 1"), "{err}");
        // Unknown format version is rejected, not silently parsed.
        let err = read_edges("# arbocc-edges/v2 n=2\n0 1\n").unwrap_err().to_string();
        assert!(err.contains("unsupported") && err.contains("line 1"), "{err}");
    }

    #[test]
    fn stats_count_normalization() {
        let text = "0 1\n1 0\n0 1\n2 2\n1 2\n";
        let (g, stats) = read_edges(text).unwrap();
        assert_eq!(g.m(), 2);
        assert_eq!(stats.duplicates, 2);
        assert_eq!(stats.self_loops, 1);
        assert_eq!(stats.rows, 5);
        assert!(stats.describe().contains("2 duplicate(s)"));
    }

    #[test]
    fn empty_input_is_the_empty_graph() {
        let (g, stats) = read_edges("# nothing\n\n").unwrap();
        assert_eq!(g.n(), 0);
        assert_eq!(stats.rows, 0);
    }
}
