//! Graph I/O: plain edge-list format (SNAP-style) read/write.
//!
//! Format: one `u v` pair per line; lines starting with `#` or `%` are
//! comments; vertices are non-negative integers (arbitrary ids are
//! compacted on read).  This is the format of the SNAP datasets the
//! correlation-clustering literature evaluates on, so real graphs drop in
//! directly:
//!
//! ```text
//! # com-DBLP ungraph.txt
//! 0 1
//! 0 2
//! ```

use std::io::{BufRead, Write};

use crate::graph::Graph;

/// Read an edge list; returns the graph and the original-id-of-vertex map
/// (ids are compacted to `[0, n)` in first-appearance order).
pub fn read_edge_list<R: BufRead>(reader: R) -> std::io::Result<(Graph, Vec<u64>)> {
    let mut id_of: std::collections::HashMap<u64, u32> = std::collections::HashMap::new();
    let mut original: Vec<u64> = Vec::new();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let intern = |raw: u64, original: &mut Vec<u64>,
                      id_of: &mut std::collections::HashMap<u64, u32>| {
        *id_of.entry(raw).or_insert_with(|| {
            let id = original.len() as u32;
            original.push(raw);
            id
        })
    };
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') || trimmed.starts_with('%') {
            continue;
        }
        let mut parts = trimmed.split_whitespace();
        let parse = |tok: Option<&str>| -> std::io::Result<u64> {
            tok.and_then(|t| t.parse().ok()).ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("line {}: expected 'u v'", lineno + 1),
                )
            })
        };
        let u = parse(parts.next())?;
        let v = parse(parts.next())?;
        if u == v {
            continue; // drop self-loops, standard for these datasets
        }
        let ui = intern(u, &mut original, &mut id_of);
        let vi = intern(v, &mut original, &mut id_of);
        edges.push((ui, vi));
    }
    let n = original.len();
    Ok((Graph::from_edges(n, &edges), original))
}

/// Read from a file path.
pub fn read_edge_list_file(path: &std::path::Path) -> std::io::Result<(Graph, Vec<u64>)> {
    let file = std::fs::File::open(path)?;
    read_edge_list(std::io::BufReader::new(file))
}

/// Write a graph as an edge list (compact ids).
pub fn write_edge_list<W: Write>(g: &Graph, mut writer: W) -> std::io::Result<()> {
    writeln!(writer, "# arbocc edge list: n={} m={}", g.n(), g.m())?;
    for (u, v) in g.edges() {
        writeln!(writer, "{u} {v}")?;
    }
    Ok(())
}

pub fn write_edge_list_file(g: &Graph, path: &std::path::Path) -> std::io::Result<()> {
    let file = std::fs::File::create(path)?;
    write_edge_list(g, std::io::BufWriter::new(file))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::lambda_arboric;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(300);
        let g = lambda_arboric(200, 3, &mut rng);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let (g2, original) = read_edge_list(std::io::Cursor::new(buf)).unwrap();
        assert_eq!(g2.m(), g.m());
        // Vertex count can differ (isolated vertices are not serialized);
        // edges must be preserved under the id map.
        let mut back: Vec<(u32, u32)> = g2
            .edges()
            .map(|(u, v)| {
                let (a, b) = (original[u as usize] as u32, original[v as usize] as u32);
                if a < b { (a, b) } else { (b, a) }
            })
            .collect();
        back.sort_unstable();
        let mut fwd: Vec<(u32, u32)> = g.edges().collect();
        fwd.sort_unstable();
        assert_eq!(back, fwd);
    }

    #[test]
    fn parses_comments_and_arbitrary_ids() {
        let text = "# comment\n% also comment\n\n1000000 5\n5 7\n7 1000000\n";
        let (g, original) = read_edge_list(std::io::Cursor::new(text)).unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert_eq!(original, vec![1_000_000, 5, 7]);
    }

    #[test]
    fn drops_self_loops() {
        let text = "1 1\n1 2\n";
        let (g, _) = read_edge_list(std::io::Cursor::new(text)).unwrap();
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_edge_list(std::io::Cursor::new("1 x\n")).is_err());
        assert!(read_edge_list(std::io::Cursor::new("1\n")).is_err());
    }
}
