//! Graph substrate: CSR storage, generators for every workload family in
//! the paper, arboricity estimation, and connectivity.
//!
//! Convention: a [`csr::Graph`] *is* the positive-edge graph `(V, E+)` of
//! the paper's complete signed graph.  Negative edges are implicit — every
//! non-adjacent pair of vertices is a negative edge — so `N = |E+|` is the
//! input size, exactly as the paper's MPC accounting assumes (§1.1).

pub mod arboricity;
pub mod components;
pub mod csr;
pub mod generators;

pub use csr::Graph;
