//! Connected components and clique detection — the substrate of the
//! Corollary 32 "simple algorithm" (clique components become clusters).

use crate::graph::csr::Graph;

/// Component labelling of a graph.
#[derive(Debug, Clone)]
pub struct Components {
    /// `label[v]` is the component id of v, in `[0, count)`.
    pub label: Vec<u32>,
    pub count: usize,
}

impl Components {
    /// Vertices of each component.
    pub fn members(&self) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); self.count];
        for (v, &c) in self.label.iter().enumerate() {
            out[c as usize].push(v as u32);
        }
        out
    }

    /// Size of each component.
    pub fn sizes(&self) -> Vec<usize> {
        let mut out = vec![0usize; self.count];
        for &c in &self.label {
            out[c as usize] += 1;
        }
        out
    }

    pub fn largest(&self) -> usize {
        self.sizes().into_iter().max().unwrap_or(0)
    }
}

/// BFS-based component labelling, O(n + m).
pub fn components(g: &Graph) -> Components {
    let mut out = Components { label: Vec::new(), count: 0 };
    components_into(g, &mut out);
    out
}

/// [`components`] into caller-owned scratch: the label Vec's capacity is
/// reused across calls, so the incremental delta maintainer and the
/// decomposition driver stop paying an n-sized allocation per
/// relabelling.
pub fn components_into(g: &Graph, out: &mut Components) {
    let n = g.n();
    out.label.clear();
    out.label.resize(n, u32::MAX);
    let label = &mut out.label;
    let mut count = 0u32;
    let mut queue = std::collections::VecDeque::new();
    for start in 0..n as u32 {
        if label[start as usize] != u32::MAX {
            continue;
        }
        label[start as usize] = count;
        queue.push_back(start);
        while let Some(v) = queue.pop_front() {
            for &u in g.neighbors(v) {
                if label[u as usize] == u32::MAX {
                    label[u as usize] = count;
                    queue.push_back(u);
                }
            }
        }
        count += 1;
    }
    out.count = count as usize;
}

/// Split a graph into one compact subgraph per connected component.
///
/// Returns, per component, the compacted [`Graph`] plus the
/// old-id-of-new-id mapping (ascending original ids, so sorted CSR
/// adjacency is preserved). One O(n + m) pass over the whole graph —
/// unlike calling [`Graph::induced_compact`] per component, which would
/// pay O(n) per component just for the keep mask. This is the substrate
/// of the solve engine's per-component decomposition driver.
pub fn split_components(g: &Graph, comps: &Components) -> Vec<(Graph, Vec<u32>)> {
    let n = g.n();
    assert_eq!(comps.label.len(), n);
    let members = comps.members();
    // Position of each vertex inside its own component.
    let mut new_id = vec![0u32; n];
    for m in &members {
        for (i, &v) in m.iter().enumerate() {
            new_id[v as usize] = i as u32;
        }
    }
    members
        .into_iter()
        .map(|m| {
            let mut offsets = Vec::with_capacity(m.len() + 1);
            // Pre-reserve the component's degree sum (one counting pass
            // over members) so the adjacency Vec never regrows.
            let degree_sum: usize = m.iter().map(|&v| g.degree(v)).sum();
            let mut neighbors = Vec::with_capacity(degree_sum);
            offsets.push(0);
            for &v in &m {
                // Every neighbor shares v's component, so the mapped ids
                // stay sorted (members are ascending original ids).
                neighbors.extend(g.neighbors(v).iter().map(|&u| new_id[u as usize]));
                offsets.push(neighbors.len());
            }
            (Graph::from_csr(offsets, neighbors), m)
        })
        .collect()
}

/// The incremental component maintainer's output: the post-delta
/// labelling (bit-identical to `components(new_g)`, pinned by tests)
/// plus, per new component, which old component it is an untouched copy
/// of.
#[derive(Debug, Clone)]
pub struct DeltaComponents {
    /// Labelling of the post-delta graph, in canonical order (component
    /// ids ascend with each component's minimum vertex id — the same
    /// numbering [`components`] produces).
    pub comps: Components,
    /// `clean_from[j] = Some(c)`: new component j is exactly old
    /// component c with no op endpoint inside it, so its induced
    /// subgraph is unchanged. `None`: j is dirty and must be re-solved.
    pub clean_from: Vec<Option<u32>>,
}

impl DeltaComponents {
    /// `(clean, dirty)` component counts.
    pub fn clean_dirty(&self) -> (usize, usize) {
        let clean = self.clean_from.iter().filter(|c| c.is_some()).count();
        (clean, self.comps.count - clean)
    }
}

/// Update a component labelling across one edge-delta batch without a
/// full BFS where possible:
///
/// * old components with **no** op endpoint keep their single fragment —
///   no edge of theirs changed, so no traversal happens at all;
/// * components hit by a **delete** may split, so they are re-BFS'd on
///   `new_g` restricted to their own member set (localized: the cost is
///   the touched components' size, not n + m);
/// * **inserts** only merge, so they become unions over the resulting
///   fragments in a scratch [`UnionFind`].
///
/// Fragments are renumbered by first occurrence in vertex order, which
/// reproduces [`components`]' canonical numbering exactly — the
/// incremental driver's per-component seeds depend on it.
pub fn components_after_delta(
    new_g: &Graph,
    old: &Components,
    inserts: &[(u32, u32)],
    deletes: &[(u32, u32)],
) -> DeltaComponents {
    let n = new_g.n();
    assert_eq!(old.label.len(), n, "old labelling must cover the post-delta vertex set");
    // Which old components any op touches, and which need a localized
    // re-BFS (deletes can split; inserts only merge).
    let mut touched = vec![false; old.count];
    let mut rebfs = vec![false; old.count];
    for &(u, v) in inserts {
        touched[old.label[u as usize] as usize] = true;
        touched[old.label[v as usize] as usize] = true;
    }
    for &(u, v) in deletes {
        for w in [u, v] {
            let c = old.label[w as usize] as usize;
            touched[c] = true;
            rebfs[c] = true;
        }
    }
    // Fragment labelling: one fragment per untouched-by-delete old
    // component (no traversal), BFS fragments inside re-BFS components.
    // Cross-component inserts are invisible here (the BFS stays inside
    // the old member set); the union pass below stitches them.
    let mut frag = vec![u32::MAX; n];
    let mut comp_frag = vec![u32::MAX; old.count];
    let mut frag_count = 0u32;
    let mut queue = std::collections::VecDeque::new();
    for v in 0..n as u32 {
        if frag[v as usize] != u32::MAX {
            continue;
        }
        let c = old.label[v as usize] as usize;
        if !rebfs[c] {
            if comp_frag[c] == u32::MAX {
                comp_frag[c] = frag_count;
                frag_count += 1;
            }
            frag[v as usize] = comp_frag[c];
            continue;
        }
        frag[v as usize] = frag_count;
        queue.push_back(v);
        while let Some(x) = queue.pop_front() {
            for &u in new_g.neighbors(x) {
                if old.label[u as usize] as usize == c && frag[u as usize] == u32::MAX {
                    frag[u as usize] = frag_count;
                    queue.push_back(u);
                }
            }
        }
        frag_count += 1;
    }
    // Inserts merge fragments.
    let mut uf = UnionFind::new(frag_count as usize);
    for &(u, v) in inserts {
        uf.union(frag[u as usize], frag[v as usize]);
    }
    // Canonical renumber by first occurrence in vertex order (the same
    // order BFS from ascending start vertices assigns), plus the
    // clean-component certificate.
    let mut root_to_new = vec![u32::MAX; frag_count as usize];
    let mut label = vec![u32::MAX; n];
    let mut clean_from = Vec::new();
    for v in 0..n {
        let root = uf.find(frag[v]) as usize;
        if root_to_new[root] == u32::MAX {
            root_to_new[root] = clean_from.len() as u32;
            let c = old.label[v];
            // An untouched old component has no insert endpoint (so its
            // fragment was never unioned) and no delete endpoint (so it
            // is one whole fragment): the new component IS old c.
            clean_from.push(if touched[c as usize] { None } else { Some(c) });
        }
        label[v] = root_to_new[root];
    }
    let count = clean_from.len();
    DeltaComponents { comps: Components { label, count }, clean_from }
}

/// Is the vertex set `vs` a clique in g? (Checks degrees first: in a
/// clique of size k every member has >= k-1 neighbors inside.)
pub fn is_clique(g: &Graph, vs: &[u32]) -> bool {
    let k = vs.len();
    if k <= 1 {
        return true;
    }
    // Degree short-circuit: internal degree can't reach k-1 if total
    // degree is below it.
    if vs.iter().any(|&v| g.degree(v) < k - 1) {
        return false;
    }
    let mut sorted: Vec<u32> = vs.to_vec();
    sorted.sort_unstable();
    for &v in vs {
        let internal =
            g.neighbors(v).iter().filter(|&&u| sorted.binary_search(&u).is_ok()).count();
        if internal < k - 1 {
            return false;
        }
    }
    true
}

/// Union-Find with path halving + union by size; used by the MPC
/// connectivity primitives and matching algorithms.
#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl UnionFind {
    pub fn new(n: usize) -> UnionFind {
        UnionFind { parent: (0..n as u32).collect(), size: vec![1; n] }
    }

    pub fn find(&mut self, mut v: u32) -> u32 {
        while self.parent[v as usize] != v {
            self.parent[v as usize] = self.parent[self.parent[v as usize] as usize];
            v = self.parent[v as usize];
        }
        v
    }

    /// Union the sets of a and b; returns true if they were distinct.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra as usize] < self.size[rb as usize] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb as usize] = ra;
        self.size[ra as usize] += self.size[rb as usize];
        true
    }

    pub fn same(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }

    pub fn set_size(&mut self, v: u32) -> usize {
        let r = self.find(v);
        self.size[r as usize] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{clique, disjoint_cliques, path};

    #[test]
    fn components_of_disjoint_cliques() {
        let g = disjoint_cliques(3, 4);
        let c = components(&g);
        assert_eq!(c.count, 3);
        assert_eq!(c.sizes(), vec![4, 4, 4]);
        assert_eq!(c.largest(), 4);
        for vs in c.members() {
            assert!(is_clique(&g, &vs));
        }
    }

    #[test]
    fn path_is_single_component_not_clique() {
        let g = path(5);
        let c = components(&g);
        assert_eq!(c.count, 1);
        let vs: Vec<u32> = (0..5).collect();
        assert!(!is_clique(&g, &vs));
    }

    #[test]
    fn isolated_vertices_are_components_and_cliques() {
        let g = Graph::empty(3);
        let c = components(&g);
        assert_eq!(c.count, 3);
        assert!(is_clique(&g, &[0]));
        assert!(is_clique(&g, &[]));
    }

    #[test]
    fn clique_detection_positive() {
        let g = clique(5);
        assert!(is_clique(&g, &[0, 1, 2, 3, 4]));
        assert!(is_clique(&g, &[1, 3]));
    }

    #[test]
    fn split_components_partitions_edges_and_vertices() {
        // Two cliques + an isolated vertex: 3 compact parts that cover
        // every vertex and every edge exactly once.
        let mut edges = vec![(0u32, 1u32), (1, 2), (0, 2)]; // K3 on {0,1,2}
        edges.extend([(4, 5)]); // K2 on {4,5}; vertex 3 isolated
        let g = Graph::from_edges(6, &edges);
        let comps = components(&g);
        let parts = split_components(&g, &comps);
        assert_eq!(parts.len(), 3);
        let total_n: usize = parts.iter().map(|(p, _)| p.n()).sum();
        let total_m: usize = parts.iter().map(|(p, _)| p.m()).sum();
        assert_eq!(total_n, 6);
        assert_eq!(total_m, g.m());
        // Mappings are ascending and mapped edges exist in the original.
        for (part, old) in &parts {
            assert_eq!(part.n(), old.len());
            assert!(old.windows(2).all(|w| w[0] < w[1]));
            for (u, v) in part.edges() {
                assert!(g.has_edge(old[u as usize], old[v as usize]));
            }
        }
        // The K3 part really is a clique.
        let k3 = parts.iter().find(|(p, _)| p.n() == 3).unwrap();
        assert!(is_clique(&k3.0, &[0, 1, 2]));
    }

    #[test]
    fn split_components_random_forest_roundtrip() {
        use crate::graph::generators::random_forest;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let g = random_forest(200, 0.7, &mut rng);
        let comps = components(&g);
        let parts = split_components(&g, &comps);
        assert_eq!(parts.len(), comps.count);
        let mut covered = vec![false; g.n()];
        let mut total_m = 0usize;
        for (part, old) in &parts {
            total_m += part.m();
            for &v in old {
                assert!(!covered[v as usize], "vertex {v} in two parts");
                covered[v as usize] = true;
            }
        }
        assert!(covered.into_iter().all(|c| c));
        assert_eq!(total_m, g.m());
    }

    #[test]
    fn components_into_reuses_scratch() {
        let g1 = disjoint_cliques(3, 4);
        let g2 = path(5);
        let mut scratch = Components { label: Vec::new(), count: 0 };
        components_into(&g1, &mut scratch);
        assert_eq!(scratch.count, 3);
        assert_eq!(scratch.label, components(&g1).label);
        // Reuse across a smaller graph: stale labels must not leak.
        components_into(&g2, &mut scratch);
        assert_eq!(scratch.count, 1);
        assert_eq!(scratch.label, components(&g2).label);
    }

    fn delta_vs_full(
        old_g: &Graph,
        inserts: &[(u32, u32)],
        deletes: &[(u32, u32)],
    ) -> DeltaComponents {
        let mut edges: std::collections::BTreeSet<(u32, u32)> = old_g.edges().collect();
        for &(u, v) in deletes {
            assert!(edges.remove(&(u, v)), "test delete ({u},{v}) missing");
        }
        for &(u, v) in inserts {
            assert!(edges.insert((u, v)), "test insert ({u},{v}) already present");
        }
        let list: Vec<(u32, u32)> = edges.into_iter().collect();
        let new_g = Graph::from_edges(old_g.n(), &list);
        let old = components(old_g);
        let dc = components_after_delta(&new_g, &old, inserts, deletes);
        let full = components(&new_g);
        assert_eq!(dc.comps.label, full.label, "incremental labelling must match full BFS");
        assert_eq!(dc.comps.count, full.count);
        // Clean components really are untouched old components.
        let old_members = old.members();
        let new_members = dc.comps.members();
        for (j, from) in dc.clean_from.iter().enumerate() {
            if let Some(c) = from {
                assert_eq!(new_members[j], old_members[*c as usize], "clean comp {j}");
            }
        }
        dc
    }

    #[test]
    fn delta_components_merge_split_and_clean() {
        // Three K4s: {0..3}, {4..7}, {8..11}.
        let g = disjoint_cliques(3, 4);
        // Insert a bridge 0–4: comps 0,1 merge, comp 2 stays clean.
        let dc = delta_vs_full(&g, &[(0, 4)], &[]);
        assert_eq!(dc.comps.count, 2);
        assert_eq!(dc.clean_dirty(), (1, 1));
        assert_eq!(dc.clean_from, vec![None, Some(2)]);
        // Delete an internal edge (clique stays connected): dirty but
        // structurally intact; others clean.
        let dc = delta_vs_full(&g, &[], &[(0, 1)]);
        assert_eq!(dc.comps.count, 3);
        assert_eq!(dc.clean_dirty(), (2, 1));
        // Split: delete all of vertex 3's edges; {0,1,2} + isolated {3}.
        let dc = delta_vs_full(&g, &[], &[(0, 3), (1, 3), (2, 3)]);
        assert_eq!(dc.comps.count, 4);
        assert_eq!(dc.clean_dirty(), (2, 2));
        // Merge and split in one batch.
        let dc = delta_vs_full(&g, &[(0, 8)], &[(4, 5), (4, 6), (4, 7)]);
        assert_eq!(dc.comps.count, 3); // {0..3}+{8..11}, {5,6,7}, {4}
        assert_eq!(dc.clean_dirty(), (0, 3));
    }

    #[test]
    fn delta_components_random_drift_matches_full_bfs() {
        use crate::graph::generators::random_forest;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(910);
        for trial in 0..20 {
            let g = random_forest(80, 0.8, &mut rng);
            let mut inserts = Vec::new();
            let mut deletes = Vec::new();
            let mut pool: Vec<(u32, u32)> = g.edges().collect();
            rng.shuffle(&mut pool);
            deletes.extend(pool.into_iter().take(trial % 5));
            while inserts.len() < trial % 4 {
                let u = rng.index(80) as u32;
                let v = rng.index(80) as u32;
                let (a, b) = (u.min(v), u.max(v));
                if a != b && !g.has_edge(a, b) && !inserts.contains(&(a, b)) {
                    inserts.push((a, b));
                }
            }
            delta_vs_full(&g, &inserts, &deletes);
        }
    }

    #[test]
    fn union_find_basics() {
        let mut uf = UnionFind::new(6);
        assert!(uf.union(0, 1));
        assert!(uf.union(1, 2));
        assert!(!uf.union(0, 2));
        assert!(uf.same(0, 2));
        assert!(!uf.same(0, 3));
        assert_eq!(uf.set_size(2), 3);
        assert_eq!(uf.set_size(5), 1);
    }
}
