//! Connected components and clique detection — the substrate of the
//! Corollary 32 "simple algorithm" (clique components become clusters).

use crate::graph::csr::Graph;

/// Component labelling of a graph.
#[derive(Debug, Clone)]
pub struct Components {
    /// `label[v]` is the component id of v, in `[0, count)`.
    pub label: Vec<u32>,
    pub count: usize,
}

impl Components {
    /// Vertices of each component.
    pub fn members(&self) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); self.count];
        for (v, &c) in self.label.iter().enumerate() {
            out[c as usize].push(v as u32);
        }
        out
    }

    /// Size of each component.
    pub fn sizes(&self) -> Vec<usize> {
        let mut out = vec![0usize; self.count];
        for &c in &self.label {
            out[c as usize] += 1;
        }
        out
    }

    pub fn largest(&self) -> usize {
        self.sizes().into_iter().max().unwrap_or(0)
    }
}

/// BFS-based component labelling, O(n + m).
pub fn components(g: &Graph) -> Components {
    let n = g.n();
    let mut label = vec![u32::MAX; n];
    let mut count = 0u32;
    let mut queue = std::collections::VecDeque::new();
    for start in 0..n as u32 {
        if label[start as usize] != u32::MAX {
            continue;
        }
        label[start as usize] = count;
        queue.push_back(start);
        while let Some(v) = queue.pop_front() {
            for &u in g.neighbors(v) {
                if label[u as usize] == u32::MAX {
                    label[u as usize] = count;
                    queue.push_back(u);
                }
            }
        }
        count += 1;
    }
    Components { label, count: count as usize }
}

/// Split a graph into one compact subgraph per connected component.
///
/// Returns, per component, the compacted [`Graph`] plus the
/// old-id-of-new-id mapping (ascending original ids, so sorted CSR
/// adjacency is preserved). One O(n + m) pass over the whole graph —
/// unlike calling [`Graph::induced_compact`] per component, which would
/// pay O(n) per component just for the keep mask. This is the substrate
/// of the solve engine's per-component decomposition driver.
pub fn split_components(g: &Graph, comps: &Components) -> Vec<(Graph, Vec<u32>)> {
    let n = g.n();
    assert_eq!(comps.label.len(), n);
    let members = comps.members();
    // Position of each vertex inside its own component.
    let mut new_id = vec![0u32; n];
    for m in &members {
        for (i, &v) in m.iter().enumerate() {
            new_id[v as usize] = i as u32;
        }
    }
    members
        .into_iter()
        .map(|m| {
            let mut offsets = Vec::with_capacity(m.len() + 1);
            let mut neighbors = Vec::new();
            offsets.push(0);
            for &v in &m {
                // Every neighbor shares v's component, so the mapped ids
                // stay sorted (members are ascending original ids).
                neighbors.extend(g.neighbors(v).iter().map(|&u| new_id[u as usize]));
                offsets.push(neighbors.len());
            }
            (Graph::from_csr(offsets, neighbors), m)
        })
        .collect()
}

/// Is the vertex set `vs` a clique in g? (Checks degrees first: in a
/// clique of size k every member has >= k-1 neighbors inside.)
pub fn is_clique(g: &Graph, vs: &[u32]) -> bool {
    let k = vs.len();
    if k <= 1 {
        return true;
    }
    // Degree short-circuit: internal degree can't reach k-1 if total
    // degree is below it.
    if vs.iter().any(|&v| g.degree(v) < k - 1) {
        return false;
    }
    let mut sorted: Vec<u32> = vs.to_vec();
    sorted.sort_unstable();
    for &v in vs {
        let internal =
            g.neighbors(v).iter().filter(|&&u| sorted.binary_search(&u).is_ok()).count();
        if internal < k - 1 {
            return false;
        }
    }
    true
}

/// Union-Find with path halving + union by size; used by the MPC
/// connectivity primitives and matching algorithms.
#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl UnionFind {
    pub fn new(n: usize) -> UnionFind {
        UnionFind { parent: (0..n as u32).collect(), size: vec![1; n] }
    }

    pub fn find(&mut self, mut v: u32) -> u32 {
        while self.parent[v as usize] != v {
            self.parent[v as usize] = self.parent[self.parent[v as usize] as usize];
            v = self.parent[v as usize];
        }
        v
    }

    /// Union the sets of a and b; returns true if they were distinct.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra as usize] < self.size[rb as usize] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb as usize] = ra;
        self.size[ra as usize] += self.size[rb as usize];
        true
    }

    pub fn same(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }

    pub fn set_size(&mut self, v: u32) -> usize {
        let r = self.find(v);
        self.size[r as usize] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{clique, disjoint_cliques, path};

    #[test]
    fn components_of_disjoint_cliques() {
        let g = disjoint_cliques(3, 4);
        let c = components(&g);
        assert_eq!(c.count, 3);
        assert_eq!(c.sizes(), vec![4, 4, 4]);
        assert_eq!(c.largest(), 4);
        for vs in c.members() {
            assert!(is_clique(&g, &vs));
        }
    }

    #[test]
    fn path_is_single_component_not_clique() {
        let g = path(5);
        let c = components(&g);
        assert_eq!(c.count, 1);
        let vs: Vec<u32> = (0..5).collect();
        assert!(!is_clique(&g, &vs));
    }

    #[test]
    fn isolated_vertices_are_components_and_cliques() {
        let g = Graph::empty(3);
        let c = components(&g);
        assert_eq!(c.count, 3);
        assert!(is_clique(&g, &[0]));
        assert!(is_clique(&g, &[]));
    }

    #[test]
    fn clique_detection_positive() {
        let g = clique(5);
        assert!(is_clique(&g, &[0, 1, 2, 3, 4]));
        assert!(is_clique(&g, &[1, 3]));
    }

    #[test]
    fn split_components_partitions_edges_and_vertices() {
        // Two cliques + an isolated vertex: 3 compact parts that cover
        // every vertex and every edge exactly once.
        let mut edges = vec![(0u32, 1u32), (1, 2), (0, 2)]; // K3 on {0,1,2}
        edges.extend([(4, 5)]); // K2 on {4,5}; vertex 3 isolated
        let g = Graph::from_edges(6, &edges);
        let comps = components(&g);
        let parts = split_components(&g, &comps);
        assert_eq!(parts.len(), 3);
        let total_n: usize = parts.iter().map(|(p, _)| p.n()).sum();
        let total_m: usize = parts.iter().map(|(p, _)| p.m()).sum();
        assert_eq!(total_n, 6);
        assert_eq!(total_m, g.m());
        // Mappings are ascending and mapped edges exist in the original.
        for (part, old) in &parts {
            assert_eq!(part.n(), old.len());
            assert!(old.windows(2).all(|w| w[0] < w[1]));
            for (u, v) in part.edges() {
                assert!(g.has_edge(old[u as usize], old[v as usize]));
            }
        }
        // The K3 part really is a clique.
        let k3 = parts.iter().find(|(p, _)| p.n() == 3).unwrap();
        assert!(is_clique(&k3.0, &[0, 1, 2]));
    }

    #[test]
    fn split_components_random_forest_roundtrip() {
        use crate::graph::generators::random_forest;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let g = random_forest(200, 0.7, &mut rng);
        let comps = components(&g);
        let parts = split_components(&g, &comps);
        assert_eq!(parts.len(), comps.count);
        let mut covered = vec![false; g.n()];
        let mut total_m = 0usize;
        for (part, old) in &parts {
            total_m += part.m();
            for &v in old {
                assert!(!covered[v as usize], "vertex {v} in two parts");
                covered[v as usize] = true;
            }
        }
        assert!(covered.into_iter().all(|c| c));
        assert_eq!(total_m, g.m());
    }

    #[test]
    fn union_find_basics() {
        let mut uf = UnionFind::new(6);
        assert!(uf.union(0, 1));
        assert!(uf.union(1, 2));
        assert!(!uf.union(0, 2));
        assert!(uf.same(0, 2));
        assert!(!uf.same(0, 3));
        assert_eq!(uf.set_size(2), 3);
        assert_eq!(uf.set_size(5), 1);
    }
}
