//! Arboricity machinery: degeneracy (Matula–Beck peel) and Nash–Williams
//! density witnesses.
//!
//! The paper's parameter λ is the arboricity of the positive-edge graph,
//! `λ = max_S ⌈|E(S)|/(|S|-1)⌉`.  Computing λ exactly is a matroid-union
//! problem; the standard practical sandwich is
//!
//! ```text
//! density_lb  ≤  λ  ≤  degeneracy(G)  ≤  2λ - 1
//! ```
//!
//! where `density_lb` is the best Nash–Williams density over the suffix
//! subgraphs of the degeneracy order (each suffix is an induced subgraph,
//! hence a valid witness).  The algorithms only need an O(λ) degree
//! threshold, so any constant-factor estimate is sufficient — we report
//! both ends of the sandwich.

use crate::graph::csr::Graph;

/// Result of the degeneracy peel.
#[derive(Debug, Clone)]
pub struct ArboricityEstimate {
    /// Degeneracy d(G): the largest minimum degree over all subgraphs.
    pub degeneracy: usize,
    /// Best Nash–Williams density witness found: ⌈m_S / (|S|-1)⌉ maximized
    /// over the peel-order suffixes. A certified *lower* bound on λ.
    pub density_lower_bound: usize,
    /// Peel order (smallest-degree-first removal order).
    pub order: Vec<u32>,
}

impl ArboricityEstimate {
    /// λ is within [density_lower_bound, degeneracy].
    pub fn bounds(&self) -> (usize, usize) {
        (self.density_lower_bound, self.degeneracy.max(self.density_lower_bound))
    }
}

/// Matula–Beck bucket peel in O(n + m).
pub fn estimate_arboricity(g: &Graph) -> ArboricityEstimate {
    let n = g.n();
    if n == 0 {
        return ArboricityEstimate { degeneracy: 0, density_lower_bound: 0, order: vec![] };
    }
    let max_deg = g.max_degree();
    let mut degree: Vec<usize> = (0..n as u32).map(|v| g.degree(v)).collect();
    // Bucket queue keyed by current degree.
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); max_deg + 1];
    for v in 0..n as u32 {
        buckets[degree[v as usize]].push(v);
    }
    let mut removed = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut degeneracy = 0usize;
    let mut cursor = 0usize;
    for _ in 0..n {
        // Find the lowest non-empty bucket; cursor can only have decreased
        // by 1 per removal, so reset it down first.
        while cursor > 0 && !buckets[cursor - 1].is_empty() {
            cursor -= 1;
        }
        while buckets[cursor].is_empty() {
            cursor += 1;
        }
        let v = loop {
            let cand = buckets[cursor].pop().expect("bucket nonempty");
            // Lazy deletion: entries may be stale (degree changed).
            if !removed[cand as usize] && degree[cand as usize] == cursor {
                break cand;
            }
            while buckets[cursor].is_empty() {
                cursor += 1;
            }
        };
        removed[v as usize] = true;
        degeneracy = degeneracy.max(cursor);
        order.push(v);
        for &u in g.neighbors(v) {
            if !removed[u as usize] {
                let d = degree[u as usize];
                degree[u as usize] = d - 1;
                buckets[d - 1].push(u);
            }
        }
    }

    // Nash–Williams density over suffixes of the peel order: walk the
    // order backwards, counting edges internal to the suffix.
    let mut in_suffix = vec![false; n];
    let mut suffix_edges = 0usize;
    let mut best_density = 0usize;
    let mut suffix_size = 0usize;
    for &v in order.iter().rev() {
        suffix_edges += g.neighbors(v).iter().filter(|&&u| in_suffix[u as usize]).count();
        in_suffix[v as usize] = true;
        suffix_size += 1;
        if suffix_size >= 2 && suffix_edges > 0 {
            let dens = suffix_edges.div_ceil(suffix_size - 1);
            best_density = best_density.max(dens);
        }
    }

    ArboricityEstimate { degeneracy, density_lower_bound: best_density, order }
}

/// Orient edges along the peel order (each vertex keeps the neighbors
/// peeled after it): yields out-degree ≤ degeneracy, the standard
/// bounded-out-degree orientation used for O(λ)-style arguments.
pub fn peel_orientation(g: &Graph, est: &ArboricityEstimate) -> Vec<Vec<u32>> {
    let n = g.n();
    let mut rank = vec![0u32; n];
    for (i, &v) in est.order.iter().enumerate() {
        rank[v as usize] = i as u32;
    }
    let mut out = vec![Vec::new(); n];
    for v in 0..n as u32 {
        for &u in g.neighbors(v) {
            if rank[u as usize] > rank[v as usize] {
                out[v as usize].push(u);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{clique, grid, lambda_arboric, random_tree, star};
    use crate::util::rng::Rng;

    #[test]
    fn tree_estimates() {
        let mut rng = Rng::new(1);
        let t = random_tree(500, &mut rng);
        let est = estimate_arboricity(&t);
        assert_eq!(est.degeneracy, 1);
        assert_eq!(est.density_lower_bound, 1);
        assert_eq!(est.bounds(), (1, 1));
    }

    #[test]
    fn clique_estimates() {
        // K_k: degeneracy k-1, arboricity ⌈k/2⌉.
        let g = clique(8);
        let est = estimate_arboricity(&g);
        assert_eq!(est.degeneracy, 7);
        assert_eq!(est.density_lower_bound, 4); // 28 / 7 = 4
    }

    #[test]
    fn grid_estimates() {
        let g = grid(10, 10);
        let est = estimate_arboricity(&g);
        assert_eq!(est.degeneracy, 2);
        assert!(est.density_lower_bound >= 1 && est.density_lower_bound <= 2);
    }

    #[test]
    fn star_is_one_arboric() {
        let est = estimate_arboricity(&star(50));
        assert_eq!(est.degeneracy, 1);
        assert_eq!(est.density_lower_bound, 1);
    }

    #[test]
    fn lambda_arboric_sandwich() {
        let mut rng = Rng::new(7);
        for lambda in [1usize, 2, 3, 5] {
            let g = lambda_arboric(400, lambda, &mut rng);
            let est = estimate_arboricity(&g);
            let (lo, hi) = est.bounds();
            assert!(lo <= lambda, "density lb {lo} exceeds construction λ {lambda}");
            assert!(hi >= lambda.min(2), "degeneracy {hi} too small for λ {lambda}");
            assert!(hi <= 2 * lambda, "degeneracy {hi} above 2λ for λ {lambda}");
        }
    }

    #[test]
    fn orientation_bounded_by_degeneracy() {
        let mut rng = Rng::new(9);
        let g = lambda_arboric(300, 3, &mut rng);
        let est = estimate_arboricity(&g);
        let orient = peel_orientation(&g, &est);
        let max_out = orient.iter().map(|o| o.len()).max().unwrap();
        assert!(max_out <= est.degeneracy);
        // Orientation covers each edge exactly once.
        let total: usize = orient.iter().map(|o| o.len()).sum();
        assert_eq!(total, g.m());
    }

    #[test]
    fn empty_graph_ok() {
        let est = estimate_arboricity(&Graph::empty(0));
        assert_eq!(est.bounds(), (0, 0));
        let est1 = estimate_arboricity(&Graph::empty(5));
        assert_eq!(est1.degeneracy, 0);
    }
}
