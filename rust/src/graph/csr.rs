//! Immutable CSR (compressed sparse row) undirected graph.
//!
//! This is the positive-edge graph `(V, E+)` of the paper's complete signed
//! graph; negative edges are implicit (every non-adjacent vertex pair).
//! Vertices are `u32` ids in `[0, n)`.  Every undirected edge {u, v} is
//! stored twice (u→v and v→u); `m()` reports undirected edge count.

/// CSR undirected graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    /// `offsets[v]..offsets[v+1]` indexes `neighbors` for vertex v.
    offsets: Vec<usize>,
    /// Concatenated sorted adjacency lists.
    neighbors: Vec<u32>,
}

impl Graph {
    /// Build from an undirected edge list. Self-loops are rejected,
    /// duplicate edges are deduplicated.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Graph {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(u, v) in edges {
            assert!(u != v, "self-loop {u}");
            assert!((u as usize) < n && (v as usize) < n, "edge ({u},{v}) out of range n={n}");
            adj[u as usize].push(v);
            adj[v as usize].push(u);
        }
        let mut offsets = Vec::with_capacity(n + 1);
        let mut neighbors = Vec::new();
        offsets.push(0);
        for list in &mut adj {
            list.sort_unstable();
            list.dedup();
            neighbors.extend_from_slice(list);
            offsets.push(neighbors.len());
        }
        Graph { offsets, neighbors }
    }

    /// Build directly from CSR parts (used by generators that already
    /// produce sorted unique adjacency).
    pub fn from_csr(offsets: Vec<usize>, neighbors: Vec<u32>) -> Graph {
        assert!(!offsets.is_empty());
        assert_eq!(*offsets.last().unwrap(), neighbors.len());
        Graph { offsets, neighbors }
    }

    /// Empty graph on n vertices.
    pub fn empty(n: usize) -> Graph {
        Graph { offsets: vec![0; n + 1], neighbors: Vec::new() }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.neighbors.len() / 2
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        &self.neighbors[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    /// Adjacency test via binary search (lists are sorted).
    #[inline]
    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n() as u32).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Iterator over undirected edges (u < v).
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.n() as u32).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .copied()
                .filter(move |&v| u < v)
                .map(move |v| (u, v))
        })
    }

    /// Induced subgraph on `keep`-marked vertices, **preserving vertex
    /// ids** (dropped vertices become isolated).  This matches the paper's
    /// operations (e.g. "remove high-degree vertices", "prefix graph"):
    /// cluster labels must keep referring to original ids.
    pub fn induced_in_place(&self, keep: &[bool]) -> Graph {
        assert_eq!(keep.len(), self.n());
        let n = self.n();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut neighbors = Vec::with_capacity(self.neighbors.len());
        offsets.push(0);
        for v in 0..n as u32 {
            if keep[v as usize] {
                neighbors.extend(
                    self.neighbors(v).iter().copied().filter(|&u| keep[u as usize]),
                );
            }
            offsets.push(neighbors.len());
        }
        Graph { offsets, neighbors }
    }

    /// Compact induced subgraph: relabels kept vertices to `[0, k)`.
    /// Returns the subgraph and the old-id-of-new-id mapping.
    pub fn induced_compact(&self, keep: &[bool]) -> (Graph, Vec<u32>) {
        assert_eq!(keep.len(), self.n());
        let mut new_id = vec![u32::MAX; self.n()];
        let mut old_id = Vec::new();
        for v in 0..self.n() {
            if keep[v] {
                new_id[v] = old_id.len() as u32;
                old_id.push(v as u32);
            }
        }
        let mut offsets = Vec::with_capacity(old_id.len() + 1);
        let mut neighbors = Vec::new();
        offsets.push(0);
        for &old in &old_id {
            neighbors.extend(
                self.neighbors(old)
                    .iter()
                    .copied()
                    .filter(|&u| keep[u as usize])
                    .map(|u| new_id[u as usize]),
            );
            offsets.push(neighbors.len());
        }
        (Graph { offsets, neighbors }, old_id)
    }

    /// Degree histogram (index = degree).
    pub fn degree_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.max_degree() + 1];
        for v in 0..self.n() as u32 {
            h[self.degree(v)] += 1;
        }
        h
    }

    /// Union of two graphs on the same vertex set.
    pub fn union(&self, other: &Graph) -> Graph {
        assert_eq!(self.n(), other.n());
        let mut edges: Vec<(u32, u32)> = self.edges().collect();
        edges.extend(other.edges());
        Graph::from_edges(self.n(), &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_pendant() -> Graph {
        // 0-1, 1-2, 0-2 triangle; 2-3 pendant.
        Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)])
    }

    #[test]
    fn basic_queries() {
        let g = triangle_plus_pendant();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(3), 1);
        assert_eq!(g.max_degree(), 3);
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0));
        assert!(!g.has_edge(0, 3));
    }

    #[test]
    fn dedup_edges() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (0, 1)]);
        assert_eq!(g.m(), 1);
        assert_eq!(g.degree(0), 1);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn rejects_self_loop() {
        Graph::from_edges(2, &[(1, 1)]);
    }

    #[test]
    fn edges_iter_yields_each_once() {
        let g = triangle_plus_pendant();
        let edges: Vec<_> = g.edges().collect();
        assert_eq!(edges.len(), 4);
        for (u, v) in edges {
            assert!(u < v);
        }
    }

    #[test]
    fn induced_in_place_keeps_ids() {
        let g = triangle_plus_pendant();
        let keep = vec![true, false, true, true];
        let sub = g.induced_in_place(&keep);
        assert_eq!(sub.n(), 4);
        assert_eq!(sub.m(), 2); // 0-2 and 2-3
        assert_eq!(sub.degree(1), 0);
        assert!(sub.has_edge(0, 2));
        assert!(!sub.has_edge(0, 1));
    }

    #[test]
    fn induced_compact_relabels() {
        let g = triangle_plus_pendant();
        let keep = vec![true, false, true, true];
        let (sub, old_id) = g.induced_compact(&keep);
        assert_eq!(sub.n(), 3);
        assert_eq!(old_id, vec![0, 2, 3]);
        assert!(sub.has_edge(0, 1)); // old 0-2
        assert!(sub.has_edge(1, 2)); // old 2-3
        assert_eq!(sub.m(), 2);
    }

    #[test]
    fn union_merges() {
        let a = Graph::from_edges(4, &[(0, 1)]);
        let b = Graph::from_edges(4, &[(1, 2), (0, 1)]);
        let u = a.union(&b);
        assert_eq!(u.m(), 2);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::empty(5);
        assert_eq!(g.n(), 5);
        assert_eq!(g.m(), 0);
        assert_eq!(g.max_degree(), 0);
    }

    #[test]
    fn degree_histogram_sums_to_n() {
        let g = triangle_plus_pendant();
        let h = g.degree_histogram();
        assert_eq!(h.iter().sum::<usize>(), 4);
        assert_eq!(h[3], 1);
        assert_eq!(h[1], 1);
        assert_eq!(h[2], 2);
    }
}
