//! Workload generators for every graph family the paper reasons about.
//!
//! The paper's motivation is scale-free / low-arboricity graphs (§1):
//! Barabási–Albert networks, forests (λ=1), planar-like grids, and
//! adversarial tightness instances (barbell of Remark 33, P4 of Remark 30).
//! The λ-arboric family is generated *by construction* as a union of λ
//! random forests, which has arboricity ≤ λ by Nash-Williams.
//!
//! **Determinism contract:** every generator is a pure, single-threaded
//! function of its parameters and the [`Rng`] stream it is handed — the
//! same seed and parameters produce the bit-identical [`Graph`] on every
//! platform and at any shard count (generators never consult thread
//! identity, time, or global state).  `data::corpus` addresses the
//! families by string spec on this basis, and `tests/data_io.rs` pins
//! the contract by regenerating the corpus on 1/2/8-shard pools.
//!
//! Edge-count arithmetic uses checked/saturating `usize` ops: capacity
//! hints saturate (a short hint only costs a realloc), while vertex- and
//! pair-count computations that index memory are `checked_*` with a
//! named panic instead of a silent release-mode wraparound.

use crate::graph::csr::Graph;
use crate::util::rng::Rng;

/// Uniform random labelled tree on n vertices via a random Prüfer sequence.
pub fn random_tree(n: usize, rng: &mut Rng) -> Graph {
    match n {
        0 => return Graph::empty(0),
        1 => return Graph::empty(1),
        2 => return Graph::from_edges(2, &[(0, 1)]),
        _ => {}
    }
    let seq: Vec<u32> = (0..n - 2).map(|_| rng.index(n) as u32).collect();
    prufer_to_tree(n, &seq)
}

/// Decode a Prüfer sequence into its tree.
pub fn prufer_to_tree(n: usize, seq: &[u32]) -> Graph {
    assert_eq!(seq.len(), n - 2);
    let mut degree = vec![1u32; n];
    for &s in seq {
        degree[s as usize] += 1;
    }
    // Min-heap of current leaves.
    let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<u32>> = (0..n as u32)
        .filter(|&v| degree[v as usize] == 1)
        .map(std::cmp::Reverse)
        .collect();
    let mut edges = Vec::with_capacity(n - 1);
    for &s in seq {
        let std::cmp::Reverse(leaf) = heap.pop().expect("prufer decode underflow");
        edges.push((leaf, s));
        degree[s as usize] -= 1;
        if degree[s as usize] == 1 {
            heap.push(std::cmp::Reverse(s));
        }
    }
    let std::cmp::Reverse(a) = heap.pop().unwrap();
    let std::cmp::Reverse(b) = heap.pop().unwrap();
    edges.push((a, b));
    Graph::from_edges(n, &edges)
}

/// Random forest: a random tree with each edge kept with probability
/// `keep_p` (keep_p = 1 gives a spanning tree).
pub fn random_forest(n: usize, keep_p: f64, rng: &mut Rng) -> Graph {
    let tree = random_tree(n, rng);
    let edges: Vec<(u32, u32)> = tree.edges().filter(|_| rng.bernoulli(keep_p)).collect();
    Graph::from_edges(n, &edges)
}

/// λ-arboric graph by construction: union of `lambda` random spanning
/// trees (arboricity ≤ λ by Nash–Williams decomposition; ≥ λ w.h.p. for
/// n large since the union has ~λ(n-1) distinct edges).
pub fn lambda_arboric(n: usize, lambda: usize, rng: &mut Rng) -> Graph {
    assert!(lambda >= 1);
    let mut g = random_tree(n, rng);
    for _ in 1..lambda {
        g = g.union(&random_tree(n, rng));
    }
    g
}

/// Barabási–Albert preferential attachment: each new vertex attaches to
/// `m_attach` existing vertices sampled proportionally to degree.
/// Arboricity ≤ m_attach (edges orient from newer to older endpoint with
/// out-degree m_attach), while the maximum degree grows like sqrt(n) —
/// exactly the "few high degree nodes, small average degree" regime the
/// paper targets.
pub fn barabasi_albert(n: usize, m_attach: usize, rng: &mut Rng) -> Graph {
    assert!(m_attach >= 1 && n > m_attach);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n.saturating_mul(m_attach));
    // Repeated-endpoint urn: sampling a uniform entry of `urn` is
    // degree-proportional sampling.
    let mut urn: Vec<u32> = Vec::with_capacity(n.saturating_mul(m_attach).saturating_mul(2));
    // Seed: star on m_attach + 1 vertices.
    for v in 0..m_attach as u32 {
        edges.push((v, m_attach as u32));
        urn.push(v);
        urn.push(m_attach as u32);
    }
    for v in (m_attach + 1) as u32..n as u32 {
        // Insertion-ordered distinct targets (a Vec, not a HashSet: the
        // set's randomized iteration order leaked into the urn layout and
        // made the generator nondeterministic across identical seeds —
        // the determinism contract above forbids that, and m_attach is
        // small enough that linear `contains` wins anyway).
        let mut targets: Vec<u32> = Vec::with_capacity(m_attach);
        let mut guard = 0;
        while targets.len() < m_attach {
            let t = urn[rng.index(urn.len())];
            if !targets.contains(&t) {
                targets.push(t);
            }
            guard += 1;
            if guard > m_attach.saturating_mul(100) {
                // Degenerate small graphs: fall back to uniform fill.
                for u in 0..v {
                    if targets.len() >= m_attach {
                        break;
                    }
                    if !targets.contains(&u) {
                        targets.push(u);
                    }
                }
            }
        }
        for &t in &targets {
            edges.push((v, t));
            urn.push(v);
            urn.push(t);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Erdős–Rényi G(n, p) — used as a *non*-bounded-arboricity contrast
/// workload (its arboricity is Θ(np) for p above the connectivity
/// threshold).
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Rng) -> Graph {
    // Geometric skipping for sparse p.
    let mut edges = Vec::new();
    if p <= 0.0 {
        return Graph::empty(n);
    }
    if p >= 1.0 {
        for u in 0..n as u32 {
            for v in u + 1..n as u32 {
                edges.push((u, v));
            }
        }
        return Graph::from_edges(n, &edges);
    }
    let log1p = (1.0 - p).ln();
    let total_pairs = pair_count(n);
    let mut idx: i64 = -1;
    loop {
        let r = rng.f64().max(f64::MIN_POSITIVE);
        let skip = (r.ln() / log1p).floor() as i64 + 1;
        idx += skip.max(1);
        if idx as usize >= total_pairs {
            break;
        }
        let (u, v) = pair_from_index(n, idx as usize);
        edges.push((u, v));
    }
    Graph::from_edges(n, &edges)
}

/// `n choose 2`, checked: the geometric-skipping samplers linearize the
/// pair space into a usize index, so a wraparound here would silently
/// truncate the sample space in release builds.
fn pair_count(n: usize) -> usize {
    if n < 2 {
        return 0;
    }
    n.checked_mul(n - 1).map(|x| x / 2).expect("pair count n*(n-1)/2 overflows usize")
}

/// Map a linear index to the (u, v) pair with u < v (row-major upper
/// triangle).
fn pair_from_index(n: usize, mut idx: usize) -> (u32, u32) {
    for u in 0..n - 1 {
        let row = n - 1 - u;
        if idx < row {
            return (u as u32, (u + 1 + idx) as u32);
        }
        idx -= row;
    }
    unreachable!("pair index out of range");
}

/// w×h grid graph — planar, arboricity ≤ 2, unbounded Δ=4 contrast.
pub fn grid(w: usize, h: usize) -> Graph {
    let n = w.checked_mul(h).expect("grid: w*h overflows usize");
    let mut edges = Vec::with_capacity(n.saturating_mul(2));
    let id = |x: usize, y: usize| {
        y.checked_mul(w).and_then(|yw| yw.checked_add(x)).expect("id < n = w*h, checked") as u32
    };
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w {
                edges.push((id(x, y), id(x + 1, y)));
            }
            if y + 1 < h {
                edges.push((id(x, y), id(x, y + 1)));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// 2×k ladder: two parallel k-paths ("rails") plus the k rungs between
/// them.  Planar, arboricity ≤ 2, Δ = 3 — the bounded-everything
/// contrast workload; `with_flip_noise` perturbs it into the adversarial
/// near-ladder family of the corpus.
pub fn ladder(k: usize) -> Graph {
    let n = k.checked_mul(2).expect("ladder: 2k overflows usize");
    let mut edges = Vec::with_capacity(k.saturating_mul(3));
    for i in 0..k as u32 {
        edges.push((2 * i, 2 * i + 1)); // rung
        if (i as usize) + 1 < k {
            edges.push((2 * i, 2 * i + 2)); // left rail
            edges.push((2 * i + 1, 2 * i + 3)); // right rail
        }
    }
    Graph::from_edges(n, &edges)
}

/// Edge flip noise: each positive edge is dropped with probability `p`,
/// and for each original edge a uniformly random non-loop pair is added
/// with probability `p` — the expected edge count is preserved while the
/// clean structure (forest, ladder, …) is adversarially perturbed.
/// `p = 0` returns the graph unchanged without consuming any randomness.
pub fn with_flip_noise(g: &Graph, p: f64, rng: &mut Rng) -> Graph {
    assert!((0.0..=1.0).contains(&p), "flip probability {p} outside [0,1]");
    let n = g.n();
    if p <= 0.0 || n < 2 {
        return g.clone();
    }
    let mut edges: Vec<(u32, u32)> = g.edges().filter(|_| !rng.bernoulli(p)).collect();
    for _ in 0..g.m() {
        if rng.bernoulli(p) {
            loop {
                let u = rng.index(n) as u32;
                let v = rng.index(n) as u32;
                if u != v {
                    edges.push((u, v));
                    break;
                }
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Complete graph K_k.
pub fn clique(k: usize) -> Graph {
    let mut edges = Vec::with_capacity(k.saturating_mul(k.saturating_sub(1)) / 2);
    for u in 0..k as u32 {
        for v in u + 1..k as u32 {
            edges.push((u, v));
        }
    }
    Graph::from_edges(k, &edges)
}

/// Disjoint union of `count` cliques of size `k` each.
pub fn disjoint_cliques(count: usize, k: usize) -> Graph {
    let n = count.checked_mul(k).expect("disjoint_cliques: count*k overflows usize");
    let mut edges = Vec::new();
    for c in 0..count {
        let base = c.checked_mul(k).expect("base < n = count*k, checked") as u32;
        for u in 0..k as u32 {
            for v in u + 1..k as u32 {
                edges.push((base + u, base + v));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Remark 33's tightness instance: two K_λ cliques joined by one edge.
/// OPT clusters each clique (1 disagreement); singletons pay ≈ λ².
pub fn barbell(lambda: usize) -> Graph {
    assert!(lambda >= 1);
    let n = 2 * lambda;
    let mut edges = Vec::new();
    for u in 0..lambda as u32 {
        for v in u + 1..lambda as u32 {
            edges.push((u, v));
            edges.push((lambda as u32 + u, lambda as u32 + v));
        }
    }
    edges.push((0, lambda as u32));
    Graph::from_edges(n, &edges)
}

/// Path on n vertices. P4 is Remark 30's maximal-matching tightness case.
pub fn path(n: usize) -> Graph {
    let edges: Vec<(u32, u32)> = (0..n.saturating_sub(1) as u32).map(|i| (i, i + 1)).collect();
    Graph::from_edges(n, &edges)
}

/// Star K_{1,k}: the minimal unbounded-degree forest (λ=1, Δ=k).
pub fn star(k: usize) -> Graph {
    let edges: Vec<(u32, u32)> = (1..=k as u32).map(|v| (0, v)).collect();
    Graph::from_edges(k.checked_add(1).expect("star: k+1 overflows usize"), &edges)
}

/// Caterpillar: a path spine with `legs` pendant vertices per spine vertex.
pub fn caterpillar(spine: usize, legs: usize) -> Graph {
    let n = spine
        .checked_mul(legs)
        .and_then(|x| x.checked_add(spine))
        .expect("caterpillar: spine*(legs+1) overflows usize");
    let mut edges = Vec::new();
    for i in 0..spine.saturating_sub(1) as u32 {
        edges.push((i, i + 1));
    }
    for s in 0..spine as u32 {
        for l in 0..legs as u32 {
            // Leg ids start after the spine block: spine + s·legs + l < n.
            let leg = s
                .checked_mul(legs as u32)
                .and_then(|x| x.checked_add(spine as u32))
                .and_then(|x| x.checked_add(l))
                .expect("caterpillar: vertex id overflows u32");
            edges.push((s, leg));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Planted-partition ("noisy cliques") instance: the community-detection
/// workload correlation clustering is motivated by (§1).  `k` ground-truth
/// communities of size `n/k`; intra-community positive edges appear with
/// probability `p_in`, inter-community with `p_out`.  Returns the graph
/// and the planted labels (ground truth for recovery metrics).
///
/// With p_in close to 1 and small communities this stays low-arboricity;
/// with p_in·(n/k) large it leaves the bounded-arboricity regime — used as
/// the contrast case in the recovery experiment.
pub fn planted_partition(
    n: usize,
    k: usize,
    p_in: f64,
    p_out: f64,
    rng: &mut Rng,
) -> (Graph, Vec<u32>) {
    assert!(k >= 1 && k <= n.max(1));
    let labels: Vec<u32> = (0..n)
        .map(|v| (v.checked_mul(k).expect("planted_partition: v*k overflows usize") / n.max(1)) as u32)
        .collect();
    let mut edges = Vec::new();
    // Dense sampling within communities (they are small), geometric
    // skipping across communities (p_out is tiny).
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (v, &l) in labels.iter().enumerate() {
        members[l as usize].push(v as u32);
    }
    for comm in &members {
        for (i, &u) in comm.iter().enumerate() {
            for &v in &comm[i + 1..] {
                if rng.bernoulli(p_in) {
                    edges.push((u, v));
                }
            }
        }
    }
    if p_out > 0.0 {
        // Sample inter-community pairs by rejection over all pairs; for
        // small p_out this is efficient via geometric skipping on the
        // linearized pair index.
        let total_pairs = pair_count(n);
        let log1p = (1.0 - p_out).ln();
        let mut idx: i64 = -1;
        loop {
            let r = rng.f64().max(f64::MIN_POSITIVE);
            let skip = (r.ln() / log1p).floor() as i64 + 1;
            idx += skip.max(1);
            if idx as usize >= total_pairs {
                break;
            }
            let (u, v) = pair_from_index(n, idx as usize);
            if labels[u as usize] != labels[v as usize] {
                edges.push((u, v));
            }
        }
    }
    (Graph::from_edges(n, &edges), labels)
}

/// Disjoint union of arbitrary parts: part `i`'s vertices are offset by
/// the total size of parts `0..i`, with no cross edges.  The
/// multi-component workload builder behind the solve engine's
/// per-component decomposition tests and benchmarks.
pub fn disjoint_union(parts: &[Graph]) -> Graph {
    let n: usize = parts.iter().fold(0usize, |acc, g| {
        acc.checked_add(g.n()).expect("disjoint_union: total n overflows usize")
    });
    assert!(n <= u32::MAX as usize, "disjoint_union: {n} vertices exceed the u32 id space");
    let mut edges = Vec::new();
    let mut base = 0u32;
    for g in parts {
        edges.extend(g.edges().map(|(u, v)| (base + u, base + v)));
        base += g.n() as u32;
    }
    Graph::from_edges(n, &edges)
}

/// A named workload registry used by the bench harness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Forest,
    LambdaArboric(usize),
    BarabasiAlbert(usize),
    Grid,
    Path,
    Star,
    Barbell(usize),
    DisjointCliques(usize),
}

impl Family {
    pub fn name(&self) -> String {
        match self {
            Family::Forest => "forest".into(),
            Family::LambdaArboric(l) => format!("arboric-{l}"),
            Family::BarabasiAlbert(m) => format!("ba-{m}"),
            Family::Grid => "grid".into(),
            Family::Path => "path".into(),
            Family::Star => "star".into(),
            Family::Barbell(l) => format!("barbell-{l}"),
            Family::DisjointCliques(k) => format!("cliques-{k}"),
        }
    }

    /// Generate an instance with ~n vertices.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Graph {
        match *self {
            Family::Forest => random_forest(n, 0.9, rng),
            Family::LambdaArboric(l) => lambda_arboric(n, l, rng),
            Family::BarabasiAlbert(m) => barabasi_albert(n.max(m + 2), m, rng),
            Family::Grid => {
                let side = (n as f64).sqrt().ceil() as usize;
                grid(side.max(2), side.max(2))
            }
            Family::Path => path(n),
            Family::Star => star(n.saturating_sub(1).max(1)),
            Family::Barbell(l) => barbell(l),
            Family::DisjointCliques(k) => disjoint_cliques((n / k).max(1), k),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components::components;

    #[test]
    fn random_tree_is_tree() {
        let mut rng = Rng::new(1);
        for n in [1usize, 2, 3, 10, 100] {
            let t = random_tree(n, &mut rng);
            assert_eq!(t.n(), n);
            if n > 0 {
                assert_eq!(t.m(), n - 1);
                let comp = components(&t);
                assert_eq!(comp.count, 1, "tree on {n} vertices must be connected");
            }
        }
    }

    #[test]
    fn prufer_known_decode() {
        // Sequence [3, 3] on n=4 gives star at 3 plus edge: edges (0,3),(1,3),(2,3).
        let t = prufer_to_tree(4, &[3, 3]);
        assert_eq!(t.m(), 3);
        assert_eq!(t.degree(3), 3);
    }

    #[test]
    fn lambda_arboric_edge_budget() {
        let mut rng = Rng::new(2);
        let g = lambda_arboric(200, 3, &mut rng);
        assert!(g.m() <= 3 * 199);
        assert!(g.m() > 199, "union of 3 trees should exceed one tree");
    }

    #[test]
    fn ba_has_right_edge_count_and_skew() {
        let mut rng = Rng::new(3);
        let n = 2000;
        let m_attach = 3;
        let g = barabasi_albert(n, m_attach, &mut rng);
        assert_eq!(g.n(), n);
        // m_attach seed edges + m_attach per subsequent vertex.
        assert!(g.m() <= m_attach + (n - m_attach - 1) * m_attach);
        // Scale-free skew: max degree well above average.
        let avg = 2.0 * g.m() as f64 / n as f64;
        assert!(g.max_degree() as f64 > 4.0 * avg, "BA should have hubs");
    }

    #[test]
    fn er_density_close_to_p() {
        let mut rng = Rng::new(4);
        let n = 300;
        let p = 0.02;
        let g = erdos_renyi(n, p, &mut rng);
        let expected = p * (n * (n - 1) / 2) as f64;
        assert!((g.m() as f64) > expected * 0.7 && (g.m() as f64) < expected * 1.3);
    }

    #[test]
    fn er_extremes() {
        let mut rng = Rng::new(5);
        assert_eq!(erdos_renyi(10, 0.0, &mut rng).m(), 0);
        assert_eq!(erdos_renyi(10, 1.0, &mut rng).m(), 45);
    }

    #[test]
    fn pair_from_index_bijective() {
        let n = 7;
        let mut seen = std::collections::HashSet::new();
        for idx in 0..n * (n - 1) / 2 {
            let (u, v) = pair_from_index(n, idx);
            assert!(u < v && (v as usize) < n);
            assert!(seen.insert((u, v)));
        }
    }

    #[test]
    fn grid_counts() {
        let g = grid(4, 3);
        assert_eq!(g.n(), 12);
        assert_eq!(g.m(), 3 * 3 + 4 * 2); // horizontal + vertical
        assert_eq!(g.max_degree(), 4);
    }

    #[test]
    fn barbell_shape() {
        let g = barbell(5);
        assert_eq!(g.n(), 10);
        assert_eq!(g.m(), 2 * 10 + 1);
        assert_eq!(g.degree(0), 5); // clique (4) + bridge (1)
    }

    #[test]
    fn ladder_shape() {
        let g = ladder(5);
        assert_eq!(g.n(), 10);
        assert_eq!(g.m(), 5 + 2 * 4); // rungs + two rails
        assert_eq!(g.max_degree(), 3);
        assert_eq!(components(&g).count, 1);
        assert_eq!(ladder(0).n(), 0);
        assert_eq!(ladder(1).m(), 1);
    }

    #[test]
    fn flip_noise_perturbs_but_zero_is_identity() {
        let mut rng = Rng::new(17);
        let g = ladder(50);
        // p = 0: bit-identical, no randomness consumed.
        let mut before = rng.clone();
        let same = with_flip_noise(&g, 0.0, &mut rng);
        assert_eq!(same, g);
        assert_eq!(rng.next_u64(), before.next_u64(), "p=0 must not consume rng");
        // p = 0.3: expected edge count preserved within slack, structure changed.
        let noisy = with_flip_noise(&g, 0.3, &mut rng);
        assert_eq!(noisy.n(), g.n());
        assert_ne!(noisy, g);
        let (lo, hi) = (g.m() * 6 / 10, g.m() * 14 / 10);
        assert!((lo..=hi).contains(&noisy.m()), "m {} vs original {}", noisy.m(), g.m());
        // Determinism: same seed stream, same perturbation.
        let a = with_flip_noise(&g, 0.3, &mut Rng::new(99));
        let b = with_flip_noise(&g, 0.3, &mut Rng::new(99));
        assert_eq!(a, b);
    }

    #[test]
    fn generators_are_seed_deterministic() {
        // The determinism contract in the module doc: same seed + params
        // ⇒ identical graph (CSR equality), for every seeded family.
        for seed in [1u64, 42, 0xDEAD] {
            assert_eq!(
                random_tree(60, &mut Rng::new(seed)),
                random_tree(60, &mut Rng::new(seed))
            );
            assert_eq!(
                lambda_arboric(60, 3, &mut Rng::new(seed)),
                lambda_arboric(60, 3, &mut Rng::new(seed))
            );
            assert_eq!(
                barabasi_albert(60, 2, &mut Rng::new(seed)),
                barabasi_albert(60, 2, &mut Rng::new(seed))
            );
            assert_eq!(
                erdos_renyi(60, 0.05, &mut Rng::new(seed)),
                erdos_renyi(60, 0.05, &mut Rng::new(seed))
            );
            let a = planted_partition(60, 6, 0.9, 0.02, &mut Rng::new(seed));
            let b = planted_partition(60, 6, 0.9, 0.02, &mut Rng::new(seed));
            assert_eq!(a, b);
        }
    }

    #[test]
    fn clique_star_path_caterpillar() {
        assert_eq!(clique(6).m(), 15);
        assert_eq!(star(7).max_degree(), 7);
        assert_eq!(path(5).m(), 4);
        let cat = caterpillar(4, 2);
        assert_eq!(cat.n(), 12);
        assert_eq!(cat.m(), 3 + 8);
    }

    #[test]
    fn planted_partition_shapes() {
        let mut rng = Rng::new(7);
        let (g, labels) = planted_partition(300, 30, 0.9, 0.001, &mut rng);
        assert_eq!(g.n(), 300);
        assert_eq!(labels.len(), 300);
        // Communities have size 10; intra edges dominate.
        let intra = g.edges().filter(|&(u, v)| labels[u as usize] == labels[v as usize]).count();
        let inter = g.m() - intra;
        assert!(intra > 30 * 30, "intra {intra} too small");
        assert!(inter < intra / 4, "inter {inter} should be sparse vs {intra}");
        // Ground truth labels form k communities.
        let distinct: std::collections::HashSet<u32> = labels.iter().copied().collect();
        assert_eq!(distinct.len(), 30);
    }

    #[test]
    fn planted_partition_extremes() {
        let mut rng = Rng::new(8);
        let (g, labels) = planted_partition(40, 4, 1.0, 0.0, &mut rng);
        // Perfect cliques, no noise: each community is a K10.
        let c = components(&g);
        assert_eq!(c.count, 4);
        assert_eq!(g.m(), 4 * 45);
        let _ = labels;
    }

    #[test]
    fn disjoint_union_offsets_parts() {
        let u = disjoint_union(&[clique(3), path(4), Graph::empty(2)]);
        assert_eq!(u.n(), 9);
        assert_eq!(u.m(), 3 + 3);
        let c = components(&u);
        assert_eq!(c.count, 2 + 2); // K3, P4, two isolated vertices
        assert!(u.has_edge(0, 2)); // inside the clique
        assert!(u.has_edge(3, 4)); // path shifted by 3
        assert!(!u.has_edge(2, 3)); // no cross edges
        assert_eq!(disjoint_union(&[]).n(), 0);
    }

    #[test]
    fn family_generate_smoke() {
        let mut rng = Rng::new(6);
        for fam in [
            Family::Forest,
            Family::LambdaArboric(2),
            Family::BarabasiAlbert(2),
            Family::Grid,
            Family::Path,
            Family::Star,
            Family::Barbell(4),
            Family::DisjointCliques(4),
        ] {
            let g = fam.generate(64, &mut rng);
            assert!(g.n() > 0, "{}", fam.name());
        }
    }
}
