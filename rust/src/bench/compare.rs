//! Baseline diffing for the perf lab: compare two `BENCH_*.json` suite
//! results metric-by-metric with noise-aware thresholds, and report
//! regressions/improvements so `arbocc bench --compare` can gate PRs.
//!
//! The threshold per metric is
//! `max(rel_tolerance·|baseline|, noise_k·max(baseline_mad, current_mad))`
//! — deterministic metrics (round counts, cost ratios at fixed seeds)
//! carry zero noise and get the relative floor, while harness timings
//! carry their measured MAD so a noisy box does not fail the gate.

use std::path::{Path, PathBuf};

use crate::bench::suite::{Direction, SuiteResult, Tier};
use crate::util::json::parse;

/// Comparison thresholds.
#[derive(Debug, Clone)]
pub struct CompareConfig {
    /// Relative floor on the tolerance, as a fraction of the baseline.
    pub rel_tolerance: f64,
    /// Multiplier on the larger of the two MAD noise scales.
    pub noise_k: f64,
}

impl Default for CompareConfig {
    fn default() -> Self {
        CompareConfig { rel_tolerance: 0.10, noise_k: 4.0 }
    }
}

/// Per-metric outcome. `Regression` and `Missing` fail the gate
/// ([`Comparison::gated_failures`]); everything else is informational.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Regression,
    Improvement,
    WithinNoise,
    /// `Direction::Info` metric — diffed for the table, never gated.
    Info,
    /// Metric (or whole scenario) absent from the baseline.
    New,
    /// Metric (or whole scenario) absent from the current run.
    Missing,
}

impl Verdict {
    pub fn name(&self) -> &'static str {
        match self {
            Verdict::Regression => "REGRESSION",
            Verdict::Improvement => "improvement",
            Verdict::WithinNoise => "within noise",
            Verdict::Info => "info",
            Verdict::New => "new",
            Verdict::Missing => "missing",
        }
    }
}

/// One metric's delta between baseline and current run.
#[derive(Debug, Clone)]
pub struct MetricDelta {
    pub scenario: String,
    pub metric: String,
    /// NaN when the metric is `New`.
    pub baseline: f64,
    /// NaN when the metric is `Missing`.
    pub current: f64,
    pub tolerance: f64,
    pub direction: Direction,
    pub verdict: Verdict,
}

impl MetricDelta {
    /// Relative change in percent; NaN when not comparable.
    pub fn delta_pct(&self) -> f64 {
        if !self.baseline.is_finite() || !self.current.is_finite() || self.baseline == 0.0 {
            return f64::NAN;
        }
        100.0 * (self.current - self.baseline) / self.baseline.abs()
    }
}

/// The full diff of two suite results.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub baseline_label: String,
    pub current_label: String,
    pub deltas: Vec<MetricDelta>,
}

impl Comparison {
    pub fn regressions(&self) -> Vec<&MetricDelta> {
        self.deltas.iter().filter(|d| d.verdict == Verdict::Regression).collect()
    }

    pub fn improvements(&self) -> Vec<&MetricDelta> {
        self.deltas.iter().filter(|d| d.verdict == Verdict::Improvement).collect()
    }

    pub fn has_regressions(&self) -> bool {
        self.deltas.iter().any(|d| d.verdict == Verdict::Regression)
    }

    /// Regressions restricted to scenarios whose name contains one of
    /// `filters` — the `--gate` scope. An empty filter list keeps every
    /// regression (the default gate covers the whole suite).
    pub fn gated_regressions(&self, filters: &[String]) -> Vec<&MetricDelta> {
        self.regressions()
            .into_iter()
            .filter(|d| filters.is_empty() || filters.iter().any(|f| d.scenario.contains(f.as_str())))
            .collect()
    }

    /// Everything that must fail the gate within the `--gate` scope:
    /// regressions, plus gated metrics that are `Missing` from the
    /// current run. A metric the baseline had but this run silently
    /// dropped (renamed scenario, deleted metric key, skipped bin) would
    /// otherwise disarm the gate without anyone noticing — absence must
    /// fail loudly, not pass by default.
    pub fn gated_failures(&self, filters: &[String]) -> Vec<&MetricDelta> {
        self.deltas
            .iter()
            .filter(|d| matches!(d.verdict, Verdict::Regression | Verdict::Missing))
            .filter(|d| filters.is_empty() || filters.iter().any(|f| d.scenario.contains(f.as_str())))
            .collect()
    }
}

fn judge(
    baseline: &crate::bench::suite::Metric,
    current: &crate::bench::suite::Metric,
    cfg: &CompareConfig,
) -> (f64, Verdict) {
    if current.direction == Direction::Info {
        return (0.0, Verdict::Info);
    }
    let tolerance = (cfg.rel_tolerance * baseline.value.abs())
        .max(cfg.noise_k * baseline.noise.max(current.noise))
        .max(1e-12);
    let diff = current.value - baseline.value;
    let worse = match current.direction {
        Direction::Higher => -diff,
        Direction::Lower => diff,
        Direction::Info => 0.0,
    };
    let verdict = if worse > tolerance {
        Verdict::Regression
    } else if worse < -tolerance {
        Verdict::Improvement
    } else {
        Verdict::WithinNoise
    };
    (tolerance, verdict)
}

/// Diff `current` against `baseline`.
pub fn compare(baseline: &SuiteResult, current: &SuiteResult, cfg: &CompareConfig) -> Comparison {
    let mut deltas = Vec::new();
    for cs in &current.scenarios {
        let bs = baseline.find(&cs.name);
        for (key, cm) in &cs.metrics {
            match bs.and_then(|b| b.metrics.get(key)) {
                None => deltas.push(MetricDelta {
                    scenario: cs.name.clone(),
                    metric: key.clone(),
                    baseline: f64::NAN,
                    current: cm.value,
                    tolerance: 0.0,
                    direction: cm.direction,
                    verdict: Verdict::New,
                }),
                Some(bm) => {
                    let (tolerance, verdict) = judge(bm, cm, cfg);
                    deltas.push(MetricDelta {
                        scenario: cs.name.clone(),
                        metric: key.clone(),
                        baseline: bm.value,
                        current: cm.value,
                        tolerance,
                        direction: cm.direction,
                        verdict,
                    });
                }
            }
        }
        // Metrics the baseline had but this run dropped.
        if let Some(b) = bs {
            for (key, bm) in &b.metrics {
                if !cs.metrics.contains_key(key) {
                    deltas.push(MetricDelta {
                        scenario: cs.name.clone(),
                        metric: key.clone(),
                        baseline: bm.value,
                        current: f64::NAN,
                        tolerance: 0.0,
                        direction: bm.direction,
                        verdict: Verdict::Missing,
                    });
                }
            }
        }
    }
    // Scenarios the baseline had but this run dropped entirely.
    for bs in &baseline.scenarios {
        if current.find(&bs.name).is_none() {
            for (key, bm) in &bs.metrics {
                deltas.push(MetricDelta {
                    scenario: bs.name.clone(),
                    metric: key.clone(),
                    baseline: bm.value,
                    current: f64::NAN,
                    tolerance: 0.0,
                    direction: bm.direction,
                    verdict: Verdict::Missing,
                });
            }
        }
    }
    Comparison {
        baseline_label: baseline.label.clone(),
        current_label: current.label.clone(),
        deltas,
    }
}

/// Load a `BENCH_*.json` into a [`SuiteResult`].
pub fn load(path: &Path) -> Result<SuiteResult, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    let json = parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))?;
    SuiteResult::from_json(&json)
}

fn same_path(a: &Path, b: &Path) -> bool {
    match (a.canonicalize(), b.canonicalize()) {
        (Ok(x), Ok(y)) => x == y,
        _ => a == b,
    }
}

/// Natural-order sort key: digit runs compare numerically, so on an
/// mtime tie (e.g. a fresh checkout) `BENCH_PR10.json` sorts after
/// `BENCH_PR9.json` instead of before it.
fn natural_key(s: &str) -> Vec<(bool, u64, String)> {
    let mut out = Vec::new();
    let mut chars = s.chars().peekable();
    while let Some(&c) = chars.peek() {
        if c.is_ascii_digit() {
            let mut n = 0u64;
            while let Some(d) = chars.peek().and_then(|d| d.to_digit(10)) {
                n = n.saturating_mul(10).saturating_add(d as u64);
                chars.next();
            }
            out.push((true, n, String::new()));
        } else {
            let mut text = String::new();
            while let Some(&d) = chars.peek() {
                if d.is_ascii_digit() {
                    break;
                }
                text.push(d);
                chars.next();
            }
            out.push((false, 0, text));
        }
    }
    out
}

/// The most recent `BENCH_*.json` in `dir` (by modification time, then
/// natural name order), excluding the file a fresh run just wrote.
/// Unparseable and partial (`--filter` / single-bin) files never
/// qualify; when `tier` is given, only baselines recorded at that tier
/// do — smoke and full runs use ~10× different workload sizes under
/// the same metric names, so diffing across tiers would produce
/// spurious verdicts.
pub fn find_previous_baseline(
    dir: &Path,
    exclude: Option<&Path>,
    tier: Option<Tier>,
) -> Option<PathBuf> {
    let entries = std::fs::read_dir(dir).ok()?;
    let mut candidates: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|s| s.to_str())
                .map(|s| s.starts_with("BENCH_") && s.ends_with(".json"))
                .unwrap_or(false)
        })
        .filter(|p| match exclude {
            Some(x) => !same_path(p, x),
            None => true,
        })
        .filter(|p| {
            load(p)
                .map(|s| !s.partial && tier.map(|t| s.tier == t).unwrap_or(true))
                .unwrap_or(false)
        })
        .collect();
    candidates.sort_by_key(|p| {
        let name = p.file_name().and_then(|s| s.to_str()).unwrap_or("").to_string();
        (std::fs::metadata(p).and_then(|m| m.modified()).ok(), natural_key(&name))
    });
    candidates.pop()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::suite::{Metric, SuiteScenarioResult, Tier};
    use std::collections::BTreeMap;

    fn suite(label: &str, metrics: &[(&str, f64, f64, Direction)]) -> SuiteResult {
        let mut map = BTreeMap::new();
        for (k, value, noise, direction) in metrics {
            map.insert(
                k.to_string(),
                Metric { value: *value, noise: *noise, direction: *direction },
            );
        }
        SuiteResult {
            label: label.to_string(),
            tier: Tier::Smoke,
            partial: false,
            scenarios: vec![SuiteScenarioResult {
                name: "demo/scenario".to_string(),
                bin: "demo".to_string(),
                wall_s: 1.0,
                metrics: map,
            }],
        }
    }

    fn verdict_of(cmp: &Comparison, metric: &str) -> Verdict {
        cmp.deltas.iter().find(|d| d.metric == metric).unwrap().verdict
    }

    #[test]
    fn detects_regressions_both_directions() {
        let old = suite(
            "old",
            &[
                ("throughput", 100.0, 0.0, Direction::Higher),
                ("latency", 10.0, 0.0, Direction::Lower),
            ],
        );
        let new = suite(
            "new",
            &[
                ("throughput", 50.0, 0.0, Direction::Higher),
                ("latency", 20.0, 0.0, Direction::Lower),
            ],
        );
        let cmp = compare(&old, &new, &CompareConfig::default());
        assert_eq!(verdict_of(&cmp, "throughput"), Verdict::Regression);
        assert_eq!(verdict_of(&cmp, "latency"), Verdict::Regression);
        assert!(cmp.has_regressions());
        assert_eq!(cmp.regressions().len(), 2);
    }

    #[test]
    fn detects_improvements_and_within_noise() {
        let old = suite(
            "old",
            &[
                ("throughput", 100.0, 0.0, Direction::Higher),
                ("latency", 10.0, 0.0, Direction::Lower),
            ],
        );
        let new = suite(
            "new",
            &[
                ("throughput", 104.0, 0.0, Direction::Higher), // +4% < 10% floor
                ("latency", 5.0, 0.0, Direction::Lower),       // halved
            ],
        );
        let cmp = compare(&old, &new, &CompareConfig::default());
        assert_eq!(verdict_of(&cmp, "throughput"), Verdict::WithinNoise);
        assert_eq!(verdict_of(&cmp, "latency"), Verdict::Improvement);
        assert!(!cmp.has_regressions());
        assert_eq!(cmp.improvements().len(), 1);
    }

    #[test]
    fn mad_noise_widens_the_gate() {
        // -30% would regress on the 10% floor, but 4×MAD(10) = 40 absorbs it.
        let old = suite("old", &[("throughput", 100.0, 10.0, Direction::Higher)]);
        let new = suite("new", &[("throughput", 70.0, 0.0, Direction::Higher)]);
        let cmp = compare(&old, &new, &CompareConfig::default());
        assert_eq!(verdict_of(&cmp, "throughput"), Verdict::WithinNoise);
        // Beyond 4×MAD it regresses again.
        let worse = suite("new", &[("throughput", 55.0, 0.0, Direction::Higher)]);
        let cmp = compare(&old, &worse, &CompareConfig::default());
        assert_eq!(verdict_of(&cmp, "throughput"), Verdict::Regression);
    }

    #[test]
    fn info_and_new_never_gate_but_missing_fails() {
        let old = suite(
            "old",
            &[
                ("shards", 8.0, 0.0, Direction::Info),
                ("gone", 5.0, 0.0, Direction::Lower),
            ],
        );
        let new = suite(
            "new",
            &[
                ("shards", 2.0, 0.0, Direction::Info),
                ("fresh", 3.0, 0.0, Direction::Lower),
            ],
        );
        let cmp = compare(&old, &new, &CompareConfig::default());
        assert_eq!(verdict_of(&cmp, "shards"), Verdict::Info);
        assert_eq!(verdict_of(&cmp, "fresh"), Verdict::New);
        assert_eq!(verdict_of(&cmp, "gone"), Verdict::Missing);
        // Missing is not a Regression (the delta table distinguishes
        // them) and never reaches gated_regressions ...
        assert!(!cmp.has_regressions());
        assert!(cmp.gated_regressions(&[]).is_empty());
        // ... but it MUST fail the gate: a dropped metric is a silent
        // hole in coverage, not a pass.
        let failures = cmp.gated_failures(&[]);
        assert_eq!(failures.len(), 1);
        assert_eq!(failures[0].metric, "gone");
        assert_eq!(failures[0].verdict, Verdict::Missing);
    }

    #[test]
    fn gated_failures_scope_missing_metrics_like_regressions() {
        // Baseline has a whole scenario the current run renamed away:
        // every one of its metrics is Missing.
        let old = suite("old", &[("x", 1.0, 0.0, Direction::Lower)]);
        let mut new = suite("new", &[("x", 1.0, 0.0, Direction::Lower)]);
        new.scenarios[0].name = "demo/renamed".to_string();
        let cmp = compare(&old, &new, &CompareConfig::default());
        // In scope (empty filter, or a filter matching the *baseline*
        // scenario name) the absence fails the gate.
        assert_eq!(cmp.gated_failures(&[]).len(), 1);
        assert_eq!(cmp.gated_failures(&["demo/scen".to_string()]).len(), 1);
        // Out of scope it is reported but not gated.
        assert!(cmp.gated_failures(&["perf/p8".to_string()]).is_empty());
        // And with nothing missing or regressed, the gate stays green.
        let same = compare(&old, &old, &CompareConfig::default());
        assert!(same.gated_failures(&[]).is_empty());
    }

    #[test]
    fn missing_scenarios_are_reported() {
        let old = suite("old", &[("x", 1.0, 0.0, Direction::Lower)]);
        let mut new = suite("new", &[("x", 1.0, 0.0, Direction::Lower)]);
        new.scenarios[0].name = "demo/renamed".to_string();
        let cmp = compare(&old, &new, &CompareConfig::default());
        let verdicts: Vec<Verdict> = cmp.deltas.iter().map(|d| d.verdict).collect();
        assert!(verdicts.contains(&Verdict::New));
        assert!(verdicts.contains(&Verdict::Missing));
        assert!(!cmp.has_regressions());
    }

    #[test]
    fn gate_filters_scope_regressions_by_scenario() {
        let old = suite("old", &[("latency", 10.0, 0.0, Direction::Lower)]);
        let new = suite("new", &[("latency", 20.0, 0.0, Direction::Lower)]);
        let cmp = compare(&old, &new, &CompareConfig::default());
        assert_eq!(cmp.gated_regressions(&[]).len(), 1);
        assert_eq!(cmp.gated_regressions(&["demo/".to_string()]).len(), 1);
        // Two filters, one matching.
        let filters = vec!["mpc/plane_".to_string(), "demo/scen".to_string()];
        assert_eq!(cmp.gated_regressions(&filters).len(), 1);
        // No filter matches: the regression is reported but not gated.
        assert!(cmp.gated_regressions(&["perf/p8".to_string()]).is_empty());
        assert!(cmp.has_regressions());
    }

    #[test]
    fn delta_pct_handles_edge_cases() {
        let d = MetricDelta {
            scenario: "s".into(),
            metric: "m".into(),
            baseline: 100.0,
            current: 110.0,
            tolerance: 1.0,
            direction: Direction::Higher,
            verdict: Verdict::WithinNoise,
        };
        assert!((d.delta_pct() - 10.0).abs() < 1e-9);
        let nan = MetricDelta { baseline: f64::NAN, ..d };
        assert!(nan.delta_pct().is_nan());
    }

    #[test]
    fn natural_key_orders_pr_numbers() {
        assert!(natural_key("BENCH_PR10.json") > natural_key("BENCH_PR9.json"));
        assert!(natural_key("BENCH_PR9.json") > natural_key("BENCH_PR8.json"));
        assert!(natural_key("BENCH_PR2.json") < natural_key("BENCH_PR10.json"));
        // Text segments still order lexicographically.
        assert!(natural_key("BENCH_a.json") < natural_key("BENCH_b.json"));
    }

    #[test]
    fn baseline_file_round_trip_and_discovery() {
        let dir = std::env::temp_dir().join(format!(
            "arbocc-compare-test-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let old = suite("PR1", &[("x", 1.0, 0.0, Direction::Lower)]);
        let old_path = dir.join("BENCH_PR1.json");
        std::fs::write(&old_path, old.to_json().pretty()).unwrap();
        let fresh_path = dir.join("BENCH_PR2.json");
        std::fs::write(&fresh_path, "{}").unwrap();
        std::fs::write(dir.join("notes.txt"), "ignored").unwrap();

        let loaded = load(&old_path).unwrap();
        assert_eq!(loaded, old);
        let found = find_previous_baseline(&dir, Some(&fresh_path), None).unwrap();
        assert!(same_path(&found, &old_path), "found {}", found.display());
        // Tier-aware discovery: a smoke search finds the smoke baseline,
        // a full search finds nothing (PR1 was recorded at smoke tier).
        let found = find_previous_baseline(&dir, Some(&fresh_path), Some(Tier::Smoke)).unwrap();
        assert!(same_path(&found, &old_path));
        assert!(find_previous_baseline(&dir, Some(&fresh_path), Some(Tier::Full)).is_none());
        // Partial (--filter / single-bin) files never become baselines.
        let mut partial = suite("PARTIAL", &[("x", 1.0, 0.0, Direction::Lower)]);
        partial.partial = true;
        std::fs::write(dir.join("BENCH_ZZZ.json"), partial.to_json().pretty()).unwrap();
        let found = find_previous_baseline(&dir, Some(&fresh_path), Some(Tier::Smoke)).unwrap();
        assert!(same_path(&found, &old_path), "partial file must be skipped");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
