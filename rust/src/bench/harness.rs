//! Timing harness: warmup, adaptive iteration count, robust statistics.
//!
//! The criterion replacement. Usage:
//! ```ignore
//! let m = bench("cost/sparse", || { cost(&g, &c); });
//! println!("{m}");
//! ```

use crate::util::stats;
use crate::util::timer::fmt_duration;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Median absolute deviation (seconds).
    pub mad_s: f64,
    pub min_s: f64,
    pub iterations: usize,
    pub samples: usize,
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10}/iter ± {:>9} (min {:>10}, {} iters × {} samples)",
            self.name,
            fmt_duration(self.median_s),
            fmt_duration(self.mad_s),
            fmt_duration(self.min_s),
            self.iterations,
            self.samples
        )
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Minimum wall-clock time to spend measuring (seconds).
    pub measure_s: f64,
    /// Warmup time (seconds).
    pub warmup_s: f64,
    /// Number of sample groups for the median.
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { measure_s: 0.6, warmup_s: 0.15, samples: 12 }
    }
}

/// Quick preset for heavyweight end-to-end benches.
pub fn quick() -> BenchConfig {
    BenchConfig { measure_s: 0.25, warmup_s: 0.05, samples: 6 }
}

/// Run a benchmark with the default configuration.
pub fn bench<F: FnMut()>(name: &str, f: F) -> Measurement {
    bench_with(name, &BenchConfig::default(), f)
}

/// Run a benchmark.
pub fn bench_with<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> Measurement {
    // Warmup + iteration-count calibration.
    let warm_start = std::time::Instant::now();
    let mut calib_iters = 0usize;
    while warm_start.elapsed().as_secs_f64() < cfg.warmup_s || calib_iters == 0 {
        f();
        calib_iters += 1;
        if calib_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = warm_start.elapsed().as_secs_f64() / calib_iters as f64;
    // Aim each sample group at measure_s / samples.
    let group_target = cfg.measure_s / cfg.samples as f64;
    let iters = ((group_target / per_iter.max(1e-9)).ceil() as usize).max(1);
    // Calibration overshoot guard: for slow closures (per-iter above the
    // group target) `iters` bottoms out at 1 but running all `samples`
    // groups would still cost samples × per_iter — far past the budget.
    // Clamp the total measured time to ~2× measure_s by shrinking the
    // group count instead (fast closures keep all samples: their group
    // estimate is measure_s / samples, so the ratio is 2·samples). Never
    // drop below two groups (when configured for at least two): a single
    // group has MAD 0, which would strip the noise scale from exactly
    // the slowest scenarios.
    let group_est = (iters as f64 * per_iter).max(1e-12);
    let budget = (2.0 * cfg.measure_s).max(group_est);
    let samples = cfg.samples.min(((budget / group_est).floor() as usize).max(2));

    let mut groups = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = std::time::Instant::now();
        for _ in 0..iters {
            f();
        }
        groups.push(t.elapsed().as_secs_f64() / iters as f64);
    }
    Measurement {
        name: name.to_string(),
        median_s: stats::median(&groups),
        mad_s: stats::mad(&groups),
        min_s: stats::min(&groups),
        iterations: iters,
        samples,
    }
}

/// Throughput helper: items/second at the median.
pub fn throughput(m: &Measurement, items_per_iter: f64) -> f64 {
    items_per_iter / m.median_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut x = 0u64;
        let m = bench_with(
            "noop-ish",
            &BenchConfig { measure_s: 0.05, warmup_s: 0.01, samples: 4 },
            || {
                x = x.wrapping_add(std::hint::black_box(1));
            },
        );
        assert!(m.median_s >= 0.0);
        assert!(m.iterations >= 1);
        assert_eq!(m.samples, 4);
    }

    #[test]
    fn slow_closures_respect_the_time_budget() {
        // Per-iter (~25 ms) is over the group target (50 ms / 12), so the
        // full 12 groups would take ~0.3 s against a 0.05 s budget; the
        // clamp must shrink the group count to ≈ 2×measure_s / per_iter.
        let cfg = BenchConfig { measure_s: 0.05, warmup_s: 0.0, samples: 12 };
        let t = std::time::Instant::now();
        let m = bench_with("slow", &cfg, || {
            std::thread::sleep(std::time::Duration::from_millis(25));
        });
        assert_eq!(m.iterations, 1);
        assert!(m.samples >= 1 && m.samples <= 5, "got {} samples", m.samples);
        // Warmup (1 call) + measured groups; generous ceiling for CI noise.
        assert!(t.elapsed().as_secs_f64() < 1.0, "took {:?}", t.elapsed());
    }

    #[test]
    fn fast_closures_keep_all_sample_groups() {
        let cfg = BenchConfig { measure_s: 0.02, warmup_s: 0.005, samples: 6 };
        let mut x = 0u64;
        let m = bench_with("fast", &cfg, || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert_eq!(m.samples, 6);
    }

    #[test]
    fn throughput_inverts_time() {
        let m = Measurement {
            name: "t".into(),
            median_s: 0.5,
            mad_s: 0.0,
            min_s: 0.5,
            iterations: 1,
            samples: 1,
        };
        assert_eq!(throughput(&m, 100.0), 200.0);
    }
}
