//! Shared experiment workloads: named family × size sweeps with
//! deterministic per-cell seeds, so every bench table is regenerated from
//! identical instances.
//!
//! Every cell is also *corpus-addressable*: [`Workload::spec`] renders
//! the equivalent `data::corpus` spec string (pinned to generate the
//! bit-identical graph), so any bench row can be reproduced from a shell
//! with `arbocc gen <spec>` or pointed at the solver engine with
//! `--workload <spec>`.  [`corpus`] is the standard corpus sweep the new
//! data scenarios iterate.

use crate::data::corpus::{sweep_corpus, WorkloadSpec};
use crate::graph::generators::Family;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// One cell of a sweep.
#[derive(Debug, Clone)]
pub struct Workload {
    pub family: Family,
    pub n: usize,
    pub seed: u64,
}

impl Workload {
    pub fn name(&self) -> String {
        format!("{}/n={}", self.family.name(), self.n)
    }

    pub fn generate(&self) -> Graph {
        let mut rng = Rng::new(self.seed);
        self.family.generate(self.n, &mut rng)
    }

    /// RNG stream for algorithm randomness on this workload (decorrelated
    /// from the generator stream).
    pub fn algo_rng(&self, trial: u64) -> Rng {
        Rng::new(self.seed ^ 0xA11C0DE ^ trial.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The `data::corpus` spec string generating the bit-identical graph
    /// (pinned by `workloads_are_corpus_addressable`), so every bench
    /// cell is reproducible by name from the CLI.
    pub fn spec(&self) -> String {
        let (n, seed) = (self.n, self.seed);
        match self.family {
            Family::Forest => format!("forest:n={n},keep=0.9,seed={seed}"),
            Family::LambdaArboric(l) => format!("arboric:n={n},lambda={l},seed={seed}"),
            Family::BarabasiAlbert(m) => format!("powerlaw:n={n},attach={m},seed={seed}"),
            Family::Grid => {
                let side = ((n as f64).sqrt().ceil() as usize).max(2);
                format!("grid:w={side},h={side}")
            }
            Family::Path => format!("path:n={n}"),
            Family::Star => format!("star:k={}", n.saturating_sub(1).max(1)),
            Family::Barbell(l) => format!("barbell:lambda={l}"),
            Family::DisjointCliques(k) => format!("cliques:count={},k={k}", (n / k).max(1)),
        }
    }
}

/// The standard corpus sweep (one spec per structural axis, sized by the
/// caller) as parsed workload specs — what `solve/corpus_sweep` and the
/// dataset example iterate.
pub fn corpus(n: usize, seed: u64) -> Vec<WorkloadSpec> {
    sweep_corpus(n, seed)
        .iter()
        .map(|s| WorkloadSpec::parse(s).expect("sweep_corpus specs always parse"))
        .collect()
}

/// The standard family set for clustering experiments (bounded-arboricity
/// focus of the paper).
pub fn clustering_families() -> Vec<Family> {
    vec![
        Family::Forest,
        Family::LambdaArboric(2),
        Family::LambdaArboric(4),
        Family::LambdaArboric(8),
        Family::BarabasiAlbert(3),
        Family::Grid,
    ]
}

/// Tier a full-scale size ladder: `Full` keeps it, `Smoke` divides each
/// size by 8 and clamps into [512, 16384] (never above the full size),
/// deduplicating while preserving order. Both the scenario registry and
/// ad-hoc bins use this so smoke sweeps stay CI-sized but keep the same
/// shape as the paper-scale tables.
pub fn ladder(tier: crate::bench::suite::Tier, full: &[usize]) -> Vec<usize> {
    match tier {
        crate::bench::suite::Tier::Full => full.to_vec(),
        crate::bench::suite::Tier::Smoke => {
            let mut out: Vec<usize> = Vec::new();
            for &n in full {
                let scaled = (n / 8).clamp(512, 16_384).min(n);
                if !out.contains(&scaled) {
                    out.push(scaled);
                }
            }
            out
        }
    }
}

/// Build a sweep: all families × all sizes, seeds derived from a base.
pub fn sweep(families: &[Family], sizes: &[usize], base_seed: u64) -> Vec<Workload> {
    let mut out = Vec::new();
    for (fi, &family) in families.iter().enumerate() {
        for (si, &n) in sizes.iter().enumerate() {
            out.push(Workload {
                family,
                n,
                seed: base_seed
                    .wrapping_add((fi as u64) << 32)
                    .wrapping_add((si as u64) << 16)
                    .wrapping_add(1),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_deterministic() {
        let s1 = sweep(&clustering_families(), &[100, 1000], 7);
        let s2 = sweep(&clustering_families(), &[100, 1000], 7);
        assert_eq!(s1.len(), s2.len());
        for (a, b) in s1.iter().zip(&s2) {
            assert_eq!(a.seed, b.seed);
            let ga = a.generate();
            let gb = b.generate();
            assert_eq!(ga.n(), gb.n());
            assert_eq!(ga.m(), gb.m());
        }
    }

    #[test]
    fn ladder_tiers() {
        use crate::bench::suite::Tier;
        let full = [2_000usize, 8_000, 32_000, 128_000];
        assert_eq!(ladder(Tier::Full, &full), full.to_vec());
        let smoke = ladder(Tier::Smoke, &full);
        assert_eq!(smoke, vec![512, 1_000, 4_000, 16_000]);
        // Dedup: tiny full sizes collapse onto the 512 floor once.
        assert_eq!(ladder(Tier::Smoke, &[600, 700, 4_096]), vec![512]);
        // Never scale a size *up* past the full value.
        assert!(ladder(Tier::Smoke, &[100]) == vec![100]);
    }

    #[test]
    fn workloads_are_corpus_addressable() {
        // Every Family cell and its corpus spec generate the identical
        // graph — the bridge that makes bench rows reproducible by name.
        let fams = [
            Family::Forest,
            Family::LambdaArboric(3),
            Family::BarabasiAlbert(3),
            Family::Grid,
            Family::Path,
            Family::Star,
            Family::Barbell(6),
            Family::DisjointCliques(5),
        ];
        for family in fams {
            let w = Workload { family, n: 120, seed: 9 };
            let spec = WorkloadSpec::parse(&w.spec()).unwrap_or_else(|e| {
                panic!("{}: {e}", w.spec());
            });
            let direct = w.generate();
            let via_corpus = spec.generate().unwrap();
            assert_eq!(direct, via_corpus, "{}", w.spec());
        }
    }

    #[test]
    fn corpus_sweep_materializes() {
        let specs = corpus(400, 7);
        assert!(specs.len() >= 5);
        let names: std::collections::HashSet<&str> =
            specs.iter().map(|s| s.family()).collect();
        assert_eq!(names.len(), specs.len(), "one spec per family axis");
    }

    #[test]
    fn workload_names_unique() {
        let s = sweep(&clustering_families(), &[64, 256], 3);
        let names: std::collections::HashSet<String> = s.iter().map(|w| w.name()).collect();
        assert_eq!(names.len(), s.len());
    }
}
