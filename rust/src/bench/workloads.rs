//! Shared experiment workloads: named family × size sweeps with
//! deterministic per-cell seeds, so every bench table is regenerated from
//! identical instances.

use crate::graph::generators::Family;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// One cell of a sweep.
#[derive(Debug, Clone)]
pub struct Workload {
    pub family: Family,
    pub n: usize,
    pub seed: u64,
}

impl Workload {
    pub fn name(&self) -> String {
        format!("{}/n={}", self.family.name(), self.n)
    }

    pub fn generate(&self) -> Graph {
        let mut rng = Rng::new(self.seed);
        self.family.generate(self.n, &mut rng)
    }

    /// RNG stream for algorithm randomness on this workload (decorrelated
    /// from the generator stream).
    pub fn algo_rng(&self, trial: u64) -> Rng {
        Rng::new(self.seed ^ 0xA11C0DE ^ trial.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// The standard family set for clustering experiments (bounded-arboricity
/// focus of the paper).
pub fn clustering_families() -> Vec<Family> {
    vec![
        Family::Forest,
        Family::LambdaArboric(2),
        Family::LambdaArboric(4),
        Family::LambdaArboric(8),
        Family::BarabasiAlbert(3),
        Family::Grid,
    ]
}

/// Tier a full-scale size ladder: `Full` keeps it, `Smoke` divides each
/// size by 8 and clamps into [512, 16384] (never above the full size),
/// deduplicating while preserving order. Both the scenario registry and
/// ad-hoc bins use this so smoke sweeps stay CI-sized but keep the same
/// shape as the paper-scale tables.
pub fn ladder(tier: crate::bench::suite::Tier, full: &[usize]) -> Vec<usize> {
    match tier {
        crate::bench::suite::Tier::Full => full.to_vec(),
        crate::bench::suite::Tier::Smoke => {
            let mut out: Vec<usize> = Vec::new();
            for &n in full {
                let scaled = (n / 8).clamp(512, 16_384).min(n);
                if !out.contains(&scaled) {
                    out.push(scaled);
                }
            }
            out
        }
    }
}

/// Build a sweep: all families × all sizes, seeds derived from a base.
pub fn sweep(families: &[Family], sizes: &[usize], base_seed: u64) -> Vec<Workload> {
    let mut out = Vec::new();
    for (fi, &family) in families.iter().enumerate() {
        for (si, &n) in sizes.iter().enumerate() {
            out.push(Workload {
                family,
                n,
                seed: base_seed
                    .wrapping_add((fi as u64) << 32)
                    .wrapping_add((si as u64) << 16)
                    .wrapping_add(1),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_deterministic() {
        let s1 = sweep(&clustering_families(), &[100, 1000], 7);
        let s2 = sweep(&clustering_families(), &[100, 1000], 7);
        assert_eq!(s1.len(), s2.len());
        for (a, b) in s1.iter().zip(&s2) {
            assert_eq!(a.seed, b.seed);
            let ga = a.generate();
            let gb = b.generate();
            assert_eq!(ga.n(), gb.n());
            assert_eq!(ga.m(), gb.m());
        }
    }

    #[test]
    fn ladder_tiers() {
        use crate::bench::suite::Tier;
        let full = [2_000usize, 8_000, 32_000, 128_000];
        assert_eq!(ladder(Tier::Full, &full), full.to_vec());
        let smoke = ladder(Tier::Smoke, &full);
        assert_eq!(smoke, vec![512, 1_000, 4_000, 16_000]);
        // Dedup: tiny full sizes collapse onto the 512 floor once.
        assert_eq!(ladder(Tier::Smoke, &[600, 700, 4_096]), vec![512]);
        // Never scale a size *up* past the full value.
        assert!(ladder(Tier::Smoke, &[100]) == vec![100]);
    }

    #[test]
    fn workload_names_unique() {
        let s = sweep(&clustering_families(), &[64, 256], 3);
        let names: std::collections::HashSet<String> = s.iter().map(|w| w.name()).collect();
        assert_eq!(names.len(), s.len());
    }
}
