//! The perf-lab scenario registry: every bench bin registers named
//! scenarios here, and the `arbocc bench` orchestrator runs them at a
//! `smoke` or `full` tier, collecting domain metrics (edges/s, MPC
//! rounds, cost ratios, shard speedups) into one machine-readable
//! `BENCH_<label>.json` at the repo root.
//!
//! The file is the perf trajectory: `bench::compare` diffs two of them
//! with noise-aware (MAD-based) thresholds and gates regressions, so
//! every scaling PR is judged against the previous baseline instead of
//! free-form stdout tables.
//!
//! Layout:
//!
//! * [`Scenario`] — a named `fn(&ScenarioCtx) -> ScenarioRecord` owned by
//!   one bench bin; the bin itself is a thin wrapper (`run_bin`).
//! * [`Registry::standard`] — all scenarios from `bench::scenarios`.
//! * [`SuiteResult`] — the schema (`arbocc-bench/v1`) with a lossless
//!   JSON round-trip via `util::json`.

use std::collections::BTreeMap;

use crate::bench::harness::{self, BenchConfig, Measurement};
use crate::util::json::Json;
use crate::util::table::fnum;
use crate::util::timer::Timer;

/// Schema tag written into every `BENCH_*.json`.
pub const SCHEMA: &str = "arbocc-bench/v1";

/// Which sweep sizes a run uses. `Smoke` is the CI tier (< ~5 minutes
/// end to end); `Full` reproduces the paper-scale tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Smoke,
    Full,
}

impl Tier {
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Smoke => "smoke",
            Tier::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "smoke" => Some(Tier::Smoke),
            "full" => Some(Tier::Full),
            _ => None,
        }
    }
}

/// Which way a metric is supposed to move. `Info` metrics are recorded
/// and diffed but never gate a comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Higher,
    Lower,
    Info,
}

impl Direction {
    pub fn name(&self) -> &'static str {
        match self {
            Direction::Higher => "higher",
            Direction::Lower => "lower",
            Direction::Info => "info",
        }
    }

    pub fn parse(s: &str) -> Option<Direction> {
        match s {
            "higher" => Some(Direction::Higher),
            "lower" => Some(Direction::Lower),
            "info" => Some(Direction::Info),
            _ => None,
        }
    }
}

/// One recorded number with its noise scale (an absolute MAD-style
/// spread; 0 for deterministic metrics such as simulated round counts).
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub value: f64,
    pub noise: f64,
    pub direction: Direction,
}

/// What a scenario hands back to the orchestrator.
#[derive(Debug, Clone, Default)]
pub struct ScenarioRecord {
    pub metrics: BTreeMap<String, Metric>,
}

impl ScenarioRecord {
    pub fn new() -> ScenarioRecord {
        ScenarioRecord::default()
    }

    /// Record a deterministic metric (noise 0).
    pub fn metric(&mut self, key: &str, value: f64, direction: Direction) -> &mut Self {
        self.metric_with_noise(key, value, 0.0, direction)
    }

    pub fn metric_with_noise(
        &mut self,
        key: &str,
        value: f64,
        noise: f64,
        direction: Direction,
    ) -> &mut Self {
        self.metrics.insert(key.to_string(), Metric { value, noise, direction });
        self
    }

    /// Relative noise floor for wall-clock-derived metrics: even with a
    /// tiny measured MAD (few sample groups), run-to-run variance of
    /// timings on a shared machine rarely drops below a few percent.
    /// Public so scenarios recording hand-rolled timing metrics apply
    /// the same floor as the time/rate/speedup helpers.
    pub const TIMING_REL_NOISE_FLOOR: f64 = 0.05;

    /// Record a harness timing: `<key>_s` with the measurement's MAD
    /// (floored at 5% of the median) as the noise scale.
    pub fn time_metric(&mut self, key: &str, m: &Measurement) -> &mut Self {
        let noise = m.mad_s.max(Self::TIMING_REL_NOISE_FLOOR * m.median_s);
        self.metric_with_noise(&format!("{key}_s"), m.median_s, noise, Direction::Lower)
    }

    /// Record a throughput (items/second) derived from a measurement;
    /// the relative MAD (floored at 5%) carries over as the noise scale.
    pub fn rate_metric(&mut self, key: &str, m: &Measurement, items_per_iter: f64) -> &mut Self {
        let denom = m.median_s.max(1e-12);
        let value = items_per_iter / denom;
        let rel = (m.mad_s / denom).max(Self::TIMING_REL_NOISE_FLOOR);
        self.metric_with_noise(key, value, value * rel, Direction::Higher)
    }

    /// Record `slow/fast` as a speedup (higher is better) with the two
    /// relative MADs (floored at 5% combined) summed into the noise.
    pub fn speedup_metric(
        &mut self,
        key: &str,
        slow: &Measurement,
        fast: &Measurement,
    ) -> &mut Self {
        let s = slow.median_s.max(1e-12);
        let f = fast.median_s.max(1e-12);
        let value = s / f;
        let rel = (slow.mad_s / s + fast.mad_s / f).max(Self::TIMING_REL_NOISE_FLOOR);
        self.metric_with_noise(key, value, value * rel, Direction::Higher)
    }
}

/// Tier-dependent knobs handed to every scenario.
#[derive(Debug, Clone)]
pub struct ScenarioCtx {
    pub tier: Tier,
    /// The CLI's `--workload <spec>` override: corpus-driven scenarios
    /// (`solve/corpus_sweep`) sweep this one instance instead of their
    /// default slice. `None` everywhere else.
    pub workload: Option<String>,
}

impl ScenarioCtx {
    /// Pick a tier-dependent constant (sizes, seed counts, slices, …).
    pub fn pick<T: Copy>(&self, smoke: T, full: T) -> T {
        match self.tier {
            Tier::Smoke => smoke,
            Tier::Full => full,
        }
    }

    pub fn size(&self, smoke: usize, full: usize) -> usize {
        self.pick(smoke, full)
    }

    /// Pick a tier-dependent sweep, returning an owned copy (so callers
    /// can pass inline array literals without borrow gymnastics).
    pub fn sweep<T: Copy>(&self, smoke: &[T], full: &[T]) -> Vec<T> {
        match self.tier {
            Tier::Smoke => smoke.to_vec(),
            Tier::Full => full.to_vec(),
        }
    }

    /// Harness preset for this tier: smoke keeps each measurement to a
    /// fraction of a second, full uses the quick preset the bins used.
    pub fn bench_cfg(&self) -> BenchConfig {
        match self.tier {
            Tier::Smoke => BenchConfig { measure_s: 0.06, warmup_s: 0.02, samples: 4 },
            Tier::Full => harness::quick(),
        }
    }
}

/// A registered scenario. `bin` names the owning bench bin so the thin
/// wrappers in `benches/` can select their slice of the registry.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub name: &'static str,
    pub bin: &'static str,
    pub about: &'static str,
    pub run: fn(&ScenarioCtx) -> ScenarioRecord,
}

/// One scenario's row in a [`SuiteResult`].
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteScenarioResult {
    pub name: String,
    pub bin: String,
    pub wall_s: f64,
    pub metrics: BTreeMap<String, Metric>,
}

/// A whole suite run — what `BENCH_<label>.json` serializes.
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteResult {
    pub label: String,
    pub tier: Tier,
    /// True when the run covered only a subset of the registry (a
    /// `--filter` run or a single bin). Partial files are never picked
    /// as baselines by `compare::find_previous_baseline` — a missing
    /// scenario would silently un-gate everything it lacks.
    pub partial: bool,
    pub scenarios: Vec<SuiteScenarioResult>,
}

impl SuiteResult {
    pub fn find(&self, name: &str) -> Option<&SuiteScenarioResult> {
        self.scenarios.iter().find(|s| s.name == name)
    }

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("schema", Json::str(SCHEMA))
            .set("label", Json::str(self.label.clone()))
            .set("tier", Json::str(self.tier.name()))
            .set("partial", Json::Bool(self.partial));
        let mut arr = Json::Arr(Vec::new());
        for s in &self.scenarios {
            let mut o = Json::obj();
            o.set("name", Json::str(s.name.clone()))
                .set("bin", Json::str(s.bin.clone()))
                .set("wall_s", Json::num(s.wall_s));
            let mut metrics = Json::obj();
            for (k, m) in &s.metrics {
                let mut mo = Json::obj();
                mo.set("value", Json::num(m.value))
                    .set("noise", Json::num(m.noise))
                    .set("better", Json::str(m.direction.name()));
                metrics.set(k, mo);
            }
            o.set("metrics", metrics);
            arr.push(o);
        }
        root.set("scenarios", arr);
        root
    }

    pub fn from_json(j: &Json) -> Result<SuiteResult, String> {
        let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
        if !schema.starts_with("arbocc-bench/") {
            return Err(format!("not an arbocc bench report (schema '{schema}')"));
        }
        let label = j.get("label").and_then(Json::as_str).unwrap_or("unknown").to_string();
        let tier = j
            .get("tier")
            .and_then(Json::as_str)
            .and_then(Tier::parse)
            .unwrap_or(Tier::Full);
        let partial = matches!(j.get("partial"), Some(Json::Bool(true)));
        let mut scenarios = Vec::new();
        for s in j.get("scenarios").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| "scenario entry missing 'name'".to_string())?
                .to_string();
            let bin = s.get("bin").and_then(Json::as_str).unwrap_or("").to_string();
            let wall_s = s.get("wall_s").and_then(Json::as_f64).unwrap_or(0.0);
            let mut metrics = BTreeMap::new();
            if let Some(Json::Obj(map)) = s.get("metrics") {
                for (k, v) in map {
                    let value = v
                        .get("value")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| format!("metric '{name}/{k}' missing 'value'"))?;
                    let noise = v.get("noise").and_then(Json::as_f64).unwrap_or(0.0);
                    let direction = v
                        .get("better")
                        .and_then(Json::as_str)
                        .and_then(Direction::parse)
                        .unwrap_or(Direction::Info);
                    metrics.insert(k.clone(), Metric { value, noise, direction });
                }
            }
            scenarios.push(SuiteScenarioResult { name, bin, wall_s, metrics });
        }
        Ok(SuiteResult { label, tier, partial, scenarios })
    }
}

/// The scenario registry.
#[derive(Debug, Default)]
pub struct Registry {
    scenarios: Vec<Scenario>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Everything `bench::scenarios` registers — the whole perf lab.
    pub fn standard() -> Registry {
        let mut r = Registry::new();
        crate::bench::scenarios::register_all(&mut r);
        r
    }

    pub fn register(&mut self, scenario: Scenario) {
        assert!(
            self.scenarios.iter().all(|s| s.name != scenario.name),
            "duplicate scenario name '{}'",
            scenario.name
        );
        self.scenarios.push(scenario);
    }

    pub fn scenarios(&self) -> &[Scenario] {
        &self.scenarios
    }

    pub fn len(&self) -> usize {
        self.scenarios.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }

    /// Run every scenario the predicate keeps, in registration order.
    pub fn run_filtered<F: Fn(&Scenario) -> bool>(
        &self,
        tier: Tier,
        label: &str,
        keep: F,
    ) -> SuiteResult {
        self.run_scoped(tier, label, keep, None)
    }

    /// [`run_filtered`](Registry::run_filtered) with the optional
    /// `--workload` spec threaded into the scenario context.
    pub fn run_scoped<F: Fn(&Scenario) -> bool>(
        &self,
        tier: Tier,
        label: &str,
        keep: F,
        workload: Option<&str>,
    ) -> SuiteResult {
        println!("== arbocc bench suite — tier {}, label {} ==", tier.name(), label);
        let total = Timer::start();
        let ctx = ScenarioCtx { tier, workload: workload.map(str::to_string) };
        let mut out = Vec::new();
        for s in &self.scenarios {
            if !keep(s) {
                continue;
            }
            println!("\n-- {} — {} --", s.name, s.about);
            let t = Timer::start();
            let record = (s.run)(&ctx);
            let wall_s = t.elapsed_s();
            for (k, m) in &record.metrics {
                println!("   metric {k} = {} ({})", fnum(m.value), m.direction.name());
            }
            println!("   scenario wall time {wall_s:.2}s");
            out.push(SuiteScenarioResult {
                name: s.name.to_string(),
                bin: s.bin.to_string(),
                wall_s,
                metrics: record.metrics,
            });
        }
        println!(
            "\nsuite done: {} scenario(s) in {:.1}s",
            out.len(),
            total.elapsed_s()
        );
        let partial = out.len() != self.scenarios.len();
        SuiteResult { label: label.to_string(), tier, partial, scenarios: out }
    }

    /// Run with an optional substring filter on scenario or bin name.
    pub fn run(&self, tier: Tier, label: &str, filter: Option<&str>) -> SuiteResult {
        self.run_with(tier, label, filter, None)
    }

    /// [`run`](Registry::run) plus the `--workload` spec override.
    pub fn run_with(
        &self,
        tier: Tier,
        label: &str,
        filter: Option<&str>,
        workload: Option<&str>,
    ) -> SuiteResult {
        self.run_scoped(
            tier,
            label,
            |s| match filter {
                None => true,
                Some(f) => s.name.contains(f) || s.bin.contains(f),
            },
            workload,
        )
    }
}

/// Entry point for the thin bench bins: run the scenarios registered
/// under `bin` (default tier `full`, override with `-- --tier smoke`)
/// and keep the `reports/<bin>.json` flow alive.
pub fn run_bin(bin: &str) {
    let args = crate::util::cli::Args::from_env();
    let tier_s = args.get_str("tier", "full");
    let tier = Tier::parse(&tier_s)
        .unwrap_or_else(|| panic!("unknown --tier '{tier_s}' (smoke|full)"));
    let registry = Registry::standard();
    let result = registry.run_filtered(tier, bin, |s| s.bin == bin);
    assert!(
        !result.scenarios.is_empty(),
        "no scenarios registered for bench bin '{bin}'"
    );
    let path = crate::util::json::write_report(bin, &result.to_json())
        .expect("writing bench report");
    println!("report: {}", path.display());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_result() -> SuiteResult {
        let mut metrics = BTreeMap::new();
        metrics.insert(
            "edges_per_s".to_string(),
            Metric { value: 1.25e8, noise: 2.5e6, direction: Direction::Higher },
        );
        metrics.insert(
            "rounds".to_string(),
            Metric { value: 34.0, noise: 0.0, direction: Direction::Lower },
        );
        metrics.insert(
            "shards".to_string(),
            Metric { value: 8.0, noise: 0.0, direction: Direction::Info },
        );
        SuiteResult {
            label: "PR2".to_string(),
            tier: Tier::Smoke,
            partial: false,
            scenarios: vec![SuiteScenarioResult {
                name: "perf/p1_sparse_cost".to_string(),
                bin: "perf_hotpaths".to_string(),
                wall_s: 1.5,
                metrics,
            }],
        }
    }

    #[test]
    fn schema_round_trips() {
        let r = demo_result();
        let text = r.to_json().pretty();
        let back = SuiteResult::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        // The partial marker survives the trip too.
        let mut p = demo_result();
        p.partial = true;
        let text = p.to_json().pretty();
        let back = SuiteResult::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert!(back.partial);
    }

    #[test]
    fn from_json_rejects_foreign_documents() {
        let j = crate::util::json::parse("{\"schema\": \"something-else\"}").unwrap();
        assert!(SuiteResult::from_json(&j).is_err());
        let j = crate::util::json::parse("{\"n\": 3}").unwrap();
        assert!(SuiteResult::from_json(&j).is_err());
    }

    #[test]
    fn registry_rejects_duplicates() {
        fn noop(_: &ScenarioCtx) -> ScenarioRecord {
            ScenarioRecord::new()
        }
        let mut r = Registry::new();
        r.register(Scenario { name: "a/x", bin: "a", about: "", run: noop });
        let dup = Scenario { name: "a/x", bin: "b", about: "", run: noop };
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            r.register(dup);
        }));
        assert!(got.is_err(), "duplicate registration must panic");
    }

    #[test]
    fn standard_registry_is_populated() {
        let r = Registry::standard();
        assert!(
            r.len() >= 10,
            "perf lab needs at least 10 scenarios, found {}",
            r.len()
        );
        let names: Vec<&str> = r.scenarios().iter().map(|s| s.name).collect();
        assert!(names.contains(&"perf/p8_shard_speedup"), "{names:?}");
        assert!(names.contains(&"e4/mis_rounds"), "{names:?}");
        assert!(names.contains(&"data/snapshot_roundtrip"), "{names:?}");
        assert!(names.contains(&"solve/corpus_sweep"), "{names:?}");
    }

    #[test]
    fn record_helpers_set_directions() {
        let m = Measurement {
            name: "t".into(),
            median_s: 0.5,
            mad_s: 0.05,
            min_s: 0.4,
            iterations: 3,
            samples: 4,
        };
        let mut rec = ScenarioRecord::new();
        rec.time_metric("step", &m);
        rec.rate_metric("items_per_s", &m, 100.0);
        let t = &rec.metrics["step_s"];
        assert_eq!(t.direction, Direction::Lower);
        assert!((t.value - 0.5).abs() < 1e-12);
        assert!((t.noise - 0.05).abs() < 1e-12);
        let r = &rec.metrics["items_per_s"];
        assert_eq!(r.direction, Direction::Higher);
        assert!((r.value - 200.0).abs() < 1e-9);
        assert!(r.noise > 0.0);
    }

    #[test]
    fn tier_and_direction_parse() {
        assert_eq!(Tier::parse("smoke"), Some(Tier::Smoke));
        assert_eq!(Tier::parse("full"), Some(Tier::Full));
        assert_eq!(Tier::parse("warp"), None);
        assert_eq!(Direction::parse("higher"), Some(Direction::Higher));
        assert_eq!(Direction::parse("sideways"), None);
    }
}
