//! Report rendering: collect `reports/*.json` (written by the bench
//! bins) into one markdown summary, and render `bench::compare` results
//! as the markdown delta table `arbocc bench --compare` prints.

use std::path::Path;

use crate::bench::compare::{Comparison, Verdict};
use crate::util::json::{parse, Json};
use crate::util::table::fnum;

/// One loaded report.
#[derive(Debug)]
pub struct Report {
    pub name: String,
    pub data: Json,
}

/// Load every `*.json` under `dir` (sorted by name for determinism).
pub fn load_reports(dir: &Path) -> std::io::Result<Vec<Report>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    let mut entries: Vec<_> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort();
    for path in entries {
        let text = std::fs::read_to_string(&path)?;
        match parse(&text) {
            Ok(data) => out.push(Report {
                name: path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| path.display().to_string()),
                data,
            }),
            Err(err) => eprintln!("warning: skipping {}: {err}", path.display()),
        }
    }
    Ok(out)
}

/// Render one suite-schema report (what `suite::run_bin` writes) as a
/// table per scenario.
fn render_suite(out: &mut String, suite: &crate::bench::suite::SuiteResult) {
    out.push_str(&format!(
        "tier `{}`, label `{}`, {} scenario(s).\n",
        suite.tier.name(),
        suite.label,
        suite.scenarios.len()
    ));
    for s in &suite.scenarios {
        out.push_str(&format!("\n### {} ({:.2}s)\n\n", s.name, s.wall_s));
        out.push_str("| metric | value | noise | better |\n|---|---|---|---|\n");
        for (k, m) in &s.metrics {
            out.push_str(&format!(
                "| {k} | {} | {} | {} |\n",
                fnum(m.value),
                fnum(m.noise),
                m.direction.name()
            ));
        }
    }
}

/// Render all reports as a markdown document.
pub fn render_markdown(reports: &[Report]) -> String {
    let mut out = String::new();
    out.push_str("# arbocc experiment reports\n\n");
    out.push_str(&format!("{} report file(s) aggregated from `reports/`.\n", reports.len()));
    for r in reports {
        out.push_str(&format!("\n## {}\n\n", r.name));
        // Suite-schema reports (bench bins since the perf lab) get the
        // structured rendering; flat key→value objects keep the old one.
        if let Ok(suite) = crate::bench::suite::SuiteResult::from_json(&r.data) {
            render_suite(&mut out, &suite);
            continue;
        }
        match &r.data {
            Json::Obj(map) => {
                out.push_str("| key | value |\n|---|---|\n");
                for (k, v) in map {
                    let rendered = match v {
                        Json::Num(x) => crate::util::table::fnum(*x),
                        Json::Str(s) => s.clone(),
                        Json::Bool(b) => b.to_string(),
                        other => other.pretty().replace('\n', " "),
                    };
                    out.push_str(&format!("| {k} | {rendered} |\n"));
                }
            }
            other => {
                out.push_str("```json\n");
                out.push_str(&other.pretty());
                out.push_str("\n```\n");
            }
        }
    }
    out
}

/// Render a baseline comparison as a markdown delta table.
pub fn render_comparison(cmp: &Comparison) -> String {
    let fmt = |x: f64| if x.is_finite() { fnum(x) } else { "—".to_string() };
    let mut out = String::new();
    out.push_str(&format!(
        "# bench delta — {} vs baseline {}\n\n",
        cmp.current_label, cmp.baseline_label
    ));
    out.push_str(&format!(
        "{} metric(s) diffed: {} regression(s), {} improvement(s).\n\n",
        cmp.deltas.len(),
        cmp.regressions().len(),
        cmp.improvements().len()
    ));
    out.push_str("| scenario | metric | baseline | current | Δ% | tolerance | verdict |\n");
    out.push_str("|---|---|---|---|---|---|---|\n");
    for d in &cmp.deltas {
        let verdict = if d.verdict == Verdict::Regression {
            format!("**{}**", d.verdict.name())
        } else {
            d.verdict.name().to_string()
        };
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} |\n",
            d.scenario,
            d.metric,
            fmt(d.baseline),
            fmt(d.current),
            fmt(d.delta_pct()),
            fmt(d.tolerance),
            verdict
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn loads_and_renders() {
        let dir = std::env::temp_dir().join(format!("arbocc-report-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut j = Json::obj();
        j.set("ratio", Json::num(2.5)).set("family", Json::str("ba-3"));
        std::fs::write(dir.join("demo.json"), j.pretty()).unwrap();
        std::fs::write(dir.join("broken.json"), "{not json").unwrap();
        std::fs::write(dir.join("ignored.txt"), "x").unwrap();

        let reports = load_reports(&dir).unwrap();
        assert_eq!(reports.len(), 1, "only the valid json loads");
        let md = render_markdown(&reports);
        assert!(md.contains("## demo"));
        assert!(md.contains("| ratio | 2.500 |"), "got:\n{md}");
        assert!(md.contains("| family | ba-3 |"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_dir_ok() {
        // Unique per process: a fixed name collides when several `cargo
        // test` invocations run in parallel against the same temp dir.
        let dir = std::env::temp_dir()
            .join(format!("arbocc-report-test-none-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let reports = load_reports(&dir).unwrap();
        assert!(reports.is_empty());
        let md = render_markdown(&reports);
        assert!(md.contains("0 report file(s)"));
    }

    #[test]
    fn renders_suite_reports_structurally() {
        use crate::bench::suite::{Direction, Metric, SuiteResult, SuiteScenarioResult, Tier};
        use std::collections::BTreeMap;

        let mut metrics = BTreeMap::new();
        metrics.insert(
            "rounds".to_string(),
            Metric { value: 34.0, noise: 0.0, direction: Direction::Lower },
        );
        let suite = SuiteResult {
            label: "PR2".to_string(),
            tier: Tier::Smoke,
            partial: true,
            scenarios: vec![SuiteScenarioResult {
                name: "e4/mis_rounds".to_string(),
                bin: "e4_mis_rounds".to_string(),
                wall_s: 2.0,
                metrics,
            }],
        };
        let dir = std::env::temp_dir()
            .join(format!("arbocc-report-suite-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("e4_mis_rounds.json"), suite.to_json().pretty()).unwrap();

        let reports = load_reports(&dir).unwrap();
        let md = render_markdown(&reports);
        assert!(md.contains("### e4/mis_rounds"), "got:\n{md}");
        assert!(md.contains("| rounds | 34 | 0 | lower |"), "got:\n{md}");
        assert!(!md.contains("\"schema\""), "suite docs must not fall back to raw JSON:\n{md}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn renders_comparison_table() {
        use crate::bench::compare::{compare, CompareConfig};
        use crate::bench::suite::{Direction, Metric, SuiteResult, SuiteScenarioResult, Tier};
        use std::collections::BTreeMap;

        let mk = |label: &str, value: f64| {
            let mut metrics = BTreeMap::new();
            metrics.insert(
                "edges_per_s".to_string(),
                Metric { value, noise: 0.0, direction: Direction::Higher },
            );
            SuiteResult {
                label: label.to_string(),
                tier: Tier::Smoke,
                partial: false,
                scenarios: vec![SuiteScenarioResult {
                    name: "perf/p1".to_string(),
                    bin: "perf_hotpaths".to_string(),
                    wall_s: 1.0,
                    metrics,
                }],
            }
        };
        let cmp = compare(&mk("PR1", 100.0), &mk("PR2", 50.0), &CompareConfig::default());
        let md = render_comparison(&cmp);
        assert!(md.contains("# bench delta — PR2 vs baseline PR1"), "got:\n{md}");
        assert!(md.contains("1 regression(s)"));
        assert!(md.contains("| perf/p1 | edges_per_s |"));
        assert!(md.contains("**REGRESSION**"));
    }
}
