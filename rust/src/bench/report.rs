//! Report aggregation: collect `reports/*.json` (written by the bench
//! bins) into one markdown summary — the mechanical half of keeping
//! EXPERIMENTS.md in sync with reruns.

use std::path::Path;

use crate::util::json::{parse, Json};

/// One loaded report.
#[derive(Debug)]
pub struct Report {
    pub name: String,
    pub data: Json,
}

/// Load every `*.json` under `dir` (sorted by name for determinism).
pub fn load_reports(dir: &Path) -> std::io::Result<Vec<Report>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    let mut entries: Vec<_> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort();
    for path in entries {
        let text = std::fs::read_to_string(&path)?;
        match parse(&text) {
            Ok(data) => out.push(Report {
                name: path.file_stem().unwrap().to_string_lossy().into_owned(),
                data,
            }),
            Err(err) => eprintln!("warning: skipping {}: {err}", path.display()),
        }
    }
    Ok(out)
}

/// Render all reports as a markdown document.
pub fn render_markdown(reports: &[Report]) -> String {
    let mut out = String::new();
    out.push_str("# arbocc experiment reports\n\n");
    out.push_str(&format!("{} report file(s) aggregated from `reports/`.\n", reports.len()));
    for r in reports {
        out.push_str(&format!("\n## {}\n\n", r.name));
        match &r.data {
            Json::Obj(map) => {
                out.push_str("| key | value |\n|---|---|\n");
                for (k, v) in map {
                    let rendered = match v {
                        Json::Num(x) => crate::util::table::fnum(*x),
                        Json::Str(s) => s.clone(),
                        Json::Bool(b) => b.to_string(),
                        other => other.pretty().replace('\n', " "),
                    };
                    out.push_str(&format!("| {k} | {rendered} |\n"));
                }
            }
            other => {
                out.push_str("```json\n");
                out.push_str(&other.pretty());
                out.push_str("\n```\n");
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn loads_and_renders() {
        let dir = std::env::temp_dir().join(format!("arbocc-report-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut j = Json::obj();
        j.set("ratio", Json::num(2.5)).set("family", Json::str("ba-3"));
        std::fs::write(dir.join("demo.json"), j.pretty()).unwrap();
        std::fs::write(dir.join("broken.json"), "{not json").unwrap();
        std::fs::write(dir.join("ignored.txt"), "x").unwrap();

        let reports = load_reports(&dir).unwrap();
        assert_eq!(reports.len(), 1, "only the valid json loads");
        let md = render_markdown(&reports);
        assert!(md.contains("## demo"));
        assert!(md.contains("| ratio | 2.500 |"), "got:\n{md}");
        assert!(md.contains("| family | ba-3 |"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_dir_ok() {
        let dir = std::env::temp_dir().join("arbocc-report-test-none");
        let reports = load_reports(&dir).unwrap();
        assert!(reports.is_empty());
        let md = render_markdown(&reports);
        assert!(md.contains("0 report file(s)"));
    }
}
