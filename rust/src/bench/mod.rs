//! The perf lab: micro-benchmark harness, scenario registry, baseline
//! comparison and shared experiment plumbing (criterion is unavailable
//! offline; see DESIGN.md §2).
//!
//! * [`harness`] — warmup + timed iterations with median/MAD reporting;
//! * [`workloads`] — the named graph-family × size sweeps the experiment
//!   benches share, so every table is generated from the same instances;
//! * [`suite`] — the scenario registry behind `arbocc bench`: named
//!   scenarios with `smoke`/`full` tiers and the `BENCH_*.json` schema;
//! * [`scenarios`] — the registered scenarios (the former bench-bin
//!   bodies, tier-parameterized);
//! * [`compare`] — noise-aware baseline diffing and the regression gate;
//! * [`report`] — markdown rendering of reports and comparisons.

pub mod compare;
pub mod harness;
pub mod report;
pub mod scenarios;
pub mod suite;
pub mod workloads;
