//! Micro-benchmark harness + shared experiment plumbing (criterion is
//! unavailable offline; see DESIGN.md §2).
//!
//! * [`harness`] — warmup + timed iterations with median/MAD reporting;
//! * [`workloads`] — the named graph-family × size sweeps the experiment
//!   benches share, so every table is generated from the same instances.

pub mod harness;
pub mod report;
pub mod workloads;
