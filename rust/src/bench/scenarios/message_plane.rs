//! §MPC message-plane scenarios (bin `message_plane`): the pooled
//! flat-arena wire format measured against the retired per-message
//! plane, and the narrow (u32) storage width against the wide one.
//!
//! The plane refactor exists so rounds cost what the *algorithms* cost,
//! not what the allocator costs — the same motive as P8's shard speedup
//! and E4c's executor pipeline, which both ride every routed round. The
//! family records:
//!
//! * `mpc/plane_round_throughput` — words/s and µs/message through
//!   [`Router::round`] on a fan-out schedule with multi-word payloads,
//!   plus the steady-state heap-allocation count of a warm pooled round
//!   (when the host binary installs the counting allocator);
//! * `mpc/plane_vs_permsg`       — the same schedule through the arena
//!   plane vs a faithful reproduction of the retired one-`Vec<u64>`-per-
//!   message plane (identical ledger accounting), with the speedup gated;
//! * `mpc/plane_width_speedup`   — the identical id schedule on the u64
//!   vs the u32 storage plane: traces must match word-for-word (ledger
//!   charges model words, not storage units) while the narrow plane
//!   moves half the bytes at the barrier — the speedup is gated;
//! * `mpc/plane_codecs`          — typed [`Encode`]/[`Decode`] frame
//!   round-trips per second (the codec layer must stay free);
//! * `mpc/plane_tree_schedule`   — the broadcast/convergecast trees on
//!   the plane: deterministic round counts and peak words (noise 0), the
//!   smoke-sized twin of the `tests/round_counts.rs` goldens.

use crate::bench::harness::bench_with;
use crate::bench::suite::{Direction, Registry, Scenario, ScenarioCtx, ScenarioRecord};
use crate::mpc::broadcast::{Aggregate, BroadcastTree};
use crate::mpc::router::Router;
use crate::mpc::wire::{
    per_message_round, Decode, Encode, LabelUpdate, SlabBuf, SlabReader, SlabWriter, VertexStatus,
    WireOutbox, WordWidth,
};
use crate::mpc::{MpcConfig, MpcSimulator};
use crate::util::alloc;
use crate::util::table::fnum;

const BIN: &str = "message_plane";

pub fn register(r: &mut Registry) {
    r.register(Scenario {
        name: "mpc/plane_round_throughput",
        bin: BIN,
        about: "pooled router round (words/s, µs/message, allocs/round)",
        run: plane_round_throughput,
    });
    r.register(Scenario {
        name: "mpc/plane_vs_permsg",
        bin: BIN,
        about: "arena plane vs retired per-message plane (speedup)",
        run: plane_vs_permsg,
    });
    r.register(Scenario {
        name: "mpc/plane_width_speedup",
        bin: BIN,
        about: "u64 vs u32 storage plane, identical schedule (speedup)",
        run: plane_width_speedup,
    });
    r.register(Scenario {
        name: "mpc/plane_codecs",
        bin: BIN,
        about: "typed payload codecs (frames/s encode+decode)",
        run: plane_codecs,
    });
    r.register(Scenario {
        name: "mpc/plane_tree_schedule",
        bin: BIN,
        about: "broadcast/convergecast on the plane (deterministic words)",
        run: plane_tree_schedule,
    });
}

fn plane_sim() -> MpcSimulator {
    MpcSimulator::new(MpcConfig::model1(1_000_000, 10_000_000, 0.6))
}

/// The benchmark schedule: machine `m` sends [`FAN`] messages of
/// [`PAYLOAD_WORDS`] words each, destinations striding the fleet (7 and
/// 13 are coprime to every power-of-two fleet, so receives stay uniform).
const FAN: usize = 16;
const PAYLOAD_WORDS: usize = 4;

fn fan_dst(machines: usize, m: usize, k: usize) -> usize {
    (m * 7 + k * 13 + 1) % machines
}

/// Arena-side builder: payloads are stack arrays of vertex ids appended
/// straight into the shard slab — zero heap allocations per message, the
/// point of the plane. Ids (not raw u64s) so the same builder exercises
/// both storage widths: on the u32 plane each id frame occupies half the
/// bytes while the ledger words are unchanged.
fn arena_build(machines: usize) -> impl Fn(usize, &mut WireOutbox) + Sync {
    move |m: usize, out: &mut WireOutbox| {
        for k in 0..FAN {
            out.send_ids(fan_dst(machines, m, k), &[(m + k) as u32; PAYLOAD_WORDS]);
        }
    }
}

/// The identical schedule in the retired format: one `Vec<u64>` per
/// message (this allocation churn is what the baseline measures).
fn permsg_outboxes(machines: usize) -> Vec<Vec<(usize, Vec<u64>)>> {
    (0..machines)
        .map(|m| {
            (0..FAN)
                .map(|k| (fan_dst(machines, m, k), vec![(m + k) as u64; PAYLOAD_WORDS]))
                .collect()
        })
        .collect()
}

fn plane_round_throughput(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let machines = ctx.size(128, 512);
    let build = arena_build(machines);
    let router = Router::new(machines);
    let m = bench_with(
        &format!("plane round ({machines} machines × {FAN} msgs × {PAYLOAD_WORDS} words)"),
        &cfg,
        || {
            let mut sim = plane_sim();
            std::hint::black_box(router.round(&mut sim, "bench", &build));
        },
    );
    let msgs = (machines * FAN) as f64;
    let words = msgs * PAYLOAD_WORDS as f64;
    println!("{m}\n    ⇒ {:.3} µs/message", m.median_s * 1e6 / msgs);
    let mut rec = ScenarioRecord::new();
    rec.rate_metric("words_per_s", &m, words);
    let value = m.median_s * 1e6 / msgs;
    let noise = (m.mad_s * 1e6 / msgs).max(ScenarioRecord::TIMING_REL_NOISE_FLOOR * value);
    rec.metric_with_noise("us_per_message", value, noise, Direction::Lower);

    // Steady-state allocation count of one warm pooled round: after the
    // arena has seen a few rounds, slabs/ledgers/inboxes are recycled and
    // a round should cost only the executor's own bookkeeping (trace
    // label, round stats). Counted only when the host binary installed
    // the counting allocator (the bench bins and the CLI do; the
    // unit-test harness does not).
    if alloc::installed() {
        let mut sim = plane_sim();
        for _ in 0..4 {
            std::hint::black_box(router.round(&mut sim, "warm", &build));
        }
        let before = alloc::allocations();
        std::hint::black_box(router.round(&mut sim, "warm", &build));
        let per_round = (alloc::allocations() - before) as f64;
        println!("    ⇒ {per_round} heap allocations in a warm round");
        rec.metric_with_noise("allocs_per_round", per_round, 2.0, Direction::Lower);
    }
    rec
}

fn plane_vs_permsg(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let machines = ctx.size(128, 512);
    let build = arena_build(machines);
    let router = Router::new(machines);

    // Parity check before timing: same trace, same delivered stream
    // (ids widen back to the exact u64 words the retired plane carried).
    {
        let mut arena_sim = plane_sim();
        let arena = router.round(&mut arena_sim, "round", &build);
        let mut legacy_sim = plane_sim();
        let legacy =
            per_message_round(machines, &mut legacy_sim, "round", permsg_outboxes(machines));
        assert_eq!(arena_sim.trace(), legacy_sim.trace(), "plane traces diverged");
        for (m, want) in legacy.iter().enumerate() {
            let got: Vec<(usize, Vec<u64>)> =
                arena.inbox(m).iter().map(|w| (w.from, w.to_words())).collect();
            assert_eq!(&got, want, "machine {m}: delivery diverged");
        }
    }

    let ma = bench_with(&format!("arena plane ({machines} machines × {FAN} msgs)"), &cfg, || {
        let mut sim = plane_sim();
        std::hint::black_box(router.round(&mut sim, "bench", &build));
    });
    println!("{ma}");
    let ml = bench_with(&format!("per-msg plane ({machines} machines × {FAN} msgs)"), &cfg, || {
        let mut sim = plane_sim();
        std::hint::black_box(per_message_round(
            machines,
            &mut sim,
            "bench",
            permsg_outboxes(machines),
        ));
    });
    println!("{ml}");
    println!("    ⇒ arena speedup ×{}", fnum(ml.median_s / ma.median_s.max(1e-12)));
    let mut rec = ScenarioRecord::new();
    rec.speedup_metric("arena_speedup", &ml, &ma);
    rec.time_metric("arena_round", &ma);
    rec.time_metric("permsg_round", &ml);
    rec
}

fn plane_width_speedup(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let machines = ctx.size(128, 512);
    let build = arena_build(machines);
    let wide = Router::with_width(machines, WordWidth::W64);
    let narrow = Router::with_width(machines, WordWidth::W32);

    // Parity check before timing: the storage width is invisible to the
    // model — charged schedule and decoded streams must be bit-identical.
    {
        let mut sim64 = plane_sim();
        let a = wide.round(&mut sim64, "round", &build);
        let mut sim32 = plane_sim();
        let b = narrow.round(&mut sim32, "round", &build);
        assert_eq!(sim64.trace(), sim32.trace(), "storage width changed the charged schedule");
        for m in 0..machines {
            let x: Vec<(usize, Vec<u64>)> =
                a.inbox(m).iter().map(|w| (w.from, w.to_words())).collect();
            let y: Vec<(usize, Vec<u64>)> =
                b.inbox(m).iter().map(|w| (w.from, w.to_words())).collect();
            assert_eq!(x, y, "machine {m}: storage width changed delivery");
        }
    }

    let m64 = bench_with(&format!("u64 plane ({machines} machines × {FAN} id msgs)"), &cfg, || {
        let mut sim = plane_sim();
        std::hint::black_box(wide.round(&mut sim, "bench", &build));
    });
    println!("{m64}");
    let m32 = bench_with(&format!("u32 plane ({machines} machines × {FAN} id msgs)"), &cfg, || {
        let mut sim = plane_sim();
        std::hint::black_box(narrow.round(&mut sim, "bench", &build));
    });
    println!("{m32}");
    println!("    ⇒ narrow-width speedup ×{}", fnum(m64.median_s / m32.median_s.max(1e-12)));
    let mut rec = ScenarioRecord::new();
    rec.speedup_metric("width_speedup", &m64, &m32);
    rec.time_metric("u64_round", &m64);
    rec.time_metric("u32_round", &m32);
    rec
}

fn plane_codecs(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let frames = ctx.size(50_000, 500_000);
    let statuses: Vec<VertexStatus> = (0..frames)
        .map(|i| VertexStatus { vertex: i as u32, in_mis: i % 3 == 0 })
        .collect();
    let labels: Vec<LabelUpdate> = (0..frames)
        .map(|i| LabelUpdate { vertex: i as u32, label: (i / 7) as u32 })
        .collect();
    // Both frame types are one pair-packed word = one u64 unit, so frame
    // `i` lives in slab units `i..i+1`.
    let mut slab = SlabBuf::new(WordWidth::W64);
    slab.reserve(2 * frames);
    let m = bench_with(&format!("codec round-trip ({} frames)", 2 * frames), &cfg, || {
        slab.clear();
        {
            let mut w = SlabWriter::new(&mut slab);
            for s in &statuses {
                s.encode_into(&mut w);
            }
            for l in &labels {
                l.encode_into(&mut w);
            }
        }
        let mut acc = 0u64;
        for i in 0..frames {
            let s: VertexStatus =
                VertexStatus::decode(SlabReader::new(slab.view(i..i + 1))).expect("status frame");
            acc = acc.wrapping_add(u64::from(s.vertex));
        }
        for i in frames..2 * frames {
            let l: LabelUpdate =
                LabelUpdate::decode(SlabReader::new(slab.view(i..i + 1))).expect("label frame");
            acc = acc.wrapping_add(u64::from(l.label));
        }
        std::hint::black_box(acc);
    });
    println!("{m}");
    let mut rec = ScenarioRecord::new();
    rec.rate_metric("frames_per_s", &m, 2.0 * frames as f64);
    rec
}

fn plane_tree_schedule(ctx: &ScenarioCtx) -> ScenarioRecord {
    // Deterministic twin of the round_counts goldens at bench scale: the
    // tree primitives on the plane, metrics with zero noise so the gate
    // catches any schedule drift.
    let machines = ctx.size(256, 1024);
    let mut cfg = MpcConfig::model1(1_000_000, 10_000_000, 0.6);
    cfg.machines = machines;
    let mut sim = MpcSimulator::new(cfg);
    let router = Router::new(machines);
    let tree = BroadcastTree::new(machines, 4);
    let values: Vec<u64> = (0..machines as u64).map(|v| v * 3 + 1).collect();
    let agg = tree.aggregate(&mut sim, &router, &values, Aggregate::Max);
    let conv_rounds = sim.n_rounds();
    tree.broadcast(&mut sim, &router, agg);
    let bcast_rounds = sim.n_rounds() - conv_rounds;
    let peak = sim.peak_machine_words();
    let total = sim.total_communication();
    println!(
        "tree schedule on {machines} machines: {conv_rounds} convergecast + {bcast_rounds} \
         broadcast rounds, peak {peak} words, total {total} words"
    );
    let mut rec = ScenarioRecord::new();
    rec.metric("convergecast_rounds", conv_rounds as f64, Direction::Lower);
    rec.metric("broadcast_rounds", bcast_rounds as f64, Direction::Lower);
    rec.metric("peak_machine_words", peak as f64, Direction::Lower);
    rec.metric("total_words", total as f64, Direction::Lower);
    rec
}
