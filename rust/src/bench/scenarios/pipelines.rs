//! Matching-based forest pipelines (E8, Corollaries 27/29/31 + Remark 30)
//! and graph-exponentiation geometry (E11, §2.1.3 / Figures 1–2).

use crate::algorithms::forest::{clustering_from_matching, matching_clustering_cost};
use crate::algorithms::matching::{
    approx_matching, is_maximal, maximal_matching, maximum_matching_forest,
};
use crate::bench::suite::{Direction, Registry, Scenario, ScenarioCtx, ScenarioRecord};
use crate::cluster::cost::cost;
use crate::cluster::exact::exact_cost;
use crate::graph::generators::{grid, path, random_forest, random_tree};
use crate::mpc::exponentiation::{bfs_ball, gather_balls};
use crate::mpc::memory::Words;
use crate::mpc::{MpcConfig, MpcSimulator};
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::util::table::{fnum, Table};

pub fn register(r: &mut Registry) {
    r.register(Scenario {
        name: "e8/forest_pipelines",
        bin: "e8_forest",
        about: "λ=1: matchings ⇒ clusterings (Corollaries 27/29/31)",
        run: e8_forest_pipelines,
    });
    r.register(Scenario {
        name: "e11/exponentiation",
        bin: "e11_exponentiation",
        about: "graph exponentiation: radius doubling + memory caps",
        run: e11_exponentiation,
    });
}

// ---------------------------------------------------------------- E8

fn e8_forest_pipelines(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();

    // Corollary 27: maximum-matching clustering is optimal on forests.
    let mut rng = Rng::new(9000);
    let trials = ctx.size(10, 50);
    let mut equal = 0;
    for _ in 0..trials {
        let g = random_forest(12, 0.85, &mut rng);
        let m = maximum_matching_forest(&g);
        let c = clustering_from_matching(g.n(), &m);
        if cost(&g, &c).total() == exact_cost(&g) {
            equal += 1;
        }
    }
    println!(
        "E8a — Corollary 27: maximum-matching clustering = OPT on {equal}/{trials} random forests (n=12)"
    );
    assert_eq!(equal, trials);

    // Corollary 31 pipelines across sizes.
    let sizes = ctx.sweep(&[5_000usize], &[5_000, 20_000, 80_000]);
    let seeds = ctx.pick(2u64, 3u64);
    let mut table = Table::new(
        &format!("E8b — forest pipelines ({seeds} seeds, mean): cost ratio vs OPT and rounds"),
        &[
            "n", "maximal ratio", "maximal rounds", "(1+0.5) ratio", "(1+0.5) rounds",
            "(1+0.25) ratio",
        ],
    );
    for &n in &sizes {
        let mut maximal_ratio = Vec::new();
        let mut maximal_rounds = Vec::new();
        let mut a05_ratio = Vec::new();
        let mut a05_rounds = Vec::new();
        let mut a025_ratio = Vec::new();
        for s in 0..seeds {
            let mut rng = Rng::new(9100 + s * 13 + n as u64);
            let g = random_forest(n, 0.9, &mut rng);
            let opt = matching_clustering_cost(g.m(), maximum_matching_forest(&g).len()).max(1);
            let words = (g.n() + 2 * g.m()) as Words;

            let mut sim = MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));
            let mm = maximal_matching(&g, &mut rng, &mut sim, 64);
            assert!(is_maximal(&g, &mm.matching));
            maximal_ratio
                .push(matching_clustering_cost(g.m(), mm.matching.len()) as f64 / opt as f64);
            maximal_rounds.push(sim.n_rounds() as f64);

            let mut sim2 = MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));
            let a = approx_matching(&g, mm.matching.clone(), 0.5, &mut sim2);
            a05_ratio.push(matching_clustering_cost(g.m(), a.matching.len()) as f64 / opt as f64);
            a05_rounds.push(sim2.n_rounds() as f64);

            let mut sim3 = MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));
            let a2 = approx_matching(&g, mm.matching.clone(), 0.25, &mut sim3);
            a025_ratio
                .push(matching_clustering_cost(g.m(), a2.matching.len()) as f64 / opt as f64);
        }
        table.row(&[
            n.to_string(),
            fnum(mean(&maximal_ratio)),
            fnum(mean(&maximal_rounds)),
            fnum(mean(&a05_ratio)),
            fnum(mean(&a05_rounds)),
            fnum(mean(&a025_ratio)),
        ]);
        // Guarantees: maximal ≤ 2×, (1+ε) ≤ (1+ε)×.
        assert!(mean(&maximal_ratio) <= 2.0 + 1e-9);
        assert!(mean(&a05_ratio) <= 1.5 + 1e-9);
        assert!(mean(&a025_ratio) <= 1.25 + 1e-9);
        if n == 5_000 {
            rec.metric("maximal_ratio_n5000", mean(&maximal_ratio), Direction::Lower);
            rec.metric("maximal_rounds_n5000", mean(&maximal_rounds), Direction::Lower);
            rec.metric("eps05_ratio_n5000", mean(&a05_ratio), Direction::Lower);
        }
    }
    table.print();

    // Remark 30: P4 tightness of the maximal-matching bound.
    let p4 = path(4);
    let worst = matching_clustering_cost(p4.m(), 1); // middle-edge maximal
    let best = matching_clustering_cost(p4.m(), maximum_matching_forest(&p4).len());
    println!(
        "E8c — Remark 30 (P4): worst maximal cost {worst} vs OPT {best} ⇒ ratio {} (tight at 2)",
        fnum(worst as f64 / best as f64)
    );
    assert_eq!(worst / best.max(1), 2);
    rec
}

// ---------------------------------------------------------------- E11

fn e11_sim(n: usize, m: usize) -> MpcSimulator {
    MpcSimulator::new(MpcConfig::model2(n.max(2), (n + 2 * m).max(4) as Words, 0.9))
}

fn e11_exponentiation(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();

    // (a) rounds = log2(radius): R doubles every round (Figure 1).
    let path_n = ctx.size(1_024, 4_096);
    let grid_side = ctx.size(32, 64);
    let radii = ctx.sweep(&[4usize, 16], &[4, 16, 64]);
    let mut ta = Table::new(
        "E11a — rounds to gather radius R (Figure 1: R doubles per round)",
        &["graph", "R", "rounds"],
    );
    let mut rng = Rng::new(11_000);
    let graphs: Vec<(String, crate::graph::Graph)> = vec![
        (format!("path({path_n})"), path(path_n)),
        (format!("tree({path_n})"), random_tree(path_n, &mut rng)),
        (format!("grid({grid_side}x{grid_side})"), grid(grid_side, grid_side)),
    ];
    for (name, g) in &graphs {
        for &r in &radii {
            let mut s = e11_sim(g.n(), g.m());
            let targets: Vec<u32> = (0..g.n() as u32).collect();
            let res = gather_balls(g, &targets, r, u64::MAX, &mut s, "e11");
            assert_eq!(res.rounds, (r as f64).log2().ceil() as usize, "{name} R={r}");
            // Spot-check correctness against BFS.
            let v = (g.n() / 2) as u32;
            assert_eq!(res.balls[v as usize], bfs_ball(g, v, res.radius));
            ta.row(&[name.clone(), r.to_string(), res.rounds.to_string()]);
            if r == 16 && name.starts_with("grid") {
                rec.metric("grid_rounds_r16", res.rounds as f64, Direction::Lower);
            }
        }
    }
    ta.print();

    // (b) memory caps halt growth where ball topology exceeds S.
    let g = grid(grid_side, grid_side);
    let caps = ctx.sweep(&[32u64, 2_048, u64::MAX], &[32, 256, 2_048, 16_384, u64::MAX]);
    let mut tb = Table::new(
        &format!("E11b — memory-capped growth on grid({grid_side}x{grid_side}): radius vs cap"),
        &["cap (words)", "radius reached", "capped"],
    );
    for &cap in &caps {
        let mut s = e11_sim(g.n(), g.m());
        let targets: Vec<u32> = (0..g.n() as u32).collect();
        let res = gather_balls(&g, &targets, radii[radii.len() - 1], cap, &mut s, "e11b");
        tb.row(&[
            if cap == u64::MAX { "∞".into() } else { cap.to_string() },
            res.radius.to_string(),
            res.memory_capped.to_string(),
        ]);
        if cap == 2_048 {
            rec.metric("grid_cap2048_radius", res.radius as f64, Direction::Info);
        }
    }
    tb.print();

    // (c) virtual diameter (Figure 2): gathering ℓ-hop balls divides a
    // path's effective diameter by ℓ.
    let n = 1024;
    let mut tc = Table::new(
        &format!("E11c — Figure 2: path({n}) virtual diameter after gathering ℓ-hop balls"),
        &["ℓ", "virtual diameter ⌈(n-1)/ℓ⌉"],
    );
    for &l in &[1usize, 2, 4, 8, 16] {
        let virt = (n - 1usize).div_ceil(l);
        tc.row(&[l.to_string(), virt.to_string()]);
    }
    tc.print();
    rec
}
