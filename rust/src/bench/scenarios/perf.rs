//! §Perf hot-path scenarios (owned by the `perf_hotpaths` bin):
//!
//! P1  sparse cost evaluation (edges/s)            — L3 target ≥ 100 M/s
//! P2  dense native block cost (vs PJRT when artifacts are present)
//! P3  batched scorer vs one-at-a-time             — the Remark 14 win
//! P4  greedy MIS simulation (vertices/s)          — L3 target ≥ 10 M/s
//! P5  bad-triangle counting + packing
//! P6  MPC router (messages/s)
//! P7  end-to-end best-of-K through the coordinator
//! P8  sharded MPC executor: sequential vs multi-threaded MIS pipeline,
//!     and best-of-K at 1 vs N workers — the measured shard speedups
//! P9  local-search refinement passes (edges/s) — the Vec-tally hot loop

use std::sync::Arc;

use crate::algorithms::greedy_mis::greedy_mis;
use crate::algorithms::local_search::local_search;
use crate::algorithms::mpc_mis::{alg1_greedy_mis, Alg1Params};
use crate::algorithms::pivot::pivot_random;
use crate::bench::harness::bench_with;
use crate::bench::suite::{Direction, Registry, Scenario, ScenarioCtx, ScenarioRecord};
use crate::cluster::cost::cost;
use crate::cluster::triangles::{count_bad_triangles, greedy_packing};
use crate::coordinator::{best_of_k, TrialSpec};
use crate::graph::generators::{barabasi_albert, lambda_arboric};
use crate::mpc::memory::Words;
use crate::mpc::router::Router;
use crate::mpc::{MpcConfig, MpcSimulator};
use crate::runtime::blocks::{block_tensors, plan_blocks};
use crate::runtime::fallback::dense_cost_block;
use crate::runtime::{BackendKind, CostEngine};
use crate::util::rng::Rng;
use crate::util::table::fnum;

const BIN: &str = "perf_hotpaths";

pub fn register(r: &mut Registry) {
    r.register(Scenario {
        name: "perf/p1_sparse_cost",
        bin: BIN,
        about: "sparse disagreement cost (edges/s)",
        run: p1_sparse_cost,
    });
    r.register(Scenario {
        name: "perf/p2_block_cost",
        bin: BIN,
        about: "dense block cost kernel (native, PJRT when present)",
        run: p2_block_cost,
    });
    r.register(Scenario {
        name: "perf/p3_batch_scoring",
        bin: BIN,
        about: "batched candidate scoring vs one-at-a-time",
        run: p3_batch_scoring,
    });
    r.register(Scenario {
        name: "perf/p4_greedy_mis",
        bin: BIN,
        about: "sequential greedy MIS (vertices/s)",
        run: p4_greedy_mis,
    });
    r.register(Scenario {
        name: "perf/p5_triangles",
        bin: BIN,
        about: "bad-triangle counting and greedy packing",
        run: p5_triangles,
    });
    r.register(Scenario {
        name: "perf/p6_router",
        bin: BIN,
        about: "MPC router all-to-all round (µs/message)",
        run: p6_router,
    });
    r.register(Scenario {
        name: "perf/p7_best_of_k",
        bin: BIN,
        about: "end-to-end best-of-8 through the coordinator",
        run: p7_best_of_k,
    });
    r.register(Scenario {
        name: "perf/p8_shard_speedup",
        bin: BIN,
        about: "sharded executor speedups (MIS pipeline + best-of-K pool)",
        run: p8_shard_speedup,
    });
    r.register(Scenario {
        name: "perf/p9_local_search",
        bin: BIN,
        about: "local-search refinement passes (edges/s, Vec tallies)",
        run: p9_local_search,
    });
}

fn p1_sparse_cost(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let n = ctx.size(20_000, 200_000);
    let mut rng = Rng::new(13_000);
    let g = lambda_arboric(n, 4, &mut rng);
    let c = pivot_random(&g, &mut rng);
    let m = bench_with(&format!("P1 sparse cost (n={n}, m={})", g.m()), &cfg, || {
        std::hint::black_box(cost(&g, &c));
    });
    println!("{m}");
    let mut rec = ScenarioRecord::new();
    rec.rate_metric("edges_per_s", &m, g.m() as f64);
    rec
}

fn p2_block_cost(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let mut rng = Rng::new(13_100);
    let g = lambda_arboric(240, 3, &mut rng);
    let c = pivot_random(&g, &mut rng);
    let plan = plan_blocks(&g, &c).unwrap();
    let (adj, onehot, valid) = block_tensors(&g, &c, &plan.blocks[0]);
    let m = bench_with("P2 dense block cost (native)", &cfg, || {
        std::hint::black_box(dense_cost_block(&adj, &onehot, &valid));
    });
    println!("{m}");
    let mut rec = ScenarioRecord::new();
    rec.time_metric("native_block", &m);
    let engine = CostEngine::auto_default();
    if engine.kind() == BackendKind::Pjrt {
        let mp = bench_with("P2 dense block cost (PJRT)", &cfg, || {
            std::hint::black_box(engine.cost(&g, &c).unwrap());
        });
        println!("{mp}");
        rec.time_metric("pjrt_block", &mp);
    } else {
        println!("   (PJRT column skipped — native backend; run `make artifacts` first)");
    }
    rec
}

fn p3_batch_scoring(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let mut rng = Rng::new(13_200);
    let g = lambda_arboric(240, 3, &mut rng);
    let candidates: Vec<_> = (0..8).map(|_| pivot_random(&g, &mut rng)).collect();
    let engine = CostEngine::native();
    let mb = bench_with("P3 batched scorer (8 cand.)", &cfg, || {
        std::hint::black_box(engine.cost_batch_single_block(&g, &candidates).unwrap());
    });
    println!("{mb}");
    let ms = bench_with("P3 one-at-a-time (8 cand.)", &cfg, || {
        for c in &candidates {
            std::hint::black_box(engine.cost(&g, c).unwrap());
        }
    });
    println!("{ms}");
    println!("    ⇒ batching speedup ×{}", fnum(ms.median_s / mb.median_s.max(1e-12)));
    let mut rec = ScenarioRecord::new();
    rec.time_metric("batched_8", &mb);
    rec.speedup_metric("batch_speedup", &ms, &mb);
    rec
}

fn p4_greedy_mis(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let n = ctx.size(50_000, 500_000);
    let mut rng = Rng::new(13_300);
    let g = barabasi_albert(n, 3, &mut rng);
    let perm = rng.permutation(g.n());
    let m = bench_with(&format!("P4 greedy MIS (n={n})"), &cfg, || {
        std::hint::black_box(greedy_mis(&g, &perm));
    });
    println!("{m}");
    let mut rec = ScenarioRecord::new();
    rec.rate_metric("vertices_per_s", &m, g.n() as f64);
    rec
}

fn p5_triangles(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let n = ctx.size(10_000, 50_000);
    let mut rng = Rng::new(13_400);
    let g = lambda_arboric(n, 4, &mut rng);
    let mc = bench_with(&format!("P5 bad-triangle count (n={n})"), &cfg, || {
        std::hint::black_box(count_bad_triangles(&g));
    });
    println!("{mc}");
    let mp = bench_with(&format!("P5 greedy packing (n={n})"), &cfg, || {
        std::hint::black_box(greedy_packing(&g));
    });
    println!("{mp}");
    let mut rec = ScenarioRecord::new();
    rec.time_metric("count", &mc);
    rec.time_metric("packing", &mp);
    rec
}

fn p6_router(ctx: &ScenarioCtx) -> ScenarioRecord {
    // This µs/message figure rides two executor-layer fixes recorded in
    // BENCH_PR8: the router's pooled round arena (outboxes, inboxes and
    // ledgers recycled across rounds instead of reallocated) and the
    // shard pool's arithmetic `range_of` (no per-round `Vec<Range>` on
    // the `run`/seeded paths) — compare against the PR 7 baseline to see
    // the delta.
    let cfg = ctx.bench_cfg();
    let machines = 64;
    let router = Router::new(machines);
    let m = bench_with("P6 router round (64 machines × 64 msgs)", &cfg, || {
        let mut sim = MpcSimulator::new(MpcConfig::model1(100_000, 1_000_000, 0.6));
        std::hint::black_box(router.round(&mut sim, "bench", |i, out| {
            for j in 0..machines {
                out.send(j, &(i as u64));
            }
        }));
    });
    let msgs = (machines * machines) as f64;
    println!("{m}\n    ⇒ {:.2} µs/message", m.median_s * 1e6 / msgs);
    let mut rec = ScenarioRecord::new();
    // Wall-clock-derived, so it gets the same noise floor as the
    // time/rate helpers (a tight MAD over few groups must not make the
    // gate's tolerance collapse to the bare relative floor).
    let value = m.median_s * 1e6 / msgs;
    let noise = (m.mad_s * 1e6 / msgs).max(ScenarioRecord::TIMING_REL_NOISE_FLOOR * value);
    rec.metric_with_noise("us_per_message", value, noise, Direction::Lower);
    rec
}

fn p7_best_of_k(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let n = ctx.size(10_000, 50_000);
    let mut rng = Rng::new(13_500);
    let g = Arc::new(lambda_arboric(n, 4, &mut rng));
    let engine = CostEngine::native();
    let m = bench_with(&format!("P7 best-of-8 end-to-end (n={n}, native)"), &cfg, || {
        std::hint::black_box(best_of_k(&g, &TrialSpec::Pivot, 8, 4, 1, &engine).unwrap());
    });
    println!("{m}");
    let mut rec = ScenarioRecord::new();
    rec.time_metric("best_of_8", &m);
    rec
}

fn p8_shard_speedup(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let n = ctx.size(12_000, 60_000);
    let mut rng = Rng::new(13_800);
    let g = barabasi_albert(n, 3, &mut rng);
    let perm = rng.permutation(g.n());
    let words = (g.n() + 2 * g.m()) as Words;

    // Same seed, same rounds, 1 vs N threads on the MIS pipeline.
    let mut mis_rounds = [0usize; 2];
    let mut run_mis = |n_shards: usize, rounds_slot: &mut usize| {
        let mcfg = MpcConfig::model1(g.n(), words, 0.5);
        let mut sim = MpcSimulator::lenient_sharded(mcfg, n_shards);
        std::hint::black_box(alg1_greedy_mis(&g, &perm, &Alg1Params::default(), &mut sim));
        *rounds_slot = sim.n_rounds();
    };
    let m1 = bench_with(&format!("P8 MIS pipeline (n={n}, 1 shard)"), &cfg, || {
        run_mis(1, &mut mis_rounds[0])
    });
    println!("{m1}");
    let mn = bench_with(&format!("P8 MIS pipeline (n={n}, {shards} shards)"), &cfg, || {
        run_mis(shards, &mut mis_rounds[1])
    });
    println!("{mn}");
    assert_eq!(mis_rounds[0], mis_rounds[1], "sharding must not change round counts");
    println!(
        "    ⇒ MIS pipeline shard speedup ×{} ({} rounds at both shard counts)",
        fnum(m1.median_s / mn.median_s.max(1e-12)),
        mis_rounds[0]
    );

    // Best-of-K trials on the worker pool: 1 vs `workers` workers.
    let gb = Arc::new(lambda_arboric(ctx.size(10_000, 50_000), 4, &mut rng));
    let engine = CostEngine::native();
    let workers = shards.clamp(2, 4);
    let b1 = bench_with("P8 best-of-8 (1 worker)", &cfg, || {
        std::hint::black_box(best_of_k(&gb, &TrialSpec::Pivot, 8, 1, 1, &engine).unwrap());
    });
    println!("{b1}");
    let bw = bench_with(&format!("P8 best-of-8 ({workers} workers)"), &cfg, || {
        std::hint::black_box(best_of_k(&gb, &TrialSpec::Pivot, 8, workers, 1, &engine).unwrap());
    });
    println!("{bw}");
    println!("    ⇒ best-of-K pool speedup ×{}", fnum(b1.median_s / bw.median_s.max(1e-12)));

    let mut rec = ScenarioRecord::new();
    rec.speedup_metric("mis_shard_speedup", &m1, &mn);
    rec.speedup_metric("bok_pool_speedup", &b1, &bw);
    rec.metric("shards", shards as f64, Direction::Info);
    rec.metric("mis_rounds", mis_rounds[0] as f64, Direction::Info);
    rec
}

fn p9_local_search(ctx: &ScenarioCtx) -> ScenarioRecord {
    // §Perf P9: the local-search hot loop (flat Vec tallies + label free
    // list; the `HashMap`-tallied version this replaces is the perf-fix
    // baseline the PR 3 delta is recorded against).
    let cfg = ctx.bench_cfg();
    let n = ctx.size(20_000, 200_000);
    let mut rng = Rng::new(13_900);
    let g = lambda_arboric(n, 4, &mut rng);
    let start = pivot_random(&g, &mut rng);
    let passes = 2usize;
    let m = bench_with(&format!("P9 local search (n={n}, {passes} passes)"), &cfg, || {
        std::hint::black_box(local_search(&g, &start, passes));
    });
    println!("{m}");
    let mut rec = ScenarioRecord::new();
    // Each pass touches every directed edge once: 2m per pass.
    rec.rate_metric("edges_per_s", &m, (passes * 2 * g.m()) as f64);
    rec
}
