//! The perf lab's scenario library: every experiment the 14 bench bins
//! used to run inline now lives here as a registered [`Scenario`], so
//! `arbocc bench` (and CI's bench-smoke job) can run the whole sweep at
//! either tier and record one `BENCH_*.json`.
//!
//! Grouping mirrors the bins:
//!
//! * [`perf`] — §Perf hot paths P1–P8 (`perf_hotpaths`);
//! * [`clustering`] — cost/approximation experiments (`e1_structural`,
//!   `e2_alg4`, `e3_clustering`, `e9_simple`, `e10_baselines`,
//!   `e12_best_of_k`);
//! * [`mis`] — greedy-MIS round/structure experiments (`e4_mis_rounds`,
//!   `e5_components`, `e6_degree_decay`, `e7_dependency`,
//!   `ablation_constants`);
//! * [`pipelines`] — forest matchings and exponentiation (`e8_forest`,
//!   `e11_exponentiation`);
//! * [`solve`] — the unified solver engine: planner overhead,
//!   per-component shard speedup, mixed-family auto routing
//!   (`solve_engine`);
//! * [`data`] — the dataset subsystem: ingest/snapshot throughput and
//!   the corpus sweep (`data_lab`);
//! * [`message_plane`] — the flat-arena wire format vs the retired
//!   per-message plane, codec throughput, tree schedules
//!   (`message_plane`);
//! * [`headtohead`] — source paper vs constant-round rival solvers:
//!   ratio-vs-OPT, round/word growth, wall-clock (`headtohead`).

use crate::bench::suite::Registry;

pub mod clustering;
pub mod data;
pub mod headtohead;
pub mod message_plane;
pub mod mis;
pub mod perf;
pub mod pipelines;
pub mod solve;

/// Register the whole perf lab (what [`Registry::standard`] calls).
pub fn register_all(r: &mut Registry) {
    perf::register(r);
    clustering::register(r);
    mis::register(r);
    pipelines::register(r);
    solve::register(r);
    data::register(r);
    message_plane::register(r);
    headtohead::register(r);
}
