//! Greedy-MIS round/structure scenarios: Theorem 24 round counts and the
//! sharded-executor speedup (E4), Lemma 18 chunk components (E5),
//! Lemma 22 degree decay (E6), Fischer–Noever dependency lengths (E7)
//! and the design-constant ablation.

use crate::algorithms::greedy_mis::{
    greedy_mis, greedy_mis_on_subset, longest_dependency_path, parallel_greedy_rounds,
};
use crate::algorithms::mpc_mis::alg2::alg2_process;
use crate::algorithms::mpc_mis::{
    alg1_greedy_mis, direct_simulation_mis, Alg1Params, Alg2Params, Alg3Params, Subroutine,
};
use crate::bench::suite::{Direction, Registry, Scenario, ScenarioCtx, ScenarioRecord};
use crate::bench::workloads;
use crate::graph::generators::{barabasi_albert, lambda_arboric};
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::{MpcConfig, MpcSimulator};
use crate::util::rng::Rng;
use crate::util::stats::{self, linear_fit, mean};
use crate::util::table::{fnum, Table};
use crate::util::timer::Timer;

pub fn register(r: &mut Registry) {
    r.register(Scenario {
        name: "e4/mis_rounds",
        bin: "e4_mis_rounds",
        about: "Theorem 24: MIS round counts, Δ and n sweeps",
        run: e4_mis_rounds,
    });
    r.register(Scenario {
        name: "e4/shard_speedup",
        bin: "e4_mis_rounds",
        about: "sequential vs machine-sharded Alg1+Alg2 wall clock",
        run: e4_shard_speedup,
    });
    r.register(Scenario {
        name: "e5/chunk_components",
        bin: "e5_components",
        about: "Lemma 18: chunk-graph components stay O(log n)",
        run: e5_chunk_components,
    });
    r.register(Scenario {
        name: "e6/degree_decay",
        bin: "e6_degree_decay",
        about: "Lemma 22: residual max degree O(n log n / t)",
        run: e6_degree_decay,
    });
    r.register(Scenario {
        name: "e7/dependency_length",
        bin: "e7_dependency",
        about: "Fischer–Noever: dependency structure is O(log n)",
        run: e7_dependency_length,
    });
    r.register(Scenario {
        name: "ablation/constants",
        bin: "ablation_constants",
        about: "design constants: chunk divisor, c_prefix, Alg3 radius",
        run: ablation_constants,
    });
}

// ---------------------------------------------------------------- E4

/// Rounds of (direct, Alg1+Alg2, Alg1+Alg3) on the same permutation; all
/// three pipelines must produce the sequential greedy MIS exactly.
fn e4_run_all(g: &Graph, seed: u64) -> (usize, usize, usize) {
    let mut rng = Rng::new(seed);
    let perm = rng.permutation(g.n());
    let words = (g.n() + 2 * g.m()) as Words;
    let reference = greedy_mis(g, &perm);

    let mut s_d = MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));
    let direct = direct_simulation_mis(g, &perm, &mut s_d);
    let mut s_2 = MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));
    let a2 = alg1_greedy_mis(
        g,
        &perm,
        &Alg1Params { c_prefix: 1.0, subroutine: Subroutine::Alg2(Alg2Params::default()) },
        &mut s_2,
    );
    let mut s_3 = MpcSimulator::new(MpcConfig::model2(g.n(), words, 0.5));
    let a3 = alg1_greedy_mis(
        g,
        &perm,
        &Alg1Params { c_prefix: 1.0, subroutine: Subroutine::Alg3(Alg3Params::default()) },
        &mut s_3,
    );
    assert_eq!(direct, reference);
    assert_eq!(a2.in_mis, reference);
    assert_eq!(a3.in_mis, reference);
    (s_d.n_rounds(), s_2.n_rounds(), s_3.n_rounds())
}

fn e4_mis_rounds(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();

    // (a) Δ sweep at fixed n via the BA attach parameter.
    let n = ctx.size(6_000, 30_000);
    let attaches = ctx.sweep(&[1usize, 4, 16], &[1, 2, 4, 8, 16]);
    let mut ta = Table::new(
        &format!("E4a — greedy MIS rounds, n={n}, Δ sweep via BA attach"),
        &["attach", "Δ", "direct (M1)", "Alg1+Alg2 (M1)", "Alg1+Alg3 (M2)"],
    );
    for &attach in &attaches {
        let mut rng = Rng::new(5000 + attach as u64);
        let g = barabasi_albert(n, attach, &mut rng);
        let (d, a2, a3) = e4_run_all(&g, 5100 + attach as u64);
        ta.row(&[
            attach.to_string(),
            g.max_degree().to_string(),
            d.to_string(),
            a2.to_string(),
            a3.to_string(),
        ]);
        if attach == 16 {
            rec.metric("attach16_direct_rounds", d as f64, Direction::Lower);
            rec.metric("attach16_alg2_rounds", a2 as f64, Direction::Lower);
            rec.metric("attach16_alg3_rounds", a3 as f64, Direction::Lower);
        }
    }
    ta.print();

    // (b) n sweep at fixed λ: direct grows with log n, Alg3 stays flat.
    let lambda = 3usize;
    let full_ns = [2_000usize, 8_000, 32_000, 128_000];
    let ns = workloads::ladder(ctx.tier, &full_ns);
    let mut tb = Table::new(
        &format!("E4b — greedy MIS rounds, λ={lambda}, n sweep"),
        &["n", "log2 n", "direct (M1)", "Alg1+Alg2 (M1)", "Alg1+Alg3 (M2)"],
    );
    let mut directs = Vec::new();
    let mut alg3s = Vec::new();
    for &n in &ns {
        let mut rng = Rng::new(5200 + n as u64);
        let g = lambda_arboric(n, lambda, &mut rng);
        let (d, a2, a3) = e4_run_all(&g, 5300 + n as u64);
        tb.row(&[
            n.to_string(),
            fnum((n as f64).log2()),
            d.to_string(),
            a2.to_string(),
            a3.to_string(),
        ]);
        directs.push(d as f64);
        alg3s.push(a3 as f64);
        rec.metric(&format!("n{n}_direct_rounds"), d as f64, Direction::Lower);
        rec.metric(&format!("n{n}_alg3_rounds"), a3 as f64, Direction::Lower);
    }
    tb.print();
    let d_growth = directs.last().unwrap() / directs.first().unwrap();
    let a3_growth = alg3s.last().unwrap() / alg3s.first().unwrap();
    println!(
        "growth over the sweep: direct ×{d_growth:.2} (tracks log n), Alg1+Alg3 ×{a3_growth:.2} (flatter)"
    );
    rec.metric("direct_growth", d_growth, Direction::Info);
    rec.metric("alg3_growth", a3_growth, Direction::Info);
    rec
}

fn e4_shard_speedup(ctx: &ScenarioCtx) -> ScenarioRecord {
    let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let n = ctx.size(24_000, 128_000);
    let lambda = 3usize;
    let reps = ctx.size(2, 3);
    let mut rng = Rng::new(5999);
    let g = lambda_arboric(n, lambda, &mut rng);
    let perm = rng.permutation(g.n());
    let words = (g.n() + 2 * g.m()) as Words;
    let cell = |n_shards: usize| -> (usize, Vec<bool>, f64) {
        let mut sim =
            MpcSimulator::lenient_sharded(MpcConfig::model1(g.n(), words, 0.5), n_shards);
        let t = Timer::start();
        let run = alg1_greedy_mis(&g, &perm, &Alg1Params::default(), &mut sim);
        (sim.n_rounds(), run.in_mis, t.elapsed_s())
    };

    let mut seq_t = Vec::new();
    let mut par_t = Vec::new();
    let mut rounds = 0usize;
    for _ in 0..reps {
        let (rounds_seq, mis_seq, secs_seq) = cell(1);
        let (rounds_par, mis_par, secs_par) = cell(shards);
        assert_eq!(rounds_seq, rounds_par, "sharding must not change round counts");
        assert_eq!(mis_seq, mis_par, "sharding must not change the MIS");
        rounds = rounds_seq;
        seq_t.push(secs_seq);
        par_t.push(secs_par);
    }
    let med_seq = stats::median(&seq_t);
    let med_par = stats::median(&par_t).max(1e-9);
    let speedup = med_seq / med_par;
    // Built from `reps` raw Timer samples, so floor the relative noise
    // like the harness-backed speedup helper does.
    let rel = (stats::mad(&seq_t) / med_seq.max(1e-9) + stats::mad(&par_t) / med_par)
        .max(ScenarioRecord::TIMING_REL_NOISE_FLOOR);
    println!(
        "E4c — executor: n={n}, {rounds} rounds; sequential {med_seq:.2}s vs {shards}-shard {med_par:.2}s ⇒ speedup ×{}",
        fnum(speedup)
    );
    let mut rec = ScenarioRecord::new();
    rec.metric_with_noise("shard_speedup", speedup, speedup * rel, Direction::Higher);
    rec.metric("shards", shards as f64, Direction::Info);
    rec.metric("rounds", rounds as f64, Direction::Info);
    rec
}

// ---------------------------------------------------------------- E5

fn e5_max_component(n: usize, lambda: usize, params: &Alg2Params, seed: u64) -> usize {
    let mut rng = Rng::new(seed);
    let g = lambda_arboric(n, lambda, &mut rng);
    let perm = rng.permutation(n);
    let words = (g.n() + 2 * g.m()) as Words;
    // Lenient: the supercritical contrast is *expected* to blow budgets.
    let mut sim = MpcSimulator::lenient(MpcConfig::model1(n, words, 0.5));
    let mut blocked = vec![false; n];
    let mut in_mis = vec![false; n];
    let stats = alg2_process(&g, &perm, &mut blocked, &mut in_mis, &mut sim, params);
    stats.chunk_max_components.into_iter().max().unwrap_or(0)
}

fn e5_chunk_components(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let lambda = 4usize;
    let ns = ctx.sweep(&[4_000usize, 16_000], &[4_000, 16_000, 64_000, 256_000]);
    let mut table = Table::new(
        &format!("E5 — Lemma 18: max chunk-graph component, λ={lambda} (3 seeds, worst)"),
        &["n", "log2 n", "subcritical (div=8)", "paper (div=100)", "supercritical (div=1.5)"],
    );
    for &n in &ns {
        let worst = |params: &Alg2Params| {
            (0..3)
                .map(|s| e5_max_component(n, lambda, params, 6000 + s * 31 + n as u64))
                .max()
                .unwrap()
        };
        let sub = worst(&Alg2Params::default());
        let faithful = worst(&Alg2Params::faithful());
        // The supercritical contrast column only runs at the smallest
        // size — its components (deliberately) explode with n.
        let sup_cell = if n == ns[0] {
            let sup = worst(&Alg2Params { divisor: 1.5, iters_factor: 4.0 });
            rec.metric("supercritical_worst", sup as f64, Direction::Info);
            sup.to_string()
        } else {
            "-".to_string()
        };
        let log2n = (n as f64).log2();
        table.row(&[
            n.to_string(),
            fnum(log2n),
            sub.to_string(),
            faithful.to_string(),
            sup_cell,
        ]);
        assert!(
            (sub as f64) <= 6.0 * log2n,
            "subcritical component {sub} exceeds 6·log2(n)={:.0}",
            6.0 * log2n
        );
        assert!(
            (faithful as f64) <= 4.0 * log2n,
            "faithful component {faithful} exceeds 4·log2(n)"
        );
        rec.metric(&format!("n{n}_subcritical"), sub as f64, Direction::Lower);
    }
    table.print();
    println!("the supercritical column shows why the divisor constant is load-bearing.");
    rec
}

// ---------------------------------------------------------------- E6

fn e6_degree_decay(ctx: &ScenarioCtx) -> ScenarioRecord {
    let n = ctx.size(20_000, 100_000);
    let mut rng = Rng::new(7000);
    let g = barabasi_albert(n, 4, &mut rng);
    let perm = rng.permutation(n);

    let mut table = Table::new(
        &format!("E6 — Lemma 22 degree decay, BA(n={n}, m=4), Δ₀={}", g.max_degree()),
        &["t (prefix)", "measured max residual deg", "bound 10·n·ln(n)/t", "within"],
    );
    let checkpoints = ctx.sweep(
        &[n / 16, n / 4, n / 2],
        &[n / 64, n / 32, n / 16, n / 8, n / 4, n / 2, (3 * n) / 4],
    );
    let mut blocked = vec![false; n];
    let mut in_mis = vec![false; n];
    let mut pos = 0usize;
    let mut worst_fraction = 0.0f64;
    for &t in &checkpoints {
        greedy_mis_on_subset(&g, &perm[pos..t], &mut blocked, &mut in_mis);
        pos = t;
        // Residual: unprocessed and unblocked.
        let mut live = vec![false; n];
        for &v in &perm[pos..] {
            if !blocked[v as usize] {
                live[v as usize] = true;
            }
        }
        let max_deg = (0..n as u32)
            .filter(|&v| live[v as usize])
            .map(|v| g.neighbors(v).iter().filter(|&&u| live[u as usize]).count())
            .max()
            .unwrap_or(0);
        let bound = 10.0 * n as f64 * (n as f64).ln() / t as f64;
        table.row(&[
            t.to_string(),
            max_deg.to_string(),
            fnum(bound),
            (if (max_deg as f64) <= bound { "yes" } else { "NO" }).to_string(),
        ]);
        assert!((max_deg as f64) <= bound, "Lemma 22 bound violated at t={t}");
        worst_fraction = worst_fraction.max(max_deg as f64 / bound);
    }
    table.print();
    let mut rec = ScenarioRecord::new();
    rec.metric("worst_bound_fraction", worst_fraction, Direction::Lower);
    rec
}

// ---------------------------------------------------------------- E7

fn e7_dependency_length(ctx: &ScenarioCtx) -> ScenarioRecord {
    let lambda = 3usize;
    let ns = ctx.sweep(
        &[1_000usize, 4_000, 16_000],
        &[1_000, 4_000, 16_000, 64_000, 256_000],
    );
    let seeds = ctx.pick(2u64, 5u64);
    let mut table = Table::new(
        &format!("E7 — Fischer–Noever dependency lengths, arboric-{lambda} ({seeds} seeds, mean)"),
        &["n", "log2 n", "fixpoint iters", "dependency path", "iters/log2 n"],
    );
    let mut rec = ScenarioRecord::new();
    let mut logs = Vec::new();
    let mut iters_series = Vec::new();
    for &n in &ns {
        let mut iters_v = Vec::new();
        let mut dep_v = Vec::new();
        for s in 0..seeds {
            let mut rng = Rng::new(8000 + s * 97 + n as u64);
            let g = lambda_arboric(n, lambda, &mut rng);
            let perm = rng.permutation(n);
            let (_, iters) = parallel_greedy_rounds(&g, &perm);
            iters_v.push(iters as f64);
            dep_v.push(longest_dependency_path(&g, &perm) as f64);
        }
        let log2n = (n as f64).log2();
        table.row(&[
            n.to_string(),
            fnum(log2n),
            fnum(mean(&iters_v)),
            fnum(mean(&dep_v)),
            fnum(mean(&iters_v) / log2n),
        ]);
        logs.push(log2n);
        iters_series.push(mean(&iters_v));
    }
    table.print();
    let (_, slope, r2) = linear_fit(&logs, &iters_series);
    println!(
        "fixpoint iters vs log2 n: slope {slope:.2} per log2 n (r²={r2:.3}) — linear in log n"
    );
    let r2_floor = ctx.pick(0.7, 0.8);
    assert!(r2 > r2_floor, "iterations should correlate strongly with log n (r²={r2})");
    rec.metric("iters_slope", slope, Direction::Lower);
    rec.metric("fit_r2", r2, Direction::Info);
    rec
}

// ---------------------------------------------------------------- ablation

fn ablation_constants(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let n = ctx.size(8_000, 40_000);
    let lambda = 4usize;
    let mut rng = Rng::new(14_000);
    let g = lambda_arboric(n, lambda, &mut rng);
    let perm = rng.permutation(n);
    let words = (g.n() + 2 * g.m()) as Words;
    let expected = greedy_mis(&g, &perm);

    // (a) chunk divisor sweep (subcriticality).
    let divisors = ctx.sweep(&[2.0f64, 8.0, 100.0], &[2.0, 4.0, 8.0, 16.0, 100.0]);
    let mut ta = Table::new(
        "ablation (a) — Alg2 chunk divisor (subcriticality)",
        &["divisor", "rounds", "max component", "exact MIS"],
    );
    for &div in &divisors {
        let mut sim = MpcSimulator::lenient(MpcConfig::model1(n, words, 0.5));
        let mut blocked = vec![false; n];
        let mut in_mis = vec![false; n];
        let stats = alg2_process(
            &g,
            &perm,
            &mut blocked,
            &mut in_mis,
            &mut sim,
            &Alg2Params { divisor: div, iters_factor: 4.0 },
        );
        let maxc = stats.chunk_max_components.iter().copied().max().unwrap_or(0);
        assert_eq!(in_mis, expected);
        ta.row(&[fnum(div), sim.n_rounds().to_string(), maxc.to_string(), "yes".into()]);
        if div == 8.0 {
            rec.metric("divisor8_rounds", sim.n_rounds() as f64, Direction::Lower);
            rec.metric("divisor8_maxcomp", maxc as f64, Direction::Lower);
        }
    }
    ta.print();

    // (b) prefix constant sweep.
    let cs = ctx.sweep(&[0.2f64, 1.0], &[0.05, 0.2, 1.0, 4.0]);
    let mut tb = Table::new(
        "ablation (b) — Alg1 prefix constant c_prefix",
        &["c_prefix", "phases", "rounds", "exact MIS"],
    );
    for &c in &cs {
        let mut sim = MpcSimulator::lenient(MpcConfig::model1(n, words, 0.5));
        let params = Alg1Params { c_prefix: c, ..Default::default() };
        let run = alg1_greedy_mis(&g, &perm, &params, &mut sim);
        assert_eq!(run.in_mis, expected);
        tb.row(&[
            c.to_string(),
            run.phases.len().to_string(),
            sim.n_rounds().to_string(),
            "yes".into(),
        ]);
        if c == 1.0 {
            rec.metric("cprefix1_rounds", sim.n_rounds() as f64, Direction::Lower);
        }
    }
    tb.print();

    // (c) Alg3 radius constant sweep (Model 2).
    let radii = ctx.sweep(&[0.5f64, 1.0], &[0.25, 0.5, 1.0]);
    let mut tc = Table::new(
        "ablation (c) — Alg3 radius constant (compression factor)",
        &["C", "rounds (M2)", "exact MIS"],
    );
    for &c in &radii {
        let mut sim = MpcSimulator::lenient(MpcConfig::model2(n, words, 0.5));
        let params = Alg1Params {
            c_prefix: 1.0,
            subroutine: Subroutine::Alg3(Alg3Params { radius_constant: c, max_radius: 64 }),
        };
        let run = alg1_greedy_mis(&g, &perm, &params, &mut sim);
        assert_eq!(run.in_mis, expected);
        tc.row(&[c.to_string(), sim.n_rounds().to_string(), "yes".into()]);
        if c == 0.5 {
            rec.metric("radius05_rounds_m2", sim.n_rounds() as f64, Direction::Lower);
        }
    }
    tc.print();
    println!("all constants preserve exactness; they trade rounds against memory.");
    rec
}
