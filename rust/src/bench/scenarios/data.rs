//! Dataset-subsystem scenarios (`data_lab` bin): ingest throughput for
//! both text formats and the binary snapshot, round-trip fidelity, and
//! the corpus sweep driving the auto solver over every addressable
//! family.

use std::sync::Arc;

use crate::bench::harness;
use crate::bench::suite::{Direction, Registry, Scenario, ScenarioCtx, ScenarioRecord};
use crate::bench::workloads;
use crate::cluster::triangles::packing_lower_bound;
use crate::data::corpus::WorkloadSpec;
use crate::data::{edge_list, snapshot};
use crate::solve::{solve_decomposed, DriverConfig, SolveRequest, SolverRegistry};
use crate::util::table::{fnum, Table};
use crate::util::timer::Timer;

pub fn register(r: &mut Registry) {
    r.register(Scenario {
        name: "data/ingest_throughput",
        bin: "data_lab",
        about: "edge-list / CSV / snapshot parse throughput",
        run: ingest_throughput,
    });
    r.register(Scenario {
        name: "data/snapshot_roundtrip",
        bin: "data_lab",
        about: "arbocc-csr/v1 round-trip fidelity + encode/decode rates",
        run: snapshot_roundtrip,
    });
    r.register(Scenario {
        name: "solve/corpus_sweep",
        bin: "data_lab",
        about: "auto solver over the generator corpus, ratio vs LB",
        run: corpus_sweep,
    });
}

// ------------------------------------------------- data/ingest_throughput

fn ingest_throughput(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let n = ctx.size(20_000, 200_000);
    let spec = WorkloadSpec::parse(&format!("arboric:n={n},lambda=3,seed=42")).expect("spec");
    let g = spec.generate().expect("corpus generate");
    let mut ws = Vec::new();
    edge_list::write_edges(&g, &mut ws, edge_list::EdgeListFormat::Whitespace).expect("write");
    let mut csv = Vec::new();
    edge_list::write_edges(&g, &mut csv, edge_list::EdgeListFormat::Csv).expect("write");
    let text_ws = String::from_utf8(ws).expect("ascii edge list");
    let text_csv = String::from_utf8(csv).expect("ascii edge list");
    let bytes = snapshot::snapshot_bytes(&g);
    println!(
        "ingest workload {}: m={} — {} B text, {} B csv, {} B snapshot",
        spec.canonical(),
        g.m(),
        text_ws.len(),
        text_csv.len(),
        bytes.len()
    );
    let cfg = ctx.bench_cfg();
    let m_ws = harness::bench_with("edgelist_parse", &cfg, || {
        let (parsed, _) = edge_list::read_edges(&text_ws).expect("parse");
        assert_eq!(parsed.m(), g.m());
    });
    rec.rate_metric("edgelist_edges_per_s", &m_ws, g.m() as f64);
    let m_csv = harness::bench_with("csv_parse", &cfg, || {
        let (parsed, _) = edge_list::read_edges(&text_csv).expect("parse");
        assert_eq!(parsed.m(), g.m());
    });
    rec.rate_metric("csv_edges_per_s", &m_csv, g.m() as f64);
    let m_snap = harness::bench_with("snapshot_read", &cfg, || {
        let parsed = snapshot::read_snapshot_bytes(&bytes).expect("read");
        assert_eq!(parsed.m(), g.m());
    });
    rec.rate_metric("snapshot_edges_per_s", &m_snap, g.m() as f64);
    let per_edge = bytes.len() as f64 / g.m().max(1) as f64;
    rec.metric("snapshot_bytes_per_edge", per_edge, Direction::Info);
    rec
}

// ------------------------------------------------ data/snapshot_roundtrip

fn snapshot_roundtrip(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let n = ctx.size(10_000, 100_000);
    for spec_s in [format!("mixed:n={n},seed=4"), format!("powerlaw:n={n},attach=3,seed=4")] {
        let spec = WorkloadSpec::parse(&spec_s).expect("spec");
        let g = spec.generate().expect("corpus generate");
        let bytes = snapshot::snapshot_bytes(&g);
        let back = snapshot::read_snapshot_bytes(&bytes).expect("read");
        assert_eq!(back, g, "{spec_s}: snapshot round-trip must be lossless");
        assert_eq!(
            snapshot::snapshot_bytes(&back),
            bytes,
            "{spec_s}: re-encoding must be byte-identical"
        );
        println!("roundtrip {} OK: {} B for m={}", spec.canonical(), bytes.len(), g.m());
    }
    let g = WorkloadSpec::parse(&format!("mixed:n={n},seed=4"))
        .expect("spec")
        .generate()
        .expect("generate");
    let cfg = ctx.bench_cfg();
    let m_enc = harness::bench_with("snapshot_encode", &cfg, || {
        let b = snapshot::snapshot_bytes(&g);
        assert!(b.len() > 32);
    });
    let bytes = snapshot::snapshot_bytes(&g);
    let m_dec = harness::bench_with("snapshot_decode", &cfg, || {
        let parsed = snapshot::read_snapshot_bytes(&bytes).expect("read");
        assert_eq!(parsed.n(), g.n());
    });
    let mb = bytes.len() as f64 / (1024.0 * 1024.0);
    rec.rate_metric("encode_mb_per_s", &m_enc, mb);
    rec.rate_metric("decode_mb_per_s", &m_dec, mb);
    rec
}

// --------------------------------------------------- solve/corpus_sweep

fn corpus_sweep(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let n = ctx.size(2_000, 50_000);
    let specs: Vec<WorkloadSpec> = match &ctx.workload {
        Some(s) => vec![WorkloadSpec::parse(s).expect("--workload spec")],
        None => workloads::corpus(n, 7),
    };
    let registry = SolverRegistry::standard();
    let mut table = Table::new(
        &format!("corpus sweep — auto solver, n≈{n}"),
        &["workload", "n", "m", "cost", "ratio ≤", "wall s"],
    );
    for spec in &specs {
        let g = spec.generate().expect("corpus generate");
        let req = SolveRequest { seed: 11, ..SolveRequest::new(Arc::new(g)) };
        let timer = Timer::start();
        let report = solve_decomposed(&req, &DriverConfig::auto(2), &registry)
            .expect("auto driver cannot fail");
        let wall = timer.elapsed_s();
        let lb = packing_lower_bound(&req.graph);
        let ratio = report.cost.total() as f64 / lb.max(1) as f64;
        table.row(&[
            spec.canonical(),
            req.graph.n().to_string(),
            req.graph.m().to_string(),
            report.cost.total().to_string(),
            fnum(ratio),
            format!("{wall:.3}"),
        ]);
        // Cost is deterministic (noise 0); wall time is informational.
        // Under a `--workload` override the metric is keyed by the full
        // canonical spec, never the bare family name — a compare against
        // a default-sweep baseline must report Missing/New, not diff two
        // different instances under one key.
        let key = if ctx.workload.is_some() {
            spec.canonical()
        } else {
            spec.family().to_string()
        };
        let cost_total = report.cost.total() as f64;
        rec.metric(&format!("{key}_cost"), cost_total, Direction::Lower);
        rec.metric(&format!("{key}_ratio"), ratio, Direction::Info);
    }
    table.print();
    rec
}
