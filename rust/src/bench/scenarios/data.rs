//! Dataset-subsystem scenarios (`data_lab` bin): ingest throughput for
//! both text formats and both snapshot generations, round-trip
//! fidelity, the v2 compression/parallel-decode lab, and the corpus
//! sweep driving the auto solver over every addressable family.

use std::sync::Arc;

use crate::bench::harness;
use crate::bench::suite::{Direction, Registry, Scenario, ScenarioCtx, ScenarioRecord};
use crate::bench::workloads;
use crate::cluster::triangles::packing_lower_bound;
use crate::data::corpus::WorkloadSpec;
use crate::data::{edge_list, snapshot, snapshot_v2};
use crate::mpc::pool::ShardPool;
use crate::solve::{solve_decomposed, DriverConfig, SolveRequest, SolverRegistry};
use crate::util::table::{fnum, Table};
use crate::util::timer::Timer;

pub fn register(r: &mut Registry) {
    r.register(Scenario {
        name: "data/ingest_throughput",
        bin: "data_lab",
        about: "edge-list / CSV / snapshot v1+v2 parse throughput",
        run: ingest_throughput,
    });
    r.register(Scenario {
        name: "data/snapshot_roundtrip",
        bin: "data_lab",
        about: "arbocc-csr v1+v2 round-trip fidelity + encode/decode rates",
        run: snapshot_roundtrip,
    });
    r.register(Scenario {
        name: "data/snapshot_v2_ratio",
        bin: "data_lab",
        about: "v2 columnar compression vs v1 + ShardPool decode speedup",
        run: snapshot_v2_ratio,
    });
    r.register(Scenario {
        name: "solve/corpus_sweep",
        bin: "data_lab",
        about: "auto solver over the generator corpus, ratio vs LB",
        run: corpus_sweep,
    });
}

// ------------------------------------------------- data/ingest_throughput

fn ingest_throughput(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let n = ctx.size(20_000, 200_000);
    let spec = WorkloadSpec::parse(&format!("arboric:n={n},lambda=3,seed=42")).expect("spec");
    let g = spec.generate().expect("corpus generate");
    let mut ws = Vec::new();
    edge_list::write_edges(&g, &mut ws, edge_list::EdgeListFormat::Whitespace).expect("write");
    let mut csv = Vec::new();
    edge_list::write_edges(&g, &mut csv, edge_list::EdgeListFormat::Csv).expect("write");
    let text_ws = String::from_utf8(ws).expect("ascii edge list");
    let text_csv = String::from_utf8(csv).expect("ascii edge list");
    let bytes = snapshot::snapshot_bytes(&g).expect("snapshot encode");
    let v2 = snapshot_v2::snapshot_v2_bytes(&g).expect("v2 encode");
    println!(
        "ingest workload {}: m={} — {} B text, {} B csv, {} B snapshot, {} B v2",
        spec.canonical(),
        g.m(),
        text_ws.len(),
        text_csv.len(),
        bytes.len(),
        v2.len()
    );
    let cfg = ctx.bench_cfg();
    let m_ws = harness::bench_with("edgelist_parse", &cfg, || {
        let (parsed, _) = edge_list::read_edges(&text_ws).expect("parse");
        assert_eq!(parsed.m(), g.m());
    });
    rec.rate_metric("edgelist_edges_per_s", &m_ws, g.m() as f64);
    let m_csv = harness::bench_with("csv_parse", &cfg, || {
        let (parsed, _) = edge_list::read_edges(&text_csv).expect("parse");
        assert_eq!(parsed.m(), g.m());
    });
    rec.rate_metric("csv_edges_per_s", &m_csv, g.m() as f64);
    let m_snap = harness::bench_with("snapshot_read", &cfg, || {
        let parsed = snapshot::read_snapshot_bytes(&bytes).expect("read");
        assert_eq!(parsed.m(), g.m());
    });
    rec.rate_metric("snapshot_edges_per_s", &m_snap, g.m() as f64);
    let per_edge = bytes.len() as f64 / g.m().max(1) as f64;
    rec.metric("snapshot_bytes_per_edge", per_edge, Direction::Info);
    let pool = ShardPool::auto();
    let m_v2 = harness::bench_with("snapshot_v2_read", &cfg, || {
        let parsed = snapshot_v2::read_snapshot_v2_bytes(&v2, &pool).expect("v2 read");
        assert_eq!(parsed.m(), g.m());
    });
    rec.rate_metric("snapshot_v2_edges_per_s", &m_v2, g.m() as f64);
    let v2_per_edge = v2.len() as f64 / g.m().max(1) as f64;
    rec.metric("snapshot_v2_bytes_per_edge", v2_per_edge, Direction::Info);
    rec
}

// ------------------------------------------------ data/snapshot_roundtrip

fn snapshot_roundtrip(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let n = ctx.size(10_000, 100_000);
    let pool = ShardPool::auto();
    for spec_s in [format!("mixed:n={n},seed=4"), format!("powerlaw:n={n},attach=3,seed=4")] {
        let spec = WorkloadSpec::parse(&spec_s).expect("spec");
        let g = spec.generate().expect("corpus generate");
        let bytes = snapshot::snapshot_bytes(&g).expect("encode");
        let back = snapshot::read_snapshot_bytes(&bytes).expect("read");
        assert_eq!(back, g, "{spec_s}: snapshot round-trip must be lossless");
        assert_eq!(
            snapshot::snapshot_bytes(&back).expect("encode"),
            bytes,
            "{spec_s}: re-encoding must be byte-identical"
        );
        let v2 = snapshot_v2::snapshot_v2_bytes(&g).expect("v2 encode");
        let back2 = snapshot_v2::read_snapshot_v2_bytes(&v2, &pool).expect("v2 read");
        assert_eq!(back2, g, "{spec_s}: v2 round-trip must be lossless");
        assert_eq!(
            snapshot_v2::snapshot_v2_bytes(&back2).expect("v2 encode"),
            v2,
            "{spec_s}: v2 re-encoding must be byte-identical"
        );
        println!(
            "roundtrip {} OK: {} B v1 / {} B v2 for m={}",
            spec.canonical(),
            bytes.len(),
            v2.len(),
            g.m()
        );
    }
    let g = WorkloadSpec::parse(&format!("mixed:n={n},seed=4"))
        .expect("spec")
        .generate()
        .expect("generate");
    let cfg = ctx.bench_cfg();
    let m_enc = harness::bench_with("snapshot_encode", &cfg, || {
        let b = snapshot::snapshot_bytes(&g).expect("encode");
        assert!(b.len() > 32);
    });
    let bytes = snapshot::snapshot_bytes(&g).expect("encode");
    let m_dec = harness::bench_with("snapshot_decode", &cfg, || {
        let parsed = snapshot::read_snapshot_bytes(&bytes).expect("read");
        assert_eq!(parsed.n(), g.n());
    });
    let mb = bytes.len() as f64 / (1024.0 * 1024.0);
    rec.rate_metric("encode_mb_per_s", &m_enc, mb);
    rec.rate_metric("decode_mb_per_s", &m_dec, mb);
    let m_enc2 = harness::bench_with("snapshot_v2_encode", &cfg, || {
        let b = snapshot_v2::snapshot_v2_bytes(&g).expect("v2 encode");
        assert!(b.len() > 56);
    });
    let v2 = snapshot_v2::snapshot_v2_bytes(&g).expect("v2 encode");
    let m_dec2 = harness::bench_with("snapshot_v2_decode", &cfg, || {
        let parsed = snapshot_v2::read_snapshot_v2_bytes(&v2, &pool).expect("v2 read");
        assert_eq!(parsed.n(), g.n());
    });
    // Rates are per *decoded* (v1-equivalent) megabyte so v1 and v2 are
    // comparable: v2 moves fewer bytes for the same graph.
    rec.rate_metric("v2_encode_mb_per_s", &m_enc2, mb);
    rec.rate_metric("v2_decode_mb_per_s", &m_dec2, mb);
    rec
}

// ------------------------------------------------ data/snapshot_v2_ratio

/// The v2 acceptance lab: on a planted low-arboricity workload (the
/// regime this repo targets — ≥1M undirected edges at the full tier),
/// pin (a) v2 compression vs v1, (b) bit-identical v1→v2→v1
/// transcoding, and (c) the ShardPool parallel-decode speedup.
fn snapshot_v2_ratio(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let spec_s = ctx.pick(
        "planted:n=4000,k=40,pin=0.9,p=0.00002,seed=7",
        "planted:n=24000,k=200,pin=0.9,p=0.00002,seed=7",
    );
    let spec = WorkloadSpec::parse(spec_s).expect("spec");
    let g = spec.generate().expect("corpus generate");
    let v1 = snapshot::snapshot_bytes(&g).expect("v1 encode");
    let v2 = snapshot_v2::snapshot_v2_bytes(&g).expect("v2 encode");
    // Cross-format fidelity: v1 → v2 → v1 must be byte-identical.
    let auto = ShardPool::auto();
    let via_v1 = snapshot::read_snapshot_bytes(&v1).expect("v1 read");
    let via_v2 = snapshot_v2::read_snapshot_v2_bytes(&v2, &auto).expect("v2 read");
    assert_eq!(via_v2, g, "{spec_s}: v2 round-trip must be lossless");
    assert_eq!(via_v1, via_v2, "{spec_s}: v1 and v2 must decode the same graph");
    assert_eq!(
        snapshot::snapshot_bytes(&via_v2).expect("re-encode"),
        v1,
        "{spec_s}: v1→v2→v1 must be bit-identical"
    );
    let ratio = v1.len() as f64 / v2.len().max(1) as f64;
    println!(
        "{spec_s}: m={} — v1 {} B, v2 {} B, ratio {ratio:.2}x",
        g.m(),
        v1.len(),
        v2.len()
    );
    rec.metric("v1_bytes_per_edge", v1.len() as f64 / g.m().max(1) as f64, Direction::Info);
    rec.metric("v2_bytes_per_edge", v2.len() as f64 / g.m().max(1) as f64, Direction::Info);
    rec.metric("compression_ratio", ratio, Direction::Higher);
    let cfg = ctx.bench_cfg();
    let serial = ShardPool::serial();
    let m_serial = harness::bench_with("v2_decode_serial", &cfg, || {
        let parsed = snapshot_v2::read_snapshot_v2_bytes(&v2, &serial).expect("v2 read");
        assert_eq!(parsed.m(), g.m());
    });
    let m_auto = harness::bench_with("v2_decode_parallel", &cfg, || {
        let parsed = snapshot_v2::read_snapshot_v2_bytes(&v2, &auto).expect("v2 read");
        assert_eq!(parsed.m(), g.m());
    });
    rec.rate_metric("v2_decode_edges_per_s", &m_auto, g.m() as f64);
    rec.speedup_metric("parallel_decode_speedup", &m_serial, &m_auto);
    rec.metric("decode_shards", auto.shards() as f64, Direction::Info);
    rec
}

// --------------------------------------------------- solve/corpus_sweep

fn corpus_sweep(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let n = ctx.size(2_000, 50_000);
    let specs: Vec<WorkloadSpec> = match &ctx.workload {
        Some(s) => vec![WorkloadSpec::parse(s).expect("--workload spec")],
        None => workloads::corpus(n, 7),
    };
    let registry = SolverRegistry::standard();
    let mut table = Table::new(
        &format!("corpus sweep — auto solver, n≈{n}"),
        &["workload", "n", "m", "cost", "ratio ≤", "wall s"],
    );
    for spec in &specs {
        let g = spec.generate().expect("corpus generate");
        let req = SolveRequest { seed: 11, ..SolveRequest::new(Arc::new(g)) };
        let timer = Timer::start();
        let report = solve_decomposed(&req, &DriverConfig::auto(2), &registry)
            .expect("auto driver cannot fail");
        let wall = timer.elapsed_s();
        let lb = packing_lower_bound(&req.graph);
        let ratio = report.cost.total() as f64 / lb.max(1) as f64;
        table.row(&[
            spec.canonical(),
            req.graph.n().to_string(),
            req.graph.m().to_string(),
            report.cost.total().to_string(),
            fnum(ratio),
            format!("{wall:.3}"),
        ]);
        // Cost is deterministic (noise 0); wall time is informational.
        // Under a `--workload` override the metric is keyed by the full
        // canonical spec, never the bare family name — a compare against
        // a default-sweep baseline must report Missing/New, not diff two
        // different instances under one key.
        let key = if ctx.workload.is_some() {
            spec.canonical()
        } else {
            spec.family().to_string()
        };
        let cost_total = report.cost.total() as f64;
        rec.metric(&format!("{key}_cost"), cost_total, Direction::Lower);
        rec.metric(&format!("{key}_ratio"), ratio, Direction::Info);
    }
    table.print();
    rec
}
