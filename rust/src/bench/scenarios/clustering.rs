//! Cost/approximation scenarios: the Lemma 25 structural bound (E1),
//! Algorithm 4 degree filtering (E2), the full MPC PIVOT round/ratio
//! sweeps (E3), the O(λ²) simple algorithm (E9), the §1.4 baseline
//! head-to-head (E10) and the Remark 14 best-of-K driver (E12).

use std::sync::Arc;

use crate::algorithms::alg4::{alg4, degree_threshold, split_high_degree};
use crate::algorithms::baselines::{c4, clusterwild, parallel_pivot};
use crate::algorithms::mpc_mis::{
    mpc_pivot, Alg1Params, Alg2Params, Alg3Params, Subroutine,
};
use crate::algorithms::pivot::{pivot, pivot_random};
use crate::algorithms::simple::simple_clustering;
use crate::bench::suite::{Direction, Registry, Scenario, ScenarioCtx, ScenarioRecord};
use crate::bench::workloads;
use crate::cluster::cost::cost;
use crate::cluster::exact::{exact_cost, solve_exact};
use crate::cluster::structural::bound_cluster_sizes;
use crate::cluster::triangles::packing_lower_bound;
use crate::cluster::Clustering;
use crate::coordinator::{best_of_k, TrialSpec};
use crate::graph::generators::{barabasi_albert, barbell, disjoint_cliques, lambda_arboric, Family};
use crate::mpc::memory::Words;
use crate::mpc::{MpcConfig, MpcSimulator};
use crate::runtime::CostEngine;
use crate::util::rng::Rng;
use crate::util::stats::{linear_fit, max, mean, min};
use crate::util::table::{fnum, Table};
use crate::util::timer::Timer;

pub fn register(r: &mut Registry) {
    r.register(Scenario {
        name: "e1/structural_bound",
        bin: "e1_structural",
        about: "Lemma 25: cluster sizes ≤ 4λ−2 at no cost increase",
        run: e1_structural_bound,
    });
    r.register(Scenario {
        name: "e2/alg4_filtering",
        bin: "e2_alg4",
        about: "Theorem 26: high-degree filtering costs ≤ max{1+ε, α}",
        run: e2_alg4_filtering,
    });
    r.register(Scenario {
        name: "e3/mpc_pivot_rounds",
        bin: "e3_clustering",
        about: "Corollary 28: MPC PIVOT ratio and round sweeps",
        run: e3_mpc_pivot_rounds,
    });
    r.register(Scenario {
        name: "e9/simple_clustering",
        bin: "e9_simple",
        about: "Corollary 32: O(λ²) worst case in O(1) rounds",
        run: e9_simple_clustering,
    });
    r.register(Scenario {
        name: "e10/baselines",
        bin: "e10_baselines",
        about: "§1.4 head-to-head vs C4, ClusterWild!, ParallelPivot",
        run: e10_baselines,
    });
    r.register(Scenario {
        name: "e12/best_of_k",
        bin: "e12_best_of_k",
        about: "Remark 14: best-of-K concentration and scorer throughput",
        run: e12_best_of_k,
    });
}

// ---------------------------------------------------------------- E1

fn e1_structural_bound(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let mut table = Table::new(
        "E1 — Lemma 25 structural bound (limit = 4λ−2)",
        &["λ", "mode", "instances", "cost preserved", "max|C| ≤ 4λ−2", "worst max|C|"],
    );

    // (a) exact instances: the transform preserves optimal cost.
    let exact_lambdas = ctx.sweep(&[1usize, 2], &[1, 2, 3]);
    let trials = ctx.size(8, 30);
    for &lambda in &exact_lambdas {
        let mut rng = Rng::new(1000 + lambda as u64);
        let mut preserved = 0;
        let mut bounded = 0;
        let mut worst = 0usize;
        for _ in 0..trials {
            let g = lambda_arboric(11, lambda, &mut rng);
            let (opt, opt_cost) = solve_exact(&g);
            let res = bound_cluster_sizes(&g, &opt, lambda);
            if cost(&g, &res.clustering).total() == opt_cost.total() {
                preserved += 1;
            }
            if res.max_cluster_size <= 4 * lambda - 2 {
                bounded += 1;
            }
            worst = worst.max(res.max_cluster_size);
        }
        table.row(&[
            lambda.to_string(),
            "exact-opt (n=11)".into(),
            trials.to_string(),
            format!("{preserved}/{trials}"),
            format!("{bounded}/{trials}"),
            worst.to_string(),
        ]);
        assert_eq!(preserved, trials, "transform must preserve optimal cost");
        assert_eq!(bounded, trials);
    }

    // (b) large instances: never increases cost, always lands in bound.
    let large_lambdas = ctx.sweep(&[2usize, 8], &[1, 2, 4, 8]);
    let n = ctx.size(1_500, 5_000);
    let large_trials = ctx.size(2, 5);
    for &lambda in &large_lambdas {
        let mut rng = Rng::new(2000 + lambda as u64);
        let mut non_increase = 0;
        let mut bounded = 0;
        let mut worst = 0usize;
        for _ in 0..large_trials {
            let g = lambda_arboric(n, lambda, &mut rng);
            for start in [Clustering::single_cluster(g.n()), pivot_random(&g, &mut rng)] {
                let before = cost(&g, &start).total();
                let res = bound_cluster_sizes(&g, &start, lambda);
                if cost(&g, &res.clustering).total() <= before {
                    non_increase += 1;
                }
                if res.max_cluster_size <= 4 * lambda - 2 {
                    bounded += 1;
                }
                worst = worst.max(res.max_cluster_size);
            }
        }
        table.row(&[
            lambda.to_string(),
            format!("large (n={n})"),
            (2 * large_trials).to_string(),
            format!("{non_increase}/{}", 2 * large_trials),
            format!("{bounded}/{}", 2 * large_trials),
            worst.to_string(),
        ]);
        assert_eq!(non_increase, 2 * large_trials);
        assert_eq!(bounded, 2 * large_trials);
        if lambda == 8 {
            rec.metric("worst_max_cluster_lambda8", worst as f64, Direction::Info);
        }
    }
    table.print();
    rec.metric("bound_violations", 0.0, Direction::Lower);
    rec
}

// ---------------------------------------------------------------- E2

fn e2_alg4_filtering(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let eps_sweep = ctx.sweep(&[1.0f64, 2.0], &[0.5, 1.0, 2.0, 4.0]);

    // (a) vs exact optima.
    let trials = ctx.size(8, 25);
    let mut ta = Table::new(
        &format!("E2a — Alg4(exact inner) vs OPT, n=12, λ=1 (worst over {trials} seeds)"),
        &["ε", "bound max{1+ε,1}", "worst ratio", "mean ratio"],
    );
    let mut worst_exact = 0.0f64;
    for &eps in &eps_sweep {
        let mut rng = Rng::new(3000);
        let mut ratios = Vec::new();
        for _ in 0..trials {
            let g = lambda_arboric(12, 1, &mut rng);
            let opt = exact_cost(&g);
            let c = alg4(&g, 1, eps, |sub| solve_exact(sub).0);
            let got = cost(&g, &c).total();
            if opt > 0 {
                ratios.push(got as f64 / opt as f64);
            } else {
                assert_eq!(got, 0, "zero-opt instance must stay zero");
            }
        }
        let worst = ratios.iter().copied().fold(0.0, f64::max);
        let bound = (1.0 + eps).max(1.0);
        assert!(worst <= bound + 1e-9, "Theorem 26 violated: {worst} > {bound}");
        worst_exact = worst_exact.max(worst);
        ta.row(&[eps.to_string(), fnum(bound), fnum(worst), fnum(mean(&ratios))]);
    }
    ta.print();
    rec.metric("exact_worst_ratio", worst_exact, Direction::Info);

    // (b) at scale with PIVOT inner.
    let n = ctx.size(4_000, 20_000);
    let repeats = ctx.size(2, 5);
    let mut tb = Table::new(
        &format!("E2b — Alg4(PIVOT) on BA(n={n}, m=3), λ=3: ratio vs triangle LB"),
        &["ε", "threshold", "filtered |H|", "mean cost", "ratio≤ (vs LB)"],
    );
    let mut rng = Rng::new(3100);
    let g = barabasi_albert(n, 3, &mut rng);
    let lambda = 3usize;
    let lb = packing_lower_bound(&g).max(1);
    for &eps in &eps_sweep {
        let (_, high) = split_high_degree(&g, lambda, eps);
        let costs: Vec<f64> = (0..repeats)
            .map(|_| {
                let c = alg4(&g, lambda, eps, |sub| pivot_random(sub, &mut rng));
                cost(&g, &c).total() as f64
            })
            .collect();
        let m = mean(&costs);
        tb.row(&[
            eps.to_string(),
            fnum(degree_threshold(lambda, eps)),
            high.len().to_string(),
            fnum(m),
            fnum(m / lb as f64),
        ]);
        if eps == 2.0 {
            rec.metric("ba_ratio_ub_eps2", m / lb as f64, Direction::Lower);
        }
    }
    tb.print();
    rec
}

// ---------------------------------------------------------------- E3

/// One (n, λ) cell: mean (ratio ub, rounds M1, rounds M2) over seeds.
fn e3_cell(n: usize, lambda: usize, seeds: u64) -> (f64, f64, f64) {
    let mut ratios = Vec::new();
    let mut rounds1 = Vec::new();
    let mut rounds2 = Vec::new();
    for s in 0..seeds {
        let mut rng = Rng::new(4000 + s * 7919 + (n as u64) + ((lambda as u64) << 20));
        let g = lambda_arboric(n, lambda, &mut rng);
        let words = (g.n() + 2 * g.m()) as Words;
        let perm = rng.permutation(g.n());
        let lb = packing_lower_bound(&g).max(1);

        let mut sim1 = MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));
        let run1 = mpc_pivot(
            &g,
            &perm,
            &Alg1Params { c_prefix: 1.0, subroutine: Subroutine::Alg2(Alg2Params::default()) },
            &mut sim1,
        );
        ratios.push(cost(&g, &run1.clustering).total() as f64 / lb as f64);
        rounds1.push(sim1.n_rounds() as f64);

        let mut sim2 = MpcSimulator::new(MpcConfig::model2(g.n(), words, 0.5));
        let run2 = mpc_pivot(
            &g,
            &perm,
            &Alg1Params { c_prefix: 1.0, subroutine: Subroutine::Alg3(Alg3Params::default()) },
            &mut sim2,
        );
        assert_eq!(
            run1.clustering.normalize(),
            run2.clustering.normalize(),
            "M1 and M2 pipelines must agree"
        );
        rounds2.push(sim2.n_rounds() as f64);
    }
    (mean(&ratios), mean(&rounds1), mean(&rounds2))
}

fn e3_mpc_pivot_rounds(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let seeds = ctx.pick(1u64, 3u64);

    // λ sweep at fixed n.
    let n = ctx.size(4_000, 20_000);
    let lambdas = ctx.sweep(&[1usize, 4, 16], &[1, 2, 4, 8, 16]);
    let mut t1 = Table::new(
        &format!("E3a — MPC PIVOT, n={n}, λ sweep ({seeds} seed(s) each)"),
        &["λ", "ratio≤ (vs LB)", "rounds M1", "rounds M2"],
    );
    let mut log_lams = Vec::new();
    let mut r1s = Vec::new();
    for &lambda in &lambdas {
        let (ratio, r1, r2) = e3_cell(n, lambda, seeds);
        t1.row(&[lambda.to_string(), fnum(ratio), fnum(r1), fnum(r2)]);
        log_lams.push((lambda.max(2) as f64).log2());
        r1s.push(r1);
        rec.metric(&format!("lambda{lambda}_rounds_m1"), r1, Direction::Lower);
        rec.metric(&format!("lambda{lambda}_rounds_m2"), r2, Direction::Lower);
        if lambda == 4 {
            rec.metric("ratio_lambda4", ratio, Direction::Lower);
        }
    }
    t1.print();
    let (_, slope, r2fit) = linear_fit(&log_lams, &r1s);
    println!(
        "rounds(M1) vs log2 λ: slope {slope:.1} per doubling (r²={r2fit:.3}) — the log λ factor"
    );
    rec.metric("rounds_vs_loglambda_slope", slope, Direction::Info);

    // n sweep at fixed λ.
    let lambda = 4usize;
    let full_ns = [2_000usize, 8_000, 32_000, 128_000];
    let ns = workloads::ladder(ctx.tier, &full_ns);
    let mut t2 = Table::new(
        &format!("E3b — MPC PIVOT, λ={lambda}, n sweep ({seeds} seed(s) each)"),
        &["n", "ratio≤ (vs LB)", "rounds M1", "rounds M2", "loglog n"],
    );
    for &n in &ns {
        let (ratio, r1, r2) = e3_cell(n, lambda, seeds);
        t2.row(&[
            n.to_string(),
            fnum(ratio),
            fnum(r1),
            fnum(r2),
            fnum((n as f64).log2().log2()),
        ]);
        rec.metric(&format!("n{n}_rounds_m1"), r1, Direction::Lower);
        if n >= 2_000 {
            assert!(ratio <= 3.5, "ratio upper bound should stay near/below 3 (got {ratio})");
        }
    }
    t2.print();
    rec
}

// ---------------------------------------------------------------- E9

fn e9_sim_for(n: usize, m: usize) -> MpcSimulator {
    MpcSimulator::new(MpcConfig::model1(n.max(2), (n + 2 * m).max(4) as Words, 0.5))
}

fn e9_simple_clustering(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();

    // (a) clique unions are solved exactly.
    let g = disjoint_cliques(50, 6);
    let mut s = e9_sim_for(g.n(), g.m());
    let run = simple_clustering(&g, 3, &mut s);
    println!(
        "E9a — 50×K6: cost {} (OPT 0), {} clique clusters, {} rounds",
        cost(&g, &run.clustering).total(),
        run.clique_clusters,
        run.rounds
    );
    assert_eq!(cost(&g, &run.clustering).total(), 0);

    // (b) barbell tightness (Remark 33).
    let barbell_lambdas = ctx.sweep(&[3usize, 5], &[3, 4, 5, 6]);
    let mut tb = Table::new(
        "E9b — Remark 33 barbell K_λ–K_λ: simple vs OPT",
        &["λ", "simple cost", "OPT", "ratio", "λ²"],
    );
    for &lambda in &barbell_lambdas {
        let g = barbell(lambda);
        let mut s = e9_sim_for(g.n(), g.m());
        let run = simple_clustering(&g, lambda, &mut s);
        let got = cost(&g, &run.clustering).total();
        let opt = exact_cost(&g);
        tb.row(&[
            lambda.to_string(),
            got.to_string(),
            opt.to_string(),
            fnum(got as f64 / opt.max(1) as f64),
            (lambda * lambda).to_string(),
        ]);
        assert_eq!(opt, 1);
        assert!(got as f64 >= (lambda * (lambda - 1)) as f64, "tightness shape");
        if lambda == 5 {
            rec.metric("barbell5_ratio", got as f64 / opt as f64, Direction::Info);
        }
    }
    tb.print();

    // (c) O(1) rounds across n.
    let ns = ctx.sweep(&[1_000usize, 10_000], &[1_000, 10_000, 100_000]);
    let mut tc = Table::new("E9c — round counts vs n (must be flat)", &["n", "rounds"]);
    let mut rounds_seen = Vec::new();
    for &n in &ns {
        let mut rng = Rng::new(9900 + n as u64);
        let g = lambda_arboric(n, 2, &mut rng);
        let mut s = e9_sim_for(g.n(), g.m());
        let run = simple_clustering(&g, 2, &mut s);
        tc.row(&[n.to_string(), run.rounds.to_string()]);
        rounds_seen.push(run.rounds);
    }
    tc.print();
    let spread = rounds_seen.iter().max().unwrap() - rounds_seen.iter().min().unwrap();
    assert!(spread <= 2, "rounds must be O(1): saw spread {spread}");
    rec.metric("rounds_n1000", rounds_seen[0] as f64, Direction::Lower);
    rec.metric("rounds_spread", spread as f64, Direction::Lower);
    rec
}

// ---------------------------------------------------------------- E10

fn e10_baselines(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let families = ctx.sweep(
        &[Family::LambdaArboric(3), Family::Forest],
        &[Family::LambdaArboric(3), Family::BarabasiAlbert(3), Family::Forest],
    );
    let n = ctx.size(4_000, 20_000);
    let seeds = ctx.pick(1u64, 3u64);

    let mut table = Table::new(
        &format!("E10 — baselines on n={n} (mean over {seeds} seed(s)): ratio≤ vs LB | rounds"),
        &[
            "family", "PIVOT(seq)", "ours M1", "ours rounds", "C4", "C4 rounds", "Wild!",
            "Wild rounds", "PPivot", "PP rounds",
        ],
    );

    for &family in &families {
        let mut acc: std::collections::HashMap<&str, Vec<f64>> = Default::default();
        for s in 0..seeds {
            let mut rng = Rng::new(10_000 + s * 101);
            let g = family.generate(n, &mut rng);
            let perm = rng.permutation(g.n());
            let lb = packing_lower_bound(&g).max(1) as f64;
            let words = (g.n() + 2 * g.m()) as Words;
            let sim = || MpcSimulator::new(MpcConfig::model1(g.n(), words, 0.5));

            let seq = pivot(&g, &perm);
            acc.entry("pivot").or_default().push(cost(&g, &seq).total() as f64 / lb);

            let mut s1 = sim();
            let ours = mpc_pivot(
                &g,
                &perm,
                &Alg1Params {
                    c_prefix: 1.0,
                    subroutine: Subroutine::Alg2(Alg2Params::default()),
                },
                &mut s1,
            );
            assert_eq!(ours.clustering.normalize(), seq.normalize(), "ours ≡ PIVOT");
            acc.entry("ours").or_default().push(cost(&g, &ours.clustering).total() as f64 / lb);
            acc.entry("ours_r").or_default().push(s1.n_rounds() as f64);

            let mut s2 = sim();
            let r = c4::c4(&g, &perm, 0.9, &mut s2);
            assert_eq!(r.clustering.normalize(), seq.normalize(), "C4 ≡ PIVOT");
            acc.entry("c4").or_default().push(cost(&g, &r.clustering).total() as f64 / lb);
            acc.entry("c4_r").or_default().push(r.rounds as f64);

            let mut s3 = sim();
            let r = clusterwild::clusterwild(&g, &perm, 0.9, &mut s3);
            acc.entry("wild").or_default().push(cost(&g, &r.clustering).total() as f64 / lb);
            acc.entry("wild_r").or_default().push(r.rounds as f64);

            let mut s4 = sim();
            let r = parallel_pivot::parallel_pivot(&g, &perm, 0.5, &mut rng, &mut s4);
            acc.entry("pp").or_default().push(cost(&g, &r.clustering).total() as f64 / lb);
            acc.entry("pp_r").or_default().push(r.rounds as f64);
        }
        let m = |k: &str| mean(&acc[k]);
        table.row(&[
            family.name(),
            fnum(m("pivot")),
            fnum(m("ours")),
            fnum(m("ours_r")),
            fnum(m("c4")),
            fnum(m("c4_r")),
            fnum(m("wild")),
            fnum(m("wild_r")),
            fnum(m("pp")),
            fnum(m("pp_r")),
        ]);
        let fam = family.name();
        rec.metric(&format!("{fam}_ours_ratio"), m("ours"), Direction::Lower);
        rec.metric(&format!("{fam}_ours_rounds"), m("ours_r"), Direction::Lower);
        rec.metric(&format!("{fam}_wild_rounds"), m("wild_r"), Direction::Lower);
        // Shape: ClusterWild! never beats PIVOT on cost but wins on rounds.
        assert!(
            m("wild") + 1e-9 >= m("pivot") * 0.95,
            "Wild! shouldn't beat PIVOT systematically"
        );
        assert!(m("wild_r") <= m("c4_r") + 1e-9, "Wild! must not use more rounds than C4");
    }
    table.print();
    rec
}

// ---------------------------------------------------------------- E12

fn e12_best_of_k(ctx: &ScenarioCtx) -> ScenarioRecord {
    let mut rec = ScenarioRecord::new();
    let n = ctx.size(5_000, 20_000);
    let ks = ctx.sweep(&[1usize, 4, 8], &[1, 2, 4, 8, 16, 32]);
    let seeds = ctx.pick(2u64, 5u64);
    let slack = ctx.pick(1.08, 1.02);

    let mut rng = Rng::new(12_000);
    let g = Arc::new(lambda_arboric(n, 4, &mut rng));
    let lb = packing_lower_bound(&g).max(1) as f64;
    let engine = CostEngine::native();

    let mut table = Table::new(
        &format!("E12 — best-of-K on arboric-4 (n={n}), {seeds} seed(s)"),
        &["K", "mean best ratio≤", "min", "max", "spread", "trials/s"],
    );
    let mut prev_mean = f64::INFINITY;
    for &k in &ks {
        let mut bests = Vec::new();
        let mut thru = Vec::new();
        for s in 0..seeds {
            let t = Timer::start();
            let run = best_of_k(
                &g,
                &TrialSpec::Alg4Pivot { lambda: 4, eps: 2.0 },
                k,
                4,
                999 + s,
                &engine,
            )
            .unwrap();
            thru.push(k as f64 / t.elapsed_s());
            bests.push(run.best_cost.total() as f64 / lb);
        }
        let m = mean(&bests);
        table.row(&[
            k.to_string(),
            fnum(m),
            fnum(min(&bests)),
            fnum(max(&bests)),
            fnum(max(&bests) - min(&bests)),
            fnum(mean(&thru)),
        ]);
        if k == 8 {
            rec.metric("k8_mean_ratio", m, Direction::Lower);
            rec.metric("k8_spread", max(&bests) - min(&bests), Direction::Info);
            let t = mean(&thru);
            rec.metric_with_noise(
                "k8_trials_per_s",
                t,
                t * 0.25 + crate::util::stats::mad(&thru),
                Direction::Higher,
            );
        }
        assert!(m <= prev_mean * slack, "best-of-K mean must not grow with K");
        prev_mean = m;
    }
    table.print();
    rec
}
