//! Solver-engine scenarios (owned by the `solve_engine` bin):
//!
//! * `solve/planner_overhead`  — what the structure inspection costs
//!   relative to the full auto-routed solve it steers;
//! * `solve/component_speedup` — the per-component decomposition driver
//!   at 1 shard vs all hardware threads on a multi-component workload
//!   (the gated tentpole metric: sharding must beat a single shard);
//! * `solve/mixed_families`    — auto-routed solves across forest, grid
//!   and scale-free inputs, with the planner's routes asserted;
//! * `solve/delta_speedup`     — the warm-start incremental driver
//!   replaying a drift stream vs from-scratch re-solves of every
//!   post-batch graph (gated: incremental must stay ahead on the
//!   multi-component planted corpus; the connected powerlaw leg
//!   documents the bound where every batch dirties the one component).

use std::sync::Arc;

use crate::bench::harness::bench_with;
use crate::bench::suite::{Direction, Registry, Scenario, ScenarioCtx, ScenarioRecord};
use crate::data::corpus::WorkloadSpec;
use crate::data::delta::drift_batches;
use crate::graph::generators::{barabasi_albert, disjoint_union, grid, lambda_arboric, random_forest};
use crate::graph::Graph;
use crate::solve::{
    plan, solve_decomposed, DriverConfig, IncrementalState, SolveCtx, SolveRequest,
    SolverRegistry,
};
use crate::util::rng::Rng;
use crate::util::table::fnum;

const BIN: &str = "solve_engine";

pub fn register(r: &mut Registry) {
    r.register(Scenario {
        name: "solve/planner_overhead",
        bin: BIN,
        about: "planner inspection cost vs the full auto-routed solve",
        run: planner_overhead,
    });
    r.register(Scenario {
        name: "solve/component_speedup",
        bin: BIN,
        about: "per-component sharded driver: 1 shard vs all threads",
        run: component_speedup,
    });
    r.register(Scenario {
        name: "solve/mixed_families",
        bin: BIN,
        about: "auto-routed solves across forest/grid/scale-free",
        run: mixed_families,
    });
    r.register(Scenario {
        name: "solve/delta_speedup",
        bin: BIN,
        about: "warm-start delta replay vs from-scratch re-solves",
        run: delta_speedup,
    });
}

fn planner_overhead(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let n = ctx.size(20_000, 200_000);
    let mut rng = Rng::new(14_000);
    let g = barabasi_albert(n, 3, &mut rng);
    let mp = bench_with(&format!("planner inspection (n={n})"), &cfg, || {
        std::hint::black_box(plan(&g, None));
    });
    println!("{mp}");
    let registry = SolverRegistry::standard();
    let auto = registry.get("auto").expect("auto registered");
    let req = SolveRequest { seed: 42, ..SolveRequest::new(Arc::new(g)) };
    let ms = bench_with(&format!("auto solve end-to-end (n={n})"), &cfg, || {
        std::hint::black_box(auto.solve(&req, &mut SolveCtx::serial()));
    });
    println!("{ms}");
    let frac = mp.median_s / ms.median_s.max(1e-12);
    println!("    ⇒ planning is ×{} of the solve it steers", fnum(frac));
    let mut rec = ScenarioRecord::new();
    rec.time_metric("plan", &mp);
    rec.time_metric("auto_solve", &ms);
    rec.metric("plan_frac", frac, Direction::Info);
    rec
}

fn component_speedup(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let k = 8usize;
    let comp_n = ctx.size(4_000, 40_000);
    let mut rng = Rng::new(14_100);
    let parts: Vec<Graph> = (0..k).map(|_| lambda_arboric(comp_n, 3, &mut rng)).collect();
    let g = Arc::new(disjoint_union(&parts));
    let req = SolveRequest { seed: 7, ..SolveRequest::new(g) };
    let registry = SolverRegistry::standard();

    // Bit-identical stitched labels at both shard counts (the driver's
    // tentpole invariant), checked outside the timed region.
    let one = solve_decomposed(&req, &DriverConfig::auto(1), &registry).unwrap();
    let many = solve_decomposed(&req, &DriverConfig::auto(shards), &registry).unwrap();
    assert_eq!(
        one.clustering.labels(),
        many.clustering.labels(),
        "sharded driver must be bit-identical to serial"
    );

    let m1 = bench_with(&format!("driver ({k}×{comp_n}, 1 shard)"), &cfg, || {
        std::hint::black_box(
            solve_decomposed(&req, &DriverConfig::auto(1), &registry).unwrap(),
        );
    });
    println!("{m1}");
    let mn = bench_with(&format!("driver ({k}×{comp_n}, {shards} shards)"), &cfg, || {
        std::hint::black_box(
            solve_decomposed(&req, &DriverConfig::auto(shards), &registry).unwrap(),
        );
    });
    println!("{mn}");
    println!(
        "    ⇒ component-parallel speedup ×{}",
        fnum(m1.median_s / mn.median_s.max(1e-12))
    );

    let mut rec = ScenarioRecord::new();
    rec.speedup_metric("component_speedup", &m1, &mn);
    rec.metric("components", k as f64, Direction::Info);
    rec.metric("shards", shards as f64, Direction::Info);
    rec
}

fn delta_speedup(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let registry = SolverRegistry::standard();
    let batches = 4usize;
    let n = ctx.size(4_000, 40_000);
    // Two corpus legs: `planted` at p=0 is many components (deltas dirty
    // a few, the rest ride the cache), `powerlaw` is one connected
    // component (every delta dirties it — the honest lower bound).
    let legs: [(&str, String); 2] = [
        ("planted", format!("planted:n={n},k=16,p=0,seed=7")),
        ("powerlaw", format!("powerlaw:n={n},attach=3,seed=7")),
    ];

    let mut rec = ScenarioRecord::new();
    rec.metric("batches", batches as f64, Direction::Info);
    for (tag, spec_s) in &legs {
        let base = WorkloadSpec::parse(spec_s).unwrap().generate().unwrap();
        let req = SolveRequest { seed: 11, ..SolveRequest::new(Arc::new(base)) };
        let dcfg = DriverConfig::auto(shards);
        let stream = drift_batches(&req.graph, batches, 0.002, 901).unwrap();
        let ops: usize = stream.iter().map(|b| b.ops.len()).sum();

        // Warm session plus bit-identity replay, all outside the timed
        // region: every batch's incremental result must equal the
        // from-scratch solve of its post-batch graph (the golden
        // contract — asserted here so a regression fails the run, not
        // just the metric).
        let warm = IncrementalState::new(req.clone(), dcfg.clone(), &registry).unwrap();
        let mut check = warm.clone();
        let mut posts: Vec<SolveRequest> = Vec::new();
        let mut dirty_total = 0usize;
        for batch in &stream {
            let rep = check.apply_batch(batch, &registry).unwrap();
            let preq = SolveRequest { graph: check.graph().clone(), ..req.clone() };
            let scratch = solve_decomposed(&preq, &dcfg, &registry).unwrap();
            assert_eq!(
                rep.clustering.labels(),
                scratch.clustering.labels(),
                "{tag}: incremental replay must be bit-identical to scratch"
            );
            dirty_total += check.stats().dirty;
            posts.push(preq);
        }

        let ms = bench_with(&format!("{tag}: scratch re-solve ×{batches} (n={n})"), &cfg, || {
            for preq in &posts {
                std::hint::black_box(
                    solve_decomposed(preq, &dcfg, &registry).unwrap(),
                );
            }
        });
        println!("{ms}");
        // The per-iteration session clone is charged to the incremental
        // side, so the metric is conservative.
        let mi = bench_with(&format!("{tag}: incremental replay ×{batches}"), &cfg, || {
            let mut s = warm.clone();
            for batch in &stream {
                std::hint::black_box(s.apply_batch(batch, &registry).unwrap());
            }
        });
        println!("{mi}");
        println!(
            "    ⇒ {tag}: warm-start speedup ×{} ({ops} op(s), {dirty_total} dirty \
             component-solve(s) across {batches} batches)",
            fnum(ms.median_s / mi.median_s.max(1e-12))
        );
        rec.speedup_metric(&format!("{tag}_speedup"), &ms, &mi);
        rec.metric(&format!("{tag}_ops"), ops as f64, Direction::Info);
        rec.metric(&format!("{tag}_dirty"), dirty_total as f64, Direction::Info);
    }
    rec
}

fn mixed_families(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let n = ctx.size(8_000, 80_000);
    let side = (n as f64).sqrt().ceil() as usize;
    let mut rng = Rng::new(14_200);
    let workloads: Vec<(&str, Graph, &str)> = vec![
        ("forest", random_forest(n, 0.9, &mut rng), "forest"),
        ("grid", grid(side, side), "simple"),
        ("ba", barabasi_albert(n, 3, &mut rng), "alg4-pivot"),
    ];
    let registry = SolverRegistry::standard();
    let reqs: Vec<(&str, SolveRequest, &str)> = workloads
        .into_iter()
        .map(|(name, g, want)| (name, SolveRequest { seed: 5, ..SolveRequest::new(Arc::new(g)) }, want))
        .collect();

    // Route checks (cheap, outside the timed region): the planner picks
    // the paper-correct solver per family.
    for (name, req, want) in &reqs {
        let p = plan(&req.graph, None);
        assert_eq!(
            &p.solver, want,
            "{name}: planner picked {} instead of {want}",
            p.solver
        );
    }

    let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let m = bench_with(&format!("auto solve, 3 families (n≈{n})"), &cfg, || {
        for (_, req, _) in &reqs {
            std::hint::black_box(
                solve_decomposed(req, &DriverConfig::auto(shards), &registry).unwrap(),
            );
        }
    });
    println!("{m}");

    let mut rec = ScenarioRecord::new();
    rec.time_metric("three_family_solve", &m);
    for (name, req, _) in &reqs {
        let report = solve_decomposed(req, &DriverConfig::auto(shards), &registry).unwrap();
        rec.metric(&format!("{name}_cost"), report.cost.total() as f64, Direction::Info);
    }
    rec
}
