//! Head-to-head scenarios (owned by the `headtohead` bin): the source
//! paper's MPC PIVOT (Corollary 28) vs the constant-round rivals
//! (`cal-pivot`, arxiv 2106.08448; `bcmt-pivot`, arxiv 2205.03710) on
//! identical inputs, identical simulator, identical ledger.
//!
//! * `headtohead/tiny_ratio`   — approximation quality on `tiny_corpus`
//!   against exact optima: `source_ratio` / `cal_ratio` / `bcmt_ratio`
//!   (aggregate cost over aggregate OPT, gated Lower);
//! * `headtohead/round_growth` — rounds and total words as n grows:
//!   `{source,cal,bcmt}_rounds` and `{source,cal,bcmt}_words` at the
//!   large size plus `{source,cal,bcmt}_round_growth` (large-over-small
//!   round ratio — the rivals' is 1.0, that is the whole point);
//! * `headtohead/throughput`   — wall-clock per solver on one mid-size
//!   λ-arboric instance: `{source,cal,bcmt}_solve_s` time metrics.
//!
//! All three scenarios drive the solvers through the registry (the same
//! adapters `arbocc solve --algo <name>` dispatches), so what the bench
//! records is what users get.

use std::sync::Arc;

use crate::bench::harness::bench_with;
use crate::bench::suite::{Direction, Registry, Scenario, ScenarioCtx, ScenarioRecord};
use crate::cluster::exact::exact_cost;
use crate::data::corpus::{tiny_corpus, WorkloadSpec};
use crate::graph::generators::lambda_arboric;
use crate::solve::{SolveCtx, SolveReport, SolveRequest, SolverRegistry};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

const BIN: &str = "headtohead";

/// The competitors: short metric prefix → registry solver name. The
/// source paper is represented by `mpc-pivot` (Corollary 28), the one
/// source-route solver that charges rounds and words to the simulator.
const RIVALS: &[(&str, &str)] = &[
    ("source", "mpc-pivot"),
    ("cal", "cal-pivot"),
    ("bcmt", "bcmt-pivot"),
];

pub fn register(r: &mut Registry) {
    r.register(Scenario {
        name: "headtohead/tiny_ratio",
        bin: BIN,
        about: "source vs rival approximation ratios on tiny_corpus vs OPT",
        run: tiny_ratio,
    });
    r.register(Scenario {
        name: "headtohead/round_growth",
        bin: BIN,
        about: "rounds & words as n grows: source log-shape vs rival flat",
        run: round_growth,
    });
    r.register(Scenario {
        name: "headtohead/throughput",
        bin: BIN,
        about: "wall-clock per solver on one λ-arboric instance",
        run: throughput,
    });
}

fn solve_named(registry: &SolverRegistry, name: &str, req: &SolveRequest) -> SolveReport {
    registry
        .get(name)
        .unwrap_or_else(|| panic!("{name} must be registered"))
        .solve(req, &mut SolveCtx::serial())
}

fn tiny_ratio(_ctx: &ScenarioCtx) -> ScenarioRecord {
    let registry = SolverRegistry::standard();
    let mut table = Table::new(
        "head-to-head on tiny_corpus (aggregate cost vs exact OPT)",
        &["solver", "Σcost", "ΣOPT", "ratio"],
    );
    let mut rec = ScenarioRecord::new();
    for (prefix, name) in RIVALS {
        let mut total_cost = 0u64;
        let mut total_opt = 0u64;
        for spec in tiny_corpus() {
            let g = WorkloadSpec::parse(spec)
                .and_then(|s| s.generate())
                .unwrap_or_else(|e| panic!("{spec}: {e}"));
            total_opt += exact_cost(&g);
            let req = SolveRequest { seed: 71, ..SolveRequest::new(Arc::new(g)) };
            total_cost += solve_named(&registry, name, &req).cost.total();
        }
        let ratio = total_cost as f64 / total_opt.max(1) as f64;
        table.row(&[
            name.to_string(),
            total_cost.to_string(),
            total_opt.to_string(),
            fnum(ratio),
        ]);
        // Deterministic in the pinned seed, so noise 0: any drift is a
        // real quality change and should gate.
        rec.metric(&format!("{prefix}_ratio"), ratio, Direction::Lower);
    }
    table.print();
    rec
}

fn round_growth(ctx: &ScenarioCtx) -> ScenarioRecord {
    let registry = SolverRegistry::standard();
    let n_small = ctx.size(300, 2_000);
    let n_large = ctx.size(3_000, 40_000);
    let mut rng = Rng::new(15_100);
    let small = Arc::new(lambda_arboric(n_small, 3, &mut rng));
    let large = Arc::new(lambda_arboric(n_large, 3, &mut rng));

    let mut table = Table::new(
        &format!("round/word growth, λ-arboric n={n_small} → n={n_large}"),
        &["solver", "rounds@small", "rounds@large", "growth", "words@large"],
    );
    let mut rec = ScenarioRecord::new();
    for (prefix, name) in RIVALS {
        let rep_small = solve_named(
            &registry,
            name,
            &SolveRequest { seed: 71, ..SolveRequest::new(small.clone()) },
        );
        let rep_large = solve_named(
            &registry,
            name,
            &SolveRequest { seed: 71, ..SolveRequest::new(large.clone()) },
        );
        let (rs, rl) = (
            rep_small.mpc_rounds.unwrap_or(0),
            rep_large.mpc_rounds.unwrap_or(0),
        );
        let words = rep_large.mpc_words.unwrap_or(0);
        let growth = rl as f64 / rs.max(1) as f64;
        table.row(&[
            name.to_string(),
            rs.to_string(),
            rl.to_string(),
            fnum(growth),
            words.to_string(),
        ]);
        rec.metric(&format!("{prefix}_rounds"), rl as f64, Direction::Lower);
        rec.metric(&format!("{prefix}_words"), words as f64, Direction::Lower);
        rec.metric(&format!("{prefix}_round_growth"), growth, Direction::Info);
    }
    table.print();
    rec
}

fn throughput(ctx: &ScenarioCtx) -> ScenarioRecord {
    let cfg = ctx.bench_cfg();
    let registry = SolverRegistry::standard();
    let n = ctx.size(2_000, 30_000);
    let mut rng = Rng::new(15_200);
    let g = Arc::new(lambda_arboric(n, 3, &mut rng));
    let req = SolveRequest { seed: 71, ..SolveRequest::new(g) };

    let mut rec = ScenarioRecord::new();
    for (prefix, name) in RIVALS {
        let m = bench_with(&format!("{name} (n={n})"), &cfg, || {
            std::hint::black_box(solve_named(&registry, name, &req));
        });
        println!("{m}");
        rec.time_metric(&format!("{prefix}_solve"), &m);
    }
    rec
}
