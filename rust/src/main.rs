//! `arbocc` — command-line launcher.
//!
//! Subcommands:
//!   solve     the unified solver engine: planner-routed (`--algo auto`)
//!             or named solver, per-component sharded decomposition,
//!             plan trace in the output; `--delta <file>` replays an
//!             `arbocc-delta/v1` stream through the warm-start
//!             incremental driver (`--verify` cross-checks the final
//!             batch against a from-scratch solve)
//!   delta     edge-delta streams: `delta gen <drift:...> -o f` writes
//!             an `arbocc-delta/v1` file, `delta apply <f>` replays it
//!             against its recorded (or `--input`) base graph
//!   cluster   run one registered solver on a generated workload; report
//!             cost, lower-bound ratio and MPC rounds
//!   gen       generate a corpus workload (`arbocc gen planted:n=2000,k=8
//!             -o g.csr`); `--list` prints the family registry
//!   convert   re-encode a graph file (edge list ⇄ arbocc-csr v1/v2
//!             snapshot, format chosen by the output extension — `.csr`
//!             v1, `.csr2` columnar compressed v2)
//!   mis       run the MPC greedy-MIS pipeline; report round counts
//!   best-of-k the Remark 14 driver: K trials of any registered solver
//!             through the coordinator + PJRT engine
//!   forest    matching-based forest algorithms (Corollary 31)
//!   bench     the perf-lab orchestrator: run the scenario registry at a
//!             tier, write BENCH_<label>.json, optionally gate against a
//!             baseline (--compare [path]; exits 1 on regression, scope
//!             narrowable with --gate substr[,substr...])
//!   check     verify PJRT artifacts against the native fallback
//!   audit     the determinism / MPC-invariant static analysis pass
//!             (DESIGN.md §8): walks rust/src under audit.toml, exits
//!             non-zero on findings
//!   info      environment / artifact status
//!
//! Dispatch errors (unknown `--algo`, `--family`, `--method`, `--model`)
//! exit with a one-line message, never a panic backtrace.

use std::sync::Arc;

use arbocc::util::error::{Result, ResultExt};

// Counting allocator so `arbocc bench` records the same allocation
// metrics as the bench bins (`mpc/plane_round_throughput` probes for it
// at run time and skips the metric when absent).
#[global_allocator]
static ALLOC: arbocc::util::alloc::CountingAlloc = arbocc::util::alloc::CountingAlloc;

use arbocc::algorithms::forest::clustering_from_matching;
use arbocc::algorithms::matching::{approx_matching, maximal_matching, maximum_matching_forest};
use arbocc::algorithms::mpc_mis::{
    alg1_greedy_mis, direct_simulation_mis, Alg1Params, Alg2Params, Alg3Params, Subroutine,
};
use arbocc::algorithms::pivot::pivot_random;
use arbocc::cluster::cost::cost;
use arbocc::data::corpus::{describe_families, WorkloadSpec};
use arbocc::cluster::triangles::packing_lower_bound;
use arbocc::coordinator::best_of_k_solver;
use arbocc::graph::arboricity::estimate_arboricity;
use arbocc::graph::generators::Family;
use arbocc::graph::Graph;
use arbocc::cluster::exact::MAX_EXACT_N;
use arbocc::runtime::{BackendKind, CostEngine};
use arbocc::solve::{
    simulator_for, solve_decomposed, DriverConfig, IncrementalState, ModelKind, SolveCtx,
    SolveReport, SolveRequest, SolverRegistry,
};
use arbocc::util::cli::Args;
use arbocc::util::rng::Rng;
use arbocc::util::table::{fnum, Table};
use arbocc::util::timer::Timer;

fn parse_family(s: &str) -> Result<Family> {
    fn parsed(part: &str, pat: &str) -> Result<usize> {
        match part.parse() {
            Ok(v) => Ok(v),
            Err(_) => arbocc::bail!("bad --family parameter '{part}' (expected {pat})"),
        }
    }
    if let Some(l) = s.strip_prefix("arboric-") {
        return Ok(Family::LambdaArboric(parsed(l, "arboric-<λ>")?));
    }
    if let Some(m) = s.strip_prefix("ba-") {
        return Ok(Family::BarabasiAlbert(parsed(m, "ba-<m>")?));
    }
    if let Some(l) = s.strip_prefix("barbell-") {
        return Ok(Family::Barbell(parsed(l, "barbell-<λ>")?));
    }
    if let Some(k) = s.strip_prefix("cliques-") {
        return Ok(Family::DisjointCliques(parsed(k, "cliques-<k>")?));
    }
    match s {
        "forest" => Ok(Family::Forest),
        "grid" => Ok(Family::Grid),
        "path" => Ok(Family::Path),
        "star" => Ok(Family::Star),
        _ => arbocc::bail!(
            "unknown --family '{s}' (try forest|arboric-K|ba-M|grid|path|star|barbell-K|cliques-K)"
        ),
    }
}

/// Workload source, in precedence order: `--input <file>` (edge list or
/// `arbocc-csr` snapshot, auto-detected), `--workload <spec>` (any
/// registered corpus family, e.g. `planted:n=50000,k=40,seed=7`), or the
/// legacy named generator family (`--family`, `--n`).
fn make_graph(args: &Args) -> Result<(Graph, String, u64)> {
    let seed = args.get_u64("seed", 1)?;
    if let Some(path) = args.get("input") {
        let (g, stats) = arbocc::data::load_graph(std::path::Path::new(path))
            .with_context(|| format!("reading --input {path}"))?;
        println!("loaded {path}: {}", stats.describe());
        return Ok((g, format!("file:{path}"), seed));
    }
    if let Some(spec_s) = args.get("workload") {
        let spec = WorkloadSpec::parse(spec_s)?;
        let g = spec.generate()?;
        return Ok((g, spec.canonical(), seed));
    }
    let family = parse_family(&args.get_str("family", "arboric-3"))?;
    let n = args.get_usize("n", 10_000)?;
    let mut rng = Rng::new(seed);
    let g = family.generate(n, &mut rng);
    Ok((g, family.name(), seed))
}

/// The shared request shape every solver-engine command builds from the
/// CLI flags (`--lambda`, `--eps`, `--model`, `--delta`, `--rounds`,
/// `--trials`). `--rounds R` sets the round budget the planner's
/// rival-routing rule compares against (DESIGN.md §9).
fn request_from_args(args: &Args, g: Graph, seed: u64) -> Result<SolveRequest> {
    let model_s = args.get_str("model", "m1");
    let Some(model) = ModelKind::parse(&model_s) else {
        arbocc::bail!("unknown --model '{model_s}' (m1|m2)");
    };
    let mut req = SolveRequest::new(Arc::new(g));
    req.seed = seed;
    req.lambda =
        if args.has("lambda") { Some(args.get_usize("lambda", 1)?.max(1)) } else { None };
    req.eps = args.get_f64("eps", 2.0)?;
    req.model = model;
    // `--delta` is overloaded in `solve`: a number is the MPC memory
    // sublinearity δ, anything else names an `arbocc-delta/v1` stream
    // (consumed by `cmd_solve`), so a non-numeric value keeps δ at its
    // default here instead of erroring.
    req.delta = match args.get("delta") {
        Some(v) => v.parse().unwrap_or(0.5),
        None => 0.5,
    };
    req.round_budget = if args.has("rounds") { Some(args.get_usize("rounds", 0)?) } else { None };
    req.trials = args.get_usize("trials", 1)?.max(1);
    Ok(req)
}

/// The standalone exact solver is hard-capped at n ≤ 14; dispatching it
/// at a larger size must be a message, not a panic (the decomposition
/// driver enforces its own per-component version of this).
fn guard_exact_small(algo: &str, g: &Graph) -> Result<()> {
    if algo == "exact-small" {
        arbocc::ensure!(
            g.n() <= MAX_EXACT_N,
            "--algo exact-small is capped at n={MAX_EXACT_N} (got n={}); \
             use --algo auto to solve tiny components exactly",
            g.n()
        );
    }
    Ok(())
}

fn print_graph_line(family: &str, g: &Graph) {
    let est = estimate_arboricity(g);
    println!(
        "graph: {} n={} m={} Δ={} λ∈[{},{}]",
        family,
        g.n(),
        g.m(),
        g.max_degree(),
        est.density_lower_bound,
        est.degeneracy
    );
}

fn print_report(req: &SolveRequest, report: &SolveReport) {
    if !report.plan.is_empty() {
        println!("plan:");
        for line in &report.plan {
            println!("  {line}");
        }
    }
    let c = report.cost;
    println!(
        "solver={} cost={} (pos {}, neg {}) clusters={} max|C|={}",
        report.solver,
        c.total(),
        c.positive,
        c.negative,
        report.clustering.n_clusters(),
        report.clustering.max_cluster_size()
    );
    let lb = packing_lower_bound(&req.graph);
    if lb > 0 {
        println!(
            "bad-triangle packing LB={} ⇒ ratio ≤ {}",
            lb,
            fnum(c.total() as f64 / lb as f64)
        );
    }
    if let Some(r) = report.mpc_rounds {
        let words = report
            .mpc_words
            .map(|w| format!(", {w} words"))
            .unwrap_or_default();
        println!("MPC rounds={r}{words} (model={}, δ={})", req.model.name(), req.delta);
    }
    println!("wall time: {:.3}s", report.wall_s);
}

/// The unified solver engine:
///
///   arbocc solve [--algo auto|<name>] [--family F --n N | --input path]
///                [--shards S] [--exact-cutoff C] [--lambda λ] [--eps ε]
///                [--model m1|m2] [--delta δ|<stream>] [--rounds R]
///                [--trials K] [--verify] [--list]
///
/// `--algo auto` routes each connected component through the planner's
/// Theorem 26 / Corollary 27–32 decision tree, extended by the §9 rival
/// rules (`--rounds R` budget → bcmt-pivot, λ > 8 → cal-pivot); any
/// registered solver name forces that algorithm. Components are solved
/// concurrently on an S-shard pool (bit-identical results at every S).
/// `--trials K > 1` runs the Remark 14 best-of-K driver over the whole
/// graph instead.
///
/// `--delta <file>` (any non-numeric value) replays an `arbocc-delta/v1`
/// stream through the warm-start incremental driver: the base graph is
/// solved once, then each batch updates the component labelling in place
/// and re-solves only the components the delta dirtied (per-batch cache
/// stats printed). The stitched result of every batch is bit-identical
/// to a from-scratch solve of the post-batch graph; `--verify` proves it
/// for the final batch by running one.
fn cmd_solve(args: &Args) -> Result<()> {
    let registry = SolverRegistry::standard();
    if args.get_bool("list") {
        println!("{} registered solver(s):", registry.len());
        for line in registry.describe() {
            println!("  {line}");
        }
        return Ok(());
    }
    let (g, family, seed) = make_graph(args)?;
    let algo = args.get_str("algo", "auto");
    let Some(solver) = registry.get(&algo) else {
        arbocc::bail!(
            "unknown --algo '{algo}'; registered solvers:\n  {}",
            registry.describe().join("\n  ")
        );
    };
    let shards = args.get_usize(
        "shards",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
    )?;
    let req = request_from_args(args, g, seed)?;
    print_graph_line(&family, &req.graph);

    // A non-numeric `--delta` names an edge-delta stream to replay
    // incrementally (a number is the MPC δ, handled by the request).
    let delta_file = args.get("delta").filter(|v| v.parse::<f64>().is_err());
    if let Some(dpath) = delta_file {
        arbocc::ensure!(
            req.trials <= 1,
            "--delta streams cannot be combined with --trials (the warm-start \
             driver is a single-trial path)"
        );
        let cfg = DriverConfig {
            shards,
            exact_cutoff: args.get_usize("exact-cutoff", 8)?,
            algo: if algo == "auto" { None } else { Some(algo.clone()) },
        };
        return solve_delta_stream(&req, &cfg, &registry, &dpath, args.get_bool("verify"));
    }

    if req.trials > 1 {
        // Remark 14: K independent trials through the coordinator.
        guard_exact_small(&algo, &req.graph)?;
        let engine = if args.get_bool("native") {
            CostEngine::native()
        } else {
            CostEngine::auto_default()
        };
        let timer = Timer::start();
        let run = best_of_k_solver(&req, solver, shards, &engine)?;
        let worst = run.costs.iter().max().copied().unwrap_or(run.best_cost.total());
        println!(
            "best-of-{} ({algo}): best={} worst={} (spread {}) in {:.3}s",
            req.trials,
            run.best_cost.total(),
            worst,
            worst - run.best_cost.total(),
            timer.elapsed_s()
        );
        let lb = packing_lower_bound(&req.graph);
        if lb > 0 {
            println!(
                "LB={lb} ⇒ best ratio ≤ {}",
                fnum(run.best_cost.total() as f64 / lb as f64)
            );
        }
        return Ok(());
    }

    let cfg = DriverConfig {
        shards,
        exact_cutoff: args.get_usize("exact-cutoff", 8)?,
        algo: if algo == "auto" { None } else { Some(algo.clone()) },
    };
    let report = solve_decomposed(&req, &cfg, &registry)?;
    print_report(&req, &report);
    Ok(())
}

/// The `solve --delta <stream>` path: base solve, then one warm-start
/// re-solve per batch with per-batch dirty/cache accounting.
fn solve_delta_stream(
    req: &SolveRequest,
    cfg: &DriverConfig,
    registry: &SolverRegistry,
    dpath: &str,
    verify: bool,
) -> Result<()> {
    let delta = arbocc::data::delta::read_delta_file(std::path::Path::new(dpath))
        .with_context(|| format!("reading --delta {dpath}"))?;
    arbocc::ensure!(
        req.graph.n() == delta.n
            && arbocc::data::delta::graph_fingerprint(&req.graph) == delta.base_fingerprint,
        "--delta {dpath}: stream was recorded against a different base graph \
         (stream base: n={}, spec {}) — regenerate it or pass the matching --input",
        delta.n,
        delta.base_spec
    );
    let mut state = IncrementalState::new(req.clone(), cfg.clone(), registry)?;
    println!(
        "base solve: {} component(s), cost={} in {:.3}s",
        state.stats().components,
        state.report().cost.total(),
        state.report().wall_s
    );
    for (i, batch) in delta.batches.iter().enumerate() {
        let rep = state
            .apply_batch(batch, registry)
            .with_context(|| format!("applying delta batch {i}"))?;
        let s = *state.stats();
        println!(
            "batch {i}: +{}/-{} op(s) -> {} component(s) ({} clean, {} dirty), \
             cache {} hit / {} miss, cost={} in {:.3}s",
            s.inserts,
            s.deletes,
            s.components,
            s.clean,
            s.dirty,
            s.cache_hits,
            s.cache_misses,
            rep.cost.total(),
            rep.wall_s
        );
    }
    let final_req = SolveRequest { graph: state.graph().clone(), ..req.clone() };
    print_graph_line("post-delta", &final_req.graph);
    print_report(&final_req, state.report());
    let (hits, misses) = state.cache_stats();
    println!("session cache: {hits} hit(s) / {misses} miss(es)");
    if verify {
        let scratch = solve_decomposed(&final_req, cfg, registry)?;
        arbocc::ensure!(
            scratch.clustering.labels() == state.report().clustering.labels()
                && scratch.cost == state.report().cost,
            "verify: incremental result diverges from the from-scratch solve \
             (this is a bug — the warm-start contract is bit-identity)"
        );
        println!("verify: bit-identical to a from-scratch solve of the final graph");
    }
    Ok(())
}

/// Edge-delta streams (`arbocc-delta/v1`):
///
///   arbocc delta gen <drift:base=...;...,batches=K,flip=P,seed=S> -o <file>
///   arbocc delta apply <file> [--input <base>] [-o <out>]
///
/// `gen` evaluates a `drift` corpus spec into a checksummed stream of
/// insert/delete batches against its base graph (inner commas of the
/// base spec written as `;`). `apply` replays a stream — against
/// `--input` when given, else the recorded base spec is regenerated —
/// printing per-batch graph sizes; `-o` writes the final graph in the
/// format its extension names.
fn cmd_delta(args: &Args) -> Result<()> {
    let pos = args.positional();
    let verb = pos.get(1).map(|s| s.as_str()).unwrap_or("");
    match verb {
        "gen" => {
            let Some(spec_s) = pos.get(2) else {
                arbocc::bail!(
                    "usage: arbocc delta gen <drift:base=...;...,batches=K,flip=P,seed=S> \
                     -o <file>"
                );
            };
            let spec = WorkloadSpec::parse(spec_s)?;
            let delta = arbocc::data::delta::drift_delta(&spec)?;
            let Some(path) = args.get("o").or_else(|| args.get("out")) else {
                arbocc::bail!("delta gen: pass -o <file> to write the stream");
            };
            arbocc::data::delta::write_delta_file(&delta, std::path::Path::new(&path))
                .with_context(|| format!("writing {path}"))?;
            let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            println!(
                "wrote {path} (arbocc-delta/v1, {} batch(es), {} op(s), {bytes} bytes) \
                 against base {}",
                delta.batches.len(),
                delta.total_ops(),
                delta.base_spec
            );
            Ok(())
        }
        "apply" => {
            let Some(path) = pos.get(2) else {
                arbocc::bail!("usage: arbocc delta apply <file> [--input <base>] [-o <out>]");
            };
            let delta = arbocc::data::delta::read_delta_file(std::path::Path::new(path))
                .with_context(|| format!("reading {path}"))?;
            let base = if let Some(input) = args.get("input") {
                let (g, stats) = arbocc::data::load_graph(std::path::Path::new(&input))
                    .with_context(|| format!("reading --input {input}"))?;
                println!("loaded {input}: {}", stats.describe());
                g
            } else {
                let spec = WorkloadSpec::parse(&delta.base_spec).with_context(|| {
                    format!("regenerating recorded base '{}'", delta.base_spec)
                })?;
                spec.generate()?
            };
            print_graph_line(&delta.base_spec, &base);
            let graphs = arbocc::data::delta::apply_batches(&base, &delta)?;
            for (i, g) in graphs.iter().enumerate() {
                println!("after batch {i}: n={} m={}", g.n(), g.m());
            }
            if let Some(out) = args.get("o").or_else(|| args.get("out")) {
                let last = graphs.last().unwrap_or(&base);
                let p = std::path::Path::new(&out);
                let format = arbocc::data::save_graph(last, p)
                    .with_context(|| format!("writing {out}"))?;
                println!("wrote {out} ({format})");
            }
            Ok(())
        }
        other => arbocc::bail!("unknown delta verb '{other}' (gen|apply)"),
    }
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let (g, family, seed) = make_graph(args)?;
    let algo = args.get_str("algo", "alg4-pivot");
    let registry = SolverRegistry::standard();
    let Some(solver) = registry.get(&algo) else {
        arbocc::bail!("unknown --algo '{algo}' (known: {})", registry.names().join("|"));
    };
    let req = request_from_args(args, g, seed ^ 0xC0FFEE)?;
    guard_exact_small(&algo, &req.graph)?;
    print_graph_line(&family, &req.graph);
    let mut ctx = SolveCtx::serial();
    let report = solver.solve(&req, &mut ctx);
    print_report(&req, &report);
    Ok(())
}

fn cmd_mis(args: &Args) -> Result<()> {
    let (g, family, seed) = make_graph(args)?;
    let delta = args.get_f64("delta", 0.5)?;
    let method = args.get_str("method", "alg2");
    if !["alg2", "alg3", "direct", "all"].contains(&method.as_str()) {
        arbocc::bail!("unknown --method '{method}' (alg2|alg3|direct|all)");
    }
    let mut rng = Rng::new(seed ^ 0x5EED);
    let perm = rng.permutation(g.n());

    let mut table = Table::new(
        &format!("greedy MIS rounds — {} n={} Δ={}", family, g.n(), g.max_degree()),
        &["method", "model", "rounds", "|MIS|"],
    );
    let run_one = |method: &str, table: &mut Table| {
        // Total over the validated method set: `direct` and `alg2` share
        // a subroutine, `alg3` is the M2 variant.
        let (model, sub) = match method {
            "alg3" => (ModelKind::M2, Subroutine::Alg3(Alg3Params::default())),
            _ => (ModelKind::M1, Subroutine::Alg2(Alg2Params::default())),
        };
        let mut sim = simulator_for(&g, model, delta, seed);
        let mis = if method == "direct" {
            direct_simulation_mis(&g, &perm, &mut sim)
        } else {
            alg1_greedy_mis(&g, &perm, &Alg1Params { c_prefix: 1.0, subroutine: sub }, &mut sim)
                .in_mis
        };
        let size = mis.iter().filter(|&&b| b).count();
        table.row(&[
            method.to_string(),
            model.name().to_string(),
            sim.n_rounds().to_string(),
            size.to_string(),
        ]);
    };
    if method == "all" {
        for m in ["direct", "alg2", "alg3"] {
            run_one(m, &mut table);
        }
    } else {
        run_one(&method, &mut table);
    }
    table.print();
    Ok(())
}

fn cmd_best_of_k(args: &Args) -> Result<()> {
    let (g, family, seed) = make_graph(args)?;
    let k = args.get_usize("k", 16)?;
    let workers = args.get_usize("workers", 4)?;
    let algo = args.get_str("algo", "alg4-pivot");
    let registry = SolverRegistry::standard();
    let Some(solver) = registry.get(&algo) else {
        arbocc::bail!("unknown --algo '{algo}' (known: {})", registry.names().join("|"));
    };
    let mut req = request_from_args(args, g, seed)?;
    req.trials = k.max(1);
    guard_exact_small(&algo, &req.graph)?;
    let engine =
        if args.get_bool("native") { CostEngine::native() } else { CostEngine::auto_default() };
    println!(
        "backend: {:?}; workload {} n={} m={}; algo={algo}, K={k}, workers={workers}",
        engine.kind(),
        family,
        req.graph.n(),
        req.graph.m()
    );
    let timer = Timer::start();
    let run = best_of_k_solver(&req, solver, workers, &engine)?;
    let elapsed = timer.elapsed_s();
    let lb = packing_lower_bound(&req.graph);
    let worst = run.costs.iter().max().copied().unwrap_or(run.best_cost.total());
    println!(
        "best={} worst={} (spread {}); LB={} ⇒ best ratio ≤ {}",
        run.best_cost.total(),
        worst,
        worst - run.best_cost.total(),
        lb,
        if lb > 0 { fnum(run.best_cost.total() as f64 / lb as f64) } else { "n/a".into() }
    );
    println!("scored {k} clusterings in {elapsed:.3}s ({:.1} trials/s)", k as f64 / elapsed);
    Ok(())
}

fn cmd_forest(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 10_000)?;
    let seed = args.get_u64("seed", 1)?;
    let eps = args.get_f64("eps", 0.5)?;
    let mut rng = Rng::new(seed);
    let g = arbocc::graph::generators::random_forest(n, 0.9, &mut rng);

    let mut table = Table::new(
        &format!("forest algorithms — n={} m={}", g.n(), g.m()),
        &["algorithm", "|M|", "cost", "rounds"],
    );
    // Corollary 31(i): exact maximum matching.
    let m_star = maximum_matching_forest(&g);
    let c = clustering_from_matching(g.n(), &m_star);
    table.row(&[
        "maximum (opt)".into(),
        m_star.len().to_string(),
        cost(&g, &c).total().to_string(),
        "-".into(),
    ]);
    // Maximal (2-approx).
    let mut sim = simulator_for(&g, ModelKind::M1, 0.5, seed);
    let maximal = maximal_matching(&g, &mut rng, &mut sim, 64);
    let cm = clustering_from_matching(g.n(), &maximal.matching);
    table.row(&[
        "maximal (2-approx)".into(),
        maximal.matching.len().to_string(),
        cost(&g, &cm).total().to_string(),
        sim.n_rounds().to_string(),
    ]);
    // (1+ε).
    let mut sim2 = simulator_for(&g, ModelKind::M1, 0.5, seed);
    let approx = approx_matching(&g, maximal.matching.clone(), eps, &mut sim2);
    let ca = clustering_from_matching(g.n(), &approx.matching);
    table.row(&[
        format!("(1+{eps})-approx"),
        approx.matching.len().to_string(),
        cost(&g, &ca).total().to_string(),
        sim2.n_rounds().to_string(),
    ]);
    table.print();
    Ok(())
}

/// Dataset generator:
///
///   arbocc gen <family:k=v,...> [-o <file>]   generate + write
///   arbocc gen --list                          print the family registry
///
/// The output format follows the extension: `.csr` writes the
/// `arbocc-csr/v1` binary snapshot, `.csr2` the columnar compressed
/// `arbocc-csr/v2` snapshot, `.csv` a CSV edge list, anything else a
/// whitespace edge list. Without `-o` the instance is generated and
/// summarized (a dry run).
fn cmd_gen(args: &Args) -> Result<()> {
    if args.get_bool("list") {
        let lines = describe_families();
        println!("{} registered workload famil(ies):", lines.len());
        for line in lines {
            println!("  {line}");
        }
        return Ok(());
    }
    let Some(spec_s) = args.positional().get(1) else {
        arbocc::bail!(
            "usage: arbocc gen <family:k=v,...> [-o <file>] — \
             `arbocc gen --list` prints the registered families"
        );
    };
    let spec = WorkloadSpec::parse(spec_s)?;
    let g = spec.generate()?;
    print_graph_line(&spec.canonical(), &g);
    match args.get("o").or_else(|| args.get("out")) {
        Some(path) => {
            let p = std::path::Path::new(path);
            let format = arbocc::data::save_graph(&g, p)
                .with_context(|| format!("writing {path}"))?;
            let bytes = std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
            println!("wrote {path} ({format}, {bytes} bytes)");
        }
        None => println!("(dry run — pass -o <file> to write .csr / .csr2 / .edges / .csv)"),
    }
    Ok(())
}

/// Re-encode a graph file; the target format follows the output
/// extension (`.csr` v1 snapshot, `.csr2` columnar v2, `.csv` /
/// anything else text), the source format is auto-detected by magic —
/// so `arbocc convert g.csr g.csr2` and back transcode between the
/// snapshot generations.
fn cmd_convert(args: &Args) -> Result<()> {
    let pos = args.positional();
    let (Some(src), Some(dst)) = (pos.get(1), pos.get(2)) else {
        arbocc::bail!("usage: arbocc convert <in> <out> (format chosen by <out>'s extension)");
    };
    let (g, stats) = arbocc::data::load_graph(std::path::Path::new(src))
        .with_context(|| format!("reading {src}"))?;
    println!("read {src}: {}", stats.describe());
    print_graph_line(&format!("file:{src}"), &g);
    let format = arbocc::data::save_graph(&g, std::path::Path::new(dst))
        .with_context(|| format!("writing {dst}"))?;
    println!("wrote {dst} ({format})");
    Ok(())
}

fn cmd_check(_args: &Args) -> Result<()> {
    let engine = CostEngine::auto_default();
    match engine.kind() {
        BackendKind::Native => {
            println!("artifacts/ missing or unloadable — run `make artifacts` first");
            return Ok(());
        }
        BackendKind::Pjrt => println!("PJRT engine loaded from artifacts/"),
    }
    let native = CostEngine::native();
    let mut rng = Rng::new(123);
    let mut checked = 0;
    for lambda in [1usize, 2, 4] {
        let g = arbocc::graph::generators::lambda_arboric(200, lambda, &mut rng);
        let c = pivot_random(&g, &mut rng);
        let a = engine.cost(&g, &c)?;
        let b = native.cost(&g, &c)?;
        arbocc::ensure!(a == b, "cost mismatch: pjrt {a:?} vs native {b:?}");
        let ta = engine.bad_triangles_single_block(&g)?;
        let tb = native.bad_triangles_single_block(&g)?;
        arbocc::ensure!(ta == tb, "triangles mismatch: {ta} vs {tb}");
        let cs: Vec<_> = (0..9).map(|_| pivot_random(&g, &mut rng)).collect();
        let ba = engine.cost_batch_single_block(&g, &cs)?;
        let bb = native.cost_batch_single_block(&g, &cs)?;
        arbocc::ensure!(ba == bb, "batch mismatch");
        checked += 3;
    }
    println!("self-check OK: {checked} PJRT-vs-native comparisons identical");
    Ok(())
}

/// The determinism / MPC-invariant static analysis pass (DESIGN.md §8):
///
///   arbocc audit [--manifest audit.toml] [--json] [--list-rules]
///
/// Walks `<manifest dir>/<root>` (default `src/` next to `audit.toml`),
/// applies the class-scoped rule set, and exits non-zero when any
/// finding survives the justified-`audit:allow` suppressions. `--json`
/// prints the `arbocc-audit/v1` report instead of `file:line` lines.
fn cmd_audit(args: &Args) -> Result<()> {
    use arbocc::audit::{self, rules};

    if args.get_bool("list-rules") {
        println!("{} audit rule(s):", rules::RULES.len());
        for r in rules::RULES {
            println!("  {:<16} [{:<13}] {}", r.id, r.class, r.summary);
        }
        return Ok(());
    }
    let manifest_s = args.get_str("manifest", "audit.toml");
    let manifest_path = std::path::Path::new(&manifest_s);
    let manifest = audit::Manifest::load(manifest_path)?;
    let dir = match manifest_path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let report = audit::audit_tree(&dir, &manifest)?;
    if args.get_bool("json") {
        println!("{}", report.to_json().pretty());
    } else {
        print!("{}", report.render_human());
    }
    arbocc::ensure!(
        report.is_clean(),
        "audit: {} finding(s) — see the report above (suppress only with a \
         justified `// audit:allow(<rule>): <why>`)",
        report.findings.len()
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("arbocc {}", env!("CARGO_PKG_VERSION"));
    println!(
        "artifacts present: {}",
        arbocc::runtime::client::PjrtEngine::artifacts_present(std::path::Path::new("artifacts"))
    );
    println!(
        "block protocol: N={} batch={}",
        arbocc::runtime::blocks::BLOCK_N,
        arbocc::runtime::blocks::BLOCK_BATCH
    );
    Ok(())
}

/// The perf-lab orchestrator (see DESIGN.md §perf-lab):
///
///   arbocc bench [--tier smoke|full] [--label PR3] [--out path.json]
///                [--filter substr] [--compare [baseline.json]]
///                [--gate substr[,substr...]] [--replay run.json]
///                [--workload spec] [--list]
///
/// `--workload <spec>` hands a corpus spec to the corpus-driven
/// scenarios (e.g. `--filter corpus --workload planted:n=8000,k=16`),
/// pointing the sweep at one addressable instance.
///
/// Runs the registered scenarios, writes `BENCH_<label>.json`, and with
/// `--compare` diffs against a baseline (explicit path, or the newest
/// other same-tier `BENCH_*.json` next to the output) — exiting
/// non-zero when any gated metric regresses beyond its noise-aware
/// tolerance. `--gate` narrows which scenarios can fail the gate to
/// those whose name contains one of the comma-separated substrings
/// (e.g. `--gate mpc/plane_,perf/p8`); regressions outside the scope
/// are still reported. `--replay` loads a previous run's JSON instead
/// of re-running the suite, so CI can gate an already-recorded run.
fn cmd_bench(args: &Args) -> Result<()> {
    use arbocc::bench::compare::{self, CompareConfig};
    use arbocc::bench::suite::{Registry, Tier};

    let registry = Registry::standard();
    if args.get_bool("list") {
        println!("{} registered scenario(s):", registry.len());
        for s in registry.scenarios() {
            println!("  {:<24} [{:<18}] {}", s.name, s.bin, s.about);
        }
        return Ok(());
    }

    let (result, out_path, prior) = if let Some(replay) = args.get("replay") {
        let path = std::path::PathBuf::from(replay);
        let result = match compare::load(&path) {
            Ok(r) => r,
            Err(e) => arbocc::bail!("loading --replay {}: {e}", path.display()),
        };
        println!(
            "replayed {} ({} scenarios, tier {})",
            path.display(),
            result.scenarios.len(),
            result.tier.name()
        );
        (result, path, None)
    } else {
        let tier_s = args.get_str("tier", "smoke");
        let tier = match Tier::parse(&tier_s) {
            Some(t) => t,
            None => arbocc::bail!("unknown --tier '{tier_s}' (smoke|full)"),
        };
        let label = args.get_str("label", "local");
        let filter = args.get("filter");
        let result = registry.run_with(tier, &label, filter, args.get("workload"));
        arbocc::ensure!(
            !result.scenarios.is_empty(),
            "no scenarios matched filter {:?}",
            filter
        );
        let out = args.get_str("out", &format!("BENCH_{label}.json"));
        let out_path = std::path::PathBuf::from(&out);
        // A previous run at the same path is the natural baseline for a
        // bare --compare — capture it before the write destroys it
        // (otherwise `make bench-gate` would clobber the only baseline
        // and then gate against nothing).
        let prior = if args.has("compare") {
            compare::load(&out_path).ok().filter(|b| !b.partial && b.tier == tier)
        } else {
            None
        };
        std::fs::write(&out_path, result.to_json().pretty())?;
        println!("wrote {} ({} scenarios)", out_path.display(), result.scenarios.len());
        (result, out_path, prior)
    };

    let Some(cmp_flag) = args.get("compare") else {
        return Ok(());
    };
    let (baseline, baseline_name) = if cmp_flag == "true" {
        if let Some(b) = prior {
            // Pre-run contents of the output path.
            (b, format!("{} (previous contents)", out_path.display()))
        } else {
            // Newest other same-tier BENCH_*.json next to the output
            // (smoke and full runs are never diffed against each other —
            // same metric names, ~10× different workloads).
            let dir = match out_path.parent() {
                Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
                _ => std::path::PathBuf::from("."),
            };
            let found = compare::find_previous_baseline(&dir, Some(&out_path), Some(result.tier));
            let path = match found {
                Some(p) => p,
                None => {
                    println!(
                        "no previous {}-tier BENCH_*.json in {} — baseline recorded, nothing to gate",
                        result.tier.name(),
                        dir.display()
                    );
                    return Ok(());
                }
            };
            match compare::load(&path) {
                Ok(b) => (b, path.display().to_string()),
                Err(e) => arbocc::bail!("loading baseline {}: {e}", path.display()),
            }
        }
    } else {
        let path = std::path::PathBuf::from(cmp_flag);
        match compare::load(&path) {
            Ok(b) => (b, path.display().to_string()),
            Err(e) => arbocc::bail!("loading baseline {}: {e}", path.display()),
        }
    };
    arbocc::ensure!(
        baseline.tier == result.tier,
        "tier mismatch: baseline {baseline_name} is {}-tier but this run is {}-tier — \
         smoke and full sweeps use different workload sizes and cannot be gated \
         against each other",
        baseline.tier.name(),
        result.tier.name()
    );
    let cmp = compare::compare(&baseline, &result, &CompareConfig::default());
    let md = arbocc::bench::report::render_comparison(&cmp);
    println!("\n{md}");
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/COMPARE.md", &md)?;
    let gate_filters: Vec<String> = args
        .get("gate")
        .map(|g| {
            g.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
        .unwrap_or_default();
    let gated = cmp.gated_failures(&gate_filters);
    if !gated.is_empty() {
        // A gated metric that vanished from this run fails as loudly as
        // a regression — silently dropping a metric must not disarm the
        // gate.
        let missing = gated
            .iter()
            .filter(|d| d.verdict == compare::Verdict::Missing)
            .count();
        let regressed = gated.len() - missing;
        eprintln!(
            "bench gate: {regressed} regression(s), {missing} gated metric(s) \
             missing from this run vs {baseline_name}"
        );
        std::process::exit(1);
    }
    let outside = cmp.regressions().len();
    if outside > 0 {
        println!(
            "bench gate: {outside} regression(s) outside --gate scope \
             (reported above, not gating) vs {baseline_name}"
        );
    } else {
        println!("bench gate: no regressions vs {baseline_name}");
    }
    Ok(())
}

fn cmd_report() -> Result<()> {
    let reports = arbocc::bench::report::load_reports(std::path::Path::new("reports"))?;
    if reports.is_empty() {
        println!("no reports found — run `cargo bench` first");
        return Ok(());
    }
    let md = arbocc::bench::report::render_markdown(&reports);
    let out = std::path::Path::new("reports/SUMMARY.md");
    std::fs::write(out, &md)?;
    println!("{} reports aggregated -> {}", reports.len(), out.display());
    Ok(())
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("info");
    let result = match cmd {
        "solve" => cmd_solve(&args),
        "cluster" => cmd_cluster(&args),
        "delta" => cmd_delta(&args),
        "gen" => cmd_gen(&args),
        "convert" => cmd_convert(&args),
        "mis" => cmd_mis(&args),
        "best-of-k" => cmd_best_of_k(&args),
        "forest" => cmd_forest(&args),
        "bench" => cmd_bench(&args),
        "check" => cmd_check(&args),
        "audit" => cmd_audit(&args),
        "report" => cmd_report(),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command '{other}'");
            eprintln!(
                "usage: arbocc <solve|cluster|delta|gen|convert|mis|best-of-k|forest|bench|check|audit|report|info> [--flags]"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
