//! `arbocc` — command-line launcher.
//!
//! Subcommands:
//!   cluster   run a correlation-clustering algorithm on a generated
//!             workload; report cost, lower-bound ratio and MPC rounds
//!   mis       run the MPC greedy-MIS pipeline; report round counts
//!   best-of-k the Remark 14 driver through the coordinator + PJRT engine
//!   forest    matching-based forest algorithms (Corollary 31)
//!   bench     the perf-lab orchestrator: run the scenario registry at a
//!             tier, write BENCH_<label>.json, optionally gate against a
//!             baseline (--compare [path]; exits 1 on regression)
//!   check     verify PJRT artifacts against the native fallback
//!   info      environment / artifact status

use std::sync::Arc;

use arbocc::util::error::Result;

use arbocc::algorithms::alg4::alg4;
use arbocc::algorithms::forest::clustering_from_matching;
use arbocc::algorithms::matching::{approx_matching, maximal_matching, maximum_matching_forest};
use arbocc::algorithms::mpc_mis::{
    alg1_greedy_mis, direct_simulation_mis, mpc_pivot, Alg1Params, Alg2Params, Alg3Params,
    Subroutine,
};
use arbocc::algorithms::pivot::pivot_random;
use arbocc::algorithms::simple::simple_clustering;
use arbocc::cluster::cost::cost;
use arbocc::cluster::triangles::packing_lower_bound;
use arbocc::coordinator::{best_of_k, TrialSpec};
use arbocc::graph::arboricity::estimate_arboricity;
use arbocc::graph::generators::Family;
use arbocc::graph::Graph;
use arbocc::mpc::memory::Words;
use arbocc::mpc::{MpcConfig, MpcSimulator};
use arbocc::runtime::{BackendKind, CostEngine};
use arbocc::util::cli::Args;
use arbocc::util::rng::Rng;
use arbocc::util::table::{fnum, Table};
use arbocc::util::timer::Timer;

fn parse_family(s: &str) -> Family {
    if let Some(l) = s.strip_prefix("arboric-") {
        return Family::LambdaArboric(l.parse().expect("arboric-<λ>"));
    }
    if let Some(m) = s.strip_prefix("ba-") {
        return Family::BarabasiAlbert(m.parse().expect("ba-<m>"));
    }
    if let Some(l) = s.strip_prefix("barbell-") {
        return Family::Barbell(l.parse().expect("barbell-<λ>"));
    }
    if let Some(k) = s.strip_prefix("cliques-") {
        return Family::DisjointCliques(k.parse().expect("cliques-<k>"));
    }
    match s {
        "forest" => Family::Forest,
        "grid" => Family::Grid,
        "path" => Family::Path,
        "star" => Family::Star,
        _ => panic!(
            "unknown family '{s}' (try forest|arboric-K|ba-M|grid|path|star|barbell-K|cliques-K)"
        ),
    }
}

/// Workload source: `--input <edge-list file>` (SNAP format) or a named
/// generator family (`--family`, `--n`).
fn make_graph(args: &Args) -> (Graph, String, u64) {
    let seed = args.get_u64("seed", 1);
    if let Some(path) = args.get("input") {
        let (g, _orig) =
            arbocc::graph::io::read_edge_list_file(std::path::Path::new(path))
                .unwrap_or_else(|e| panic!("reading --input {path}: {e}"));
        return (g, format!("file:{path}"), seed);
    }
    let family = parse_family(&args.get_str("family", "arboric-3"));
    let n = args.get_usize("n", 10_000);
    let mut rng = Rng::new(seed);
    let g = family.generate(n, &mut rng);
    (g, family.name(), seed)
}

fn sim_for(g: &Graph, model: &str, delta: f64, seed: u64) -> MpcSimulator {
    let words = (g.n() + 2 * g.m()).max(4) as Words;
    let cfg = match model {
        "m2" => MpcConfig::model2(g.n().max(2), words, delta),
        _ => MpcConfig::model1(g.n().max(2), words, delta),
    };
    // Seed keys the per-machine RNG streams (randomized schedules such as
    // the matching proposal phase), keeping whole runs reproducible.
    MpcSimulator::new(cfg).with_seed(seed)
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let (g, family, seed) = make_graph(args);
    let algo = args.get_str("algo", "alg4-pivot");
    let model = args.get_str("model", "m1");
    let delta = args.get_f64("delta", 0.5);
    let eps = args.get_f64("eps", 2.0);
    let est = estimate_arboricity(&g);
    let lambda = args.get_usize("lambda", est.degeneracy.max(1));
    let mut rng = Rng::new(seed ^ 0xC0FFEE);

    println!(
        "graph: {} n={} m={} Δ={} λ∈[{},{}]",
        family,
        g.n(),
        g.m(),
        g.max_degree(),
        est.density_lower_bound,
        est.degeneracy
    );

    let timer = Timer::start();
    let mut rounds = None;
    let clustering = match algo.as_str() {
        "pivot" => pivot_random(&g, &mut rng),
        "alg4-pivot" => alg4(&g, lambda, eps, |sub| pivot_random(sub, &mut rng)),
        "mpc-pivot" => {
            let mut sim = sim_for(&g, &model, delta, seed);
            let sub = if model == "m2" {
                Subroutine::Alg3(Alg3Params::default())
            } else {
                Subroutine::Alg2(Alg2Params::default())
            };
            let perm = rng.permutation(g.n());
            let run =
                mpc_pivot(&g, &perm, &Alg1Params { c_prefix: 1.0, subroutine: sub }, &mut sim);
            rounds = Some(sim.n_rounds());
            run.clustering
        }
        "simple" => {
            let mut sim = sim_for(&g, &model, delta, seed);
            let run = simple_clustering(&g, lambda, &mut sim);
            rounds = Some(run.rounds);
            run.clustering
        }
        other => panic!("unknown --algo '{other}' (pivot|alg4-pivot|mpc-pivot|simple)"),
    };
    let elapsed = timer.elapsed_s();

    let c = cost(&g, &clustering);
    let lb = packing_lower_bound(&g);
    println!(
        "algo={algo} cost={} (pos {}, neg {}) clusters={} max|C|={}",
        c.total(),
        c.positive,
        c.negative,
        clustering.n_clusters(),
        clustering.max_cluster_size()
    );
    if lb > 0 {
        println!(
            "bad-triangle packing LB={} ⇒ ratio ≤ {}",
            lb,
            fnum(c.total() as f64 / lb as f64)
        );
    }
    if let Some(r) = rounds {
        println!("MPC rounds={r} (model={model}, δ={delta})");
    }
    println!("wall time: {elapsed:.3}s");
    Ok(())
}

fn cmd_mis(args: &Args) -> Result<()> {
    let (g, family, seed) = make_graph(args);
    let delta = args.get_f64("delta", 0.5);
    let method = args.get_str("method", "alg2");
    let mut rng = Rng::new(seed ^ 0x5EED);
    let perm = rng.permutation(g.n());

    let mut table = Table::new(
        &format!("greedy MIS rounds — {} n={} Δ={}", family, g.n(), g.max_degree()),
        &["method", "model", "rounds", "|MIS|"],
    );
    let run_one = |method: &str, table: &mut Table| {
        let (model, sub) = match method {
            "alg2" => ("m1", Subroutine::Alg2(Alg2Params::default())),
            "alg3" => ("m2", Subroutine::Alg3(Alg3Params::default())),
            "direct" => ("m1", Subroutine::Alg2(Alg2Params::default())),
            other => panic!("unknown --method '{other}' (alg2|alg3|direct|all)"),
        };
        let mut sim = sim_for(&g, model, delta, seed);
        let mis = if method == "direct" {
            direct_simulation_mis(&g, &perm, &mut sim)
        } else {
            alg1_greedy_mis(&g, &perm, &Alg1Params { c_prefix: 1.0, subroutine: sub }, &mut sim)
                .in_mis
        };
        let size = mis.iter().filter(|&&b| b).count();
        table.row(&[
            method.to_string(),
            model.to_string(),
            sim.n_rounds().to_string(),
            size.to_string(),
        ]);
    };
    if method == "all" {
        for m in ["direct", "alg2", "alg3"] {
            run_one(m, &mut table);
        }
    } else {
        run_one(&method, &mut table);
    }
    table.print();
    Ok(())
}

fn cmd_best_of_k(args: &Args) -> Result<()> {
    let (g, family, seed) = make_graph(args);
    let k = args.get_usize("k", 16);
    let workers = args.get_usize("workers", 4);
    let eps = args.get_f64("eps", 2.0);
    let est = estimate_arboricity(&g);
    let lambda = args.get_usize("lambda", est.degeneracy.max(1));
    let engine =
        if args.get_bool("native") { CostEngine::native() } else { CostEngine::auto_default() };
    println!(
        "backend: {:?}; workload {} n={} m={}; K={k}, workers={workers}",
        engine.kind(),
        family,
        g.n(),
        g.m()
    );
    let g = Arc::new(g);
    let timer = Timer::start();
    let run = best_of_k(&g, &TrialSpec::Alg4Pivot { lambda, eps }, k, workers, seed, &engine)?;
    let elapsed = timer.elapsed_s();
    let lb = packing_lower_bound(&g);
    let worst = *run.costs.iter().max().unwrap();
    println!(
        "best={} worst={} (spread {}); LB={} ⇒ best ratio ≤ {}",
        run.best_cost.total(),
        worst,
        worst - run.best_cost.total(),
        lb,
        if lb > 0 { fnum(run.best_cost.total() as f64 / lb as f64) } else { "n/a".into() }
    );
    println!("scored {k} clusterings in {elapsed:.3}s ({:.1} trials/s)", k as f64 / elapsed);
    Ok(())
}

fn cmd_forest(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 10_000);
    let seed = args.get_u64("seed", 1);
    let eps = args.get_f64("eps", 0.5);
    let mut rng = Rng::new(seed);
    let g = arbocc::graph::generators::random_forest(n, 0.9, &mut rng);

    let mut table = Table::new(
        &format!("forest algorithms — n={} m={}", g.n(), g.m()),
        &["algorithm", "|M|", "cost", "rounds"],
    );
    // Corollary 31(i): exact maximum matching.
    let m_star = maximum_matching_forest(&g);
    let c = clustering_from_matching(g.n(), &m_star);
    table.row(&[
        "maximum (opt)".into(),
        m_star.len().to_string(),
        cost(&g, &c).total().to_string(),
        "-".into(),
    ]);
    // Maximal (2-approx).
    let mut sim = sim_for(&g, "m1", 0.5, seed);
    let maximal = maximal_matching(&g, &mut rng, &mut sim, 64);
    let cm = clustering_from_matching(g.n(), &maximal.matching);
    table.row(&[
        "maximal (2-approx)".into(),
        maximal.matching.len().to_string(),
        cost(&g, &cm).total().to_string(),
        sim.n_rounds().to_string(),
    ]);
    // (1+ε).
    let mut sim2 = sim_for(&g, "m1", 0.5, seed);
    let approx = approx_matching(&g, maximal.matching.clone(), eps, &mut sim2);
    let ca = clustering_from_matching(g.n(), &approx.matching);
    table.row(&[
        format!("(1+{eps})-approx"),
        approx.matching.len().to_string(),
        cost(&g, &ca).total().to_string(),
        sim2.n_rounds().to_string(),
    ]);
    table.print();
    Ok(())
}

fn cmd_check(_args: &Args) -> Result<()> {
    let engine = CostEngine::auto_default();
    match engine.kind() {
        BackendKind::Native => {
            println!("artifacts/ missing or unloadable — run `make artifacts` first");
            return Ok(());
        }
        BackendKind::Pjrt => println!("PJRT engine loaded from artifacts/"),
    }
    let native = CostEngine::native();
    let mut rng = Rng::new(123);
    let mut checked = 0;
    for lambda in [1usize, 2, 4] {
        let g = arbocc::graph::generators::lambda_arboric(200, lambda, &mut rng);
        let c = pivot_random(&g, &mut rng);
        let a = engine.cost(&g, &c)?;
        let b = native.cost(&g, &c)?;
        arbocc::ensure!(a == b, "cost mismatch: pjrt {a:?} vs native {b:?}");
        let ta = engine.bad_triangles_single_block(&g)?;
        let tb = native.bad_triangles_single_block(&g)?;
        arbocc::ensure!(ta == tb, "triangles mismatch: {ta} vs {tb}");
        let cs: Vec<_> = (0..9).map(|_| pivot_random(&g, &mut rng)).collect();
        let ba = engine.cost_batch_single_block(&g, &cs)?;
        let bb = native.cost_batch_single_block(&g, &cs)?;
        arbocc::ensure!(ba == bb, "batch mismatch");
        checked += 3;
    }
    println!("self-check OK: {checked} PJRT-vs-native comparisons identical");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("arbocc {}", env!("CARGO_PKG_VERSION"));
    println!(
        "artifacts present: {}",
        arbocc::runtime::client::PjrtEngine::artifacts_present(std::path::Path::new("artifacts"))
    );
    println!(
        "block protocol: N={} batch={}",
        arbocc::runtime::blocks::BLOCK_N,
        arbocc::runtime::blocks::BLOCK_BATCH
    );
    Ok(())
}

/// The perf-lab orchestrator (see DESIGN.md §perf-lab):
///
///   arbocc bench [--tier smoke|full] [--label PR2] [--out path.json]
///                [--filter substr] [--compare [baseline.json]]
///                [--replay run.json] [--list]
///
/// Runs the registered scenarios, writes `BENCH_<label>.json`, and with
/// `--compare` diffs against a baseline (explicit path, or the newest
/// other same-tier `BENCH_*.json` next to the output) — exiting
/// non-zero when any gated metric regresses beyond its noise-aware
/// tolerance. `--replay` loads a previous run's JSON instead of
/// re-running the suite, so CI can gate an already-recorded run.
fn cmd_bench(args: &Args) -> Result<()> {
    use arbocc::bench::compare::{self, CompareConfig};
    use arbocc::bench::suite::{Registry, Tier};

    let registry = Registry::standard();
    if args.get_bool("list") {
        println!("{} registered scenario(s):", registry.len());
        for s in registry.scenarios() {
            println!("  {:<24} [{:<18}] {}", s.name, s.bin, s.about);
        }
        return Ok(());
    }

    let (result, out_path, prior) = if let Some(replay) = args.get("replay") {
        let path = std::path::PathBuf::from(replay);
        let result = match compare::load(&path) {
            Ok(r) => r,
            Err(e) => arbocc::bail!("loading --replay {}: {e}", path.display()),
        };
        println!(
            "replayed {} ({} scenarios, tier {})",
            path.display(),
            result.scenarios.len(),
            result.tier.name()
        );
        (result, path, None)
    } else {
        let tier_s = args.get_str("tier", "smoke");
        let tier = match Tier::parse(&tier_s) {
            Some(t) => t,
            None => arbocc::bail!("unknown --tier '{tier_s}' (smoke|full)"),
        };
        let label = args.get_str("label", "local");
        let filter = args.get("filter");
        let result = registry.run(tier, &label, filter);
        arbocc::ensure!(
            !result.scenarios.is_empty(),
            "no scenarios matched filter {:?}",
            filter
        );
        let out = args.get_str("out", &format!("BENCH_{label}.json"));
        let out_path = std::path::PathBuf::from(&out);
        // A previous run at the same path is the natural baseline for a
        // bare --compare — capture it before the write destroys it
        // (otherwise `make bench-gate` would clobber the only baseline
        // and then gate against nothing).
        let prior = if args.has("compare") {
            compare::load(&out_path).ok().filter(|b| !b.partial && b.tier == tier)
        } else {
            None
        };
        std::fs::write(&out_path, result.to_json().pretty())?;
        println!("wrote {} ({} scenarios)", out_path.display(), result.scenarios.len());
        (result, out_path, prior)
    };

    let Some(cmp_flag) = args.get("compare") else {
        return Ok(());
    };
    let (baseline, baseline_name) = if cmp_flag == "true" {
        if let Some(b) = prior {
            // Pre-run contents of the output path.
            (b, format!("{} (previous contents)", out_path.display()))
        } else {
            // Newest other same-tier BENCH_*.json next to the output
            // (smoke and full runs are never diffed against each other —
            // same metric names, ~10× different workloads).
            let dir = match out_path.parent() {
                Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
                _ => std::path::PathBuf::from("."),
            };
            let found = compare::find_previous_baseline(&dir, Some(&out_path), Some(result.tier));
            let path = match found {
                Some(p) => p,
                None => {
                    println!(
                        "no previous {}-tier BENCH_*.json in {} — baseline recorded, nothing to gate",
                        result.tier.name(),
                        dir.display()
                    );
                    return Ok(());
                }
            };
            match compare::load(&path) {
                Ok(b) => (b, path.display().to_string()),
                Err(e) => arbocc::bail!("loading baseline {}: {e}", path.display()),
            }
        }
    } else {
        let path = std::path::PathBuf::from(cmp_flag);
        match compare::load(&path) {
            Ok(b) => (b, path.display().to_string()),
            Err(e) => arbocc::bail!("loading baseline {}: {e}", path.display()),
        }
    };
    arbocc::ensure!(
        baseline.tier == result.tier,
        "tier mismatch: baseline {baseline_name} is {}-tier but this run is {}-tier — \
         smoke and full sweeps use different workload sizes and cannot be gated \
         against each other",
        baseline.tier.name(),
        result.tier.name()
    );
    let cmp = compare::compare(&baseline, &result, &CompareConfig::default());
    let md = arbocc::bench::report::render_comparison(&cmp);
    println!("\n{md}");
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/COMPARE.md", &md)?;
    if cmp.has_regressions() {
        eprintln!(
            "bench gate: {} regression(s) vs {baseline_name}",
            cmp.regressions().len()
        );
        std::process::exit(1);
    }
    println!("bench gate: no regressions vs {baseline_name}");
    Ok(())
}

fn cmd_report() -> Result<()> {
    let reports = arbocc::bench::report::load_reports(std::path::Path::new("reports"))?;
    if reports.is_empty() {
        println!("no reports found — run `cargo bench` first");
        return Ok(());
    }
    let md = arbocc::bench::report::render_markdown(&reports);
    let out = std::path::Path::new("reports/SUMMARY.md");
    std::fs::write(out, &md)?;
    println!("{} reports aggregated -> {}", reports.len(), out.display());
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("info");
    match cmd {
        "cluster" => cmd_cluster(&args),
        "mis" => cmd_mis(&args),
        "best-of-k" => cmd_best_of_k(&args),
        "forest" => cmd_forest(&args),
        "bench" => cmd_bench(&args),
        "check" => cmd_check(&args),
        "report" => cmd_report(),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command '{other}'");
            eprintln!(
                "usage: arbocc <cluster|mis|best-of-k|forest|bench|check|report|info> [--flags]"
            );
            std::process::exit(2);
        }
    }
}
