//! MPC connected components: label propagation with pointer doubling —
//! the distributed substrate behind Corollary 32's component detection
//! (and a standard O(log D) MPC primitive in its own right).
//!
//! Each vertex maintains a candidate component label (initially its own
//! id).  Rounds alternate (a) label exchange with neighbors — take the
//! min — and (b) pointer jumping through the current label's label, which
//! squares the propagation distance.  Terminates in O(log D) rounds on
//! diameter-D graphs; every round is charged to the simulator with its
//! measured traffic.
//!
//! The exchange half (a pure function of the previous round's labels) is
//! the round's local compute and fans out across the simulator's shard
//! pool, merged in shard order at the barrier — results and traces are
//! identical at every shard count.  The jump half stays sequential: its
//! in-pass chain compression (`next[v] ← next[next[v]]` reading earlier
//! writes) is part of the charged schedule.

use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;

/// Result with round observability.
#[derive(Debug, Clone)]
pub struct MpcComponents {
    /// Component label per vertex (the min vertex id of the component).
    pub label: Vec<u32>,
    pub rounds: usize,
}

/// Min-label propagation with pointer jumping.
pub fn mpc_components(g: &Graph, sim: &mut MpcSimulator) -> MpcComponents {
    let n = g.n();
    let mut label: Vec<u32> = (0..n as u32).collect();
    let rounds_before = sim.n_rounds();
    let max_deg = g.max_degree() as Words;
    let pool = sim.pool();
    // Round-recycled scratch: per-shard output buffers ride the seeded
    // pool API (drained in, recycled out each round with capacity warm),
    // and `label`/`next` ping-pong via swap — after the first round the
    // O(log D) loop allocates nothing.
    let mut seeds: Vec<Vec<u32>> = Vec::new();
    let mut parts: Vec<(Vec<u32>, bool)> = Vec::new();
    let mut next: Vec<u32> = Vec::with_capacity(n);
    loop {
        // (a) neighbor min-exchange — per-vertex local compute over the
        // previous labels, sharded on the pool and merged in shard order.
        while seeds.len() < pool.shard_count(n) {
            seeds.push(Vec::new());
        }
        let label_now = &label;
        pool.run_fine_seeded(n, &mut seeds, &mut parts, |_, range, mut out: Vec<u32>| {
            out.clear();
            out.reserve(range.len());
            let mut shard_changed = false;
            for v in range {
                let mut best = label_now[v];
                for &u in g.neighbors(v as u32) {
                    best = best.min(label_now[u as usize]);
                }
                shard_changed |= best < label_now[v];
                out.push(best);
            }
            (out, shard_changed)
        });
        let mut changed = false;
        next.clear();
        for (part, shard_changed) in &parts {
            next.extend_from_slice(part);
            changed |= *shard_changed;
        }
        seeds.extend(parts.drain(..).map(|(mut part, _)| {
            part.clear();
            part
        }));
        sim.round("components/exchange", max_deg, max_deg, 2 * g.m() as Words, max_deg + 1);
        // (b) pointer jumping: label <- label[label].
        for v in 0..n {
            let l = next[v] as usize;
            if next[l] < next[v] {
                next[v] = next[l];
                changed = true;
            }
        }
        sim.round("components/jump", 2, 2, n as Words, 2);
        std::mem::swap(&mut label, &mut next);
        if !changed {
            break;
        }
    }
    MpcComponents { label, rounds: sim.n_rounds() - rounds_before }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components::components;
    use crate::graph::generators::{disjoint_cliques, grid, path, random_forest};
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    fn sim(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(
            g.n().max(2),
            (g.n() + 2 * g.m()).max(4) as Words,
            0.5,
        ))
    }

    #[test]
    fn matches_bfs_components() {
        let mut rng = Rng::new(320);
        for trial in 0..5 {
            let g = random_forest(300, 0.7, &mut rng);
            let mut s = sim(&g);
            let mpc = mpc_components(&g, &mut s);
            let reference = components(&g);
            // Same partition: labels agree iff reference labels agree.
            for u in 0..g.n() as u32 {
                for &v in g.neighbors(u) {
                    assert_eq!(
                        mpc.label[u as usize] == mpc.label[v as usize],
                        reference.label[u as usize] == reference.label[v as usize],
                        "trial {trial}"
                    );
                }
            }
            let distinct: std::collections::HashSet<u32> = mpc.label.iter().copied().collect();
            assert_eq!(distinct.len(), reference.count, "trial {trial}");
        }
    }

    #[test]
    fn label_is_component_min() {
        let g = disjoint_cliques(3, 4);
        let mut s = sim(&g);
        let mpc = mpc_components(&g, &mut s);
        assert_eq!(mpc.label[0..4], [0, 0, 0, 0]);
        assert_eq!(mpc.label[4..8], [4, 4, 4, 4]);
    }

    #[test]
    fn rounds_logarithmic_in_diameter() {
        // Pointer jumping: a path of length 4096 should resolve in far
        // fewer than 4096 rounds.
        let g = path(4096);
        let mut s = sim(&g);
        let mpc = mpc_components(&g, &mut s);
        assert!(mpc.rounds < 200, "rounds {} not sublinear in diameter", mpc.rounds);
        assert!(mpc.label.iter().all(|&l| l == 0));
    }

    #[test]
    fn grid_single_component() {
        let g = grid(32, 32);
        let mut s = sim(&g);
        let mpc = mpc_components(&g, &mut s);
        assert!(mpc.label.iter().all(|&l| l == 0));
    }
}
