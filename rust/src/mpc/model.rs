//! MPC model configurations (paper §1.3.2, Models 1 and 2).
//!
//! * **Model 1** (strongly sublinear): `S = Θ̃(n^δ)` words per machine,
//!   `M = Θ(N / S)` machines, global memory `M · S ≥ N`.
//! * **Model 2** (≥ n machines): every vertex owns a machine with
//!   `S = Θ̃(n^δ)`; global memory may reach `Θ̃(n^{1+δ})`.
//!
//! `Θ̃` hides polylog(n) factors; the `polylog_slack` knob makes that
//! hidden factor explicit so experiments can report *which* constant was
//! needed — e.g. Algorithm 2's component gathering needs S large enough
//! for poly(log n)-sized components, which is exactly the paper's
//! assumption.

use crate::mpc::memory::Words;

/// Which memory regime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Model 1: M = Θ(N/S) machines.
    M1,
    /// Model 2: M ≥ n machines, one per vertex.
    M2,
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelKind::M1 => write!(f, "Model1"),
            ModelKind::M2 => write!(f, "Model2"),
        }
    }
}

/// A concrete instantiation of the model for an input instance.
#[derive(Debug, Clone)]
pub struct MpcConfig {
    pub kind: ModelKind,
    /// Number of vertices of the input graph.
    pub n: usize,
    /// Input size in words (N = |E+| edge records, at least n).
    pub input_words: Words,
    /// Memory exponent δ ∈ (0, 1).
    pub delta: f64,
    /// Hidden polylog factor: S = polylog_slack · log²(n) · n^δ.
    pub polylog_slack: f64,
    /// Per-machine memory in words.
    pub s_words: Words,
    /// Number of machines.
    pub machines: usize,
    /// Global memory budget in words.
    pub global_words: Words,
}

impl MpcConfig {
    /// Standard strongly-sublinear configuration (Model 1).
    pub fn model1(n: usize, input_words: Words, delta: f64) -> MpcConfig {
        Self::model1_slack(n, input_words, delta, 4.0)
    }

    pub fn model1_slack(n: usize, input_words: Words, delta: f64, slack: f64) -> MpcConfig {
        assert!((0.0..1.0).contains(&delta), "δ must be in (0,1)");
        let s = s_words(n, delta, slack);
        // M = Θ(N/S), with headroom 2 for round scratch; at least 1.
        let machines = ((2 * input_words).div_ceil(s) as usize).max(1);
        MpcConfig {
            kind: ModelKind::M1,
            n,
            input_words,
            delta,
            polylog_slack: slack,
            s_words: s,
            machines,
            // M·S ≥ N by construction; allow the model's Õ slack globally.
            global_words: s * machines as Words,
        }
    }

    /// Model 2: at least n machines (one per vertex plus the M1 fleet).
    pub fn model2(n: usize, input_words: Words, delta: f64) -> MpcConfig {
        Self::model2_slack(n, input_words, delta, 4.0)
    }

    pub fn model2_slack(n: usize, input_words: Words, delta: f64, slack: f64) -> MpcConfig {
        assert!((0.0..1.0).contains(&delta), "δ must be in (0,1)");
        let s = s_words(n, delta, slack);
        let m1_machines = ((2 * input_words).div_ceil(s) as usize).max(1);
        let machines = m1_machines.max(n.max(1));
        MpcConfig {
            kind: ModelKind::M2,
            n,
            input_words,
            delta,
            polylog_slack: slack,
            s_words: s,
            machines,
            global_words: s * machines as Words,
        }
    }

    /// Rounds needed by a broadcast/convergecast tree (§2.1.5):
    /// ⌈log_S(machines)⌉, i.e. O(1/δ) for constant δ.
    pub fn broadcast_tree_depth(&self) -> usize {
        if self.machines <= 1 {
            return 1;
        }
        let s = (self.s_words as f64).max(2.0);
        let mut depth = 0usize;
        let mut reach = 1f64;
        while reach < self.machines as f64 {
            reach *= s;
            depth += 1;
        }
        depth
    }

    /// Does a per-vertex state of `words` fit a single machine?
    pub fn fits_machine(&self, words: Words) -> bool {
        words <= self.s_words
    }
}

/// S = slack · log2(n)^2 · n^δ words (the Õ(n^δ) of the paper, with the
/// polylog factor explicit).
pub fn s_words(n: usize, delta: f64, slack: f64) -> Words {
    let n = n.max(2) as f64;
    let log2n = n.log2().max(1.0);
    (slack * log2n * log2n * n.powf(delta)).ceil() as Words
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model1_memory_identity() {
        // Large n so the Õ polylog slack doesn't dominate n^δ.
        let n = 1_000_000;
        let cfg = MpcConfig::model1(n, 3 * n as Words, 0.3);
        assert_eq!(cfg.kind, ModelKind::M1);
        // Global memory covers the input.
        assert!(cfg.global_words >= cfg.input_words);
        // Strongly sublinear: S ≪ N.
        assert!(cfg.s_words < cfg.input_words);
    }

    #[test]
    fn model2_has_n_machines() {
        let n = 5_000;
        let cfg = MpcConfig::model2(n, 2 * n as Words, 0.3);
        assert!(cfg.machines >= n);
    }

    #[test]
    fn s_grows_with_delta() {
        let n = 100_000;
        assert!(s_words(n, 0.8, 1.0) > s_words(n, 0.3, 1.0));
    }

    #[test]
    fn broadcast_depth_is_small() {
        let cfg = MpcConfig::model1(1_000_000, 10_000_000, 0.5);
        // S ~ 4·20²·1000 = 1.6M words, machines ~ 13 ⇒ depth 1.
        assert!(cfg.broadcast_tree_depth() <= 2, "depth {}", cfg.broadcast_tree_depth());
    }

    #[test]
    fn fits_machine_respects_s() {
        let cfg = MpcConfig::model1(1000, 5000, 0.5);
        assert!(cfg.fits_machine(cfg.s_words));
        assert!(!cfg.fits_machine(cfg.s_words + 1));
    }

    #[test]
    #[should_panic(expected = "δ must be in")]
    fn bad_delta_panics() {
        MpcConfig::model1(100, 100, 1.5);
    }
}
