//! Synchronous message router: the executable all-to-all layer, running
//! on the flat-arena message plane ([`crate::mpc::wire`]).
//!
//! One call to [`Router::round`] is one MPC communication round: each
//! shard of the simulator's [`ShardPool`] builds its machines' outboxes
//! into one contiguous payload slab plus a `(from, dst, offset, len)`
//! index (the round's local-compute half), send words are tallied on
//! per-shard [`ShardLedger`]s as messages are appended, and the
//! synchronous barrier exchanges *slabs*, not per-message allocations:
//! index entries are walked in shard order — which is sender order — so
//! inbox delivery order is identical to the retired per-message plane,
//! and payloads are copied once into receiver-side slabs that inboxes
//! borrow zero-copy. Ledgers are merged into fleet [`MemoryLedger`]s at
//! the barrier, where O(S) send/receive and global budget violations
//! surface exactly as in sequential execution, and the round is recorded
//! on the [`MpcSimulator`]. The broadcast/convergecast trees (§2.1.5)
//! run on top of this for real, so their round counts are measured
//! rather than asserted.
//!
//! With a one-shard pool the build closure runs inline on the caller's
//! thread: the sequential executor is the same code path. Inboxes,
//! statistics and violations are bit-identical at every shard count.
//!
//! [`ShardPool`]: crate::mpc::pool::ShardPool

use crate::mpc::memory::{BudgetError, MemoryLedger, ShardLedger, Words};
use crate::mpc::simulator::MpcSimulator;
use crate::mpc::wire::{RoundInboxes, WireOutbox};

/// Stateless router over `machines` mailboxes.
#[derive(Debug)]
pub struct Router {
    machines: usize,
}

impl Router {
    pub fn new(machines: usize) -> Router {
        Router { machines }
    }

    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Execute one synchronous round on the flat-arena plane.
    ///
    /// `build(m, outbox)` produces machine `m`'s messages — the round's
    /// local compute — and is invoked on the shard that owns `m`, with
    /// the outbox positioned on sender `m`. Returns the round's
    /// [`RoundInboxes`]: zero-copy per-machine views, delivered in
    /// deterministic (sender-ordered) order.
    pub fn round<F>(&self, sim: &mut MpcSimulator, label: &str, build: F) -> RoundInboxes
    where
        F: Fn(usize, &mut WireOutbox) + Sync,
    {
        let pool = sim.pool();
        // Local-compute half, fanned out per machine shard (fine-grained:
        // small fleets build their outboxes inline). Each shard appends
        // into its own slab and tallies send words on its private ledger.
        let shard_out: Vec<WireOutbox> = pool.run_fine(self.machines, |_, range| {
            let mut out = WireOutbox::new(range.clone(), self.machines);
            for m in range {
                out.begin(m);
                build(m, &mut out);
            }
            out
        });
        // Exchange at the synchronous round boundary: shards are walked
        // in order, so inbox contents match the sequential sender order.
        let mut recv = ShardLedger::new(0..self.machines);
        let inboxes = RoundInboxes::deliver(self.machines, &shard_out, &mut recv);
        let send_ledgers: Vec<ShardLedger> =
            shard_out.into_iter().map(WireOutbox::into_ledger).collect();
        self.barrier(sim, label, &send_ledgers, recv);
        inboxes
    }

    /// The round barrier: merge shard ledgers into fleet ledgers, surface
    /// the first budget violation, record the round's merged statistics.
    fn barrier(
        &self,
        sim: &mut MpcSimulator,
        label: &str,
        send: &[ShardLedger],
        recv: ShardLedger,
    ) {
        // Statistics come from the raw shard tallies (complete even when a
        // budget is blown, so traces are identical in strict and lenient
        // mode and at every shard count).
        let max_out: Words = send.iter().map(ShardLedger::max_local).max().unwrap_or(0);
        let max_in: Words = recv.max_local();
        let total: Words = send.iter().map(ShardLedger::total).sum();
        // Budget enforcement on the merged ledgers. The global budget is
        // charged once, on the send side (receive totals mirror it).
        let s = sim.config.s_words;
        let mut sent_fleet = MemoryLedger::new(self.machines, s, sim.config.global_words);
        let mut recv_fleet = MemoryLedger::new(self.machines, s, Words::MAX);
        let mut violation: Option<BudgetError> = None;
        for shard in send {
            if violation.is_none() {
                violation = sent_fleet.absorb(shard).err();
            }
        }
        if violation.is_none() {
            violation = recv_fleet.absorb(&recv).err();
        }
        sim.round_checked(label, max_out, max_in, total, max_out.max(max_in), violation);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::model::MpcConfig;

    fn sim_for(machines: usize) -> MpcSimulator {
        // Large-ish S so normal tests pass budgets.
        MpcSimulator::new(MpcConfig::model1(10_000, 100_000, 0.6))
        .into_with(machines)
    }

    trait With {
        fn into_with(self, machines: usize) -> MpcSimulator;
    }
    impl With for MpcSimulator {
        fn into_with(mut self, machines: usize) -> MpcSimulator {
            self.config.machines = machines;
            self
        }
    }

    #[test]
    fn delivers_messages() {
        let router = Router::new(3);
        let mut sim = sim_for(3);
        let inboxes = router.round(&mut sim, "test", |m, out| match m {
            0 => {
                out.send(1, &42u64);
                out.send_words(2, &[7, 8]);
            }
            1 => out.send(0, &1u64),
            _ => {}
        });
        assert_eq!(inboxes.inbox(1).len(), 1);
        assert_eq!(inboxes.inbox(1).get(0).payload, &[42]);
        assert_eq!(inboxes.inbox(1).get(0).from, 0);
        assert_eq!(inboxes.inbox(2).get(0).payload, &[7, 8]);
        assert_eq!(inboxes.inbox(0).get(0).from, 1);
        assert_eq!(sim.n_rounds(), 1);
    }

    #[test]
    #[should_panic(expected = "model violation")]
    fn oversized_send_violates() {
        let router = Router::new(2);
        let mut sim = sim_for(2);
        let huge = vec![0u64; sim.config.s_words as usize + 10];
        router.round(&mut sim, "big", |m, out| {
            if m == 0 {
                out.send_words(1, &huge);
            }
        });
    }

    #[test]
    fn empty_round_counts() {
        let router = Router::new(2);
        let mut sim = sim_for(2);
        let inboxes = router.round(&mut sim, "idle", |_, _| {});
        assert_eq!(inboxes.total_messages(), 0);
        assert!((0..2).all(|m| inboxes.inbox(m).is_empty()));
        assert_eq!(sim.n_rounds(), 1);
    }

    /// An all-to-some schedule with payload sizes varying by sender,
    /// written once so the arena plane and the legacy oracle send the
    /// byte-identical message stream.
    fn varied_schedule(machines: usize, m: usize) -> Vec<(usize, Vec<u64>)> {
        (0..machines)
            .filter(|&d| (m + d) % 3 == 0)
            .map(|d| (d, vec![m as u64; 1 + (m % 4)]))
            .collect()
    }

    fn varied_build(machines: usize) -> impl Fn(usize, &mut WireOutbox) + Sync {
        move |m: usize, out: &mut WireOutbox| {
            for (d, payload) in varied_schedule(machines, m) {
                out.send_words(d, &payload);
            }
        }
    }

    #[test]
    fn sharded_round_matches_serial_round() {
        let machines = 13;
        let router = Router::new(machines);
        let mut seq = sim_for(machines);
        let expected = router.round(&mut seq, "x", varied_build(machines));
        for shards in [1usize, 2, 8] {
            let mut sim = MpcSimulator::sharded(MpcConfig::model1(10_000, 100_000, 0.6), shards)
                .into_with(machines);
            let got = router.round(&mut sim, "x", varied_build(machines));
            assert_eq!(got, expected, "{shards} shards");
            assert_eq!(sim.trace(), seq.trace(), "{shards} shards");
        }
    }

    #[test]
    fn sharded_round_threads_on_large_fleets() {
        // A fleet above the pool's SERIAL_CUTOFF drives the scoped-thread
        // outbox path and the cross-shard slab exchange for real.
        let machines = 600;
        let build = |m: usize, out: &mut WireOutbox| {
            out.send((m * 7 + 1) % machines, &(m as u64, (m / 3) as u64));
        };
        let router = Router::new(machines);
        let mut seq = sim_for(machines);
        let expected = router.round(&mut seq, "big", build);
        let mut sim = MpcSimulator::sharded(MpcConfig::model1(10_000, 100_000, 0.6), 8)
            .into_with(machines);
        let got = router.round(&mut sim, "big", build);
        assert_eq!(got, expected);
        assert_eq!(sim.trace(), seq.trace());
    }

    #[test]
    fn sharded_violation_reports_offending_machine() {
        let machines = 8;
        let cfg = MpcConfig::model1(10_000, 100_000, 0.6);
        let huge = vec![9u64; cfg.s_words as usize + 10];
        let mut sim = MpcSimulator::lenient_sharded(cfg, 4).into_with(machines);
        let router = Router::new(machines);
        let inboxes = router.round(&mut sim, "overflow", |m, out| {
            if m == 5 {
                out.send_words(0, &huge);
            }
        });
        assert_eq!(inboxes.inbox(0).len(), 1, "messages still delivered for diagnosis");
        assert!(!sim.ok());
        assert_eq!(sim.violations().len(), 1);
        let err = format!("{}", sim.violations()[0]);
        assert!(err.contains("machine 5"), "{err}");
    }

    #[test]
    fn arena_plane_matches_legacy_per_message_plane() {
        // Old-vs-new parity: identical RoundStat sequences and identical
        // delivered (from, payload) streams on a representative workload,
        // at 1/2/8 shards on the arena side. The oracle is the single
        // retired-plane reproduction in `wire::per_message_round` —
        // shared with the `mpc/plane_vs_permsg` benchmark baseline.
        let machines = 23;
        let mut legacy_sim = sim_for(machines);
        let mut legacy_rounds = Vec::new();
        for r in 0..3 {
            let outboxes: Vec<Vec<(usize, Vec<u64>)>> =
                (0..machines).map(|m| varied_schedule(machines, m)).collect();
            legacy_rounds.push(crate::mpc::wire::per_message_round(
                machines,
                &mut legacy_sim,
                &format!("round[{r}]"),
                outboxes,
            ));
        }
        let router = Router::new(machines);
        for shards in [1usize, 2, 8] {
            let mut sim = MpcSimulator::sharded(MpcConfig::model1(10_000, 100_000, 0.6), shards)
                .into_with(machines);
            for (r, legacy) in legacy_rounds.iter().enumerate() {
                let got =
                    router.round(&mut sim, &format!("round[{r}]"), varied_build(machines));
                for (m, want) in legacy.iter().enumerate() {
                    let arena: Vec<(usize, Vec<u64>)> =
                        got.inbox(m).iter().map(|w| (w.from, w.payload.to_vec())).collect();
                    assert_eq!(&arena, want, "{shards} shards, round {r}, machine {m}");
                }
            }
            assert_eq!(sim.trace(), legacy_sim.trace(), "{shards} shards");
        }
    }
}
