//! Synchronous message router: the executable all-to-all layer, running
//! on the pooled flat-arena message plane ([`crate::mpc::wire`],
//! [`crate::mpc::arena`]).
//!
//! One call to [`Router::round`] is one MPC communication round: each
//! shard of the simulator's [`ShardPool`] builds its machines' outboxes
//! into one contiguous payload slab plus a `(from, dst, offset, len)`
//! index (the round's local-compute half), send words are tallied on
//! per-shard [`ShardLedger`]s as messages are appended, and the
//! synchronous barrier exchanges *slabs*, not per-message allocations:
//! index entries are walked in shard order — which is sender order — so
//! inbox delivery order is identical to the retired per-message plane,
//! and payloads are copied once into receiver-side slabs that inboxes
//! borrow zero-copy. Ledgers are merged into fleet [`MemoryLedger`]s at
//! the barrier, where O(S) send/receive and global budget violations
//! surface exactly as in sequential execution, and the round is recorded
//! on the [`MpcSimulator`]. The broadcast/convergecast trees (§2.1.5)
//! run on top of this for real, so their round counts are measured
//! rather than asserted.
//!
//! Two raw-speed properties live here, both invisible to the model:
//!
//! * **Pooling** — every reusable body of the round barrier (outbox
//!   slabs, index Vecs, ledgers, sizing scratch, receiver slabs) lives
//!   in the router's [`RoundArena`] and is recycled `clear()`-style
//!   across rounds, so a steady-state round performs no heap allocation
//!   on the plane.
//! * **Width** — a router built via [`Router::for_fleet`] selects a
//!   [`WordWidth`] from the id range: when every vertex id and machine
//!   id fits `u32`, slabs store packed 4-byte units and the barrier
//!   copies half the bytes. The ledger charges *model words*, which are
//!   width-invariant, so budgets, traces and golden round schedules are
//!   bit-identical at both widths ([`Router::new`] keeps the `u64`
//!   plane, which the old-vs-new parity tests pin).
//!
//! With a one-shard pool the build closure runs inline on the caller's
//! thread: the sequential executor is the same code path. Inboxes,
//! statistics and violations are bit-identical at every shard count.
//!
//! [`ShardPool`]: crate::mpc::pool::ShardPool
//! [`RoundArena`]: crate::mpc::arena::RoundArena

use crate::mpc::arena::RoundArena;
use crate::mpc::memory::{BudgetError, MemoryLedger, ShardLedger, Words};
use crate::mpc::simulator::MpcSimulator;
use crate::mpc::wire::{RoundInboxes, WireOutbox, WordWidth};

/// Router over `machines` mailboxes, owning the pooled round arena.
#[derive(Debug)]
pub struct Router {
    machines: usize,
    width: WordWidth,
    arena: RoundArena,
}

impl Router {
    /// Router on the `u64` plane (the PR 5 wire format) — the width
    /// parity baseline, and the right default when id ranges are
    /// unknown.
    pub fn new(machines: usize) -> Router {
        Router::with_width(machines, WordWidth::W64)
    }

    /// Router at an explicit storage width (parity tests force both).
    pub fn with_width(machines: usize, width: WordWidth) -> Router {
        Router { machines, width, arena: RoundArena::new() }
    }

    /// Router for a fleet routing vertex ids in `0..n`: selects the
    /// narrow `u32` plane whenever ids and machine indices fit.
    pub fn for_fleet(machines: usize, n: usize) -> Router {
        Router::with_width(machines, WordWidth::for_ids(n, machines))
    }

    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Storage width of this router's slabs.
    pub fn width(&self) -> WordWidth {
        self.width
    }

    /// Execute one synchronous round on the flat-arena plane.
    ///
    /// `build(m, outbox)` produces machine `m`'s messages — the round's
    /// local compute — and is invoked on the shard that owns `m`, with
    /// the outbox positioned on sender `m`. Returns the round's
    /// [`RoundInboxes`]: zero-copy per-machine views, delivered in
    /// deterministic (sender-ordered) order. Dropping them returns their
    /// buffers to this router's arena.
    pub fn round<F>(&self, sim: &mut MpcSimulator, label: &str, build: F) -> RoundInboxes
    where
        F: Fn(usize, &mut WireOutbox) + Sync,
    {
        let machines = self.machines;
        let width = self.width;
        let mut guard = self.arena.lock();
        let core = &mut *guard;
        let pool = sim.pool();
        // Local-compute half, fanned out per machine shard (fine-grained:
        // small fleets build their outboxes inline). Each shard rewinds a
        // pooled outbox — slab and index keep their high-water capacity —
        // appends into it, and tallies send words on its private ledger.
        core.ensure_seeds(pool.shard_count(machines), width);
        pool.run_fine_seeded(
            machines,
            &mut core.seeds,
            &mut core.built,
            |_, range, mut out: WireOutbox| {
                out.reset(range.clone(), machines, width);
                for m in range {
                    out.begin(m);
                    build(m, &mut out);
                }
                out
            },
        );
        // Exchange at the synchronous round boundary: shards are walked
        // in order, so inbox contents match the sequential sender order.
        // Receiver bodies come from (and on drop return to) the arena's
        // reclaim bin.
        match &mut core.recv {
            Some(ledger) => ledger.reset(0..machines),
            None => core.recv = Some(ShardLedger::new(0..machines)),
        }
        let recv = core.recv.as_mut().expect("just installed");
        let inboxes = RoundInboxes::deliver(
            machines,
            width,
            &core.built,
            recv,
            &mut core.deliver,
            Some(&core.reclaim),
        );
        // The round barrier: statistics come from the raw shard tallies
        // (complete even when a budget is blown, so traces are identical
        // in strict and lenient mode and at every shard count).
        let max_out: Words =
            core.built.iter().map(|ob| ob.ledger().max_local()).max().unwrap_or(0);
        let max_in: Words = recv.max_local();
        let total: Words = core.built.iter().map(|ob| ob.ledger().total()).sum();
        // Budget enforcement on the merged (pooled, freshly re-targeted)
        // fleet ledgers. The global budget is charged once, on the send
        // side (receive totals mirror it).
        let s = sim.config.s_words;
        core.sent_fleet.reconfigure(machines, s, sim.config.global_words);
        core.recv_fleet.reconfigure(machines, s, Words::MAX);
        let mut violation: Option<BudgetError> = None;
        for ob in &core.built {
            if violation.is_none() {
                violation = core.sent_fleet.absorb(ob.ledger()).err();
            }
        }
        if violation.is_none() {
            let recv = core.recv.as_ref().expect("installed above");
            violation = core.recv_fleet.absorb(recv).err();
        }
        // Outboxes go back to the seed pool for the next round.
        core.seeds.append(&mut core.built);
        // Release the arena before recording: strict-mode violations
        // panic out of `round_checked`, and the arena must not be held
        // (poisoned) across that unwind more than necessary.
        drop(guard);
        sim.round_checked(label, max_out, max_in, total, max_out.max(max_in), violation);
        inboxes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::model::MpcConfig;

    fn sim_for(machines: usize) -> MpcSimulator {
        // Large-ish S so normal tests pass budgets.
        MpcSimulator::new(MpcConfig::model1(10_000, 100_000, 0.6))
        .into_with(machines)
    }

    trait With {
        fn into_with(self, machines: usize) -> MpcSimulator;
    }
    impl With for MpcSimulator {
        fn into_with(mut self, machines: usize) -> MpcSimulator {
            self.config.machines = machines;
            self
        }
    }

    #[test]
    fn delivers_messages() {
        let router = Router::new(3);
        let mut sim = sim_for(3);
        let inboxes = router.round(&mut sim, "test", |m, out| match m {
            0 => {
                out.send(1, &42u64);
                out.send_words(2, &[7, 8]);
            }
            1 => out.send(0, &1u64),
            _ => {}
        });
        assert_eq!(inboxes.inbox(1).len(), 1);
        assert_eq!(inboxes.inbox(1).get(0).decode::<u64>(), 42);
        assert_eq!(inboxes.inbox(1).get(0).from, 0);
        assert_eq!(inboxes.inbox(2).get(0).to_words(), vec![7, 8]);
        assert_eq!(inboxes.inbox(0).get(0).from, 1);
        assert_eq!(sim.n_rounds(), 1);
    }

    #[test]
    #[should_panic(expected = "model violation")]
    fn oversized_send_violates() {
        let router = Router::new(2);
        let mut sim = sim_for(2);
        let huge = vec![0u64; sim.config.s_words as usize + 10];
        router.round(&mut sim, "big", |m, out| {
            if m == 0 {
                out.send_words(1, &huge);
            }
        });
    }

    #[test]
    fn empty_round_counts() {
        let router = Router::new(2);
        let mut sim = sim_for(2);
        let inboxes = router.round(&mut sim, "idle", |_, _| {});
        assert_eq!(inboxes.total_messages(), 0);
        assert!((0..2).all(|m| inboxes.inbox(m).is_empty()));
        assert_eq!(sim.n_rounds(), 1);
    }

    /// An all-to-some schedule with payload sizes varying by sender,
    /// written once so the arena plane and the legacy oracle send the
    /// byte-identical message stream.
    fn varied_schedule(machines: usize, m: usize) -> Vec<(usize, Vec<u64>)> {
        (0..machines)
            .filter(|&d| (m + d) % 3 == 0)
            .map(|d| (d, vec![m as u64; 1 + (m % 4)]))
            .collect()
    }

    fn varied_build(machines: usize) -> impl Fn(usize, &mut WireOutbox) + Sync {
        move |m: usize, out: &mut WireOutbox| {
            for (d, payload) in varied_schedule(machines, m) {
                out.send_words(d, &payload);
            }
        }
    }

    #[test]
    fn sharded_round_matches_serial_round() {
        let machines = 13;
        let router = Router::new(machines);
        let mut seq = sim_for(machines);
        let expected = router.round(&mut seq, "x", varied_build(machines));
        for shards in [1usize, 2, 8] {
            let mut sim = MpcSimulator::sharded(MpcConfig::model1(10_000, 100_000, 0.6), shards)
                .into_with(machines);
            let got = router.round(&mut sim, "x", varied_build(machines));
            assert_eq!(got, expected, "{shards} shards");
            assert_eq!(sim.trace(), seq.trace(), "{shards} shards");
        }
    }

    #[test]
    fn sharded_round_threads_on_large_fleets() {
        // A fleet above the pool's SERIAL_CUTOFF drives the scoped-thread
        // outbox path and the cross-shard slab exchange for real.
        let machines = 600;
        let build = |m: usize, out: &mut WireOutbox| {
            out.send((m * 7 + 1) % machines, &(m as u64, (m / 3) as u64));
        };
        let router = Router::new(machines);
        let mut seq = sim_for(machines);
        let expected = router.round(&mut seq, "big", build);
        let mut sim = MpcSimulator::sharded(MpcConfig::model1(10_000, 100_000, 0.6), 8)
            .into_with(machines);
        let got = router.round(&mut sim, "big", build);
        assert_eq!(got, expected);
        assert_eq!(sim.trace(), seq.trace());
    }

    #[test]
    fn sharded_violation_reports_offending_machine() {
        let machines = 8;
        let cfg = MpcConfig::model1(10_000, 100_000, 0.6);
        let huge = vec![9u64; cfg.s_words as usize + 10];
        let mut sim = MpcSimulator::lenient_sharded(cfg, 4).into_with(machines);
        let router = Router::new(machines);
        let inboxes = router.round(&mut sim, "overflow", |m, out| {
            if m == 5 {
                out.send_words(0, &huge);
            }
        });
        assert_eq!(inboxes.inbox(0).len(), 1, "messages still delivered for diagnosis");
        assert!(!sim.ok());
        assert_eq!(sim.violations().len(), 1);
        let err = format!("{}", sim.violations()[0]);
        assert!(err.contains("machine 5"), "{err}");
    }

    #[test]
    fn arena_plane_matches_legacy_per_message_plane() {
        // Old-vs-new parity: identical RoundStat sequences and identical
        // delivered (from, payload) streams on a representative workload,
        // at 1/2/8 shards on the arena side. The oracle is the single
        // retired-plane reproduction in `wire::per_message_round` —
        // shared with the `mpc/plane_vs_permsg` benchmark baseline.
        let machines = 23;
        let mut legacy_sim = sim_for(machines);
        let mut legacy_rounds = Vec::new();
        for r in 0..3 {
            let outboxes: Vec<Vec<(usize, Vec<u64>)>> =
                (0..machines).map(|m| varied_schedule(machines, m)).collect();
            legacy_rounds.push(crate::mpc::wire::per_message_round(
                machines,
                &mut legacy_sim,
                &format!("round[{r}]"),
                outboxes,
            ));
        }
        let router = Router::new(machines);
        for shards in [1usize, 2, 8] {
            let mut sim = MpcSimulator::sharded(MpcConfig::model1(10_000, 100_000, 0.6), shards)
                .into_with(machines);
            for (r, legacy) in legacy_rounds.iter().enumerate() {
                let got =
                    router.round(&mut sim, &format!("round[{r}]"), varied_build(machines));
                for (m, want) in legacy.iter().enumerate() {
                    let arena: Vec<(usize, Vec<u64>)> =
                        got.inbox(m).iter().map(|w| (w.from, w.to_words())).collect();
                    assert_eq!(&arena, want, "{shards} shards, round {r}, machine {m}");
                }
            }
            assert_eq!(sim.trace(), legacy_sim.trace(), "{shards} shards");
        }
    }

    #[test]
    fn for_fleet_selects_width_and_matches_u64_plane() {
        // The narrow plane must be a pure storage change: same inbox
        // streams (modulo unit packing), same traces, same ledgers.
        let machines = 13;
        assert_eq!(Router::for_fleet(machines, 1000).width(), WordWidth::W32);
        assert_eq!(
            Router::for_fleet(machines, u32::MAX as usize + 1).width(),
            WordWidth::W64
        );
        let build = |m: usize, out: &mut WireOutbox| {
            for d in 0..machines {
                if (m + d) % 4 == 0 {
                    out.send(d, &crate::mpc::wire::RankAnnounce {
                        vertex: m as u32,
                        rank: (d * 3) as u32,
                    });
                }
            }
        };
        let wide = Router::new(machines);
        let mut wide_sim = sim_for(machines);
        let expected = wide.round(&mut wide_sim, "w", build);
        let narrow = Router::for_fleet(machines, 1000);
        let mut narrow_sim = sim_for(machines);
        let got = narrow.round(&mut narrow_sim, "w", build);
        assert_eq!(narrow_sim.trace(), wide_sim.trace(), "model stats are width-invariant");
        for m in 0..machines {
            let w: Vec<(usize, crate::mpc::wire::RankAnnounce)> =
                expected.inbox(m).iter().map(|x| (x.from, x.decode())).collect();
            let n: Vec<(usize, crate::mpc::wire::RankAnnounce)> =
                got.inbox(m).iter().map(|x| (x.from, x.decode())).collect();
            assert_eq!(w, n, "machine {m}");
        }
    }

    #[test]
    fn pooled_rounds_recycle_inboxes() {
        // Dropping a round's inboxes hands their buffers back to the
        // router's arena; the next round pops them instead of allocating.
        let machines = 5;
        let router = Router::new(machines);
        let mut sim = sim_for(machines);
        let first = router.round(&mut sim, "r", varied_build(machines));
        let bin = {
            let core = router.arena.lock();
            core.reclaim.clone()
        };
        assert!(bin.lock().unwrap().is_empty(), "buffers out on loan");
        drop(first);
        assert!(!bin.lock().unwrap().is_empty(), "drop returns buffers to the bin");
        let expected = {
            let fresh = Router::new(machines);
            let mut s = sim_for(machines);
            fresh.round(&mut s, "r", varied_build(machines))
        };
        let second = router.round(&mut sim, "r", varied_build(machines));
        assert!(bin.lock().unwrap().is_empty(), "second round reuses the returned set");
        assert_eq!(second, expected, "recycling never changes delivered data");
    }
}
