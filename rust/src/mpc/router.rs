//! Synchronous message router: the executable all-to-all layer.
//!
//! One call to [`Router::step`] is one MPC communication round: every
//! machine's outbox is validated against the O(S) send budget, every
//! inbox against the O(S) receive budget, messages are delivered, and the
//! round is recorded on the [`MpcSimulator`].  The broadcast/convergecast
//! trees (§2.1.5) run on top of this for real, so their round counts are
//! measured rather than asserted.

use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;

/// A message between machines: opaque words plus the sender id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    pub from: usize,
    pub payload: Vec<u64>,
}

impl Message {
    pub fn words(&self) -> Words {
        // +1 word of envelope (sender id).
        self.payload.len() as Words + 1
    }
}

/// Stateless router over `machines` mailboxes.
#[derive(Debug)]
pub struct Router {
    machines: usize,
}

impl Router {
    pub fn new(machines: usize) -> Router {
        Router { machines }
    }

    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Execute one synchronous round.
    ///
    /// `outboxes[m]` is the list of `(dst, payload)` machine `m` sends.
    /// Returns `inboxes[m]`: messages delivered to machine `m`, in
    /// deterministic (sender-ordered) order.
    pub fn step(
        &self,
        sim: &mut MpcSimulator,
        label: &str,
        outboxes: Vec<Vec<(usize, Vec<u64>)>>,
    ) -> Vec<Vec<Message>> {
        assert_eq!(outboxes.len(), self.machines, "outbox per machine required");
        let mut inboxes: Vec<Vec<Message>> = vec![Vec::new(); self.machines];
        let mut max_out: Words = 0;
        let mut total: Words = 0;
        for (from, outbox) in outboxes.into_iter().enumerate() {
            let mut sent: Words = 0;
            for (dst, payload) in outbox {
                assert!(dst < self.machines, "message to unknown machine {dst}");
                let msg = Message { from, payload };
                sent += msg.words();
                inboxes[dst].push(msg);
            }
            max_out = max_out.max(sent);
            total += sent;
        }
        let max_in: Words = inboxes
            .iter()
            .map(|inbox| inbox.iter().map(Message::words).sum::<Words>())
            .max()
            .unwrap_or(0);
        // Resident state during a routing round is bounded by the larger
        // of what a machine sent or received.
        sim.round(label, max_out, max_in, total, max_out.max(max_in));
        inboxes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::model::MpcConfig;

    fn sim_for(machines: usize) -> MpcSimulator {
        // Large-ish S so normal tests pass budgets.
        MpcSimulator::new(MpcConfig::model1(10_000, 100_000, 0.6))
        .into_with(machines)
    }

    trait With {
        fn into_with(self, machines: usize) -> MpcSimulator;
    }
    impl With for MpcSimulator {
        fn into_with(mut self, machines: usize) -> MpcSimulator {
            self.config.machines = machines;
            self
        }
    }

    #[test]
    fn delivers_messages() {
        let router = Router::new(3);
        let mut sim = sim_for(3);
        let out = vec![
            vec![(1, vec![42]), (2, vec![7, 8])],
            vec![(0, vec![1])],
            vec![],
        ];
        let inboxes = router.step(&mut sim, "test", out);
        assert_eq!(inboxes[1].len(), 1);
        assert_eq!(inboxes[1][0].payload, vec![42]);
        assert_eq!(inboxes[1][0].from, 0);
        assert_eq!(inboxes[2][0].payload, vec![7, 8]);
        assert_eq!(inboxes[0][0].from, 1);
        assert_eq!(sim.n_rounds(), 1);
    }

    #[test]
    #[should_panic(expected = "model violation")]
    fn oversized_send_violates() {
        let router = Router::new(2);
        let mut sim = sim_for(2);
        let huge = vec![0u64; sim.config.s_words as usize + 10];
        router.step(&mut sim, "big", vec![vec![(1, huge)], vec![]]);
    }

    #[test]
    fn empty_round_counts() {
        let router = Router::new(2);
        let mut sim = sim_for(2);
        let inboxes = router.step(&mut sim, "idle", vec![vec![], vec![]]);
        assert!(inboxes.iter().all(|i| i.is_empty()));
        assert_eq!(sim.n_rounds(), 1);
    }
}
