//! Synchronous message router: the executable all-to-all layer.
//!
//! One call to [`Router::step`] (or [`Router::step_sharded`]) is one MPC
//! communication round: every machine's outbox is tallied on a
//! word-granular [`ShardLedger`], ledgers are merged into fleet
//! [`MemoryLedger`]s at the round barrier — where O(S) send/receive and
//! global budget violations surface exactly as in sequential execution —
//! messages are delivered in deterministic (sender-ordered) order, and the
//! round is recorded on the [`MpcSimulator`].  The broadcast/convergecast
//! trees (§2.1.5) run on top of this for real, so their round counts are
//! measured rather than asserted.
//!
//! [`Router::step_sharded`] is the multi-threaded path: outbox
//! construction (the round's local-compute half) fans out across the
//! simulator's [`ShardPool`], one contiguous machine range per shard, and
//! the per-shard outbox batches are exchanged at the synchronous round
//! boundary.  Inboxes, statistics and violations are bit-identical to
//! [`Router::step`] at every shard count.
//!
//! [`ShardPool`]: crate::mpc::pool::ShardPool

use crate::mpc::memory::{BudgetError, MemoryLedger, ShardLedger, Words};
use crate::mpc::simulator::MpcSimulator;

/// A message between machines: opaque words plus the sender id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    pub from: usize,
    pub payload: Vec<u64>,
}

impl Message {
    pub fn words(&self) -> Words {
        // +1 word of envelope (sender id).
        self.payload.len() as Words + 1
    }
}

/// Stateless router over `machines` mailboxes.
#[derive(Debug)]
pub struct Router {
    machines: usize,
}

impl Router {
    pub fn new(machines: usize) -> Router {
        Router { machines }
    }

    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Execute one synchronous round.
    ///
    /// `outboxes[m]` is the list of `(dst, payload)` machine `m` sends.
    /// Returns `inboxes[m]`: messages delivered to machine `m`, in
    /// deterministic (sender-ordered) order.
    pub fn step(
        &self,
        sim: &mut MpcSimulator,
        label: &str,
        outboxes: Vec<Vec<(usize, Vec<u64>)>>,
    ) -> Vec<Vec<Message>> {
        assert_eq!(outboxes.len(), self.machines, "outbox per machine required");
        let mut send = ShardLedger::new(0..self.machines);
        let mut recv = ShardLedger::new(0..self.machines);
        let mut inboxes: Vec<Vec<Message>> = vec![Vec::new(); self.machines];
        for (from, outbox) in outboxes.into_iter().enumerate() {
            for (dst, payload) in outbox {
                assert!(dst < self.machines, "message to unknown machine {dst}");
                let msg = Message { from, payload };
                send.charge(from, msg.words());
                recv.charge(dst, msg.words());
                inboxes[dst].push(msg);
            }
        }
        self.barrier(sim, label, &[send], recv);
        inboxes
    }

    /// Execute one synchronous round with shard-parallel outbox building.
    ///
    /// `outbox_of(m)` produces machine `m`'s outbox — the round's local
    /// compute — and is invoked on the shard that owns `m`.  Each shard
    /// batches its machines' messages and tallies their send words on a
    /// private [`ShardLedger`]; batches and ledgers are exchanged at the
    /// round boundary, where delivery happens in sender order and budgets
    /// are enforced on the merged fleet ledgers.
    pub fn step_sharded<F>(
        &self,
        sim: &mut MpcSimulator,
        label: &str,
        outbox_of: F,
    ) -> Vec<Vec<Message>>
    where
        F: Fn(usize) -> Vec<(usize, Vec<u64>)> + Sync,
    {
        let pool = sim.pool();
        // Local-compute half, fanned out per machine shard (fine-grained:
        // small fleets build their outboxes inline).
        let shard_out: Vec<(Vec<(usize, Message)>, ShardLedger)> =
            pool.run_fine(self.machines, |_, range| {
                let mut ledger = ShardLedger::new(range.clone());
                let mut msgs: Vec<(usize, Message)> = Vec::new();
                for m in range {
                    for (dst, payload) in outbox_of(m) {
                        let msg = Message { from: m, payload };
                        ledger.charge(m, msg.words());
                        msgs.push((dst, msg));
                    }
                }
                (msgs, ledger)
            });
        // Exchange at the synchronous round boundary: shards are drained
        // in order, so inbox contents match the sequential sender order.
        let mut send_ledgers = Vec::with_capacity(shard_out.len());
        let mut recv = ShardLedger::new(0..self.machines);
        let mut inboxes: Vec<Vec<Message>> = vec![Vec::new(); self.machines];
        for (msgs, ledger) in shard_out {
            for (dst, msg) in msgs {
                assert!(dst < self.machines, "message to unknown machine {dst}");
                recv.charge(dst, msg.words());
                inboxes[dst].push(msg);
            }
            send_ledgers.push(ledger);
        }
        self.barrier(sim, label, &send_ledgers, recv);
        inboxes
    }

    /// The round barrier: merge shard ledgers into fleet ledgers, surface
    /// the first budget violation, record the round's merged statistics.
    fn barrier(
        &self,
        sim: &mut MpcSimulator,
        label: &str,
        send: &[ShardLedger],
        recv: ShardLedger,
    ) {
        // Statistics come from the raw shard tallies (complete even when a
        // budget is blown, so traces are identical in strict and lenient
        // mode and at every shard count).
        let max_out: Words = send.iter().map(ShardLedger::max_local).max().unwrap_or(0);
        let max_in: Words = recv.max_local();
        let total: Words = send.iter().map(ShardLedger::total).sum();
        // Budget enforcement on the merged ledgers. The global budget is
        // charged once, on the send side (receive totals mirror it).
        let s = sim.config.s_words;
        let mut sent_fleet = MemoryLedger::new(self.machines, s, sim.config.global_words);
        let mut recv_fleet = MemoryLedger::new(self.machines, s, Words::MAX);
        let mut violation: Option<BudgetError> = None;
        for shard in send {
            if violation.is_none() {
                violation = sent_fleet.absorb(shard).err();
            }
        }
        if violation.is_none() {
            violation = recv_fleet.absorb(&recv).err();
        }
        sim.round_checked(label, max_out, max_in, total, max_out.max(max_in), violation);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::model::MpcConfig;

    fn sim_for(machines: usize) -> MpcSimulator {
        // Large-ish S so normal tests pass budgets.
        MpcSimulator::new(MpcConfig::model1(10_000, 100_000, 0.6))
        .into_with(machines)
    }

    trait With {
        fn into_with(self, machines: usize) -> MpcSimulator;
    }
    impl With for MpcSimulator {
        fn into_with(mut self, machines: usize) -> MpcSimulator {
            self.config.machines = machines;
            self
        }
    }

    #[test]
    fn delivers_messages() {
        let router = Router::new(3);
        let mut sim = sim_for(3);
        let out = vec![
            vec![(1, vec![42]), (2, vec![7, 8])],
            vec![(0, vec![1])],
            vec![],
        ];
        let inboxes = router.step(&mut sim, "test", out);
        assert_eq!(inboxes[1].len(), 1);
        assert_eq!(inboxes[1][0].payload, vec![42]);
        assert_eq!(inboxes[1][0].from, 0);
        assert_eq!(inboxes[2][0].payload, vec![7, 8]);
        assert_eq!(inboxes[0][0].from, 1);
        assert_eq!(sim.n_rounds(), 1);
    }

    #[test]
    #[should_panic(expected = "model violation")]
    fn oversized_send_violates() {
        let router = Router::new(2);
        let mut sim = sim_for(2);
        let huge = vec![0u64; sim.config.s_words as usize + 10];
        router.step(&mut sim, "big", vec![vec![(1, huge)], vec![]]);
    }

    #[test]
    fn empty_round_counts() {
        let router = Router::new(2);
        let mut sim = sim_for(2);
        let inboxes = router.step(&mut sim, "idle", vec![vec![], vec![]]);
        assert!(inboxes.iter().all(|i| i.is_empty()));
        assert_eq!(sim.n_rounds(), 1);
    }

    #[test]
    fn sharded_step_matches_sequential_step() {
        let machines = 13;
        // All-to-some schedule with payload sizes varying by sender.
        let outbox_of = |m: usize| -> Vec<(usize, Vec<u64>)> {
            (0..machines)
                .filter(|&d| (m + d) % 3 == 0)
                .map(|d| (d, vec![m as u64; 1 + (m % 4)]))
                .collect()
        };
        let router = Router::new(machines);
        let mut seq = sim_for(machines);
        let expected =
            router.step(&mut seq, "x", (0..machines).map(|m| outbox_of(m)).collect());
        for shards in [1usize, 2, 8] {
            let mut sim = MpcSimulator::sharded(MpcConfig::model1(10_000, 100_000, 0.6), shards)
                .into_with(machines);
            let got = router.step_sharded(&mut sim, "x", outbox_of);
            assert_eq!(got, expected, "{shards} shards");
            assert_eq!(sim.trace(), seq.trace(), "{shards} shards");
        }
    }

    #[test]
    fn sharded_step_threads_on_large_fleets() {
        // A fleet above the pool's SERIAL_CUTOFF drives the scoped-thread
        // outbox path and the cross-shard ledger merge for real.
        let machines = 600;
        let outbox_of = |m: usize| -> Vec<(usize, Vec<u64>)> {
            vec![((m * 7 + 1) % machines, vec![m as u64, (m / 3) as u64])]
        };
        let router = Router::new(machines);
        let mut seq = sim_for(machines);
        let expected =
            router.step(&mut seq, "big", (0..machines).map(|m| outbox_of(m)).collect());
        let mut sim = MpcSimulator::sharded(MpcConfig::model1(10_000, 100_000, 0.6), 8)
            .into_with(machines);
        let got = router.step_sharded(&mut sim, "big", outbox_of);
        assert_eq!(got, expected);
        assert_eq!(sim.trace(), seq.trace());
    }

    #[test]
    fn sharded_violation_reports_offending_machine() {
        let machines = 8;
        let cfg = MpcConfig::model1(10_000, 100_000, 0.6);
        let huge = cfg.s_words as usize + 10;
        let mut sim = MpcSimulator::lenient_sharded(cfg, 4).into_with(machines);
        let router = Router::new(machines);
        let inboxes = router.step_sharded(&mut sim, "overflow", |m| {
            if m == 5 { vec![(0, vec![9u64; huge])] } else { Vec::new() }
        });
        assert_eq!(inboxes[0].len(), 1, "messages still delivered for diagnosis");
        assert!(!sim.ok());
        assert_eq!(sim.violations().len(), 1);
        let err = format!("{}", sim.violations()[0]);
        assert!(err.contains("machine 5"), "{err}");
    }
}
