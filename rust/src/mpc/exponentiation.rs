//! Graph exponentiation (paper §2.1.3, Lenzen–Wattenhofer; Figures 1–2).
//!
//! Each vertex starts knowing its 1-hop ball; in round k, vertices
//! exchange their current balls and learn the 2^k-hop ball:
//! `ball_{2r}(v) = ∪_{u ∈ ball_r(v)} ball_r(u)`.  A radius-R ball is thus
//! gathered in ⌈log₂ R⌉ + 1 MPC rounds, memory permitting.
//!
//! The gatherer charges the simulator one round per doubling with the
//! *measured* maximal ball topology size, so the memory feasibility the
//! paper argues (e.g. Δ^R ∈ O(n^δ) in Lemma 21) is checked, not assumed.
//!
//! Each doubling's per-ball unions — the round's local compute — fan out
//! across the simulator's shard pool and are merged at the round barrier,
//! so results and charged rounds are identical at every shard count.

use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::{MpcSimulator, ShardRoundStat};

/// Result of a ball-gathering run.
#[derive(Debug, Clone)]
pub struct Balls {
    /// `balls[i]` = sorted vertex ids within distance `radius` of
    /// `targets[i]`.
    pub balls: Vec<Vec<u32>>,
    /// Radius actually reached (== requested unless capped by memory).
    pub radius: usize,
    /// Rounds charged.
    pub rounds: usize,
    /// True if growth stopped early due to the memory cap.
    pub memory_capped: bool,
}

/// Words needed to store a ball's topology: one word per member plus one
/// per adjacency entry of members (the induced edges a vertex must hold to
/// simulate LOCAL rounds inside its ball).
fn ball_words(g: &Graph, ball: &[u32]) -> Words {
    ball.iter().map(|&u| 1 + g.degree(u) as Words).sum()
}

/// Merge two sorted id lists into `out` (cleared first). Callers ping-pong
/// two scratch buffers across a ball's members, so a doubling allocates
/// O(1) buffers per ball instead of one fresh `Vec` per union — the same
/// flat-buffer discipline as the router's message plane.
fn union_into(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    out.reserve(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => {
                out.push(a[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push(b[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
}

/// Gather balls of radius `target_radius` around `targets` by repeated
/// doubling, charging `sim` one round per doubling.
///
/// `mem_cap` bounds the per-vertex ball topology in words (typically
/// `sim.config.s_words`); growth stops before exceeding it, mirroring
/// "collect the largest possible neighborhood" from §2.1.4 step 1.
pub fn gather_balls(
    g: &Graph,
    targets: &[u32],
    target_radius: usize,
    mem_cap: Words,
    sim: &mut MpcSimulator,
    label: &str,
) -> Balls {
    // Radius 1 balls: v plus its neighbors (known without communication —
    // the input distribution already co-locates a vertex with its edges).
    let mut balls: Vec<Vec<u32>> = targets
        .iter()
        .map(|&v| {
            let mut b = vec![v];
            b.extend_from_slice(g.neighbors(v));
            b.sort_unstable();
            b.dedup();
            b
        })
        .collect();
    let mut radius = 1usize;
    let mut rounds = 0usize;
    let mut memory_capped = false;

    // Ball lookup for union steps: we need balls of *all* vertices that
    // appear inside target balls, not just targets. Maintain a global map
    // lazily (radius-1 balls are cheap to recompute).
    let ball_of = |v: u32| -> Vec<u32> {
        let mut b = vec![v];
        b.extend_from_slice(g.neighbors(v));
        b.sort_unstable();
        b.dedup();
        b
    };

    // For doubling to be exact we must also grow balls of non-target
    // vertices; to keep memory honest we grow *all* vertices' balls when
    // targets don't cover V (the paper's algorithms run with one ball per
    // alive vertex anyway).
    let all_vertices: Vec<u32> = (0..g.n() as u32).collect();
    let growing_all = targets.len() == g.n();
    let mut global_balls: Vec<Vec<u32>> = if growing_all {
        Vec::new() // `balls` already covers everything
    } else {
        all_vertices.iter().map(|&v| ball_of(v)).collect()
    };

    let pool = sim.pool();
    // Per-shard free-lists of retired ball buffers: each doubling's
    // accumulators are drawn from (and the previous generation's Vecs
    // recycled into) these, so successive doublings reuse ball capacity
    // instead of reallocating one Vec per ball per round.
    let mut shard_free: Vec<Vec<Vec<u32>>> = Vec::new();
    let mut shard_doubled: Vec<Result<(Vec<Vec<u32>>, Vec<Vec<u32>>), ()>> = Vec::new();
    while radius < target_radius {
        // Tentatively double, one shard per contiguous slice of target
        // balls (the round's per-machine local compute). A shard aborts as
        // soon as any of its balls would exceed the memory cap — the
        // sequential early-abort, applied shard-locally — and the barrier
        // discards the whole tentative doubling if any shard aborted.
        while shard_free.len() < pool.shard_count(balls.len()) {
            shard_free.push(Vec::new());
        }
        let balls_now = &balls;
        let global_now = &global_balls;
        pool.run_seeded(balls.len(), &mut shard_free, &mut shard_doubled, |_, range, mut free| {
            let mut out: Vec<Vec<u32>> = Vec::with_capacity(range.len());
            let mut scratch: Vec<u32> = free.pop().unwrap_or_default();
            for ball in &balls_now[range] {
                let mut acc: Vec<u32> = free.pop().unwrap_or_default();
                acc.clear();
                for &u in ball {
                    let src: &[u32] = if growing_all {
                        &balls_now[u as usize]
                    } else {
                        &global_now[u as usize]
                    };
                    union_into(&acc, src, &mut scratch);
                    std::mem::swap(&mut acc, &mut scratch);
                    if ball_words(g, &acc) > mem_cap {
                        return Err(());
                    }
                }
                out.push(acc);
            }
            free.push(scratch);
            Ok((out, free))
        });
        if shard_doubled.iter().any(Result::is_err) {
            memory_capped = true;
            break;
        }
        let mut doubled: Vec<Vec<u32>> = Vec::with_capacity(balls.len());
        for shard in shard_doubled.drain(..) {
            let (out, free) = shard.expect("over-cap shards handled above");
            doubled.extend(out);
            shard_free.push(free);
        }
        // Measure the committed footprint per shard; the partials are
        // merged (max/max/sum/max) at the round barrier.
        let partials: Vec<ShardRoundStat> = pool.run_fine(doubled.len(), |_, range| {
            let mut stat = ShardRoundStat::default();
            for b in &doubled[range] {
                let w = ball_words(g, b);
                stat.max_out = stat.max_out.max(w);
                stat.total += w;
            }
            stat.max_in = stat.max_out;
            stat.max_state = stat.max_out;
            stat
        });
        // Commit: charge one exchange round with the measured footprint,
        // and recycle the retired generation's buffers into the
        // free-lists (round-robin keeps the shards' pools balanced).
        rounds += 1;
        sim.round_from_shards(&format!("{label}/double[{rounds}]"), &partials);
        let retired = std::mem::replace(&mut balls, doubled);
        if !shard_free.is_empty() {
            for (i, mut b) in retired.into_iter().enumerate() {
                b.clear();
                shard_free[i % shard_free.len()].push(b);
            }
        }
        if !growing_all {
            global_balls = pool
                .run(global_balls.len(), |_, range| {
                    let mut out: Vec<Vec<u32>> = Vec::with_capacity(range.len());
                    let mut scratch: Vec<u32> = Vec::new();
                    for ball in &global_balls[range] {
                        let mut acc: Vec<u32> = Vec::new();
                        for &u in ball {
                            union_into(&acc, &global_balls[u as usize], &mut scratch);
                            std::mem::swap(&mut acc, &mut scratch);
                        }
                        out.push(acc);
                    }
                    out
                })
                .into_iter()
                .flatten()
                .collect();
        }
        radius *= 2;
        // Converged (ball = component) — further doubling is free.
        if radius >= g.n() {
            break;
        }
    }

    Balls { balls, radius: radius.min(target_radius.max(1)), rounds, memory_capped }
}

/// Exact BFS ball (test oracle, also the sampling probe behind
/// `approx_matching`'s ball-words bound). Frontier-by-frontier BFS with
/// no per-vertex distance array: work and memory are O(|ball|), not
/// O(n), and the membership set is only ever *probed*, never iterated —
/// the sorted output comes from an explicit sort, so no hash iteration
/// order leaks into any deterministic path.
pub fn bfs_ball(g: &Graph, v: u32, radius: usize) -> Vec<u32> {
    // audit:allow(hash-iter): probe-only set — never iterated; the ball is sorted before return
    let mut visited = std::collections::HashSet::new();
    visited.insert(v);
    let mut ball = vec![v];
    let mut frontier = vec![v];
    for _ in 0..radius {
        let mut next = Vec::new();
        for &u in &frontier {
            for &w in g.neighbors(u) {
                if visited.insert(w) {
                    next.push(w);
                    ball.push(w);
                }
            }
        }
        if next.is_empty() {
            break;
        }
        frontier = next;
    }
    ball.sort_unstable();
    ball
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{grid, path, random_tree};
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    fn sim() -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model2(4096, 40_960, 0.99))
    }

    #[test]
    fn doubling_matches_bfs() {
        let mut rng = Rng::new(50);
        let g = random_tree(200, &mut rng);
        let targets: Vec<u32> = (0..200).collect();
        let mut s = sim();
        let res = gather_balls(&g, &targets, 8, u64::MAX, &mut s, "test");
        assert_eq!(res.radius, 8);
        for (i, ball) in res.balls.iter().enumerate() {
            assert_eq!(ball, &bfs_ball(&g, i as u32, 8), "vertex {i}");
        }
    }

    #[test]
    fn log_rounds_for_radius() {
        let g = path(600);
        let targets: Vec<u32> = (0..600).collect();
        let mut s = sim();
        let res = gather_balls(&g, &targets, 16, u64::MAX, &mut s, "test");
        // radius 1 -> 2 -> 4 -> 8 -> 16: 4 doublings.
        assert_eq!(res.rounds, 4);
        assert_eq!(s.n_rounds(), 4);
    }

    #[test]
    fn memory_cap_stops_growth() {
        let g = grid(30, 30);
        let targets: Vec<u32> = (0..900).collect();
        let mut s = sim();
        // Tiny cap: radius-2 balls of the grid need > 26 words.
        let res = gather_balls(&g, &targets, 32, 26, &mut s, "test");
        assert!(res.memory_capped);
        assert_eq!(res.radius, 1);
        assert_eq!(res.rounds, 0);
    }

    #[test]
    fn subset_targets_match_bfs() {
        let mut rng = Rng::new(51);
        let g = random_tree(150, &mut rng);
        let targets = vec![0u32, 5, 17];
        let mut s = sim();
        let res = gather_balls(&g, &targets, 4, u64::MAX, &mut s, "test");
        for (i, &t) in targets.iter().enumerate() {
            assert_eq!(res.balls[i], bfs_ball(&g, t, 4));
        }
    }

    #[test]
    fn ball_words_counts_topology() {
        let g = path(5);
        // Ball {1,2,3}: members 3 + degrees 2+2+2 = 9.
        assert_eq!(ball_words(&g, &[1, 2, 3]), 9);
    }

    #[test]
    fn sharded_gather_matches_serial() {
        let mut rng = Rng::new(52);
        let g = random_tree(400, &mut rng);
        let targets: Vec<u32> = (0..400).collect();
        let run = |shards: usize| {
            let mut s = MpcSimulator::sharded(MpcConfig::model2(4096, 40_960, 0.99), shards);
            let res = gather_balls(&g, &targets, 8, u64::MAX, &mut s, "test");
            let trace: Vec<_> = s
                .trace()
                .iter()
                .map(|r| (r.label.clone(), r.max_out, r.max_in, r.total, r.max_state))
                .collect();
            (res.balls, res.radius, res.rounds, res.memory_capped, trace)
        };
        let serial = run(1);
        for shards in [2usize, 8] {
            assert_eq!(run(shards), serial, "{shards} shards");
        }
    }
}
