//! The machine-sharded worker pool behind the multi-threaded MPC executor.
//!
//! The machine fleet (or any per-machine / per-vertex / per-trial index
//! space) is partitioned into contiguous shards; each shard runs on its own
//! OS thread via `std::thread::scope` (no external dependencies) and
//! produces a partial result; partials are collected **in shard order**, so
//! every reduction a caller performs over them is independent of thread
//! scheduling. This is what makes the sharded executor bit-identical to
//! the sequential one: parallelism lives strictly *inside* a synchronous
//! round, and everything that crosses the round barrier is merged
//! deterministically.
//!
//! A [`ShardPool`] is a value (just a shard count) — cloning it is free and
//! threads are scoped per call, so holding one inside `MpcSimulator` never
//! leaks resources. With one shard, work runs inline on the caller's
//! thread: `ShardPool::serial()` *is* the old sequential executor.

use std::ops::Range;

use crate::util::rng::Rng;

/// Below this many items a [`ShardPool::run`] call executes inline: the
/// per-call thread spawn/join overhead exceeds the sharded work.
pub const SERIAL_CUTOFF: usize = 256;

/// A scoped, deterministic fork-join pool over contiguous index shards.
#[derive(Debug, Clone)]
pub struct ShardPool {
    shards: usize,
}

impl ShardPool {
    /// Pool with a fixed shard count (at least 1).
    pub fn new(shards: usize) -> ShardPool {
        ShardPool { shards: shards.max(1) }
    }

    /// Single-shard pool: runs everything inline (the sequential executor).
    pub fn serial() -> ShardPool {
        ShardPool::new(1)
    }

    /// One shard per available hardware thread.
    pub fn auto() -> ShardPool {
        let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        ShardPool::new(shards)
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Contiguous partition of `0..n` into at most `shards()` ranges, the
    /// first `n % shards` ranges one element longer. Deterministic in `n`.
    pub fn ranges(&self, n: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return Vec::new();
        }
        let shards = self.shards.min(n);
        let base = n / shards;
        let extra = n % shards;
        let mut out = Vec::with_capacity(shards);
        let mut start = 0usize;
        for s in 0..shards {
            let len = base + usize::from(s < extra);
            out.push(start..start + len);
            start += len;
        }
        out
    }

    /// Run `f(shard_index, index_range)` once per shard over `0..n`,
    /// returning the partial results **in shard order**.
    ///
    /// Fans out to one scoped thread per shard whenever the pool has more
    /// than one shard — use this when per-item work dwarfs a thread spawn
    /// (clustering trials, ball unions, scans over large graphs). For
    /// per-machine round bookkeeping on small fleets use [`Self::run_fine`].
    /// A panic in any shard is resumed on the caller, so strict-mode
    /// budget violations behave exactly as in sequential execution.
    pub fn run<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        self.run_ranges(self.ranges(n), f)
    }

    /// Like [`Self::run`], but executes inline when `n ≤` [`SERIAL_CUTOFF`]:
    /// for fine-grained per-item work (outbox building, degree scans on a
    /// small fleet) the scoped-thread spawn/join cost — tens of
    /// microseconds — dwarfs the sharded work. The cutoff changes
    /// scheduling only, never results: partials are merged identically
    /// either way.
    pub fn run_fine<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        let ranges = self.ranges(n);
        if n <= SERIAL_CUTOFF {
            return ranges.into_iter().enumerate().map(|(s, r)| f(s, r)).collect();
        }
        self.run_ranges(ranges, f)
    }

    fn run_ranges<R, F>(&self, ranges: Vec<Range<usize>>, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        if ranges.len() <= 1 {
            return ranges.into_iter().enumerate().map(|(s, r)| f(s, r)).collect();
        }
        let f = &f;
        std::thread::scope(|scope| {
            let handles: Vec<_> = ranges
                .into_iter()
                .enumerate()
                .map(|(s, r)| scope.spawn(move || f(s, r)))
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(out) => out,
                    Err(panic) => std::panic::resume_unwind(panic),
                })
                .collect()
        })
    }

    /// Shard-parallel max-reduce of `f` over `0..n` (0 when `n == 0`).
    /// Convenience for the per-vertex degree/footprint aggregates the
    /// algorithms compute every round; fine-grained, so the serial cutoff
    /// applies.
    pub fn max_by<F>(&self, n: usize, f: F) -> u64
    where
        F: Fn(usize) -> u64 + Sync,
    {
        self.run_fine(n, |_, range| range.map(&f).max().unwrap_or(0))
            .into_iter()
            .max()
            .unwrap_or(0)
    }
}

/// Deterministic per-machine RNG stream: machine `m`'s stream depends only
/// on `(base_seed, m)`, never on which shard or thread hosts the machine,
/// so randomized schedules are reproducible across shard counts.
pub fn machine_rng(base_seed: u64, machine: usize) -> Rng {
    machine_stream(base_seed, machine, 0)
}

/// Tagged variant of [`machine_rng`] for per-round streams: one generator
/// construction keyed on `(base_seed, machine, tag)` — hot loops drawing
/// per machine per round use this instead of `machine_rng(..).fork(tag)`,
/// which would build two generators.
pub fn machine_stream(base_seed: u64, machine: usize, tag: u64) -> Rng {
    let m = (machine as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    Rng::new(base_seed ^ m.rotate_left(17) ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_partition_exactly() {
        for shards in 1..6 {
            let pool = ShardPool::new(shards);
            for n in [0usize, 1, 2, 7, 16, 100] {
                let ranges = pool.ranges(n);
                let mut covered = 0usize;
                let mut expect_start = 0usize;
                for r in &ranges {
                    assert_eq!(r.start, expect_start, "contiguous shards");
                    covered += r.len();
                    expect_start = r.end;
                }
                assert_eq!(covered, n, "shards must cover 0..{n}");
                assert!(ranges.len() <= shards.max(1));
            }
        }
    }

    #[test]
    fn run_results_arrive_in_shard_order() {
        let pool = ShardPool::new(4);
        let out = pool.run(100, |shard, range| (shard, range.start));
        for (i, &(shard, _)) in out.iter().enumerate() {
            assert_eq!(shard, i);
        }
        let starts: Vec<usize> = out.iter().map(|&(_, s)| s).collect();
        let mut sorted = starts.clone();
        sorted.sort_unstable();
        assert_eq!(starts, sorted, "partials must be in index order");
    }

    #[test]
    fn run_fine_matches_run_above_and_below_cutoff() {
        let pool = ShardPool::new(4);
        for n in [SERIAL_CUTOFF / 2, SERIAL_CUTOFF + 100] {
            let a = pool.run(n, |_, range| range.sum::<usize>());
            let b = pool.run_fine(n, |_, range| range.sum::<usize>());
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn run_is_shard_count_invariant() {
        let data: Vec<u64> = (0..997).map(|i| (i * i) % 83).collect();
        let sum = |pool: &ShardPool| -> u64 {
            pool.run(data.len(), |_, range| range.map(|i| data[i]).sum::<u64>())
                .into_iter()
                .sum()
        };
        let expect = sum(&ShardPool::serial());
        for shards in [2usize, 3, 8, 32] {
            assert_eq!(sum(&ShardPool::new(shards)), expect, "{shards} shards");
        }
    }

    #[test]
    fn max_by_matches_sequential() {
        let data: Vec<u64> = (0..357).map(|i| (i * 7919) % 1231).collect();
        let expect = data.iter().copied().max().unwrap();
        for shards in [1usize, 2, 8] {
            let pool = ShardPool::new(shards);
            assert_eq!(pool.max_by(data.len(), |i| data[i]), expect);
        }
        assert_eq!(ShardPool::new(4).max_by(0, |_| 7), 0);
    }

    #[test]
    fn shard_panics_propagate() {
        let pool = ShardPool::new(4);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(16, |_, range| {
                if range.contains(&9) {
                    panic!("shard blew up");
                }
                0u32
            });
        }));
        assert!(caught.is_err());
    }

    #[test]
    fn machine_rng_is_shard_independent() {
        // Stream identity depends on the machine id only.
        let a: Vec<u64> = (0..8).map(|m| machine_rng(42, m).next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|m| machine_rng(42, m).next_u64()).collect();
        assert_eq!(a, b);
        // Distinct machines get decorrelated streams.
        let distinct: std::collections::HashSet<u64> = a.iter().copied().collect();
        assert_eq!(distinct.len(), a.len());
    }
}
