//! The machine-sharded worker pool behind the multi-threaded MPC executor.
//!
//! The machine fleet (or any per-machine / per-vertex / per-trial index
//! space) is partitioned into contiguous shards; each shard runs on its own
//! OS thread via `std::thread::scope` (no external dependencies) and
//! produces a partial result; partials are collected **in shard order**, so
//! every reduction a caller performs over them is independent of thread
//! scheduling. This is what makes the sharded executor bit-identical to
//! the sequential one: parallelism lives strictly *inside* a synchronous
//! round, and everything that crosses the round barrier is merged
//! deterministically.
//!
//! A [`ShardPool`] is a value (just a shard count) — cloning it is free and
//! threads are scoped per call, so holding one inside `MpcSimulator` never
//! leaks resources. With one shard, work runs inline on the caller's
//! thread: `ShardPool::serial()` *is* the old sequential executor.

use std::ops::Range;

use crate::util::rng::Rng;

/// Below this many items a [`ShardPool::run`] call executes inline: the
/// per-call thread spawn/join overhead exceeds the sharded work.
pub const SERIAL_CUTOFF: usize = 256;

/// A scoped, deterministic fork-join pool over contiguous index shards.
#[derive(Debug, Clone)]
pub struct ShardPool {
    shards: usize,
}

impl ShardPool {
    /// Pool with a fixed shard count (at least 1).
    pub fn new(shards: usize) -> ShardPool {
        ShardPool { shards: shards.max(1) }
    }

    /// Single-shard pool: runs everything inline (the sequential executor).
    pub fn serial() -> ShardPool {
        ShardPool::new(1)
    }

    /// One shard per available hardware thread.
    pub fn auto() -> ShardPool {
        let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        ShardPool::new(shards)
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// How many shards a partition of `0..n` actually uses (0 for an
    /// empty index space, never more than `n` or `shards()`).
    pub fn shard_count(&self, n: usize) -> usize {
        if n == 0 {
            0
        } else {
            self.shards.min(n)
        }
    }

    /// Shard `s`'s contiguous range in the partition of `0..n`, computed
    /// arithmetically (no `Vec<Range>` materialization — the per-round
    /// hot paths call this instead of [`Self::ranges`]). The first
    /// `n % shard_count` ranges are one element longer, matching
    /// [`Self::ranges`] exactly.
    pub fn range_of(&self, n: usize, s: usize) -> Range<usize> {
        let shards = self.shard_count(n);
        debug_assert!(s < shards, "shard {s} of {shards}");
        let base = n / shards;
        let extra = n % shards;
        let start = s * base + s.min(extra);
        let len = base + usize::from(s < extra);
        start..start + len
    }

    /// Contiguous partition of `0..n` into at most `shards()` ranges, the
    /// first `n % shards` ranges one element longer. Deterministic in `n`.
    /// Allocates; round-rate callers use [`Self::range_of`] directly.
    pub fn ranges(&self, n: usize) -> Vec<Range<usize>> {
        (0..self.shard_count(n)).map(|s| self.range_of(n, s)).collect()
    }

    /// Run `f(shard_index, index_range)` once per shard over `0..n`,
    /// returning the partial results **in shard order**.
    ///
    /// Fans out to one scoped thread per shard whenever the pool has more
    /// than one shard — use this when per-item work dwarfs a thread spawn
    /// (clustering trials, ball unions, scans over large graphs). For
    /// per-machine round bookkeeping on small fleets use [`Self::run_fine`].
    /// A panic in any shard is resumed on the caller, so strict-mode
    /// budget violations behave exactly as in sequential execution.
    pub fn run<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        let k = self.shard_count(n);
        if k <= 1 {
            return (0..k).map(|s| f(s, self.range_of(n, s))).collect();
        }
        let f = &f;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..k)
                .map(|s| {
                    let r = self.range_of(n, s);
                    scope.spawn(move || f(s, r))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(out) => out,
                    Err(panic) => std::panic::resume_unwind(panic),
                })
                .collect()
        })
    }

    /// Like [`Self::run`], but executes inline when `n ≤` [`SERIAL_CUTOFF`]:
    /// for fine-grained per-item work (outbox building, degree scans on a
    /// small fleet) the scoped-thread spawn/join cost — tens of
    /// microseconds — dwarfs the sharded work. The cutoff changes
    /// scheduling only, never results: partials are merged identically
    /// either way. The serial path computes shard ranges arithmetically —
    /// no `Vec<Range>` per call, so small-fleet rounds stay allocation-free
    /// apart from the result Vec (which [`Self::run_fine_seeded`] also
    /// eliminates).
    pub fn run_fine<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        if n <= SERIAL_CUTOFF {
            let k = self.shard_count(n);
            return (0..k).map(|s| f(s, self.range_of(n, s))).collect();
        }
        self.run(n, f)
    }

    /// Fully pooled variant of [`Self::run`]: shard `s` consumes
    /// `seeds[s]` (scratch state recycled from a previous round — the
    /// first `shard_count(n)` seeds are drained) and partial results are
    /// written into `out` (cleared, then filled in shard order). Neither
    /// the seeds nor the results vector is allocated per call, so a
    /// caller that keeps both across rounds runs the barrier loop
    /// allocation-free. Panics if fewer than `shard_count(n)` seeds are
    /// supplied.
    pub fn run_seeded<T, R, F>(&self, n: usize, seeds: &mut Vec<T>, out: &mut Vec<R>, f: F)
    where
        T: Send,
        R: Send,
        F: Fn(usize, Range<usize>, T) -> R + Sync,
    {
        let k = self.shard_count(n);
        assert!(seeds.len() >= k, "{} seeds for {k} shards", seeds.len());
        out.clear();
        if k <= 1 {
            out.extend(seeds.drain(..k).enumerate().map(|(s, seed)| f(s, self.range_of(n, s), seed)));
            return;
        }
        let f = &f;
        std::thread::scope(|scope| {
            let handles: Vec<_> = seeds
                .drain(..k)
                .enumerate()
                .map(|(s, seed)| {
                    let r = self.range_of(n, s);
                    scope.spawn(move || f(s, r, seed))
                })
                .collect();
            out.extend(handles.into_iter().map(|h| match h.join() {
                Ok(v) => v,
                Err(panic) => std::panic::resume_unwind(panic),
            }));
        })
    }

    /// [`Self::run_seeded`] with the [`SERIAL_CUTOFF`] inline path — the
    /// seeded twin of [`Self::run_fine`], used by the round executor so
    /// steady-state rounds on small fleets neither spawn threads nor
    /// allocate.
    pub fn run_fine_seeded<T, R, F>(&self, n: usize, seeds: &mut Vec<T>, out: &mut Vec<R>, f: F)
    where
        T: Send,
        R: Send,
        F: Fn(usize, Range<usize>, T) -> R + Sync,
    {
        if n <= SERIAL_CUTOFF {
            let k = self.shard_count(n);
            assert!(seeds.len() >= k, "{} seeds for {k} shards", seeds.len());
            out.clear();
            out.extend(seeds.drain(..k).enumerate().map(|(s, seed)| f(s, self.range_of(n, s), seed)));
            return;
        }
        self.run_seeded(n, seeds, out, f)
    }

    /// Shard-parallel max-reduce of `f` over `0..n` (0 when `n == 0`).
    /// Convenience for the per-vertex degree/footprint aggregates the
    /// algorithms compute every round; fine-grained, so the serial cutoff
    /// applies.
    pub fn max_by<F>(&self, n: usize, f: F) -> u64
    where
        F: Fn(usize) -> u64 + Sync,
    {
        self.run_fine(n, |_, range| range.map(&f).max().unwrap_or(0))
            .into_iter()
            .max()
            .unwrap_or(0)
    }
}

/// Deterministic per-machine RNG stream: machine `m`'s stream depends only
/// on `(base_seed, m)`, never on which shard or thread hosts the machine,
/// so randomized schedules are reproducible across shard counts.
pub fn machine_rng(base_seed: u64, machine: usize) -> Rng {
    machine_stream(base_seed, machine, 0)
}

/// Tagged variant of [`machine_rng`] for per-round streams: one generator
/// construction keyed on `(base_seed, machine, tag)` — hot loops drawing
/// per machine per round use this instead of `machine_rng(..).fork(tag)`,
/// which would build two generators.
pub fn machine_stream(base_seed: u64, machine: usize, tag: u64) -> Rng {
    let m = (machine as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    Rng::new(base_seed ^ m.rotate_left(17) ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_partition_exactly() {
        for shards in 1..6 {
            let pool = ShardPool::new(shards);
            for n in [0usize, 1, 2, 7, 16, 100] {
                let ranges = pool.ranges(n);
                let mut covered = 0usize;
                let mut expect_start = 0usize;
                for r in &ranges {
                    assert_eq!(r.start, expect_start, "contiguous shards");
                    covered += r.len();
                    expect_start = r.end;
                }
                assert_eq!(covered, n, "shards must cover 0..{n}");
                assert!(ranges.len() <= shards.max(1));
            }
        }
    }

    #[test]
    fn run_results_arrive_in_shard_order() {
        let pool = ShardPool::new(4);
        let out = pool.run(100, |shard, range| (shard, range.start));
        for (i, &(shard, _)) in out.iter().enumerate() {
            assert_eq!(shard, i);
        }
        let starts: Vec<usize> = out.iter().map(|&(_, s)| s).collect();
        let mut sorted = starts.clone();
        sorted.sort_unstable();
        assert_eq!(starts, sorted, "partials must be in index order");
    }

    #[test]
    fn range_of_matches_ranges() {
        for shards in 1..6 {
            let pool = ShardPool::new(shards);
            for n in [0usize, 1, 2, 7, 16, 100, 257] {
                let expect = pool.ranges(n);
                assert_eq!(pool.shard_count(n), expect.len(), "n={n} shards={shards}");
                for (s, r) in expect.iter().enumerate() {
                    assert_eq!(pool.range_of(n, s), *r, "n={n} shards={shards} s={s}");
                }
            }
        }
    }

    #[test]
    fn seeded_runs_match_run_and_recycle_seeds() {
        for shards in [1usize, 4] {
            let pool = ShardPool::new(shards);
            for n in [5usize, SERIAL_CUTOFF + 100] {
                let expect = pool.run(n, |s, range| (s, range.sum::<usize>()));
                let mut out = Vec::new();
                let mut seeds: Vec<u64> = (0..pool.shards() as u64).collect();
                pool.run_fine_seeded(n, &mut seeds, &mut out, |s, range, seed| {
                    assert_eq!(seed, s as u64, "seeds drained in shard order");
                    (s, range.sum::<usize>())
                });
                assert_eq!(out, expect, "n={n} shards={shards}");
                assert_eq!(seeds.len(), pool.shards() - pool.shard_count(n), "seeds drained");
                let mut seeds: Vec<u64> = (0..pool.shards() as u64).collect();
                pool.run_seeded(n, &mut seeds, &mut out, |s, range, _| (s, range.sum::<usize>()));
                assert_eq!(out, expect, "threaded seeded, n={n} shards={shards}");
            }
        }
    }

    #[test]
    fn run_fine_matches_run_above_and_below_cutoff() {
        let pool = ShardPool::new(4);
        for n in [SERIAL_CUTOFF / 2, SERIAL_CUTOFF + 100] {
            let a = pool.run(n, |_, range| range.sum::<usize>());
            let b = pool.run_fine(n, |_, range| range.sum::<usize>());
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn run_is_shard_count_invariant() {
        let data: Vec<u64> = (0..997).map(|i| (i * i) % 83).collect();
        let sum = |pool: &ShardPool| -> u64 {
            pool.run(data.len(), |_, range| range.map(|i| data[i]).sum::<u64>())
                .into_iter()
                .sum()
        };
        let expect = sum(&ShardPool::serial());
        for shards in [2usize, 3, 8, 32] {
            assert_eq!(sum(&ShardPool::new(shards)), expect, "{shards} shards");
        }
    }

    #[test]
    fn max_by_matches_sequential() {
        let data: Vec<u64> = (0..357).map(|i| (i * 7919) % 1231).collect();
        let expect = data.iter().copied().max().unwrap();
        for shards in [1usize, 2, 8] {
            let pool = ShardPool::new(shards);
            assert_eq!(pool.max_by(data.len(), |i| data[i]), expect);
        }
        assert_eq!(ShardPool::new(4).max_by(0, |_| 7), 0);
    }

    #[test]
    fn shard_panics_propagate() {
        let pool = ShardPool::new(4);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(16, |_, range| {
                if range.contains(&9) {
                    panic!("shard blew up");
                }
                0u32
            });
        }));
        assert!(caught.is_err());
    }

    #[test]
    fn machine_rng_is_shard_independent() {
        // Stream identity depends on the machine id only.
        let a: Vec<u64> = (0..8).map(|m| machine_rng(42, m).next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|m| machine_rng(42, m).next_u64()).collect();
        assert_eq!(a, b);
        // Distinct machines get decorrelated streams.
        let distinct: std::collections::HashSet<u64> = a.iter().copied().collect();
        assert_eq!(distinct.len(), a.len());
    }
}
