//! The flat-arena message plane: slab wire format + typed payload codecs,
//! at two storage widths.
//!
//! The retired wire format allocated one `Vec<u64>` per message
//! (`outboxes: Vec<Vec<(usize, Vec<u64>)>>`), so a round moving millions
//! of words also made millions of tiny heap allocations — allocator churn
//! the perf lab measured instead of the algorithms. This module replaces
//! that plane:
//!
//! * **Send side** — each shard appends every payload it produces into
//!   one contiguous slab ([`WireOutbox`]), recording a
//!   `(from, dst, offset, units, words)` index entry per message.
//!   Building a round's outbox is one growing buffer per shard, not one
//!   allocation per message — and outboxes are pooled by the router's
//!   [`RoundArena`](crate::mpc::arena::RoundArena), so steady-state
//!   rounds reuse the previous round's capacity instead of allocating.
//! * **Barrier** — the router exchanges slabs, not messages: index
//!   entries are walked in shard order (= sender order, matching the
//!   retired plane's delivery order bit for bit) and payload ranges are
//!   copied once into per-destination receiver slabs
//!   ([`RoundInboxes::deliver`]).
//! * **Receive side** — an [`Inbox`] is a zero-copy view over the
//!   receiver slab: every [`WireMsg`] borrows its payload
//!   ([`PayloadView`]) instead of owning a fresh `Vec<u64>`.
//! * **Widths** — the slab stores either `u64` or packed `u32` units
//!   ([`WordWidth`], selected per simulation from `n` and the fleet
//!   size). One *model word* — what the ledger charges — maps to one
//!   unit when it carries a single vertex-sized id, and to two `u32`
//!   units when it carries a wide value or a packed id pair. Ledger
//!   charges are computed from model words and are therefore
//!   **bit-identical at both widths**; only the bytes the barrier
//!   memcpys shrink.
//! * **Codecs** — [`Encode`]/[`Decode`] give the payload shapes the
//!   algorithms actually send (single-word aggregates, packed
//!   [`VertexStatus`]/[`LabelUpdate`] words, small tuples, and the
//!   [`RankAnnounce`]/[`PivotClaim`] frames the constant-round rival
//!   solvers route through [`crate::mpc::router::Router::round`]) a
//!   typed round-trip against a [`SlabWriter`]/[`SlabReader`] pair, so
//!   call sites are width-agnostic.
//!
//! Word accounting is unchanged from the per-message plane: a message of
//! `words` model words still charges `words + `[`ENVELOPE_WORDS`] on
//! both the send and receive ledgers (the sender id travels in the index
//! entry, and the ledger keeps pricing it as one word), so O(S) budget
//! violations fire at exactly the same rounds as before the refactor —
//! at either width.

use std::ops::Range;
use std::sync::{Arc, Mutex};

use crate::mpc::memory::{ShardLedger, Words};

/// Envelope cost of every message in ledger words: the sender id. In the
/// flat format the sender lives in the index entry, but the model still
/// pays for shipping it.
pub const ENVELOPE_WORDS: Words = 1;

// ---------------------------------------------------------------- widths

/// Storage width of a slab: how many bytes one *unit* occupies. The
/// ledger always counts **model words** (width-independent); the width
/// only decides how those words are packed into memory and therefore how
/// many bytes the barrier copies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WordWidth {
    /// One unit per model word, 8 bytes each (the PR 5 format).
    W64,
    /// Id-sized model words take one 4-byte unit; wide values and packed
    /// id pairs take two. Halves barrier copy bytes for id traffic.
    W32,
}

impl WordWidth {
    /// Width for a fleet routing vertex ids in `0..n` across `machines`
    /// machines: packed `u32` units whenever both fit, else `u64`.
    pub fn for_ids(n: usize, machines: usize) -> WordWidth {
        if n <= u32::MAX as usize && machines <= u32::MAX as usize {
            WordWidth::W32
        } else {
            WordWidth::W64
        }
    }

    /// Bytes per storage unit.
    pub fn unit_bytes(self) -> usize {
        match self {
            WordWidth::W64 => 8,
            WordWidth::W32 => 4,
        }
    }
}

/// A payload slab at one of the two storage widths. All slab mutation
/// goes through [`SlabWriter`]; the enum itself only exposes the
/// capacity-preserving maintenance the arena pool needs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SlabBuf {
    W64(Vec<u64>),
    W32(Vec<u32>),
}

impl SlabBuf {
    pub fn new(width: WordWidth) -> SlabBuf {
        match width {
            WordWidth::W64 => SlabBuf::W64(Vec::new()),
            WordWidth::W32 => SlabBuf::W32(Vec::new()),
        }
    }

    pub fn width(&self) -> WordWidth {
        match self {
            SlabBuf::W64(_) => WordWidth::W64,
            SlabBuf::W32(_) => WordWidth::W32,
        }
    }

    /// Length in storage units (not model words).
    pub fn len_units(&self) -> usize {
        match self {
            SlabBuf::W64(v) => v.len(),
            SlabBuf::W32(v) => v.len(),
        }
    }

    /// Clear contents, keeping the high-water-mark capacity (the arena
    /// pool's recycling contract).
    pub fn clear(&mut self) {
        match self {
            SlabBuf::W64(v) => v.clear(),
            SlabBuf::W32(v) => v.clear(),
        }
    }

    pub fn reserve(&mut self, additional_units: usize) {
        match self {
            SlabBuf::W64(v) => v.reserve(additional_units),
            SlabBuf::W32(v) => v.reserve(additional_units),
        }
    }

    /// Borrow a unit range as a typed payload view.
    pub fn view(&self, range: Range<usize>) -> PayloadView<'_> {
        match self {
            SlabBuf::W64(v) => PayloadView::W64(&v[range]),
            SlabBuf::W32(v) => PayloadView::W32(&v[range]),
        }
    }

    /// Append a unit range of `src` — the barrier's single memcpy per
    /// message. Widths must match: the router fixes one width per
    /// simulation, so a mismatch is a wiring bug, not data.
    pub fn copy_range_from(&mut self, src: &SlabBuf, range: Range<usize>) {
        match (self, src) {
            (SlabBuf::W64(dst), SlabBuf::W64(s)) => dst.extend_from_slice(&s[range]),
            (SlabBuf::W32(dst), SlabBuf::W32(s)) => dst.extend_from_slice(&s[range]),
            _ => panic!("slab width mismatch at the barrier"),
        }
    }
}

/// Append-only writer over a [`SlabBuf`]: the codec layer's only way to
/// emit payload data, counting **model words** as it goes so the outbox
/// can assert the [`Encode::words`] contract and charge the ledger
/// width-independently.
#[derive(Debug)]
pub struct SlabWriter<'a> {
    buf: &'a mut SlabBuf,
    words: usize,
}

impl<'a> SlabWriter<'a> {
    pub fn new(buf: &'a mut SlabBuf) -> SlabWriter<'a> {
        SlabWriter { buf, words: 0 }
    }

    /// Model words written through this writer.
    pub fn words(&self) -> usize {
        self.words
    }

    /// One model word carrying a full-width value (aggregates, sums):
    /// one `u64` unit, or two `u32` units (lo then hi).
    pub fn push_wide(&mut self, w: u64) {
        match self.buf {
            SlabBuf::W64(v) => v.push(w),
            // audit:allow(cast-truncate): deliberate split — the lo half is the truncation, the hi half follows
            SlabBuf::W32(v) => {
                v.push(w as u32);
                v.push((w >> 32) as u32)
            }
        }
        self.words += 1;
    }

    /// One model word carrying a single vertex-sized id: one unit at
    /// either width — the case the narrow plane halves.
    pub fn push_id(&mut self, id: u32) {
        match self.buf {
            SlabBuf::W64(v) => v.push(id as u64),
            SlabBuf::W32(v) => v.push(id),
        }
        self.words += 1;
    }

    /// One model word carrying a packed `(hi, lo)` id pair: one
    /// `(hi << 32) | lo` unit, or two `u32` units (hi then lo). Already
    /// bit-dense at W64, so W32 splits it without byte savings — the
    /// model word count (and thus the ledger) is identical either way.
    pub fn push_pair(&mut self, hi: u32, lo: u32) {
        match self.buf {
            SlabBuf::W64(v) => v.push(((hi as u64) << 32) | lo as u64),
            SlabBuf::W32(v) => {
                v.push(hi);
                v.push(lo)
            }
        }
        self.words += 1;
    }
}

/// A borrowed payload at its storage width — what a [`WireMsg`] hands to
/// the codec layer (or, via [`PayloadView::to_words`], to width-agnostic
/// diagnostics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PayloadView<'a> {
    W64(&'a [u64]),
    W32(&'a [u32]),
}

impl PayloadView<'_> {
    /// Length in storage units (not model words).
    pub fn units(&self) -> usize {
        match self {
            PayloadView::W64(v) => v.len(),
            PayloadView::W32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.units() == 0
    }

    /// Raw units widened to `u64` — diagnostics and parity harnesses
    /// only; typed access goes through [`Decode`].
    pub fn to_words(&self) -> Vec<u64> {
        match self {
            PayloadView::W64(v) => v.to_vec(),
            PayloadView::W32(v) => v.iter().map(|&u| u as u64).collect(),
        }
    }
}

/// Cursor over a [`PayloadView`]: the codec layer's only way to read
/// payload data back, mirroring [`SlabWriter`]'s three word shapes. Every
/// read is shape-checked (`None` on underrun or a value that does not fit
/// the requested shape), which is what lets [`Decode`] keep the "wrong
/// shape ⇒ `None`" contract at both widths.
#[derive(Debug, Clone)]
pub struct SlabReader<'a> {
    view: PayloadView<'a>,
    pos: usize,
}

impl<'a> SlabReader<'a> {
    pub fn new(view: PayloadView<'a>) -> SlabReader<'a> {
        SlabReader { view, pos: 0 }
    }

    fn next_u64(&mut self) -> Option<u64> {
        match self.view {
            PayloadView::W64(v) => {
                let w = *v.get(self.pos)?;
                self.pos += 1;
                Some(w)
            }
            PayloadView::W32(_) => None,
        }
    }

    fn next_u32(&mut self) -> Option<u32> {
        match self.view {
            PayloadView::W32(v) => {
                let u = *v.get(self.pos)?;
                self.pos += 1;
                Some(u)
            }
            PayloadView::W64(_) => None,
        }
    }

    /// Read one wide model word (inverse of [`SlabWriter::push_wide`]).
    pub fn read_wide(&mut self) -> Option<u64> {
        match self.view {
            PayloadView::W64(_) => self.next_u64(),
            PayloadView::W32(_) => {
                let lo = self.next_u32()?;
                let hi = self.next_u32()?;
                Some((hi as u64) << 32 | lo as u64)
            }
        }
    }

    /// Read one id-sized model word (inverse of [`SlabWriter::push_id`]).
    /// At W64 the unit must actually fit an id — a wide value where an id
    /// frame is expected is a shape error, exactly like a wrong length.
    pub fn read_id(&mut self) -> Option<u32> {
        match self.view {
            PayloadView::W64(_) => u32::try_from(self.next_u64()?).ok(),
            PayloadView::W32(_) => self.next_u32(),
        }
    }

    /// Read one packed `(hi, lo)` pair (inverse of
    /// [`SlabWriter::push_pair`]).
    pub fn read_pair(&mut self) -> Option<(u32, u32)> {
        match self.view {
            PayloadView::W64(_) => {
                let w = self.next_u64()?;
                // audit:allow(cast-truncate): bit extraction — each half of the packed word is taken on purpose
                Some(((w >> 32) as u32, w as u32))
            }
            PayloadView::W32(_) => {
                let hi = self.next_u32()?;
                let lo = self.next_u32()?;
                Some((hi, lo))
            }
        }
    }

    /// True when the payload is fully consumed — every [`Decode`] impl
    /// checks this so trailing garbage fails the frame.
    pub fn done(&self) -> bool {
        self.pos == self.view.units()
    }
}

// ---------------------------------------------------------------- codecs

/// A payload that can be appended to a slab at either width.
///
/// Contract: `encode_into` writes exactly [`Encode::words`] model words —
/// the outbox asserts it, so codec bugs surface at the send site, not as
/// garbled frames at the receiver.
pub trait Encode {
    /// Payload length in model words (excluding the envelope) — what the
    /// ledger charges, independent of storage width.
    fn words(&self) -> usize;
    /// Append the payload's words through the writer.
    fn encode_into(&self, w: &mut SlabWriter<'_>);
}

/// A payload that can be read back from a borrowed slab range.
pub trait Decode: Sized {
    /// Parse a payload; `None` if the frame has the wrong shape.
    fn decode(r: SlabReader<'_>) -> Option<Self>;
}

impl Encode for u64 {
    fn words(&self) -> usize {
        1
    }

    fn encode_into(&self, w: &mut SlabWriter<'_>) {
        w.push_wide(*self);
    }
}

impl Decode for u64 {
    fn decode(mut r: SlabReader<'_>) -> Option<u64> {
        let w = r.read_wide()?;
        r.done().then_some(w)
    }
}

impl Encode for (u64, u64) {
    fn words(&self) -> usize {
        2
    }

    fn encode_into(&self, w: &mut SlabWriter<'_>) {
        w.push_wide(self.0);
        w.push_wide(self.1);
    }
}

impl Decode for (u64, u64) {
    fn decode(mut r: SlabReader<'_>) -> Option<(u64, u64)> {
        let a = r.read_wide()?;
        let b = r.read_wide()?;
        r.done().then_some((a, b))
    }
}

impl Encode for (u64, u64, u64) {
    fn words(&self) -> usize {
        3
    }

    fn encode_into(&self, w: &mut SlabWriter<'_>) {
        w.push_wide(self.0);
        w.push_wide(self.1);
        w.push_wide(self.2);
    }
}

impl Decode for (u64, u64, u64) {
    fn decode(mut r: SlabReader<'_>) -> Option<(u64, u64, u64)> {
        let a = r.read_wide()?;
        let b = r.read_wide()?;
        let c = r.read_wide()?;
        r.done().then_some((a, b, c))
    }
}

/// Status publication frame: a vertex id and its MIS bit packed into one
/// model word — the shape of what Alg 1/2/3's publish rounds ship per
/// edge. Those rounds currently account their traffic via `sim.round`
/// without routing real payloads; this frame is the wire format they
/// adopt as they move onto the routed plane (today it is exercised by the
/// wire tests and the `mpc/plane_codecs` benchmark).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VertexStatus {
    pub vertex: u32,
    pub in_mis: bool,
}

impl Encode for VertexStatus {
    fn words(&self) -> usize {
        1
    }

    fn encode_into(&self, w: &mut SlabWriter<'_>) {
        w.push_pair(self.vertex, u32::from(self.in_mis));
    }
}

impl Decode for VertexStatus {
    fn decode(mut r: SlabReader<'_>) -> Option<VertexStatus> {
        let (vertex, bit) = r.read_pair()?;
        if bit > 1 || !r.done() {
            return None;
        }
        Some(VertexStatus { vertex, in_mis: bit == 1 })
    }
}

/// Label-propagation frame: `(vertex, label)` packed into one model word
/// — the shape of a connectivity/clustering update. Like
/// [`VertexStatus`], this is the declared wire format for rounds whose
/// traffic is still charged via `sim.round`; its current users are the
/// wire tests and the `mpc/plane_codecs` benchmark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LabelUpdate {
    pub vertex: u32,
    pub label: u32,
}

impl Encode for LabelUpdate {
    fn words(&self) -> usize {
        1
    }

    fn encode_into(&self, w: &mut SlabWriter<'_>) {
        w.push_pair(self.vertex, self.label);
    }
}

impl Decode for LabelUpdate {
    fn decode(mut r: SlabReader<'_>) -> Option<LabelUpdate> {
        let (vertex, label) = r.read_pair()?;
        r.done().then_some(LabelUpdate { vertex, label })
    }
}

/// Rival announce frame: `(vertex, rank)` packed into one model word —
/// what a constant-round pivot phase ([`crate::algorithms::rivals`])
/// ships per directed edge in its announce round: "your neighbor with
/// this rank is eligible this phase". The receiver folds the minimum
/// rank per vertex, which is all the local-minimum pivot rule needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankAnnounce {
    /// Destination vertex (the announcing vertex's neighbor).
    pub vertex: u32,
    /// The announcing vertex's position in the pre-sampled random order.
    pub rank: u32,
}

impl Encode for RankAnnounce {
    fn words(&self) -> usize {
        1
    }

    fn encode_into(&self, w: &mut SlabWriter<'_>) {
        w.push_pair(self.vertex, self.rank);
    }
}

impl Decode for RankAnnounce {
    fn decode(mut r: SlabReader<'_>) -> Option<RankAnnounce> {
        let (vertex, rank) = r.read_pair()?;
        r.done().then_some(RankAnnounce { vertex, rank })
    }
}

/// Rival claim frame: a freshly-elected pivot claiming `vertex` into its
/// cluster. Two model words — `(vertex, pivot)` packed plus the pivot's
/// id-sized rank — because the receiver adopts the **minimum-rank**
/// claimer and, on a real MPC fleet, does not hold remote vertices'
/// ranks locally. The rank word is id-sized, so the W32 plane stores the
/// frame in 3 units (12 bytes) instead of 16.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PivotClaim {
    /// The claimed vertex.
    pub vertex: u32,
    /// The claiming pivot (its id becomes the cluster label).
    pub pivot: u32,
    /// The pivot's rank, shipped so the receiver can break ties locally.
    pub rank: u32,
}

impl Encode for PivotClaim {
    fn words(&self) -> usize {
        2
    }

    fn encode_into(&self, w: &mut SlabWriter<'_>) {
        w.push_pair(self.vertex, self.pivot);
        w.push_id(self.rank);
    }
}

impl Decode for PivotClaim {
    fn decode(mut r: SlabReader<'_>) -> Option<PivotClaim> {
        let (vertex, pivot) = r.read_pair()?;
        let rank = r.read_id()?;
        r.done().then_some(PivotClaim { vertex, pivot, rank })
    }
}

// ------------------------------------------------------------- send side

/// One message's index entry in a sender-side slab. `offset`/`units` are
/// in storage units; `words` is the model-word count the ledger charges.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct WireEntry {
    from: u32,
    dst: u32,
    offset: u32,
    units: u32,
    words: u32,
}

/// A shard's outbox for one round: one contiguous payload slab plus the
/// `(from, dst, offset, units, words)` index, with send words tallied on
/// the shard's private [`ShardLedger`] as messages are appended.
///
/// The router hands one of these (positioned on the current sender via
/// `begin`) to the round's build closure; callers only see the typed
/// [`WireOutbox::send`] / bulk [`WireOutbox::append_run`] / raw
/// [`WireOutbox::send_words`]/[`WireOutbox::send_ids`] API. Outboxes are
/// pooled: [`WireOutbox::reset`] rewinds one for the next round while
/// keeping every buffer's high-water-mark capacity.
#[derive(Debug)]
pub struct WireOutbox {
    machines: usize,
    from: u32,
    slab: SlabBuf,
    entries: Vec<WireEntry>,
    words_total: usize,
    ledger: ShardLedger,
}

impl WireOutbox {
    /// Outbox for the shard owning machines `range` of a `machines`-wide
    /// fleet, at the PR 5 `u64` width.
    pub(crate) fn new(range: Range<usize>, machines: usize) -> WireOutbox {
        WireOutbox::with_width(range, machines, WordWidth::W64)
    }

    /// Width-selecting constructor.
    pub(crate) fn with_width(
        range: Range<usize>,
        machines: usize,
        width: WordWidth,
    ) -> WireOutbox {
        WireOutbox {
            machines,
            from: u32::try_from(range.start).expect("machine index fits u32"),
            slab: SlabBuf::new(width),
            entries: Vec::new(),
            words_total: 0,
            ledger: ShardLedger::new(range),
        }
    }

    /// A pool seed: no machines yet, rewound by [`WireOutbox::reset`]
    /// before first use.
    pub(crate) fn empty(width: WordWidth) -> WireOutbox {
        WireOutbox::with_width(0..0, 0, width)
    }

    /// Rewind for a new round, keeping slab/index capacity (the arena
    /// pool's recycling path — this is `clear()`, not drop).
    pub(crate) fn reset(&mut self, range: Range<usize>, machines: usize, width: WordWidth) {
        self.machines = machines;
        self.from = u32::try_from(range.start).expect("machine index fits u32");
        if self.slab.width() != width {
            self.slab = SlabBuf::new(width);
        }
        self.slab.clear();
        self.entries.clear();
        self.words_total = 0;
        self.ledger.reset(range);
    }

    /// Storage width of this outbox's slab.
    pub fn width(&self) -> WordWidth {
        self.slab.width()
    }

    /// Position the outbox on sender `m` (the router calls this once per
    /// machine, in range order, before invoking the build closure).
    pub(crate) fn begin(&mut self, m: usize) {
        self.from = u32::try_from(m).expect("machine index fits u32");
    }

    /// Send a typed payload to `dst`.
    pub fn send<T: Encode>(&mut self, dst: usize, msg: &T) {
        let offset = self.slab.len_units();
        let mut w = SlabWriter::new(&mut self.slab);
        msg.encode_into(&mut w);
        let words = w.words();
        assert_eq!(words, msg.words(), "Encode wrote {words} words, declared {}", msg.words());
        let units = self.slab.len_units() - offset;
        self.push_entry(dst, offset, units, words);
    }

    /// Send raw wide payload words to `dst` (the untyped escape hatch;
    /// empty payloads are legal and cost the envelope word alone).
    pub fn send_words(&mut self, dst: usize, payload: &[u64]) {
        let offset = self.slab.len_units();
        let mut w = SlabWriter::new(&mut self.slab);
        for &word in payload {
            w.push_wide(word);
        }
        let units = self.slab.len_units() - offset;
        self.push_entry(dst, offset, units, payload.len());
    }

    /// Send a raw run of vertex-sized ids to `dst`: one model word each,
    /// one storage unit each at either width — the bulk path the narrow
    /// plane halves byte-for-byte.
    pub fn send_ids(&mut self, dst: usize, ids: &[u32]) {
        let offset = self.slab.len_units();
        match &mut self.slab {
            SlabBuf::W64(v) => v.extend(ids.iter().map(|&id| id as u64)),
            SlabBuf::W32(v) => v.extend_from_slice(ids),
        }
        let units = self.slab.len_units() - offset;
        self.push_entry(dst, offset, units, ids.len());
    }

    /// Bulk-encode a run of typed messages to one destination: the
    /// destination is validated once, the index reserves once from the
    /// iterator's size hint, and the ledger is charged once for the whole
    /// run instead of per message.
    pub fn append_run<T, I>(&mut self, dst: usize, msgs: I)
    where
        T: Encode,
        I: IntoIterator<Item = T>,
    {
        assert!(dst < self.machines, "message to unknown machine {dst}");
        let dst = u32::try_from(dst).expect("machine index fits u32");
        let iter = msgs.into_iter();
        let (lower, _) = iter.size_hint();
        self.entries.reserve(lower);
        self.slab.reserve(lower);
        let mut run_words: Words = 0;
        for msg in iter {
            run_words += self.encode_frame(dst, &msg);
        }
        if run_words > 0 {
            self.ledger.charge(self.from as usize, run_words);
        }
    }

    /// Bulk-encode `(dst, msg)` pairs, detecting runs of consecutive
    /// equal destinations: the destination check runs once per run, and
    /// the sender's ledger is charged once for the whole call. Delivery
    /// order is identical to an equivalent sequence of
    /// [`WireOutbox::send`] calls — this is strictly a batching of the
    /// bookkeeping around the same frame stream.
    pub fn append_runs<T, I>(&mut self, msgs: I)
    where
        T: Encode,
        I: IntoIterator<Item = (usize, T)>,
    {
        let iter = msgs.into_iter();
        let (lower, _) = iter.size_hint();
        self.entries.reserve(lower);
        self.slab.reserve(lower);
        let mut run_words: Words = 0;
        let mut current: Option<u32> = None;
        for (dst, msg) in iter {
            let dst = match current {
                Some(d) if d as usize == dst => d,
                _ => {
                    assert!(dst < self.machines, "message to unknown machine {dst}");
                    let d = u32::try_from(dst).expect("machine index fits u32");
                    current = Some(d);
                    d
                }
            };
            run_words += self.encode_frame(dst, &msg);
        }
        if run_words > 0 {
            self.ledger.charge(self.from as usize, run_words);
        }
    }

    /// Encode one frame with a pre-validated destination, returning its
    /// ledger cost (payload + envelope) for the caller to batch-charge.
    fn encode_frame<T: Encode>(&mut self, dst: u32, msg: &T) -> Words {
        let offset = self.slab.len_units();
        let mut w = SlabWriter::new(&mut self.slab);
        msg.encode_into(&mut w);
        let words = w.words();
        debug_assert_eq!(
            words,
            msg.words(),
            "Encode wrote {words} words, declared {}",
            msg.words()
        );
        let units = self.slab.len_units() - offset;
        let offset = u32::try_from(offset).expect("round slab exceeds u32 offsets");
        let units = u32::try_from(units).expect("payload exceeds u32 length");
        let words32 = u32::try_from(words).expect("payload exceeds u32 length");
        self.entries.push(WireEntry { from: self.from, dst, offset, units, words: words32 });
        self.words_total += words;
        words as Words + ENVELOPE_WORDS
    }

    /// Messages appended so far (across all senders of the shard).
    pub fn messages(&self) -> usize {
        self.entries.len()
    }

    /// Payload model words appended so far.
    pub fn slab_words(&self) -> usize {
        self.words_total
    }

    /// Payload storage units appended so far (`== slab_words()` at W64;
    /// smaller than `2 · slab_words()` at W32 whenever id-sized traffic
    /// is present).
    pub fn slab_units(&self) -> usize {
        self.slab.len_units()
    }

    fn push_entry(&mut self, dst: usize, offset: usize, units: usize, words: usize) {
        assert!(dst < self.machines, "message to unknown machine {dst}");
        let offset = u32::try_from(offset).expect("round slab exceeds u32 offsets");
        let units = u32::try_from(units).expect("payload exceeds u32 length");
        let words32 = u32::try_from(words).expect("payload exceeds u32 length");
        let dst = u32::try_from(dst).expect("machine index fits u32");
        self.entries.push(WireEntry { from: self.from, dst, offset, units, words: words32 });
        self.words_total += words;
        self.ledger.charge(self.from as usize, words as Words + ENVELOPE_WORDS);
    }

    /// The shard's send ledger (the barrier absorbs it).
    pub(crate) fn ledger(&self) -> &ShardLedger {
        &self.ledger
    }
}

// ---------------------------------------------------------- receive side

/// One delivered message's index entry in a receiver-side slab.
/// `offset`/`units` are in storage units; `words` is the model-word
/// count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct InboxEntry {
    from: u32,
    offset: u32,
    units: u32,
    words: u32,
}

/// Cleared inbox bodies awaiting reuse, shared between the router's
/// arena and every [`RoundInboxes`] it has handed out: when a caller
/// drops a round's inboxes, the slabs and index Vecs return here
/// (capacity intact) instead of freeing, and the next barrier pops them.
/// Bounded to a couple of sets so callers that hoard inboxes cannot grow
/// the pool.
#[derive(Debug, Default)]
pub(crate) struct ReclaimBin {
    sets: Vec<(Vec<SlabBuf>, Vec<Vec<InboxEntry>>)>,
}

impl ReclaimBin {
    /// True when no cleared inbox bodies are pooled (all are on loan or
    /// none were ever returned).
    pub(crate) fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }
}

/// Most callers hold at most the current round's inboxes while the next
/// round builds, so two pooled sets give steady-state reuse.
const RECLAIM_SETS: usize = 2;

pub(crate) type InboxReclaim = Arc<Mutex<ReclaimBin>>;

/// Reusable sizing scratch for [`RoundInboxes::deliver`] (per-destination
/// unit and message counts), pooled by the router's arena so the barrier
/// does not allocate them per round.
#[derive(Debug, Default)]
pub struct DeliverScratch {
    units: Vec<usize>,
    counts: Vec<usize>,
}

/// Receiver-side arena for one round: one contiguous slab per destination
/// machine plus per-destination entry lists. Built once at the round
/// barrier; all access is zero-copy via [`RoundInboxes::inbox`]. When
/// built through a pooling router, dropping it returns the buffers to the
/// router's arena instead of freeing them.
#[derive(Debug)]
pub struct RoundInboxes {
    slabs: Vec<SlabBuf>,
    entries: Vec<Vec<InboxEntry>>,
    reclaim: Option<InboxReclaim>,
}

impl PartialEq for RoundInboxes {
    fn eq(&self, other: &RoundInboxes) -> bool {
        // Delivered data only — the reclaim back-channel is plumbing.
        self.slabs == other.slabs && self.entries == other.entries
    }
}

impl Eq for RoundInboxes {}

impl Clone for RoundInboxes {
    fn clone(&self) -> RoundInboxes {
        // A clone is a caller-owned copy: it does not share the pool
        // back-channel (returning the same buffers twice would alias).
        RoundInboxes { slabs: self.slabs.clone(), entries: self.entries.clone(), reclaim: None }
    }
}

impl Drop for RoundInboxes {
    fn drop(&mut self) {
        let Some(reclaim) = self.reclaim.take() else { return };
        let mut slabs = std::mem::take(&mut self.slabs);
        let mut entries = std::mem::take(&mut self.entries);
        for s in &mut slabs {
            s.clear();
        }
        for e in &mut entries {
            e.clear();
        }
        let mut bin = reclaim.lock().unwrap_or_else(|p| p.into_inner());
        if bin.sets.len() < RECLAIM_SETS {
            bin.sets.push((slabs, entries));
        }
    }
}

impl RoundInboxes {
    /// The barrier's exchange half: walk the shard outboxes in shard
    /// order (= sender order), copy each payload range once into its
    /// destination slab, and charge receive words on `recv`. `scratch`
    /// provides the reusable sizing buffers; `reclaim`, when given, is
    /// the pool the returned value's buffers are drawn from and returned
    /// to on drop.
    pub(crate) fn deliver(
        machines: usize,
        width: WordWidth,
        shards: &[WireOutbox],
        recv: &mut ShardLedger,
        scratch: &mut DeliverScratch,
        reclaim: Option<&InboxReclaim>,
    ) -> RoundInboxes {
        // Sizing pass so the receiver slabs allocate (or grow) at most
        // once each.
        scratch.units.clear();
        scratch.units.resize(machines, 0);
        scratch.counts.clear();
        scratch.counts.resize(machines, 0);
        for ob in shards {
            for e in &ob.entries {
                scratch.units[e.dst as usize] += e.units as usize;
                scratch.counts[e.dst as usize] += 1;
            }
        }
        let (mut slabs, mut entries) = reclaim
            .and_then(|r| r.lock().unwrap_or_else(|p| p.into_inner()).sets.pop())
            .unwrap_or_default();
        // Normalize the recycled (or fresh) bodies to this fleet/width.
        slabs.truncate(machines);
        for s in &mut slabs {
            if s.width() != width {
                *s = SlabBuf::new(width);
            }
            debug_assert_eq!(s.len_units(), 0, "reclaimed slab not cleared");
        }
        while slabs.len() < machines {
            slabs.push(SlabBuf::new(width));
        }
        entries.truncate(machines);
        entries.resize_with(machines, Vec::new);
        for d in 0..machines {
            slabs[d].reserve(scratch.units[d]);
            entries[d].reserve(scratch.counts[d]);
        }
        for ob in shards {
            for e in &ob.entries {
                let d = e.dst as usize;
                let offset =
                    u32::try_from(slabs[d].len_units()).expect("receiver slab exceeds u32 offsets");
                slabs[d].copy_range_from(
                    &ob.slab,
                    e.offset as usize..e.offset as usize + e.units as usize,
                );
                entries[d].push(InboxEntry { from: e.from, offset, units: e.units, words: e.words });
                recv.charge(d, e.words as Words + ENVELOPE_WORDS);
            }
        }
        RoundInboxes { slabs, entries, reclaim: reclaim.cloned() }
    }

    pub fn machines(&self) -> usize {
        self.entries.len()
    }

    /// Zero-copy view of machine `m`'s inbox.
    pub fn inbox(&self, m: usize) -> Inbox<'_> {
        Inbox { slab: &self.slabs[m], entries: &self.entries[m] }
    }

    /// Messages delivered this round, across all machines.
    pub fn total_messages(&self) -> usize {
        self.entries.iter().map(Vec::len).sum()
    }

    /// Payload model words delivered this round, across all machines.
    pub fn total_words(&self) -> usize {
        self.entries.iter().flatten().map(|e| e.words as usize).sum()
    }
}

/// One machine's inbox: borrowed slices over the receiver slab, in the
/// deterministic sender order the barrier delivered.
#[derive(Debug, Clone, Copy)]
pub struct Inbox<'a> {
    slab: &'a SlabBuf,
    entries: &'a [InboxEntry],
}

impl<'a> Inbox<'a> {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, i: usize) -> WireMsg<'a> {
        let e = self.entries[i];
        WireMsg {
            from: e.from as usize,
            payload: self.slab.view(e.offset as usize..e.offset as usize + e.units as usize),
            words: e.words,
        }
    }

    pub fn first(&self) -> Option<WireMsg<'a>> {
        if self.is_empty() {
            None
        } else {
            Some(self.get(0))
        }
    }

    pub fn iter(self) -> InboxIter<'a> {
        InboxIter { slab: self.slab, entries: self.entries.iter() }
    }
}

impl<'a> IntoIterator for Inbox<'a> {
    type Item = WireMsg<'a>;
    type IntoIter = InboxIter<'a>;

    fn into_iter(self) -> InboxIter<'a> {
        self.iter()
    }
}

/// Iterator over an [`Inbox`] in delivery order.
#[derive(Debug, Clone)]
pub struct InboxIter<'a> {
    slab: &'a SlabBuf,
    entries: std::slice::Iter<'a, InboxEntry>,
}

impl<'a> Iterator for InboxIter<'a> {
    type Item = WireMsg<'a>;

    fn next(&mut self) -> Option<WireMsg<'a>> {
        let e = self.entries.next()?;
        Some(WireMsg {
            from: e.from as usize,
            payload: self.slab.view(e.offset as usize..e.offset as usize + e.units as usize),
            words: e.words,
        })
    }
}

/// A delivered message: sender id plus a borrowed payload view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireMsg<'a> {
    pub from: usize,
    pub payload: PayloadView<'a>,
    words: u32,
}

impl WireMsg<'_> {
    /// Ledger words of this message (payload + envelope), matching the
    /// retired per-message accounting exactly — at either storage width.
    pub fn words(&self) -> Words {
        self.words as Words + ENVELOPE_WORDS
    }

    /// Payload length in model words (excluding the envelope).
    pub fn payload_words(&self) -> usize {
        self.words as usize
    }

    /// Raw payload units widened to `u64` (diagnostics / parity tests).
    pub fn to_words(&self) -> Vec<u64> {
        self.payload.to_words()
    }

    /// Decode the payload, panicking on a malformed frame (senders and
    /// receivers share the codec, so a mismatch is a bug, not data).
    pub fn decode<T: Decode>(&self) -> T {
        self.try_decode().unwrap_or_else(|| {
            panic!(
                "payload of {} units does not decode as {}",
                self.payload.units(),
                std::any::type_name::<T>()
            )
        })
    }

    pub fn try_decode<T: Decode>(&self) -> Option<T> {
        T::decode(SlabReader::new(self.payload))
    }
}

// ------------------------------------------------------- legacy oracle

/// The retired per-message wire format, reproduced as a single
/// executable oracle: one heap-allocated `Vec<u64>` per message on both
/// sides, sender-ordered delivery, the same `+1` envelope word on the
/// ledgers, and the router barrier's exact check ordering (send shards
/// absorbed before the receive ledger).
///
/// This is deliberately the **only** place the old format survives —
/// the router's old-vs-new parity test and the `mpc/plane_vs_permsg`
/// benchmark baseline both call it, so they can never drift apart. It
/// is not a Router path; production code sends through [`WireOutbox`].
pub fn per_message_round(
    machines: usize,
    sim: &mut crate::mpc::simulator::MpcSimulator,
    label: &str,
    outboxes: Vec<Vec<(usize, Vec<u64>)>>,
) -> Vec<Vec<(usize, Vec<u64>)>> {
    use crate::mpc::memory::MemoryLedger;
    let mut send = ShardLedger::new(0..machines);
    let mut recv = ShardLedger::new(0..machines);
    let mut inboxes: Vec<Vec<(usize, Vec<u64>)>> = vec![Vec::new(); machines];
    for (from, outbox) in outboxes.into_iter().enumerate() {
        for (dst, payload) in outbox {
            let words = payload.len() as Words + ENVELOPE_WORDS;
            send.charge(from, words);
            recv.charge(dst, words);
            inboxes[dst].push((from, payload));
        }
    }
    let max_out = send.max_local();
    let max_in = recv.max_local();
    let total = send.total();
    let s = sim.config.s_words;
    let mut sent_fleet = MemoryLedger::new(machines, s, sim.config.global_words);
    let mut recv_fleet = MemoryLedger::new(machines, s, Words::MAX);
    let mut violation = sent_fleet.absorb(&send).err();
    if violation.is_none() {
        violation = recv_fleet.absorb(&recv).err();
    }
    sim.round_checked(label, max_out, max_in, total, max_out.max(max_in), violation);
    inboxes
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const BOTH_WIDTHS: [WordWidth; 2] = [WordWidth::W64, WordWidth::W32];

    /// Decode a W64 payload straight from wide words (test shorthand).
    fn decode_w64<T: Decode>(payload: &[u64]) -> Option<T> {
        T::decode(SlabReader::new(PayloadView::W64(payload)))
    }

    fn roundtrip_at<T: Encode + Decode + PartialEq + Copy + std::fmt::Debug>(
        width: WordWidth,
        v: T,
    ) {
        let mut buf = SlabBuf::new(width);
        let mut w = SlabWriter::new(&mut buf);
        v.encode_into(&mut w);
        assert_eq!(w.words(), v.words(), "declared vs written words ({width:?})");
        let units = buf.len_units();
        let got = T::decode(SlabReader::new(buf.view(0..units)));
        assert_eq!(got, Some(v), "encode∘decode must be id ({width:?})");
    }

    fn roundtrip<T: Encode + Decode + PartialEq + Copy + std::fmt::Debug>(v: T) {
        for width in BOTH_WIDTHS {
            roundtrip_at(width, v);
        }
    }

    #[test]
    fn codec_roundtrips() {
        roundtrip(0u64);
        roundtrip(u64::MAX);
        roundtrip((3u64, 9u64));
        roundtrip((1u64, u64::MAX, 7u64));
        roundtrip(VertexStatus { vertex: 0, in_mis: false });
        roundtrip(VertexStatus { vertex: u32::MAX, in_mis: true });
        roundtrip(LabelUpdate { vertex: 17, label: 0 });
        roundtrip(LabelUpdate { vertex: u32::MAX, label: u32::MAX });
        roundtrip(RankAnnounce { vertex: 0, rank: 0 });
        roundtrip(RankAnnounce { vertex: u32::MAX, rank: u32::MAX });
        roundtrip(PivotClaim { vertex: 3, pivot: 9, rank: 1 });
        roundtrip(PivotClaim { vertex: u32::MAX, pivot: u32::MAX, rank: u32::MAX });
    }

    #[test]
    fn codec_rejects_wrong_shapes() {
        assert_eq!(decode_w64::<u64>(&[]), None);
        assert_eq!(decode_w64::<u64>(&[1, 2]), None);
        assert_eq!(decode_w64::<(u64, u64)>(&[1]), None);
        assert_eq!(decode_w64::<(u64, u64, u64)>(&[1, 2]), None);
        assert_eq!(decode_w64::<VertexStatus>(&[u64::MAX]), None, "MIS bit must be 0/1");
        assert_eq!(decode_w64::<LabelUpdate>(&[1, 2]), None);
        assert_eq!(decode_w64::<RankAnnounce>(&[1, 2]), None);
        assert_eq!(decode_w64::<PivotClaim>(&[1]), None);
        assert_eq!(decode_w64::<PivotClaim>(&[1, u64::MAX]), None, "rank must be id-sized");
    }

    #[test]
    fn w64_layouts_match_packed_words() {
        // The W64 slab layout is the PR 5 wire format: packed pairs are
        // `(hi << 32) | lo`, wide values verbatim, ids widened — pinned
        // here so width plumbing can never silently reshuffle bits.
        let mut buf = SlabBuf::new(WordWidth::W64);
        let mut w = SlabWriter::new(&mut buf);
        LabelUpdate { vertex: 5, label: 9 }.encode_into(&mut w);
        RankAnnounce { vertex: 2, rank: 3 }.encode_into(&mut w);
        PivotClaim { vertex: 7, pivot: 1, rank: 4 }.encode_into(&mut w);
        11u64.encode_into(&mut w);
        assert_eq!(
            buf,
            SlabBuf::W64(vec![(5 << 32) | 9, (2 << 32) | 3, (7 << 32) | 1, 4, 11])
        );
    }

    #[test]
    fn w32_unit_counts_shrink_id_frames() {
        // Model words are width-invariant; storage units are not. An
        // id-sized word is 1 unit at both widths (8 → 4 bytes), a wide
        // or packed word is 1 vs 2 units (8 → 8 bytes).
        let count = |width: WordWidth| {
            let mut buf = SlabBuf::new(width);
            let mut w = SlabWriter::new(&mut buf);
            PivotClaim { vertex: 1, pivot: 2, rank: 3 }.encode_into(&mut w);
            (w.words(), buf.len_units(), buf.len_units() * width.unit_bytes())
        };
        assert_eq!(count(WordWidth::W64), (2, 2, 16));
        assert_eq!(count(WordWidth::W32), (2, 3, 12));
    }

    #[test]
    fn width_selection_follows_id_range() {
        assert_eq!(WordWidth::for_ids(1_000_000, 512), WordWidth::W32);
        assert_eq!(WordWidth::for_ids(u32::MAX as usize, 1), WordWidth::W32);
        assert_eq!(WordWidth::for_ids(u32::MAX as usize + 1, 1), WordWidth::W64);
    }

    #[test]
    fn word_counts_match_ledger_accounting() {
        // Every codec's words() + the envelope equals what the retired
        // per-message plane charged for the same payload — at both
        // storage widths (the ledger never sees units).
        let v = VertexStatus { vertex: 4, in_mis: true };
        for width in BOTH_WIDTHS {
            let mut buf = SlabBuf::new(width);
            let mut w = SlabWriter::new(&mut buf);
            v.encode_into(&mut w);
            let legacy_words = 1 as Words + 1; // one packed word + sender word
            assert_eq!(w.words() as Words + ENVELOPE_WORDS, legacy_words, "{width:?}");
        }
    }

    #[test]
    fn outbox_builds_one_slab_with_index() {
        let mut out = WireOutbox::new(0..2, 4);
        out.begin(0);
        out.send(1, &7u64);
        out.send_words(3, &[1, 2, 3]);
        out.begin(1);
        out.send_words(2, &[]);
        assert_eq!(out.messages(), 3);
        assert_eq!(out.slab_words(), 4);
        assert_eq!(out.slab, SlabBuf::W64(vec![7, 1, 2, 3]));
        assert_eq!(
            out.entries,
            vec![
                WireEntry { from: 0, dst: 1, offset: 0, units: 1, words: 1 },
                WireEntry { from: 0, dst: 3, offset: 1, units: 3, words: 3 },
                WireEntry { from: 1, dst: 2, offset: 4, units: 0, words: 0 },
            ]
        );
        // Ledger: machine 0 sent (1+1) + (3+1) = 6, machine 1 sent 0+1.
        assert_eq!(out.ledger().used(0), 6);
        assert_eq!(out.ledger().used(1), 1);
    }

    #[test]
    fn outbox_reset_recycles_capacity() {
        let mut out = WireOutbox::new(0..2, 4);
        out.begin(0);
        out.send_words(1, &[1, 2, 3, 4, 5]);
        out.reset(2..4, 4, WordWidth::W64);
        assert_eq!(out.messages(), 0);
        assert_eq!(out.slab_words(), 0);
        assert_eq!(out.ledger().base(), 2);
        assert_eq!(out.ledger().total(), 0);
        out.begin(2);
        out.send(0, &9u64);
        assert_eq!(out.ledger().used(2), 2);
    }

    #[test]
    fn append_run_matches_per_message_sends() {
        for width in BOTH_WIDTHS {
            let mut bulk = WireOutbox::with_width(0..1, 4, width);
            bulk.begin(0);
            bulk.append_run(2, (0..5u32).map(|i| RankAnnounce { vertex: i, rank: i * 3 }));
            let mut single = WireOutbox::with_width(0..1, 4, width);
            single.begin(0);
            for i in 0..5u32 {
                single.send(2, &RankAnnounce { vertex: i, rank: i * 3 });
            }
            assert_eq!(bulk.slab, single.slab, "{width:?}: identical frame stream");
            assert_eq!(bulk.entries, single.entries, "{width:?}");
            assert_eq!(bulk.ledger().used(0), single.ledger().used(0), "{width:?}");
        }
    }

    #[test]
    fn append_runs_batches_mixed_destinations() {
        for width in BOTH_WIDTHS {
            let schedule: Vec<(usize, PivotClaim)> = [0, 0, 2, 2, 2, 1, 0]
                .iter()
                .enumerate()
                .map(|(i, &d)| (d, PivotClaim { vertex: i as u32, pivot: 1, rank: 2 }))
                .collect();
            let mut bulk = WireOutbox::with_width(0..1, 3, width);
            bulk.begin(0);
            bulk.append_runs(schedule.iter().copied());
            let mut single = WireOutbox::with_width(0..1, 3, width);
            single.begin(0);
            for &(d, msg) in &schedule {
                single.send(d, &msg);
            }
            assert_eq!(bulk.slab, single.slab, "{width:?}");
            assert_eq!(bulk.entries, single.entries, "{width:?}");
            assert_eq!(bulk.ledger().used(0), single.ledger().used(0), "{width:?}");
        }
    }

    #[test]
    #[should_panic(expected = "unknown machine")]
    fn outbox_rejects_unknown_destination() {
        let mut out = WireOutbox::new(0..1, 2);
        out.begin(0);
        out.send(5, &1u64);
    }

    #[test]
    #[should_panic(expected = "unknown machine")]
    fn append_run_rejects_unknown_destination() {
        let mut out = WireOutbox::new(0..1, 2);
        out.begin(0);
        out.append_run(7, std::iter::once(1u64));
    }

    #[test]
    fn deliver_copies_in_sender_order_and_charges_receive() {
        // Two shards; delivery must interleave by shard order then
        // sender order, exactly like the retired plane — at both widths
        // with identical ledger charges.
        for width in BOTH_WIDTHS {
            let mut a = WireOutbox::with_width(0..2, 3, width);
            a.begin(0);
            a.send(2, &10u64);
            a.begin(1);
            a.send_words(2, &[20, 21]);
            let mut b = WireOutbox::with_width(2..3, 3, width);
            b.begin(2);
            b.send(2, &30u64);
            b.send(0, &(1u64, 2u64));
            let mut recv = ShardLedger::new(0..3);
            let mut scratch = DeliverScratch::default();
            let inboxes =
                RoundInboxes::deliver(3, width, &[a, b], &mut recv, &mut scratch, None);
            let froms: Vec<usize> = inboxes.inbox(2).iter().map(|m| m.from).collect();
            assert_eq!(froms, [0, 1, 2], "{width:?}: shard order then sender order");
            assert_eq!(inboxes.inbox(2).get(0).decode::<u64>(), 10, "{width:?}");
            assert_eq!(inboxes.inbox(2).get(1).decode::<(u64, u64)>(), (20, 21), "{width:?}");
            assert_eq!(inboxes.inbox(2).get(2).decode::<u64>(), 30, "{width:?}");
            assert_eq!(
                inboxes.inbox(0).first().map(|m| m.decode::<(u64, u64)>()),
                Some((1, 2)),
                "{width:?}"
            );
            assert!(inboxes.inbox(1).is_empty(), "{width:?}");
            // Receive ledger: machine 2 got 2 + 3 + 2 = 7 words, machine 0 got 3
            // — model words, identical at both widths.
            assert_eq!(recv.used(2), 7, "{width:?}");
            assert_eq!(recv.used(0), 3, "{width:?}");
            assert_eq!(inboxes.total_messages(), 4, "{width:?}");
            assert_eq!(inboxes.total_words(), 6, "{width:?}");
        }
    }

    #[test]
    fn deliver_recycles_through_the_reclaim_bin() {
        let reclaim: InboxReclaim = Arc::default();
        let mut scratch = DeliverScratch::default();
        let run = || {
            let mut out = WireOutbox::with_width(0..2, 2, WordWidth::W32);
            out.begin(0);
            out.send_ids(1, &[1, 2, 3]);
            out
        };
        let mut recv = ShardLedger::new(0..2);
        let first = RoundInboxes::deliver(
            2,
            WordWidth::W32,
            &[run()],
            &mut recv,
            &mut scratch,
            Some(&reclaim),
        );
        assert_eq!(first.inbox(1).get(0).to_words(), vec![1, 2, 3]);
        assert!(reclaim.lock().unwrap().sets.is_empty(), "buffers are out on loan");
        drop(first);
        assert_eq!(reclaim.lock().unwrap().sets.len(), 1, "drop returns the buffers");
        let mut recv = ShardLedger::new(0..2);
        let second = RoundInboxes::deliver(
            2,
            WordWidth::W32,
            &[run()],
            &mut recv,
            &mut scratch,
            Some(&reclaim),
        );
        assert!(reclaim.lock().unwrap().sets.is_empty(), "second round reuses the set");
        assert_eq!(second.inbox(1).get(0).to_words(), vec![1, 2, 3]);
        assert_eq!(recv.used(1), 4);
    }

    #[test]
    fn send_ids_halves_w32_bytes_but_not_ledger_words() {
        let bytes = |width: WordWidth| {
            let mut out = WireOutbox::with_width(0..1, 2, width);
            out.begin(0);
            out.send_ids(1, &[10, 20, 30, 40]);
            (out.slab_units() * width.unit_bytes(), out.ledger().used(0))
        };
        let (b64, w64) = bytes(WordWidth::W64);
        let (b32, w32) = bytes(WordWidth::W32);
        assert_eq!(b64, 32);
        assert_eq!(b32, 16, "id runs halve on the narrow plane");
        assert_eq!(w64, w32, "ledger charges are width-invariant");
        assert_eq!(w64, 5);
    }
}
