//! The flat-arena message plane: slab wire format + typed payload codecs.
//!
//! The retired wire format allocated one `Vec<u64>` per message
//! (`outboxes: Vec<Vec<(usize, Vec<u64>)>>`), so a round moving millions
//! of words also made millions of tiny heap allocations — allocator churn
//! the perf lab measured instead of the algorithms. This module replaces
//! that plane:
//!
//! * **Send side** — each shard appends every payload it produces into
//!   one contiguous `Vec<u64>` slab ([`WireOutbox`]), recording a
//!   `(from, dst, offset, len)` index entry per message. Building a
//!   round's outbox is one growing buffer per shard, not one allocation
//!   per message.
//! * **Barrier** — the router exchanges slabs, not messages: index
//!   entries are walked in shard order (= sender order, matching the
//!   retired plane's delivery order bit for bit) and payload ranges are
//!   copied once into per-destination receiver slabs
//!   ([`RoundInboxes::deliver`]).
//! * **Receive side** — an [`Inbox`] is a zero-copy view over the
//!   receiver slab: every [`WireMsg`] borrows its payload words instead
//!   of owning a fresh `Vec<u64>`.
//! * **Codecs** — [`Encode`]/[`Decode`] give the payload shapes the
//!   algorithms actually send (single-word aggregates, packed
//!   [`VertexStatus`]/[`LabelUpdate`] words, small tuples, and the
//!   [`RankAnnounce`]/[`PivotClaim`] frames the constant-round rival
//!   solvers route through [`crate::mpc::router::Router::round`]) a
//!   typed round-trip, replacing ad-hoc `payload[0]` indexing at call
//!   sites.
//!
//! Word accounting is unchanged from the per-message plane: a message of
//! `len` payload words still charges `len + `[`ENVELOPE_WORDS`] on both
//! the send and receive ledgers (the sender id travels in the index
//! entry, and the ledger keeps pricing it as one word), so O(S) budget
//! violations fire at exactly the same rounds as before the refactor.

use crate::mpc::memory::{ShardLedger, Words};

/// Envelope cost of every message in ledger words: the sender id. In the
/// flat format the sender lives in the index entry, but the model still
/// pays for shipping it.
pub const ENVELOPE_WORDS: Words = 1;

// ---------------------------------------------------------------- codecs

/// A payload that can be appended to a slab.
///
/// Contract: `encode` appends exactly [`Encode::words`] words — the
/// outbox asserts it, so codec bugs surface at the send site, not as
/// garbled frames at the receiver.
pub trait Encode {
    /// Payload length in words (excluding the envelope).
    fn words(&self) -> usize;
    /// Append the payload's words to `slab`.
    fn encode(&self, slab: &mut Vec<u64>);
}

/// A payload that can be read back from a borrowed slab range.
pub trait Decode: Sized {
    /// Parse a payload; `None` if the frame has the wrong shape.
    fn decode(payload: &[u64]) -> Option<Self>;
}

impl Encode for u64 {
    fn words(&self) -> usize {
        1
    }

    fn encode(&self, slab: &mut Vec<u64>) {
        slab.push(*self);
    }
}

impl Decode for u64 {
    fn decode(payload: &[u64]) -> Option<u64> {
        match payload {
            [w] => Some(*w),
            _ => None,
        }
    }
}

impl Encode for (u64, u64) {
    fn words(&self) -> usize {
        2
    }

    fn encode(&self, slab: &mut Vec<u64>) {
        slab.push(self.0);
        slab.push(self.1);
    }
}

impl Decode for (u64, u64) {
    fn decode(payload: &[u64]) -> Option<(u64, u64)> {
        match payload {
            [a, b] => Some((*a, *b)),
            _ => None,
        }
    }
}

impl Encode for (u64, u64, u64) {
    fn words(&self) -> usize {
        3
    }

    fn encode(&self, slab: &mut Vec<u64>) {
        slab.push(self.0);
        slab.push(self.1);
        slab.push(self.2);
    }
}

impl Decode for (u64, u64, u64) {
    fn decode(payload: &[u64]) -> Option<(u64, u64, u64)> {
        match payload {
            [a, b, c] => Some((*a, *b, *c)),
            _ => None,
        }
    }
}

/// Status publication frame: a vertex id and its MIS bit packed into one
/// word — the shape of what Alg 1/2/3's publish rounds ship per edge.
/// Those rounds currently account their traffic via `sim.round` without
/// routing real payloads; this frame is the wire format they adopt as
/// they move onto the routed plane (today it is exercised by the wire
/// tests and the `mpc/plane_codecs` benchmark).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VertexStatus {
    pub vertex: u32,
    pub in_mis: bool,
}

impl Encode for VertexStatus {
    fn words(&self) -> usize {
        1
    }

    fn encode(&self, slab: &mut Vec<u64>) {
        slab.push(((self.vertex as u64) << 1) | u64::from(self.in_mis));
    }
}

impl Decode for VertexStatus {
    fn decode(payload: &[u64]) -> Option<VertexStatus> {
        match payload {
            [w] if *w >> 33 == 0 => Some(VertexStatus {
                // audit:allow(cast-truncate): bit extraction — the guard proves the high bits are zero
                vertex: (*w >> 1) as u32,
                in_mis: *w & 1 == 1,
            }),
            _ => None,
        }
    }
}

/// Label-propagation frame: `(vertex, label)` packed into one word —
/// the shape of a connectivity/clustering update. Like
/// [`VertexStatus`], this is the declared wire format for rounds whose
/// traffic is still charged via `sim.round`; its current users are the
/// wire tests and the `mpc/plane_codecs` benchmark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LabelUpdate {
    pub vertex: u32,
    pub label: u32,
}

impl Encode for LabelUpdate {
    fn words(&self) -> usize {
        1
    }

    fn encode(&self, slab: &mut Vec<u64>) {
        slab.push(((self.vertex as u64) << 32) | self.label as u64);
    }
}

impl Decode for LabelUpdate {
    fn decode(payload: &[u64]) -> Option<LabelUpdate> {
        match payload {
            // audit:allow(cast-truncate): bit extraction — each half of the packed word is taken on purpose
            [w] => Some(LabelUpdate { vertex: (*w >> 32) as u32, label: *w as u32 }),
            _ => None,
        }
    }
}

/// Rival announce frame: `(vertex, rank)` packed into one word — what a
/// constant-round pivot phase ([`crate::algorithms::rivals`]) ships per
/// directed edge in its announce round: "your neighbor with this rank is
/// eligible this phase". The receiver folds the minimum rank per vertex,
/// which is all the local-minimum pivot rule needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankAnnounce {
    /// Destination vertex (the announcing vertex's neighbor).
    pub vertex: u32,
    /// The announcing vertex's position in the pre-sampled random order.
    pub rank: u32,
}

impl Encode for RankAnnounce {
    fn words(&self) -> usize {
        1
    }

    fn encode(&self, slab: &mut Vec<u64>) {
        slab.push(((self.vertex as u64) << 32) | self.rank as u64);
    }
}

impl Decode for RankAnnounce {
    fn decode(payload: &[u64]) -> Option<RankAnnounce> {
        match payload {
            [w] => Some(RankAnnounce {
                vertex: u32::try_from(*w >> 32).expect("shifted half fits"),
                rank: u32::try_from(*w & u64::from(u32::MAX)).expect("masked half fits"),
            }),
            _ => None,
        }
    }
}

/// Rival claim frame: a freshly-elected pivot claiming `vertex` into its
/// cluster. Two words — `(vertex, pivot)` packed plus the pivot's rank —
/// because the receiver adopts the **minimum-rank** claimer and, on a
/// real MPC fleet, does not hold remote vertices' ranks locally.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PivotClaim {
    /// The claimed vertex.
    pub vertex: u32,
    /// The claiming pivot (its id becomes the cluster label).
    pub pivot: u32,
    /// The pivot's rank, shipped so the receiver can break ties locally.
    pub rank: u32,
}

impl Encode for PivotClaim {
    fn words(&self) -> usize {
        2
    }

    fn encode(&self, slab: &mut Vec<u64>) {
        slab.push(((self.vertex as u64) << 32) | self.pivot as u64);
        slab.push(self.rank as u64);
    }
}

impl Decode for PivotClaim {
    fn decode(payload: &[u64]) -> Option<PivotClaim> {
        match payload {
            [a, b] if *b >> 32 == 0 => Some(PivotClaim {
                vertex: u32::try_from(*a >> 32).expect("shifted half fits"),
                pivot: u32::try_from(*a & u64::from(u32::MAX)).expect("masked half fits"),
                rank: u32::try_from(*b).expect("high bits guarded above"),
            }),
            _ => None,
        }
    }
}

// ------------------------------------------------------------- send side

/// One message's index entry in a sender-side slab.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct WireEntry {
    from: u32,
    dst: u32,
    offset: u32,
    len: u32,
}

/// A shard's outbox for one round: one contiguous payload slab plus the
/// `(from, dst, offset, len)` index, with send words tallied on the
/// shard's private [`ShardLedger`] as messages are appended.
///
/// The router hands one of these (positioned on the current sender via
/// `begin`) to the round's build closure; callers only see the typed
/// [`WireOutbox::send`] / raw [`WireOutbox::send_words`] API.
#[derive(Debug)]
pub struct WireOutbox {
    machines: usize,
    from: u32,
    slab: Vec<u64>,
    entries: Vec<WireEntry>,
    ledger: ShardLedger,
}

impl WireOutbox {
    /// Outbox for the shard owning machines `range` of a `machines`-wide
    /// fleet.
    pub(crate) fn new(range: std::ops::Range<usize>, machines: usize) -> WireOutbox {
        WireOutbox {
            machines,
            from: u32::try_from(range.start).expect("machine index fits u32"),
            slab: Vec::new(),
            entries: Vec::new(),
            ledger: ShardLedger::new(range),
        }
    }

    /// Position the outbox on sender `m` (the router calls this once per
    /// machine, in range order, before invoking the build closure).
    pub(crate) fn begin(&mut self, m: usize) {
        self.from = u32::try_from(m).expect("machine index fits u32");
    }

    /// Send a typed payload to `dst`.
    pub fn send<T: Encode>(&mut self, dst: usize, msg: &T) {
        let offset = self.slab.len();
        msg.encode(&mut self.slab);
        let len = self.slab.len() - offset;
        assert_eq!(len, msg.words(), "Encode wrote {len} words, declared {}", msg.words());
        self.push_entry(dst, offset, len);
    }

    /// Send raw payload words to `dst` (the untyped escape hatch; empty
    /// payloads are legal and cost the envelope word alone).
    pub fn send_words(&mut self, dst: usize, payload: &[u64]) {
        let offset = self.slab.len();
        self.slab.extend_from_slice(payload);
        self.push_entry(dst, offset, payload.len());
    }

    /// Messages appended so far (across all senders of the shard).
    pub fn messages(&self) -> usize {
        self.entries.len()
    }

    /// Payload words appended so far.
    pub fn slab_words(&self) -> usize {
        self.slab.len()
    }

    fn push_entry(&mut self, dst: usize, offset: usize, len: usize) {
        assert!(dst < self.machines, "message to unknown machine {dst}");
        let offset = u32::try_from(offset).expect("round slab exceeds u32 offsets");
        let len = u32::try_from(len).expect("payload exceeds u32 length");
        let dst = u32::try_from(dst).expect("machine index fits u32");
        self.entries.push(WireEntry { from: self.from, dst, offset, len });
        self.ledger.charge(self.from as usize, len as Words + ENVELOPE_WORDS);
    }

    /// Tear down into the send ledger (the barrier absorbs it).
    pub(crate) fn into_ledger(self) -> ShardLedger {
        self.ledger
    }
}

// ---------------------------------------------------------- receive side

/// One delivered message's index entry in a receiver-side slab.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct InboxEntry {
    from: u32,
    offset: u32,
    len: u32,
}

/// Receiver-side arena for one round: one contiguous slab per destination
/// machine plus per-destination entry lists. Built once at the round
/// barrier; all access is zero-copy via [`RoundInboxes::inbox`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundInboxes {
    slabs: Vec<Vec<u64>>,
    entries: Vec<Vec<InboxEntry>>,
}

impl RoundInboxes {
    /// The barrier's exchange half: walk the shard outboxes in shard
    /// order (= sender order), copy each payload range once into its
    /// destination slab, and charge receive words on `recv`.
    pub(crate) fn deliver(
        machines: usize,
        shards: &[WireOutbox],
        recv: &mut ShardLedger,
    ) -> RoundInboxes {
        // Sizing pass so the receiver slabs allocate exactly once.
        let mut words = vec![0usize; machines];
        let mut counts = vec![0usize; machines];
        for ob in shards {
            for e in &ob.entries {
                words[e.dst as usize] += e.len as usize;
                counts[e.dst as usize] += 1;
            }
        }
        let mut slabs: Vec<Vec<u64>> = words.iter().map(|&w| Vec::with_capacity(w)).collect();
        let mut entries: Vec<Vec<InboxEntry>> =
            counts.iter().map(|&c| Vec::with_capacity(c)).collect();
        for ob in shards {
            for e in &ob.entries {
                let d = e.dst as usize;
                let offset =
                    u32::try_from(slabs[d].len()).expect("receiver slab exceeds u32 offsets");
                slabs[d].extend_from_slice(
                    &ob.slab[e.offset as usize..e.offset as usize + e.len as usize],
                );
                entries[d].push(InboxEntry { from: e.from, offset, len: e.len });
                recv.charge(d, e.len as Words + ENVELOPE_WORDS);
            }
        }
        RoundInboxes { slabs, entries }
    }

    pub fn machines(&self) -> usize {
        self.entries.len()
    }

    /// Zero-copy view of machine `m`'s inbox.
    pub fn inbox(&self, m: usize) -> Inbox<'_> {
        Inbox { slab: &self.slabs[m], entries: &self.entries[m] }
    }

    /// Messages delivered this round, across all machines.
    pub fn total_messages(&self) -> usize {
        self.entries.iter().map(Vec::len).sum()
    }

    /// Payload words delivered this round, across all machines.
    pub fn total_words(&self) -> usize {
        self.slabs.iter().map(Vec::len).sum()
    }
}

/// One machine's inbox: borrowed slices over the receiver slab, in the
/// deterministic sender order the barrier delivered.
#[derive(Debug, Clone, Copy)]
pub struct Inbox<'a> {
    slab: &'a [u64],
    entries: &'a [InboxEntry],
}

impl<'a> Inbox<'a> {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, i: usize) -> WireMsg<'a> {
        let e = self.entries[i];
        WireMsg {
            from: e.from as usize,
            payload: &self.slab[e.offset as usize..e.offset as usize + e.len as usize],
        }
    }

    pub fn first(&self) -> Option<WireMsg<'a>> {
        if self.is_empty() {
            None
        } else {
            Some(self.get(0))
        }
    }

    pub fn iter(self) -> InboxIter<'a> {
        InboxIter { slab: self.slab, entries: self.entries.iter() }
    }
}

impl<'a> IntoIterator for Inbox<'a> {
    type Item = WireMsg<'a>;
    type IntoIter = InboxIter<'a>;

    fn into_iter(self) -> InboxIter<'a> {
        self.iter()
    }
}

/// Iterator over an [`Inbox`] in delivery order.
#[derive(Debug, Clone)]
pub struct InboxIter<'a> {
    slab: &'a [u64],
    entries: std::slice::Iter<'a, InboxEntry>,
}

impl<'a> Iterator for InboxIter<'a> {
    type Item = WireMsg<'a>;

    fn next(&mut self) -> Option<WireMsg<'a>> {
        let e = self.entries.next()?;
        Some(WireMsg {
            from: e.from as usize,
            payload: &self.slab[e.offset as usize..e.offset as usize + e.len as usize],
        })
    }
}

/// A delivered message: sender id plus a borrowed payload slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireMsg<'a> {
    pub from: usize,
    pub payload: &'a [u64],
}

impl WireMsg<'_> {
    /// Ledger words of this message (payload + envelope), matching the
    /// retired per-message accounting exactly.
    pub fn words(&self) -> Words {
        self.payload.len() as Words + ENVELOPE_WORDS
    }

    /// Decode the payload, panicking on a malformed frame (senders and
    /// receivers share the codec, so a mismatch is a bug, not data).
    pub fn decode<T: Decode>(&self) -> T {
        self.try_decode().unwrap_or_else(|| {
            panic!(
                "payload of {} words does not decode as {}",
                self.payload.len(),
                std::any::type_name::<T>()
            )
        })
    }

    pub fn try_decode<T: Decode>(&self) -> Option<T> {
        T::decode(self.payload)
    }
}

// ------------------------------------------------------- legacy oracle

/// The retired per-message wire format, reproduced as a single
/// executable oracle: one heap-allocated `Vec<u64>` per message on both
/// sides, sender-ordered delivery, the same `+1` envelope word on the
/// ledgers, and the router barrier's exact check ordering (send shards
/// absorbed before the receive ledger).
///
/// This is deliberately the **only** place the old format survives —
/// the router's old-vs-new parity test and the `mpc/plane_vs_permsg`
/// benchmark baseline both call it, so they can never drift apart. It
/// is not a Router path; production code sends through [`WireOutbox`].
pub fn per_message_round(
    machines: usize,
    sim: &mut crate::mpc::simulator::MpcSimulator,
    label: &str,
    outboxes: Vec<Vec<(usize, Vec<u64>)>>,
) -> Vec<Vec<(usize, Vec<u64>)>> {
    use crate::mpc::memory::MemoryLedger;
    let mut send = ShardLedger::new(0..machines);
    let mut recv = ShardLedger::new(0..machines);
    let mut inboxes: Vec<Vec<(usize, Vec<u64>)>> = vec![Vec::new(); machines];
    for (from, outbox) in outboxes.into_iter().enumerate() {
        for (dst, payload) in outbox {
            let words = payload.len() as Words + ENVELOPE_WORDS;
            send.charge(from, words);
            recv.charge(dst, words);
            inboxes[dst].push((from, payload));
        }
    }
    let max_out = send.max_local();
    let max_in = recv.max_local();
    let total = send.total();
    let s = sim.config.s_words;
    let mut sent_fleet = MemoryLedger::new(machines, s, sim.config.global_words);
    let mut recv_fleet = MemoryLedger::new(machines, s, Words::MAX);
    let mut violation = sent_fleet.absorb(&send).err();
    if violation.is_none() {
        violation = recv_fleet.absorb(&recv).err();
    }
    sim.round_checked(label, max_out, max_in, total, max_out.max(max_in), violation);
    inboxes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        let mut slab = Vec::new();
        v.encode(&mut slab);
        assert_eq!(slab.len(), v.words(), "declared vs written words");
        assert_eq!(T::decode(&slab), Some(v), "encode∘decode must be id");
    }

    #[test]
    fn codec_roundtrips() {
        roundtrip(0u64);
        roundtrip(u64::MAX);
        roundtrip((3u64, 9u64));
        roundtrip((1u64, u64::MAX, 7u64));
        roundtrip(VertexStatus { vertex: 0, in_mis: false });
        roundtrip(VertexStatus { vertex: u32::MAX, in_mis: true });
        roundtrip(LabelUpdate { vertex: 17, label: 0 });
        roundtrip(LabelUpdate { vertex: u32::MAX, label: u32::MAX });
        roundtrip(RankAnnounce { vertex: 0, rank: 0 });
        roundtrip(RankAnnounce { vertex: u32::MAX, rank: u32::MAX });
        roundtrip(PivotClaim { vertex: 3, pivot: 9, rank: 1 });
        roundtrip(PivotClaim { vertex: u32::MAX, pivot: u32::MAX, rank: u32::MAX });
    }

    #[test]
    fn codec_rejects_wrong_shapes() {
        assert_eq!(u64::decode(&[]), None);
        assert_eq!(u64::decode(&[1, 2]), None);
        assert_eq!(<(u64, u64)>::decode(&[1]), None);
        assert_eq!(<(u64, u64, u64)>::decode(&[1, 2]), None);
        assert_eq!(VertexStatus::decode(&[u64::MAX]), None, "high bits must be clear");
        assert_eq!(LabelUpdate::decode(&[1, 2]), None);
        assert_eq!(RankAnnounce::decode(&[1, 2]), None);
        assert_eq!(PivotClaim::decode(&[1]), None);
        assert_eq!(PivotClaim::decode(&[1, u64::MAX]), None, "rank high bits must be clear");
    }

    #[test]
    fn word_counts_match_ledger_accounting() {
        // Every codec's words() + the envelope equals what the retired
        // per-message plane charged for the same payload.
        let mut slab = Vec::new();
        let v = VertexStatus { vertex: 4, in_mis: true };
        v.encode(&mut slab);
        let legacy_words = slab.len() as Words + 1; // Vec payload + sender word
        assert_eq!(v.words() as Words + ENVELOPE_WORDS, legacy_words);
    }

    #[test]
    fn outbox_builds_one_slab_with_index() {
        let mut out = WireOutbox::new(0..2, 4);
        out.begin(0);
        out.send(1, &7u64);
        out.send_words(3, &[1, 2, 3]);
        out.begin(1);
        out.send_words(2, &[]);
        assert_eq!(out.messages(), 3);
        assert_eq!(out.slab_words(), 4);
        assert_eq!(out.slab, vec![7, 1, 2, 3]);
        assert_eq!(
            out.entries,
            vec![
                WireEntry { from: 0, dst: 1, offset: 0, len: 1 },
                WireEntry { from: 0, dst: 3, offset: 1, len: 3 },
                WireEntry { from: 1, dst: 2, offset: 4, len: 0 },
            ]
        );
        // Ledger: machine 0 sent (1+1) + (3+1) = 6, machine 1 sent 0+1.
        let ledger = out.into_ledger();
        assert_eq!(ledger.used(0), 6);
        assert_eq!(ledger.used(1), 1);
    }

    #[test]
    #[should_panic(expected = "unknown machine")]
    fn outbox_rejects_unknown_destination() {
        let mut out = WireOutbox::new(0..1, 2);
        out.begin(0);
        out.send(5, &1u64);
    }

    #[test]
    fn deliver_copies_in_sender_order_and_charges_receive() {
        // Two shards; delivery must interleave by shard order then
        // sender order, exactly like the retired plane.
        let mut a = WireOutbox::new(0..2, 3);
        a.begin(0);
        a.send(2, &10u64);
        a.begin(1);
        a.send_words(2, &[20, 21]);
        let mut b = WireOutbox::new(2..3, 3);
        b.begin(2);
        b.send(2, &30u64);
        b.send(0, &(1u64, 2u64));
        let mut recv = ShardLedger::new(0..3);
        let inboxes = RoundInboxes::deliver(3, &[a, b], &mut recv);
        let got: Vec<(usize, Vec<u64>)> =
            inboxes.inbox(2).iter().map(|m| (m.from, m.payload.to_vec())).collect();
        assert_eq!(got, vec![(0, vec![10]), (1, vec![20, 21]), (2, vec![30])]);
        assert_eq!(inboxes.inbox(0).first().map(|m| m.decode::<(u64, u64)>()), Some((1, 2)));
        assert!(inboxes.inbox(1).is_empty());
        // Receive ledger: machine 2 got 2 + 3 + 2 = 7 words, machine 0 got 3.
        assert_eq!(recv.used(2), 7);
        assert_eq!(recv.used(0), 3);
        assert_eq!(inboxes.total_messages(), 4);
        assert_eq!(inboxes.total_words(), 6);
    }
}
