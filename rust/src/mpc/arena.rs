//! Pooled per-round scratch for the router: the allocation-recycling
//! half of the flat-arena message plane.
//!
//! PR 5 made a round *one slab per shard* instead of one `Vec` per
//! message; this module makes steady-state rounds reuse those slabs
//! instead of reallocating them. A [`RoundArena`] owns every reusable
//! body the round barrier needs — shard outboxes, the receiver-side
//! sizing scratch, the receive shard ledger, the two fleet ledgers the
//! barrier absorbs into, and the inbox reclaim bin — and
//! [`Router::round`](crate::mpc::router::Router::round) borrows the lot
//! for the duration of one round.
//!
//! The recycling policy is uniformly **`clear()`, not drop**: every
//! buffer is rewound to length zero but keeps its high-water-mark
//! capacity, so after the first round (or the first round at a new
//! fleet/width shape) the plane's steady state performs no heap
//! allocation — outbox slabs, index Vecs, receiver slabs, ledgers and
//! sizing scratch are all reused. Inbox bodies complete the cycle
//! through the reclaim bin: a [`RoundInboxes`](crate::mpc::wire::RoundInboxes)
//! built by a pooling router returns its slabs there when dropped, and
//! the next barrier pops them back out.
//!
//! The arena never influences *what* a round computes: it holds no
//! message data across rounds (everything is cleared before reuse), and
//! ledger charges are taken on freshly-zeroed tallies. It is invisible
//! to the model — only to the allocator.

use std::sync::{Mutex, MutexGuard};

use crate::mpc::memory::{MemoryLedger, ShardLedger, Words};
use crate::mpc::wire::{DeliverScratch, InboxReclaim, WireOutbox, WordWidth};

/// Reusable round-barrier state, shared behind the router's `Arc`.
///
/// Interior mutability (a `Mutex`, never contended in the common case of
/// one round at a time per router) keeps `Router::round`'s signature
/// `&self`, exactly as before pooling. If two threads do race rounds on
/// one router, they serialize on the arena — correct, just not pooled
/// across the two streams.
#[derive(Debug, Default)]
pub struct RoundArena {
    core: Mutex<ArenaCore>,
}

/// The arena's contents; field-level access is crate-internal (the
/// router is the only consumer).
#[derive(Debug, Default)]
pub(crate) struct ArenaCore {
    /// Idle outboxes awaiting the next round's shards (capacity warm).
    pub(crate) seeds: Vec<WireOutbox>,
    /// Shard-order outboxes of the round in flight (drained back into
    /// `seeds` at the barrier).
    pub(crate) built: Vec<WireOutbox>,
    /// Receiver-side sizing scratch for `RoundInboxes::deliver`.
    pub(crate) deliver: DeliverScratch,
    /// The receive-side shard ledger (re-targeted every round).
    pub(crate) recv: Option<ShardLedger>,
    /// Fleet ledger the send shards are absorbed into.
    pub(crate) sent_fleet: MemoryLedger,
    /// Fleet ledger the receive tallies are absorbed into.
    pub(crate) recv_fleet: MemoryLedger,
    /// Pool of cleared inbox bodies (shared with outstanding inboxes).
    pub(crate) reclaim: InboxReclaim,
}

impl RoundArena {
    pub fn new() -> RoundArena {
        RoundArena::default()
    }

    /// Borrow the arena for one round. A poisoned lock is recovered, not
    /// propagated: poisoning here only means a previous round panicked
    /// mid-barrier (e.g. a strict-mode model violation unwound through
    /// `round_checked`), and every `reset`/`reconfigure` call at the top
    /// of the next round re-normalizes the state before use.
    pub(crate) fn lock(&self) -> MutexGuard<'_, ArenaCore> {
        self.core.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

impl ArenaCore {
    /// Top up the seed pool so the next round can hand one outbox to
    /// each of `shards` shard workers.
    pub(crate) fn ensure_seeds(&mut self, shards: usize, width: WordWidth) {
        while self.seeds.len() < shards {
            self.seeds.push(WireOutbox::empty(width));
        }
    }

    /// Re-target the pooled receive ledger at `0..machines`, zeroed.
    pub(crate) fn recv_ledger(&mut self, machines: usize) -> &mut ShardLedger {
        match &mut self.recv {
            Some(ledger) => {
                ledger.reset(0..machines);
            }
            None => self.recv = Some(ShardLedger::new(0..machines)),
        }
        self.recv.as_mut().expect("just installed")
    }

    /// Re-target both pooled fleet ledgers for a barrier over `machines`
    /// machines with local budget `s_words` and global budget
    /// `global_words` (receive side is globally unbounded, matching the
    /// pre-pooling barrier exactly).
    pub(crate) fn fleet_ledgers(
        &mut self,
        machines: usize,
        s_words: Words,
        global_words: Words,
    ) -> (&mut MemoryLedger, &mut MemoryLedger) {
        self.sent_fleet.reconfigure(machines, s_words, global_words);
        self.recv_fleet.reconfigure(machines, s_words, Words::MAX);
        (&mut self.sent_fleet, &mut self.recv_fleet)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_top_up_and_recycle() {
        let arena = RoundArena::new();
        let mut core = arena.lock();
        core.ensure_seeds(3, WordWidth::W32);
        assert_eq!(core.seeds.len(), 3);
        assert_eq!(core.seeds[0].width(), WordWidth::W32);
        // A smaller round keeps the surplus seeds warm.
        core.ensure_seeds(1, WordWidth::W32);
        assert_eq!(core.seeds.len(), 3);
    }

    #[test]
    fn recv_ledger_is_retargeted_not_reallocated() {
        let arena = RoundArena::new();
        let mut core = arena.lock();
        core.recv_ledger(4).charge(2, 7);
        let l = core.recv_ledger(2);
        assert_eq!(l.machines(), 2);
        assert_eq!(l.total(), 0, "retarget zeroes old tallies");
    }

    #[test]
    fn fleet_ledgers_reconfigure_budgets() {
        let arena = RoundArena::new();
        let mut core = arena.lock();
        let (sent, recv) = core.fleet_ledgers(3, 10, 100);
        assert!(sent.charge(0, 11).is_err(), "local budget enforced");
        assert!(recv.charge(0, 11).is_err(), "receive local budget enforced");
        let (sent, _) = core.fleet_ledgers(3, 1000, 100);
        assert_eq!(sent.total(), 0, "reconfigure zeroes previous charges");
        assert!(sent.charge(0, 11).is_ok());
    }

    #[test]
    fn poisoned_arena_recovers() {
        let arena = std::sync::Arc::new(RoundArena::new());
        let a2 = arena.clone();
        let _ = std::thread::spawn(move || {
            let _guard = a2.lock();
            panic!("poison the lock");
        })
        .join();
        let mut core = arena.lock();
        core.ensure_seeds(1, WordWidth::W64);
        assert_eq!(core.seeds.len(), 1);
    }
}
