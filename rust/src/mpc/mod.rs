//! The MPC (Massively Parallel Computation) simulator: the paper's
//! computational model, built for real.
//!
//! * [`model`] — Model 1 / Model 2 parameterizations (S = Õ(n^δ), machine
//!   fleets, global memory budgets).
//! * [`memory`] — word-granular budget ledger; violations fail runs.
//! * [`simulator`] — synchronous round accounting and traces; the round
//!   counts reported by every experiment come from here.
//! * [`wire`] — the flat-arena message plane: per-shard payload slabs
//!   (at `u64` or packed `u32` width) with `(from, dst, offset, len)`
//!   indexes, zero-copy inbox views, and the typed
//!   [`wire::Encode`]/[`wire::Decode`] payload codecs.
//! * [`arena`] — the pooled per-round scratch behind the router:
//!   outbox/inbox slabs, index Vecs and ledgers recycled `clear()`-style
//!   so steady-state rounds allocate nothing.
//! * [`router`] — executable all-to-all message delivery on the wire
//!   plane with O(S) per-machine send/receive enforcement.
//! * [`broadcast`] — S-ary broadcast/convergecast trees (§2.1.5) running
//!   on the router.
//! * [`exponentiation`] — graph exponentiation (§2.1.3): 2^k-hop ball
//!   gathering with measured memory footprints.
//! * [`pool`] — the machine-sharded scoped-thread pool: per-machine local
//!   compute fans out across shards and is merged deterministically at
//!   every synchronous round barrier.

pub mod arena;
pub mod broadcast;
pub mod connectivity;
pub mod exponentiation;
pub mod memory;
pub mod model;
pub mod pool;
pub mod router;
pub mod simulator;
pub mod wire;

pub use model::{ModelKind, MpcConfig};
pub use pool::ShardPool;
pub use router::Router;
pub use simulator::MpcSimulator;
pub use wire::{Decode, Encode, PayloadView, RoundInboxes, WireMsg, WireOutbox, WordWidth};
