//! Round-accounting MPC simulator.
//!
//! Algorithms in `algorithms/mpc_mis/` execute their *logic* in plain Rust
//! (the MPC model allows arbitrary local computation) while reporting every
//! synchronous communication round to this simulator: what the round was
//! for, the maximum per-machine words sent/received, and the per-machine
//! state held.  The simulator enforces the model:
//!
//! * a round whose max per-machine traffic exceeds O(S) fails the run;
//! * per-machine state beyond S words fails the run;
//! * the reported round count *is* the experiment's measured quantity.
//!
//! This is the standard methodology for evaluating MPC algorithms without
//! a 10,000-node cluster: round complexity and memory feasibility are
//! properties of the communication schedule, which is executed faithfully;
//! wall-clock of an actual deployment is out of scope (the paper never
//! reports one).
//!
//! Rounds that move real messages do so on the flat-arena wire plane
//! ([`crate::mpc::wire`]) via [`crate::mpc::router::Router::round`];
//! `tests/round_counts.rs` pins the golden communication schedule so
//! plane refactors cannot silently change it.

use crate::mpc::memory::{BudgetError, Words};
use crate::mpc::model::MpcConfig;
use crate::mpc::pool::{self, ShardPool};
use crate::util::rng::Rng;

/// Statistics of one synchronous round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundStat {
    pub label: String,
    /// Max words sent by any machine this round.
    pub max_out: Words,
    /// Max words received by any machine this round.
    pub max_in: Words,
    /// Total words moved this round.
    pub total: Words,
    /// Max per-machine resident state this round.
    pub max_state: Words,
}

/// One shard's partial statistics for a round in flight. Shards fill these
/// independently during the round's local-compute half; the barrier merges
/// them (max/max/sum/max) into the round's [`RoundStat`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardRoundStat {
    pub max_out: Words,
    pub max_in: Words,
    pub total: Words,
    pub max_state: Words,
}

impl ShardRoundStat {
    pub fn merge(self, other: ShardRoundStat) -> ShardRoundStat {
        ShardRoundStat {
            max_out: self.max_out.max(other.max_out),
            max_in: self.max_in.max(other.max_in),
            total: self.total + other.total,
            max_state: self.max_state.max(other.max_state),
        }
    }
}

/// Error type: a model violation with the offending round.
#[derive(Debug)]
pub struct MpcViolation {
    pub round: usize,
    pub label: String,
    pub error: BudgetError,
}

impl std::fmt::Display for MpcViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "round {} ({}): {}", self.round, self.label, self.error)
    }
}

impl std::error::Error for MpcViolation {}

/// The simulator. Cheap to clone-free pass by `&mut` through algorithms.
///
/// Carries the [`ShardPool`] the executor runs on: `new`/`lenient` build
/// the sequential (one-shard) executor, `sharded` the multi-threaded one.
/// Round *accounting* always happens on the caller's thread at the round
/// barrier — shards only produce partials — so traces, violations and
/// round counts are identical at every shard count.
#[derive(Debug)]
pub struct MpcSimulator {
    pub config: MpcConfig,
    trace: Vec<RoundStat>,
    /// When true, budget violations panic immediately (tests/benches);
    /// when false they are recorded and surfaced at the end.
    strict: bool,
    violations: Vec<MpcViolation>,
    pool: ShardPool,
    /// Base seed for the deterministic per-machine RNG streams.
    seed: u64,
}

impl MpcSimulator {
    pub fn new(config: MpcConfig) -> MpcSimulator {
        Self::build(config, ShardPool::serial(), true)
    }

    pub fn lenient(config: MpcConfig) -> MpcSimulator {
        Self::build(config, ShardPool::serial(), false)
    }

    /// Strict simulator on a machine-sharded pool of `shards` threads.
    pub fn sharded(config: MpcConfig, shards: usize) -> MpcSimulator {
        Self::build(config, ShardPool::new(shards), true)
    }

    /// Lenient simulator on a machine-sharded pool of `shards` threads.
    pub fn lenient_sharded(config: MpcConfig, shards: usize) -> MpcSimulator {
        Self::build(config, ShardPool::new(shards), false)
    }

    fn build(config: MpcConfig, pool: ShardPool, strict: bool) -> MpcSimulator {
        MpcSimulator {
            config,
            trace: Vec::new(),
            strict,
            violations: Vec::new(),
            pool,
            seed: 0,
        }
    }

    /// Set the base seed for per-machine RNG streams (builder style).
    pub fn with_seed(mut self, seed: u64) -> MpcSimulator {
        self.seed = seed;
        self
    }

    /// The executor's shard pool. Cloning is free; primitives grab a clone
    /// so they can fan work out while holding `&mut self` for the barrier.
    pub fn pool(&self) -> ShardPool {
        self.pool.clone()
    }

    pub fn shards(&self) -> usize {
        self.pool.shards()
    }

    /// Deterministic RNG stream for one machine: a function of the base
    /// seed and the machine id only, never of shard count or scheduling.
    pub fn machine_rng(&self, machine: usize) -> Rng {
        pool::machine_rng(self.seed, machine)
    }

    /// Per-round machine stream: like [`Self::machine_rng`] but keyed on
    /// an extra tag, built with a single generator construction.
    pub fn machine_stream(&self, machine: usize, tag: u64) -> Rng {
        pool::machine_stream(self.seed, machine, tag)
    }

    /// Record one synchronous round.
    ///
    /// `max_out` / `max_in`: maximum words any machine sends/receives.
    /// `max_state`: maximum words any machine holds while computing.
    /// `total`: total words communicated (for the report; not a budget).
    pub fn round(&mut self, label: &str, max_out: Words, max_in: Words, total: Words, max_state: Words) {
        self.round_checked(label, max_out, max_in, total, max_state, None);
    }

    /// Merge per-shard partials at the round barrier and record the round.
    pub fn round_from_shards(&mut self, label: &str, shards: &[ShardRoundStat]) {
        let merged = shards
            .iter()
            .copied()
            .fold(ShardRoundStat::default(), ShardRoundStat::merge);
        self.round(label, merged.max_out, merged.max_in, merged.total, merged.max_state);
    }

    /// Record a round whose budgets were already checked against a merged
    /// memory ledger (the router's barrier path). A `ledger_violation`
    /// takes precedence — it carries the offending machine id — otherwise
    /// the standard threshold checks run.
    pub fn round_checked(
        &mut self,
        label: &str,
        max_out: Words,
        max_in: Words,
        total: Words,
        max_state: Words,
        ledger_violation: Option<BudgetError>,
    ) {
        let stat = RoundStat {
            label: label.to_string(),
            max_out,
            max_in,
            total,
            max_state,
        };
        let round_idx = self.trace.len();
        // The model allows messages of size O(S); we use the literal S as
        // the constant (the polylog slack already lives inside S).
        let s = self.config.s_words;
        let violation = if ledger_violation.is_some() {
            ledger_violation
        } else if max_out > s || max_in > s {
            Some(BudgetError::LocalExceeded {
                machine: 0,
                used: max_out.max(max_in),
                budget: s,
            })
        } else if max_state > s {
            Some(BudgetError::LocalExceeded { machine: 0, used: max_state, budget: s })
        } else if total > self.config.global_words {
            Some(BudgetError::GlobalExceeded { used: total, budget: self.config.global_words })
        } else {
            None
        };
        self.trace.push(stat);
        if let Some(error) = violation {
            let v = MpcViolation { round: round_idx, label: label.to_string(), error };
            if self.strict {
                panic!("{v}");
            }
            self.violations.push(v);
        }
    }

    /// Record `k` rounds of identical shape (e.g. a broadcast tree pass).
    pub fn rounds(&mut self, label: &str, k: usize, max_words: Words, total: Words) {
        for i in 0..k {
            self.round(&format!("{label}[{i}]"), max_words, max_words, total, max_words);
        }
    }

    pub fn n_rounds(&self) -> usize {
        self.trace.len()
    }

    pub fn trace(&self) -> &[RoundStat] {
        &self.trace
    }

    pub fn violations(&self) -> &[MpcViolation] {
        &self.violations
    }

    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Peak per-machine words observed over all rounds.
    pub fn peak_machine_words(&self) -> Words {
        self.trace
            .iter()
            .map(|r| r.max_out.max(r.max_in).max(r.max_state))
            .max()
            .unwrap_or(0)
    }

    /// Total communication over the whole run.
    pub fn total_communication(&self) -> Words {
        self.trace.iter().map(|r| r.total).sum()
    }

    /// Rounds whose label starts with the given phase prefix.
    pub fn rounds_with_prefix(&self, prefix: &str) -> usize {
        self.trace.iter().filter(|r| r.label.starts_with(prefix)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::model::MpcConfig;

    fn sim() -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(10_000, 50_000, 0.5))
    }

    #[test]
    fn counts_rounds_and_peaks() {
        let mut s = sim();
        s.round("a", 10, 20, 100, 30);
        s.round("b", 5, 5, 50, 40);
        assert_eq!(s.n_rounds(), 2);
        assert_eq!(s.peak_machine_words(), 40);
        assert_eq!(s.total_communication(), 150);
        assert!(s.ok());
    }

    #[test]
    #[should_panic(expected = "model violation")]
    fn strict_violation_panics() {
        let mut s = sim();
        let too_much = s.config.s_words + 1;
        s.round("overflow", too_much, 0, too_much, 0);
    }

    #[test]
    fn lenient_records_violation() {
        let cfg = MpcConfig::model1(10_000, 50_000, 0.5);
        let mut s = MpcSimulator::lenient(cfg);
        let too_much = s.config.s_words + 1;
        s.round("overflow", too_much, 0, too_much, 0);
        assert!(!s.ok());
        assert_eq!(s.violations().len(), 1);
    }

    #[test]
    fn rounds_with_prefix_filters() {
        let mut s = sim();
        s.rounds("phase1/bcast", 3, 1, 1);
        s.round("phase2", 1, 1, 1, 1);
        assert_eq!(s.rounds_with_prefix("phase1"), 3);
        assert_eq!(s.n_rounds(), 4);
    }

    #[test]
    fn shard_partials_merge_like_one_round() {
        let partials = [
            ShardRoundStat { max_out: 10, max_in: 3, total: 100, max_state: 7 },
            ShardRoundStat { max_out: 4, max_in: 20, total: 50, max_state: 9 },
            ShardRoundStat::default(),
        ];
        let mut a = sim();
        a.round_from_shards("merged", &partials);
        let mut b = sim();
        b.round("merged", 10, 20, 150, 9);
        assert_eq!(a.trace(), b.trace());
    }

    #[test]
    fn sharded_constructor_keeps_accounting_identical() {
        let cfg = MpcConfig::model1(10_000, 50_000, 0.5);
        let mut seq = MpcSimulator::new(cfg.clone());
        let mut par = MpcSimulator::sharded(cfg, 8);
        assert_eq!(par.shards(), 8);
        for s in [&mut seq, &mut par] {
            s.round("a", 10, 20, 100, 30);
            s.rounds("b", 2, 5, 25);
        }
        assert_eq!(seq.trace(), par.trace());
        assert_eq!(seq.peak_machine_words(), par.peak_machine_words());
    }

    #[test]
    fn machine_rng_streams_stable_across_shard_counts() {
        let cfg = MpcConfig::model1(10_000, 50_000, 0.5);
        let a = MpcSimulator::new(cfg.clone()).with_seed(99);
        let b = MpcSimulator::sharded(cfg, 4).with_seed(99);
        for m in 0..16 {
            assert_eq!(a.machine_rng(m).next_u64(), b.machine_rng(m).next_u64());
        }
    }

    #[test]
    fn ledger_violation_takes_precedence() {
        let cfg = MpcConfig::model1(10_000, 50_000, 0.5);
        let mut s = MpcSimulator::lenient(cfg);
        let err = crate::mpc::memory::BudgetError::LocalExceeded {
            machine: 5,
            used: 123,
            budget: 7,
        };
        s.round_checked("ledger", 1, 1, 1, 1, Some(err.clone()));
        assert_eq!(s.violations().len(), 1);
        assert_eq!(s.violations()[0].error, err);
    }
}
