//! Round-accounting MPC simulator.
//!
//! Algorithms in `algorithms/mpc_mis/` execute their *logic* in plain Rust
//! (the MPC model allows arbitrary local computation) while reporting every
//! synchronous communication round to this simulator: what the round was
//! for, the maximum per-machine words sent/received, and the per-machine
//! state held.  The simulator enforces the model:
//!
//! * a round whose max per-machine traffic exceeds O(S) fails the run;
//! * per-machine state beyond S words fails the run;
//! * the reported round count *is* the experiment's measured quantity.
//!
//! This is the standard methodology for evaluating MPC algorithms without
//! a 10,000-node cluster: round complexity and memory feasibility are
//! properties of the communication schedule, which is executed faithfully;
//! wall-clock of an actual deployment is out of scope (the paper never
//! reports one).

use crate::mpc::memory::{BudgetError, Words};
use crate::mpc::model::MpcConfig;

/// Statistics of one synchronous round.
#[derive(Debug, Clone)]
pub struct RoundStat {
    pub label: String,
    /// Max words sent by any machine this round.
    pub max_out: Words,
    /// Max words received by any machine this round.
    pub max_in: Words,
    /// Total words moved this round.
    pub total: Words,
    /// Max per-machine resident state this round.
    pub max_state: Words,
}

/// Error type: a model violation with the offending round.
#[derive(Debug)]
pub struct MpcViolation {
    pub round: usize,
    pub label: String,
    pub error: BudgetError,
}

impl std::fmt::Display for MpcViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "round {} ({}): {}", self.round, self.label, self.error)
    }
}

impl std::error::Error for MpcViolation {}

/// The simulator. Cheap to clone-free pass by `&mut` through algorithms.
#[derive(Debug)]
pub struct MpcSimulator {
    pub config: MpcConfig,
    trace: Vec<RoundStat>,
    /// When true, budget violations panic immediately (tests/benches);
    /// when false they are recorded and surfaced at the end.
    strict: bool,
    violations: Vec<MpcViolation>,
}

impl MpcSimulator {
    pub fn new(config: MpcConfig) -> MpcSimulator {
        MpcSimulator { config, trace: Vec::new(), strict: true, violations: Vec::new() }
    }

    pub fn lenient(config: MpcConfig) -> MpcSimulator {
        MpcSimulator { config, trace: Vec::new(), strict: false, violations: Vec::new() }
    }

    /// Record one synchronous round.
    ///
    /// `max_out` / `max_in`: maximum words any machine sends/receives.
    /// `max_state`: maximum words any machine holds while computing.
    /// `total`: total words communicated (for the report; not a budget).
    pub fn round(&mut self, label: &str, max_out: Words, max_in: Words, total: Words, max_state: Words) {
        let stat = RoundStat {
            label: label.to_string(),
            max_out,
            max_in,
            total,
            max_state,
        };
        let round_idx = self.trace.len();
        // The model allows messages of size O(S); we use the literal S as
        // the constant (the polylog slack already lives inside S).
        let s = self.config.s_words;
        let violation = if max_out > s || max_in > s {
            Some(BudgetError::LocalExceeded {
                machine: 0,
                used: max_out.max(max_in),
                budget: s,
            })
        } else if max_state > s {
            Some(BudgetError::LocalExceeded { machine: 0, used: max_state, budget: s })
        } else if total > self.config.global_words {
            Some(BudgetError::GlobalExceeded { used: total, budget: self.config.global_words })
        } else {
            None
        };
        self.trace.push(stat);
        if let Some(error) = violation {
            let v = MpcViolation { round: round_idx, label: label.to_string(), error };
            if self.strict {
                panic!("{v}");
            }
            self.violations.push(v);
        }
    }

    /// Record `k` rounds of identical shape (e.g. a broadcast tree pass).
    pub fn rounds(&mut self, label: &str, k: usize, max_words: Words, total: Words) {
        for i in 0..k {
            self.round(&format!("{label}[{i}]"), max_words, max_words, total, max_words);
        }
    }

    pub fn n_rounds(&self) -> usize {
        self.trace.len()
    }

    pub fn trace(&self) -> &[RoundStat] {
        &self.trace
    }

    pub fn violations(&self) -> &[MpcViolation] {
        &self.violations
    }

    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Peak per-machine words observed over all rounds.
    pub fn peak_machine_words(&self) -> Words {
        self.trace
            .iter()
            .map(|r| r.max_out.max(r.max_in).max(r.max_state))
            .max()
            .unwrap_or(0)
    }

    /// Total communication over the whole run.
    pub fn total_communication(&self) -> Words {
        self.trace.iter().map(|r| r.total).sum()
    }

    /// Rounds whose label starts with the given phase prefix.
    pub fn rounds_with_prefix(&self, prefix: &str) -> usize {
        self.trace.iter().filter(|r| r.label.starts_with(prefix)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::model::MpcConfig;

    fn sim() -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(10_000, 50_000, 0.5))
    }

    #[test]
    fn counts_rounds_and_peaks() {
        let mut s = sim();
        s.round("a", 10, 20, 100, 30);
        s.round("b", 5, 5, 50, 40);
        assert_eq!(s.n_rounds(), 2);
        assert_eq!(s.peak_machine_words(), 40);
        assert_eq!(s.total_communication(), 150);
        assert!(s.ok());
    }

    #[test]
    #[should_panic(expected = "model violation")]
    fn strict_violation_panics() {
        let mut s = sim();
        let too_much = s.config.s_words + 1;
        s.round("overflow", too_much, 0, too_much, 0);
    }

    #[test]
    fn lenient_records_violation() {
        let cfg = MpcConfig::model1(10_000, 50_000, 0.5);
        let mut s = MpcSimulator::lenient(cfg);
        let too_much = s.config.s_words + 1;
        s.round("overflow", too_much, 0, too_much, 0);
        assert!(!s.ok());
        assert_eq!(s.violations().len(), 1);
    }

    #[test]
    fn rounds_with_prefix_filters() {
        let mut s = sim();
        s.rounds("phase1/bcast", 3, 1, 1);
        s.round("phase2", 1, 1, 1, 1);
        assert_eq!(s.rounds_with_prefix("phase1"), 3);
        assert_eq!(s.n_rounds(), 4);
    }
}
