//! Word-based memory accounting for the MPC simulator.
//!
//! The MPC model measures everything in machine words (a vertex id, an
//! edge endpoint pair, a permutation rank are O(1) words each).  Budgets
//! are enforced, not advisory: exceeding a per-machine or global budget is
//! a *model violation* and fails the run — that is how the simulator
//! certifies that an algorithm really fits the regime it claims.

/// Number of machine words.
pub type Words = u64;

/// Outcome of a budget charge.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BudgetError {
    /// A single machine exceeded its local memory S.
    LocalExceeded { machine: usize, used: Words, budget: Words },
    /// Total memory across machines exceeded the global budget.
    GlobalExceeded { used: Words, budget: Words },
}

impl std::fmt::Display for BudgetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BudgetError::LocalExceeded { machine, used, budget } => write!(
                f,
                "MPC model violation: machine {machine} used {used} words (budget S = {budget})"
            ),
            BudgetError::GlobalExceeded { used, budget } => write!(
                f,
                "MPC model violation: global memory {used} words (budget {budget})"
            ),
        }
    }
}

impl std::error::Error for BudgetError {}

/// Tracks per-machine usage against local and global budgets.
#[derive(Debug, Clone)]
pub struct MemoryLedger {
    local_budget: Words,
    global_budget: Words,
    used: Vec<Words>,
    total: Words,
    /// High-water marks for reporting.
    pub peak_local: Words,
    pub peak_total: Words,
}

impl MemoryLedger {
    pub fn new(machines: usize, local_budget: Words, global_budget: Words) -> MemoryLedger {
        MemoryLedger {
            local_budget,
            global_budget,
            used: vec![0; machines],
            total: 0,
            peak_local: 0,
            peak_total: 0,
        }
    }

    pub fn machines(&self) -> usize {
        self.used.len()
    }

    pub fn local_budget(&self) -> Words {
        self.local_budget
    }

    pub fn charge(&mut self, machine: usize, words: Words) -> Result<(), BudgetError> {
        let used = &mut self.used[machine];
        *used += words;
        self.total += words;
        self.peak_local = self.peak_local.max(*used);
        self.peak_total = self.peak_total.max(self.total);
        if *used > self.local_budget {
            return Err(BudgetError::LocalExceeded {
                machine,
                used: *used,
                budget: self.local_budget,
            });
        }
        if self.total > self.global_budget {
            return Err(BudgetError::GlobalExceeded { used: self.total, budget: self.global_budget });
        }
        Ok(())
    }

    pub fn release(&mut self, machine: usize, words: Words) {
        let used = &mut self.used[machine];
        debug_assert!(*used >= words, "releasing more than charged");
        *used = used.saturating_sub(words);
        self.total = self.total.saturating_sub(words);
    }

    /// Release everything on every machine (round teardown).
    pub fn reset(&mut self) {
        for u in &mut self.used {
            *u = 0;
        }
        self.total = 0;
    }

    pub fn used(&self, machine: usize) -> Words {
        self.used[machine]
    }

    pub fn total(&self) -> Words {
        self.total
    }

    /// Re-target a pooled ledger at a new fleet shape, zeroing all
    /// tallies and peaks while keeping the `used` vector's capacity.
    /// Equivalent to `*self = MemoryLedger::new(..)` without the
    /// allocation — the round arena calls this once per round.
    pub(crate) fn reconfigure(
        &mut self,
        machines: usize,
        local_budget: Words,
        global_budget: Words,
    ) {
        self.local_budget = local_budget;
        self.global_budget = global_budget;
        self.used.clear();
        self.used.resize(machines, 0);
        self.total = 0;
        self.peak_local = 0;
        self.peak_total = 0;
    }

    /// Merge one shard's word tallies at the round barrier.
    ///
    /// Budget enforcement happens *here*, not in the shard: shards charge
    /// without checking (they cannot see the fleet-wide total), and the
    /// first violation found while absorbing — lowest machine id of the
    /// lowest shard — is returned, exactly as sequential charging would
    /// have found it.
    pub fn absorb(&mut self, shard: &ShardLedger) -> Result<(), BudgetError> {
        for (offset, &words) in shard.used.iter().enumerate() {
            if words > 0 {
                self.charge(shard.base + offset, words)?;
            }
        }
        Ok(())
    }
}

/// Unchecked per-shard word tally over a contiguous machine range.
///
/// The sharded executor gives each worker thread one of these; workers
/// charge freely during the round's local-compute half (the wire plane's
/// [`WireOutbox`](crate::mpc::wire::WireOutbox) charges one as messages
/// are appended to its slab), and the round barrier merges every shard
/// into the fleet [`MemoryLedger`] via [`MemoryLedger::absorb`], where
/// budget violations surface with the same semantics as sequential
/// execution.
#[derive(Debug, Clone)]
pub struct ShardLedger {
    base: usize,
    used: Vec<Words>,
}

impl ShardLedger {
    /// Ledger covering machines `range.start..range.end` (global ids).
    pub fn new(range: std::ops::Range<usize>) -> ShardLedger {
        ShardLedger { base: range.start, used: vec![0; range.len()] }
    }

    /// Re-target a pooled ledger at a new machine range, zeroing all
    /// tallies while keeping the `used` vector's capacity. Equivalent to
    /// `*self = ShardLedger::new(range)` without the allocation.
    pub(crate) fn reset(&mut self, range: std::ops::Range<usize>) {
        self.base = range.start;
        self.used.clear();
        self.used.resize(range.len(), 0);
    }

    /// Charge `words` to a machine (global id) owned by this shard.
    pub fn charge(&mut self, machine: usize, words: Words) {
        debug_assert!(
            machine >= self.base && machine < self.base + self.used.len(),
            "machine {machine} outside shard {}..{}",
            self.base,
            self.base + self.used.len()
        );
        self.used[machine - self.base] += words;
    }

    /// First machine id covered by the shard.
    pub fn base(&self) -> usize {
        self.base
    }

    /// Number of machines covered by the shard.
    pub fn machines(&self) -> usize {
        self.used.len()
    }

    /// Words charged to one machine (global id).
    pub fn used(&self, machine: usize) -> Words {
        self.used[machine - self.base]
    }

    /// Total words charged across the shard.
    pub fn total(&self) -> Words {
        self.used.iter().sum()
    }

    /// Largest per-machine tally in the shard.
    pub fn max_local(&self) -> Words {
        self.used.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_and_releases() {
        let mut l = MemoryLedger::new(2, 100, 150);
        l.charge(0, 60).unwrap();
        l.charge(1, 60).unwrap();
        assert_eq!(l.total(), 120);
        l.release(0, 60);
        assert_eq!(l.used(0), 0);
        assert_eq!(l.total(), 60);
        assert_eq!(l.peak_total, 120);
    }

    #[test]
    fn local_violation_detected() {
        let mut l = MemoryLedger::new(1, 10, 1000);
        assert!(l.charge(0, 5).is_ok());
        let err = l.charge(0, 6).unwrap_err();
        assert!(matches!(err, BudgetError::LocalExceeded { used: 11, .. }));
    }

    #[test]
    fn global_violation_detected() {
        let mut l = MemoryLedger::new(3, 100, 150);
        l.charge(0, 80).unwrap();
        let err = l.charge(1, 80).unwrap_err();
        assert!(matches!(err, BudgetError::GlobalExceeded { used: 160, .. }));
    }

    #[test]
    fn reset_clears_usage_keeps_peaks() {
        let mut l = MemoryLedger::new(2, 100, 200);
        l.charge(0, 90).unwrap();
        l.reset();
        assert_eq!(l.total(), 0);
        assert_eq!(l.peak_local, 90);
    }

    #[test]
    fn absorb_merges_shards_like_sequential_charging() {
        let mut fleet = MemoryLedger::new(6, 100, 1000);
        let mut a = ShardLedger::new(0..3);
        let mut b = ShardLedger::new(3..6);
        a.charge(0, 10);
        a.charge(2, 20);
        b.charge(4, 30);
        assert_eq!(a.total(), 30);
        assert_eq!(b.max_local(), 30);
        fleet.absorb(&a).unwrap();
        fleet.absorb(&b).unwrap();
        assert_eq!(fleet.used(0), 10);
        assert_eq!(fleet.used(2), 20);
        assert_eq!(fleet.used(4), 30);
        assert_eq!(fleet.total(), 60);
    }

    #[test]
    fn absorb_surfaces_local_violation_with_machine_id() {
        let mut fleet = MemoryLedger::new(4, 50, 10_000);
        let mut shard = ShardLedger::new(2..4);
        shard.charge(3, 51);
        let err = fleet.absorb(&shard).unwrap_err();
        assert!(
            matches!(err, BudgetError::LocalExceeded { machine: 3, used: 51, budget: 50 }),
            "{err:?}"
        );
    }

    #[test]
    fn absorb_surfaces_global_violation_across_shards() {
        let mut fleet = MemoryLedger::new(4, 100, 150);
        let mut a = ShardLedger::new(0..2);
        let mut b = ShardLedger::new(2..4);
        a.charge(0, 80);
        b.charge(2, 80);
        fleet.absorb(&a).unwrap();
        let err = fleet.absorb(&b).unwrap_err();
        assert!(matches!(err, BudgetError::GlobalExceeded { used: 160, .. }), "{err:?}");
    }
}
