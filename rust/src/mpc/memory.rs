//! Word-based memory accounting for the MPC simulator.
//!
//! The MPC model measures everything in machine words (a vertex id, an
//! edge endpoint pair, a permutation rank are O(1) words each).  Budgets
//! are enforced, not advisory: exceeding a per-machine or global budget is
//! a *model violation* and fails the run — that is how the simulator
//! certifies that an algorithm really fits the regime it claims.

/// Number of machine words.
pub type Words = u64;

/// Outcome of a budget charge.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BudgetError {
    /// A single machine exceeded its local memory S.
    LocalExceeded { machine: usize, used: Words, budget: Words },
    /// Total memory across machines exceeded the global budget.
    GlobalExceeded { used: Words, budget: Words },
}

impl std::fmt::Display for BudgetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BudgetError::LocalExceeded { machine, used, budget } => write!(
                f,
                "MPC model violation: machine {machine} used {used} words (budget S = {budget})"
            ),
            BudgetError::GlobalExceeded { used, budget } => write!(
                f,
                "MPC model violation: global memory {used} words (budget {budget})"
            ),
        }
    }
}

impl std::error::Error for BudgetError {}

/// Tracks per-machine usage against local and global budgets.
#[derive(Debug, Clone)]
pub struct MemoryLedger {
    local_budget: Words,
    global_budget: Words,
    used: Vec<Words>,
    total: Words,
    /// High-water marks for reporting.
    pub peak_local: Words,
    pub peak_total: Words,
}

impl MemoryLedger {
    pub fn new(machines: usize, local_budget: Words, global_budget: Words) -> MemoryLedger {
        MemoryLedger {
            local_budget,
            global_budget,
            used: vec![0; machines],
            total: 0,
            peak_local: 0,
            peak_total: 0,
        }
    }

    pub fn machines(&self) -> usize {
        self.used.len()
    }

    pub fn local_budget(&self) -> Words {
        self.local_budget
    }

    pub fn charge(&mut self, machine: usize, words: Words) -> Result<(), BudgetError> {
        let used = &mut self.used[machine];
        *used += words;
        self.total += words;
        self.peak_local = self.peak_local.max(*used);
        self.peak_total = self.peak_total.max(self.total);
        if *used > self.local_budget {
            return Err(BudgetError::LocalExceeded {
                machine,
                used: *used,
                budget: self.local_budget,
            });
        }
        if self.total > self.global_budget {
            return Err(BudgetError::GlobalExceeded { used: self.total, budget: self.global_budget });
        }
        Ok(())
    }

    pub fn release(&mut self, machine: usize, words: Words) {
        let used = &mut self.used[machine];
        debug_assert!(*used >= words, "releasing more than charged");
        *used = used.saturating_sub(words);
        self.total = self.total.saturating_sub(words);
    }

    /// Release everything on every machine (round teardown).
    pub fn reset(&mut self) {
        for u in &mut self.used {
            *u = 0;
        }
        self.total = 0;
    }

    pub fn used(&self, machine: usize) -> Words {
        self.used[machine]
    }

    pub fn total(&self) -> Words {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_and_releases() {
        let mut l = MemoryLedger::new(2, 100, 150);
        l.charge(0, 60).unwrap();
        l.charge(1, 60).unwrap();
        assert_eq!(l.total(), 120);
        l.release(0, 60);
        assert_eq!(l.used(0), 0);
        assert_eq!(l.total(), 60);
        assert_eq!(l.peak_total, 120);
    }

    #[test]
    fn local_violation_detected() {
        let mut l = MemoryLedger::new(1, 10, 1000);
        assert!(l.charge(0, 5).is_ok());
        let err = l.charge(0, 6).unwrap_err();
        assert!(matches!(err, BudgetError::LocalExceeded { used: 11, .. }));
    }

    #[test]
    fn global_violation_detected() {
        let mut l = MemoryLedger::new(3, 100, 150);
        l.charge(0, 80).unwrap();
        let err = l.charge(1, 80).unwrap_err();
        assert!(matches!(err, BudgetError::GlobalExceeded { used: 160, .. }));
    }

    #[test]
    fn reset_clears_usage_keeps_peaks() {
        let mut l = MemoryLedger::new(2, 100, 200);
        l.charge(0, 90).unwrap();
        l.reset();
        assert_eq!(l.total(), 0);
        assert_eq!(l.peak_local, 90);
    }
}
