//! Broadcast / convergecast trees (paper §2.1.5, Goodrich–Sitchinava–
//! Zhang) executed on the message router.
//!
//! An S-ary virtual tree is laid over the machines; a convergecast
//! aggregates one value per machine to the root in ⌈log_S M⌉ real routed
//! rounds, and a broadcast pushes the result back down in the same number
//! of rounds.  For constant δ this is O(1/δ) = O(1) rounds, which is what
//! lets Corollary 32's "simple algorithm" run in O(1) MPC rounds.
//!
//! Tree values ride the flat-arena plane as typed single-word frames
//! (`u64` via [`crate::mpc::wire::Encode`]): outboxes append into the
//! owning shard's slab and inbox reads decode borrowed slices — no
//! per-message allocation on either side.

use crate::mpc::router::Router;
use crate::mpc::simulator::MpcSimulator;

/// A distributive aggregate function over u64-encoded values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregate {
    Sum,
    Min,
    Max,
}

impl Aggregate {
    /// Identity element (exposed for callers that fold partial streams).
    pub fn identity(&self) -> u64 {
        match self {
            Aggregate::Sum => 0,
            Aggregate::Min => u64::MAX,
            Aggregate::Max => 0,
        }
    }

    fn combine(&self, a: u64, b: u64) -> u64 {
        match self {
            Aggregate::Sum => a + b,
            Aggregate::Min => a.min(b),
            Aggregate::Max => a.max(b),
        }
    }
}

/// S-ary broadcast tree over the machines of a simulator's config.
#[derive(Debug)]
pub struct BroadcastTree {
    machines: usize,
    /// Tree arity: how many children each internal node has.
    arity: usize,
}

impl BroadcastTree {
    /// Arity is capped by S (each parent exchanges O(1) words with each of
    /// its ≤ S children per round).
    pub fn new(machines: usize, s_words: u64) -> BroadcastTree {
        let arity = (s_words.min(machines.max(2) as u64) as usize).max(2);
        BroadcastTree { machines, arity }
    }

    /// Tree depth = number of convergecast rounds.
    pub fn depth(&self) -> usize {
        if self.machines <= 1 {
            return 1;
        }
        let mut depth = 0;
        let mut reach = 1usize;
        while reach < self.machines {
            reach = reach.saturating_mul(self.arity);
            depth += 1;
        }
        depth
    }

    fn parent(&self, m: usize) -> usize {
        (m - 1) / self.arity
    }

    /// Convergecast: aggregate one value per machine to machine 0.
    /// Executes `depth()` routed rounds.
    pub fn aggregate(
        &self,
        sim: &mut MpcSimulator,
        router: &Router,
        values: &[u64],
        f: Aggregate,
    ) -> u64 {
        assert_eq!(values.len(), self.machines);
        if self.machines == 1 {
            sim.round("convergecast[trivial]", 0, 0, 0, 1);
            return values[0];
        }
        // acc[m] = partial aggregate held by machine m. Each machine
        // sends exactly once: when all of its children have reported.
        // Leaves fire in the first round, so the run takes depth() rounds.
        let mut acc: Vec<u64> = values.to_vec();
        let mut pending: Vec<usize> = (0..self.machines)
            .map(|m| {
                (1..=self.arity)
                    .map(|c| m * self.arity + c)
                    .filter(|&child| child < self.machines)
                    .count()
            })
            .collect();
        let mut sent = vec![false; self.machines];
        let mut level = 0usize;
        // One reusable firing buffer for the whole cascade: refilled in
        // place each round, so the per-level loop allocates nothing of
        // its own (the routed rounds underneath run on the pooled arena).
        let mut firing = vec![false; self.machines];
        loop {
            // Which machines fire this round (all children reported, not
            // yet sent). A plain scan: the predicate is a few loads per
            // machine, far below the cost of fanning out to the pool —
            // the sharded work is the outbox construction below.
            for (m, fires) in firing.iter_mut().enumerate() {
                *fires = m > 0 && !sent[m] && pending[m] == 0;
            }
            if !firing.iter().any(|&fires| fires) {
                break;
            }
            let inboxes = router.round(sim, &format!("convergecast[{level}]"), |m, out| {
                if firing[m] {
                    out.send(self.parent(m), &acc[m]);
                }
            });
            for (m, &fires) in firing.iter().enumerate() {
                if fires {
                    sent[m] = true;
                }
            }
            for m in 0..self.machines {
                for msg in inboxes.inbox(m).iter() {
                    acc[m] = f.combine(acc[m], msg.decode::<u64>());
                    pending[m] -= 1;
                }
            }
            level += 1;
            assert!(level <= self.depth() + 1, "convergecast failed to converge");
        }
        assert_eq!(pending[0], 0, "root did not hear from all children");
        acc[0]
    }

    /// Broadcast a value from machine 0 to all machines.
    pub fn broadcast(&self, sim: &mut MpcSimulator, router: &Router, value: u64) -> Vec<u64> {
        if self.machines == 1 {
            sim.round("broadcast[trivial]", 0, 0, 0, 1);
            return vec![value];
        }
        let mut have: Vec<Option<u64>> = vec![None; self.machines];
        have[0] = Some(value);
        let mut level = 0usize;
        while have.iter().any(Option::is_none) {
            // Each holder sends to children that don't have the value yet;
            // outboxes are built on the shard owning the sender.
            let inboxes = router.round(sim, &format!("broadcast[{level}]"), |m, out| {
                let Some(v) = have[m] else { return };
                for child in (1..=self.arity)
                    .map(|c| m * self.arity + c)
                    .filter(|&child| child < self.machines && have[child].is_none())
                {
                    out.send(child, &v);
                }
            });
            for (m, slot) in have.iter_mut().enumerate() {
                if let Some(msg) = inboxes.inbox(m).first() {
                    *slot = Some(msg.decode::<u64>());
                }
            }
            level += 1;
            assert!(level <= self.depth() + 1, "broadcast failed to converge");
        }
        have.into_iter().map(|v| v.unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::model::MpcConfig;

    fn setup(machines: usize, arity_s: u64) -> (MpcSimulator, Router, BroadcastTree) {
        let mut cfg = MpcConfig::model1(100_000, 1_000_000, 0.5);
        cfg.machines = machines;
        let sim = MpcSimulator::new(cfg);
        (sim, Router::new(machines), BroadcastTree::new(machines, arity_s))
    }

    #[test]
    fn aggregate_sum_min_max() {
        let (mut sim, router, tree) = setup(10, 3);
        let values: Vec<u64> = (1..=10).collect();
        assert_eq!(tree.aggregate(&mut sim, &router, &values, Aggregate::Sum), 55);
        assert_eq!(tree.aggregate(&mut sim, &router, &values, Aggregate::Min), 1);
        assert_eq!(tree.aggregate(&mut sim, &router, &values, Aggregate::Max), 10);
    }

    #[test]
    fn broadcast_reaches_all() {
        let (mut sim, router, tree) = setup(17, 4);
        let got = tree.broadcast(&mut sim, &router, 99);
        assert_eq!(got, vec![99; 17]);
    }

    #[test]
    fn depth_is_logarithmic() {
        let tree = BroadcastTree::new(1_000_000, 100);
        assert_eq!(tree.depth(), 3); // 100^3 = 10^6
        let wide = BroadcastTree::new(1000, 1_000_000);
        assert_eq!(wide.depth(), 1);
    }

    #[test]
    fn rounds_charged_at_most_depth_plus_slack() {
        let (mut sim, router, tree) = setup(64, 4);
        let values = vec![1u64; 64];
        tree.aggregate(&mut sim, &router, &values, Aggregate::Sum);
        assert!(sim.n_rounds() <= tree.depth());
        let before = sim.n_rounds();
        tree.broadcast(&mut sim, &router, 5);
        assert!(sim.n_rounds() - before <= tree.depth() + 1);
    }

    #[test]
    fn single_machine_trivial() {
        let (mut sim, router, tree) = setup(1, 4);
        assert_eq!(tree.aggregate(&mut sim, &router, &[7], Aggregate::Sum), 7);
        assert_eq!(tree.broadcast(&mut sim, &router, 3), vec![3]);
    }

    #[test]
    fn sharded_tree_matches_serial_tree() {
        let machines = 23;
        let values: Vec<u64> = (0..machines as u64).map(|v| v * 3 + 1).collect();
        let run = |shards: usize| {
            let mut cfg = MpcConfig::model1(100_000, 1_000_000, 0.5);
            cfg.machines = machines;
            let mut sim = MpcSimulator::sharded(cfg, shards);
            let router = Router::new(machines);
            let tree = BroadcastTree::new(machines, 3);
            let agg = tree.aggregate(&mut sim, &router, &values, Aggregate::Max);
            let bcast = tree.broadcast(&mut sim, &router, agg);
            let trace: Vec<_> = sim
                .trace()
                .iter()
                .map(|r| (r.label.clone(), r.max_out, r.max_in, r.total, r.max_state))
                .collect();
            (agg, bcast, trace)
        };
        let serial = run(1);
        for shards in [2usize, 8] {
            assert_eq!(run(shards), serial, "{shards} shards");
        }
    }
}
