//! Randomized greedy MIS — the sequential ground truth every MPC variant
//! must reproduce exactly, plus Fischer–Noever instrumentation.
//!
//! Given an ordering π (a permutation: position → vertex), **greedy MIS**
//! iterates π(1), ..., π(n) and adds a vertex iff no earlier neighbor was
//! added.  PIVOT is greedy MIS plus a cluster-join step, so the paper's
//! correctness story reduces to: *the MPC algorithms compute exactly this
//! set for the same π* (Algorithms 1–3 are simulations, not
//! approximations).
//!
//! Instrumentation for the paper's round-complexity claims:
//! * [`parallel_greedy_rounds`] — iterations of the parallel fixpoint
//!   ("all π-local minima join"), the quantity Blelloch–Fineman–Shun and
//!   Fischer–Noever bound (Theorem 5: O(log n) w.h.p.);
//! * [`longest_dependency_path`] — the longest π-decreasing *query chain*,
//!   Fischer–Noever's dependency-length measure.

use crate::graph::Graph;

/// Ranks: `rank[v]` = position of vertex v in π (smaller = earlier).
pub fn ranks_from_permutation(perm: &[u32]) -> Vec<u32> {
    let mut rank = vec![0u32; perm.len()];
    for (pos, &v) in perm.iter().enumerate() {
        rank[v as usize] = pos as u32;
    }
    rank
}

/// Sequential greedy MIS with respect to π. Returns `in_mis[v]`.
pub fn greedy_mis(g: &Graph, perm: &[u32]) -> Vec<bool> {
    assert_eq!(perm.len(), g.n());
    let mut in_mis = vec![false; g.n()];
    let mut blocked = vec![false; g.n()];
    for &v in perm {
        if !blocked[v as usize] {
            in_mis[v as usize] = true;
            for &u in g.neighbors(v) {
                blocked[u as usize] = true;
            }
        }
    }
    in_mis
}

/// Greedy MIS restricted to a subset of vertices (used by prefix/chunk
/// processing): `order` lists the subset in π order; `blocked` carries
/// decisions from earlier prefixes and is updated in place.
pub fn greedy_mis_on_subset(g: &Graph, order: &[u32], blocked: &mut [bool], in_mis: &mut [bool]) {
    for &v in order {
        if !blocked[v as usize] {
            in_mis[v as usize] = true;
            for &u in g.neighbors(v) {
                blocked[u as usize] = true;
            }
        }
    }
}

/// Iterations of the *parallel* greedy-MIS fixpoint: in each iteration all
/// undecided vertices that are π-minimal in their undecided neighborhood
/// join the MIS and knock out their neighbors. The fixpoint computes
/// exactly the sequential greedy MIS; the iteration count is the paper's
/// "direct simulation" round cost (O(log n) w.h.p. by Fischer–Noever).
pub fn parallel_greedy_rounds(g: &Graph, perm: &[u32]) -> (Vec<bool>, usize) {
    let rank = ranks_from_permutation(perm);
    let n = g.n();
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Undecided,
        In,
        Out,
    }
    let mut st = vec![St::Undecided; n];
    let mut undecided = n;
    let mut iters = 0usize;
    while undecided > 0 {
        iters += 1;
        // Local minima among undecided.
        let mut joiners: Vec<u32> = Vec::new();
        for v in 0..n as u32 {
            if st[v as usize] != St::Undecided {
                continue;
            }
            let is_min = g
                .neighbors(v)
                .iter()
                .all(|&u| st[u as usize] != St::Undecided || rank[u as usize] > rank[v as usize]);
            if is_min {
                joiners.push(v);
            }
        }
        debug_assert!(!joiners.is_empty(), "fixpoint stalled");
        for &v in &joiners {
            st[v as usize] = St::In;
            undecided -= 1;
        }
        for &v in &joiners {
            for &u in g.neighbors(v) {
                if st[u as usize] == St::Undecided {
                    st[u as usize] = St::Out;
                    undecided -= 1;
                }
            }
        }
    }
    (st.iter().map(|&s| s == St::In).collect(), iters)
}

/// Fischer–Noever dependency length: the longest chain
/// v_1 → v_2 → ... → v_k along edges with strictly decreasing rank such
/// that each v_{i+1} was still *undecided* when v_i queried it in the
/// lazy greedy evaluation. We measure the standard conservative variant:
/// longest strictly-π-decreasing path restricted to edges (v, u) where u
/// is either in the MIS or blocked by a vertex of smaller rank than v
/// (i.e. edges the lazy evaluation actually traverses).
pub fn longest_dependency_path(g: &Graph, perm: &[u32]) -> usize {
    let rank = ranks_from_permutation(perm);
    let in_mis = greedy_mis(g, perm);
    let n = g.n();
    // depth[v] = longest dependency chain ending at v. Process in π order
    // (all π-smaller endpoints are final when v is processed).
    let mut depth = vec![0u32; n];
    let mut best = 0usize;
    for &v in perm {
        let mut d = 1u32;
        for &u in g.neighbors(v) {
            if rank[u as usize] < rank[v as usize] {
                // The lazy evaluation of v queries u's status; the chain
                // extends through u only if u's own status required
                // evaluation (always true transitively) — standard
                // conservative bound: take max over all smaller-rank
                // neighbors that are MIS members or whose blocking
                // happened before v's query.
                let relevant = in_mis[u as usize] || depth[u as usize] > 0;
                if relevant {
                    d = d.max(depth[u as usize] + 1);
                }
            }
        }
        depth[v as usize] = d;
        best = best.max(d as usize);
    }
    best
}

/// Check the MIS property (independent + maximal) — used by tests and the
/// property harness.
pub fn is_valid_mis(g: &Graph, in_mis: &[bool]) -> bool {
    for v in 0..g.n() as u32 {
        if in_mis[v as usize] {
            if g.neighbors(v).iter().any(|&u| in_mis[u as usize]) {
                return false; // not independent
            }
        } else if !g.neighbors(v).iter().any(|&u| in_mis[u as usize]) {
            return false; // not maximal
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{lambda_arboric, path, star};
    use crate::util::rng::Rng;

    #[test]
    fn greedy_mis_is_valid() {
        let mut rng = Rng::new(60);
        for lambda in [1usize, 2, 4] {
            let g = lambda_arboric(200, lambda, &mut rng);
            let perm = rng.permutation(200);
            let mis = greedy_mis(&g, &perm);
            assert!(is_valid_mis(&g, &mis));
        }
    }

    #[test]
    fn greedy_mis_respects_order() {
        // On a path 0-1-2, order [1,0,2] puts 1 in the MIS, blocks 0 and 2.
        let g = path(3);
        let mis = greedy_mis(&g, &[1, 0, 2]);
        assert_eq!(mis, vec![false, true, false]);
        // Order [0,1,2]: 0 joins, 1 blocked, 2 joins.
        let mis = greedy_mis(&g, &[0, 1, 2]);
        assert_eq!(mis, vec![true, false, true]);
    }

    #[test]
    fn parallel_fixpoint_equals_sequential() {
        let mut rng = Rng::new(61);
        for trial in 0..10 {
            let g = lambda_arboric(100, 1 + trial % 3, &mut rng);
            let perm = rng.permutation(100);
            let seq = greedy_mis(&g, &perm);
            let (par, iters) = parallel_greedy_rounds(&g, &perm);
            assert_eq!(seq, par, "trial {trial}");
            assert!(iters >= 1);
        }
    }

    #[test]
    fn star_center_first_takes_one_round() {
        let g = star(10);
        let mut perm = vec![0u32];
        perm.extend(1..=10u32);
        let (mis, iters) = parallel_greedy_rounds(&g, &perm);
        assert!(mis[0]);
        assert_eq!(iters, 1);
    }

    #[test]
    fn path_order_extremes() {
        // Monotone rank along a path cascades: only the first endpoint is
        // a local min each round ⇒ n/2 rounds (the worst case that makes
        // Fischer–Noever's O(log n) for *random* π non-trivial).
        let n = 20;
        let g = path(n);
        let perm: Vec<u32> = (0..n as u32).collect();
        let (_, iters) = parallel_greedy_rounds(&g, &perm);
        assert_eq!(iters, n / 2, "monotone order is linear-depth");
        // Alternating order resolves in one round: all even vertices are
        // simultaneous local minima.
        let mut alt: Vec<u32> = (0..n as u32).step_by(2).collect();
        alt.extend((1..n as u32).step_by(2));
        let (_, iters_alt) = parallel_greedy_rounds(&g, &alt);
        assert_eq!(iters_alt, 1, "alternating order is depth 1");
    }

    #[test]
    fn dependency_path_bounded_by_n() {
        let mut rng = Rng::new(62);
        let g = lambda_arboric(300, 2, &mut rng);
        let perm = rng.permutation(300);
        let d = longest_dependency_path(&g, &perm);
        assert!(d >= 1 && d <= 300);
    }

    #[test]
    fn subset_greedy_matches_full_run_split() {
        // Processing π in two prefixes must equal the one-shot run.
        let mut rng = Rng::new(63);
        let g = lambda_arboric(80, 2, &mut rng);
        let perm = rng.permutation(80);
        let full = greedy_mis(&g, &perm);

        let mut blocked = vec![false; 80];
        let mut in_mis = vec![false; 80];
        let (first, second) = perm.split_at(30);
        greedy_mis_on_subset(&g, first, &mut blocked, &mut in_mis);
        greedy_mis_on_subset(&g, second, &mut blocked, &mut in_mis);
        let got: Vec<bool> = in_mis;
        assert_eq!(got, full);
    }

    #[test]
    fn ranks_invert_permutation() {
        let perm = vec![2u32, 0, 3, 1];
        let rank = ranks_from_permutation(&perm);
        assert_eq!(rank, vec![1, 3, 0, 2]);
    }
}
