//! Forest specialization glue (Lemma 29 / Corollaries 27 & 31):
//! matchings become clusterings, with the paper's cost identity
//! `cost = (#non-isolated-structure pairs) − |M|` made checkable.
//!
//! Clustering rule: each matched pair is a 2-cluster; every unmatched
//! vertex is a singleton.  On a forest, Corollary 27 says a *maximum*
//! matching yields an optimum clustering, and Lemma 29 transfers an
//! α-approximate matching into an α-approximate clustering.

use crate::algorithms::matching::Matching;
use crate::cluster::Clustering;

/// Build the clustering induced by a matching.
pub fn clustering_from_matching(n: usize, m: &Matching) -> Clustering {
    let mut c = Clustering::singletons(n);
    for &(u, v) in m {
        let label = c.label(u);
        c.set_label(v, label);
    }
    c
}

/// The paper's closed form for matching-based clustering cost on a forest
/// with `edges` positive edges: every positive edge not inside a matched
/// pair disagrees, negatives never do (clusters have ≤ 2 members joined
/// by a positive edge): `cost = m − |M|`.
pub fn matching_clustering_cost(edges: usize, matching_size: usize) -> u64 {
    (edges - matching_size) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::matching::maximum::maximum_matching_forest;
    use crate::cluster::cost::cost;
    use crate::cluster::exact::exact_cost;
    use crate::graph::generators::{path, random_forest, star};
    use crate::util::rng::Rng;

    #[test]
    fn cost_closed_form_matches() {
        let mut rng = Rng::new(160);
        for trial in 0..10 {
            let g = random_forest(60, 0.85, &mut rng);
            let m = maximum_matching_forest(&g);
            let c = clustering_from_matching(g.n(), &m);
            assert_eq!(
                cost(&g, &c).total(),
                matching_clustering_cost(g.m(), m.len()),
                "trial {trial}"
            );
        }
    }

    #[test]
    fn corollary_27_maximum_matching_is_optimal() {
        // On forests small enough for the exact solver, the maximum-
        // matching clustering cost equals OPT.
        let mut rng = Rng::new(161);
        for trial in 0..15 {
            let g = random_forest(12, 0.8, &mut rng);
            let m = maximum_matching_forest(&g);
            let c = clustering_from_matching(g.n(), &m);
            assert_eq!(cost(&g, &c).total(), exact_cost(&g), "trial {trial}");
        }
    }

    #[test]
    fn star_and_path_forms() {
        let g = star(5);
        let m = maximum_matching_forest(&g);
        let c = clustering_from_matching(g.n(), &m);
        assert_eq!(cost(&g, &c).total(), 4); // k - 1

        let p = path(4);
        let mp = maximum_matching_forest(&p);
        let cp = clustering_from_matching(p.n(), &mp);
        assert_eq!(cost(&p, &cp).total(), 1);
    }

    #[test]
    fn lemma_29_alpha_transfer() {
        // If α|M| ≥ |M*| then matching-clustering cost ≤ α · OPT.
        let mut rng = Rng::new(162);
        for trial in 0..10 {
            let g = random_forest(80, 0.9, &mut rng);
            let mstar = maximum_matching_forest(&g);
            if mstar.is_empty() {
                continue;
            }
            // Use half the maximum matching as an artificial 2-approx.
            let half: Matching = mstar.iter().copied().step_by(2).collect();
            let alpha = mstar.len() as f64 / half.len() as f64;
            let opt_cost = matching_clustering_cost(g.m(), mstar.len());
            let half_cost = matching_clustering_cost(g.m(), half.len());
            if opt_cost == 0 {
                continue;
            }
            assert!(
                half_cost as f64 <= alpha * opt_cost as f64 + 1e-9,
                "trial {trial}: {half_cost} > {alpha} × {opt_cost}"
            );
        }
    }
}
