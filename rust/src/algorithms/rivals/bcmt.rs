//! Behnezhad–Charikar–Ma–Tan constant-round almost-3-approximation
//! (arxiv 2205.03710), as a threshold schedule for the shared
//! [`pivot_phase_engine`].
//!
//! BCMT's insight (their Theorem 1) is that *truncated* parallel
//! pivoting — run the local-minimum peeling for only R = ⌈4/ε⌉ phases
//! over the **whole** vertex set and declare every survivor a singleton
//! — is already a (3+ε)-approximation. The coupling argument (their
//! Lemma 3.1 / randomized greedy MIS round-compression) shows the
//! vertices still unclustered after R whole-graph peeling phases
//! account for at most an ε fraction of the sequential PIVOT cost in
//! expectation, so truncation is charged to the ε slack rather than to
//! correctness.
//!
//! Against [`super::cal`] the trade is phases-for-eligibility: BCMT
//! runs a *fixed* ⌈4/ε⌉ phases with every unclustered vertex eligible
//! (thresholds all `n`), where CAL runs a prefix schedule that admits
//! few vertices early. Same engine, same two routed rounds per phase,
//! same Θ(m)-word announce ceiling — which is exactly what the
//! head-to-head bench family measures against the source paper's
//! O(log λ · poly(log log n)) schedule.

use crate::graph::Graph;
use crate::mpc::simulator::MpcSimulator;

use super::{pivot_phase_engine, rival_eps, RivalRun};

/// Tuning for [`bcmt_pivot`]. ε sets the truncation depth R = ⌈4/ε⌉
/// (their Theorem 1); smaller ε runs more peeling phases and leaves
/// fewer forced singletons.
#[derive(Debug, Clone, Copy)]
pub struct BcmtParams {
    pub eps: f64,
}

impl Default for BcmtParams {
    fn default() -> BcmtParams {
        BcmtParams { eps: super::RIVAL_DEFAULT_EPS }
    }
}

/// The truncated whole-graph peeling schedule: R = ⌈4/ε⌉ phases, every
/// unclustered vertex eligible in each (threshold `n` throughout).
pub fn bcmt_thresholds(n: usize, eps: f64) -> Vec<u32> {
    let eps = rival_eps(eps);
    if n == 0 {
        return Vec::new();
    }
    let r = (4.0 / eps).ceil() as usize;
    vec![u32::try_from(n).expect("vertex counts fit u32"); r.max(1)]
}

/// Run BCMT truncated parallel pivoting over a pre-sampled rank order
/// (`rank` must be a permutation of `0..n`). Charges 2 routed rounds
/// per executed phase to `sim`; early-exits when the graph clusters
/// before the truncation depth.
pub fn bcmt_pivot(
    g: &Graph,
    rank: &[u32],
    params: &BcmtParams,
    sim: &mut MpcSimulator,
) -> RivalRun {
    let thresholds = bcmt_thresholds(g.n(), params.eps);
    pivot_phase_engine(g, rank, &thresholds, "bcmt", sim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy_mis::ranks_from_permutation;
    use crate::algorithms::rivals::rival_input_words;
    use crate::graph::generators::{path, star};
    use crate::mpc::MpcConfig;
    use crate::util::rng::Rng;

    #[test]
    fn truncation_depth_is_ceil_4_over_eps() {
        assert_eq!(bcmt_thresholds(10, 0.25), vec![10u32; 16]);
        assert_eq!(bcmt_thresholds(10, 0.5).len(), 8);
        assert_eq!(bcmt_thresholds(10, 0.9).len(), 5);
        // Engine-default ε = 2.0 falls back to the rival default 0.25.
        assert_eq!(bcmt_thresholds(10, 2.0).len(), 16);
        assert!(bcmt_thresholds(0, 0.25).is_empty());
    }

    #[test]
    fn path8_identity_rank_peels_in_four_phases() {
        // Hand-derived companion to the tests/round_counts.rs pin: with
        // identity ranks the path's only local minimum each phase is its
        // smallest unclustered vertex, so phases peel {0},{2},{4},{6}
        // and the early exit fires before phase 5.
        let g = path(8);
        let rank: Vec<u32> = (0..8).collect();
        let mut sim =
            MpcSimulator::new(MpcConfig::model1(g.n(), rival_input_words(&g), 0.5));
        let run = bcmt_pivot(&g, &rank, &BcmtParams::default(), &mut sim);
        assert_eq!(run.phases, 4);
        assert_eq!(sim.n_rounds(), 8);
        assert_eq!(run.clustering.labels(), &[0, 0, 2, 2, 4, 4, 6, 6]);
    }

    #[test]
    fn star_clusters_whole_in_one_or_two_phases() {
        // On star:k=9 a single phase suffices when the center has the
        // minimum rank; with identity ranks vertex 0 is the center and
        // everything joins it in phase 1.
        let g = star(9);
        let rank: Vec<u32> = (0..g.n() as u32).collect();
        let mut sim =
            MpcSimulator::new(MpcConfig::model1(g.n(), rival_input_words(&g), 0.5));
        let run = bcmt_pivot(&g, &rank, &BcmtParams::default(), &mut sim);
        assert_eq!(run.phases, 1);
        assert_eq!(run.clustering.n_clusters(), 1);
    }

    #[test]
    fn shard_invariant_on_random_orders() {
        let g = crate::graph::generators::lambda_arboric(90, 3, &mut Rng::new(6));
        let rank = ranks_from_permutation(&Rng::new(23).permutation(g.n()));
        let mut run = |shards: usize| {
            let cfg = MpcConfig::model1(g.n(), rival_input_words(&g), 0.5);
            let mut sim = if shards == 1 {
                MpcSimulator::new(cfg)
            } else {
                MpcSimulator::sharded(cfg, shards)
            };
            bcmt_pivot(&g, &rank, &BcmtParams::default(), &mut sim).clustering
        };
        let base = run(1);
        assert_eq!(base.labels(), run(2).labels());
        assert_eq!(base.labels(), run(8).labels());
    }
}
