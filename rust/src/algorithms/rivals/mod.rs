//! Constant-round rival solvers: the head-to-head competitors to the
//! source paper, run on the same simulator, ledger and message plane.
//!
//! The source result (Theorem 26: 3-approximation in
//! O(log λ · poly(log log n)) rounds) has two direct constant-round
//! competitors, both implemented here as first-class algorithms over
//! [`Router::round`] so their round counts and message words are
//! *measured* on the identical accounting as Algorithms 1–4:
//!
//! | Rival | Module | Schedule |
//! |---|---|---|
//! | Cohen-Addad–Lattanzi et al., parallel PIVOT (arxiv 2106.08448) | [`cal`] | O(1/ε) phases over a geometric prefix of a pre-sampled order |
//! | Behnezhad–Charikar–Ma–Tan, almost-3-approx (arxiv 2205.03710) | [`bcmt`] | ⌈4/ε⌉ truncated whole-graph peeling phases |
//!
//! Both reduce to the same two-round phase primitive, implemented once
//! in [`pivot_phase_engine`]:
//!
//! 1. **announce** — every *eligible* unclustered vertex v (rank below
//!    the phase threshold) ships a packed [`RankAnnounce`] word to each
//!    unclustered neighbor; receivers fold the per-vertex minimum rank.
//!    v elects itself pivot iff its rank beats every announcement it
//!    received — the local-minimum rule, which on distinct ranks yields
//!    an independent set (two adjacent eligible vertices both see each
//!    other's rank, and only the smaller survives).
//! 2. **claim** — each new pivot ships a [`PivotClaim`] (claimed vertex,
//!    pivot id, pivot rank) to each unclustered neighbor; receivers join
//!    the minimum-rank claimer, pivots label themselves.
//!
//! The rivals differ only in the eligibility-threshold schedule they
//! feed the engine (`cal`: geometric prefixes T₁ = ⌈εn⌉,
//! T_{i+1} = ⌈T_i(1+ε)⌉ capped at n; `bcmt`: everything eligible for
//! ⌈4/ε⌉ phases). Vertices still unclustered when the schedule runs out
//! become singletons communication-free — the truncation both papers'
//! analyses charge to the ε slack in their approximation factors.
//!
//! Vertex ownership is round-robin (`v mod machines`), all per-phase
//! state lives in vertex-indexed scratch vectors (no hash containers:
//! the engine sits in the audit's deterministic class), and every
//! message moves through the flat-arena plane, so schedules are
//! shard-invariant and pinned by `tests/round_counts.rs` exactly like
//! Algorithms 1–3. The engine picks the narrow `u32` wire plane whenever
//! ids fit (ledger charges are width-invariant, so the pinned schedules
//! don't move) and ships each vertex's fan-out through the outbox's bulk
//! `append_runs` path — one ledger charge per announcing vertex instead
//! of one per edge, with a byte-identical frame stream.
//!
//! [`Router::round`]: crate::mpc::router::Router::round
//! [`RankAnnounce`]: crate::mpc::wire::RankAnnounce
//! [`PivotClaim`]: crate::mpc::wire::PivotClaim

pub mod bcmt;
pub mod cal;

pub use bcmt::{bcmt_pivot, BcmtParams};
pub use cal::{cal_pivot, CalParams};

use crate::cluster::Clustering;
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::router::Router;
use crate::mpc::simulator::MpcSimulator;
use crate::mpc::wire::{PivotClaim, RankAnnounce, WordWidth};

/// Label value for a vertex no phase has clustered yet.
const UNCLUSTERED: u32 = u32::MAX;

/// The rivals' sampling/truncation parameter when the request's ε is not
/// usable. The engine-wide `SolveRequest::eps` defaults to 2.0 (the
/// Algorithm 4 degree-threshold convention), but both rival schedules
/// need ε ∈ (0, 1) — ⌈4/ε⌉ phases / ⌈εn⌉ prefixes are meaningless at
/// ε = 2 — so out-of-range values fall back to this default.
pub const RIVAL_DEFAULT_EPS: f64 = 0.25;

/// Clamp a request ε into the rivals' usable range: itself when in
/// (0, 1), otherwise [`RIVAL_DEFAULT_EPS`].
pub fn rival_eps(eps: f64) -> f64 {
    if eps > 0.0 && eps < 1.0 {
        eps
    } else {
        RIVAL_DEFAULT_EPS
    }
}

/// MPC input sizing for the rival fleets: `(n + 4m).max(4)` words.
///
/// The engine-wide default (`solve::simulator_for`) provisions for
/// `n + 2m` input words, but a rival announce round peaks at ~2 words
/// per **directed** edge (a packed word plus the envelope, both
/// directions at once on a fully-unclustered graph) — up to `4m` fleet
/// words in one round. Provisioning the fleet for that peak keeps the
/// strict simulator's O(S) checks meaningful (they still fire on genuine
/// per-machine hot spots, e.g. a vertex of degree > S/2) without
/// tripping on the algorithm's by-design whole-graph first phase.
pub fn rival_input_words(g: &Graph) -> Words {
    (g.n() + 4 * g.m()).max(4) as Words
}

/// What a rival run hands back: the clustering plus phase/round
/// observability (rounds are also on the simulator's trace/ledger).
#[derive(Debug, Clone)]
pub struct RivalRun {
    pub clustering: Clustering,
    /// Phases actually executed (early exit when everything clusters).
    pub phases: usize,
    /// Communication rounds charged: 2 per executed phase.
    pub rounds: usize,
}

/// Run the shared two-round pivot phase engine over an
/// eligibility-threshold schedule.
///
/// Phase `i` (1-based) lets exactly the unclustered vertices with
/// `rank[v] < thresholds[i-1]` compete for pivothood; the schedule length
/// bounds the round count at `2 · thresholds.len()`. Ranks must be a
/// permutation of `0..n` (distinct — the independence of the pivot set
/// relies on it), as produced by
/// [`crate::algorithms::greedy_mis::ranks_from_permutation`].
///
/// Runs `2·phases` routed rounds labelled `{label}/announce[i]` and
/// `{label}/claim[i]`; breaks out early only when no unclustered vertex
/// remains (a fleet-visible condition: the fixed schedule is what makes
/// the rivals constant-round, so empty *eligible* sets still run their
/// two rounds — machines cannot know the phase is silent without
/// communicating).
pub fn pivot_phase_engine(
    g: &Graph,
    rank: &[u32],
    thresholds: &[u32],
    label: &str,
    sim: &mut MpcSimulator,
) -> RivalRun {
    let machines = sim.config.machines.max(1);
    pivot_phase_engine_on(g, rank, thresholds, label, sim, WordWidth::for_ids(g.n(), machines))
}

/// [`pivot_phase_engine`] at a forced wire width. The default entry point
/// selects the narrow `u32` plane whenever ids fit (always, for `u32`
/// vertex ids on realistic fleets); parity tests force both widths and
/// pin that traces, ledgers and clusterings are bit-identical.
pub fn pivot_phase_engine_on(
    g: &Graph,
    rank: &[u32],
    thresholds: &[u32],
    label: &str,
    sim: &mut MpcSimulator,
    width: WordWidth,
) -> RivalRun {
    let n = g.n();
    assert_eq!(rank.len(), n, "rank must cover every vertex");
    let machines = sim.config.machines.max(1);
    let router = Router::with_width(machines, width);

    let mut labels = vec![UNCLUSTERED; n];
    // Vertex-indexed per-phase scratch (reset per phase, no hash maps).
    let mut min_seen = vec![u32::MAX; n];
    let mut is_pivot = vec![false; n];
    let mut claim_rank = vec![u32::MAX; n];
    let mut claim_pivot = vec![0u32; n];
    let mut active = n;
    let mut phases = 0usize;

    for (i, &t) in thresholds.iter().enumerate() {
        if active == 0 {
            break;
        }
        phases += 1;
        let p = i + 1;

        // Round 1: eligible unclustered vertices announce their rank to
        // every unclustered neighbor (the prefix subgraph's edges). Each
        // vertex's fan-out goes through the bulk `append_runs` path: one
        // ledger charge and one destination check per run instead of per
        // edge, with a frame stream identical to per-message sends.
        let announces = router.round(sim, &format!("{label}/announce[{p}]"), |m, out| {
            for v in (m..n).step_by(machines) {
                if labels[v] != UNCLUSTERED || rank[v] >= t {
                    continue;
                }
                out.append_runs(
                    g.neighbors(v as u32)
                        .iter()
                        .filter(|&&u| labels[u as usize] == UNCLUSTERED)
                        .map(|&u| {
                            (u as usize % machines, RankAnnounce { vertex: u, rank: rank[v] })
                        }),
                );
            }
        });
        for m in 0..machines {
            for msg in announces.inbox(m) {
                let a: RankAnnounce = msg.decode();
                let u = a.vertex as usize;
                min_seen[u] = min_seen[u].min(a.rank);
            }
        }
        // Local-minimum pivot rule: an eligible vertex whose rank beats
        // every announcement it received (none ⇒ isolated in the prefix
        // subgraph ⇒ pivot). Distinct ranks make the pivot set
        // independent: adjacent eligible vertices saw each other.
        for v in 0..n {
            is_pivot[v] = labels[v] == UNCLUSTERED && rank[v] < t && rank[v] < min_seen[v];
        }

        // Round 2: new pivots claim their unclustered neighbors.
        let claims = router.round(sim, &format!("{label}/claim[{p}]"), |m, out| {
            for v in (m..n).step_by(machines) {
                if !is_pivot[v] {
                    continue;
                }
                out.append_runs(
                    g.neighbors(v as u32)
                        .iter()
                        .filter(|&&u| labels[u as usize] == UNCLUSTERED)
                        .map(|&u| {
                            (
                                u as usize % machines,
                                PivotClaim { vertex: u, pivot: v as u32, rank: rank[v] },
                            )
                        }),
                );
            }
        });
        for v in 0..n {
            if is_pivot[v] {
                labels[v] = v as u32;
                active -= 1;
            }
        }
        for m in 0..machines {
            for msg in claims.inbox(m) {
                let c: PivotClaim = msg.decode();
                let u = c.vertex as usize;
                // Adopt the minimum-rank claimer; the pivot set is
                // independent, so a claimed vertex is never itself a
                // pivot and the `labels` guard below stays true.
                if labels[u] == UNCLUSTERED && c.rank < claim_rank[u] {
                    claim_rank[u] = c.rank;
                    claim_pivot[u] = c.pivot;
                }
            }
        }
        for u in 0..n {
            if claim_rank[u] != u32::MAX {
                debug_assert_eq!(labels[u], UNCLUSTERED);
                labels[u] = claim_pivot[u];
                active -= 1;
            }
            // Reset the scratch for the next phase.
            claim_rank[u] = u32::MAX;
            min_seen[u] = u32::MAX;
            is_pivot[u] = false;
        }
    }

    // Truncation: whatever the schedule left unclustered becomes a
    // singleton, communication-free (both papers charge this to ε).
    for v in 0..n {
        if labels[v] == UNCLUSTERED {
            labels[v] = v as u32;
        }
    }

    RivalRun { clustering: Clustering::from_labels(labels), phases, rounds: 2 * phases }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy_mis::ranks_from_permutation;
    use crate::graph::generators::{clique, disjoint_cliques, path};
    use crate::mpc::MpcConfig;
    use crate::util::rng::Rng;

    fn sim_for(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(g.n().max(2), rival_input_words(g), 0.5))
    }

    fn identity_rank(n: usize) -> Vec<u32> {
        (0..n as u32).collect()
    }

    #[test]
    fn eps_clamp() {
        assert_eq!(rival_eps(0.1), 0.1);
        assert_eq!(rival_eps(2.0), RIVAL_DEFAULT_EPS);
        assert_eq!(rival_eps(0.0), RIVAL_DEFAULT_EPS);
        assert_eq!(rival_eps(-1.0), RIVAL_DEFAULT_EPS);
        assert_eq!(rival_eps(1.0), RIVAL_DEFAULT_EPS);
    }

    #[test]
    fn full_threshold_engine_is_sequential_local_minimum_peeling() {
        // thresholds = [n; k]: every phase peels the local minima of the
        // unclustered subgraph. On path:n=8 with identity ranks phase 1
        // elects pivot 0 (the only local minimum), clustering {0,1}; then
        // 2, then 4, then 6.
        let g = path(8);
        let rank = identity_rank(8);
        let mut sim = sim_for(&g);
        let run = pivot_phase_engine(&g, &rank, &[8, 8, 8, 8, 8], "t", &mut sim);
        assert_eq!(run.phases, 4, "active hits zero after phase 4");
        assert_eq!(run.rounds, 8);
        assert_eq!(run.clustering.labels(), &[0, 0, 2, 2, 4, 4, 6, 6]);
    }

    #[test]
    fn engine_exits_early_when_everything_clusters() {
        // One phase consumes a clique entirely: pivot = min rank vertex,
        // everyone else joins it. Remaining schedule entries never run.
        let g = clique(6);
        let rank = identity_rank(6);
        let mut sim = sim_for(&g);
        let run = pivot_phase_engine(&g, &rank, &[6, 6, 6, 6], "t", &mut sim);
        assert_eq!(run.phases, 1);
        assert_eq!(sim.n_rounds(), 2);
        assert_eq!(run.clustering.labels(), &[0; 6]);
    }

    #[test]
    fn truncated_schedule_leaves_singletons() {
        // A schedule whose thresholds admit nobody: both rounds still run
        // (the fleet cannot know a phase is silent without the barrier),
        // nothing clusters, and the truncation makes everyone a
        // singleton.
        let g = path(4);
        let rank = identity_rank(4);
        let mut sim = sim_for(&g);
        let run = pivot_phase_engine(&g, &rank, &[0], "t", &mut sim);
        assert_eq!(run.phases, 1);
        assert_eq!(sim.n_rounds(), 2);
        assert_eq!(sim.total_communication(), 0);
        assert_eq!(run.clustering.labels(), &[0, 1, 2, 3]);
    }

    #[test]
    fn pivot_set_is_independent_every_phase() {
        // Random rank orders on a clique union: within one phase no two
        // adjacent vertices may both elect themselves. Cliques make the
        // check total — every pair is adjacent, so each phase's pivots
        // within a clique must be a single vertex, and each clique must
        // collapse to one cluster.
        let g = disjoint_cliques(3, 5);
        let mut rng = Rng::new(77);
        for _ in 0..10 {
            let perm = rng.permutation(g.n());
            let rank = ranks_from_permutation(&perm);
            let mut sim = sim_for(&g);
            let run = pivot_phase_engine(&g, &rank, &[g.n() as u32; 4], "t", &mut sim);
            assert_eq!(run.phases, 1, "a clique union peels in one phase");
            assert_eq!(run.clustering.n_clusters(), 3);
        }
    }

    #[test]
    fn engine_is_shard_invariant() {
        let g = crate::graph::generators::lambda_arboric(120, 3, &mut Rng::new(9));
        let perm = Rng::new(41).permutation(g.n());
        let rank = ranks_from_permutation(&perm);
        let schedule = vec![g.n() as u32; 6];
        let mut base = sim_for(&g);
        let want = pivot_phase_engine(&g, &rank, &schedule, "t", &mut base);
        for shards in [2usize, 8] {
            let mut sim = MpcSimulator::sharded(
                MpcConfig::model1(g.n(), rival_input_words(&g), 0.5),
                shards,
            );
            let run = pivot_phase_engine(&g, &rank, &schedule, "t", &mut sim);
            assert_eq!(
                run.clustering.labels(),
                want.clustering.labels(),
                "{shards} shards must be bit-identical"
            );
            assert_eq!(sim.trace(), base.trace(), "{shards} shards: identical schedule");
        }
    }

    #[test]
    fn model2_fleet_runs_the_same_clustering() {
        // One machine per vertex (Model 2) changes ownership and the
        // per-machine ledger shape but not the clustering.
        let g = path(8);
        let rank = identity_rank(8);
        let mut m1 = sim_for(&g);
        let a = pivot_phase_engine(&g, &rank, &[8, 8, 8, 8], "t", &mut m1);
        let mut m2 =
            MpcSimulator::new(MpcConfig::model2(g.n(), rival_input_words(&g), 0.5));
        let b = pivot_phase_engine(&g, &rank, &[8, 8, 8, 8], "t", &mut m2);
        assert_eq!(a.clustering.labels(), b.clustering.labels());
    }
}
