//! Cohen-Addad–Lattanzi et al. constant-round parallel PIVOT
//! (arxiv 2106.08448), as a threshold schedule for the shared
//! [`pivot_phase_engine`].
//!
//! The paper's Algorithm 1 samples a uniform random order π up front and
//! then, instead of peeling one pivot at a time (sequential PIVOT),
//! processes *geometrically growing prefixes* of π: phase i admits the
//! first T_i vertices of the order, T₁ = ⌈εn⌉ and
//! T_{i+1} = ⌈T_i · (1+ε)⌉, capped at n. Within a phase every admitted
//! unclustered vertex that is a rank minimum among its admitted
//! unclustered neighbors becomes a pivot and claims its unclustered
//! neighborhood — exactly the two routed rounds of the engine. Because
//! the prefix grows by a (1+ε) factor each phase, ⌈log_{1+ε}(1/ε)⌉ + 1
//! phases reach the full order: O(1/ε · log 1/ε) rounds total,
//! *independent of n and λ* (their Theorem 1.1; the (3+ε)-approximation
//! comes from coupling each phase with the sequential PIVOT prefix it
//! simulates, their Lemma 3.2).
//!
//! What this module pins down for the head-to-head lab: the round count
//! is flat in n (see `tests/round_counts.rs`), but the announce rounds
//! ship Θ(m) words *per phase* — the per-round word ceiling the source
//! paper's degree-reduction machinery exists to avoid.

use crate::graph::Graph;
use crate::mpc::simulator::MpcSimulator;

use super::{pivot_phase_engine, rival_eps, RivalRun};

/// Tuning for [`cal_pivot`]. ε controls both the first prefix (⌈εn⌉)
/// and the growth factor (1+ε); smaller ε means more phases and a
/// tighter coupling to sequential PIVOT (approximation 3+O(ε)).
#[derive(Debug, Clone, Copy)]
pub struct CalParams {
    pub eps: f64,
}

impl Default for CalParams {
    fn default() -> CalParams {
        CalParams { eps: super::RIVAL_DEFAULT_EPS }
    }
}

/// The geometric prefix schedule: T₁ = ⌈εn⌉ (at least 1),
/// T_{i+1} = ⌈T_i · (1+ε)⌉, capped at n; the final entry is always n so
/// the whole order is eventually admitted. The ceil in the recurrence
/// guarantees strict growth, so the schedule has
/// O(log_{1+ε}(n/⌈εn⌉)) = O(1/ε · log 1/ε) entries independent of n
/// (for n large enough that ⌈εn⌉ ≥ 1/ε; tiny n just converges faster).
pub fn cal_thresholds(n: usize, eps: f64) -> Vec<u32> {
    let eps = rival_eps(eps);
    if n == 0 {
        return Vec::new();
    }
    let n32 = u32::try_from(n).expect("vertex counts fit u32");
    let mut t = (((n as f64) * eps).ceil() as u64).clamp(1, u64::from(n32));
    let mut out = Vec::new();
    loop {
        let t32 = u32::try_from(t).expect("clamped to n");
        out.push(t32);
        if t32 == n32 {
            return out;
        }
        let grown = ((t as f64) * (1.0 + eps)).ceil() as u64;
        t = grown.max(t + 1).min(u64::from(n32));
    }
}

/// Run constant-round parallel PIVOT over a pre-sampled rank order
/// (`rank` must be a permutation of `0..n`, the MPC stand-in for the
/// paper's uniform random π). Charges 2 routed rounds per executed
/// phase to `sim`; see the module docs for the schedule.
pub fn cal_pivot(
    g: &Graph,
    rank: &[u32],
    params: &CalParams,
    sim: &mut MpcSimulator,
) -> RivalRun {
    let thresholds = cal_thresholds(g.n(), params.eps);
    pivot_phase_engine(g, rank, &thresholds, "cal", sim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy_mis::ranks_from_permutation;
    use crate::algorithms::rivals::rival_input_words;
    use crate::graph::generators::path;
    use crate::mpc::MpcConfig;
    use crate::util::rng::Rng;

    #[test]
    fn threshold_schedule_for_n8_quarter_eps() {
        // ⌈0.25·8⌉ = 2, then ⌈2·1.25⌉ = 3 (ceil > +1), ⌈3·1.25⌉ = 4,
        // ⌈4·1.25⌉ = 5, ⌈5·1.25⌉ = 7, ⌈7·1.25⌉ = 9 → capped at 8.
        assert_eq!(cal_thresholds(8, 0.25), vec![2, 3, 4, 5, 7, 8]);
    }

    #[test]
    fn threshold_schedule_always_ends_at_n_and_strictly_grows() {
        for n in [1usize, 2, 7, 100, 4097] {
            for eps in [0.1, 0.25, 0.5, 0.9] {
                let ts = cal_thresholds(n, eps);
                assert_eq!(*ts.last().unwrap() as usize, n, "n={n} eps={eps}");
                assert!(ts.windows(2).all(|w| w[0] < w[1]), "n={n} eps={eps}: {ts:?}");
            }
        }
    }

    #[test]
    fn schedule_length_is_flat_in_n() {
        // The constant-round claim: phases depend on ε, not n.
        let small = cal_thresholds(100, 0.25).len();
        let large = cal_thresholds(100_000, 0.25).len();
        assert!(large <= small + 1, "schedule grew with n: {small} -> {large}");
    }

    #[test]
    fn degenerate_eps_falls_back() {
        // eps = 2.0 (the engine-wide default) must not yield a one-phase
        // whole-graph schedule pretending to be CAL.
        assert_eq!(cal_thresholds(8, 2.0), cal_thresholds(8, 0.25));
        assert!(cal_thresholds(0, 0.25).is_empty());
    }

    #[test]
    fn path8_identity_rank_run() {
        // Hand-derived companion to the tests/round_counts.rs pin: with
        // identity ranks on path:n=8 the prefix schedule [2,3,4,5,7,8]
        // peels pivots {0}, {2}, {}, {4}, {6} and phase 6 never runs.
        let g = path(8);
        let rank: Vec<u32> = (0..8).collect();
        let mut sim =
            MpcSimulator::new(MpcConfig::model1(g.n(), rival_input_words(&g), 0.5));
        let run = cal_pivot(&g, &rank, &CalParams::default(), &mut sim);
        assert_eq!(run.phases, 5);
        assert_eq!(run.rounds, 10);
        assert_eq!(sim.n_rounds(), 10);
        assert_eq!(run.clustering.labels(), &[0, 0, 2, 2, 4, 4, 6, 6]);
    }

    #[test]
    fn seed_determinism_through_sampled_order() {
        let g = crate::graph::generators::lambda_arboric(90, 3, &mut Rng::new(4));
        let rank = ranks_from_permutation(&Rng::new(11).permutation(g.n()));
        let mut run = |shards: usize| {
            let cfg = MpcConfig::model1(g.n(), rival_input_words(&g), 0.5);
            let mut sim = if shards == 1 {
                MpcSimulator::new(cfg)
            } else {
                MpcSimulator::sharded(cfg, shards)
            };
            cal_pivot(&g, &rank, &CalParams::default(), &mut sim).clustering
        };
        let base = run(1);
        assert_eq!(base.labels(), run(1).labels());
        assert_eq!(base.labels(), run(2).labels());
        assert_eq!(base.labels(), run(8).labels());
    }
}
