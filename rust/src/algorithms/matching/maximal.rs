//! Randomized MPC maximal matching (the always-applicable 2-approximation
//! of Lemma 15/29).
//!
//! Israeli–Itai-style proposal rounds: every unmatched vertex proposes to
//! a uniformly random unmatched neighbor; an edge whose two endpoints
//! propose to each other — or whose target accepts the lowest-id proposal
//! it received — joins the matching.  O(log n) rounds w.h.p. on bounded-
//! degree graphs; each round is O(1) MPC rounds (messages are single
//! words along edges).  A final sequential sweep guarantees maximality
//! (charged as one more round: any surviving edge can be claimed greedily
//! by rank without conflicts after degrees are exhausted).

use crate::algorithms::matching::maximum::Matching;
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;
use crate::util::rng::Rng;

/// Result with round observability.
#[derive(Debug, Clone)]
pub struct MaximalRun {
    pub matching: Matching,
    pub proposal_rounds: usize,
}

/// Compute a maximal matching, counting proposal rounds on `sim`.
pub fn maximal_matching(
    g: &Graph,
    rng: &mut Rng,
    sim: &mut MpcSimulator,
    max_rounds: usize,
) -> MaximalRun {
    let n = g.n();
    let mut matched = vec![false; n];
    let mut matching: Matching = Vec::new();
    let mut rounds = 0usize;

    let live_edge_exists = |matched: &[bool]| {
        g.edges().any(|(u, v)| !matched[u as usize] && !matched[v as usize])
    };

    while rounds < max_rounds && live_edge_exists(&matched) {
        rounds += 1;
        // Proposal phase: vertex v's machine flips its own coins. The
        // stream is a function of (caller stream, simulator seed, v,
        // round) only — never of shard scheduling or visit order — so
        // proposal schedules are reproducible on the sharded executor.
        let round_tag = rng.next_u64();
        let mut proposal: Vec<Option<u32>> = vec![None; n];
        for v in 0..n as u32 {
            if matched[v as usize] {
                continue;
            }
            let cand: Vec<u32> = g
                .neighbors(v)
                .iter()
                .copied()
                .filter(|&u| !matched[u as usize])
                .collect();
            if !cand.is_empty() {
                let mut vrng = sim.machine_stream(v as usize, round_tag);
                proposal[v as usize] = Some(cand[vrng.index(cand.len())]);
            }
        }
        // Acceptance: u accepts the smallest proposer; the pair matches if
        // u's own proposal agrees or u is free to accept.
        let mut incoming: Vec<Vec<u32>> = vec![Vec::new(); n];
        for v in 0..n as u32 {
            if let Some(u) = proposal[v as usize] {
                incoming[u as usize].push(v);
            }
        }
        let mut newly: Vec<(u32, u32)> = Vec::new();
        for u in 0..n as u32 {
            if matched[u as usize] || incoming[u as usize].is_empty() {
                continue;
            }
            let &v = incoming[u as usize].iter().min().unwrap();
            if matched[v as usize] {
                continue;
            }
            // Mutual consent: accept if u proposed back to v, or u made no
            // proposal, or u's proposal target also rejected it this round
            // (resolved conservatively: require u's proposal == v or None).
            let ok = match proposal[u as usize] {
                None => true,
                Some(t) => t == v,
            };
            if ok && !matched[u as usize] && !matched[v as usize] {
                matched[u as usize] = true;
                matched[v as usize] = true;
                newly.push(if u < v { (u, v) } else { (v, u) });
            }
        }
        matching.extend(newly);
        let max_deg = g.max_degree() as Words;
        sim.round("maximal/propose+accept", max_deg, max_deg, 2 * g.m() as Words, max_deg + 2);
    }

    // Completion sweep (greedy over remaining edges) — exact maximality.
    let mut completed = false;
    for (u, v) in g.edges() {
        if !matched[u as usize] && !matched[v as usize] {
            matched[u as usize] = true;
            matched[v as usize] = true;
            matching.push((u, v));
            completed = true;
        }
    }
    if completed {
        let max_deg = g.max_degree() as Words;
        sim.round("maximal/complete", max_deg, max_deg, 2 * g.m() as Words, max_deg + 2);
        rounds += 1;
    }

    MaximalRun { matching, proposal_rounds: rounds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::matching::maximum::{is_matching, is_maximal, maximum_matching_forest};
    use crate::graph::generators::{lambda_arboric, path, random_forest};
    use crate::mpc::model::MpcConfig;

    fn sim(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(g.n().max(2), (g.n() + 2 * g.m()).max(4) as Words, 0.5))
    }

    #[test]
    fn produces_maximal_matching() {
        let mut rng = Rng::new(140);
        for trial in 0..10 {
            let g = lambda_arboric(120, 1 + trial % 3, &mut rng);
            let mut s = sim(&g);
            let run = maximal_matching(&g, &mut rng, &mut s, 64);
            assert!(is_matching(&g, &run.matching), "trial {trial}");
            assert!(is_maximal(&g, &run.matching), "trial {trial}");
        }
    }

    #[test]
    fn maximal_at_least_half_of_maximum_on_forests() {
        // The 2-approximation guarantee of any maximal matching.
        let mut rng = Rng::new(141);
        for trial in 0..10 {
            let g = random_forest(100, 0.9, &mut rng);
            let mut s = sim(&g);
            let run = maximal_matching(&g, &mut rng, &mut s, 64);
            let opt = maximum_matching_forest(&g).len();
            assert!(2 * run.matching.len() >= opt, "trial {trial}: {} vs {opt}", run.matching.len());
        }
    }

    #[test]
    fn rounds_are_logarithmic_in_practice() {
        let mut rng = Rng::new(142);
        let g = random_forest(2000, 0.95, &mut rng);
        let mut s = sim(&g);
        let run = maximal_matching(&g, &mut rng, &mut s, 200);
        assert!(run.proposal_rounds <= 40, "rounds {}", run.proposal_rounds);
    }

    #[test]
    fn p4_tightness_possible() {
        // Remark 30: maximal matching on P4 can be half of maximum; our
        // completion sweep means we always return a maximal one, and on
        // P4 either size-1 (middle edge) or size-2 is maximal.
        let g = path(4);
        let mut rng = Rng::new(143);
        let mut s = sim(&g);
        let run = maximal_matching(&g, &mut rng, &mut s, 16);
        assert!(run.matching.len() == 1 || run.matching.len() == 2);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::empty(5);
        let mut rng = Rng::new(144);
        let mut s = sim(&g);
        let run = maximal_matching(&g, &mut rng, &mut s, 8);
        assert!(run.matching.is_empty());
        assert_eq!(run.proposal_rounds, 0);
    }
}
