//! Maximum matching on forests — exact, linear time.
//!
//! Corollary 27: on forests (λ = 1), clustering by a maximum matching is
//! an *optimum* correlation clustering.  The classic greedy-leaf-peel is
//! exact on forests: repeatedly take any leaf, match it to its neighbor,
//! delete both.  (Exchange argument: some maximum matching matches every
//! leaf's unique edge or leaves the leaf exposed — matching the leaf edge
//! never hurts.)
//!
//! A vertex-DP variant is included as an independent implementation for
//! cross-checking (tests assert both produce the same matching *size*).

use crate::graph::Graph;

/// A matching as a list of edges (u < v), pairwise vertex-disjoint.
pub type Matching = Vec<(u32, u32)>;

/// Check the matching property against a graph.
pub fn is_matching(g: &Graph, m: &Matching) -> bool {
    let mut used = vec![false; g.n()];
    for &(u, v) in m {
        if !g.has_edge(u, v) {
            return false;
        }
        if used[u as usize] || used[v as usize] {
            return false;
        }
        used[u as usize] = true;
        used[v as usize] = true;
    }
    true
}

/// Is `m` maximal (no free edge can be added)?
pub fn is_maximal(g: &Graph, m: &Matching) -> bool {
    let mut matched = vec![false; g.n()];
    for &(u, v) in m {
        matched[u as usize] = true;
        matched[v as usize] = true;
    }
    g.edges().all(|(u, v)| matched[u as usize] || matched[v as usize])
}

/// Exact maximum matching on a forest via leaf peeling.
///
/// Panics if the graph contains a cycle (it is only exact on forests).
pub fn maximum_matching_forest(g: &Graph) -> Matching {
    let n = g.n();
    let mut degree: Vec<usize> = (0..n as u32).map(|v| g.degree(v)).collect();
    let mut removed = vec![false; n];
    let mut matched = vec![false; n];
    let mut matching = Vec::new();
    // Queue of current leaves (degree 1 among the remaining graph).
    let mut queue: std::collections::VecDeque<u32> =
        (0..n as u32).filter(|&v| degree[v as usize] == 1).collect();
    let mut processed = 0usize;

    let remove = |v: u32,
                      degree: &mut Vec<usize>,
                      removed: &mut Vec<bool>,
                      queue: &mut std::collections::VecDeque<u32>| {
        removed[v as usize] = true;
        for &u in g.neighbors(v) {
            if !removed[u as usize] {
                degree[u as usize] -= 1;
                if degree[u as usize] == 1 {
                    queue.push_back(u);
                }
            }
        }
    };

    while let Some(leaf) = queue.pop_front() {
        if removed[leaf as usize] || degree[leaf as usize] == 0 {
            // Became isolated or already handled.
            if !removed[leaf as usize] {
                removed[leaf as usize] = true;
            }
            continue;
        }
        processed += 1;
        // Its unique remaining neighbor.
        let parent = g
            .neighbors(leaf)
            .iter()
            .copied()
            .find(|&u| !removed[u as usize])
            .expect("leaf with degree 1 has a live neighbor");
        matching.push(if leaf < parent { (leaf, parent) } else { (parent, leaf) });
        matched[leaf as usize] = true;
        matched[parent as usize] = true;
        remove(leaf, &mut degree, &mut removed, &mut queue);
        remove(parent, &mut degree, &mut removed, &mut queue);
    }
    let _ = processed;
    // Cycle detection: in a forest, peeling exhausts all edges.
    let leftover_edges = (0..n as u32)
        .filter(|&v| !removed[v as usize])
        .map(|v| g.neighbors(v).iter().filter(|&&u| !removed[u as usize]).count())
        .sum::<usize>()
        / 2;
    assert_eq!(leftover_edges, 0, "maximum_matching_forest requires a forest (cycle found)");
    matching
}

/// Independent check: maximum-matching *size* on a forest via rooted DP
/// (`take[v]` = best matching in subtree if v is matched to a child,
/// `skip[v]` = best if not).
pub fn maximum_matching_size_dp(g: &Graph) -> usize {
    let n = g.n();
    let mut visited = vec![false; n];
    let mut total = 0usize;
    for root in 0..n as u32 {
        if visited[root as usize] {
            continue;
        }
        // Iterative post-order DFS.
        let mut order = Vec::new();
        let mut parent = vec![u32::MAX; n];
        let mut stack = vec![root];
        visited[root as usize] = true;
        while let Some(v) = stack.pop() {
            order.push(v);
            for &u in g.neighbors(v) {
                if !visited[u as usize] {
                    visited[u as usize] = true;
                    parent[u as usize] = v;
                    stack.push(u);
                }
            }
        }
        let mut take = vec![0i64; n]; // v matched to one child
        let mut skip = vec![0i64; n]; // v unmatched
        for &v in order.iter().rev() {
            let mut sum_best = 0i64; // Σ max(take, skip) over children
            let mut best_gain = i64::MIN; // best (skip_c + 1 - max_c)
            for &c in g.neighbors(v) {
                if parent[c as usize] != v {
                    continue;
                }
                let m = take[c as usize].max(skip[c as usize]);
                sum_best += m;
                best_gain = best_gain.max(skip[c as usize] + 1 - m);
            }
            skip[v as usize] = sum_best;
            take[v as usize] =
                if best_gain == i64::MIN { 0 } else { sum_best + best_gain };
        }
        total += take[root as usize].max(skip[root as usize]) as usize;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{caterpillar, path, random_forest, random_tree, star};
    use crate::util::rng::Rng;

    #[test]
    fn path_matchings() {
        assert_eq!(maximum_matching_forest(&path(2)).len(), 1);
        assert_eq!(maximum_matching_forest(&path(3)).len(), 1);
        assert_eq!(maximum_matching_forest(&path(4)).len(), 2);
        assert_eq!(maximum_matching_forest(&path(7)).len(), 3);
    }

    #[test]
    fn star_matches_one() {
        assert_eq!(maximum_matching_forest(&star(9)).len(), 1);
    }

    #[test]
    fn caterpillar_matches_spine_count() {
        // Each spine vertex can match one of its legs.
        let g = caterpillar(5, 2);
        assert_eq!(maximum_matching_forest(&g).len(), 5);
    }

    #[test]
    fn peel_equals_dp_on_random_forests() {
        let mut rng = Rng::new(130);
        for trial in 0..20 {
            let g = random_forest(80, 0.8, &mut rng);
            let peel = maximum_matching_forest(&g);
            assert!(is_matching(&g, &peel), "trial {trial}");
            assert_eq!(peel.len(), maximum_matching_size_dp(&g), "trial {trial}");
        }
    }

    #[test]
    fn peel_result_is_maximal() {
        let mut rng = Rng::new(131);
        let g = random_tree(100, &mut rng);
        let m = maximum_matching_forest(&g);
        assert!(is_maximal(&g, &m), "a maximum matching is maximal");
    }

    #[test]
    #[should_panic(expected = "requires a forest")]
    fn cycle_rejected() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        maximum_matching_forest(&g);
    }

    #[test]
    fn empty_and_isolated() {
        assert!(maximum_matching_forest(&Graph::empty(5)).is_empty());
        assert_eq!(maximum_matching_size_dp(&Graph::empty(5)), 0);
    }
}
