//! Matching algorithms for the forest (λ = 1) specialization
//! (Corollaries 27, 29, 31):
//!
//! * [`maximum`] — exact maximum matching on forests (leaf peel + DP
//!   cross-check);
//! * [`maximal`] — randomized MPC maximal matching (2-approx);
//! * [`approx`] — (1+ε)-approx via bounded-length augmenting paths.

pub mod approx;
pub mod maximal;
pub mod maximum;

pub use approx::{approx_matching, ApproxRun};
pub use maximal::{maximal_matching, MaximalRun};
pub use maximum::{is_matching, is_maximal, maximum_matching_forest, Matching};
