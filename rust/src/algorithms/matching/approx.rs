//! (1+ε)-approximate matching on bounded-degree forests via short
//! augmenting paths (the Hopcroft–Karp mechanism behind Corollary 31's
//! EMR and BCGS invocations).
//!
//! Standard fact: if a matching admits no augmenting path of length
//! ≤ 2k−1, it is a (1 + 1/k)-approximation of the maximum.  So for
//! ε ≥ 1/k it suffices to start from any maximal matching and repeatedly
//! flip maximal sets of vertex-disjoint augmenting paths of length
//! ≤ 2k−1.  On bounded-degree graphs each flip phase is implementable in
//! O_ε(1) MPC rounds by gathering O(k)-hop balls (charged via the
//! exponentiation cost model), which is how the paper reaches
//! O_ε(log log* n) / O_ε(1) rounds.

use crate::algorithms::matching::maximum::Matching;
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;

/// Result with phase observability.
#[derive(Debug, Clone)]
pub struct ApproxRun {
    pub matching: Matching,
    /// Augmenting phases executed.
    pub phases: usize,
    /// Rounds charged to the simulator.
    pub rounds: usize,
}

/// Improve `initial` to a (1+ε)-approximate matching by augmenting along
/// paths of length ≤ 2⌈1/ε⌉ − 1.
pub fn approx_matching(
    g: &Graph,
    initial: Matching,
    eps: f64,
    sim: &mut MpcSimulator,
) -> ApproxRun {
    assert!(eps > 0.0, "ε must be positive");
    let k = (1.0 / eps).ceil() as usize;
    let max_len = 2 * k - 1; // augmenting path length in edges
    let n = g.n();

    let mut mate: Vec<Option<u32>> = vec![None; n];
    for &(u, v) in &initial {
        mate[u as usize] = Some(v);
        mate[v as usize] = Some(u);
    }

    let rounds_before = sim.n_rounds();
    let mut phases = 0usize;
    // Phase limit: k phases suffice to kill all ≤ (2k−1)-length augmenting
    // paths when each phase flips a *maximal* disjoint set (Hopcroft–Karp
    // phase argument); a couple of extra phases cover greedy slack.
    for _phase in 0..(2 * k + 2) {
        let flipped = augment_phase(g, &mut mate, max_len);
        // Round charge per phase: gather (2k−1)-hop balls by doubling
        // (⌈log2(2k)⌉ rounds) + 1 flip-commit round. Degrees are O(λ/ε)
        // after Algorithm 4's filtering, so ball words are O_ε(1).
        let gather = (((max_len + 1) as f64).log2().ceil() as usize).max(1);
        let ball_cap = ball_words_bound(g, max_len);
        for r in 0..gather {
            sim.round(&format!("approx/gather[{r}]"), ball_cap, ball_cap, n as Words, ball_cap);
        }
        sim.round("approx/flip", 2, 2, 2 * g.m() as Words, ball_cap);
        phases += 1;
        if flipped == 0 {
            break;
        }
    }

    let mut matching = Vec::new();
    for v in 0..n as u32 {
        if let Some(u) = mate[v as usize] {
            if v < u {
                matching.push((v, u));
            }
        }
    }
    ApproxRun { matching, phases, rounds: sim.n_rounds() - rounds_before }
}

/// Measured per-vertex ball footprint for radius `r`: exact max over all
/// vertices for small graphs, deterministic stride sample for large ones
/// (the paper's precondition — Algorithm 4 has already bounded degrees to
/// O(λ/ε) — keeps the true value O_ε(1) anyway).
fn ball_words_bound(g: &Graph, r: usize) -> Words {
    let n = g.n();
    if n == 0 {
        return 1;
    }
    let stride = if n <= 4096 { 1 } else { n / 2048 };
    let mut best: Words = 1;
    let mut v = 0usize;
    while v < n {
        let ball = crate::mpc::exponentiation::bfs_ball(g, v as u32, r);
        let words: Words = ball.iter().map(|&u| 1 + g.degree(u) as Words).sum();
        best = best.max(words);
        v += stride;
    }
    best
}

/// One phase: greedily find a maximal set of vertex-disjoint augmenting
/// paths of length ≤ max_len and flip them. Returns #paths flipped.
fn augment_phase(g: &Graph, mate: &mut [Option<u32>], max_len: usize) -> usize {
    let n = g.n();
    let mut used = vec![false; n];
    let mut flips = 0usize;
    for v in 0..n as u32 {
        if mate[v as usize].is_some() || used[v as usize] {
            continue;
        }
        // DFS for an alternating path starting unmatched at v, ending at
        // an unmatched vertex, length ≤ max_len, avoiding `used`.
        if let Some(path) = find_augmenting(g, mate, &used, v, max_len) {
            // Flip: unmatched edges become matched and vice versa.
            for pair in path.windows(2) {
                let (a, b) = (pair[0], pair[1]);
                let was_matched = mate[a as usize] == Some(b);
                if was_matched {
                    mate[a as usize] = None;
                    mate[b as usize] = None;
                }
            }
            let mut i = 0;
            while i + 1 < path.len() {
                let (a, b) = (path[i], path[i + 1]);
                mate[a as usize] = Some(b);
                mate[b as usize] = Some(a);
                i += 2;
            }
            for &x in &path {
                used[x as usize] = true;
            }
            flips += 1;
        }
    }
    flips
}

/// DFS for an augmenting path from free vertex `start` (odd length,
/// alternating unmatched/matched, both ends free).
fn find_augmenting(
    g: &Graph,
    mate: &[Option<u32>],
    used: &[bool],
    start: u32,
    max_len: usize,
) -> Option<Vec<u32>> {
    // stack of (vertex, expects_matched_edge_next, path)
    fn dfs(
        g: &Graph,
        mate: &[Option<u32>],
        used: &[bool],
        path: &mut Vec<u32>,
        expect_matched: bool,
        max_len: usize,
    ) -> bool {
        let v = *path.last().unwrap();
        if path.len() > max_len + 1 {
            return false;
        }
        // Success: we arrived via an unmatched edge (so the next expected
        // edge is matched), the path has an odd number of edges (= even
        // number of vertices), and the endpoint is free.
        if expect_matched && path.len() % 2 == 0 && mate[v as usize].is_none() {
            return true;
        }
        if path.len() > max_len {
            return false;
        }
        for &u in g.neighbors(v) {
            // `path` is at most max_len+1 vertices, so a linear membership
            // scan beats a set here — and keeps the hot path allocation-free.
            if used[u as usize] || path.contains(&u) {
                continue;
            }
            let edge_is_matched = mate[v as usize] == Some(u);
            if edge_is_matched != expect_matched {
                continue;
            }
            path.push(u);
            // After an unmatched edge we reached u; if u is free we're
            // done (checked at loop head), else continue via its mate.
            if dfs(g, mate, used, path, !expect_matched, max_len) {
                return true;
            }
            path.pop();
        }
        false
    }

    let mut path = vec![start];
    if dfs(g, mate, used, &mut path, false, max_len) {
        Some(path)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::matching::maximum::{is_matching, maximum_matching_forest};
    use crate::graph::generators::{path, random_forest};
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    fn sim(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(
            g.n().max(2),
            (g.n() + 2 * g.m()).max(4) as Words,
            0.5,
        ))
    }

    #[test]
    fn p4_maximal_middle_edge_gets_augmented() {
        // Remark 30's instance: start from the worst maximal matching
        // (the middle edge); one augmenting path of length 3 fixes it.
        let g = path(4);
        let initial = vec![(1u32, 2u32)];
        let mut s = sim(&g);
        let run = approx_matching(&g, initial, 0.5, &mut s);
        assert_eq!(run.matching.len(), 2, "should reach maximum");
    }

    #[test]
    fn reaches_one_plus_eps_on_random_forests() {
        let mut rng = Rng::new(150);
        for trial in 0..10 {
            let g = random_forest(120, 0.9, &mut rng);
            let opt = maximum_matching_forest(&g).len();
            let mut s = sim(&g);
            let eps = 0.34; // k = 3, paths up to length 5
            let run = approx_matching(&g, Vec::new(), eps, &mut s);
            assert!(is_matching(&g, &run.matching), "trial {trial}");
            let bound = (1.0 + eps) * run.matching.len() as f64;
            assert!(
                bound + 1e-9 >= opt as f64,
                "trial {trial}: (1+ε)|M|={bound} < |M*|={opt}"
            );
        }
    }

    #[test]
    fn tighter_eps_gets_closer() {
        let mut rng = Rng::new(151);
        let g = random_forest(200, 0.95, &mut rng);
        let opt = maximum_matching_forest(&g).len();
        let mut s1 = sim(&g);
        let loose = approx_matching(&g, Vec::new(), 1.0, &mut s1).matching.len();
        let mut s2 = sim(&g);
        let tight = approx_matching(&g, Vec::new(), 0.2, &mut s2).matching.len();
        assert!(tight >= loose);
        assert!((1.2) * tight as f64 + 1e-9 >= opt as f64);
    }

    #[test]
    fn rounds_independent_of_n() {
        // O_ε(1) rounds: phases and per-phase round charges don't grow
        // with n (forest, constant ε).
        let mut rng = Rng::new(152);
        let small = random_forest(100, 0.9, &mut rng);
        let large = random_forest(3000, 0.9, &mut rng);
        let mut s1 = sim(&small);
        let r1 = approx_matching(&small, Vec::new(), 0.5, &mut s1).rounds;
        let mut s2 = sim(&large);
        let r2 = approx_matching(&large, Vec::new(), 0.5, &mut s2).rounds;
        assert!(r2 <= 2 * r1 + 8, "rounds grew with n: {r1} -> {r2}");
    }

    #[test]
    fn empty_graph() {
        let g = Graph::empty(4);
        let mut s = sim(&g);
        let run = approx_matching(&g, Vec::new(), 0.5, &mut s);
        assert!(run.matching.is_empty());
    }
}
