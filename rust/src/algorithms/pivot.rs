//! PIVOT (Ailon–Charikar–Newman): the 3-approximation (in expectation)
//! workhorse, in two equivalent forms.
//!
//! Sequential form: repeatedly pick the earliest unclustered vertex in π,
//! cluster it with its unclustered positive neighbors.
//!
//! MIS form (the one the paper exploits): the pivots are exactly the
//! greedy MIS with respect to π, and every non-pivot joins its
//! *earliest-in-π* pivot neighbor.  [`pivot`] uses the direct form;
//! [`pivot_from_mis`] derives the clustering from any (correct) greedy
//! MIS — this is what the MPC pipeline uses after Algorithms 1–3 produce
//! the MIS, and the tests assert the two forms coincide.

use crate::algorithms::greedy_mis::{greedy_mis, ranks_from_permutation};
use crate::cluster::Clustering;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// Sequential PIVOT with respect to permutation π.
pub fn pivot(g: &Graph, perm: &[u32]) -> Clustering {
    assert_eq!(perm.len(), g.n());
    let mut label = vec![u32::MAX; g.n()];
    for &v in perm {
        if label[v as usize] != u32::MAX {
            continue;
        }
        label[v as usize] = v;
        for &u in g.neighbors(v) {
            if label[u as usize] == u32::MAX {
                label[u as usize] = v;
            }
        }
    }
    Clustering::from_labels(label)
}

/// PIVOT with a fresh uniform-at-random permutation.
pub fn pivot_random(g: &Graph, rng: &mut Rng) -> Clustering {
    let perm = rng.permutation(g.n());
    pivot(g, &perm)
}

/// Derive the PIVOT clustering from a greedy MIS (the cluster-join step of
/// the MPC pipeline: one extra round in which every non-MIS vertex joins
/// its earliest MIS neighbor).
pub fn pivot_from_mis(g: &Graph, perm: &[u32], in_mis: &[bool]) -> Clustering {
    let rank = ranks_from_permutation(perm);
    let mut label = vec![u32::MAX; g.n()];
    for v in 0..g.n() as u32 {
        if in_mis[v as usize] {
            label[v as usize] = v;
        }
    }
    for v in 0..g.n() as u32 {
        if in_mis[v as usize] {
            continue;
        }
        let mut best: Option<u32> = None;
        for &u in g.neighbors(v) {
            if in_mis[u as usize]
                && best.map(|b| rank[u as usize] < rank[b as usize]).unwrap_or(true)
            {
                best = Some(u);
            }
        }
        // Maximality of the MIS guarantees a pivot neighbor exists.
        let p = best.expect("non-MIS vertex without MIS neighbor: MIS not maximal");
        label[v as usize] = p;
    }
    Clustering::from_labels(label)
}

/// Convenience: full sequential PIVOT expressed through the MIS path.
pub fn pivot_via_mis(g: &Graph, perm: &[u32]) -> Clustering {
    let mis = greedy_mis(g, perm);
    pivot_from_mis(g, perm, &mis)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::cost;
    use crate::cluster::exact::exact_cost;
    use crate::graph::generators::{clique, lambda_arboric, path, star};
    use crate::util::rng::Rng;

    #[test]
    fn pivot_equals_mis_form() {
        let mut rng = Rng::new(70);
        for trial in 0..20 {
            let g = lambda_arboric(120, 1 + trial % 4, &mut rng);
            let perm = rng.permutation(120);
            assert_eq!(
                pivot(&g, &perm).normalize(),
                pivot_via_mis(&g, &perm).normalize(),
                "trial {trial}"
            );
        }
    }

    #[test]
    fn pivot_on_clique_is_one_cluster() {
        let g = clique(8);
        let mut rng = Rng::new(71);
        let c = pivot_random(&g, &mut rng);
        assert_eq!(c.n_clusters(), 1);
        assert_eq!(cost(&g, &c).total(), 0);
    }

    #[test]
    fn pivot_star_center_first() {
        let g = star(6);
        let mut perm = vec![0u32];
        perm.extend(1..=6u32);
        let c = pivot(&g, &perm);
        assert_eq!(c.n_clusters(), 1);
    }

    #[test]
    fn pivot_star_leaf_first() {
        // Leaf pivot takes {leaf, center}; remaining leaves become
        // singletons.
        let g = star(6);
        let mut perm: Vec<u32> = vec![1, 0];
        perm.extend(2..=6u32);
        let c = pivot(&g, &perm);
        assert_eq!(c.n_clusters(), 6);
        assert!(c.same_cluster(0, 1));
    }

    #[test]
    fn expected_ratio_at_most_three_on_small_instances() {
        // Monte-Carlo check of the 3-approximation (in expectation):
        // mean PIVOT cost / OPT ≤ 3 with slack for sampling noise.
        let mut rng = Rng::new(72);
        for trial in 0..5 {
            let g = lambda_arboric(11, 1 + trial % 3, &mut rng);
            let opt = exact_cost(&g);
            if opt == 0 {
                continue;
            }
            let trials = 400;
            let mean: f64 = (0..trials)
                .map(|_| cost(&g, &pivot_random(&g, &mut rng)).total() as f64)
                .sum::<f64>()
                / trials as f64;
            let ratio = mean / opt as f64;
            assert!(ratio <= 3.3, "trial {trial}: mean ratio {ratio} > 3.3");
        }
    }

    #[test]
    fn path_identity_order() {
        let g = path(4);
        let c = pivot(&g, &[0, 1, 2, 3]);
        // 0 clusters {0,1}; 2 clusters {2,3}.
        assert!(c.same_cluster(0, 1));
        assert!(c.same_cluster(2, 3));
        assert_eq!(cost(&g, &c).total(), 1);
    }

    #[test]
    fn clusters_are_pivot_neighborhood_subsets() {
        let mut rng = Rng::new(73);
        let g = lambda_arboric(100, 2, &mut rng);
        let perm = rng.permutation(100);
        let c = pivot(&g, &perm);
        // Every cluster is {pivot} ∪ subset of N(pivot): diameter ≤ 2 in E+.
        for members in c.members() {
            if members.len() <= 1 {
                continue;
            }
            // The pivot is the member adjacent to all others.
            let has_center = members.iter().any(|&p| {
                members.iter().all(|&u| u == p || g.has_edge(p, u))
            });
            assert!(has_center, "cluster {members:?} lacks a center");
        }
    }
}
