//! Algorithm 4 / Theorem 26 — the paper's main algorithmic implication:
//! high-degree vertices can be ignored.
//!
//! Given ε > 0 and arboricity bound λ, the vertices with degree above
//! `8(1+ε)/ε · λ` become singletons; any α-approximate algorithm A runs
//! on the remaining bounded-degree subgraph (max degree ≤ 8(1+ε)λ/ε);
//! the union is a `max{1+ε, α}`-approximation.
//!
//! The module also exposes the Theorem 26 edge-accounting helpers used by
//! the unit tests to validate Equation (1) (`|M⁺| ≤ Σ_{v∈H} d⁺(v) ≤ 2|M⁺|`,
//! Figure 5) — the identity at the heart of the proof.

use crate::cluster::Clustering;
use crate::graph::Graph;

/// Degree threshold of Theorem 26: `8(1+ε)/ε · λ`.
pub fn degree_threshold(lambda: usize, eps: f64) -> f64 {
    assert!(eps > 0.0, "ε must be positive");
    8.0 * (1.0 + eps) / eps * lambda as f64
}

/// Split the vertex set into high-degree H and the kept subgraph G'.
/// Returns (keep mask, H as vertex list).
pub fn split_high_degree(g: &Graph, lambda: usize, eps: f64) -> (Vec<bool>, Vec<u32>) {
    let thr = degree_threshold(lambda, eps);
    let mut keep = vec![true; g.n()];
    let mut high = Vec::new();
    for v in 0..g.n() as u32 {
        if g.degree(v) as f64 > thr {
            keep[v as usize] = false;
            high.push(v);
        }
    }
    (keep, high)
}

/// Edge partition of the Theorem 26 proof: positive edges incident to H
/// (`M⁺`) vs. unmarked (`U`). Negative marked edges `M⁻` are implicit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeAccounting {
    /// |M⁺| — positive edges with ≥ 1 endpoint in H.
    pub marked_positive: u64,
    /// Σ_{v∈H} d⁺(v) — the double-counting sum of Equation (1).
    pub degree_sum_h: u64,
    /// |U ∩ E⁺| — positive edges with no endpoint in H.
    pub unmarked_positive: u64,
}

pub fn edge_accounting(g: &Graph, keep: &[bool]) -> EdgeAccounting {
    let mut marked = 0u64;
    let mut unmarked = 0u64;
    let mut dsum = 0u64;
    for (u, v) in g.edges() {
        if keep[u as usize] && keep[v as usize] {
            unmarked += 1;
        } else {
            marked += 1;
        }
    }
    for v in 0..g.n() as u32 {
        if !keep[v as usize] {
            dsum += g.degree(v) as u64;
        }
    }
    EdgeAccounting { marked_positive: marked, degree_sum_h: dsum, unmarked_positive: unmarked }
}

/// Run Algorithm 4: singletons for H, `inner` on the compacted G', union.
///
/// `inner` receives the compacted subgraph and must return a clustering of
/// it; its vertex ids are positions in the returned `old_ids` mapping.
pub fn alg4<F>(g: &Graph, lambda: usize, eps: f64, inner: F) -> Clustering
where
    F: FnOnce(&Graph) -> Clustering,
{
    let (keep, _high) = split_high_degree(g, lambda, eps);
    let (sub, old_ids) = g.induced_compact(&keep);
    let sub_clustering = inner(&sub);
    assert_eq!(sub_clustering.n(), sub.n(), "inner clustering size mismatch");
    // Start from all-singletons (covers H), then merge A(G').
    let mut out = Clustering::singletons(g.n());
    out.merge_subclustering(&sub_clustering, &old_ids);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pivot::pivot_random;
    use crate::cluster::cost::cost;
    use crate::cluster::exact::{exact_cost, MAX_EXACT_N};
    use crate::graph::generators::{lambda_arboric, star};
    use crate::util::rng::Rng;

    #[test]
    fn threshold_matches_paper_examples() {
        // ε = 2 (Corollary 28): threshold = 8·3/2·λ = 12λ.
        assert_eq!(degree_threshold(1, 2.0), 12.0);
        assert_eq!(degree_threshold(5, 2.0), 60.0);
    }

    #[test]
    fn split_bounds_remaining_degree() {
        let mut rng = Rng::new(120);
        let g = star(100); // λ=1, hub degree 100
        let (keep, high) = split_high_degree(&g, 1, 2.0);
        assert_eq!(high, vec![0]);
        let (sub, _) = g.induced_compact(&keep);
        assert_eq!(sub.max_degree(), 0, "leaves are isolated after hub removal");
        let _ = rng;
    }

    #[test]
    fn equation_1_marked_edge_sandwich() {
        // Figure 5 / Equation (1): |M⁺| ≤ Σ_{v∈H} d⁺(v) ≤ 2|M⁺|.
        let mut rng = Rng::new(121);
        for trial in 0..10 {
            let g = lambda_arboric(200, 1 + trial % 4, &mut rng);
            let lambda = 1 + trial % 4;
            let (keep, high) = split_high_degree(&g, lambda, 0.5);
            if high.is_empty() {
                continue;
            }
            let acc = edge_accounting(&g, &keep);
            assert!(acc.marked_positive <= acc.degree_sum_h, "trial {trial}");
            assert!(acc.degree_sum_h <= 2 * acc.marked_positive, "trial {trial}");
            assert_eq!(
                acc.marked_positive + acc.unmarked_positive,
                g.m() as u64,
                "edge partition must cover E+"
            );
        }
    }

    #[test]
    fn alg4_produces_valid_partition() {
        let mut rng = Rng::new(122);
        let g = lambda_arboric(150, 2, &mut rng);
        let mut inner_rng = rng.fork(1);
        let c = alg4(&g, 2, 2.0, |sub| pivot_random(sub, &mut inner_rng));
        assert_eq!(c.n(), 150);
        // High-degree vertices are singletons.
        let (keep, high) = split_high_degree(&g, 2, 2.0);
        let _ = keep;
        for &h in &high {
            let label = c.label(h);
            let same = (0..150u32).filter(|&v| c.label(v) == label).count();
            assert_eq!(same, 1, "high-degree vertex {h} must be a singleton");
        }
    }

    #[test]
    fn alg4_ratio_within_theorem_bound_on_small_instances() {
        // With ε = 2 and exact inner solver, the union must be within
        // max{1+ε, 1} = 3× OPT; in practice far closer.
        let mut rng = Rng::new(123);
        for trial in 0..8 {
            let n = MAX_EXACT_N - 2;
            let g = lambda_arboric(n, 1, &mut rng);
            let opt = exact_cost(&g);
            let c = alg4(&g, 1, 2.0, |sub| {
                crate::cluster::exact::solve_exact(sub).0
            });
            let got = cost(&g, &c).total();
            if opt == 0 {
                assert_eq!(got, 0, "trial {trial}");
            } else {
                assert!(
                    got as f64 <= 3.0 * opt as f64,
                    "trial {trial}: {got} > 3 × {opt}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "ε must be positive")]
    fn zero_eps_rejected() {
        degree_threshold(1, 0.0);
    }
}
