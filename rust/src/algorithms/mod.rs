//! Every algorithm in the paper, plus its baselines and constant-round
//! rivals.
//!
//! | Paper reference | Module |
//! |---|---|
//! | PIVOT (ACN'05) | [`pivot`] |
//! | Randomized greedy MIS + Fischer–Noever instrumentation | [`greedy_mis`] |
//! | Algorithms 1–3 (MPC greedy MIS, Theorem 24) | [`mpc_mis`] |
//! | Algorithm 4 / Theorem 26 (high-degree filtering) | [`alg4`] |
//! | Corollaries 27/29/31 (forest ⇒ matchings) | [`matching`], [`forest`] |
//! | Corollary 32 (O(λ²) in O(1) rounds) | [`simple`] |
//! | §1.4 baselines (ParallelPivot, C4, ClusterWild!) | [`baselines`] |
//! | Rival constant-round solvers (arxiv 2106.08448 / 2205.03710) | [`rivals`] |

pub mod alg4;
pub mod baselines;
pub mod forest;
pub mod greedy_mis;
pub mod local_search;
pub mod matching;
pub mod mpc_mis;
pub mod pivot;
pub mod rivals;
pub mod simple;
