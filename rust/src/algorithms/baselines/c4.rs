//! C4 (PPORRJ, NeurIPS'15): concurrency-safe parallel PIVOT.
//!
//! Epochs: the `⌈εn/Δ⌉` lowest-π-rank *active* vertices become the
//! candidate set; within an epoch, candidates resolve greedy MIS among
//! themselves by waiting on π-smaller candidate neighbors (we count those
//! waiting steps as rounds — the "concurrency-safe" serialization C4
//! pays); MIS candidates become pivots and claim their active neighbors
//! (smallest-rank pivot wins).
//!
//! Because the candidate sets are successive rank-prefixes of the active
//! graph, C4's final clustering **equals sequential PIVOT** for the same
//! π — the 3-approximation is inherited, only the round schedule differs.
//! (This is exactly the footnote-2 distinction the paper draws between
//! greedy-MIS-faithful algorithms and ParallelPivot.)

use crate::algorithms::greedy_mis::ranks_from_permutation;
use crate::cluster::Clustering;
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;

/// Result with epoch/round observability.
#[derive(Debug, Clone)]
pub struct C4Run {
    pub clustering: Clustering,
    pub epochs: usize,
    pub rounds: usize,
}

/// Run C4 with candidate-set parameter ε (epoch size = εn_active/Δ_active).
pub fn c4(g: &Graph, perm: &[u32], eps: f64, sim: &mut MpcSimulator) -> C4Run {
    assert!(eps > 0.0);
    let n = g.n();
    let rank = ranks_from_permutation(perm);
    let rounds_before = sim.n_rounds();
    let mut label = vec![u32::MAX; n];
    let mut epochs = 0usize;

    // Active vertices in rank order (π order filtered to unclustered).
    let mut remaining: Vec<u32> = perm.to_vec();
    // Vertex-indexed scratch reused across epochs (reset per epoch over
    // the candidate set only), so no hash containers touch the
    // deterministic path.
    let mut in_cand = vec![false; n];
    let mut blocked = vec![false; n];
    let mut depth = vec![0usize; n]; // 0 = not (yet) a selected pivot
    while !remaining.is_empty() {
        epochs += 1;
        let active_deg = remaining
            .iter()
            .map(|&v| {
                g.neighbors(v).iter().filter(|&&u| label[u as usize] == u32::MAX).count()
            })
            .max()
            .unwrap_or(0)
            .max(1);
        let take = ((eps * remaining.len() as f64 / active_deg as f64).ceil() as usize)
            .clamp(1, remaining.len());
        let candidates: Vec<u32> = remaining[..take].to_vec();
        for &v in &candidates {
            in_cand[v as usize] = true;
        }

        // Greedy MIS among candidates (waiting chains = parallel fixpoint
        // iterations on the candidate subgraph — C4's per-epoch cost).
        let mut in_mis: Vec<u32> = Vec::new();
        let mut wait_iters = 1usize;
        {
            // Sequential resolution in rank order gives the MIS; the
            // waiting depth is the longest rank-decreasing candidate
            // chain, measured via per-vertex depth (blocked candidates
            // keep depth 0, so they never extend a chain).
            for &v in &candidates {
                if blocked[v as usize] {
                    continue;
                }
                let d = g
                    .neighbors(v)
                    .iter()
                    .filter(|&&u| in_cand[u as usize] && rank[u as usize] < rank[v as usize])
                    .map(|&u| depth[u as usize])
                    .max()
                    .unwrap_or(0)
                    + 1;
                depth[v as usize] = d;
                wait_iters = wait_iters.max(d);
                in_mis.push(v);
                for &u in g.neighbors(v) {
                    if in_cand[u as usize] {
                        blocked[u as usize] = true;
                    }
                }
            }
        }

        // Pivots claim themselves and their active neighbors. `in_mis`
        // is in rank order (candidates were scanned in π order), so the
        // first pivot to reach a vertex is its smallest-rank pivot
        // neighbor — exactly PIVOT's assignment rule.
        for &p in &in_mis {
            label[p as usize] = p;
        }
        for &p in &in_mis {
            for &u in g.neighbors(p) {
                if label[u as usize] == u32::MAX {
                    label[u as usize] = p;
                }
            }
        }
        // Non-MIS candidates blocked by a pivot were claimed above
        // (pivot is their neighbor); any still-unlabeled candidate was
        // blocked only by non-selected candidates — stays active.
        let max_deg = g.max_degree() as Words;
        for i in 0..wait_iters {
            sim.round(
                &format!("c4/epoch{epochs}/wait[{i}]"),
                max_deg,
                max_deg,
                2 * g.m() as Words,
                max_deg + 2,
            );
        }
        sim.round(
            &format!("c4/epoch{epochs}/claim"),
            max_deg,
            max_deg,
            2 * g.m() as Words,
            max_deg + 2,
        );

        // Reset the scratch over exactly the vertices this epoch touched
        // (blocked is only ever set on candidates).
        for &v in &candidates {
            in_cand[v as usize] = false;
            blocked[v as usize] = false;
            depth[v as usize] = 0;
        }

        remaining.retain(|&v| label[v as usize] == u32::MAX);
    }

    C4Run {
        clustering: Clustering::from_labels(label),
        epochs,
        rounds: sim.n_rounds() - rounds_before,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pivot::pivot;
    use crate::graph::generators::lambda_arboric;
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    fn sim(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(
            g.n().max(2),
            (g.n() + 2 * g.m()).max(4) as Words,
            0.5,
        ))
    }

    #[test]
    fn c4_equals_pivot() {
        let mut rng = Rng::new(180);
        for trial in 0..8 {
            let g = lambda_arboric(130, 1 + trial % 3, &mut rng);
            let perm = rng.permutation(130);
            let mut s = sim(&g);
            let run = c4(&g, &perm, 0.9, &mut s);
            assert_eq!(
                run.clustering.normalize(),
                pivot(&g, &perm).normalize(),
                "trial {trial}: C4 must reproduce PIVOT for the same π"
            );
        }
    }

    #[test]
    fn epochs_and_rounds_recorded() {
        let mut rng = Rng::new(181);
        let g = lambda_arboric(300, 3, &mut rng);
        let perm = rng.permutation(300);
        let mut s = sim(&g);
        let run = c4(&g, &perm, 0.5, &mut s);
        assert!(run.epochs >= 1);
        assert!(run.rounds >= run.epochs);
    }
}
