//! ParallelPivot (Chierichetti–Dalvi–Kumar, KDD'14): the MapReduce
//! baseline.
//!
//! Unlike C4/greedy-MIS algorithms, ParallelPivot does **not** compute a
//! greedy MIS (paper footnote 3): each epoch independently samples active
//! vertices with probability `ε / Δ_active`; the sampled set is thinned
//! to an independent set by dropping any sampled vertex adjacent to a
//! sampled vertex of smaller π-rank (the initial ordering is used only
//! for tie-breaking); surviving pivots claim their active neighbors,
//! smallest rank first.  O((1/ε)·log n·log Δ) rounds w.h.p., constant
//! approximation.

use crate::algorithms::greedy_mis::ranks_from_permutation;
use crate::cluster::Clustering;
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;
use crate::util::rng::Rng;

/// Result with epoch observability.
#[derive(Debug, Clone)]
pub struct ParallelPivotRun {
    pub clustering: Clustering,
    pub epochs: usize,
    pub rounds: usize,
}

/// Run ParallelPivot with sampling parameter ε.
pub fn parallel_pivot(
    g: &Graph,
    perm: &[u32],
    eps: f64,
    rng: &mut Rng,
    sim: &mut MpcSimulator,
) -> ParallelPivotRun {
    assert!(eps > 0.0);
    let n = g.n();
    let rank = ranks_from_permutation(perm);
    let rounds_before = sim.n_rounds();
    let mut label = vec![u32::MAX; n];
    let mut epochs = 0usize;
    let mut active: Vec<u32> = (0..n as u32).collect();
    // Vertex-indexed sample marker, reused across epochs (reset over the
    // sampled vertices only) — keeps the loop free of hash containers.
    let mut is_sampled = vec![false; n];

    while !active.is_empty() {
        epochs += 1;
        let active_deg = active
            .iter()
            .map(|&v| {
                g.neighbors(v).iter().filter(|&&u| label[u as usize] == u32::MAX).count()
            })
            .max()
            .unwrap_or(0);
        if active_deg == 0 {
            // All isolated: everyone becomes a singleton pivot in one
            // final round.
            for &v in &active {
                label[v as usize] = v;
            }
            sim.round("ppivot/final", 1, 1, active.len() as Words, 2);
            active.clear();
            break;
        }
        let p = (eps / active_deg as f64).min(1.0);
        // Independent sampling.
        let sampled: Vec<u32> = active.iter().copied().filter(|_| rng.bernoulli(p)).collect();
        for &v in &sampled {
            is_sampled[v as usize] = true;
        }
        // Thin to an independent set: drop sampled vertices with a
        // smaller-rank sampled neighbor.
        let mut pivots: Vec<u32> = sampled
            .iter()
            .copied()
            .filter(|&v| {
                !g.neighbors(v)
                    .iter()
                    .any(|&u| is_sampled[u as usize] && rank[u as usize] < rank[v as usize])
            })
            .collect();
        for &v in &sampled {
            is_sampled[v as usize] = false;
        }
        pivots.sort_by_key(|&v| rank[v as usize]);

        for &p in &pivots {
            label[p as usize] = p;
        }
        for &p in &pivots {
            for &u in g.neighbors(p) {
                if label[u as usize] == u32::MAX {
                    label[u as usize] = p;
                }
            }
        }
        let max_deg = g.max_degree() as Words;
        sim.round(
            &format!("ppivot/epoch[{epochs}]"),
            max_deg,
            max_deg,
            2 * g.m() as Words,
            max_deg + 2,
        );
        active.retain(|&v| label[v as usize] == u32::MAX);

        // Safety valve against pathological sampling stalls.
        assert!(epochs <= 200 * (n.max(2) as f64).log2() as usize + 200, "ParallelPivot stalled");
    }

    ParallelPivotRun {
        clustering: Clustering::from_labels(label),
        epochs,
        rounds: sim.n_rounds() - rounds_before,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::cost;
    use crate::cluster::exact::exact_cost;
    use crate::graph::generators::lambda_arboric;
    use crate::mpc::model::MpcConfig;

    fn sim(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(
            g.n().max(2),
            (g.n() + 2 * g.m()).max(4) as Words,
            0.5,
        ))
    }

    #[test]
    fn covers_all_vertices() {
        let mut rng = Rng::new(200);
        for trial in 0..8 {
            let g = lambda_arboric(150, 1 + trial % 3, &mut rng);
            let perm = rng.permutation(150);
            let mut s = sim(&g);
            let run = parallel_pivot(&g, &perm, 0.5, &mut rng, &mut s);
            assert!(run.clustering.labels().iter().all(|&l| l != u32::MAX), "trial {trial}");
            assert_eq!(run.rounds, run.epochs);
        }
    }

    #[test]
    fn pivots_form_independent_clusters() {
        let mut rng = Rng::new(201);
        let g = lambda_arboric(100, 2, &mut rng);
        let perm = rng.permutation(100);
        let mut s = sim(&g);
        let run = parallel_pivot(&g, &perm, 0.5, &mut rng, &mut s);
        // Every cluster has a center adjacent to all members.
        for members in run.clustering.members() {
            if members.len() <= 1 {
                continue;
            }
            let has_center = members
                .iter()
                .any(|&p| members.iter().all(|&u| u == p || g.has_edge(p, u)));
            assert!(has_center);
        }
    }

    #[test]
    fn mean_ratio_constant_on_small_instances() {
        let mut rng = Rng::new(202);
        let g = lambda_arboric(11, 2, &mut rng);
        let opt = exact_cost(&g);
        if opt == 0 {
            return;
        }
        let trials = 300;
        let mean: f64 = (0..trials)
            .map(|_| {
                let perm = rng.permutation(11);
                let mut s = sim(&g);
                cost(&g, &parallel_pivot(&g, &perm, 0.5, &mut rng, &mut s).clustering).total()
                    as f64
            })
            .sum::<f64>()
            / trials as f64;
        assert!(mean / opt as f64 <= 5.0, "mean ratio {}", mean / opt as f64);
    }
}
