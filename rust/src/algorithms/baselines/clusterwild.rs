//! ClusterWild! (PPORRJ, NeurIPS'15): the independence-free speedup.
//!
//! Same epoch structure as C4 — the `⌈εn/Δ⌉` lowest-π-rank active
//! vertices are sampled — but *every* sampled vertex becomes a pivot
//! immediately, with no MIS among the candidates.  Active vertices
//! (including sampled ones that have a smaller-rank sampled neighbor)
//! join the smallest-rank adjacent pivot.  Skipping the waiting chains
//! makes each epoch exactly one round, at the price of a (3 + ε)
//! approximation instead of 3.

use crate::algorithms::greedy_mis::ranks_from_permutation;
use crate::cluster::Clustering;
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;

/// Result with epoch observability.
#[derive(Debug, Clone)]
pub struct ClusterWildRun {
    pub clustering: Clustering,
    pub epochs: usize,
    pub rounds: usize,
}

/// Run ClusterWild! with epoch parameter ε.
pub fn clusterwild(g: &Graph, perm: &[u32], eps: f64, sim: &mut MpcSimulator) -> ClusterWildRun {
    assert!(eps > 0.0);
    let n = g.n();
    let rank = ranks_from_permutation(perm);
    let rounds_before = sim.n_rounds();
    let mut label = vec![u32::MAX; n];
    let mut epochs = 0usize;

    let mut remaining: Vec<u32> = perm.to_vec();
    while !remaining.is_empty() {
        epochs += 1;
        let active_deg = remaining
            .iter()
            .map(|&v| {
                g.neighbors(v).iter().filter(|&&u| label[u as usize] == u32::MAX).count()
            })
            .max()
            .unwrap_or(0)
            .max(1);
        let take = ((eps * remaining.len() as f64 / active_deg as f64).ceil() as usize)
            .clamp(1, remaining.len());
        let pivots: Vec<u32> = remaining[..take].to_vec();

        // Every sampled vertex is a pivot — no independence check. A
        // sampled vertex adjacent to a smaller-rank sampled vertex is
        // "stolen" into that pivot's cluster (the approximation leak).
        for &p in &pivots {
            if label[p as usize] == u32::MAX {
                label[p as usize] = p;
            }
        }
        // `pivots` is in rank order: first claimer = smallest rank.
        for &p in &pivots {
            // A pivot stolen by an earlier pivot no longer claims.
            if label[p as usize] != p {
                continue;
            }
            for &u in g.neighbors(p) {
                if label[u as usize] == u32::MAX {
                    label[u as usize] = p;
                } else if label[u as usize] == u && u != p {
                    // u was self-labeled as a pivot this epoch but has a
                    // smaller-rank pivot neighbor p: steal (wild!).
                    if rank[p as usize] < rank[u as usize] {
                        label[u as usize] = p;
                    }
                }
            }
        }
        let max_deg = g.max_degree() as Words;
        sim.round(
            &format!("clusterwild/epoch[{epochs}]"),
            max_deg,
            max_deg,
            2 * g.m() as Words,
            max_deg + 2,
        );
        remaining.retain(|&v| label[v as usize] == u32::MAX);
    }

    ClusterWildRun {
        clustering: Clustering::from_labels(label),
        epochs,
        rounds: sim.n_rounds() - rounds_before,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::cost;
    use crate::cluster::exact::exact_cost;
    use crate::graph::generators::lambda_arboric;
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    fn sim(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(
            g.n().max(2),
            (g.n() + 2 * g.m()).max(4) as Words,
            0.5,
        ))
    }

    #[test]
    fn produces_valid_partition_and_terminates() {
        let mut rng = Rng::new(190);
        for trial in 0..8 {
            let g = lambda_arboric(150, 1 + trial % 3, &mut rng);
            let perm = rng.permutation(150);
            let mut s = sim(&g);
            let run = clusterwild(&g, &perm, 0.8, &mut s);
            assert_eq!(run.clustering.n(), 150);
            assert!(run.clustering.labels().iter().all(|&l| l != u32::MAX), "trial {trial}");
            assert_eq!(run.rounds, run.epochs);
        }
    }

    #[test]
    fn mean_ratio_reasonable_on_small_instances() {
        // (3+ε) in expectation: Monte-Carlo sanity with slack.
        let mut rng = Rng::new(191);
        let g = lambda_arboric(11, 2, &mut rng);
        let opt = exact_cost(&g);
        if opt == 0 {
            return;
        }
        let trials = 300;
        let mean: f64 = (0..trials)
            .map(|_| {
                let perm = rng.permutation(11);
                let mut s = sim(&g);
                cost(&g, &clusterwild(&g, &perm, 0.8, &mut s).clustering).total() as f64
            })
            .sum::<f64>()
            / trials as f64;
        assert!(mean / opt as f64 <= 4.2, "mean ratio {}", mean / opt as f64);
    }

    #[test]
    fn fewer_rounds_than_c4_waiting() {
        // ClusterWild!'s point: 1 round per epoch.
        let mut rng = Rng::new(192);
        let g = lambda_arboric(400, 4, &mut rng);
        let perm = rng.permutation(400);
        let mut s1 = sim(&g);
        let cw = clusterwild(&g, &perm, 0.8, &mut s1);
        let mut s2 = sim(&g);
        let c4run = crate::algorithms::baselines::c4::c4(&g, &perm, 0.8, &mut s2);
        assert!(cw.rounds <= c4run.rounds, "wild {} vs c4 {}", cw.rounds, c4run.rounds);
    }
}
