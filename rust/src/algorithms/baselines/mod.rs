//! Distributed correlation-clustering baselines the paper compares
//! against in §1.4: ParallelPivot (CDK, KDD'14) and C4 / ClusterWild!
//! (PPORRJ, NeurIPS'15).

pub mod c4;
pub mod clusterwild;
pub mod parallel_pivot;

pub use c4::{c4, C4Run};
pub use clusterwild::{clusterwild, ClusterWildRun};
pub use parallel_pivot::{parallel_pivot, ParallelPivotRun};
