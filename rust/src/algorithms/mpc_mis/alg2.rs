//! Algorithm 2: greedy MIS on a (prefix) graph via graph shattering,
//! Model 1.
//!
//! The prefix's vertices are processed in π order in *chunks* whose size
//! doubles every phase: `c_i = 2^i · n / (divisor · Δ')`.  Because a
//! chunk is a uniform sample of the surviving vertices, the chunk graph's
//! connected components are small (Lemma 18: O(log n) w.h.p. with the
//! paper's constants), so every component can be gathered onto one
//! machine by graph exponentiation in O(log log n) rounds (Lemma 19) and
//! greedily resolved there in zero additional communication.
//!
//! Exactness: chunks partition the prefix by π rank, so resolving chunks
//! in order with carried-over `blocked` state reproduces the sequential
//! greedy MIS *exactly* — the paper's simulations are not approximations.
//!
//! Constants: the paper uses divisor 100 and 2000·log Δ chunks per phase
//! "for a cleaner analysis".  Those are asymptotic-proof constants; the
//! default here keeps the *subcritical sampling* property that drives
//! Lemma 18 (expected sampled neighbors per vertex = 2/divisor < 1) with
//! a smaller constant so measured round counts aren't constant-dominated.
//! `Alg2Params::faithful()` restores the paper's literal constants.

use crate::algorithms::greedy_mis::greedy_mis_on_subset;
use crate::graph::components::UnionFind;
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;

/// Tunable constants of Algorithm 2.
#[derive(Debug, Clone)]
pub struct Alg2Params {
    /// Chunk size divisor: c_i = 2^i n / (divisor · Δ'). Must keep the
    /// per-chunk sampling subcritical (divisor > 2).
    pub divisor: f64,
    /// Chunks per phase = ceil(iters_factor · log2 Δ').
    pub iters_factor: f64,
}

impl Default for Alg2Params {
    fn default() -> Self {
        Alg2Params { divisor: 8.0, iters_factor: 4.0 }
    }
}

impl Alg2Params {
    /// The paper's literal constants (§Algorithm 2).
    pub fn faithful() -> Self {
        Alg2Params { divisor: 100.0, iters_factor: 2000.0 }
    }
}

/// Per-run observability (feeds experiments E4/E5).
#[derive(Debug, Clone, Default)]
pub struct Alg2Stats {
    /// Max connected-component size of each processed (nonempty) chunk
    /// graph — the Lemma 18 quantity.
    pub chunk_max_components: Vec<usize>,
    /// Number of nonempty chunks processed.
    pub chunks: usize,
    /// Number of phases.
    pub phases: usize,
}

/// Process `order` (vertices of a prefix, in π order) with Algorithm 2.
/// `blocked`/`in_mis` carry global greedy state across prefixes.
pub fn alg2_process(
    g: &Graph,
    order: &[u32],
    blocked: &mut [bool],
    in_mis: &mut [bool],
    sim: &mut MpcSimulator,
    params: &Alg2Params,
) -> Alg2Stats {
    let mut stats = Alg2Stats::default();
    let nprefix = order.len();
    if nprefix == 0 {
        return stats;
    }
    // Δ' = max degree of the prefix graph (induced on currently-alive
    // prefix vertices). Computing it is one aggregate (charged below);
    // the scan itself is the round's local compute, sharded on the pool.
    let pool = sim.pool();
    let alive_prefix: Vec<u32> =
        order.iter().copied().filter(|&v| !blocked[v as usize]).collect();
    let mut in_prefix = vec![false; g.n()];
    for &v in &alive_prefix {
        in_prefix[v as usize] = true;
    }
    let delta_p = (pool.max_by(alive_prefix.len(), |i| {
        g.neighbors(alive_prefix[i]).iter().filter(|&&u| in_prefix[u as usize]).count() as u64
    }) as usize)
        .max(1);
    sim.round("alg2/degree-aggregate", 1, 1, nprefix as Words, 2);

    // Chunk-local index scratch, reused across chunks: `u32::MAX` marks
    // "not in the current chunk" (only touched slots are reset). The
    // alive list and component tallies are likewise chunk-recycled.
    let mut chunk_index: Vec<u32> = vec![u32::MAX; g.n()];
    let mut scratch = ChunkScratch::default();
    let mut pos = 0usize;
    let mut phase = 0u32;
    while pos < nprefix {
        let c_i = (((1u64 << phase.min(62)) as f64) * nprefix as f64
            / (params.divisor * delta_p as f64))
            .ceil()
            .max(1.0) as usize;
        let iters = ((params.iters_factor * (delta_p.max(2) as f64).log2()).ceil() as usize).max(1);
        for _ in 0..iters {
            if pos >= nprefix {
                break;
            }
            let end = (pos + c_i).min(nprefix);
            let chunk = &order[pos..end];
            pos = end;
            process_chunk(g, chunk, blocked, in_mis, sim, &mut stats, &mut chunk_index, &mut scratch);
        }
        stats.phases += 1;
        phase += 1;
    }
    stats
}

/// Chunk-recycled scratch for [`process_chunk`]: cleared (capacity kept)
/// per chunk instead of reallocated, the same `clear()`-not-drop policy
/// as the message plane's round arena.
#[derive(Debug, Default)]
struct ChunkScratch {
    alive: Vec<u32>,
    comp_size: Vec<usize>,
    comp_words: Vec<Words>,
}

/// Resolve one chunk: gather each connected component of the chunk graph
/// on one machine (graph exponentiation — O(log(max component)) rounds),
/// run greedy locally, then one round to publish the statuses.
///
/// `chunk_index` is the caller's vertex-indexed scratch (`u32::MAX` =
/// not in chunk); all component tallies are Vec-indexed by chunk-local
/// UnionFind roots, so nothing here depends on hash iteration order.
#[allow(clippy::too_many_arguments)]
fn process_chunk(
    g: &Graph,
    chunk: &[u32],
    blocked: &mut [bool],
    in_mis: &mut [bool],
    sim: &mut MpcSimulator,
    stats: &mut Alg2Stats,
    chunk_index: &mut [u32],
    scratch: &mut ChunkScratch,
) {
    let ChunkScratch { alive, comp_size, comp_words } = scratch;
    // Alive = not yet knocked out by earlier chunks/prefixes.
    alive.clear();
    alive.extend(chunk.iter().copied().filter(|&v| !blocked[v as usize]));
    if alive.is_empty() {
        // A chunk with no surviving vertices is known empty from π and the
        // already-published statuses; no synchronous round is needed.
        return;
    }
    // Chunk-local components (edges of g among alive chunk vertices).
    for (i, &v) in alive.iter().enumerate() {
        chunk_index[v as usize] = i as u32;
    }
    let mut uf = UnionFind::new(alive.len());
    for (i, &v) in alive.iter().enumerate() {
        for &u in g.neighbors(v) {
            let j = chunk_index[u as usize];
            if j != u32::MAX {
                uf.union(i as u32, j);
            }
        }
    }
    // Component sizes and memory footprint (topology words of the largest
    // component: members + their chunk-internal adjacency), tallied into
    // root-indexed vectors (non-roots stay zero).
    comp_size.clear();
    comp_size.resize(alive.len(), 0);
    comp_words.clear();
    comp_words.resize(alive.len(), 0);
    for (i, &v) in alive.iter().enumerate() {
        let root = uf.find(i as u32) as usize;
        comp_size[root] += 1;
        let internal_deg = g
            .neighbors(v)
            .iter()
            .filter(|&&u| chunk_index[u as usize] != u32::MAX)
            .count() as Words;
        comp_words[root] += 1 + internal_deg;
    }
    let max_comp = comp_size.iter().copied().max().unwrap_or(1);
    let max_words = comp_words.iter().copied().max().unwrap_or(1);
    stats.chunk_max_components.push(max_comp);
    stats.chunks += 1;
    // Reset only the touched scratch slots for the next chunk.
    for &v in &alive {
        chunk_index[v as usize] = u32::MAX;
    }

    // Graph exponentiation inside the chunk graph: radius doubles per
    // round until it covers the largest component (diameter ≤ size).
    let gather_rounds = ((max_comp.max(2) as f64).log2().ceil() as usize).max(1);
    let total_words: Words = comp_words.iter().sum();
    for r in 0..gather_rounds {
        sim.round(
            &format!("alg2/gather[{r}]"),
            max_words,
            max_words,
            total_words,
            max_words,
        );
    }

    // Local greedy resolution (no communication; arbitrary local compute
    // is free in MPC) ...
    greedy_mis_on_subset(g, chunk, blocked, in_mis);
    // ... and one round to publish new statuses to all neighbors.
    let max_deg = alive.iter().map(|&v| g.degree(v) as Words).max().unwrap_or(0);
    let total_deg: Words = alive.iter().map(|&v| g.degree(v) as Words).sum();
    sim.round("alg2/publish", max_deg, max_deg, total_deg, max_words);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy_mis::{greedy_mis, is_valid_mis};
    use crate::graph::generators::lambda_arboric;
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    fn run_alg2(g: &Graph, perm: &[u32]) -> (Vec<bool>, Alg2Stats, usize) {
        let cfg = MpcConfig::model1(g.n(), (g.n() + 2 * g.m()) as Words, 0.5);
        let mut sim = MpcSimulator::new(cfg);
        let mut blocked = vec![false; g.n()];
        let mut in_mis = vec![false; g.n()];
        let stats =
            alg2_process(g, perm, &mut blocked, &mut in_mis, &mut sim, &Alg2Params::default());
        (in_mis, stats, sim.n_rounds())
    }

    #[test]
    fn matches_sequential_greedy_exactly() {
        let mut rng = Rng::new(80);
        for trial in 0..10 {
            let g = lambda_arboric(150, 1 + trial % 4, &mut rng);
            let perm = rng.permutation(150);
            let expected = greedy_mis(&g, &perm);
            let (got, _, _) = run_alg2(&g, &perm);
            assert_eq!(got, expected, "trial {trial}");
        }
    }

    #[test]
    fn produces_valid_mis() {
        let mut rng = Rng::new(81);
        let g = lambda_arboric(300, 3, &mut rng);
        let perm = rng.permutation(300);
        let (mis, stats, rounds) = run_alg2(&g, &perm);
        assert!(is_valid_mis(&g, &mis));
        assert!(stats.chunks > 0);
        assert!(rounds > 0);
    }

    #[test]
    fn components_stay_small() {
        // Lemma 18's shape: chunk components are O(log n)-ish. With the
        // default subcritical divisor the max component should be far
        // below the chunk size.
        let mut rng = Rng::new(82);
        let g = lambda_arboric(2000, 4, &mut rng);
        let perm = rng.permutation(2000);
        let (_, stats, _) = run_alg2(&g, &perm);
        let max_comp = stats.chunk_max_components.iter().copied().max().unwrap_or(0);
        assert!(max_comp <= 64, "component of size {max_comp} on n=2000");
    }

    #[test]
    fn faithful_constants_also_exact() {
        let mut rng = Rng::new(83);
        let g = lambda_arboric(100, 2, &mut rng);
        let perm = rng.permutation(100);
        let expected = greedy_mis(&g, &perm);
        let cfg = MpcConfig::model1(100, 700, 0.5);
        let mut sim = MpcSimulator::new(cfg);
        let mut blocked = vec![false; 100];
        let mut in_mis = vec![false; 100];
        alg2_process(&g, &perm, &mut blocked, &mut in_mis, &mut sim, &Alg2Params::faithful());
        assert_eq!(in_mis, expected);
    }

    #[test]
    fn empty_prefix_noop() {
        let g = Graph::empty(5);
        let cfg = MpcConfig::model1(5, 10, 0.5);
        let mut sim = MpcSimulator::new(cfg);
        let mut blocked = vec![false; 5];
        let mut in_mis = vec![false; 5];
        let stats =
            alg2_process(&g, &[], &mut blocked, &mut in_mis, &mut sim, &Alg2Params::default());
        assert_eq!(stats.chunks, 0);
        assert_eq!(sim.n_rounds(), 0);
    }
}
