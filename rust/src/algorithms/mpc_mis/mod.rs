//! MPC greedy-MIS pipeline (paper Section 3):
//!
//! * [`alg1`] — the phase driver with degree halving (Algorithm 1) and
//!   the direct Fischer–Noever simulation baseline;
//! * [`alg2`] — graph shattering subroutine, Model 1 (Algorithm 2);
//! * [`alg3`] — exponentiation + round compression, Model 2 (Algorithm 3);
//! * [`pivot_mpc`] — the MIS→PIVOT cluster-join wrapper (Corollary 28).

pub mod alg1;
pub mod alg2;
pub mod alg3;
pub mod pivot_mpc;

pub use alg1::{alg1_greedy_mis, direct_simulation_mis, Alg1Params, Alg1Run, Subroutine};
pub use alg2::Alg2Params;
pub use alg3::Alg3Params;
pub use pivot_mpc::{mpc_pivot, MpcPivotRun};
