//! Algorithm 3: greedy MIS via graph exponentiation + round compression,
//! Model 2 (one machine per vertex).
//!
//! Every alive vertex gathers the largest R-hop ball that fits in its
//! machine (Lemma 21 shows R ∈ O(log n / log Δ) fits when Δ^R ∈ O(n^δ)),
//! then the parallel greedy fixpoint is simulated in *compressed* rounds:
//! one MPC round advances R fixpoint iterations (a vertex's next-R-steps
//! status is a function of its R-ball), plus one round to publish updated
//! statuses (§2.1.4 steps 2–3).
//!
//! Exactness: the parallel fixpoint ("π-local minima join") computes the
//! sequential greedy MIS; compression changes only the round schedule.

use crate::algorithms::greedy_mis::ranks_from_permutation;
use crate::graph::Graph;
use crate::mpc::exponentiation::gather_balls;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;

/// Tunables for Algorithm 3.
#[derive(Debug, Clone)]
pub struct Alg3Params {
    /// Constant C in the gathered radius R = ⌈C · log n / log Δ'⌉
    /// (Lemma 21 picks C so that Δ^R ∈ O(n^δ); the memory budget still
    /// caps growth if the constant is too generous).
    pub radius_constant: f64,
    /// Hard cap on R regardless of the formula.
    pub max_radius: usize,
}

impl Default for Alg3Params {
    fn default() -> Self {
        Alg3Params { radius_constant: 0.5, max_radius: 64 }
    }
}

/// Observability for experiments.
#[derive(Debug, Clone, Default)]
pub struct Alg3Stats {
    /// Radius actually gathered.
    pub radius: usize,
    /// Rounds spent gathering.
    pub gather_rounds: usize,
    /// Fixpoint iterations needed.
    pub fixpoint_iters: usize,
    /// Compressed simulation rounds charged.
    pub simulate_rounds: usize,
}

/// Process `order` (prefix vertices in π order) with Algorithm 3.
pub fn alg3_process(
    g: &Graph,
    order: &[u32],
    blocked: &mut [bool],
    in_mis: &mut [bool],
    sim: &mut MpcSimulator,
    params: &Alg3Params,
) -> Alg3Stats {
    let mut stats = Alg3Stats::default();
    // Alive prefix vertices, with a compact relabeling for the fixpoint.
    let alive: Vec<u32> = order.iter().copied().filter(|&v| !blocked[v as usize]).collect();
    if alive.is_empty() {
        return stats;
    }
    let n = g.n();
    let mut keep = vec![false; n];
    for &v in &alive {
        keep[v as usize] = true;
    }
    let (sub, old_id) = g.induced_compact(&keep);

    // Rank of each sub-vertex = global π rank (prefix order preserved).
    let global_rank = {
        // order carries π order of the prefix; build rank over the prefix.
        let mut r = vec![u32::MAX; n];
        for (i, &v) in order.iter().enumerate() {
            r[v as usize] = i as u32;
        }
        r
    };
    let sub_perm: Vec<u32> = {
        // Permutation of sub vertices sorted by global rank. Ranks are
        // distinct (one per prefix position), so the unstable sort is
        // deterministic.
        let mut idx: Vec<u32> = (0..sub.n() as u32).collect();
        idx.sort_unstable_by_key(|&i| global_rank[old_id[i as usize] as usize]);
        idx
    };

    // Step 1 (Model 2): every vertex gathers its R-hop ball, with
    // R = ⌈C · log n / log Δ'⌉ (Lemma 21). Round cost, achieved radius
    // and memory feasibility are *measured*, not assumed.
    let delta_p = sub.max_degree().max(2) as f64;
    let target_radius = ((params.radius_constant * (sub.n().max(2) as f64).log2()
        / delta_p.log2())
    .ceil() as usize)
        .clamp(1, params.max_radius);
    let targets: Vec<u32> = (0..sub.n() as u32).collect();
    let balls = gather_balls(
        &sub,
        &targets,
        target_radius,
        sim.config.s_words,
        sim,
        "alg3/gather",
    );
    let radius = balls.radius.max(1);
    stats.radius = radius;
    stats.gather_rounds = balls.rounds;

    // Steps 2–3: compressed parallel-greedy fixpoint: R iterations per
    // compute round + 1 publish round.
    let rank = ranks_from_permutation(&sub_perm);
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Undecided,
        In,
        Out,
    }
    let mut st = vec![St::Undecided; sub.n()];
    let mut undecided = sub.n();
    let max_ball_words: Words =
        balls.balls.iter().map(|b| b.len() as Words).max().unwrap_or(1);
    while undecided > 0 {
        // One compressed MPC round = `radius` fixpoint iterations.
        for _ in 0..radius {
            if undecided == 0 {
                break;
            }
            let mut joiners: Vec<u32> = Vec::new();
            for v in 0..sub.n() as u32 {
                if st[v as usize] != St::Undecided {
                    continue;
                }
                let is_min = sub.neighbors(v).iter().all(|&u| {
                    st[u as usize] != St::Undecided || rank[u as usize] > rank[v as usize]
                });
                if is_min {
                    joiners.push(v);
                }
            }
            for &v in &joiners {
                st[v as usize] = St::In;
                undecided -= 1;
            }
            for &v in &joiners {
                for &u in sub.neighbors(v) {
                    if st[u as usize] == St::Undecided {
                        st[u as usize] = St::Out;
                        undecided -= 1;
                    }
                }
            }
            stats.fixpoint_iters += 1;
        }
        // Compute round (simulate R steps inside gathered balls) …
        sim.round(
            "alg3/simulate",
            max_ball_words,
            max_ball_words,
            sub.n() as Words,
            max_ball_words,
        );
        // … plus the status-publication round.
        let max_deg = sub.max_degree() as Words;
        sim.round("alg3/publish", max_deg, max_deg, 2 * sub.m() as Words, max_ball_words);
        stats.simulate_rounds += 2;
    }

    // Commit results to the global greedy state: MIS members first (so
    // their neighbors' `blocked` flags are set), then sanity-check Outs.
    for (i, &s) in st.iter().enumerate() {
        if s == St::In {
            let v = old_id[i];
            in_mis[v as usize] = true;
            for &u in g.neighbors(v) {
                blocked[u as usize] = true;
            }
        }
    }
    for (i, &s) in st.iter().enumerate() {
        match s {
            St::In => {}
            St::Out => debug_assert!(blocked[old_id[i] as usize]),
            St::Undecided => unreachable!("fixpoint must decide everything"),
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy_mis::{greedy_mis, is_valid_mis};
    use crate::graph::generators::{lambda_arboric, path};
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    fn run_alg3(g: &Graph, perm: &[u32]) -> (Vec<bool>, Alg3Stats, usize) {
        let cfg = MpcConfig::model2(g.n(), (g.n() + 2 * g.m()) as Words, 0.5);
        let mut sim = MpcSimulator::new(cfg);
        let mut blocked = vec![false; g.n()];
        let mut in_mis = vec![false; g.n()];
        let stats =
            alg3_process(g, perm, &mut blocked, &mut in_mis, &mut sim, &Alg3Params::default());
        (in_mis, stats, sim.n_rounds())
    }

    #[test]
    fn matches_sequential_greedy_exactly() {
        let mut rng = Rng::new(90);
        for trial in 0..10 {
            let g = lambda_arboric(150, 1 + trial % 4, &mut rng);
            let perm = rng.permutation(150);
            let expected = greedy_mis(&g, &perm);
            let (got, _, _) = run_alg3(&g, &perm);
            assert_eq!(got, expected, "trial {trial}");
        }
    }

    #[test]
    fn produces_valid_mis_and_counts_rounds() {
        let mut rng = Rng::new(91);
        let g = lambda_arboric(400, 2, &mut rng);
        let perm = rng.permutation(400);
        let (mis, stats, rounds) = run_alg3(&g, &perm);
        assert!(is_valid_mis(&g, &mis));
        assert!(stats.radius >= 1);
        assert!(rounds >= stats.gather_rounds + stats.simulate_rounds);
    }

    #[test]
    fn compression_reduces_rounds_vs_iters() {
        // With a generous memory budget the gathered radius is large, so
        // compressed rounds ≪ fixpoint iterations.
        let mut rng = Rng::new(92);
        let g = path(512);
        let perm = rng.permutation(512);
        let (_, stats, _) = run_alg3(&g, &perm);
        assert!(stats.radius >= 4, "radius {}", stats.radius);
        assert!(
            stats.simulate_rounds <= 2 * (stats.fixpoint_iters / stats.radius + 1),
            "simulate {} iters {} radius {}",
            stats.simulate_rounds,
            stats.fixpoint_iters,
            stats.radius
        );
    }

    #[test]
    fn partial_prefix_then_rest_is_exact() {
        let mut rng = Rng::new(93);
        let g = lambda_arboric(120, 3, &mut rng);
        let perm = rng.permutation(120);
        let expected = greedy_mis(&g, &perm);
        let cfg = MpcConfig::model2(120, 1000, 0.5);
        let mut sim = MpcSimulator::new(cfg);
        let mut blocked = vec![false; 120];
        let mut in_mis = vec![false; 120];
        let (a, b) = perm.split_at(40);
        alg3_process(&g, a, &mut blocked, &mut in_mis, &mut sim, &Alg3Params::default());
        alg3_process(&g, b, &mut blocked, &mut in_mis, &mut sim, &Alg3Params::default());
        assert_eq!(in_mis, expected);
    }

    #[test]
    fn empty_input_noop() {
        let g = Graph::empty(4);
        let cfg = MpcConfig::model2(4, 8, 0.5);
        let mut sim = MpcSimulator::new(cfg);
        let mut blocked = vec![false; 4];
        let mut in_mis = vec![false; 4];
        let stats =
            alg3_process(&g, &[], &mut blocked, &mut in_mis, &mut sim, &Alg3Params::default());
        assert_eq!(stats.fixpoint_iters, 0);
        assert_eq!(sim.n_rounds(), 0);
    }
}
