//! Algorithm 1: the phase driver — greedy MIS in the sublinear-memory
//! regime by prefix processing with degree halving (Lemma 22, Figure 3).
//!
//! Phase i processes the next `t_i = Θ(n log n / (Δ / 2^i))` vertices of
//! π as a *prefix graph* (induced on still-alive prefix vertices; its max
//! degree is O(log n) w.h.p. by Chernoff), using Algorithm 2 (Model 1) or
//! Algorithm 3 (Model 2) as the subroutine.  Lemma 22 guarantees the
//! residual graph's max degree halves per phase, so O(log Δ) phases
//! suffice; the driver *measures* the residual degree each phase instead
//! of assuming it (experiment E6).

use crate::algorithms::mpc_mis::alg2::{alg2_process, Alg2Params, Alg2Stats};
use crate::algorithms::mpc_mis::alg3::{alg3_process, Alg3Params, Alg3Stats};
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;

/// Which prefix-processing subroutine Algorithm 1 uses.
#[derive(Debug, Clone)]
pub enum Subroutine {
    /// Algorithm 2 (graph shattering) — Model 1.
    Alg2(Alg2Params),
    /// Algorithm 3 (exponentiation + compression) — Model 2.
    Alg3(Alg3Params),
}

impl Subroutine {
    pub fn name(&self) -> &'static str {
        match self {
            Subroutine::Alg2(_) => "alg2",
            Subroutine::Alg3(_) => "alg3",
        }
    }
}

/// Driver tunables.
#[derive(Debug, Clone)]
pub struct Alg1Params {
    /// Prefix constant: t_i = c_prefix · n · log2(n) / (Δ/2^i).
    pub c_prefix: f64,
    pub subroutine: Subroutine,
}

impl Default for Alg1Params {
    fn default() -> Self {
        Alg1Params { c_prefix: 1.0, subroutine: Subroutine::Alg2(Alg2Params::default()) }
    }
}

/// Per-phase observability (Figure 3 / Lemma 22 data).
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub phase: usize,
    /// Number of π positions consumed this phase.
    pub prefix_size: usize,
    /// Max degree of the prefix graph (should be O(log n), Chernoff).
    pub prefix_max_degree: usize,
    /// Max degree among still-alive unprocessed vertices afterwards
    /// (Lemma 22: ≤ Δ/2^{i+1} w.h.p.).
    pub residual_max_degree: usize,
    /// Rounds charged during this phase.
    pub rounds: usize,
}

/// Result of an Algorithm 1 run.
#[derive(Debug, Clone)]
pub struct Alg1Run {
    pub in_mis: Vec<bool>,
    pub phases: Vec<PhaseStat>,
    /// Max chunk-graph component sizes across all Alg2 invocations
    /// (empty when Alg3 is the subroutine) — Lemma 18's quantity.
    pub chunk_max_components: Vec<usize>,
    pub alg3_stats: Vec<Alg3Stats>,
}

/// Run Algorithm 1: greedy MIS w.r.t. `perm`, counting rounds on `sim`.
pub fn alg1_greedy_mis(
    g: &Graph,
    perm: &[u32],
    params: &Alg1Params,
    sim: &mut MpcSimulator,
) -> Alg1Run {
    let n = g.n();
    assert_eq!(perm.len(), n);
    let mut blocked = vec![false; n];
    let mut in_mis = vec![false; n];
    let mut run = Alg1Run {
        in_mis: Vec::new(),
        phases: Vec::new(),
        chunk_max_components: Vec::new(),
        alg3_stats: Vec::new(),
    };
    if n == 0 {
        return run;
    }

    let delta0 = g.max_degree().max(2);
    let logn = (n.max(2) as f64).log2();
    let pool = sim.pool();
    let mut pos = 0usize;
    let mut phase = 0usize;
    // Phase-recycled scratch: the alive list and both vertex-indexed
    // markers are reused across phases (cleared in place, capacity warm)
    // instead of reallocated O(n) per phase.
    let mut alive: Vec<u32> = Vec::new();
    let mut in_alive = vec![false; n];
    let mut unprocessed = vec![false; n];
    while pos < n {
        // Δ/2^i target for this phase (≥ 1).
        let target_delta = ((delta0 as f64) / (1u64 << phase.min(62)) as f64).max(1.0);
        let t_i =
            (((params.c_prefix * n as f64 * logn) / target_delta).ceil() as usize).clamp(1, n - pos);
        let order = &perm[pos..pos + t_i];
        pos += t_i;

        // Prefix-graph max degree (measured, for the Chernoff claim) — a
        // shard-parallel scan over the alive prefix vertices, with a flat
        // vertex-indexed membership marker (no hash structures on the
        // deterministic path).
        alive.clear();
        alive.extend(order.iter().copied().filter(|&v| !blocked[v as usize]));
        for &v in &alive {
            in_alive[v as usize] = true;
        }
        let prefix_max_degree = pool.max_by(alive.len(), |i| {
            g.neighbors(alive[i]).iter().filter(|&&u| in_alive[u as usize]).count() as u64
        }) as usize;
        // Un-mark only the set entries, leaving the marker clean for the
        // next phase.
        for &v in &alive {
            in_alive[v as usize] = false;
        }

        let rounds_before = sim.n_rounds();
        match &params.subroutine {
            Subroutine::Alg2(p) => {
                let stats: Alg2Stats =
                    alg2_process(g, order, &mut blocked, &mut in_mis, sim, p);
                run.chunk_max_components.extend(stats.chunk_max_components);
            }
            Subroutine::Alg3(p) => {
                let stats = alg3_process(g, order, &mut blocked, &mut in_mis, sim, p);
                run.alg3_stats.push(stats);
            }
        }

        // Residual degree among unprocessed alive vertices (Lemma 22) —
        // the heaviest per-phase scan, sharded across the pool.
        unprocessed.fill(false);
        for &v in &perm[pos..] {
            if !blocked[v as usize] {
                unprocessed[v as usize] = true;
            }
        }
        let residual_max_degree = pool.max_by(n, |v| {
            if !unprocessed[v] {
                return 0;
            }
            g.neighbors(v as u32).iter().filter(|&&u| unprocessed[u as usize]).count() as u64
        }) as usize;

        run.phases.push(PhaseStat {
            phase,
            prefix_size: t_i,
            prefix_max_degree,
            residual_max_degree,
            rounds: sim.n_rounds() - rounds_before,
        });
        phase += 1;
    }

    run.in_mis = in_mis;
    run
}

/// Baseline: direct Fischer–Noever simulation — one MPC round per
/// parallel-greedy fixpoint iteration (O(log n) rounds w.h.p.). This is
/// the "known" algorithm our Theorem 24 result is measured against.
pub fn direct_simulation_mis(g: &Graph, perm: &[u32], sim: &mut MpcSimulator) -> Vec<bool> {
    let (mis, iters) = crate::algorithms::greedy_mis::parallel_greedy_rounds(g, perm);
    let max_deg = g.max_degree() as Words;
    for i in 0..iters {
        sim.round(&format!("direct[{i}]"), max_deg, max_deg, 2 * g.m() as Words, max_deg + 1);
    }
    mis
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy_mis::greedy_mis;
    use crate::graph::generators::{barabasi_albert, lambda_arboric};
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    fn m1_sim(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(g.n(), (g.n() + 2 * g.m()) as Words, 0.5))
    }

    fn m2_sim(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model2(g.n(), (g.n() + 2 * g.m()) as Words, 0.5))
    }

    #[test]
    fn alg1_with_alg2_matches_sequential() {
        let mut rng = Rng::new(100);
        for trial in 0..6 {
            let g = lambda_arboric(200, 1 + trial % 3, &mut rng);
            let perm = rng.permutation(200);
            let mut sim = m1_sim(&g);
            let run = alg1_greedy_mis(&g, &perm, &Alg1Params::default(), &mut sim);
            assert_eq!(run.in_mis, greedy_mis(&g, &perm), "trial {trial}");
            assert!(!run.phases.is_empty());
        }
    }

    #[test]
    fn alg1_with_alg3_matches_sequential() {
        let mut rng = Rng::new(101);
        let g = barabasi_albert(300, 3, &mut rng);
        let perm = rng.permutation(300);
        let mut sim = m2_sim(&g);
        let params = Alg1Params {
            c_prefix: 1.0,
            subroutine: Subroutine::Alg3(Alg3Params::default()),
        };
        let run = alg1_greedy_mis(&g, &perm, &params, &mut sim);
        assert_eq!(run.in_mis, greedy_mis(&g, &perm));
    }

    #[test]
    fn residual_degree_decays() {
        // Lemma 22's shape: residual degrees shrink phase over phase.
        let mut rng = Rng::new(102);
        let g = barabasi_albert(3000, 4, &mut rng);
        let perm = rng.permutation(3000);
        let mut sim = m1_sim(&g);
        // Small prefixes to force several phases.
        let params = Alg1Params { c_prefix: 0.02, ..Default::default() };
        let run = alg1_greedy_mis(&g, &perm, &params, &mut sim);
        assert!(run.phases.len() >= 3, "want multiple phases, got {}", run.phases.len());
        let first = run.phases.first().unwrap().residual_max_degree;
        let last = run.phases.last().unwrap().residual_max_degree;
        assert!(last <= first, "residual degree should not grow: {first} -> {last}");
        assert_eq!(run.in_mis, greedy_mis(&g, &perm));
    }

    #[test]
    fn direct_simulation_matches_and_counts_rounds() {
        let mut rng = Rng::new(103);
        let g = lambda_arboric(150, 2, &mut rng);
        let perm = rng.permutation(150);
        let mut sim = m1_sim(&g);
        let mis = direct_simulation_mis(&g, &perm, &mut sim);
        assert_eq!(mis, greedy_mis(&g, &perm));
        assert!(sim.n_rounds() >= 1);
    }

    #[test]
    fn trivial_graphs() {
        let g = Graph::empty(3);
        let perm = vec![2u32, 0, 1];
        let mut sim = m1_sim(&g);
        let run = alg1_greedy_mis(&g, &perm, &Alg1Params::default(), &mut sim);
        assert_eq!(run.in_mis, vec![true, true, true]);
    }
}
