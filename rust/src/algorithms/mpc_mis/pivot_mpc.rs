//! MPC PIVOT: Algorithm 1's greedy MIS plus the one-round cluster join —
//! the algorithm behind Corollaries 13/28.

use crate::algorithms::mpc_mis::alg1::{alg1_greedy_mis, Alg1Params, Alg1Run};
use crate::algorithms::pivot::pivot_from_mis;
use crate::cluster::Clustering;
use crate::graph::Graph;
use crate::mpc::memory::Words;
use crate::mpc::simulator::MpcSimulator;

/// Result of an MPC PIVOT run.
#[derive(Debug, Clone)]
pub struct MpcPivotRun {
    pub clustering: Clustering,
    pub mis_run: Alg1Run,
    /// Total rounds including the cluster-join round.
    pub rounds: usize,
}

/// Run PIVOT in the MPC model: simulate greedy MIS w.r.t. `perm` via
/// Algorithm 1, then one more round in which every non-MIS vertex joins
/// its earliest-in-π MIS neighbor.
pub fn mpc_pivot(
    g: &Graph,
    perm: &[u32],
    params: &Alg1Params,
    sim: &mut MpcSimulator,
) -> MpcPivotRun {
    let mis_run = alg1_greedy_mis(g, perm, params, sim);
    // Cluster-join round: each vertex hears the (rank, id) of MIS
    // neighbors — one aggregate over edges.
    let max_deg = g.max_degree() as Words;
    sim.round("pivot/join", max_deg, max_deg, 2 * g.m() as Words, max_deg + 2);
    let clustering = pivot_from_mis(g, perm, &mis_run.in_mis);
    MpcPivotRun { clustering, mis_run, rounds: sim.n_rounds() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::mpc_mis::alg1::Subroutine;
    use crate::algorithms::mpc_mis::alg3::Alg3Params;
    use crate::algorithms::pivot::pivot;
    use crate::graph::generators::lambda_arboric;
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    #[test]
    fn mpc_pivot_equals_sequential_pivot() {
        let mut rng = Rng::new(110);
        for trial in 0..6 {
            let g = lambda_arboric(180, 1 + trial % 3, &mut rng);
            let perm = rng.permutation(180);
            let cfg = MpcConfig::model1(180, (180 + 2 * g.m()) as Words, 0.5);
            let mut sim = MpcSimulator::new(cfg);
            let run = mpc_pivot(&g, &perm, &Alg1Params::default(), &mut sim);
            assert_eq!(
                run.clustering.normalize(),
                pivot(&g, &perm).normalize(),
                "trial {trial}: MPC PIVOT must equal sequential PIVOT"
            );
            assert_eq!(run.rounds, sim.n_rounds());
        }
    }

    #[test]
    fn model2_variant_also_exact() {
        let mut rng = Rng::new(111);
        let g = lambda_arboric(150, 2, &mut rng);
        let perm = rng.permutation(150);
        let cfg = MpcConfig::model2(150, (150 + 2 * g.m()) as Words, 0.5);
        let mut sim = MpcSimulator::new(cfg);
        let params = Alg1Params {
            c_prefix: 1.0,
            subroutine: Subroutine::Alg3(Alg3Params::default()),
        };
        let run = mpc_pivot(&g, &perm, &params, &mut sim);
        assert_eq!(run.clustering.normalize(), pivot(&g, &perm).normalize());
    }
}
