//! Local-search refinement — an extension toward the paper's Question 3
//! ("can one do strictly better than 3 in a distributed setting?").
//!
//! Best-move local search over single-vertex relocations: each pass, every
//! vertex considers moving to the cluster of one of its positive
//! neighbors (or to a fresh singleton) and takes the move with the best
//! cost delta.  Deltas are computed locally in O(deg(v)) from cluster
//! sizes and neighbor-label counts, so a pass is O(n + m) — and the
//! *sequential-scan* variant below is exactly the kind of local update
//! Lemma 25's proof performs (singleton extraction is one of the
//! candidate moves).
//!
//! Used as (a) an ablation showing how much slack PIVOT leaves on real
//! workloads and (b) a post-processing pass that preserves all structural
//! guarantees (cost never increases).

use crate::cluster::cost::cost;
use crate::cluster::Clustering;
use crate::graph::Graph;

/// Result with pass observability.
#[derive(Debug, Clone)]
pub struct LocalSearchRun {
    pub clustering: Clustering,
    pub passes: usize,
    pub moves: usize,
    pub initial_cost: u64,
    pub final_cost: u64,
}

/// Refine `input` by single-vertex best moves until a pass makes no move
/// or `max_passes` is hit. The cost never increases.
pub fn local_search(g: &Graph, input: &Clustering, max_passes: usize) -> LocalSearchRun {
    let n = g.n();
    let norm = input.normalize();
    let mut labels: Vec<u32> = norm.labels().to_vec();
    let mut next_free = labels.iter().copied().max().map(|x| x + 1).unwrap_or(0);
    let mut sizes: std::collections::HashMap<u32, u64> = std::collections::HashMap::new();
    for &l in &labels {
        *sizes.entry(l).or_insert(0) += 1;
    }

    let initial_cost = cost(g, input).total();
    let mut moves = 0usize;
    let mut passes = 0usize;

    for _ in 0..max_passes {
        passes += 1;
        let mut moved_this_pass = 0usize;
        for v in 0..n as u32 {
            let current = labels[v as usize];
            // Count positive neighbors per adjacent cluster.
            let mut nb_count: std::collections::HashMap<u32, u64> =
                std::collections::HashMap::new();
            for &u in g.neighbors(v) {
                *nb_count.entry(labels[u as usize]).or_insert(0) += 1;
            }
            let deg_in_current = nb_count.get(&current).copied().unwrap_or(0);
            let size_current = sizes[&current];
            // Cost contribution of v in cluster C of size s with d
            // positive neighbors inside: (deg - d) positive disagreements
            // + (s - 1 - d) negative ones. The (deg) term is constant
            // across candidate moves, so compare f(C) = (s-1) - 2d.
            let f_current = (size_current - 1) as i64 - 2 * deg_in_current as i64;
            // Candidates: neighbor clusters + a fresh singleton (f = 0).
            let mut best_label = current;
            let mut best_f = f_current;
            if 0 < best_f {
                best_label = u32::MAX; // singleton marker
                best_f = 0;
            }
            for (&cand, &d) in &nb_count {
                if cand == current {
                    continue;
                }
                let s = sizes[&cand];
                let f = s as i64 - 2 * d as i64; // joining: size becomes s+1
                if f < best_f {
                    best_f = f;
                    best_label = cand;
                }
            }
            if best_label != current {
                let target = if best_label == u32::MAX {
                    let fresh = next_free;
                    next_free += 1;
                    fresh
                } else {
                    best_label
                };
                *sizes.get_mut(&current).unwrap() -= 1;
                *sizes.entry(target).or_insert(0) += 1;
                labels[v as usize] = target;
                moved_this_pass += 1;
            }
        }
        moves += moved_this_pass;
        if moved_this_pass == 0 {
            break;
        }
    }

    let clustering = Clustering::from_labels(labels);
    let final_cost = cost(g, &clustering).total();
    debug_assert!(final_cost <= initial_cost, "local search increased cost");
    LocalSearchRun { clustering, passes, moves, initial_cost, final_cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pivot::pivot_random;
    use crate::cluster::exact::exact_cost;
    use crate::graph::generators::{clique, lambda_arboric, path};
    use crate::util::rng::Rng;

    #[test]
    fn never_increases_cost() {
        let mut rng = Rng::new(310);
        for trial in 0..10 {
            let g = lambda_arboric(200, 1 + trial % 3, &mut rng);
            let start = pivot_random(&g, &mut rng);
            let run = local_search(&g, &start, 20);
            assert!(run.final_cost <= run.initial_cost, "trial {trial}");
            assert_eq!(cost(&g, &run.clustering).total(), run.final_cost);
        }
    }

    #[test]
    fn merges_a_split_clique() {
        // Start with a K6 split in two halves: local search should merge.
        let g = clique(6);
        let start = Clustering::from_labels(vec![0, 0, 0, 1, 1, 1]);
        let run = local_search(&g, &start, 20);
        assert_eq!(run.final_cost, 0);
        assert_eq!(run.clustering.n_clusters(), 1);
    }

    #[test]
    fn splits_a_bad_merge() {
        // Two disjoint edges forced into one cluster: split to optimal.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (2, 3)]);
        let start = Clustering::single_cluster(4);
        let run = local_search(&g, &start, 20);
        assert_eq!(run.final_cost, 0);
    }

    #[test]
    fn improves_toward_optimum_on_small_instances() {
        let mut rng = Rng::new(311);
        let mut at_opt = 0;
        let trials = 15;
        for _ in 0..trials {
            let g = lambda_arboric(11, 2, &mut rng);
            let opt = exact_cost(&g);
            let start = pivot_random(&g, &mut rng);
            let run = local_search(&g, &start, 30);
            assert!(run.final_cost >= opt);
            if run.final_cost == opt {
                at_opt += 1;
            }
        }
        assert!(at_opt >= trials / 2, "local search should often reach OPT: {at_opt}/{trials}");
    }

    #[test]
    fn fixed_point_on_path_opt() {
        let g = path(4);
        let opt = Clustering::from_labels(vec![0, 0, 1, 1]);
        let run = local_search(&g, &opt, 5);
        assert_eq!(run.final_cost, 1);
        assert_eq!(run.moves, 0);
    }
}
