//! Local-search refinement — an extension toward the paper's Question 3
//! ("can one do strictly better than 3 in a distributed setting?").
//!
//! Best-move local search over single-vertex relocations: each pass, every
//! vertex considers moving to the cluster of one of its positive
//! neighbors (or to a fresh singleton) and takes the move with the best
//! cost delta.  Deltas are computed locally in O(deg(v)) from cluster
//! sizes and neighbor-label counts, so a pass is O(n + m) — and the
//! *sequential-scan* variant below is exactly the kind of local update
//! Lemma 25's proof performs (singleton extraction is one of the
//! candidate moves).
//!
//! Used as (a) an ablation showing how much slack PIVOT leaves on real
//! workloads and (b) a post-processing pass that preserves all structural
//! guarantees (cost never increases).

use crate::cluster::cost::cost;
use crate::cluster::Clustering;
use crate::graph::Graph;

/// Result with pass observability.
#[derive(Debug, Clone)]
pub struct LocalSearchRun {
    pub clustering: Clustering,
    pub passes: usize,
    pub moves: usize,
    pub initial_cost: u64,
    pub final_cost: u64,
}

/// Refine `input` by single-vertex best moves until a pass makes no move
/// or `max_passes` is hit. The cost never increases.
///
/// Perf note (§Perf P9): the labels are normalized to `[0, r)` up front
/// and fresh singleton labels are recycled through a free list, so every
/// label stays below `n + 2` — which lets the hot loop replace the three
/// `HashMap`s (cluster sizes, per-vertex neighbor-label counts, and the
/// size updates on move) with flat `Vec` tallies. The neighbor counts
/// use scatter/gather with a `touched` list, so each vertex costs
/// O(deg(v)) with no hashing and no per-vertex allocation. Candidate
/// iteration follows adjacency order, making tie-breaking deterministic
/// (the `HashMap` version's iteration order was not).
pub fn local_search(g: &Graph, input: &Clustering, max_passes: usize) -> LocalSearchRun {
    let n = g.n();
    let norm = input.normalize();
    let mut labels: Vec<u32> = norm.labels().to_vec();
    // Normalized labels are < n; recycled fresh labels never push the
    // space past n + 1 (a fresh id is only minted when every smaller id
    // is live, and at most n labels are ever live at once).
    let cap = n + 2;
    let mut next_free = labels.iter().copied().max().map(|x| x + 1).unwrap_or(0);
    let mut free: Vec<u32> = Vec::new();
    let mut sizes: Vec<u64> = vec![0; cap];
    for &l in &labels {
        sizes[l as usize] += 1;
    }
    // Scatter/gather workspace for per-vertex neighbor-label counts.
    let mut counts: Vec<u64> = vec![0; cap];
    let mut touched: Vec<u32> = Vec::new();

    let initial_cost = cost(g, input).total();
    let mut moves = 0usize;
    let mut passes = 0usize;

    for _ in 0..max_passes {
        passes += 1;
        let mut moved_this_pass = 0usize;
        for v in 0..n as u32 {
            let current = labels[v as usize];
            // Count positive neighbors per adjacent cluster.
            for &u in g.neighbors(v) {
                let l = labels[u as usize];
                if counts[l as usize] == 0 {
                    touched.push(l);
                }
                counts[l as usize] += 1;
            }
            let deg_in_current = counts[current as usize];
            let size_current = sizes[current as usize];
            // Cost contribution of v in cluster C of size s with d
            // positive neighbors inside: (deg - d) positive disagreements
            // + (s - 1 - d) negative ones. The (deg) term is constant
            // across candidate moves, so compare f(C) = (s-1) - 2d.
            let f_current = (size_current - 1) as i64 - 2 * deg_in_current as i64;
            // Candidates: neighbor clusters + a fresh singleton (f = 0).
            let mut best_label = current;
            let mut best_f = f_current;
            if 0 < best_f {
                best_label = u32::MAX; // singleton marker
                best_f = 0;
            }
            for &cand in &touched {
                if cand == current {
                    continue;
                }
                let d = counts[cand as usize];
                let s = sizes[cand as usize];
                let f = s as i64 - 2 * d as i64; // joining: size becomes s+1
                if f < best_f {
                    best_f = f;
                    best_label = cand;
                }
            }
            if best_label != current {
                let target = if best_label == u32::MAX {
                    free.pop().unwrap_or_else(|| {
                        let fresh = next_free;
                        next_free += 1;
                        fresh
                    })
                } else {
                    best_label
                };
                sizes[current as usize] -= 1;
                if sizes[current as usize] == 0 {
                    free.push(current);
                }
                sizes[target as usize] += 1;
                labels[v as usize] = target;
                moved_this_pass += 1;
            }
            for &l in &touched {
                counts[l as usize] = 0;
            }
            touched.clear();
        }
        moves += moved_this_pass;
        if moved_this_pass == 0 {
            break;
        }
    }

    let clustering = Clustering::from_labels(labels);
    let final_cost = cost(g, &clustering).total();
    debug_assert!(final_cost <= initial_cost, "local search increased cost");
    LocalSearchRun { clustering, passes, moves, initial_cost, final_cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pivot::pivot_random;
    use crate::cluster::exact::exact_cost;
    use crate::graph::generators::{clique, lambda_arboric, path};
    use crate::util::rng::Rng;

    #[test]
    fn never_increases_cost() {
        let mut rng = Rng::new(310);
        for trial in 0..10 {
            let g = lambda_arboric(200, 1 + trial % 3, &mut rng);
            let start = pivot_random(&g, &mut rng);
            let run = local_search(&g, &start, 20);
            assert!(run.final_cost <= run.initial_cost, "trial {trial}");
            assert_eq!(cost(&g, &run.clustering).total(), run.final_cost);
        }
    }

    #[test]
    fn merges_a_split_clique() {
        // Start with a K6 split in two halves: local search should merge.
        let g = clique(6);
        let start = Clustering::from_labels(vec![0, 0, 0, 1, 1, 1]);
        let run = local_search(&g, &start, 20);
        assert_eq!(run.final_cost, 0);
        assert_eq!(run.clustering.n_clusters(), 1);
    }

    #[test]
    fn splits_a_bad_merge() {
        // Two disjoint edges forced into one cluster: split to optimal.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (2, 3)]);
        let start = Clustering::single_cluster(4);
        let run = local_search(&g, &start, 20);
        assert_eq!(run.final_cost, 0);
    }

    #[test]
    fn improves_toward_optimum_on_small_instances() {
        let mut rng = Rng::new(311);
        let mut at_opt = 0;
        let trials = 15;
        for _ in 0..trials {
            let g = lambda_arboric(11, 2, &mut rng);
            let opt = exact_cost(&g);
            let start = pivot_random(&g, &mut rng);
            let run = local_search(&g, &start, 30);
            assert!(run.final_cost >= opt);
            if run.final_cost == opt {
                at_opt += 1;
            }
        }
        assert!(at_opt >= trials / 2, "local search should often reach OPT: {at_opt}/{trials}");
    }

    #[test]
    fn fixed_point_on_path_opt() {
        let g = path(4);
        let opt = Clustering::from_labels(vec![0, 0, 1, 1]);
        let run = local_search(&g, &opt, 5);
        assert_eq!(run.final_cost, 1);
        assert_eq!(run.moves, 0);
    }
}
