//! Corollary 32: the O(λ²)-approximate deterministic algorithm in O(1)
//! MPC rounds.
//!
//! Rule: every connected component (w.r.t. E+) that is a clique becomes a
//! cluster; all other vertices become singletons.  MPC implementation
//! (as in the paper's proof): vertices with degree ≥ 2λ cannot be in any
//! clique component (cliques in a λ-arboric graph have ≤ 2λ vertices), so
//! after ignoring them the candidate components have bounded size; the
//! clique test is two broadcast-tree aggregates (component id = min
//! vertex id via convergecast; "is my neighborhood exactly the component"
//! via sum) — O(1/δ) routed rounds, charged for real.

use crate::cluster::Clustering;
use crate::graph::components::{components, is_clique};
use crate::graph::Graph;
use crate::mpc::broadcast::{Aggregate, BroadcastTree};
use crate::mpc::memory::Words;
use crate::mpc::router::Router;
use crate::mpc::simulator::MpcSimulator;

/// Result with round observability.
#[derive(Debug, Clone)]
pub struct SimpleRun {
    pub clustering: Clustering,
    pub rounds: usize,
    /// Number of clique components clustered.
    pub clique_clusters: usize,
}

/// Run the simple algorithm, charging its constant number of rounds.
pub fn simple_clustering(g: &Graph, lambda: usize, sim: &mut MpcSimulator) -> SimpleRun {
    let rounds_before = sim.n_rounds();
    let n = g.n();
    // Degree filter (one local round: degrees are known from input
    // placement, broadcasting the λ threshold is part of setup).
    let max_clique = 2 * lambda;
    let keep: Vec<bool> = (0..n as u32).map(|v| g.degree(v) < max_clique).collect();
    let filtered = g.induced_in_place(&keep);

    // Component labels + clique checks (the O(1)-round MPC part; executed
    // here centrally, charged as the broadcast-tree passes the proof
    // prescribes: 2 convergecasts + 1 broadcast).
    let comps = components(&filtered);
    let members = comps.members();

    let router = Router::new(sim.config.machines);
    let tree = BroadcastTree::new(sim.config.machines, sim.config.s_words);
    // Convergecast 1: global max component size (feasibility signal).
    let mut per_machine = vec![0u64; sim.config.machines];
    for (i, m) in members.iter().enumerate() {
        per_machine[i % sim.config.machines] =
            per_machine[i % sim.config.machines].max(m.len() as u64);
    }
    let _max_comp = tree.aggregate(sim, &router, &per_machine, Aggregate::Max);
    // Broadcast: commit decision round.
    tree.broadcast(sim, &router, 1);

    let mut labels: Vec<u32> = (0..n as u32).collect();
    let mut clique_clusters = 0usize;
    for m in &members {
        if m.len() >= 2 && m.len() <= max_clique && is_clique(&filtered, m) {
            // All members keep[*] == true by construction of `filtered`;
            // but a filtered vertex may have had edges to removed
            // vertices — then its component in g is bigger and not a
            // clique component of g. Check original degrees.
            let genuine = m
                .iter()
                .all(|&v| keep[v as usize] && g.degree(v) == m.len() - 1);
            if genuine {
                let label = m[0];
                for &v in m {
                    labels[v as usize] = label;
                }
                clique_clusters += 1;
            }
        }
    }
    // Final status round (cluster labels to neighbors).
    let max_deg = g.max_degree() as Words;
    sim.round("simple/commit", max_deg.max(1), max_deg.max(1), 2 * g.m() as Words, max_deg + 1);

    SimpleRun {
        clustering: Clustering::from_labels(labels),
        rounds: sim.n_rounds() - rounds_before,
        clique_clusters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cost::cost;
    use crate::cluster::exact::exact_cost;
    use crate::graph::generators::{barbell, disjoint_cliques, lambda_arboric, path};
    use crate::mpc::model::MpcConfig;
    use crate::util::rng::Rng;

    fn sim(g: &Graph) -> MpcSimulator {
        MpcSimulator::new(MpcConfig::model1(
            g.n().max(2),
            (g.n() + 2 * g.m()).max(4) as Words,
            0.5,
        ))
    }

    #[test]
    fn clique_components_become_clusters() {
        let g = disjoint_cliques(4, 5); // λ(K5) = 3
        let mut s = sim(&g);
        let run = simple_clustering(&g, 3, &mut s);
        assert_eq!(run.clique_clusters, 4);
        assert_eq!(cost(&g, &run.clustering).total(), 0);
    }

    #[test]
    fn non_cliques_become_singletons() {
        let g = path(6);
        let mut s = sim(&g);
        let run = simple_clustering(&g, 1, &mut s);
        // P6 is not a clique (except pairs are not components) ⇒ all
        // singletons except... P6 is one non-clique component: singletons.
        assert_eq!(run.clique_clusters, 0);
        assert_eq!(cost(&g, &run.clustering).total(), g.m() as u64);
    }

    #[test]
    fn pairs_are_cliques() {
        // A single edge is a K2 component: clustered together.
        let g = Graph::from_edges(4, &[(0, 1)]);
        let mut s = sim(&g);
        let run = simple_clustering(&g, 1, &mut s);
        assert!(run.clustering.same_cluster(0, 1));
        assert_eq!(run.clique_clusters, 1);
        assert_eq!(cost(&g, &run.clustering).total(), 0);
    }

    #[test]
    fn constant_rounds() {
        // Round count must not grow with n (the O(1) claim).
        let mut rng = Rng::new(170);
        let small = lambda_arboric(100, 2, &mut rng);
        let large = lambda_arboric(5000, 2, &mut rng);
        let mut s1 = sim(&small);
        let r1 = simple_clustering(&small, 2, &mut s1).rounds;
        let mut s2 = sim(&large);
        let r2 = simple_clustering(&large, 2, &mut s2).rounds;
        assert!(r2 <= r1 + 3, "rounds grew with n: {r1} -> {r2}");
        assert!(r2 <= 12, "not constant: {r2}");
    }

    #[test]
    fn barbell_ratio_is_lambda_squared_shape() {
        // Remark 33 tightness: barbell K_λ–K_λ. OPT = 1; simple pays ≈ λ².
        for lambda in [3usize, 5, 8] {
            let g = barbell(lambda);
            let mut s = sim(&g);
            let run = simple_clustering(&g, lambda, &mut s);
            let got = cost(&g, &run.clustering).total();
            // The bridge makes the two cliques one non-clique component ⇒
            // everything singleton ⇒ cost = m = 2·C(λ,2)+1 ≈ λ².
            assert_eq!(got, g.m() as u64);
            if 2 * lambda <= 12 {
                let opt = exact_cost(&g);
                assert_eq!(opt, 1);
                let ratio = got as f64 / opt as f64;
                assert!(ratio >= (lambda * (lambda - 1)) as f64, "ratio {ratio} too small");
            }
        }
    }
}
