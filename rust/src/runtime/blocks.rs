//! Dense-block packing: the protocol between the sparse graph world and
//! the fixed-shape AOT kernels.
//!
//! The exported XLA executables work on dense blocks of `AOT_N` (=256)
//! vertices (`python/compile/model.py`).  To score a clustering of an
//! arbitrary graph *exactly* with them, vertices are packed into blocks
//! such that **no cluster crosses a block boundary** (clusters are tiny —
//! Lemma 25 bounds them by 4λ−2 — so first-fit-decreasing packs well).
//! Then:
//!
//! * intra-block costs come from the dense kernel per block;
//! * every cross-block positive edge joins two different clusters by
//!   construction ⇒ it is exactly one positive disagreement;
//! * cross-block negative pairs join different clusters ⇒ never disagree.
//!
//! Total cost = Σ_blocks dense(block) + #cross-block positive edges, with
//! no approximation.

use crate::cluster::Clustering;
use crate::graph::Graph;

/// Block size of the AOT artifacts — must match `python/compile/kernels/
/// common.py::AOT_N` (checked against `artifacts/manifest.json` at load).
pub const BLOCK_N: usize = 256;

/// Batch size of the batched scorer artifact (`AOT_BATCH`).
pub const BLOCK_BATCH: usize = 8;

/// One dense block: up to BLOCK_N vertices plus their block-local data.
#[derive(Debug, Clone)]
pub struct Block {
    /// Original vertex ids, in block order.
    pub vertices: Vec<u32>,
}

/// A full packing of a clustering into blocks.
#[derive(Debug, Clone)]
pub struct BlockPlan {
    pub blocks: Vec<Block>,
    /// Positive edges whose endpoints fall in different blocks.
    pub cross_edges: u64,
}

/// Pack the clusters of `clustering` into blocks of ≤ BLOCK_N vertices,
/// first-fit-decreasing.  Fails if any single cluster exceeds BLOCK_N
/// (callers then use the sparse path; the paper's algorithms never emit
/// such clusters on bounded-arboricity inputs).
pub fn plan_blocks(g: &Graph, clustering: &Clustering) -> Result<BlockPlan, String> {
    let members = clustering.members();
    let mut order: Vec<usize> = (0..members.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(members[i].len()));

    let mut blocks: Vec<Vec<u32>> = Vec::new();
    let mut loads: Vec<usize> = Vec::new();
    for &ci in &order {
        let c = &members[ci];
        if c.len() > BLOCK_N {
            return Err(format!(
                "cluster of size {} exceeds dense block capacity {}",
                c.len(),
                BLOCK_N
            ));
        }
        // First fit.
        match loads.iter().position(|&l| l + c.len() <= BLOCK_N) {
            Some(b) => {
                blocks[b].extend_from_slice(c);
                loads[b] += c.len();
            }
            None => {
                blocks.push(c.clone());
                loads.push(c.len());
            }
        }
    }

    // Cross-block edge count.
    let mut block_of = vec![u32::MAX; g.n()];
    for (b, blk) in blocks.iter().enumerate() {
        for &v in blk {
            block_of[v as usize] = b as u32;
        }
    }
    let cross_edges = g
        .edges()
        .filter(|&(u, v)| block_of[u as usize] != block_of[v as usize])
        .count() as u64;

    Ok(BlockPlan {
        blocks: blocks.into_iter().map(|vertices| Block { vertices }).collect(),
        cross_edges,
    })
}

/// Dense tensors of one block in the kernels' layout: returns
/// (adj f32[N·N], onehot f32[N·N], valid f32[N]).
pub fn block_tensors(
    g: &Graph,
    clustering: &Clustering,
    block: &Block,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = BLOCK_N;
    let k = block.vertices.len();
    assert!(k <= n);
    let mut adj = vec![0f32; n * n];
    let mut onehot = vec![0f32; n * n];
    let mut valid = vec![0f32; n];

    let mut local_of: std::collections::HashMap<u32, usize> =
        std::collections::HashMap::with_capacity(k);
    for (i, &v) in block.vertices.iter().enumerate() {
        local_of.insert(v, i);
        valid[i] = 1.0;
    }
    // Block-local cluster columns.
    let mut col_of: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
    for (i, &v) in block.vertices.iter().enumerate() {
        let label = clustering.label(v);
        let next = col_of.len();
        let col = *col_of.entry(label).or_insert(next);
        onehot[i * n + col] = 1.0;
    }
    for (i, &v) in block.vertices.iter().enumerate() {
        for &u in g.neighbors(v) {
            if let Some(&j) = local_of.get(&u) {
                adj[i * n + j] = 1.0;
            }
        }
    }
    (adj, onehot, valid)
}

/// Dense tensors for a whole (small) graph padded to BLOCK_N — the
/// single-block fast path used by the batched scorer and the triangle
/// kernel. Requires `g.n() <= BLOCK_N`.
pub fn whole_graph_tensors(g: &Graph) -> (Vec<f32>, Vec<f32>) {
    let n = BLOCK_N;
    assert!(g.n() <= n, "graph exceeds single dense block");
    let mut adj = vec![0f32; n * n];
    let mut valid = vec![0f32; n];
    for v in 0..g.n() as u32 {
        valid[v as usize] = 1.0;
        for &u in g.neighbors(v) {
            adj[v as usize * n + u as usize] = 1.0;
        }
    }
    (adj, valid)
}

/// One-hot tensor of a clustering of a single-block graph.
pub fn whole_graph_onehot(g: &Graph, clustering: &Clustering) -> Vec<f32> {
    let n = BLOCK_N;
    assert!(g.n() <= n);
    let norm = clustering.normalize();
    let mut onehot = vec![0f32; n * n];
    for v in 0..g.n() {
        onehot[v * n + norm.label(v as u32) as usize] = 1.0;
    }
    onehot
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pivot::pivot_random;
    use crate::graph::generators::lambda_arboric;
    use crate::util::rng::Rng;

    #[test]
    fn plan_keeps_clusters_whole() {
        let mut rng = Rng::new(210);
        let g = lambda_arboric(600, 2, &mut rng);
        let c = pivot_random(&g, &mut rng);
        let plan = plan_blocks(&g, &c).unwrap();
        // Every cluster fully inside one block.
        let mut block_of = std::collections::HashMap::new();
        for (b, blk) in plan.blocks.iter().enumerate() {
            for &v in &blk.vertices {
                block_of.insert(v, b);
            }
        }
        assert_eq!(block_of.len(), 600, "every vertex packed exactly once");
        for members in c.members() {
            let b0 = block_of[&members[0]];
            assert!(members.iter().all(|v| block_of[v] == b0), "cluster split across blocks");
        }
        for blk in &plan.blocks {
            assert!(blk.vertices.len() <= BLOCK_N);
        }
    }

    #[test]
    fn cross_edges_counted() {
        let mut rng = Rng::new(211);
        let g = lambda_arboric(600, 3, &mut rng);
        let c = pivot_random(&g, &mut rng);
        let plan = plan_blocks(&g, &c).unwrap();
        let mut block_of = vec![0usize; 600];
        for (b, blk) in plan.blocks.iter().enumerate() {
            for &v in &blk.vertices {
                block_of[v as usize] = b;
            }
        }
        let manual = g
            .edges()
            .filter(|&(u, v)| block_of[u as usize] != block_of[v as usize])
            .count() as u64;
        assert_eq!(plan.cross_edges, manual);
    }

    #[test]
    fn oversized_cluster_rejected() {
        let g = Graph::empty(300);
        let c = crate::cluster::Clustering::single_cluster(300);
        assert!(plan_blocks(&g, &c).is_err());
    }

    #[test]
    fn tensors_are_symmetric_and_padded() {
        let mut rng = Rng::new(212);
        let g = lambda_arboric(100, 2, &mut rng);
        let c = pivot_random(&g, &mut rng);
        let plan = plan_blocks(&g, &c).unwrap();
        let (adj, onehot, valid) = block_tensors(&g, &c, &plan.blocks[0]);
        let n = BLOCK_N;
        let k = plan.blocks[0].vertices.len();
        assert_eq!(valid.iter().filter(|&&x| x > 0.0).count(), k);
        for i in 0..n {
            assert_eq!(adj[i * n + i], 0.0, "no self loops");
            for j in 0..n {
                assert_eq!(adj[i * n + j], adj[j * n + i], "symmetry");
            }
        }
        // Padded rows of onehot are zero.
        for i in k..n {
            assert!(onehot[i * n..(i + 1) * n].iter().all(|&x| x == 0.0));
        }
    }
}
