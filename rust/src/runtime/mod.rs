//! Numeric runtime: PJRT execution of the AOT JAX/Pallas artifacts, with
//! a bit-identical native fallback.
//!
//! * [`client`] — compile-once PJRT wrappers for the three artifacts;
//! * [`blocks`] — the dense-block packing protocol (exact, cluster-whole);
//! * [`fallback`] — pure-Rust twin of the kernels.
//!
//! [`CostEngine`] is the façade the coordinator and benches use: it
//! dispatches to PJRT when `artifacts/` is present and to the native twin
//! otherwise, with identical results either way (asserted by integration
//! tests).

pub mod blocks;
pub mod client;
pub mod fallback;

use crate::util::error::Result;

use crate::cluster::cost::Cost;
use crate::cluster::Clustering;
use crate::graph::Graph;
use blocks::{block_tensors, plan_blocks, whole_graph_onehot, whole_graph_tensors, BLOCK_BATCH, BLOCK_N};
use client::PjrtEngine;

/// Which backend a [`CostEngine`] ended up with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Pjrt,
    Native,
}

/// Dense scoring engine over the AOT block protocol.
pub enum CostEngine {
    Pjrt(PjrtEngine),
    Native,
}

impl CostEngine {
    /// Load PJRT from `dir` if the artifacts exist, else native fallback.
    ///
    /// Built without the `pjrt` cargo feature (the default — the `xla`
    /// crate is unavailable offline), loading always fails and this falls
    /// back to the bit-identical native runtime.
    pub fn auto(dir: &std::path::Path) -> CostEngine {
        if PjrtEngine::artifacts_present(dir) {
            match PjrtEngine::load(dir) {
                Ok(engine) => return CostEngine::Pjrt(engine),
                Err(err) => {
                    eprintln!("warning: PJRT load failed ({err}); using native fallback");
                }
            }
        }
        CostEngine::Native
    }

    /// Default artifact location (`artifacts/` under the repo root).
    pub fn auto_default() -> CostEngine {
        Self::auto(std::path::Path::new("artifacts"))
    }

    pub fn native() -> CostEngine {
        CostEngine::Native
    }

    pub fn kind(&self) -> BackendKind {
        match self {
            CostEngine::Pjrt(_) => BackendKind::Pjrt,
            CostEngine::Native => BackendKind::Native,
        }
    }

    /// Exact disagreement cost via the dense block protocol.
    ///
    /// Falls back to the sparse formula if a cluster exceeds the block
    /// capacity (cannot happen for Lemma 25-shaped clusterings).
    pub fn cost(&self, g: &Graph, clustering: &Clustering) -> Result<Cost> {
        let plan = match plan_blocks(g, clustering) {
            Ok(p) => p,
            Err(_) => return Ok(crate::cluster::cost::cost(g, clustering)),
        };
        let mut pos_total = plan.cross_edges as f64;
        let mut neg_total = 0f64;
        for b in &plan.blocks {
            let (adj, onehot, valid) = block_tensors(g, clustering, b);
            let (pos, neg) = match self {
                CostEngine::Pjrt(engine) => engine.cost_eval(&adj, &onehot, &valid)?,
                CostEngine::Native => fallback::dense_cost_block(&adj, &onehot, &valid),
            };
            pos_total += pos;
            neg_total += neg;
        }
        Ok(Cost { positive: pos_total as u64, negative: neg_total as u64 })
    }

    /// Score K clusterings of a single-block graph (n ≤ BLOCK_N) — the
    /// Remark 14 best-of-K hot path. Pads the batch to BLOCK_BATCH.
    pub fn cost_batch_single_block(
        &self,
        g: &Graph,
        clusterings: &[Clustering],
    ) -> Result<Vec<Cost>> {
        assert!(g.n() <= BLOCK_N, "single-block scorer needs n ≤ {BLOCK_N}");
        let (adj, valid) = whole_graph_tensors(g);
        let mut out = Vec::with_capacity(clusterings.len());
        for group in clusterings.chunks(BLOCK_BATCH) {
            match self {
                CostEngine::Pjrt(engine) => {
                    let mut onehots = vec![0f32; BLOCK_BATCH * BLOCK_N * BLOCK_N];
                    for (i, c) in group.iter().enumerate() {
                        let oh = whole_graph_onehot(g, c);
                        onehots[i * BLOCK_N * BLOCK_N..(i + 1) * BLOCK_N * BLOCK_N]
                            .copy_from_slice(&oh);
                    }
                    let scored = engine.cost_eval_batch(&adj, &onehots, &valid)?;
                    for (i, _) in group.iter().enumerate() {
                        let (pos, neg) = scored[i];
                        out.push(Cost { positive: pos as u64, negative: neg as u64 });
                    }
                }
                CostEngine::Native => {
                    for c in group {
                        let oh = whole_graph_onehot(g, c);
                        let (pos, neg) = fallback::dense_cost_block(&adj, &oh, &valid);
                        out.push(Cost { positive: pos as u64, negative: neg as u64 });
                    }
                }
            }
        }
        Ok(out)
    }

    /// Bad-triangle count of a single-block graph (n ≤ BLOCK_N).
    pub fn bad_triangles_single_block(&self, g: &Graph) -> Result<u64> {
        assert!(g.n() <= BLOCK_N, "single-block triangles needs n ≤ {BLOCK_N}");
        let (adj, valid) = whole_graph_tensors(g);
        let t = match self {
            CostEngine::Pjrt(engine) => engine.triangles(&adj, &valid)?,
            CostEngine::Native => fallback::dense_triangles_block(&adj, &valid),
        };
        Ok(t as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pivot::pivot_random;
    use crate::cluster::cost::cost;
    use crate::cluster::triangles::count_bad_triangles;
    use crate::graph::generators::lambda_arboric;
    use crate::util::rng::Rng;

    #[test]
    fn native_engine_matches_sparse_cost() {
        let mut rng = Rng::new(230);
        let engine = CostEngine::native();
        for trial in 0..5 {
            let g = lambda_arboric(500, 1 + trial % 3, &mut rng);
            let c = pivot_random(&g, &mut rng);
            assert_eq!(engine.cost(&g, &c).unwrap(), cost(&g, &c), "trial {trial}");
        }
    }

    #[test]
    fn native_batch_matches_individual() {
        let mut rng = Rng::new(231);
        let g = lambda_arboric(200, 2, &mut rng);
        let engine = CostEngine::native();
        let cs: Vec<_> = (0..5).map(|_| pivot_random(&g, &mut rng)).collect();
        let batch = engine.cost_batch_single_block(&g, &cs).unwrap();
        for (i, c) in cs.iter().enumerate() {
            assert_eq!(batch[i], cost(&g, c), "candidate {i}");
        }
    }

    #[test]
    fn native_triangles_match() {
        let mut rng = Rng::new(232);
        let g = lambda_arboric(180, 2, &mut rng);
        let engine = CostEngine::native();
        assert_eq!(
            engine.bad_triangles_single_block(&g).unwrap(),
            count_bad_triangles(&g)
        );
    }
}
